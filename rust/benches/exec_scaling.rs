//! Round-throughput scaling of the exec subsystem: the same FL run driven
//! by 1, 2, 4 and 8 workers, with a warmup run per configuration so every
//! worker's runtime is built and compiled before the timed run. Verifies
//! the determinism contract along the way (every worker count — and the
//! work-stealing dispatch policy — must reproduce the sequential round
//! records bit-for-bit) and emits `BENCH_exec.json` with seconds /
//! rounds-per-second / speedup rows.
//!
//! Also runs the **heavy-tail dispatch sweep**: deterministic
//! work-stealing vs round-robin schedules over one round of FedAvg plan
//! costs (the paper's Fig. 4 straggler tail), workers ∈ {1, 2, 4, 8},
//! emitting utilization + makespan + steals. The sweep is virtual-time
//! only, so it runs — and its utilization gate is asserted — even when
//! the AOT artifacts are absent.
//!
//! Knobs: `FEDCORE_SCALE`, `FEDCORE_ROUNDS`, `FEDCORE_CLIENTS`,
//! `FEDCORE_BENCH_OUT` (output path, default `BENCH_exec.json`),
//! `FEDCORE_OBS_OUT` (also write a schema-v1 observability trace of the
//! virtual-time sweep — one trace round per pool width, the stealing
//! schedule's ledger as per-job spans — so CI can validate the JSONL
//! schema and render `fedcore report` without artifacts).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark};
use fedcore::exec::{plan_schedule, DispatchPolicy, JobKind, ScheduleEntry, ScheduleTrace};
use fedcore::expt;
use fedcore::fl::{CoresetMode, Engine, RunConfig, Strategy};
use fedcore::metrics::RunResult;
use fedcore::obs::{Counter, Jsonl, Phase, Record, Recorder as _};
use fedcore::sim::Fleet;
use fedcore::util::json::{write_json, Json};
use fedcore::util::rng::Rng;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// The heavy-tail dispatch sweep (pure virtual time — no runtime):
/// one round of FedAvg full-set plans over a 30%-straggler fleet gives
/// the heavy-tailed cost vector; round-robin dealing and deterministic
/// work stealing schedule it at each pool width. Asserts work
/// conservation, that stealing never loses to dealing, and the tentpole
/// gate: **strictly** better utilization at ≥ 4 workers.
fn dispatch_sweep() -> Vec<Json> {
    let mut size_rng = Rng::new(7).split(0xD157);
    let sizes = data::partition::power_law_sizes(&mut size_rng, 48, 69.0, 1.4, 8);
    let mut fleet_rng = Rng::new(7).split(0xF1EE7);
    let fleet = Fleet::new(&mut fleet_rng, sizes, 6, 30.0);
    // FedAvg ignores τ, so its plans carry the fleet's raw heavy-tailed
    // round times (the Fig. 4 tail) — the workload dispatch is about.
    let costs: Vec<f64> = (0..fleet.num_clients())
        .map(|i| Strategy::FedAvg.plan(&fleet, i).sim_time(&fleet, i))
        .collect();

    println!(
        "== dispatch sweep: {} heavy-tail FedAvg plans | round_robin vs work_stealing ==",
        costs.len()
    );
    println!(
        "{:>8} {:>14} {:>12} {:>12} {:>8}",
        "workers", "policy", "makespan", "util", "steals"
    );
    // FEDCORE_OBS_OUT: trace the sweep itself. Widths become trace
    // rounds; the stealing schedules' ledgers become per-job spans. The
    // file passes `fedcore report --check` and renders a full report, so
    // CI exercises the whole obs pipeline without artifacts.
    let obs: Option<Jsonl> = std::env::var("FEDCORE_OBS_OUT").ok().map(|path| {
        let rec = Jsonl::create(&path, "bench", fedcore::util::bench::provenance(7, 4, 1.0))
            .expect("creating obs trace");
        rec.record(&Record::Event {
            name: "run_start",
            round: 0,
            fields: vec![("rounds", Json::Num(4.0)), ("strategy", Json::Str("sweep".into()))],
        });
        println!("(tracing dispatch sweep to {path})");
        rec
    });
    let mut ledger = ScheduleTrace::default();

    let mut rows = Vec::new();
    for (r, workers) in [1usize, 2, 4, 8].into_iter().enumerate() {
        let round_w0 = obs.as_ref().map_or(0, |rec| rec.now_ns());
        let rr = plan_schedule(DispatchPolicy::RoundRobin, &costs, workers);
        let ws = plan_schedule(DispatchPolicy::WorkStealing, &costs, workers);
        let plan_w1 = obs.as_ref().map_or(0, |rec| rec.now_ns());
        assert!(
            (rr.busy_seconds() - ws.busy_seconds()).abs() < 1e-9,
            "dispatch must conserve work"
        );
        assert!(
            ws.makespan <= rr.makespan + 1e-9,
            "stealing lost to round-robin at {workers} workers"
        );
        if workers >= 4 {
            // The tentpole's utilization gate: on a heavy-tailed round,
            // deterministic stealing strictly beats round-robin dealing
            // once the pool is wide enough to go idle under it — while
            // model outputs stay bit-identical (asserted in the timed
            // section below, and in tests/proptest_dispatch.rs).
            assert!(
                ws.utilization() > rr.utilization(),
                "work-stealing did not improve utilization at {workers} workers: {} vs {}",
                ws.utilization(),
                rr.utilization()
            );
        }
        for (policy, s) in [("round_robin", &rr), ("work_stealing", &ws)] {
            println!(
                "{workers:>8} {policy:>14} {:>12.2} {:>12.3} {:>8}",
                s.makespan,
                s.utilization(),
                s.steals()
            );
            rows.push(obj(vec![
                ("workers", num(workers as f64)),
                ("policy", Json::Str(policy.to_string())),
                ("makespan", num(s.makespan)),
                ("utilization", num(s.utilization())),
                ("idle_seconds", num(s.idle_seconds())),
                ("steals", num(s.steals() as f64)),
            ]));
        }
        if let Some(rec) = &obs {
            let round_w1 = rec.now_ns();
            let sp = |phase, wall, virt| Record::span(phase, r, wall, virt);
            rec.record(&sp(Phase::Round, (round_w0, round_w1), (0.0, ws.makespan)));
            rec.record(&sp(Phase::Dispatch, (round_w0, plan_w1), (0.0, 0.0)));
            rec.record(&Record::CounterVal {
                counter: Counter::Steals,
                round: r,
                value: ws.steals() as u64,
            });
            if let Some(m) = fedcore::obs::mem::sample() {
                rec.record(&Record::Mem { round: r, rss_pages: m.pages, rss_bytes: m.bytes });
            }
            let mut stolen_so_far = 0usize;
            for i in 0..costs.len() {
                stolen_so_far += ws.stolen[i] as usize;
                ledger.entries.push(ScheduleEntry {
                    round: r,
                    kind: JobKind::Client,
                    job_idx: i,
                    worker: ws.assignment[i],
                    steal_count: stolen_so_far,
                    start: ws.start[i],
                    end: ws.end[i],
                });
            }
        }
    }
    if let Some(rec) = &obs {
        fedcore::obs::emit_schedule(rec, &ledger);
    }
    rows
}

fn main() {
    // Virtual-time sweep first: it needs no artifacts, so BENCH_exec.json
    // always carries the dispatch rows even on stub-backend builds.
    let sweep_rows = dispatch_sweep();

    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };
    let scale = expt::env_f64("FEDCORE_SCALE", 1.0) * 0.35;
    let rounds = expt::env_usize("FEDCORE_ROUNDS", 6);
    let base = RunConfig {
        strategy: Strategy::FedCore,
        rounds,
        epochs: 6,
        clients_per_round: expt::env_usize("FEDCORE_CLIENTS", 8),
        lr: 0.01,
        straggler_pct: 30.0,
        seed: 7,
        coreset_method: Method::FasterPam,
        coreset_mode: CoresetMode::Adaptive,
        eval_every: 2,
        eval_cap: 256,
        workers: 1,
        trace: None,
        overlap: None,
        verbose: false,
        ..RunConfig::default()
    };

    let mut rows = Vec::new();
    if let Some(rt) = expt::try_runtime() {
        rt.warmup().expect("warmup");
        let ds = Arc::new(data::generate(bench, scale, &rt.manifest().vocab, 7));
        println!(
            "\n== exec scaling: {} | {} clients, {} samples | {} rounds × {} epochs, K = {} ==",
            bench.label(),
            ds.num_clients(),
            ds.total_samples(),
            base.rounds,
            base.epochs,
            base.clients_per_round
        );
        println!(
            "{:>8} {:>14} {:>10} {:>12} {:>9}",
            "workers", "dispatch", "seconds", "rounds/s", "speedup"
        );

        let mut reference: Option<RunResult> = None;
        let mut baseline = f64::NAN;
        // The worker sweep under round-robin, plus a work-stealing run at
        // the widest pool — same model outputs, different placement.
        let mut grid: Vec<(usize, DispatchPolicy)> =
            [1usize, 2, 4, 8].iter().map(|&w| (w, DispatchPolicy::RoundRobin)).collect();
        grid.push((8, DispatchPolicy::WorkStealing));
        for (workers, dispatch) in grid {
            let mut cfg = base.clone();
            cfg.workers = workers;
            cfg.dispatch = dispatch;
            let engine = Engine::new(&rt, &ds, cfg).expect("engine");
            // Warmup run: builds + compiles each worker's pinned runtime so
            // the timed run measures round throughput, not compilation.
            let warm = engine.run().expect("warmup run");
            let t0 = Instant::now();
            let result = engine.run().expect("timed run");
            let secs = t0.elapsed().as_secs_f64();

            // Determinism contract: identical round records at any worker
            // count and under either dispatch policy (the warmup must also
            // match the timed run — same seed, same run).
            assert_eq!(
                warm.final_params, result.final_params,
                "run is not replay-deterministic"
            );
            match &reference {
                None => reference = Some(result.clone()),
                Some(seq) => {
                    assert_eq!(
                        seq.final_params,
                        result.final_params,
                        "workers={workers} {} diverged from sequential",
                        dispatch.label()
                    );
                    for (a, b) in seq.rounds.iter().zip(&result.rounds) {
                        assert_eq!(
                            a.train_loss.to_bits(),
                            b.train_loss.to_bits(),
                            "workers={workers} {} diverged at round {}",
                            dispatch.label(),
                            a.round
                        );
                        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
                        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
                    }
                    assert_eq!(seq.to_csv(), result.to_csv(), "model CSV diverged");
                }
            }

            if workers == 1 {
                baseline = secs;
            }
            let speedup = baseline / secs;
            let rps = rounds as f64 / secs;
            let (steals, idle) = result.dispatch_totals();
            println!(
                "{workers:>8} {:>14} {secs:>10.2} {rps:>12.2} {speedup:>8.2}x",
                dispatch.label()
            );
            rows.push(obj(vec![
                ("workers", num(workers as f64)),
                ("dispatch", Json::Str(dispatch.label().to_string())),
                ("seconds", num(secs)),
                ("rounds_per_sec", num(rps)),
                ("speedup", num(speedup)),
                ("steals", num(steals as f64)),
                ("worker_idle", num(idle)),
            ]));
        }
    } else {
        println!("(no runtime: timed scaling rows skipped; dispatch sweep recorded)");
    }

    let out = obj(vec![
        ("bench", Json::Str("exec_scaling".into())),
        ("benchmark", Json::Str(bench.label())),
        ("strategy", Json::Str("FedCore".into())),
        ("rounds", num(rounds as f64)),
        ("clients_per_round", num(base.clients_per_round as f64)),
        ("epochs", num(base.epochs as f64)),
        ("provenance", fedcore::util::bench::provenance(base.seed, rounds, scale)),
        ("dispatch_sweep", Json::Arr(sweep_rows)),
        ("results", Json::Arr(rows)),
    ]);
    let mut text = String::new();
    write_json(&out, &mut text);
    text.push('\n');
    let path = std::env::var("FEDCORE_BENCH_OUT").unwrap_or_else(|_| "BENCH_exec.json".into());
    std::fs::write(&path, text).expect("writing bench output");
    println!("\nwrote {path}");
}
