//! Round-throughput scaling of the exec subsystem: the same FL run driven
//! by 1, 2, 4 and 8 workers, with a warmup run per configuration so every
//! worker's runtime is built and compiled before the timed run. Verifies
//! the determinism contract along the way (every worker count must
//! reproduce the sequential round records bit-for-bit) and emits
//! `BENCH_exec.json` with seconds / rounds-per-second / speedup rows.
//!
//! Knobs: `FEDCORE_SCALE`, `FEDCORE_ROUNDS`, `FEDCORE_CLIENTS`,
//! `FEDCORE_BENCH_OUT` (output path, default `BENCH_exec.json`).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark};
use fedcore::expt;
use fedcore::fl::{CoresetMode, Engine, RunConfig, Strategy};
use fedcore::metrics::RunResult;
use fedcore::util::json::{write_json, Json};

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() {
    let rt = expt::runtime_or_exit();
    rt.warmup().expect("warmup");

    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };
    let scale = expt::env_f64("FEDCORE_SCALE", 1.0) * 0.35;
    let ds = Arc::new(data::generate(bench, scale, &rt.manifest().vocab, 7));
    let rounds = expt::env_usize("FEDCORE_ROUNDS", 6);
    let base = RunConfig {
        strategy: Strategy::FedCore,
        rounds,
        epochs: 6,
        clients_per_round: expt::env_usize("FEDCORE_CLIENTS", 8),
        lr: 0.01,
        straggler_pct: 30.0,
        seed: 7,
        coreset_method: Method::FasterPam,
        coreset_mode: CoresetMode::Adaptive,
        eval_every: 2,
        eval_cap: 256,
        workers: 1,
        trace: None,
        overlap: None,
        verbose: false,
        ..RunConfig::default()
    };

    println!(
        "== exec scaling: {} | {} clients, {} samples | {} rounds × {} epochs, K = {} ==",
        bench.label(),
        ds.num_clients(),
        ds.total_samples(),
        base.rounds,
        base.epochs,
        base.clients_per_round
    );
    println!("{:>8} {:>10} {:>12} {:>9}", "workers", "seconds", "rounds/s", "speedup");

    let mut reference: Option<RunResult> = None;
    let mut baseline = f64::NAN;
    let mut rows = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = base.clone();
        cfg.workers = workers;
        let engine = Engine::new(&rt, &ds, cfg).expect("engine");
        // Warmup run: builds + compiles each worker's pinned runtime so the
        // timed run measures round throughput, not compilation.
        let warm = engine.run().expect("warmup run");
        let t0 = Instant::now();
        let result = engine.run().expect("timed run");
        let secs = t0.elapsed().as_secs_f64();

        // Determinism contract: identical round records at any worker count
        // (the warmup must also match the timed run — same seed, same run).
        assert_eq!(warm.final_params, result.final_params, "run is not replay-deterministic");
        match &reference {
            None => reference = Some(result.clone()),
            Some(seq) => {
                for (a, b) in seq.rounds.iter().zip(&result.rounds) {
                    assert_eq!(
                        a.train_loss.to_bits(),
                        b.train_loss.to_bits(),
                        "workers={workers} diverged from sequential at round {}",
                        a.round
                    );
                    assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
                    assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
                }
            }
        }

        if workers == 1 {
            baseline = secs;
        }
        let speedup = baseline / secs;
        let rps = rounds as f64 / secs;
        println!("{workers:>8} {secs:>10.2} {rps:>12.2} {speedup:>8.2}x");
        rows.push(obj(vec![
            ("workers", num(workers as f64)),
            ("seconds", num(secs)),
            ("rounds_per_sec", num(rps)),
            ("speedup", num(speedup)),
        ]));
    }

    let out = obj(vec![
        ("bench", Json::Str("exec_scaling".into())),
        ("benchmark", Json::Str(bench.label())),
        ("strategy", Json::Str("FedCore".into())),
        ("rounds", num(rounds as f64)),
        ("clients_per_round", num(base.clients_per_round as f64)),
        ("epochs", num(base.epochs as f64)),
        ("provenance", fedcore::util::bench::provenance(base.seed, rounds, scale)),
        ("results", Json::Arr(rows)),
    ]);
    let mut text = String::new();
    write_json(&out, &mut text);
    text.push('\n');
    let path = std::env::var("FEDCORE_BENCH_OUT").unwrap_or_else(|_| "BENCH_exec.json".into());
    std::fs::write(&path, text).expect("writing bench output");
    println!("\nwrote {path}");
}
