//! Ablation: FedCore's k-medoids coreset (FasterPAM) vs the design
//! alternatives DESIGN.md calls out — PAM (same objective, slower),
//! greedy k-center (covering objective), and uniform random selection —
//! measured both on (a) the Eq. (5) objective over real gradient features
//! and (b) end-to-end FL accuracy when plugged into the FedCore strategy.

use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark};
use fedcore::expt;
use fedcore::fl::client::{build_dist, gather_features};
use fedcore::fl::{Engine, Strategy};
use fedcore::config::ExperimentConfig;
use fedcore::util::rng::Rng;

fn main() {
    let rt = expt::runtime_or_exit();
    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };
    let ds = std::sync::Arc::new(data::generate(
        bench,
        expt::bench_scale(bench),
        &rt.manifest().vocab,
        7,
    ));
    let model = rt.manifest().model("logreg").unwrap().clone();

    // ---- (a) Eq. (5) objective on a real straggler client's features ----
    let big = (0..ds.num_clients()).max_by_key(|&i| ds.clients[i].len()).unwrap();
    let shard = &ds.clients[big];
    let m = shard.len();
    // warm one epoch so logreg features are not label-degenerate at w=0
    let mut params = model.init_params.clone();
    let bsz = rt.manifest().train_batch;
    let idxs: Vec<usize> = (0..m).collect();
    for chunk in idxs.chunks(bsz) {
        let (x, y, w) = shard.gather_batch(chunk, None, bsz);
        params = rt.train_step(&model, &params, &params, &x, &y, &w, 0.05, 0.0).unwrap().params;
    }
    let features = gather_features(&rt, &model, shard, &params).unwrap();
    let dist = build_dist(&rt, &features, m).unwrap();

    println!("(a) k-medoids objective on client {big} (m = {m}) gradient features:");
    println!("{:>6} {:<14} {:>12} {:>10}", "b", "method", "objective", "ms");
    for frac in [0.1, 0.3] {
        let b = ((m as f64 * frac) as usize).max(1);
        for method in [Method::FasterPam, Method::Pam, Method::GreedyKCenter, Method::Random] {
            if method == Method::Pam && m * b > 60_000 {
                continue;
            }
            let mut rng = Rng::new(3);
            let t0 = std::time::Instant::now();
            let cs = fedcore::coreset::select(&dist, b, method, &mut rng);
            println!(
                "{b:>6} {:<14} {:>12.3} {:>10.1}",
                method.label(),
                cs.cost,
                t0.elapsed().as_secs_f64() * 1e3
            );
        }
    }

    // ---- (b) end-to-end: FedCore accuracy per solver ----
    println!("\n(b) end-to-end FedCore accuracy by coreset solver (30% stragglers):");
    println!("{:<14} {:>9} {:>10}", "solver", "acc (%)", "final loss");
    let mut accs = Vec::new();
    for method in [Method::FasterPam, Method::GreedyKCenter, Method::Random] {
        let mut cfg = ExperimentConfig::scaled_preset(bench, expt::bench_scale(bench))
            .with_strategy(Strategy::FedCore);
        cfg.run.rounds = expt::bench_rounds(bench);
        cfg.run.lr = expt::bench_lr(bench);
        cfg.run.straggler_pct = 30.0;
        cfg.run.coreset_method = method;
        cfg.run.eval_every = 2;
        let engine = Engine::new(&rt, &ds, cfg.run.clone()).unwrap();
        let r = engine.run().unwrap();
        println!(
            "{:<14} {:>9.1} {:>10.4}",
            method.label(),
            100.0 * r.best_accuracy(),
            r.final_train_loss()
        );
        accs.push((method, r.best_accuracy()));
    }
    let fp = accs.iter().find(|(m, _)| *m == Method::FasterPam).unwrap().1;
    let rnd = accs.iter().find(|(m, _)| *m == Method::Random).unwrap().1;
    println!(
        "\nFasterPAM vs Random coresets: {:+.1} accuracy pts (paper's gradient-matching rationale)",
        100.0 * (fp - rnd)
    );

    // ---- (c) adaptive (per-round gradient-space) vs static (§4.3 d̃) ----
    println!("\n(c) FedCore coreset mode ablation (paper Q1 — adaptivity):");
    println!("{:<10} {:>9} {:>10}", "mode", "acc (%)", "final loss");
    for (label, mode) in [
        ("adaptive", fedcore::fl::CoresetMode::Adaptive),
        ("static", fedcore::fl::CoresetMode::Static),
    ] {
        let mut cfg = ExperimentConfig::scaled_preset(bench, expt::bench_scale(bench))
            .with_strategy(Strategy::FedCore);
        cfg.run.rounds = expt::bench_rounds(bench);
        cfg.run.lr = expt::bench_lr(bench);
        cfg.run.straggler_pct = 30.0;
        cfg.run.coreset_mode = mode;
        cfg.run.eval_every = 2;
        let engine = Engine::new(&rt, &ds, cfg.run.clone()).unwrap();
        let t0 = std::time::Instant::now();
        let r = engine.run().unwrap();
        println!(
            "{label:<10} {:>9.1} {:>10.4}   (wall {:.1}s)",
            100.0 * r.best_accuracy(),
            r.final_train_loss(),
            t0.elapsed().as_secs_f64()
        );
    }
    println!("(adaptive tracks the evolving model — the paper's Q1 answer; static\n trades a little accuracy for zero per-round construction cost)");
}
