//! Reproduces **Table 1 + Figure 2**: dataset statistics and the
//! per-client sample-size distributions, at full paper scale (generation
//! only — no training — so paper scale is cheap).
//!
//! Paper values:  MNIST 1,000 clients / 69,035 samples (mean 69, std 106);
//! Shakespeare 143 / 517,106 (3,616 / 6,808); Synthetic 30 / 20,101
//! (670 / 1,148).

use fedcore::data::{self, partition, Benchmark};

fn main() {
    let vocab: Vec<char> =
        "\x00 abcdefghijklmnopqrstuvwxyz.,;:!?'-\n\"()[]0123456789&_ABCDEFGHIJ"
            .chars()
            .collect();

    println!("Table 1: Statistics of the benchmarks (paper scale)");
    println!(
        "{:<14} {:>8} {:>9} {:>9} {:>9}",
        "Dataset", "Clients", "Samples", "mean", "std"
    );
    let paper = [
        ("MNIST", 1000usize, 69_035usize, 69.0, 106.0),
        ("Shakespeare", 143, 517_106, 3_616.0, 6_808.0),
        ("Synthetic", 30, 20_101, 670.0, 1_148.0),
    ];

    let benches = [
        (Benchmark::Mnist, "MNIST"),
        (Benchmark::Shakespeare, "Shakespeare"),
        (Benchmark::Synthetic { alpha: 1.0, beta: 1.0 }, "Synthetic"),
    ];

    let mut all_sizes = Vec::new();
    for (bench, label) in benches {
        let t0 = std::time::Instant::now();
        let ds = data::generate(bench, 1.0, &vocab, 7);
        let s = partition::size_stats(&ds.sizes());
        println!(
            "{label:<14} {:>8} {:>9} {:>9.0} {:>9.0}   (gen {:.1}s)",
            s.clients,
            s.total,
            s.mean,
            s.std,
            t0.elapsed().as_secs_f64()
        );
        all_sizes.push((label, ds.sizes()));
    }
    println!("\npaper reference:");
    for (label, clients, samples, mean, std) in paper {
        println!("{label:<14} {clients:>8} {samples:>9} {mean:>9.0} {std:>9.0}");
    }

    println!("\nFigure 2: distribution of training samples per client");
    for (label, sizes) in &all_sizes {
        let s = partition::size_stats(sizes);
        println!("\n{label} (min {} max {}):", s.min, s.max);
        for (edge, count) in partition::size_histogram(sizes, 14) {
            let bar = "#".repeat(((count as f64).ln_1p() * 6.0) as usize);
            println!("  [{edge:>6}+) {count:>5} |{bar}");
        }
    }

    // Sanity for the harness: long-tailed shape must hold (std ≳ mean for
    // shakespeare/synthetic; std comparable to mean for MNIST).
    for (label, sizes) in &all_sizes {
        let s = partition::size_stats(sizes);
        assert!(s.std > 0.4 * s.mean, "{label}: tail too thin (std {} mean {})", s.std, s.mean);
    }
    println!("\nshape check passed: every benchmark keeps its power-law tail");
}
