//! Reproduces **Figure 4**: per-client round-length distribution on the
//! MNIST benchmark at 30% stragglers, log-scale counts.
//!
//! Expected shape: FedAvg has a long tail stretching to many multiples of
//! the deadline (the paper shows >11×); FedAvg-DS / FedProx / FedCore all
//! stay ≤ 1×, with FedCore's mass hugging the deadline from below most
//! tightly (it converts the whole budget into gradient steps).

use fedcore::data::Benchmark;
use fedcore::expt;
use fedcore::metrics::Histogram;

fn main() {
    let rt = expt::runtime_or_exit();
    let runs = expt::run_cell(&rt, Benchmark::Mnist, 30.0, 7).expect("cell");

    println!("Fig 4: round-length distribution, MNIST @ 30% stragglers (x = t/τ)");
    let mut tails = Vec::new();
    for r in &runs {
        let times = r.client_times_normalized();
        let h = Histogram::new(&times, 0.25, 4.0);
        println!("\n{}", h.render(&format!("--- {} ({} client-rounds) ---", r.strategy, times.len())));
        let over = h.tail_fraction(1.01);
        let near = times.iter().filter(|&&t| (0.75..=1.01).contains(&t)).count() as f64
            / times.len().max(1) as f64;
        tails.push((r.strategy.clone(), over, near));
    }

    println!("{:<12} {:>14} {:>22}", "strategy", "frac > τ", "frac in [0.75τ, τ]");
    for (name, over, near) in &tails {
        println!("{name:<12} {over:>14.3} {near:>22.3}");
    }

    // Shape checks: only FedAvg exceeds τ; FedCore is the tightest to τ
    // among the deadline-aware strategies.
    let get = |n: &str| tails.iter().find(|t| t.0 == n).unwrap();
    assert!(get("FedAvg").1 > 0.0, "FedAvg shows no tail beyond τ");
    for n in ["FedAvg-DS", "FedProx", "FedCore"] {
        assert!(get(n).1 == 0.0, "{n} exceeded τ");
    }
    println!(
        "\nFedCore near-deadline mass {:.2} vs FedProx {:.2} vs FedAvg-DS {:.2} \
         (paper: FedCore most tightly clustered at τ)",
        get("FedCore").2,
        get("FedProx").2,
        get("FedAvg-DS").2
    );
}
