//! Reproduces **Figure 6** (appendix B.2): test-accuracy curves for
//! FedAvg-DS, FedProx and FedCore at 10% and 30% stragglers.
//!
//! Same runs as Fig. 3 but plotting the accuracy trace; the paper's shape
//! is FedCore on top or tied, FedAvg-DS lowest on heterogeneous synthetic.

use fedcore::data::{paper_benchmarks, Benchmark};
use fedcore::expt;

fn main() {
    let rt = expt::runtime_or_exit();
    let benches: Vec<Benchmark> = if expt::full_scale() {
        paper_benchmarks()
    } else {
        vec![Benchmark::Synthetic { alpha: 0.5, beta: 0.5 }, Benchmark::Mnist]
    };

    for bench in benches {
        for s in [10.0, 30.0] {
            let runs = expt::run_cell(&rt, bench, s, 7).expect("cell");
            println!(
                "\n== Fig 6: {} @ {}% stragglers — test accuracy (%) per round ==",
                bench.label(),
                s
            );
            print!("{:>5}", "round");
            for r in &runs {
                print!(" {:>10}", r.strategy);
            }
            println!();
            for i in 0..runs[0].rounds.len() {
                print!("{i:>5}");
                for r in &runs {
                    print!(" {:>10.1}", 100.0 * r.rounds[i].test_acc);
                }
                println!();
            }
            let best = |name: &str| {
                100.0
                    * runs
                        .iter()
                        .find(|r| r.strategy == name)
                        .unwrap()
                        .best_accuracy()
            };
            println!(
                "best: FedCore {:.1} | FedProx {:.1} | FedAvg-DS {:.1} | FedAvg {:.1}",
                best("FedCore"),
                best("FedProx"),
                best("FedAvg-DS"),
                best("FedAvg")
            );
        }
    }
}
