//! Reproduces **Figure 3**: training-loss curves for FedAvg-DS, FedProx
//! and FedCore at 10% and 30% stragglers (the paper plots these three;
//! we include FedAvg as the deadline-oblivious reference too).
//!
//! Default covers Synthetic(1,1) + MNIST (the curves where the paper's
//! separation is starkest); `FEDCORE_FULL=1` runs all benchmarks.

use fedcore::data::{paper_benchmarks, Benchmark};
use fedcore::expt;

fn main() {
    let rt = expt::runtime_or_exit();
    let benches: Vec<Benchmark> = if expt::full_scale() {
        paper_benchmarks()
    } else {
        vec![Benchmark::Synthetic { alpha: 1.0, beta: 1.0 }, Benchmark::Mnist]
    };

    for bench in benches {
        for s in [10.0, 30.0] {
            let runs = expt::run_cell(&rt, bench, s, 7).expect("cell");
            println!("\n== Fig 3: {} @ {}% stragglers — train loss per round ==", bench.label(), s);
            print!("{:>5}", "round");
            for r in &runs {
                print!(" {:>10}", r.strategy);
            }
            println!();
            for i in 0..runs[0].rounds.len() {
                print!("{i:>5}");
                for r in &runs {
                    print!(" {:>10.4}", r.rounds[i].train_loss);
                }
                println!();
            }

            // Shape: FedCore's final loss ≤ FedAvg-DS's (the paper's key
            // separation — DS drops unique straggler data).
            let fin = |name: &str| {
                runs.iter()
                    .find(|r| r.strategy == name)
                    .unwrap()
                    .final_train_loss()
            };
            println!(
                "final: FedCore {:.4} | FedProx {:.4} | FedAvg-DS {:.4} | FedAvg {:.4}",
                fin("FedCore"),
                fin("FedProx"),
                fin("FedAvg-DS"),
                fin("FedAvg")
            );
        }
    }
}
