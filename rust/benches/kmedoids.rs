//! §4.2 claim bench: "FasterPAM quickly solves the k-medoids problem,
//! generating coresets for large datasets within one second."
//!
//! Times BUILD+FasterPAM over gradient-feature clouds of m = 256…4096
//! points (k = m/10, the typical straggler compression), and compares
//! against classic PAM on the sizes where PAM is feasible.

use std::time::Duration;

use fedcore::coreset::{self, distance, Method};
use fedcore::util::bench::{bench, run_group};
use fedcore::util::rng::Rng;

fn features(rng: &mut Rng, n: usize, dim: usize) -> Vec<f32> {
    // Clustered cloud: 10 label-ish clusters, like softmax(z) − onehot(y).
    (0..n)
        .flat_map(|i| {
            let c = i % 10;
            (0..dim)
                .map(|d| if d == c { -0.8 } else { 0.1 } + 0.05 * rng.normal() as f32)
                .collect::<Vec<f32>>()
        })
        .collect()
}

fn main() {
    let mut rng = Rng::new(42);
    let dim = 64;
    let budget = Duration::from_secs(5);

    let mut results = Vec::new();
    for m in [256usize, 512, 1024, 2048, 4096] {
        let f = features(&mut rng, m, dim);
        let t0 = std::time::Instant::now();
        let dist = distance::from_features_cpu(&f, m, dim);
        let dist_ms = t0.elapsed().as_secs_f64() * 1e3;
        let k = (m / 10).max(1);

        let mut seed_rng = Rng::new(7);
        let r = bench(
            &format!("FasterPAM m={m} k={k} (dist {dist_ms:.0}ms)"),
            20,
            budget,
            || coreset::select(&dist, k, Method::FasterPam, &mut seed_rng),
        );
        // The paper's engineering claim.
        if m == 4096 {
            assert!(
                r.mean_ns < 1e9,
                "FasterPAM at m=4096 took {:.2}s — paper claims <1s",
                r.mean_ns / 1e9
            );
        }
        results.push(r);

        if m <= 256 {
            // classic PAM: O(n²k) per sweep — already ~500 ms here, the
            // runtime gap FasterPAM exists to close.
            let mut seed_rng = Rng::new(7);
            results.push(bench(&format!("PAM       m={m} k={k}"), 5, budget, || {
                coreset::select(&dist, k, Method::Pam, &mut seed_rng)
            }));
        }
    }
    run_group("k-medoids solvers (paper §4.2: FasterPAM <1s at large m)", results);

    // Quality parity snapshot at m=512.
    let f = features(&mut rng, 512, dim);
    let dist = distance::from_features_cpu(&f, 512, dim);
    let mut qrng = Rng::new(9);
    println!("\nsolution quality at m=512, k=51 (objective, lower is better):");
    for method in [Method::FasterPam, Method::Pam, Method::GreedyKCenter, Method::Random] {
        let cs = coreset::select(&dist, 51, method, &mut qrng);
        println!("  {:<14} {:>10.3}", method.label(), cs.cost);
    }
}
