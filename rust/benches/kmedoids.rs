//! §4.2 claim bench: "FasterPAM quickly solves the k-medoids problem,
//! generating coresets for large datasets within one second."
//!
//! Times BUILD+FasterPAM over gradient-feature clouds of m = 256…4096
//! points (k = m/10, the typical straggler compression), compares against
//! classic PAM on the sizes where PAM is feasible, then runs the
//! **parallel coreset sweep**: the sharded hot path (distance tiles +
//! chunked BUILD + windowed SWAP) at workers ∈ {1, 2, 4, 8}, cold vs
//! warm-started, with an in-bench sharded≡sequential assertion (medoids
//! must match bit-for-bit before any timing row is trusted). Emits
//! `BENCH_coreset.json` with per-width timings and speedups.
//!
//! Knobs: `FEDCORE_SCALE` (scales the point counts), `FEDCORE_ROUNDS`
//! (max timed iterations per sweep row), `FEDCORE_BENCH_OUT` (output
//! path, default `BENCH_coreset.json`).

use std::collections::BTreeMap;
use std::time::Duration;

use fedcore::coreset::{self, distance, Method};
use fedcore::expt;
use fedcore::util::bench::{bench, run_group};
use fedcore::util::json::{write_json, Json};
use fedcore::util::rng::Rng;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn features(rng: &mut Rng, n: usize, dim: usize) -> Vec<f32> {
    // Clustered cloud: 10 label-ish clusters, like softmax(z) − onehot(y).
    (0..n)
        .flat_map(|i| {
            let c = i % 10;
            (0..dim)
                .map(|d| if d == c { -0.8 } else { 0.1 } + 0.05 * rng.normal() as f32)
                .collect::<Vec<f32>>()
        })
        .collect()
}

fn main() {
    let mut rng = Rng::new(42);
    let dim = 64;
    let budget = Duration::from_secs(5);
    let scale = expt::env_f64("FEDCORE_SCALE", 1.0);
    let iters = expt::env_usize("FEDCORE_ROUNDS", 6).max(1);
    let m_of = |m: usize| ((m as f64 * scale) as usize).max(64);

    let mut results = Vec::new();
    for m in [256usize, 512, 1024, 2048, 4096].map(m_of) {
        let f = features(&mut rng, m, dim);
        let t0 = std::time::Instant::now();
        let dist = distance::from_features_cpu(&f, m, dim);
        let dist_ms = t0.elapsed().as_secs_f64() * 1e3;
        let k = (m / 10).max(1);

        let mut seed_rng = Rng::new(7);
        let r = bench(
            &format!("FasterPAM m={m} k={k} (dist {dist_ms:.0}ms)"),
            20,
            budget,
            || coreset::select(&dist, k, Method::FasterPam, &mut seed_rng),
        );
        // The paper's engineering claim (asserted at full scale only).
        if m == 4096 {
            assert!(
                r.mean_ns < 1e9,
                "FasterPAM at m=4096 took {:.2}s — paper claims <1s",
                r.mean_ns / 1e9
            );
        }
        results.push(r);

        if m <= 256 {
            // classic PAM: O(n²k) per sweep — already ~500 ms here, the
            // runtime gap FasterPAM exists to close.
            let mut seed_rng = Rng::new(7);
            results.push(bench(&format!("PAM       m={m} k={k}"), 5, budget, || {
                coreset::select(&dist, k, Method::Pam, &mut seed_rng)
            }));
        }
    }
    run_group("k-medoids solvers (paper §4.2: FasterPAM <1s at large m)", results);

    // ---- parallel coreset sweep: workers × {cold, warm} at the top m ----
    let m = m_of(2048);
    let k = (m / 10).max(1);
    let f = features(&mut rng, m, dim);
    let dist = distance::from_features_cpu(&f, m, dim);

    // The differential gate, in-bench: before any timing row is recorded,
    // every pool width must reproduce the sequential distance matrix and
    // medoid set bit-for-bit (the same invariant
    // tests/proptest_coreset.rs fuzzes — re-asserted here so a published
    // speedup can never come from a divergent solver).
    let cold_ref = coreset::select(&dist, k, Method::FasterPam, &mut Rng::new(7));
    for workers in [2usize, 4, 8] {
        let tiled = distance::from_features_cpu_par(&f, m, dim, workers);
        assert!(
            dist.d.iter().zip(&tiled.d).all(|(a, b)| a.to_bits() == b.to_bits()),
            "tiled distance matrix diverged at {workers} workers"
        );
        let par = coreset::select_par(&dist, k, Method::FasterPam, &mut Rng::new(7), workers);
        assert_eq!(
            cold_ref.indices, par.indices,
            "parallel medoids diverged at {workers} workers"
        );
    }

    println!("\n== parallel coreset sweep: m={m} k={k} dim={dim} ==");
    println!("{:>8} {:>12} {:>12} {:>10} {:>10}", "workers", "cold_ms", "warm_ms", "speedup", "warm/cold");
    let mut sweep_results = Vec::new();
    let mut rows = Vec::new();
    let mut cold_base_ns = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let cold = bench(&format!("cold w={workers}"), iters, budget, || {
            let d = distance::from_features_cpu_par(&f, m, dim, workers);
            coreset::select_par(&d, k, Method::FasterPam, &mut Rng::new(7), workers)
        });
        let warm = bench(&format!("warm w={workers}"), iters, budget, || {
            coreset::select_warm(
                &dist,
                k,
                Method::FasterPam,
                &cold_ref.indices,
                &mut Rng::new(7),
                workers,
            )
        });
        if workers == 1 {
            cold_base_ns = cold.mean_ns;
        }
        let speedup = cold_base_ns / cold.mean_ns.max(1.0);
        println!(
            "{workers:>8} {:>12.2} {:>12.2} {:>10.2} {:>10.2}",
            cold.mean_ns / 1e6,
            warm.mean_ns / 1e6,
            speedup,
            warm.mean_ns / cold.mean_ns.max(1.0),
        );
        rows.push(obj(vec![
            ("workers", num(workers as f64)),
            ("cold_ns", num(cold.mean_ns)),
            ("warm_ns", num(warm.mean_ns)),
            ("cold_speedup", num(speedup)),
            ("warm_over_cold", num(warm.mean_ns / cold.mean_ns.max(1.0))),
        ]));
        sweep_results.push(cold);
        sweep_results.push(warm);
    }
    run_group("parallel coreset hot path (cold = dist + BUILD + SWAP, warm = SWAP only)", sweep_results);

    // Quality parity snapshot at m=512.
    let qm = m_of(512);
    let f = features(&mut rng, qm, dim);
    let dist = distance::from_features_cpu(&f, qm, dim);
    let qk = (qm / 10).max(1);
    let mut qrng = Rng::new(9);
    println!("\nsolution quality at m={qm}, k={qk} (objective, lower is better):");
    for method in [Method::FasterPam, Method::Pam, Method::GreedyKCenter, Method::Random] {
        let cs = coreset::select(&dist, qk, method, &mut qrng);
        println!("  {:<14} {:>10.3}", method.label(), cs.cost);
    }

    let out = obj(vec![
        ("bench", Json::Str("kmedoids".into())),
        ("m", num(m as f64)),
        ("k", num(k as f64)),
        ("dim", num(dim as f64)),
        ("provenance", fedcore::util::bench::provenance(42, iters, scale)),
        ("results", Json::Arr(rows)),
    ]);
    let mut text = String::new();
    write_json(&out, &mut text);
    text.push('\n');
    let path = std::env::var("FEDCORE_BENCH_OUT").unwrap_or_else(|_| "BENCH_coreset.json".into());
    std::fs::write(&path, text).expect("writing bench output");
    println!("\nwrote {path}");
}
