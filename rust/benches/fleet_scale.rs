//! Coordinator-path memory scaling: the O(cohort) round loop at fleet
//! sizes the data-backed engine never sees (default 10^5 and 10^6
//! clients), under heavy-tail churn. Each row drives the *real*
//! coordinator pieces — a lazy [`Fleet`], a generated (never
//! materialized) availability trace, the streamed weighted selection,
//! per-client FedCore planning, and the two-tier aggregation seam — and
//! records wall time per round plus peak RSS, so the gate "round memory
//! scales with the cohort, not the fleet" is a measured number, not a
//! code-review claim.
//!
//! Asserts the tentpole's equivalence gate in-bench before any row is
//! trusted: every round's Mean/Mean tree aggregate must equal the flat
//! mean **bit-for-bit**.
//!
//! Every row also feeds a [`HealthLedger`] (top-K heavy-hitter table +
//! quantile sketches) from the same selection/drop stream, so the
//! O(cohort + K) bound on the straggler-forensics state is measured in
//! the same RSS numbers: the `rss_delta_bytes` flatness across 10^5 vs
//! 10^6 clients now covers the health path too.
//!
//! Emits `BENCH_scale.json` (provenance-stamped): one row per
//! fleet × cohort with `secs_per_round`, `peak_rss_bytes`,
//! `rss_delta_bytes` (peak minus the sweep-entry resident set — the
//! fairer per-row signal, since a process's peak RSS is monotone),
//! `online_fraction`, `dropped` counts, and `health_tracked` (ledger
//! rows — capped at the configured K regardless of fleet size).
//!
//! Knobs: `FEDCORE_SCALE_FLEETS` (comma-separated fleet sizes, default
//! `100000,1000000`), `FEDCORE_SCALE_COHORTS` (default `128,1024`),
//! `FEDCORE_ROUNDS` (rounds per row, default 5), `FEDCORE_BENCH_OUT`
//! (output path, default `BENCH_scale.json`), `FEDCORE_OBS_OUT` (when
//! set, write a schema-v2 JSONL trace there — one run segment per row
//! with round/aggregate spans, `round_path` events, and health
//! `snapshot` records, ready for `fedcore report --health --check`).

use std::collections::BTreeMap;
use std::time::Instant;

use fedcore::agg::{AggPolicy, Aggregator, TreeSpec};
use fedcore::fl::{select_available_streamed, Strategy};
use fedcore::obs::health::{HealthConfig, HealthLedger};
use fedcore::obs::{mem, Jsonl, Phase, Record, Recorder as _};
use fedcore::scenario::{AvailabilityTrace, ChurnModel, EdgePolicy};
use fedcore::sim::{Fleet, SizeLaw};
use fedcore::util::json::{write_json, Json};
use fedcore::util::rng::Rng;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.trim().parse().ok()).unwrap_or(default)
}

fn env_usize_list(key: &str, default: &[usize]) -> Vec<usize> {
    match std::env::var(key) {
        Ok(v) => v
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .filter(|&n| n > 0)
            .collect(),
        Err(_) => default.to_vec(),
    }
}

/// Synthetic model dimension: big enough that the aggregation fold does
/// real work, small enough that O(cohort · dim) stays cohort-bound.
const DIM: usize = 64;
const SEED: u64 = 7;
/// Edge fan-out for the in-bench tree≡flat gate.
const FANOUT: usize = 16;

struct Row {
    clients: usize,
    cohort: usize,
    rounds: usize,
    secs_per_round: f64,
    peak_rss_bytes: f64,
    rss_delta_bytes: f64,
    online_frac: f64,
    dropped: usize,
    deadline: f64,
    health_tracked: usize,
}

/// One fleet × cohort sweep row. `entry_rss` is the resident set at
/// sweep entry, subtracted out so each row reports its own growth.
/// `sink` (the `FEDCORE_OBS_OUT` trace) gets one run segment per row.
fn scale_row(
    clients: usize,
    cohort: usize,
    rounds: usize,
    entry_rss: u64,
    sink: Option<&Jsonl>,
) -> Row {
    // The real coordinator state: O(1) lazy fleet, O(1) generated churn
    // trace (the engine's fleet/churn salts, so the workload is the same
    // family the scenario suites gate).
    let fleet = Fleet::lazy(Rng::new(SEED).split(0xF1EE7), clients, SizeLaw::default(), 5, 30.0);
    let model = ChurnModel::HeavyTail { mean_on: 4.0, min_off: 0.5, alpha: 1.5 };
    let trace = AvailabilityTrace::generated(
        model,
        Rng::new(SEED ^ 0x5CA1E),
        clients,
        (rounds + 2) as f64,
        EdgePolicy::Wrap,
    )
    .expect("heavy-tail churn trace")
    .scaled(fleet.deadline)
    .expect("scaling the trace to τ");

    let mut select_rng = Rng::new(SEED).split(0x5E1EC7);
    let mut flat = AggPolicy::Mean.build(None);
    let mut tree = TreeSpec::mean(FANOUT).build(None);
    let mut params = vec![0.0f32; DIM];
    let mut peak = None;
    let mut dropped = 0usize;
    let mut online_sum = 0.0f64;
    // Always-on health ledger at the default K: its O(cohort + K) state
    // must be invisible in the fleet-size RSS delta, so it lives inside
    // the measured window even when no trace is written.
    let mut ledger = HealthLedger::new(HealthConfig::default());
    if let Some(s) = sink {
        s.record(&Record::Event {
            name: "run_start",
            round: 0,
            fields: vec![
                ("clients", num(clients as f64)),
                ("cohort", num(cohort as f64)),
                ("rounds", num(rounds as f64)),
            ],
        });
    }

    mem::fold_peak(&mut peak);
    let t0 = Instant::now();
    for r in 0..rounds {
        let round_w0 = t0.elapsed().as_nanos() as u64;
        let t_now = r as f64 * fleet.deadline;
        // Streamed selection: two O(fleet) passes of lazy trace/size
        // queries, O(cohort) resident.
        let selected = select_available_streamed(
            &mut select_rng,
            |i| fleet.size(i) as f64,
            |i| trace.is_online(i, t_now),
            clients,
            cohort,
        );
        // Streamed online census (`online_fraction` would materialize an
        // O(fleet) index vector — exactly what this bench must not do).
        let online = (0..clients).filter(|&i| trace.is_online(i, t_now)).count();
        online_sum += online as f64 / clients.max(1) as f64;

        let mut locals: Vec<Vec<f32>> = Vec::with_capacity(selected.len());
        let mut weights: Vec<f64> = Vec::with_capacity(selected.len());
        let urng = Rng::new(SEED ^ r as u64);
        // The round's critical-path attribution: slowest surviving
        // client (ties to the smaller id) and the virtual tail.
        let mut bound: Option<(usize, f64)> = None;
        for &i in &selected {
            // Real per-client planning against the lazy accessors; churn
            // drops clients whose plan outlives their online window.
            let plan = Strategy::FedCore.plan(&fleet, i);
            let t = plan.sim_time(&fleet, i);
            if trace.remaining_online(i, t_now) < t {
                dropped += 1;
                ledger.observe_drop(i, fleet.deadline, Some(trace.remaining_online(i, t_now)));
                continue;
            }
            ledger.observe_train(i, t);
            if bound.map_or(true, |(bc, bt)| t > bt || (t == bt && i < bc)) {
                bound = Some((i, t));
            }
            let mut cr = urng.split(i as u64);
            locals.push((0..DIM).map(|_| cr.f32() - 0.5).collect());
            weights.push(1.0);
        }
        ledger.observe_round_end(bound.map(|(c, _)| c), bound.map(|(_, t)| t));

        let refs: Vec<&[f32]> = locals.iter().map(|l| l.as_slice()).collect();
        let agg_w0 = t0.elapsed().as_nanos() as u64;
        let (a, _) = flat.aggregate_round(&params, &refs, &weights);
        let (b, _) = tree.aggregate_round(&params, &refs, &weights);
        let agg_w1 = t0.elapsed().as_nanos() as u64;
        // The tentpole gate, asserted on every benched round.
        match (&a, &b) {
            (Some(x), Some(y)) => {
                for (d, (p, q)) in x.iter().zip(y).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "round {r}: tree diverged from flat mean at dim {d}"
                    );
                }
            }
            (None, None) => {}
            _ => panic!("round {r}: tree/flat applicability diverged"),
        }
        if let Some(p) = a {
            params = p;
        }
        mem::fold_peak(&mut peak);
        if let Some(s) = sink {
            let round_w1 = t0.elapsed().as_nanos() as u64;
            let virt = bound.map(|(_, t)| t).unwrap_or(0.0);
            s.record(&Record::span(Phase::Round, r, (round_w0, round_w1), (t_now, t_now + virt)));
            s.record(&Record::span(
                Phase::Aggregate,
                r,
                (agg_w0, agg_w1),
                (t_now + virt, t_now + virt),
            ));
            s.record(&Record::Event {
                name: "round_path",
                round: r,
                fields: vec![
                    ("client", num(bound.map(|(c, _)| c as f64).unwrap_or(-1.0))),
                    ("client_s", num(virt)),
                    ("quorum_s", num(virt)),
                    ("tail_s", num(virt)),
                ],
            });
            if ledger.snapshot_due(r, rounds) {
                s.record(&ledger.snapshot(r));
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();

    let peak_bytes = peak.map(|s| s.bytes).unwrap_or(0);
    Row {
        clients,
        cohort,
        rounds,
        secs_per_round: secs / rounds.max(1) as f64,
        peak_rss_bytes: peak_bytes as f64,
        rss_delta_bytes: peak_bytes.saturating_sub(entry_rss) as f64,
        online_frac: online_sum / rounds.max(1) as f64,
        dropped,
        deadline: fleet.deadline,
        health_tracked: ledger.tracked(),
    }
}

fn main() {
    let fleets = env_usize_list("FEDCORE_SCALE_FLEETS", &[100_000, 1_000_000]);
    let cohorts = env_usize_list("FEDCORE_SCALE_COHORTS", &[128, 1024]);
    let rounds = env_usize("FEDCORE_ROUNDS", 5);
    let entry_rss = mem::sample().map(|s| s.bytes).unwrap_or(0);
    // Optional health trace: one schema-v2 JSONL file, one run segment
    // per sweep row, consumable by `fedcore report --health --check`.
    let sink = std::env::var("FEDCORE_OBS_OUT").ok().map(|path| {
        let prov = fedcore::util::bench::provenance(SEED, rounds, 1.0);
        Jsonl::create(&path, "bench", prov).expect("creating FEDCORE_OBS_OUT trace")
    });

    println!("== fleet scale: O(cohort) coordinator rounds under heavy-tail churn ==");
    println!(
        "{:>10} {:>8} {:>14} {:>14} {:>14} {:>8} {:>8} {:>8}",
        "clients", "cohort", "s/round", "peak RSS", "RSS delta", "online", "dropped", "tracked"
    );
    let mut rows = Vec::new();
    for &clients in &fleets {
        for &cohort in &cohorts {
            let row = scale_row(clients, cohort, rounds, entry_rss, sink.as_ref());
            println!(
                "{:>10} {:>8} {:>13.3}s {:>11.1} MiB {:>11.1} MiB {:>7.0}% {:>8} {:>8}",
                row.clients,
                row.cohort,
                row.secs_per_round,
                row.peak_rss_bytes / (1024.0 * 1024.0),
                row.rss_delta_bytes / (1024.0 * 1024.0),
                100.0 * row.online_frac,
                row.dropped,
                row.health_tracked,
            );
            rows.push(obj(vec![
                ("clients", num(row.clients as f64)),
                ("cohort", num(row.cohort as f64)),
                ("rounds", num(row.rounds as f64)),
                ("secs_per_round", num(row.secs_per_round)),
                ("peak_rss_bytes", num(row.peak_rss_bytes)),
                ("rss_delta_bytes", num(row.rss_delta_bytes)),
                ("online_fraction", num(row.online_frac)),
                ("dropped", num(row.dropped as f64)),
                ("deadline", num(row.deadline)),
                ("dim", num(DIM as f64)),
                ("tree_fanout", num(FANOUT as f64)),
                ("health_tracked", num(row.health_tracked as f64)),
                ("health_top_k", num(HealthConfig::default().top_k as f64)),
            ]));
        }
    }
    // Flush the buffered trace before the bench reports success.
    if let Some(s) = &sink {
        s.flush();
    }
    drop(sink);

    let out = obj(vec![
        ("bench", Json::Str("fleet_scale".into())),
        ("churn", Json::Str("heavy_tail(mean_on=4, min_off=0.5, alpha=1.5)".into())),
        ("provenance", fedcore::util::bench::provenance(SEED, rounds, 1.0)),
        ("results", Json::Arr(rows)),
    ]);
    let mut text = String::new();
    write_json(&out, &mut text);
    text.push('\n');
    let path = std::env::var("FEDCORE_BENCH_OUT").unwrap_or_else(|_| "BENCH_scale.json".into());
    std::fs::write(&path, text).expect("writing bench output");
    println!("\nwrote {path}");
}
