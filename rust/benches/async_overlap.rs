//! Synchronous vs overlapped round pipeline: the same FL workload run
//! with the classic per-round barrier and with quorum-triggered async
//! overlap (staleness-bounded delayed gradients), across the
//! straggler-heavy scenarios from the scenario subsystem (no churn, and
//! the heavy-tail availability trace). Asserts the determinism contract —
//! the degenerate overlap policy (quorum = 1.0, max_staleness = 0) must
//! reproduce the synchronous run bit-for-bit — and that the overlapped
//! server finishes its rounds in no more simulated time than the
//! synchronous one. Emits `BENCH_async.json`.
//!
//! Knobs: `FEDCORE_SCALE`, `FEDCORE_ROUNDS`, `FEDCORE_WORKERS`,
//! `FEDCORE_QUORUM` / `FEDCORE_MAX_STALENESS` / `FEDCORE_ALPHA`,
//! `FEDCORE_BENCH_OUT` (output path, default `BENCH_async.json`).

use std::collections::BTreeMap;
use std::time::Instant;

use fedcore::data::Benchmark;
use fedcore::exec::OverlapConfig;
use fedcore::expt;
use fedcore::fl::Strategy;
use fedcore::scenario::{ChurnModel, TraceSpec};
use fedcore::util::json::{write_json, Json};

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Root seed of every run in this bench — also stamped into the
/// provenance object so the JSON's workload identity cannot drift.
const SEED: u64 = 7;

/// The straggler-heavy availability scenario from the scenario bench.
fn heavy_tail() -> TraceSpec {
    TraceSpec::from_model(
        ChurnModel::HeavyTail { mean_on: 6.0, min_off: 0.5, alpha: 1.1 },
        48.0,
        11,
    )
}

fn main() {
    let rt = expt::runtime_or_exit();
    rt.warmup().expect("warmup");

    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };
    let overlap = expt::bench_overlap();
    println!(
        "== async overlap: {} | quorum {:.0}% | max staleness {} | alpha {:.2} ==",
        bench.label(),
        100.0 * overlap.quorum,
        overlap.max_staleness,
        overlap.alpha
    );

    // Degenerate-equivalence gate: full quorum + zero staleness must be
    // the synchronous engine, bit-for-bit, before any comparison is
    // worth reporting.
    {
        let sync = expt::run_with(&rt, bench, Strategy::FedCore, 30.0, SEED, None, None)
            .expect("sync run");
        let degenerate = expt::run_with(
            &rt,
            bench,
            Strategy::FedCore,
            30.0,
            SEED,
            Some(OverlapConfig::degenerate()),
            None,
        )
        .expect("degenerate overlapped run");
        assert_eq!(
            sync.final_params, degenerate.final_params,
            "degenerate overlap diverged from the synchronous engine"
        );
        for (a, b) in sync.rounds.iter().zip(&degenerate.rounds) {
            assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "round {}", a.round);
            assert_eq!(a.tail_time.to_bits(), b.tail_time.to_bits());
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(b.stale_folded + b.stale_discarded, 0, "degenerate run went stale");
        }
        println!("degenerate equivalence: OK (quorum = 1.0, max_staleness = 0 ≡ synchronous)");
    }

    println!(
        "\n{:<22} {:>9} {:>10} {:>10} {:>9} {:>7} {:>7} {:>8}",
        "scenario", "acc (%)", "sync t", "async t", "speedup", "stale+", "stale-", "seconds"
    );

    let scenarios: Vec<(&str, Option<TraceSpec>)> =
        vec![("no_churn", None), ("heavy_tail", Some(heavy_tail()))];
    let strategies = [Strategy::FedAvg, Strategy::FedCore];

    let mut rows = Vec::new();
    for (scenario, trace) in &scenarios {
        for strategy in strategies {
            let sync =
                expt::run_with(&rt, bench, strategy, 30.0, SEED, None, trace.clone())
                    .expect("sync run");
            let t0 = Instant::now();
            let over = expt::run_with(
                &rt,
                bench,
                strategy,
                30.0,
                SEED,
                Some(overlap),
                trace.clone(),
            )
            .expect("overlapped run");
            let secs = t0.elapsed().as_secs_f64();

            let sync_t = sync.total_sim_time();
            let over_t = over.total_sim_time();
            // Without churn the two runs select identical cohorts, so the
            // quorum cut bounds every round: the inequality is a hard
            // invariant. Under a trace the clocks (and hence selections)
            // diverge, so the bound is expected-but-not-guaranteed —
            // report loudly instead of panicking a bench run.
            if trace.is_none() {
                assert!(
                    over_t <= sync_t * (1.0 + 1e-9),
                    "{scenario}/{}: overlapped total sim time {over_t} exceeds synchronous {sync_t}",
                    strategy.label()
                );
            } else if over_t > sync_t {
                println!(
                    "WARNING {scenario}/{}: overlapped {over_t:.2} > synchronous {sync_t:.2} \
                     (divergent churn selections)",
                    strategy.label()
                );
            }
            let (folded, discarded) = over.stale_totals();
            let speedup = sync_t / over_t;
            println!(
                "{:<22} {:>9.1} {:>10.2} {:>10.2} {:>8.2}x {:>7} {:>7} {:>8.2}",
                format!("{scenario}/{}", strategy.label()),
                100.0 * over.best_accuracy(),
                sync_t,
                over_t,
                speedup,
                folded,
                discarded,
                secs
            );
            rows.push(obj(vec![
                ("scenario", Json::Str(scenario.to_string())),
                ("strategy", Json::Str(strategy.label().into())),
                ("sync_total_sim_time", num(sync_t)),
                ("overlapped_total_sim_time", num(over_t)),
                ("speedup", num(speedup)),
                ("sync_mean_norm_round", num(sync.mean_normalized_round_time())),
                ("overlapped_mean_norm_round", num(over.mean_normalized_round_time())),
                ("overlapped_mean_norm_tail", num(over.mean_normalized_tail_time())),
                ("sync_best_accuracy_pct", num(100.0 * sync.best_accuracy())),
                ("overlapped_best_accuracy_pct", num(100.0 * over.best_accuracy())),
                ("stale_folded", num(folded as f64)),
                ("stale_discarded", num(discarded as f64)),
                ("wall_seconds", num(secs)),
            ]));
        }
    }

    let out = obj(vec![
        ("bench", Json::Str("async_overlap".into())),
        ("benchmark", Json::Str(bench.label())),
        ("quorum", num(overlap.quorum)),
        ("max_staleness", num(overlap.max_staleness as f64)),
        ("alpha", num(overlap.alpha)),
        (
            "provenance",
            fedcore::util::bench::provenance(
                SEED,
                expt::bench_rounds(bench),
                expt::bench_scale(bench),
            ),
        ),
        ("results", Json::Arr(rows)),
    ]);
    let mut text = String::new();
    write_json(&out, &mut text);
    text.push('\n');
    let path = std::env::var("FEDCORE_BENCH_OUT").unwrap_or_else(|_| "BENCH_async.json".into());
    std::fs::write(&path, text).expect("writing bench output");
    println!("\nwrote {path}");
}
