//! Churn-scenario sweep: the same FL workload driven under four client-
//! availability regimes (always-on baseline, periodic duty cycle, Markov
//! on/off churn, heavy-tailed dropout), via the `expt::run_scenario`
//! runner. Each scenario runs twice — the second run both warms nothing
//! (scenarios share one runtime) and proves the determinism contract:
//! round records must replay bit-for-bit. A second phase races the three
//! selection policies (baseline / FLANP adaptive participation /
//! uptime-forecast) on the heavy-tail trace and asserts FLANP's
//! time-to-target-loss never exceeds the baseline's — the FLANP claim
//! (arXiv:2012.14453) at bench scale. Emits `BENCH_scenarios.json`.
//!
//! Knobs: `FEDCORE_SCALE`, `FEDCORE_ROUNDS`, `FEDCORE_WORKERS`,
//! `FEDCORE_BENCH_OUT` (output path, default `BENCH_scenarios.json`).

use std::collections::BTreeMap;
use std::time::Instant;

use fedcore::data::Benchmark;
use fedcore::expt;
use fedcore::fl::Strategy;
use fedcore::metrics::RunResult;
use fedcore::scenario::{ChurnModel, FlanpConfig, SelectPolicy, TraceSpec};
use fedcore::util::json::{write_json, Json};

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Root seed of every run in this bench — also stamped into the
/// provenance object so the JSON's workload identity cannot drift.
const SEED: u64 = 7;

fn scenarios() -> Vec<(&'static str, TraceSpec)> {
    vec![
        ("always_on", TraceSpec::always_on()),
        (
            "periodic",
            TraceSpec::from_model(ChurnModel::Periodic { period: 8.0, duty: 0.6 }, 24.0, 11),
        ),
        (
            "markov",
            TraceSpec::from_model(
                ChurnModel::Markov { mean_on: 6.0, mean_off: 2.0, p_init_online: 0.8 },
                24.0,
                11,
            ),
        ),
        (
            "heavy_tail",
            TraceSpec::from_model(
                ChurnModel::HeavyTail { mean_on: 6.0, min_off: 0.5, alpha: 1.1 },
                48.0,
                11,
            ),
        ),
    ]
}

/// Heavy-tail trace for the selection race — same shape as the sweep's
/// `heavy_tail` scenario so the race rides the workload already proven
/// deterministic above.
fn race_trace() -> TraceSpec {
    TraceSpec::from_model(ChurnModel::HeavyTail { mean_on: 6.0, min_off: 0.5, alpha: 1.1 }, 48.0, 11)
}

/// The three cohort policies under race, with race-tuned FLANP knobs: a
/// small fast prefix that widens aggressively once the loss stalls.
fn race_policies() -> Vec<(&'static str, SelectPolicy)> {
    vec![
        ("baseline", SelectPolicy::Baseline),
        ("flanp", SelectPolicy::Flanp(FlanpConfig { start: 4, factor: 2.0, threshold: 0.5 })),
        ("forecast", SelectPolicy::Forecast { bias: 1.0 }),
    ]
}

/// Simulated seconds until `train_loss` first reaches `target`. Every
/// racer's final loss is `<= target` by construction (the target is the
/// worst final loss in the field), so this always lands on a round.
fn time_to_target(result: &RunResult, target: f64) -> f64 {
    for rec in &result.rounds {
        if rec.train_loss <= target {
            return rec.sim_elapsed;
        }
    }
    result.rounds.last().map(|r| r.sim_elapsed).unwrap_or(0.0)
}

fn main() {
    let rt = expt::runtime_or_exit();
    rt.warmup().expect("warmup");

    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };
    let strategy = Strategy::FedCore;
    println!("== scenario churn: {} | {} ==", bench.label(), strategy.label());
    println!(
        "{:<12} {:>8} {:>9} {:>7} {:>8} {:>9} {:>9}",
        "scenario", "seconds", "acc (%)", "t/τ", "online%", "offline", "idle"
    );

    let mut rows = Vec::new();
    for (name, spec) in scenarios() {
        let first = expt::run_scenario(&rt, bench, strategy, 30.0, SEED, spec.clone())
            .expect("scenario run");
        let t0 = Instant::now();
        let second = expt::run_scenario(&rt, bench, strategy, 30.0, SEED, spec)
            .expect("scenario replay");
        let secs = t0.elapsed().as_secs_f64();

        // Determinism contract: a churn scenario replays bit-for-bit.
        assert_eq!(
            first.result.final_params, second.result.final_params,
            "{name}: final params diverged between identical runs"
        );
        for (a, b) in first.result.rounds.iter().zip(&second.result.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{name}: round {} train_loss not deterministic",
                a.round
            );
            assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.churn_dropped, b.churn_dropped);
        }

        let r = &second;
        let acc = 100.0 * r.result.best_accuracy();
        let t_norm = r.result.mean_normalized_round_time();
        println!(
            "{:<12} {:>8.2} {:>9.1} {:>7.2} {:>7.0}% {:>9} {:>9}",
            r.scenario,
            secs,
            acc,
            t_norm,
            100.0 * r.mean_online_fraction,
            r.churn_dropped,
            r.idle_rounds
        );
        rows.push(obj(vec![
            ("scenario", Json::Str(name.into())),
            ("seconds", num(secs)),
            ("best_accuracy_pct", num(acc)),
            ("mean_norm_round_time", num(t_norm)),
            ("mean_online_fraction", num(r.mean_online_fraction)),
            ("churn_dropped", num(r.churn_dropped as f64)),
            ("idle_rounds", num(r.idle_rounds as f64)),
            ("partial_time", num(r.partial_time)),
            ("rounds", num(r.result.rounds.len() as f64)),
        ]));
    }

    // Selection-policy race on the heavy-tail trace: same workload, three
    // cohort policies, scored by simulated time to the field's worst
    // final loss. FLANP's fastest-prefix start must not lose to the
    // baseline in simulated time — the adaptive-participation claim.
    println!("\n== selection race: heavy_tail ==");
    println!("{:<12} {:>8} {:>12} {:>12} {:>11}", "policy", "seconds", "final loss", "t_target(s)", "sim total(s)");
    let mut racers = Vec::new();
    for (name, pol) in race_policies() {
        let t0 = Instant::now();
        let report =
            expt::run_scenario_with(&rt, bench, strategy, 30.0, SEED, race_trace(), |run| {
                run.select = pol;
            })
            .expect("selection race run");
        racers.push((name, report, t0.elapsed().as_secs_f64()));
    }
    let target = racers
        .iter()
        .filter_map(|(_, r, _)| r.result.rounds.last().map(|rec| rec.train_loss))
        .fold(f64::NEG_INFINITY, f64::max);
    let mut race_rows = Vec::new();
    let mut times = BTreeMap::new();
    for (name, report, secs) in &racers {
        let rounds = &report.result.rounds;
        let final_loss = rounds.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
        let sim_total = rounds.last().map(|r| r.sim_elapsed).unwrap_or(0.0);
        let t_target = time_to_target(&report.result, target);
        times.insert(*name, t_target);
        println!("{:<12} {:>8.2} {:>12.4} {:>12.2} {:>11.2}", name, secs, final_loss, t_target, sim_total);
        race_rows.push(obj(vec![
            ("policy", Json::Str((*name).into())),
            ("seconds", num(*secs)),
            ("final_loss", num(final_loss)),
            ("time_to_target", num(t_target)),
            ("sim_total", num(sim_total)),
            ("best_accuracy_pct", num(100.0 * report.result.best_accuracy())),
            ("mean_online_fraction", num(report.mean_online_fraction)),
        ]));
    }
    assert!(
        times["flanp"] <= times["baseline"],
        "FLANP lost the heavy_tail race: time_to_target {} > baseline {}",
        times["flanp"],
        times["baseline"]
    );

    let out = obj(vec![
        ("bench", Json::Str("scenario_churn".into())),
        ("benchmark", Json::Str(bench.label())),
        ("strategy", Json::Str(strategy.label().into())),
        (
            "provenance",
            fedcore::util::bench::provenance(
                SEED,
                expt::bench_rounds(bench),
                expt::bench_scale(bench),
            ),
        ),
        ("results", Json::Arr(rows)),
        (
            "selection_race",
            obj(vec![
                ("scenario", Json::Str("heavy_tail".into())),
                ("target_loss", num(target)),
                ("results", Json::Arr(race_rows)),
            ]),
        ),
    ]);
    let mut text = String::new();
    write_json(&out, &mut text);
    text.push('\n');
    let path =
        std::env::var("FEDCORE_BENCH_OUT").unwrap_or_else(|_| "BENCH_scenarios.json".into());
    std::fs::write(&path, text).expect("writing bench output");
    println!("\nwrote {path}");
}
