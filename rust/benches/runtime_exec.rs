//! Runtime micro-bench: per-artifact PJRT execution latency — the L3 hot
//! path's unit costs (train step / feature batch / eval batch per model,
//! plus the L1 Pallas distance tile). Feeds EXPERIMENTS.md §Perf.

use std::time::Duration;

use fedcore::expt;
use fedcore::runtime::XBatch;
use fedcore::util::bench::{bench, run_group};
use fedcore::util::rng::Rng;

fn main() {
    let rt = expt::runtime_or_exit();
    rt.warmup().expect("warmup");
    let mut rng = Rng::new(1);
    let b = rt.manifest().train_batch;
    let f = rt.manifest().feat_batch;
    let budget = Duration::from_secs(3);
    let mut results = Vec::new();

    for name in ["logreg", "mnist", "shake"] {
        let model = rt.manifest().model(name).unwrap().clone();
        let xe = model.x_elems();
        let ye = model.y_elems();
        let params = model.init_params.clone();

        let make_x = |rng: &mut Rng, batch: usize| -> XBatch {
            match model.x_dtype {
                fedcore::runtime::XDtype::F32 => {
                    XBatch::F32((0..batch * xe).map(|_| rng.f32()).collect())
                }
                fedcore::runtime::XDtype::I32 => {
                    XBatch::I32((0..batch * xe).map(|_| rng.below(64) as i32).collect())
                }
            }
        };
        let y_train: Vec<i32> = (0..b * ye).map(|_| rng.below(model.num_classes) as i32).collect();
        let y_feat: Vec<i32> = (0..f * ye).map(|_| rng.below(model.num_classes) as i32).collect();
        let w = vec![1.0f32; b];
        let mask = vec![1.0f32; f];
        let x_train = make_x(&mut rng, b);
        let x_feat = make_x(&mut rng, f);

        results.push(bench(&format!("{name}: train_step (B={b})"), 400, budget, || {
            rt.train_step(&model, &params, &params, &x_train, &y_train, &w, 0.01, 0.0)
                .unwrap()
        }));
        results.push(bench(&format!("{name}: grad_features (F={f})"), 200, budget, || {
            rt.grad_features(&model, &params, &x_feat, &y_feat).unwrap()
        }));
        results.push(bench(&format!("{name}: evaluate (F={f})"), 200, budget, || {
            rt.evaluate(&model, &params, &x_feat, &y_feat, &mask).unwrap()
        }));
    }

    let t = rt.manifest().pairwise_tile;
    let c = rt.manifest().pairwise_dim;
    let a: Vec<f32> = (0..t * c).map(|_| rng.normal() as f32).collect();
    let bb: Vec<f32> = (0..t * c).map(|_| rng.normal() as f32).collect();
    results.push(bench(&format!("pallas pairwise tile ({t}×{t})"), 200, budget, || {
        rt.pairwise_tile(&a, &bb).unwrap()
    }));

    run_group("PJRT artifact execution latency", results);
    let stats = rt.stats();
    println!(
        "\ntotal: {} executions, {} compiles, {:.1} ms mean exec",
        stats.executions,
        stats.compile_count,
        stats.exec_nanos as f64 / stats.executions.max(1) as f64 / 1e6
    );
}
