//! Reproduces **Figure 7** (appendix B.2): round-duration distributions
//! across clients and rounds for every benchmark at 10% and 30% straggler
//! settings (log-scale counts).
//!
//! Default covers the three Synthetic columns + MNIST; `FEDCORE_FULL=1`
//! adds Shakespeare (slow under the LSTM).

use fedcore::data::{paper_benchmarks, Benchmark};
use fedcore::expt;
use fedcore::metrics::Histogram;

fn main() {
    let rt = expt::runtime_or_exit();
    let benches: Vec<Benchmark> = if expt::full_scale() {
        paper_benchmarks()
    } else {
        vec![
            Benchmark::Mnist,
            Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
            Benchmark::Synthetic { alpha: 0.5, beta: 0.5 },
            Benchmark::Synthetic { alpha: 0.0, beta: 0.0 },
        ]
    };

    for bench in benches {
        for s in [10.0, 30.0] {
            let runs = expt::run_cell(&rt, bench, s, 7).expect("cell");
            println!("\n== Fig 7: {} @ {}% stragglers (x = t/τ) ==", bench.label(), s);
            for r in &runs {
                let times = r.client_times_normalized();
                let h = Histogram::new(&times, 0.5, 6.0);
                let max_t = times.iter().copied().fold(0.0f64, f64::max);
                // compressed row view: bucket counts + max
                let counts: Vec<String> = h.counts.iter().map(|c| format!("{c:>4}")).collect();
                println!(
                    "{:<12} max {max_t:>5.2}τ | {}",
                    r.strategy,
                    counts.join(" ")
                );
                // shape: deadline-aware strategies never pass τ
                if r.strategy != "FedAvg" {
                    assert!(
                        max_t <= 1.05,
                        "{} @ {}: {} exceeded τ ({max_t})",
                        bench.label(),
                        s,
                        r.strategy
                    );
                }
            }
        }
    }
    println!("\nshape check passed: only FedAvg's distribution crosses τ in every panel");
}
