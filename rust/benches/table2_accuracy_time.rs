//! Reproduces **Table 2**: test accuracy and normalized mean round time
//! for FedAvg / FedAvg-DS / FedProx / FedCore across all five benchmark
//! columns at 10% and 30% stragglers.
//!
//! Expected *shape* (not absolute numbers — our substrate is a simulator):
//! FedCore top/near-top accuracy everywhere; FedAvg-DS collapses on the
//! synthetic columns; FedAvg's normalized time well above 1 (red cells);
//! the three deadline-aware strategies stay ≤ 1.
//!
//! `FEDCORE_FULL=1 cargo bench --bench table2_accuracy_time` runs paper
//! scale; the default completes in minutes.

use fedcore::data::paper_benchmarks;
use fedcore::expt;
use fedcore::metrics::table2_rows;

fn main() {
    let rt = expt::runtime_or_exit();
    let mut summary: Vec<(String, f64, Vec<(String, f64, f64)>)> = Vec::new();

    for bench in paper_benchmarks() {
        for s in [10.0, 30.0] {
            let runs = expt::run_cell(&rt, bench, s, 7).expect("cell");
            expt::print_cell_table(bench, s, &runs);
            summary.push((
                bench.label(),
                s,
                table2_rows(&runs)
                    .into_iter()
                    .map(|r| (r.strategy, r.accuracy_pct, r.mean_norm_time))
                    .collect(),
            ));
        }
    }

    // Paper-shape assertions over the whole grid.
    println!("\n=== shape checks vs paper Table 2 ===");
    let mut core_top = 0usize;
    let mut cells = 0usize;
    for (bench, s, rows) in &summary {
        cells += 1;
        let acc = |name: &str| rows.iter().find(|r| r.0 == name).map(|r| r.1).unwrap();
        let time = |name: &str| rows.iter().find(|r| r.0 == name).map(|r| r.2).unwrap();
        // deadline-aware ≤ ~1, FedAvg above 1 where stragglers bite
        for name in ["FedAvg-DS", "FedProx", "FedCore"] {
            assert!(
                time(name) <= 1.05,
                "{bench}@{s}: {name} t/τ = {:.2} exceeds deadline",
                time(name)
            );
        }
        let best = rows.iter().map(|r| r.1).fold(0.0, f64::max);
        if acc("FedCore") >= best - 2.0 {
            core_top += 1;
        }
        println!(
            "{bench}@{s}%: FedAvg t/τ {:.2} | FedCore acc {:.1} (best {:.1}) | DS acc {:.1}",
            time("FedAvg"),
            acc("FedCore"),
            best,
            acc("FedAvg-DS"),
        );
    }
    println!(
        "\nFedCore within 2 pts of the best accuracy in {core_top}/{cells} cells \
         (paper: top or near-top everywhere)"
    );

    // ---- paper-scale timing projection (sim only; paper Table 2 time rows) ----
    println!("\n=== Table 2 time rows at FULL paper scale (timing projection, sim-only) ===");
    println!("paper:   MNIST@30 FedAvg 8.48 | Shake@30 4.09 | Synth@30 4.80 | deadline-aware ≤ 1");
    println!(
        "{:<16} {:>4} {:>9} {:>11} {:>9} {:>9}",
        "benchmark", "s%", "FedAvg", "FedAvg-DS", "FedProx", "FedCore"
    );
    for bench in paper_benchmarks() {
        for s in [10.0, 30.0] {
            let rows = expt::timing_projection(bench, s, 200, 7);
            let get = |n: &str| rows.iter().find(|r| r.0 == n).map(|r| r.1).unwrap();
            println!(
                "{:<16} {:>4} {:>9.2} {:>11.2} {:>9.2} {:>9.2}",
                bench.label(),
                s,
                get("FedAvg"),
                get("FedAvg-DS"),
                get("FedProx"),
                get("FedCore")
            );
            // headline shape at paper scale: deadline-aware ≤ ~1, FedAvg ≫ 1 @30%
            for n in ["FedAvg-DS", "FedProx", "FedCore"] {
                assert!(get(n) <= 1.05, "{} exceeded τ at paper scale", n);
            }
            if s == 30.0 {
                assert!(
                    get("FedAvg") > 2.0,
                    "{}: paper-scale FedAvg only {:.2}×τ",
                    bench.label(),
                    get("FedAvg")
                );
            }
        }
    }
}
