//! Reproduces **Figure 5**: training loss vs *cumulative simulated time*
//! for FedCore vs FedProx — the paper's explanation of why coresets beat
//! epoch truncation: FedCore spends its deadline on more (coreset)
//! gradient steps, FedProx on fewer full-set epochs, so at equal wall
//! budget FedCore sits lower on the loss curve.

use fedcore::data::Benchmark;
use fedcore::expt;
use fedcore::fl::Strategy;

fn main() {
    let rt = expt::runtime_or_exit();
    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };

    let mut curves = Vec::new();
    for strategy in [Strategy::FedProx { mu: 0.1 }, Strategy::FedCore] {
        let r = expt::run_one(&rt, bench, strategy, 30.0, 7).expect("run");
        curves.push(r);
    }

    println!("Fig 5: train loss vs cumulative simulated time (t/τ units), {} @ 30%", bench.label());
    println!("{:>10} {:>10}   {:>10} {:>10}", "FedProx t", "loss", "FedCore t", "loss");
    let a = curves[0].loss_vs_time();
    let b = curves[1].loss_vs_time();
    let tau = curves[0].deadline;
    for i in 0..a.len().max(b.len()) {
        let fa = a.get(i).map(|(t, l)| format!("{:>10.2} {:>10.4}", t / tau, l));
        let fb = b.get(i).map(|(t, l)| format!("{:>10.2} {:>10.4}", t / tau, l));
        println!(
            "{}   {}",
            fa.unwrap_or_else(|| " ".repeat(21)),
            fb.unwrap_or_default()
        );
    }

    // Shape: at the shared final time budget, FedCore's loss ≤ FedProx's.
    // Per-round client mixes make single-round losses noisy on small
    // fleets, so compare the mean over the last third of the run.
    let tail_mean = |r: &fedcore::metrics::RunResult| {
        let n = r.rounds.len();
        let tail: Vec<f64> = r.rounds[n - n / 3..].iter().map(|x| x.train_loss).collect();
        fedcore::util::stats::mean(&tail)
    };
    let final_prox = tail_mean(&curves[0]);
    let final_core = tail_mean(&curves[1]);
    println!("\nconverged loss (last-third mean): FedProx {final_prox:.4} | FedCore {final_core:.4}");
    assert!(
        final_core <= final_prox * 1.15,
        "FedCore {final_core} not competitive with FedProx {final_prox}"
    );
    println!("shape check passed: FedCore ≤ ~FedProx at equal simulated budget");
}
