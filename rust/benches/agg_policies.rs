//! Server aggregation policy sweep: the same FL workload run under every
//! aggregation policy (mean, FedBuff-style buffered with momentum,
//! per-coordinate trimmed mean, coordinate median), on a clean fleet and
//! under the sign-flip corruption scenario. Asserts the degenerate gates
//! on every run — `Buffered{k=0, β=0}` and `TrimmedMean{0}` must
//! reproduce the mean engine bit-for-bit — and reports how the robust
//! policies hold accuracy when a client fraction turns adversarial.
//! Emits `BENCH_agg.json` (provenance-stamped).
//!
//! Knobs: `FEDCORE_SCALE`, `FEDCORE_ROUNDS`, `FEDCORE_WORKERS`,
//! `FEDCORE_BENCH_OUT` (output path, default `BENCH_agg.json`).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use fedcore::agg::AggPolicy;
use fedcore::data::{self, Benchmark};
use fedcore::expt;
use fedcore::fl::{Engine, RunConfig, Strategy};
use fedcore::metrics::RunResult;
use fedcore::runtime::Runtime;
use fedcore::scenario::{CorruptionKind, CorruptionSpec};
use fedcore::util::json::{write_json, Json};

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn base_cfg(bench: Benchmark) -> RunConfig {
    RunConfig {
        strategy: Strategy::FedCore,
        rounds: expt::bench_rounds(bench),
        epochs: 6,
        clients_per_round: 8,
        lr: expt::bench_lr(bench),
        straggler_pct: 30.0,
        seed: 7,
        eval_every: 2,
        eval_cap: 256,
        workers: expt::env_usize("FEDCORE_WORKERS", 1),
        ..RunConfig::default()
    }
}

fn run_policy(
    rt: &Runtime,
    ds: &Arc<data::FedDataset>,
    bench: Benchmark,
    policy: AggPolicy,
    corruption: Option<CorruptionSpec>,
) -> RunResult {
    let mut cfg = base_cfg(bench);
    cfg.aggregator = policy;
    cfg.corruption = corruption;
    Engine::new(rt, ds, cfg).expect("engine").run().expect("run")
}

fn assert_bitwise(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.final_params, b.final_params, "{what}: final params diverged");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what}: round {}", x.round);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{what}: round {}", x.round);
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{what}: round {}", x.round);
    }
    assert_eq!(a.to_csv(), b.to_csv(), "{what}: CSV diverged");
}

fn main() {
    let rt = expt::runtime_or_exit();
    rt.warmup().expect("warmup");

    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };
    let ds = Arc::new(data::generate(bench, expt::bench_scale(bench), &rt.manifest().vocab, 7));
    println!(
        "== agg policies: {} | {} clients | {} rounds ==",
        bench.label(),
        ds.num_clients(),
        base_cfg(bench).rounds
    );

    // Degenerate gates: the refactored seam must not have moved a bit.
    let mean = run_policy(&rt, &ds, bench, AggPolicy::Mean, None);
    {
        let buffered = run_policy(
            &rt,
            &ds,
            bench,
            AggPolicy::Buffered { k: 0, momentum: 0.0 },
            None,
        );
        assert_bitwise(&mean, &buffered, "Buffered{k=0, β=0} vs Mean");
        let trimmed = run_policy(&rt, &ds, bench, AggPolicy::TrimmedMean { trim_frac: 0.0 }, None);
        assert_bitwise(&mean, &trimmed, "TrimmedMean{0} vs Mean");
        println!("degenerate equivalence: OK (buffered k=0 β=0 and trim 0 ≡ mean, bitwise)");
    }

    let corruption = Some(CorruptionSpec {
        kind: CorruptionKind::SignFlip { scale: 1.0 },
        fraction: 0.25,
        seed: 5,
    });
    let policies = [
        AggPolicy::Mean,
        AggPolicy::Buffered { k: 0, momentum: 0.2 },
        AggPolicy::TrimmedMean { trim_frac: 0.25 },
        AggPolicy::CoordinateMedian,
    ];

    println!(
        "\n{:<34} {:>9} {:>9} {:>9} {:>9} {:>8}",
        "policy/scenario", "acc (%)", "loss", "rejected", "clipped", "seconds"
    );
    let mut rows = Vec::new();
    for (scenario, corrupt) in [("clean", None), ("sign_flip", corruption)] {
        for policy in policies {
            let t0 = Instant::now();
            let r = run_policy(&rt, &ds, bench, policy, corrupt);
            let secs = t0.elapsed().as_secs_f64();
            let (rejected, clipped) = r.agg_totals();
            let acc = 100.0 * r.best_accuracy();
            println!(
                "{:<34} {:>9.1} {:>9.4} {:>9} {:>9} {:>8.2}",
                format!("{scenario}/{}", policy.label()),
                acc,
                r.final_train_loss(),
                rejected,
                clipped,
                secs
            );
            rows.push(obj(vec![
                ("scenario", Json::Str(scenario.into())),
                ("policy", Json::Str(policy.label().into())),
                ("best_accuracy_pct", num(acc)),
                ("final_train_loss", num(r.final_train_loss())),
                ("agg_rejected", num(rejected as f64)),
                ("agg_clipped", num(clipped as f64)),
                ("wall_seconds", num(secs)),
            ]));
        }
    }

    // The corruption scenario must actually bite (the mean model moves),
    // and the robust paths must be doing real rejection work under it.
    let corrupted_mean = run_policy(
        &rt,
        &ds,
        bench,
        AggPolicy::Mean,
        Some(CorruptionSpec {
            kind: CorruptionKind::SignFlip { scale: 1.0 },
            fraction: 0.25,
            seed: 5,
        }),
    );
    assert_ne!(
        corrupted_mean.final_params, mean.final_params,
        "sign-flip corruption did not perturb the mean run"
    );

    let cfg = base_cfg(bench);
    let out = obj(vec![
        ("bench", Json::Str("agg_policies".into())),
        ("benchmark", Json::Str(bench.label())),
        ("strategy", Json::Str("FedCore".into())),
        ("corrupt_fraction", num(0.25)),
        (
            "provenance",
            fedcore::util::bench::provenance(cfg.seed, cfg.rounds, expt::bench_scale(bench)),
        ),
        ("results", Json::Arr(rows)),
    ]);
    let mut text = String::new();
    write_json(&out, &mut text);
    text.push('\n');
    let path = std::env::var("FEDCORE_BENCH_OUT").unwrap_or_else(|_| "BENCH_agg.json".into());
    std::fs::write(&path, text).expect("writing bench output");
    println!("\nwrote {path}");
}
