//! FedCore: straggler-free federated learning with distributed coresets.
//!
//! Rust + JAX + Pallas reproduction of Guo et al., 2024. Three layers:
//!
//! * **L3 (this crate)** — the FL coordinator: round engine, client
//!   selection, deadline simulation, the four strategies (FedAvg,
//!   FedAvg-DS, FedProx, FedCore), FasterPAM k-medoids coresets, dataset
//!   generators, metrics and CLI.
//! * **L2 (python/compile, build-time only)** — JAX models for the three
//!   paper benchmarks, AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels, build-time only)** — the Pallas
//!   pairwise gradient-distance kernel feeding coreset selection.
//!
//! At run time only this crate executes; artifacts are loaded through the
//! PJRT CPU client in [`runtime`].

pub mod config;
pub mod coreset;
pub mod expt;
pub mod data;
pub mod fl;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
