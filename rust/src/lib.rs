//! FedCore: straggler-free federated learning with distributed coresets.
//!
//! Rust + JAX + Pallas reproduction of Guo et al., 2024. Three layers:
//!
//! * **L3 (this crate)** — the FL coordinator: round engine, client
//!   selection, deadline simulation, the four strategies (FedAvg,
//!   FedAvg-DS, FedProx, FedCore), FasterPAM k-medoids coresets, dataset
//!   generators, metrics and CLI — plus the [`exec`] subsystem that
//!   shards a round's client work across runtime-pinned worker threads.
//! * **L2 (python/compile, build-time only)** — JAX models for the three
//!   paper benchmarks, AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels, build-time only)** — the Pallas
//!   pairwise gradient-distance kernel feeding coreset selection.
//!
//! At run time only this crate executes; artifacts are loaded through the
//! PJRT CPU client in [`runtime`].
//!
//! # Execution / thread model
//!
//! `PjRtClient` is `Rc`-backed and `!Send`, so a [`runtime::Runtime`] is
//! pinned to the thread that created it. Parallelism therefore follows a
//! one-runtime-per-worker model: [`exec::Sharded`] owns a persistent pool
//! of worker threads, each of which builds its own `Runtime` from a
//! [`runtime::RuntimeFactory`] (shared artifacts, per-thread compilation
//! cache) and keeps it for the pool's lifetime. The engine shards each
//! round's K selected clients — and the test-set evaluation batches —
//! across the pool, then reduces results in job order with the same f64
//! arithmetic as the sequential path, so a `RunResult` is **bit-identical
//! for any worker count** (`--workers N` on the CLI, `workers` in
//! [`fl::RunConfig`]; 0 = auto via `FEDCORE_THREADS` /
//! `util::pool::default_threads`). *Which* worker runs which job is a
//! deterministic [`exec::DispatchPolicy`] (`--dispatch`, `[fl] dispatch`,
//! `FEDCORE_DISPATCH`): round-robin dealing, or a work-stealing schedule
//! simulated in virtual time from the plans' simulated costs — better
//! utilization under heavy-tailed rounds, with model outputs still
//! bit-identical and the placement ledger ([`exec::ScheduleTrace`])
//! replayable from the seed (`rust/tests/proptest_dispatch.rs`).
//!
//! # Client availability scenarios
//!
//! The [`scenario`] subsystem adds trace-driven churn on top of the
//! static fleet: an availability trace (explicit intervals or a
//! parametric churn model) decides which clients are online at each
//! round's simulated start time, the engine samples only those, and
//! clients that go offline mid-round are dropped with their partial work
//! surfaced per-round. `--trace <file>` on the CLI, `[scenario]` in
//! config files, `trace` in [`fl::RunConfig`].
//!
//! # Async round overlap
//!
//! With [`fl::RunConfig::overlap`] set (`--overlap` on the CLI,
//! `[fl] overlap/quorum/max_staleness/alpha` in config files) the engine
//! stops barriering every round on its slowest client: it aggregates —
//! and dispatches the next round — as soon as a quorum of the round's
//! contributing clients has finished, and folds late arrivals into later
//! rounds as delayed gradients weighted `1/(1+staleness)^alpha`
//! (discarded past `max_staleness`; see [`exec::overlapped`]). The
//! degenerate policy (`quorum = 1.0`, `max_staleness = 0`) reproduces
//! the synchronous engine bit-for-bit, which anchors the differential
//! property suite in `rust/tests/proptest_overlap.rs`.

//!
//! # Server aggregation policies
//!
//! The [`agg`] subsystem makes the server's aggregation rule pluggable
//! ([`fl::RunConfig::aggregator`], `--agg` on the CLI, `[fl]
//! agg/server_momentum/buffer_k/trim_frac/clip_norm` in config files):
//! the classic weighted mean, FedBuff-style buffered aggregation with
//! server momentum, and robust aggregators (per-coordinate trimmed mean
//! / median, update-norm clipping) that survive the corrupted-update
//! scenarios in [`scenario::corruption`]. Every policy is RNG-free and
//! order-deterministic; the degenerate settings (`buffered` with
//! `k = 0, β = 0`, `trimmed_mean` with `trim_frac = 0`) reproduce the
//! mean **bit-for-bit** (`rust/tests/proptest_agg.rs`). An
//! [`agg::AdaptiveQuorum`] controller can additionally tighten or relax
//! the overlapped pipeline's quorum from the observed stale-discard
//! rate (`--adaptive-quorum`).
//!
//! # Observability
//!
//! The [`obs`] subsystem is a structured, write-only telemetry spine
//! ([`fl::RunConfig::obs`], `--obs-trace` on the CLI, `[experiment]
//! obs_trace` in config files): a [`obs::Recorder`] sink records
//! schema-versioned JSONL spans (round lifecycle phases with both
//! virtual- and wall-time bounds, per-job/per-worker schedule spans),
//! events (staleness folds/discards, churn dropouts, aggregation
//! rejections), a typed per-round counter registry, rate-limited warn
//! diagnostics, and per-round peak-RSS samples. `fedcore report`
//! renders a trace into a phase breakdown table, a critical-path /
//! straggler-tail summary, and an SVG timeline. Recording never feeds
//! back into the run: a traced run is bit-identical to an untraced one
//! (determinism rule 7, `rust/tests/proptest_obs.rs`); see
//! `docs/observability.md`.

#![warn(missing_docs)]

pub mod agg;
pub mod config;
pub mod coreset;
pub mod data;
pub mod exec;
pub mod expt;
pub mod fl;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
