//! Adaptive quorum control for the overlapped pipeline.
//!
//! The async round overlap ([`crate::exec::overlapped`]) aggregates at a
//! fixed quorum fraction; when the fraction is too low for the fleet's
//! tail, many late updates exceed the staleness cap and are **discarded**
//! — wasted client work. [`AdaptiveQuorum`] closes the loop: each round
//! it observes how the round's resolved late updates split into folded
//! vs discarded, tightens the quorum (waits for more clients) when the
//! discard rate exceeds a target, and relaxes it back toward the
//! configured floor when the pipeline runs clean.
//!
//! Determinism: the controller is a pure function of the observed
//! per-round counts — no RNG, no wall clock — so adaptive runs replay
//! bit-for-bit from their seed like every other configuration.

/// Proportional quorum controller (see the module docs). The current
/// quorum always stays within `[floor, 1.0]`, where `floor` is the
/// configured [`OverlapConfig::quorum`](crate::exec::OverlapConfig).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdaptiveQuorum {
    /// Acceptable stale-discard rate among resolved late updates.
    target: f64,
    /// Quorum adjustment per observed round.
    step: f64,
    /// The configured (most relaxed) quorum.
    floor: f64,
    /// The current quorum.
    q: f64,
}

impl AdaptiveQuorum {
    /// Default controller: target discard rate 10%, step 0.05 per round,
    /// starting at (and never relaxing below) `initial_quorum`.
    pub fn new(initial_quorum: f64) -> AdaptiveQuorum {
        AdaptiveQuorum::with_params(initial_quorum, 0.1, 0.05)
    }

    /// Controller with explicit target discard rate and per-round step.
    pub fn with_params(initial_quorum: f64, target: f64, step: f64) -> AdaptiveQuorum {
        let floor = initial_quorum.clamp(0.0, 1.0);
        AdaptiveQuorum { target: target.max(0.0), step: step.max(0.0), floor, q: floor }
    }

    /// The quorum the engine should use for the next round.
    pub fn quorum(&self) -> f64 {
        self.q
    }

    /// The configured floor the controller relaxes back to.
    pub fn floor(&self) -> f64 {
        self.floor
    }

    /// Feed one round's late-update resolution counts: `folded` delayed
    /// updates entered an aggregation, `discarded` exceeded the staleness
    /// cap. A discard rate above the target tightens the quorum one step
    /// (toward 1.0); otherwise — including rounds with no late updates at
    /// all — the quorum relaxes one step back toward the floor.
    pub fn observe(&mut self, folded: usize, discarded: usize) {
        let resolved = folded + discarded;
        let tighten = resolved > 0 && (discarded as f64 / resolved as f64) > self.target;
        self.q = if tighten {
            (self.q + self.step).min(1.0)
        } else {
            (self.q - self.step).max(self.floor)
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_floor_and_stays_bounded() {
        let mut a = AdaptiveQuorum::new(0.6);
        assert_eq!(a.quorum(), 0.6);
        assert_eq!(a.floor(), 0.6);
        // Many discard-heavy rounds: saturates at 1.0, never beyond.
        for _ in 0..100 {
            a.observe(0, 5);
            assert!(a.quorum() <= 1.0 && a.quorum() >= 0.6);
        }
        assert_eq!(a.quorum(), 1.0);
        // Many clean rounds: decays back to the floor, never below.
        for _ in 0..100 {
            a.observe(3, 0);
            assert!(a.quorum() >= 0.6);
        }
        assert_eq!(a.quorum(), 0.6);
    }

    #[test]
    fn reacts_to_the_discard_rate_not_the_count() {
        let mut a = AdaptiveQuorum::with_params(0.5, 0.5, 0.1);
        // 1 of 4 discarded = 25% ≤ target 50%: relax (already at floor).
        a.observe(3, 1);
        assert_eq!(a.quorum(), 0.5);
        // 3 of 4 discarded = 75% > 50%: tighten.
        a.observe(1, 3);
        assert!((a.quorum() - 0.6).abs() < 1e-12);
        // Quiet round (nothing resolved): relax toward the floor.
        a.observe(0, 0);
        assert_eq!(a.quorum(), 0.5);
    }

    #[test]
    fn controller_is_deterministic() {
        let run = |obs: &[(usize, usize)]| {
            let mut a = AdaptiveQuorum::new(0.7);
            for &(f, d) in obs {
                a.observe(f, d);
            }
            a.quorum()
        };
        let obs = [(1, 0), (0, 2), (2, 2), (0, 0), (5, 1)];
        assert_eq!(run(&obs).to_bits(), run(&obs).to_bits());
    }
}
