//! FedBuff-style buffered aggregation with server momentum.
//!
//! Instead of applying every round's mean immediately, the server
//! accumulates (staleness-weighted) updates in a buffer and applies them
//! as one weighted mean once the buffer holds at least `k` of them —
//! the buffered-asynchronous design of FedBuff (Nguyen et al., 2022),
//! which the delayed-gradient line of work (arXiv:2102.06329) motivates
//! as the server-side complement to staleness weighting. An optional
//! server momentum β smooths consecutive applications:
//!
//! ```text
//! w̄    = Σ λᵢ wᵢ / Σ λᵢ          (the buffered weighted mean)
//! v    ← β·v + (w̄ − w)           (velocity, in f64)
//! w    ← w + v
//! ```
//!
//! Degeneracy: with `β = 0` the velocity is exactly `w̄ − w`, so the
//! update is applied as `w̄` **directly** (no `w + (w̄ − w)` rounding
//! detour), and with `k = 0` the buffer flushes every round — together
//! reproducing [`Mean`](super::Mean) bit-for-bit
//! (`rust/tests/proptest_agg.rs`).

use super::{aggregate_weighted, AggStats, Aggregator};

/// The FedBuff-style server buffer (see the module docs).
#[derive(Clone, Debug)]
pub struct Buffered {
    /// Buffer threshold: apply once at least this many updates are held
    /// (`0` = flush every round that contributed anything).
    k: usize,
    /// Server momentum β in `[0, 1)`.
    momentum: f64,
    /// Buffered updates (owned copies) with their fold weights, in
    /// arrival order — the engine's deterministic fold order, so a
    /// flush aggregates exactly like the unbuffered path would have.
    buf_params: Vec<Vec<f32>>,
    buf_weights: Vec<f64>,
    /// Momentum velocity, in f64 (empty until the first momentum apply).
    velocity: Vec<f64>,
}

impl Buffered {
    /// A buffer that applies every `k` updates with momentum `momentum`.
    pub fn new(k: usize, momentum: f64) -> Buffered {
        Buffered {
            k,
            momentum,
            buf_params: Vec::new(),
            buf_weights: Vec::new(),
            velocity: Vec::new(),
        }
    }

    /// Updates currently held in the buffer.
    pub fn buffered(&self) -> usize {
        self.buf_params.len()
    }

    /// Drain the buffer and apply its weighted mean to `current` (with
    /// momentum when configured). `None` when the buffer was empty or
    /// carried no positive weight.
    fn apply(&mut self, current: &[f32]) -> Option<Vec<f32>> {
        let refs: Vec<&[f32]> = self.buf_params.iter().map(|v| v.as_slice()).collect();
        let mean = aggregate_weighted(&refs, &self.buf_weights);
        self.buf_params.clear();
        self.buf_weights.clear();
        let mean = mean?;
        if self.momentum == 0.0 {
            // β = 0: the velocity is exactly (w̄ − w), so w + v = w̄ —
            // apply the mean directly to keep the degenerate policy
            // bit-identical to `Mean` (no f64 add/subtract round trip).
            self.velocity.clear();
            return Some(mean);
        }
        if self.velocity.len() != current.len() {
            self.velocity = vec![0.0; current.len()];
        }
        let mut out = Vec::with_capacity(current.len());
        for ((&w, &m), v) in current.iter().zip(&mean).zip(self.velocity.iter_mut()) {
            *v = self.momentum * *v + (m as f64 - w as f64);
            out.push((w as f64 + *v) as f32);
        }
        Some(out)
    }
}

impl Aggregator for Buffered {
    fn label(&self) -> &'static str {
        "buffered"
    }

    fn aggregate_round(
        &mut self,
        current: &[f32],
        locals: &[&[f32]],
        weights: &[f64],
    ) -> (Option<Vec<f32>>, AggStats) {
        assert_eq!(locals.len(), weights.len(), "one weight per contribution");
        for (l, &w) in locals.iter().zip(weights) {
            self.buf_params.push(l.to_vec());
            self.buf_weights.push(w);
        }
        let threshold = self.k.max(1);
        if self.buf_params.is_empty() || self.buf_params.len() < threshold {
            return (None, AggStats { buffered: self.buf_params.len(), ..AggStats::default() });
        }
        (self.apply(current), AggStats::default())
    }

    fn flush(&mut self, current: &[f32]) -> Option<Vec<f32>> {
        if self.buf_params.is_empty() {
            return None;
        }
        self.apply(current)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::Mean;

    fn refs(v: &[Vec<f32>]) -> Vec<&[f32]> {
        v.iter().map(|x| x.as_slice()).collect()
    }

    #[test]
    fn degenerate_buffer_is_bitwise_mean() {
        let locals = vec![vec![0.5f32, -2.25, 3.0], vec![1.75f32, 0.1, -0.6]];
        let weights = [1.0, 0.5];
        let current = [9.0f32, 9.0, 9.0];
        let (want, _) = Mean.aggregate_round(&current, &refs(&locals), &weights);
        let mut buf = Buffered::new(0, 0.0);
        let (got, stats) = buf.aggregate_round(&current, &refs(&locals), &weights);
        for (x, y) in want.unwrap().iter().zip(&got.unwrap()) {
            assert_eq!(x.to_bits(), y.to_bits(), "k=0, β=0 must be Mean bit-for-bit");
        }
        assert_eq!(stats, AggStats::default());
        assert_eq!(buf.buffered(), 0, "degenerate buffer must drain every round");
    }

    #[test]
    fn buffer_holds_until_threshold_then_flushes() {
        let mut buf = Buffered::new(3, 0.0);
        let current = [0.0f32];
        let a = vec![vec![1.0f32]];
        let (out, stats) = buf.aggregate_round(&current, &refs(&a), &[1.0]);
        assert!(out.is_none());
        assert_eq!(stats.buffered, 1);
        let b = vec![vec![3.0f32]];
        let (out, stats) = buf.aggregate_round(&current, &refs(&b), &[1.0]);
        assert!(out.is_none());
        assert_eq!(stats.buffered, 2);
        // Third update reaches the threshold: the whole buffer applies.
        let c = vec![vec![5.0f32]];
        let (out, stats) = buf.aggregate_round(&current, &refs(&c), &[1.0]);
        assert_eq!(out.unwrap(), vec![3.0f32]); // (1 + 3 + 5) / 3
        assert_eq!(stats.buffered, 0);
        assert_eq!(buf.buffered(), 0);
    }

    #[test]
    fn flush_drains_a_partial_buffer() {
        let mut buf = Buffered::new(10, 0.0);
        let current = [0.0f32];
        let a = vec![vec![2.0f32], vec![4.0f32]];
        let (out, _) = buf.aggregate_round(&current, &refs(&a), &[1.0, 1.0]);
        assert!(out.is_none());
        assert_eq!(buf.flush(&current).unwrap(), vec![3.0f32]);
        assert!(buf.flush(&current).is_none(), "flush of an empty buffer is a no-op");
    }

    #[test]
    fn momentum_carries_velocity_across_applies() {
        let mut buf = Buffered::new(0, 0.5);
        let current = [0.0f32];
        let up = vec![vec![1.0f32]];
        // First apply: v = 0.5·0 + (1 − 0) = 1 → w = 1.
        let (out, _) = buf.aggregate_round(&current, &refs(&up), &[1.0]);
        let w1 = out.unwrap();
        assert_eq!(w1, vec![1.0f32]);
        // Second apply from w = 1 with mean 1: v = 0.5·1 + 0 = 0.5 → w = 1.5
        // (momentum overshoots past the stationary mean).
        let (out, _) = buf.aggregate_round(&w1, &refs(&up), &[1.0]);
        assert_eq!(out.unwrap(), vec![1.5f32]);
    }

    #[test]
    fn empty_round_never_applies() {
        let mut buf = Buffered::new(0, 0.0);
        let (out, stats) = buf.aggregate_round(&[1.0f32], &[], &[]);
        assert!(out.is_none());
        assert_eq!(stats, AggStats::default());
    }

    #[test]
    fn zero_weight_buffer_keeps_the_model() {
        let mut buf = Buffered::new(0, 0.0);
        let up = vec![vec![5.0f32]];
        let (out, _) = buf.aggregate_round(&[1.0f32], &refs(&up), &[0.0]);
        assert!(out.is_none(), "non-positive total weight must not move the model");
        assert_eq!(buf.buffered(), 0, "the dud buffer still drains");
    }
}
