//! The reference aggregation semantics: the (weighted) FedAvg mean.
//!
//! [`aggregate`] and [`aggregate_weighted`] are the free functions the
//! engine has always used (they moved here from `fl/engine.rs`; `fl`
//! re-exports them unchanged). [`Mean`] lifts them behind the
//! [`Aggregator`] trait — bit-identical to calling the free function,
//! which anchors every other policy's degenerate-equivalence gate.

use super::{AggStats, Aggregator};

/// FedAvg aggregation (Algorithm 1 line 15): wᵣ₊₁ = (1/K) Σ wᵢ, computed
/// in f64 for order-independence up to f32 rounding. Returns None when no
/// client contributed (all dropped — the server keeps the old model).
pub fn aggregate(locals: &[&[f32]]) -> Option<Vec<f32>> {
    let first = locals.first()?;
    let mut acc = vec![0.0f64; first.len()];
    for l in locals {
        assert_eq!(l.len(), acc.len(), "parameter dimension mismatch");
        for (a, &p) in acc.iter_mut().zip(*l) {
            *a += p as f64;
        }
    }
    let k = locals.len() as f64;
    Some(acc.into_iter().map(|a| (a / k) as f32).collect())
}

/// Weighted FedAvg aggregation for the overlapped pipeline:
/// wᵣ₊₁ = Σ λᵢ wᵢ / Σ λᵢ, computed in f64 in caller order (on-time
/// cohort in selection order, then delayed arrivals by
/// `(origin_round, slot)`). With unit weights this reproduces
/// [`aggregate`] **bit-for-bit** — `1.0 * x` is exact and the weight sum
/// accumulates to exactly `k` — which is what lets the degenerate
/// overlapped configuration match the synchronous engine
/// (`rust/tests/proptest_overlap.rs`). Returns None when nothing
/// contributed or the total weight is not positive (the server keeps the
/// old model).
pub fn aggregate_weighted(locals: &[&[f32]], weights: &[f64]) -> Option<Vec<f32>> {
    assert_eq!(locals.len(), weights.len(), "one weight per contribution");
    let first = locals.first()?;
    let mut acc = vec![0.0f64; first.len()];
    let mut total = 0.0f64;
    for (l, &w) in locals.iter().zip(weights) {
        assert_eq!(l.len(), acc.len(), "parameter dimension mismatch");
        total += w;
        for (a, &p) in acc.iter_mut().zip(*l) {
            *a += w * (p as f64);
        }
    }
    if total <= 0.0 {
        return None;
    }
    Some(acc.into_iter().map(|a| (a / total) as f32).collect())
}

/// Straggler-distillation correction (arXiv:2403.09086 shape): blend
/// weight-decayed past-staleness updates into the freshly aggregated
/// model *after* the main aggregate, instead of discarding them.
///
/// The current model carries unit weight; each distilled update `uⱼ`
/// carries its (already decayed) weight `λⱼ`, so the result is
/// `(w + Σ λⱼ uⱼ) / (1 + Σ λⱼ)`, computed in f64 in caller order like
/// [`aggregate_weighted`]. With no updates — the `distill_weight = 0`
/// degenerate path never collects any — the input is returned
/// **unchanged, bitwise**: not a single f32 operation runs, which is
/// what lets the engine's drop path stay byte-identical
/// (`rust/tests/proptest_select.rs`). Non-positive or non-finite
/// weights contribute nothing (their updates are skipped).
pub fn apply_distilled(current: &[f32], updates: &[(&[f32], f64)]) -> Vec<f32> {
    if updates.is_empty() {
        return current.to_vec();
    }
    let mut acc: Vec<f64> = current.iter().map(|&p| p as f64).collect();
    let mut total = 1.0f64;
    for (u, w) in updates {
        assert_eq!(u.len(), acc.len(), "parameter dimension mismatch");
        if !(*w > 0.0 && w.is_finite()) {
            continue;
        }
        total += w;
        for (a, &p) in acc.iter_mut().zip(*u) {
            *a += w * (p as f64);
        }
    }
    acc.into_iter().map(|a| (a / total) as f32).collect()
}

/// The weighted mean behind the [`Aggregator`] trait: exactly
/// [`aggregate_weighted`], no state, no accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct Mean;

impl Aggregator for Mean {
    fn label(&self) -> &'static str {
        "mean"
    }

    fn aggregate_round(
        &mut self,
        _current: &[f32],
        locals: &[&[f32]],
        weights: &[f64],
    ) -> (Option<Vec<f32>>, AggStats) {
        (aggregate_weighted(locals, weights), AggStats::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_aggregate_with_unit_weights_is_bitwise_plain() {
        let a = vec![0.125f32, -3.5, 7.75, 0.1];
        let b = vec![1.0f32, 2.0, -0.25, 0.3];
        let c = vec![9.5f32, 0.0, 1.5, -0.7];
        let locals: Vec<&[f32]> = vec![&a, &b, &c];
        let plain = aggregate(&locals).unwrap();
        let weighted = aggregate_weighted(&locals, &[1.0, 1.0, 1.0]).unwrap();
        for (x, y) in plain.iter().zip(&weighted) {
            assert_eq!(x.to_bits(), y.to_bits(), "unit weights must degenerate exactly");
        }
    }

    #[test]
    fn weighted_aggregate_downweights_stale_contributions() {
        let fresh = vec![0.0f32];
        let stale = vec![10.0f32];
        let locals: Vec<&[f32]> = vec![&fresh, &stale];
        // weight 1 vs 0.5: (0*1 + 10*0.5) / 1.5 = 10/3
        let out = aggregate_weighted(&locals, &[1.0, 0.5]).unwrap();
        assert!((out[0] - 10.0 / 1.5).abs() < 1e-6);
        // Heavier staleness discount pulls the mean toward the fresh update.
        let lighter = aggregate_weighted(&locals, &[1.0, 0.25]).unwrap();
        assert!(lighter[0] < out[0]);
    }

    #[test]
    fn weighted_aggregate_empty_and_zero_weight() {
        assert!(aggregate_weighted(&[], &[]).is_none());
        let p = vec![1.0f32];
        let locals: Vec<&[f32]> = vec![&p];
        assert!(aggregate_weighted(&locals, &[0.0]).is_none());
    }

    #[test]
    fn mean_trait_is_bitwise_free_function() {
        let a = vec![0.3f32, -1.5, 2.25];
        let b = vec![4.125f32, 0.5, -0.75];
        let locals: Vec<&[f32]> = vec![&a, &b];
        let weights = [1.0, 0.5];
        let (out, stats) = Mean.aggregate_round(&[0.0; 3], &locals, &weights);
        let free = aggregate_weighted(&locals, &weights).unwrap();
        let out = out.unwrap();
        for (x, y) in out.iter().zip(&free) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(stats, AggStats::default());
        // Empty round: the server keeps its model.
        let (none, _) = Mean.aggregate_round(&[0.0; 3], &[], &[]);
        assert!(none.is_none());
    }

    #[test]
    fn distilled_empty_is_bitwise_identity() {
        let current = vec![0.1f32, -2.5, 3.75];
        let out = apply_distilled(&current, &[]);
        for (a, b) in current.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits(), "no updates must be a no-op");
        }
    }

    #[test]
    fn distilled_blends_toward_updates() {
        let current = vec![0.0f32];
        let u = vec![10.0f32];
        // (0·1 + 10·0.5) / 1.5 = 10/3
        let out = apply_distilled(&current, &[(&u, 0.5)]);
        assert!((out[0] - 10.0 / 1.5).abs() < 1e-6);
        // A lighter weight pulls less.
        let lighter = apply_distilled(&current, &[(&u, 0.25)]);
        assert!(lighter[0] < out[0]);
    }

    #[test]
    fn distilled_skips_nonpositive_and_nonfinite_weights() {
        let current = vec![1.0f32, 2.0];
        let u = vec![100.0f32, 100.0];
        for w in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let out = apply_distilled(&current, &[(&u, w)]);
            // Degenerate weights contribute nothing; the 1/1 blend is
            // numerically the identity in f64 -> f32 round-trip.
            for (a, b) in current.iter().zip(&out) {
                assert_eq!(a.to_bits(), b.to_bits(), "weight {w} must be inert");
            }
        }
    }
}
