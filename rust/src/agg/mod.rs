//! Pluggable server aggregation: the seam between "the round's weighted
//! contributions" and "the next global model".
//!
//! The engine builds one ordered list per round — the on-time cohort in
//! selection order at unit weight, then arrived delayed gradients by
//! `(origin round, slot)` at their staleness weights — and folds it
//! through an [`Aggregator`]:
//!
//! * [`Mean`] — the classic weighted FedAvg mean ([`aggregate`] /
//!   [`aggregate_weighted`] live here now; `fl` re-exports them), the
//!   reference semantics every other policy degenerates to.
//!   [`apply_distilled`] rides alongside: the straggler-distillation
//!   correction that blends weight-decayed past-staleness updates into
//!   the model *after* the main aggregate (`--distill-weight`; inert at
//!   weight 0).
//! * [`Buffered`] — FedBuff-style server buffering: accumulate K
//!   (staleness-weighted) updates across rounds, apply them as one
//!   weighted mean with server momentum β. The degenerate policy
//!   (`k = 0` ⇒ flush every round, `β = 0`) reproduces [`Mean`]
//!   **bit-for-bit**.
//! * [`TrimmedMean`] / [`CoordinateMedian`] — per-coordinate robust
//!   aggregators that survive corrupted or adversarial client updates
//!   (sign flips, noise injection — see [`crate::scenario::corruption`]);
//!   [`NormClip`] wraps any of the above with update-norm clipping.
//! * [`TreeAggregator`] — hierarchical two-tier composition ([`tree`]):
//!   up to E edge aggregators over contiguous cohort shards, one root
//!   policy composing the edge aggregates. The Mean/Mean tree *relays*
//!   and reproduces the flat fold bit-for-bit at any fanout.
//! * [`AdaptiveQuorum`] — a controller that tightens the overlapped
//!   pipeline's quorum when the stale-discard rate rises and relaxes it
//!   back when the pipeline runs clean.
//!
//! Determinism contract: aggregators consume **no RNG** and hold only
//! state that is a pure function of the contribution sequence (the
//! buffer, the momentum velocity, the adaptive quorum), so every policy
//! replays bit-for-bit from the run's seed. Robust paths break ties by
//! `f32::total_cmp` then contribution index — never by pointer or hash
//! order. The differential gates live in `rust/tests/proptest_agg.rs`.

pub mod buffered;
pub mod mean;
pub mod quorum;
pub mod robust;
pub mod tree;

pub use buffered::Buffered;
pub use mean::{aggregate, aggregate_weighted, apply_distilled, Mean};
pub use quorum::AdaptiveQuorum;
pub use robust::{CoordinateMedian, NormClip, TrimmedMean};
pub use tree::{TreeAggregator, TreeSpec};

use anyhow::{anyhow, Result};

/// Per-round accounting from the aggregation seam, surfaced in
/// [`crate::metrics::RoundRecord`] (`agg_rejected` / `agg_clipped`) and
/// the CSV.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AggStats {
    /// Contribution-slots excluded from the aggregate per coordinate:
    /// `2·g` for [`TrimmedMean`] (g trimmed from each tail), `n − 1`
    /// (odd n) or `n − 2` (even n) for [`CoordinateMedian`], 0 for the
    /// mean/buffered paths.
    pub rejected: usize,
    /// Contributions whose update norm [`NormClip`] scaled down this
    /// round (0 without a clipping wrapper).
    pub clipped: usize,
    /// Updates held in the server buffer after this round ([`Buffered`]
    /// only; 0 once the buffer flushed).
    pub buffered: usize,
}

impl AggStats {
    /// Nothing rejected, clipped, or buffered this round — the quiet
    /// case the observability layer skips an event for.
    pub fn is_quiet(&self) -> bool {
        *self == AggStats::default()
    }

    /// The stats as named numeric fields, in emission order — the
    /// payload of the `agg` trace event ([`crate::obs`]).
    pub fn obs_fields(&self) -> [(&'static str, f64); 3] {
        [
            ("rejected", self.rejected as f64),
            ("clipped", self.clipped as f64),
            ("buffered", self.buffered as f64),
        ]
    }
}

/// One round's aggregation: fold weighted contributions (in the caller's
/// deterministic order) into the next global model.
///
/// Implementations must be RNG-free and order-deterministic: the same
/// `(current, locals, weights)` sequence across rounds must produce the
/// bit-identical outputs, regardless of worker count or wall clock.
pub trait Aggregator {
    /// Short policy label for logs and bench output.
    fn label(&self) -> &'static str;

    /// Fold one round's contributions into new global parameters.
    /// `locals[i]` carries weight `weights[i]`; both are in the engine's
    /// deterministic fold order. Returns `None` when nothing can be
    /// applied this round (the server keeps `current`), plus the round's
    /// accounting.
    fn aggregate_round(
        &mut self,
        current: &[f32],
        locals: &[&[f32]],
        weights: &[f64],
    ) -> (Option<Vec<f32>>, AggStats);

    /// End-of-run flush for policies that hold cross-round state
    /// ([`Buffered`]); the default has nothing to flush.
    fn flush(&mut self, _current: &[f32]) -> Option<Vec<f32>> {
        None
    }
}

impl<A: Aggregator + ?Sized> Aggregator for Box<A> {
    fn label(&self) -> &'static str {
        (**self).label()
    }

    fn aggregate_round(
        &mut self,
        current: &[f32],
        locals: &[&[f32]],
        weights: &[f64],
    ) -> (Option<Vec<f32>>, AggStats) {
        (**self).aggregate_round(current, locals, weights)
    }

    fn flush(&mut self, current: &[f32]) -> Option<Vec<f32>> {
        (**self).flush(current)
    }
}

/// Declarative aggregation policy: what [`crate::fl::RunConfig`] carries
/// and the CLI / `[fl]` config keys select. Built into a concrete
/// [`Aggregator`] once per run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum AggPolicy {
    /// Weighted FedAvg mean — the reference semantics (default).
    #[default]
    Mean,
    /// FedBuff-style server buffer with momentum (see [`Buffered`]).
    Buffered {
        /// Updates to accumulate before applying (`0` = flush every
        /// round; the degenerate "K = cohort" setting).
        k: usize,
        /// Server momentum β in `[0, 1)`; `0` applies the buffered mean
        /// directly (bit-identical to [`Mean`] when `k = 0`).
        momentum: f64,
    },
    /// Per-coordinate trimmed mean (see [`TrimmedMean`]).
    TrimmedMean {
        /// Fraction trimmed from **each** tail per coordinate, in
        /// `[0, 0.5)`; `0` trims nothing (bit-identical to [`Mean`]).
        trim_frac: f64,
    },
    /// Per-coordinate median (see [`CoordinateMedian`]).
    CoordinateMedian,
}

impl AggPolicy {
    /// Parse a policy name (knobs keep their defaults):
    /// `mean` | `buffered` | `trimmed_mean` (or `trimmed`) | `median`.
    pub fn parse(s: &str) -> Option<AggPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "mean" | "fedavg" => Some(AggPolicy::Mean),
            "buffered" | "fedbuff" => Some(AggPolicy::Buffered { k: 0, momentum: 0.0 }),
            "trimmed_mean" | "trimmed" => Some(AggPolicy::TrimmedMean { trim_frac: 0.1 }),
            "median" | "coordinate_median" => Some(AggPolicy::CoordinateMedian),
            _ => None,
        }
    }

    /// Canonical policy name.
    pub fn label(&self) -> &'static str {
        match self {
            AggPolicy::Mean => "mean",
            AggPolicy::Buffered { .. } => "buffered",
            AggPolicy::TrimmedMean { .. } => "trimmed_mean",
            AggPolicy::CoordinateMedian => "median",
        }
    }

    /// Validate the policy knobs (momentum in `[0, 1)`, trim fraction in
    /// `[0, 0.5)`).
    pub fn validate(&self) -> Result<()> {
        match self {
            AggPolicy::Mean | AggPolicy::CoordinateMedian => Ok(()),
            AggPolicy::Buffered { momentum, .. } => {
                if !(*momentum >= 0.0 && *momentum < 1.0) {
                    return Err(anyhow!(
                        "server momentum must be in [0, 1), got {momentum}"
                    ));
                }
                Ok(())
            }
            AggPolicy::TrimmedMean { trim_frac } => {
                if !(*trim_frac >= 0.0 && *trim_frac < 0.5) {
                    return Err(anyhow!(
                        "trim fraction must be in [0, 0.5), got {trim_frac}"
                    ));
                }
                Ok(())
            }
        }
    }

    /// Build the concrete aggregator, optionally wrapped in a
    /// [`NormClip`] layer (`clip_norm = Some(c)` clips update L2 norms
    /// to `c` before the base policy sees them).
    pub fn build(&self, clip_norm: Option<f64>) -> Box<dyn Aggregator> {
        let base: Box<dyn Aggregator> = match *self {
            AggPolicy::Mean => Box::new(Mean),
            AggPolicy::Buffered { k, momentum } => Box::new(Buffered::new(k, momentum)),
            AggPolicy::TrimmedMean { trim_frac } => Box::new(TrimmedMean::new(trim_frac)),
            AggPolicy::CoordinateMedian => Box::new(CoordinateMedian),
        };
        match clip_norm {
            Some(c) => Box::new(NormClip::new(c, base)),
            None => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_and_labels() {
        assert_eq!(AggPolicy::parse("mean"), Some(AggPolicy::Mean));
        assert_eq!(
            AggPolicy::parse("BUFFERED"),
            Some(AggPolicy::Buffered { k: 0, momentum: 0.0 })
        );
        assert_eq!(
            AggPolicy::parse("trimmed"),
            Some(AggPolicy::TrimmedMean { trim_frac: 0.1 })
        );
        assert_eq!(AggPolicy::parse("median"), Some(AggPolicy::CoordinateMedian));
        assert_eq!(AggPolicy::parse("nope"), None);
        assert_eq!(AggPolicy::default().label(), "mean");
    }

    #[test]
    fn policy_validation() {
        assert!(AggPolicy::Mean.validate().is_ok());
        assert!(AggPolicy::Buffered { k: 4, momentum: 0.9 }.validate().is_ok());
        assert!(AggPolicy::Buffered { k: 0, momentum: 1.0 }.validate().is_err());
        assert!(AggPolicy::Buffered { k: 0, momentum: -0.1 }.validate().is_err());
        assert!(AggPolicy::Buffered { k: 0, momentum: f64::NAN }.validate().is_err());
        assert!(AggPolicy::TrimmedMean { trim_frac: 0.49 }.validate().is_ok());
        assert!(AggPolicy::TrimmedMean { trim_frac: 0.5 }.validate().is_err());
        assert!(AggPolicy::TrimmedMean { trim_frac: -0.1 }.validate().is_err());
    }

    #[test]
    fn build_composes_clip_wrapper() {
        let plain = AggPolicy::Mean.build(None);
        assert_eq!(plain.label(), "mean");
        let clipped = AggPolicy::Mean.build(Some(1.0));
        assert_eq!(clipped.label(), "norm_clip");
    }

    #[test]
    fn stats_quietness_and_obs_fields() {
        assert!(AggStats::default().is_quiet());
        let noisy = AggStats { rejected: 2, clipped: 1, buffered: 0 };
        assert!(!noisy.is_quiet());
        assert_eq!(
            noisy.obs_fields(),
            [("rejected", 2.0), ("clipped", 1.0), ("buffered", 0.0)]
        );
    }
}
