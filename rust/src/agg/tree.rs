//! Hierarchical two-tier aggregation: E edge aggregators over contiguous
//! cohort shards, composed by one root aggregator.
//!
//! Cross-device FL servers rarely fold a whole cohort in one place — a
//! tier of edge aggregators (regional relays, parameter-server shards)
//! each reduces its slice of the cohort and the root composes the edge
//! aggregates. [`TreeAggregator`] reproduces that topology over the
//! engine's existing aggregation seam: the round's deterministic
//! contribution list is split into up to `fanout` contiguous shards, each
//! shard folds through a fresh instance of the *edge* policy (any
//! stateless [`AggPolicy`]), and the per-shard aggregates — weighted by
//! their shard's total contribution weight — fold through the persistent
//! *root* policy. Robust-at-edge/mean-at-root screens outliers close to
//! the clients; mean-at-edge/robust-at-root screens whole regions.
//!
//! # Determinism (tier-composition rule)
//!
//! The tree is part of the *model function* only through the policies it
//! composes, never through placement: shards are contiguous, in
//! selection order, and every edge folds its shard in that order, so the
//! output depends only on `(contribution sequence, edge policy, root
//! policy, fanout)` — never on worker count, dispatch, or wall clock
//! (the same rule 6 that governs [`crate::exec`]).
//!
//! f32 summation is non-associative, so a *reducing* edge tier is a
//! different (hierarchical) estimator from the flat fold. The degenerate
//! configuration is therefore explicit: a [`AggPolicy::Mean`] edge tier
//! with no norm clipping **relays** its shards' `(update, weight)` pairs
//! to the root unchanged — contiguous in-order shards concatenate back to
//! the original list — so a Mean/Mean tree reproduces the flat engine
//! **bit-for-bit** at any fanout (`rust/tests/proptest_tree.rs`). Norm
//! clipping ([`NormClip`]) composes at the edge tier, where client
//! updates are still individually visible.
//!
//! [`NormClip`]: crate::agg::NormClip

use anyhow::{anyhow, Result};

use super::{AggPolicy, AggStats, Aggregator};

/// Declarative two-tier aggregation topology: what
/// [`crate::fl::RunConfig::agg_tree`] carries and `--agg-tree` /
/// `[fl] agg_tree` select.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TreeSpec {
    /// E — number of edge aggregators. The cohort splits into up to `E`
    /// contiguous shards of `ceil(K / E)` contributions; `1` is a single
    /// edge over the whole cohort.
    pub fanout: usize,
    /// Per-shard edge policy. Must be stateless across rounds
    /// ([`AggPolicy::Buffered`] is rejected — edge instances are rebuilt
    /// every round, and cross-round edge state would couple the model to
    /// shard composition).
    pub edge: AggPolicy,
    /// Root policy composing the edge aggregates. Persistent across
    /// rounds, so buffered policies are allowed here.
    pub root: AggPolicy,
}

impl TreeSpec {
    /// A tree with `fanout` Mean edges and a Mean root — the degenerate
    /// relay topology that reproduces the flat engine bit-for-bit.
    pub fn mean(fanout: usize) -> TreeSpec {
        TreeSpec { fanout, edge: AggPolicy::Mean, root: AggPolicy::Mean }
    }

    /// Human-readable topology summary for banners and trace events.
    pub fn describe(&self) -> String {
        format!(
            "tree(fanout={}, edge={}, root={})",
            self.fanout,
            self.edge.label(),
            self.root.label()
        )
    }

    /// Reject meaningless topologies: zero fanout, a stateful edge
    /// policy, or invalid tier policy knobs.
    pub fn validate(&self) -> Result<()> {
        if self.fanout == 0 {
            return Err(anyhow!("aggregation tree fanout must be >= 1, got 0"));
        }
        if matches!(self.edge, AggPolicy::Buffered { .. }) {
            return Err(anyhow!(
                "buffered aggregation cannot run at the edge tier: edges are \
                 rebuilt per round, so cross-round buffers would silently drop \
                 updates (use it at the root instead)"
            ));
        }
        self.edge.validate()?;
        self.root.validate()
    }

    /// Build the concrete two-tier aggregator. `clip_norm` composes at
    /// the edge tier (see the module docs).
    pub fn build(&self, clip_norm: Option<f64>) -> TreeAggregator {
        TreeAggregator {
            spec: *self,
            clip_norm,
            root: self.root.build(None),
        }
    }
}

/// The two-tier [`Aggregator`]: per-round edge instances over contiguous
/// shards, one persistent root. See the module docs for the relay
/// discipline that makes the Mean/Mean tree exactly the flat fold.
pub struct TreeAggregator {
    spec: TreeSpec,
    /// Edge-tier norm clipping bound (`None` = no clipping).
    clip_norm: Option<f64>,
    /// Root policy instance, persistent across rounds (carries buffered
    /// state); built without clipping — it sees edge aggregates, not
    /// client updates.
    root: Box<dyn Aggregator>,
}

impl TreeAggregator {
    /// The topology this aggregator was built from.
    pub fn spec(&self) -> &TreeSpec {
        &self.spec
    }

    /// Mean edges with no clipping relay their shards unchanged: folding
    /// each pair through the root in order is bit-identical to the flat
    /// fold, so the edge tier vanishes from the model function entirely.
    fn relays(&self) -> bool {
        self.spec.edge == AggPolicy::Mean && self.clip_norm.is_none()
    }
}

impl Aggregator for TreeAggregator {
    fn label(&self) -> &'static str {
        "tree"
    }

    fn aggregate_round(
        &mut self,
        current: &[f32],
        locals: &[&[f32]],
        weights: &[f64],
    ) -> (Option<Vec<f32>>, AggStats) {
        // Relay discipline (and the trivial empty round): the root sees
        // the original contribution sequence, bitwise.
        if locals.is_empty() || self.relays() {
            return self.root.aggregate_round(current, locals, weights);
        }
        // Contiguous shards of ceil(K / E) contributions, in fold order.
        let shard = locals.len().div_ceil(self.spec.fanout);
        let mut edge_updates: Vec<Vec<f32>> = Vec::with_capacity(self.spec.fanout);
        let mut edge_weights: Vec<f64> = Vec::with_capacity(self.spec.fanout);
        let mut stats = AggStats::default();
        for (ls, ws) in locals.chunks(shard).zip(weights.chunks(shard)) {
            // Fresh edge instance per shard per round: edges hold no
            // cross-round state (TreeSpec::validate rejects Buffered).
            let mut edge = self.spec.edge.build(self.clip_norm);
            let (out, s) = edge.aggregate_round(current, ls, ws);
            stats.rejected += s.rejected;
            stats.clipped += s.clipped;
            stats.buffered += s.buffered;
            if let Some(update) = out {
                // The shard's aggregate enters the root fold at the
                // shard's total contribution weight, so a weighted-mean
                // root recovers the cohort-weighted composition.
                edge_updates.push(update);
                edge_weights.push(ws.iter().sum());
            }
        }
        let refs: Vec<&[f32]> = edge_updates.iter().map(|u| u.as_slice()).collect();
        let (out, root_stats) = self.root.aggregate_round(current, &refs, &edge_weights);
        stats.rejected += root_stats.rejected;
        stats.clipped += root_stats.clipped;
        stats.buffered += root_stats.buffered;
        (out, stats)
    }

    fn flush(&mut self, current: &[f32]) -> Option<Vec<f32>> {
        // Edges are per-round and hold nothing; only the root can.
        self.root.flush(current)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// A random round: `n` contributions of dimension `dim` with mixed
    /// positive weights.
    fn round(rng: &mut Rng, n: usize, dim: usize) -> (Vec<f32>, Vec<Vec<f32>>, Vec<f64>) {
        let current: Vec<f32> = (0..dim).map(|_| rng.f32() - 0.5).collect();
        let locals: Vec<Vec<f32>> =
            (0..n).map(|_| (0..dim).map(|_| 4.0 * (rng.f32() - 0.5)).collect()).collect();
        let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.25, 3.0)).collect();
        (current, locals, weights)
    }

    #[test]
    fn mean_mean_tree_is_flat_mean_bitwise_at_any_fanout() {
        let mut rng = Rng::new(0x7EE1);
        for &n in &[1usize, 2, 5, 9, 16] {
            let (current, locals, weights) = round(&mut rng, n, 17);
            let refs: Vec<&[f32]> = locals.iter().map(|l| l.as_slice()).collect();
            let (flat, flat_stats) =
                AggPolicy::Mean.build(None).aggregate_round(&current, &refs, &weights);
            let flat = flat.expect("flat mean yields params");
            for fanout in [1, 2, 3, n, n + 4] {
                let mut tree = TreeSpec::mean(fanout).build(None);
                let (out, stats) = tree.aggregate_round(&current, &refs, &weights);
                let out = out.expect("tree yields params");
                assert_eq!(stats, flat_stats, "fanout {fanout}: stats diverged");
                for (i, (a, b)) in flat.iter().zip(&out).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "n={n} fanout={fanout}: param {i} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn reducing_edges_are_a_different_estimator() {
        // A trimmed-mean edge tier actually reduces per shard: the result
        // is deterministic but deliberately NOT the flat fold.
        let mut rng = Rng::new(0x7EE2);
        let (current, locals, weights) = round(&mut rng, 12, 9);
        let refs: Vec<&[f32]> = locals.iter().map(|l| l.as_slice()).collect();
        let spec = TreeSpec {
            fanout: 3,
            edge: AggPolicy::TrimmedMean { trim_frac: 0.25 },
            root: AggPolicy::Mean,
        };
        let (a, stats_a) = spec.build(None).aggregate_round(&current, &refs, &weights);
        let (b, stats_b) = spec.build(None).aggregate_round(&current, &refs, &weights);
        assert_eq!(a, b, "tree aggregation must be deterministic");
        assert_eq!(stats_a, stats_b);
        assert!(stats_a.rejected > 0, "trimmed edges must report rejections");
        let (flat, _) = AggPolicy::Mean.build(None).aggregate_round(&current, &refs, &weights);
        assert_ne!(a, flat, "a robust edge tier should not equal the flat mean");
    }

    #[test]
    fn clipping_composes_at_the_edge_tier() {
        let mut rng = Rng::new(0x7EE3);
        let (current, locals, weights) = round(&mut rng, 8, 6);
        let refs: Vec<&[f32]> = locals.iter().map(|l| l.as_slice()).collect();
        // A tiny bound clips every update; the tree must count them all.
        let (out, stats) = TreeSpec::mean(4).build(Some(1e-3)).aggregate_round(
            &current,
            &refs,
            &weights,
        );
        assert!(out.is_some());
        assert_eq!(stats.clipped, 8, "every client update should clip at the edges");
        // And a clipped Mean tree is NOT the relay path.
        let (relay, _) = TreeSpec::mean(4).build(None).aggregate_round(&current, &refs, &weights);
        assert_ne!(out, relay);
    }

    #[test]
    fn buffered_root_flushes_through_the_tree() {
        let mut rng = Rng::new(0x7EE4);
        let (current, locals, weights) = round(&mut rng, 6, 5);
        let refs: Vec<&[f32]> = locals.iter().map(|l| l.as_slice()).collect();
        let spec = TreeSpec {
            fanout: 2,
            edge: AggPolicy::Mean,
            root: AggPolicy::Buffered { k: 100, momentum: 0.0 },
        };
        let mut tree = spec.build(None);
        let (out, stats) = tree.aggregate_round(&current, &refs, &weights);
        assert!(out.is_none(), "a far-from-full buffer applies nothing");
        assert!(stats.buffered > 0);
        assert!(tree.flush(&current).is_some(), "flush must drain the root buffer");
    }

    #[test]
    fn empty_round_behaves_like_flat() {
        let current = vec![0.5f32; 4];
        let mut tree = TreeSpec::mean(3).build(None);
        let (t_out, t_stats) = tree.aggregate_round(&current, &[], &[]);
        let (f_out, f_stats) = AggPolicy::Mean.build(None).aggregate_round(&current, &[], &[]);
        assert_eq!(t_out, f_out);
        assert_eq!(t_stats, f_stats);
    }

    #[test]
    fn validation_rejects_bad_topologies() {
        assert!(TreeSpec::mean(0).validate().is_err(), "zero fanout");
        let buffered_edge = TreeSpec {
            fanout: 2,
            edge: AggPolicy::Buffered { k: 4, momentum: 0.0 },
            root: AggPolicy::Mean,
        };
        assert!(buffered_edge.validate().is_err(), "buffered edge tier");
        let bad_knob = TreeSpec {
            fanout: 2,
            edge: AggPolicy::TrimmedMean { trim_frac: 0.7 },
            root: AggPolicy::Mean,
        };
        assert!(bad_knob.validate().is_err(), "invalid edge knob");
        let buffered_root = TreeSpec {
            fanout: 2,
            edge: AggPolicy::Mean,
            root: AggPolicy::Buffered { k: 4, momentum: 0.5 },
        };
        assert!(buffered_root.validate().is_ok(), "buffered root is legitimate");
        assert!(TreeSpec::mean(1).validate().is_ok());
    }

    #[test]
    fn describe_names_the_topology() {
        let spec = TreeSpec {
            fanout: 4,
            edge: AggPolicy::CoordinateMedian,
            root: AggPolicy::Mean,
        };
        assert_eq!(spec.describe(), "tree(fanout=4, edge=median, root=mean)");
        assert_eq!(spec.build(None).label(), "tree");
    }
}
