//! Robust aggregators: per-coordinate trimmed mean, per-coordinate
//! median, and an update-norm clipping wrapper.
//!
//! Motivated by the corrupted-update scenario family
//! ([`crate::scenario::corruption`]): when a fraction of clients returns
//! noisy or sign-flipped updates, the plain mean is dragged arbitrarily
//! far, while a trimmed mean with a trim count at least the corruption
//! count stays inside the honest values' envelope per coordinate (the
//! breakdown bound enforced by `rust/tests/proptest_agg.rs`).
//!
//! Determinism: every sort uses `f32::total_cmp` with the contribution
//! index as the tie-break, so equal (and even NaN) values trim
//! identically on every run. The `trim_frac = 0` / `clip = ∞` degenerate
//! paths delegate to the exact [`aggregate_weighted`] loop and are
//! bit-identical to [`Mean`](super::Mean).

use super::{aggregate_weighted, AggStats, Aggregator};

/// Per-coordinate trimmed mean: for each coordinate, drop the
/// `g = ⌊trim_frac · n⌋` smallest and largest values (capped so at least
/// one value survives), then take the weighted mean of the survivors.
#[derive(Clone, Copy, Debug)]
pub struct TrimmedMean {
    /// Fraction trimmed from each tail per coordinate, in `[0, 0.5)`.
    trim_frac: f64,
}

impl TrimmedMean {
    /// A trimmed mean dropping `⌊trim_frac · n⌋` values from each tail.
    pub fn new(trim_frac: f64) -> TrimmedMean {
        TrimmedMean { trim_frac }
    }

    /// How many values are trimmed from each tail for `n` contributions.
    pub fn trim_count(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        ((self.trim_frac * n as f64).floor() as usize).min((n - 1) / 2)
    }
}

impl Aggregator for TrimmedMean {
    fn label(&self) -> &'static str {
        "trimmed_mean"
    }

    fn aggregate_round(
        &mut self,
        _current: &[f32],
        locals: &[&[f32]],
        weights: &[f64],
    ) -> (Option<Vec<f32>>, AggStats) {
        assert_eq!(locals.len(), weights.len(), "one weight per contribution");
        let n = locals.len();
        let g = self.trim_count(n);
        if g == 0 {
            // Nothing to trim: the exact Mean loop, bit-for-bit.
            return (aggregate_weighted(locals, weights), AggStats::default());
        }
        let dim = locals[0].len();
        // Per coordinate: mark the g smallest and g largest values
        // (ties broken by contribution index — deterministic).
        let mut keep = vec![true; n * dim];
        let mut col: Vec<(f32, usize)> = Vec::with_capacity(n);
        for j in 0..dim {
            col.clear();
            for (i, l) in locals.iter().enumerate() {
                assert_eq!(l.len(), dim, "parameter dimension mismatch");
                col.push((l[j], i));
            }
            col.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            for t in 0..g {
                keep[col[t].1 * dim + j] = false;
                keep[col[n - 1 - t].1 * dim + j] = false;
            }
        }
        // Accumulate survivors in caller order — the same f64 loop shape
        // as `aggregate_weighted`, just with per-coordinate weight totals.
        let mut acc = vec![0.0f64; dim];
        let mut tot = vec![0.0f64; dim];
        for (i, l) in locals.iter().enumerate() {
            let w = weights[i];
            for (j, &p) in l.iter().enumerate() {
                if keep[i * dim + j] {
                    acc[j] += w * (p as f64);
                    tot[j] += w;
                }
            }
        }
        if tot.iter().any(|&t| t <= 0.0) {
            return (None, AggStats { rejected: 2 * g, ..AggStats::default() });
        }
        let out = acc.iter().zip(&tot).map(|(a, t)| (a / t) as f32).collect();
        (Some(out), AggStats { rejected: 2 * g, ..AggStats::default() })
    }
}

/// Per-coordinate median (weights are ignored — the median is already a
/// 50%-breakdown estimator; documented, not a bug). Even counts average
/// the two middle values in f64.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordinateMedian;

impl Aggregator for CoordinateMedian {
    fn label(&self) -> &'static str {
        "median"
    }

    fn aggregate_round(
        &mut self,
        _current: &[f32],
        locals: &[&[f32]],
        _weights: &[f64],
    ) -> (Option<Vec<f32>>, AggStats) {
        let n = locals.len();
        let Some(first) = locals.first() else {
            return (None, AggStats::default());
        };
        let dim = first.len();
        let mut out = Vec::with_capacity(dim);
        let mut col: Vec<f32> = Vec::with_capacity(n);
        for j in 0..dim {
            col.clear();
            for l in locals {
                assert_eq!(l.len(), dim, "parameter dimension mismatch");
                col.push(l[j]);
            }
            col.sort_by(f32::total_cmp);
            let m = if n % 2 == 1 {
                col[n / 2] as f64
            } else {
                (col[n / 2 - 1] as f64 + col[n / 2] as f64) / 2.0
            };
            out.push(m as f32);
        }
        let rejected = if n % 2 == 1 { n - 1 } else { n.saturating_sub(2) };
        (Some(out), AggStats { rejected, ..AggStats::default() })
    }
}

/// Update-norm clipping wrapper: before the inner aggregator runs, every
/// contribution whose update `wᵢ − w` has L2 norm above `max_norm` is
/// scaled back onto the norm ball (`w + (wᵢ − w)·max_norm/‖wᵢ − w‖`);
/// contributions inside the ball pass through **unmodified** (the same
/// slices — a non-finite `max_norm` disables clipping entirely and is
/// bit-transparent).
pub struct NormClip<A> {
    max_norm: f64,
    inner: A,
}

impl<A: Aggregator> NormClip<A> {
    /// Clip update norms to `max_norm` before delegating to `inner`.
    pub fn new(max_norm: f64, inner: A) -> NormClip<A> {
        NormClip { max_norm, inner }
    }

    /// The wrapped aggregator.
    pub fn inner(&self) -> &A {
        &self.inner
    }
}

impl<A: Aggregator> Aggregator for NormClip<A> {
    fn label(&self) -> &'static str {
        "norm_clip"
    }

    fn aggregate_round(
        &mut self,
        current: &[f32],
        locals: &[&[f32]],
        weights: &[f64],
    ) -> (Option<Vec<f32>>, AggStats) {
        if !self.max_norm.is_finite() {
            return self.inner.aggregate_round(current, locals, weights);
        }
        let mut clipped = 0usize;
        let scaled: Vec<Option<Vec<f32>>> = locals
            .iter()
            .map(|l| {
                assert_eq!(l.len(), current.len(), "parameter dimension mismatch");
                let norm = l
                    .iter()
                    .zip(current)
                    .map(|(&p, &c)| {
                        let d = p as f64 - c as f64;
                        d * d
                    })
                    .sum::<f64>()
                    .sqrt();
                if norm <= self.max_norm {
                    return None;
                }
                clipped += 1;
                let s = self.max_norm / norm;
                Some(
                    l.iter()
                        .zip(current)
                        .map(|(&p, &c)| (c as f64 + s * (p as f64 - c as f64)) as f32)
                        .collect(),
                )
            })
            .collect();
        let refs: Vec<&[f32]> = locals
            .iter()
            .zip(&scaled)
            .map(|(l, s)| s.as_deref().unwrap_or(l))
            .collect();
        let (out, mut stats) = self.inner.aggregate_round(current, &refs, weights);
        stats.clipped += clipped;
        (out, stats)
    }

    fn flush(&mut self, current: &[f32]) -> Option<Vec<f32>> {
        self.inner.flush(current)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::Mean;

    fn refs(v: &[Vec<f32>]) -> Vec<&[f32]> {
        v.iter().map(|x| x.as_slice()).collect()
    }

    #[test]
    fn trim_count_caps_at_survivor() {
        let t = TrimmedMean::new(0.4);
        assert_eq!(t.trim_count(0), 0);
        assert_eq!(t.trim_count(1), 0);
        assert_eq!(t.trim_count(2), 0); // 0.8 floors to 0
        assert_eq!(t.trim_count(5), 2);
        assert_eq!(t.trim_count(3), 1);
        // Even a huge fraction leaves at least one value.
        let t = TrimmedMean::new(0.49);
        assert_eq!(t.trim_count(100), 49);
    }

    #[test]
    fn zero_trim_is_bitwise_mean() {
        let locals = vec![vec![0.1f32, -7.5], vec![2.25f32, 0.3], vec![-1.0f32, 4.5]];
        let weights = [1.0, 0.5, 0.25];
        let (want, _) = Mean.aggregate_round(&[0.0; 2], &refs(&locals), &weights);
        let (got, stats) =
            TrimmedMean::new(0.0).aggregate_round(&[0.0; 2], &refs(&locals), &weights);
        for (x, y) in want.unwrap().iter().zip(&got.unwrap()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn trimmed_mean_discards_the_outlier() {
        // Four honest values near 1.0, one wild outlier per tail direction.
        let locals = vec![
            vec![1.0f32],
            vec![1.1f32],
            vec![0.9f32],
            vec![1.0f32],
            vec![1000.0f32],
        ];
        let weights = [1.0; 5];
        let (out, stats) = TrimmedMean::new(0.2).aggregate_round(&[0.0], &refs(&locals), &weights);
        let v = out.unwrap()[0];
        // g = 1: the 1000.0 and one honest extreme are gone; the result
        // stays inside the honest envelope.
        assert!((0.9..=1.1).contains(&v), "trimmed mean {v} left the honest range");
        assert_eq!(stats.rejected, 2);
        // The plain mean is dragged far outside it.
        let (mean, _) = Mean.aggregate_round(&[0.0], &refs(&locals), &weights);
        assert!(mean.unwrap()[0] > 100.0);
    }

    #[test]
    fn median_is_robust_and_counts_rejects() {
        let locals = vec![vec![1.0f32], vec![2.0f32], vec![900.0f32]];
        let (out, stats) = CoordinateMedian.aggregate_round(&[0.0], &refs(&locals), &[1.0; 3]);
        assert_eq!(out.unwrap(), vec![2.0f32]);
        assert_eq!(stats.rejected, 2);
        // Even count: mean of the middle two.
        let locals = vec![vec![1.0f32], vec![3.0f32], vec![5.0f32], vec![900.0f32]];
        let (out, stats) = CoordinateMedian.aggregate_round(&[0.0], &refs(&locals), &[1.0; 4]);
        assert_eq!(out.unwrap(), vec![4.0f32]);
        assert_eq!(stats.rejected, 2);
        let (none, _) = CoordinateMedian.aggregate_round(&[0.0], &[], &[]);
        assert!(none.is_none());
    }

    #[test]
    fn norm_clip_scales_only_over_threshold() {
        let current = vec![0.0f32, 0.0];
        // ‖(3,4)‖ = 5 → clipped to norm 1; ‖(0.6, 0.8)‖ = 1 → untouched.
        let locals = vec![vec![3.0f32, 4.0], vec![0.6f32, 0.8]];
        let (out, stats) =
            NormClip::new(1.0, Mean).aggregate_round(&current, &refs(&locals), &[1.0, 1.0]);
        assert_eq!(stats.clipped, 1);
        let out = out.unwrap();
        // Both contributions now sit at (0.6, 0.8): the mean is too.
        assert!((out[0] - 0.6).abs() < 1e-6 && (out[1] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn infinite_clip_is_bit_transparent() {
        let locals = vec![vec![5.5f32, -3.25], vec![100.0f32, 0.125]];
        let weights = [1.0, 2.0];
        let (want, _) = Mean.aggregate_round(&[0.0; 2], &refs(&locals), &weights);
        let (got, stats) = NormClip::new(f64::INFINITY, Mean)
            .aggregate_round(&[0.0; 2], &refs(&locals), &weights);
        for (x, y) in want.unwrap().iter().zip(&got.unwrap()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(stats.clipped, 0);
    }
}
