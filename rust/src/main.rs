//! `fedcore` — launcher CLI for the FedCore reproduction.
//!
//! Subcommands (first positional argument):
//!
//! * `run`    — one experiment (benchmark × strategy × straggler%), CSV out.
//! * `sweep`  — all four strategies on one benchmark (a Table 2 column pair).
//! * `data`   — generate a benchmark and print its Table 1 statistics.
//! * `info`   — show the artifact manifest the runtime would load.
//! * `report` — render an `--obs-trace` JSONL trace: per-round phase
//!   breakdown, critical-path / straggler-tail summary, SVG timeline
//!   (`--out`), straggler-forensics health report (`--health`, needs a
//!   trace recorded with `--obs-health`), or schema validation only
//!   (`--check`).
//!
//! Example:
//! ```text
//! fedcore run --bench synthetic(1,1) --strategy fedcore --stragglers 30 \
//!             --scale 0.2 --rounds 20 --out results/run.csv
//! ```

use anyhow::{anyhow, Result};

use fedcore::config::ExperimentConfig;
use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark};
use fedcore::exec::Executor as _;
use fedcore::fl::{all_strategies, Engine, Strategy};
use fedcore::metrics::table2_rows;
use fedcore::obs::Recorder as _;
use fedcore::runtime::Runtime;
use fedcore::util::cli::{Args, Cli};

fn cli() -> Cli {
    Cli::new(
        "fedcore",
        "straggler-free federated learning with distributed coresets \
         (run|sweep|data|info|report)",
    )
    .opt("bench", "synthetic(1,1)", "benchmark: mnist | shakespeare | synthetic(a,b)")
    .opt("strategy", "fedcore", "fedavg | fedavg-ds | fedprox | fedcore")
    .opt("stragglers", "30", "straggler percentage s")
    .opt("scale", "0.15", "dataset scale (1.0 = paper Table 1 sizes)")
    .opt("rounds", "0", "override communication rounds (0 = preset)")
    .opt("epochs", "0", "override local epochs (0 = preset, paper: 10)")
    .opt("clients", "0", "override clients per round K (0 = preset)")
    .opt("lr", "0", "override learning rate (0 = preset)")
    .opt("mu", "-1", "override FedProx mu (-1 = preset)")
    .opt("seed", "7", "root seed")
    .opt("method", "fasterpam", "coreset solver: fasterpam | pam | random | kcenter")
    .opt(
        "coreset-refresh",
        "0",
        "rebuild adaptive coresets every N rounds, warm-starting in between (0 = preset; 1 = every round)",
    )
    .opt("eval-cap", "512", "max test samples per evaluation (0 = all)")
    .opt("workers", "", "client-execution worker threads (0 = auto, 1 = sequential; default 1)")
    .opt(
        "dispatch",
        "",
        "job dispatch policy: round_robin (default) | work_stealing (env: FEDCORE_DISPATCH)",
    )
    .opt("trace", "", "client-availability trace file (see examples/traces/; empty = always-on)")
    .opt("quorum", "0.8", "overlap: fraction of contributing clients to await before aggregating")
    .opt("max-staleness", "2", "overlap: discard delayed updates older than this many rounds")
    .opt("alpha", "1", "overlap: staleness decay exponent for 1/(1+s)^alpha weighting")
    .opt("agg", "mean", "server aggregator: mean | buffered | trimmed_mean | median")
    .opt("server-momentum", "0", "buffered: server momentum beta in [0, 1)")
    .opt("buffer-k", "0", "buffered: updates per server-buffer flush (0 = every round)")
    .opt("trim-frac", "0.1", "trimmed_mean: fraction trimmed from each tail per coordinate")
    .opt(
        "agg-tree",
        "0",
        "two-tier aggregation: edge fan-out E (0 = flat seam; env: FEDCORE_AGG_TREE)",
    )
    .opt("agg-root", "mean", "tree root aggregator: mean | buffered | trimmed_mean | median")
    .opt("clip-norm", "0", "clip client update L2 norms before aggregating (0 = off)")
    .opt("corrupt", "", "scenario: corrupt a client fraction's updates: noise | sign_flip")
    .opt("corrupt-frac", "0.1", "scenario: fraction of clients corrupted")
    .opt("flaky-boost", "0", "selection: weight boost for low-uptime clients (needs --trace)")
    .opt(
        "select",
        "",
        "cohort selection policy: baseline | flanp | forecast (env: FEDCORE_SELECT)",
    )
    .opt("flanp-start", "0", "flanp: initial fastest-prefix size (0 = default 8)")
    .opt("flanp-factor", "2", "flanp: geometric prefix-widening factor (> 1)")
    .opt("flanp-threshold", "0.01", "flanp: relative loss-improvement stall threshold")
    .opt("forecast-bias", "1", "forecast: uptime bias strength (0 = baseline weights)")
    .opt(
        "distill-weight",
        "0",
        "overlap: fold past-staleness updates at this weight instead of dropping them (0 = drop)",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .opt("out", "", "CSV output path (empty = stdout summary only)")
    .opt("config", "", "TOML config file (configs/*.toml); CLI flags override")
    .opt("load-ckpt", "", "resume from a model checkpoint")
    .opt("save-ckpt", "", "write the final global model to this path")
    .opt("obs-trace", "", "write a structured JSONL trace here (run); trace to render (report)")
    .flag("obs-health", "run: sample per-client health + sketches into the trace (snapshot records)")
    .flag("check", "report: validate the trace against the schema and exit")
    .flag("health", "report: straggler leaderboard, critical-path attribution, anomaly flags")
    .flag("overlap", "async round overlap: quorum aggregation, staleness-weighted late updates")
    .flag("adaptive-quorum", "overlap: adapt the quorum from the observed stale-discard rate")
    .flag("static-coreset", "§4.3 static input-space coresets (default: adaptive)")
    .flag("quiet", "suppress per-round progress lines")
}

fn experiment_from_args(a: &Args) -> Result<ExperimentConfig> {
    let from_config = !a.get("config").is_empty();
    let mut cfg = if from_config {
        ExperimentConfig::from_file(a.get("config"))?
    } else {
        let bench = Benchmark::parse(a.get("bench"))
            .ok_or_else(|| anyhow!("unknown benchmark '{}'", a.get("bench")))?;
        ExperimentConfig::scaled_preset(bench, a.get_f64("scale"))
    };
    // CLI overrides: applied when given explicitly (i.e. differing from the
    // declared default), so `--config` files keep their values otherwise.
    let explicit = |name: &str, default: &str| a.get(name) != default;
    if !from_config || explicit("stragglers", "30") {
        cfg.run.straggler_pct = a.get_f64("stragglers");
    }
    if !from_config || explicit("seed", "7") {
        cfg.run.seed = a.get_u64("seed");
    }
    if !from_config || explicit("eval-cap", "512") {
        cfg.run.eval_cap = a.get_usize("eval-cap");
    }
    // Empty = not given (so `--workers 1` can force the sequential
    // reference path even over a config file's setting).
    if !a.get("workers").is_empty() {
        cfg.run.workers = a.get_usize("workers");
    }
    // Dispatch policy: empty = not given (like --workers), so an
    // explicit `--dispatch round_robin` always wins — over a config
    // file's `[fl] dispatch` and over the FEDCORE_DISPATCH environment
    // override, which only applies to flagless, fileless runs.
    if !a.get("dispatch").is_empty() {
        cfg.run.dispatch = fedcore::exec::DispatchPolicy::parse(a.get("dispatch"))
            .ok_or_else(|| anyhow!("unknown dispatch policy '{}'", a.get("dispatch")))?;
    } else if !from_config {
        cfg.run.dispatch = fedcore::exec::DispatchPolicy::from_env();
    }
    // A CLI trace overrides any [scenario] section from `--config`.
    if !a.get("trace").is_empty() {
        cfg.run.trace = Some(fedcore::scenario::TraceSpec::from_file(a.get("trace"))?);
    }
    // `--overlap` — or any explicit policy flag, mirroring the [fl]
    // section's semantics — enables the async pipeline (a config file may
    // also have enabled it); explicit policy flags override either source.
    let policy_given = explicit("quorum", "0.8")
        || explicit("max-staleness", "2")
        || explicit("alpha", "1");
    if (a.has("overlap") || policy_given) && cfg.run.overlap.is_none() {
        cfg.run.overlap = Some(fedcore::exec::OverlapConfig::default());
    }
    if let Some(ov) = &mut cfg.run.overlap {
        if explicit("quorum", "0.8") {
            ov.quorum = a.get_f64("quorum");
        }
        if explicit("max-staleness", "2") {
            ov.max_staleness = a.get_usize("max-staleness");
        }
        if explicit("alpha", "1") {
            ov.alpha = a.get_f64("alpha");
        }
        ov.validate()?;
    }
    if a.has("adaptive-quorum") {
        cfg.run.adaptive_quorum = true;
    }
    // Server aggregation policy: `--agg` selects, the knob flags
    // parameterize; any explicit flag overrides a config file's [fl]
    // keys, otherwise the file's policy stands.
    let agg_given = explicit("agg", "mean")
        || explicit("server-momentum", "0")
        || explicit("buffer-k", "0")
        || explicit("trim-frac", "0.1");
    if !from_config || agg_given {
        // Base policy: an explicit --agg wins; otherwise a config file's
        // [fl] policy stands (so `--config exp.toml --buffer-k 10` tunes
        // the file's buffered policy instead of resetting it).
        let mut pol = if !from_config || explicit("agg", "mean") {
            fedcore::agg::AggPolicy::parse(a.get("agg"))
                .ok_or_else(|| anyhow!("unknown aggregation policy '{}'", a.get("agg")))?
        } else {
            cfg.run.aggregator
        };
        // A knob flag without --agg implies its policy, like the config
        // file's knob keys do.
        if pol == fedcore::agg::AggPolicy::Mean && !explicit("agg", "mean") {
            if explicit("server-momentum", "0") || explicit("buffer-k", "0") {
                pol = fedcore::agg::AggPolicy::Buffered { k: 0, momentum: 0.0 };
            } else if explicit("trim-frac", "0.1") {
                pol = fedcore::agg::AggPolicy::TrimmedMean { trim_frac: 0.1 };
            }
        }
        // Explicit knob flags override; unset knobs keep the base
        // policy's values (CLI defaults for a fresh --agg, the config
        // file's values when tuning one).
        match &mut pol {
            fedcore::agg::AggPolicy::Buffered { k, momentum } => {
                if explicit("buffer-k", "0") {
                    *k = a.get_usize("buffer-k");
                }
                if explicit("server-momentum", "0") {
                    *momentum = a.get_f64("server-momentum");
                }
            }
            fedcore::agg::AggPolicy::TrimmedMean { trim_frac } => {
                if explicit("trim-frac", "0.1") {
                    *trim_frac = a.get_f64("trim-frac");
                }
            }
            _ => {}
        }
        // A knob aimed at a different policy is a config bug, not a
        // silent no-op.
        let buffered_knob = explicit("server-momentum", "0") || explicit("buffer-k", "0");
        if buffered_knob && !matches!(pol, fedcore::agg::AggPolicy::Buffered { .. }) {
            return Err(anyhow!(
                "--server-momentum/--buffer-k only apply to the buffered aggregator, got {}",
                pol.label()
            ));
        }
        if explicit("trim-frac", "0.1")
            && !matches!(pol, fedcore::agg::AggPolicy::TrimmedMean { .. })
        {
            return Err(anyhow!(
                "--trim-frac only applies to the trimmed_mean aggregator, got {}",
                pol.label()
            ));
        }
        pol.validate()?;
        cfg.run.aggregator = pol;
    }
    // Hierarchical aggregation: `--agg-tree E` replaces the flat seam
    // with a two-tier tree — the --agg policy runs at E-wide edge shards,
    // --agg-root composes the edge aggregates. FEDCORE_AGG_TREE seeds the
    // fan-out for flagless, fileless runs (like FEDCORE_DISPATCH); an
    // explicit `--agg-tree 0` forces the flat seam over any config file.
    let tree_fanout = if explicit("agg-tree", "0") {
        Some(a.get_usize("agg-tree"))
    } else if !from_config && cfg.run.agg_tree.is_none() {
        std::env::var("FEDCORE_AGG_TREE").ok().and_then(|v| v.trim().parse::<usize>().ok())
    } else {
        None
    };
    match tree_fanout {
        Some(0) => cfg.run.agg_tree = None,
        Some(fanout) => {
            cfg.run.agg_tree = Some(fedcore::agg::TreeSpec::mean(fanout));
        }
        None => {}
    }
    if explicit("agg-root", "mean") && cfg.run.agg_tree.is_none() {
        return Err(anyhow!("--agg-root only applies with --agg-tree (or a config file's tree)"));
    }
    if let Some(spec) = &mut cfg.run.agg_tree {
        // The edge tier stays in lockstep with the flat policy selection
        // (--agg or the [fl] agg key); an explicit --agg-root overrides
        // the root, which a fresh --agg-tree defaults to mean.
        spec.edge = cfg.run.aggregator;
        if explicit("agg-root", "mean") || matches!(tree_fanout, Some(f) if f > 0) {
            spec.root = fedcore::agg::AggPolicy::parse(a.get("agg-root"))
                .ok_or_else(|| anyhow!("unknown aggregation policy '{}'", a.get("agg-root")))?;
        }
        spec.validate()?;
    }
    if a.get_f64("clip-norm") > 0.0 {
        cfg.run.clip_norm = Some(a.get_f64("clip-norm"));
    }
    if a.get_f64("flaky-boost") > 0.0 {
        cfg.run.flaky_boost = a.get_f64("flaky-boost");
    }
    // Cohort selection policy: `--select` picks, the knob flags
    // parameterize; a knob flag alone implies its policy (like the [fl]
    // keys), and FEDCORE_SELECT seeds flagless, fileless runs (like
    // FEDCORE_DISPATCH).
    let flanp_given = explicit("flanp-start", "0")
        || explicit("flanp-factor", "2")
        || explicit("flanp-threshold", "0.01");
    let select_given =
        !a.get("select").is_empty() || flanp_given || explicit("forecast-bias", "1");
    if select_given {
        // Base policy: an explicit --select wins; otherwise a config
        // file's [fl] select stands (so knob flags tune it rather than
        // resetting it).
        let mut pol = if !a.get("select").is_empty() {
            fedcore::scenario::SelectPolicy::parse(a.get("select"))
                .ok_or_else(|| anyhow!("unknown selection policy '{}'", a.get("select")))?
        } else {
            cfg.run.select
        };
        if pol == fedcore::scenario::SelectPolicy::Baseline && a.get("select").is_empty() {
            if flanp_given {
                pol = fedcore::scenario::SelectPolicy::Flanp(Default::default());
            } else if explicit("forecast-bias", "1") {
                pol = fedcore::scenario::SelectPolicy::Forecast { bias: 1.0 };
            }
        }
        match &mut pol {
            fedcore::scenario::SelectPolicy::Flanp(fc) => {
                if explicit("flanp-start", "0") {
                    fc.start = a.get_usize("flanp-start");
                }
                if explicit("flanp-factor", "2") {
                    fc.factor = a.get_f64("flanp-factor");
                }
                if explicit("flanp-threshold", "0.01") {
                    fc.threshold = a.get_f64("flanp-threshold");
                }
            }
            fedcore::scenario::SelectPolicy::Forecast { bias } => {
                if explicit("forecast-bias", "1") {
                    *bias = a.get_f64("forecast-bias");
                }
            }
            fedcore::scenario::SelectPolicy::Baseline => {}
        }
        // A knob aimed at a different policy is a config bug, not a
        // silent no-op.
        if flanp_given && !matches!(pol, fedcore::scenario::SelectPolicy::Flanp(_)) {
            return Err(anyhow!(
                "--flanp-start/--flanp-factor/--flanp-threshold only apply to the flanp \
                 selection policy, got {}",
                pol.label()
            ));
        }
        if explicit("forecast-bias", "1")
            && !matches!(pol, fedcore::scenario::SelectPolicy::Forecast { .. })
        {
            return Err(anyhow!(
                "--forecast-bias only applies to the forecast selection policy, got {}",
                pol.label()
            ));
        }
        pol.validate()?;
        cfg.run.select = pol;
    } else if !from_config {
        cfg.run.select = fedcore::scenario::SelectPolicy::from_env();
    }
    // Straggler distillation: composes with any selection policy; the
    // engine rejects it without --overlap.
    if a.get_f64("distill-weight") > 0.0 {
        cfg.run.distill_weight = a.get_f64("distill-weight");
    }
    if !a.get("corrupt").is_empty() {
        let kind = fedcore::scenario::CorruptionKind::parse(a.get("corrupt"))
            .ok_or_else(|| anyhow!("unknown corruption kind '{}'", a.get("corrupt")))?;
        let spec =
            fedcore::scenario::CorruptionSpec::new(kind, a.get_f64("corrupt-frac"));
        spec.validate()?;
        cfg.run.corruption = Some(spec);
    }
    cfg.run.verbose = !a.has("quiet");
    if a.get_usize("rounds") > 0 {
        cfg.run.rounds = a.get_usize("rounds");
    }
    if a.get_usize("epochs") > 0 {
        cfg.run.epochs = a.get_usize("epochs");
    }
    if a.get_usize("clients") > 0 {
        cfg.run.clients_per_round = a.get_usize("clients");
    }
    if a.get_f64("lr") > 0.0 {
        cfg.run.lr = a.get_f64("lr") as f32;
    }
    if a.get_f64("mu") >= 0.0 {
        cfg.prox_mu = a.get_f64("mu") as f32;
    }
    if !from_config || explicit("method", "fasterpam") {
        cfg.run.coreset_method = Method::parse(a.get("method"))
            .ok_or_else(|| anyhow!("unknown coreset method '{}'", a.get("method")))?;
    }
    if a.has("static-coreset") {
        cfg.run.coreset_mode = fedcore::fl::CoresetMode::Static;
    }
    if a.get_usize("coreset-refresh") > 0 {
        cfg.run.coreset_refresh = a.get_usize("coreset-refresh");
    }
    // Observability sink (write-only — determinism rule 7). A CLI flag
    // overrides a config file's `[experiment] obs_trace`; `--obs-health`
    // turns on health sampling for whichever source configured the sink.
    if !a.get("obs-trace").is_empty() {
        cfg.run.obs = fedcore::obs::ObsConfig::Jsonl {
            path: a.get("obs-trace").to_string(),
            scale: cfg.scale,
            health: None,
        };
    }
    if a.has("obs-health") {
        match &mut cfg.run.obs {
            fedcore::obs::ObsConfig::Jsonl { health, .. } => {
                *health = Some(fedcore::obs::health::HealthConfig::default());
            }
            fedcore::obs::ObsConfig::Off => {
                return Err(anyhow!(
                    "--obs-health needs a trace sink: pass --obs-trace <path> \
                     (or set [experiment] obs_trace)"
                ));
            }
        }
    }
    Ok(cfg)
}

fn load_runtime(a: &Args) -> Result<Runtime> {
    Runtime::load(a.get("artifacts"))
}

fn cmd_run(a: &Args) -> Result<()> {
    let strategy = Strategy::parse(a.get("strategy"))
        .ok_or_else(|| anyhow!("unknown strategy '{}'", a.get("strategy")))?;
    let cfg = experiment_from_args(a)?.with_strategy(strategy);
    let rt = load_runtime(a)?;
    let ds = std::sync::Arc::new(data::generate(
        cfg.benchmark,
        cfg.scale,
        &rt.manifest().vocab,
        cfg.data_seed,
    ));
    eprintln!(
        "benchmark {} | {} clients, {} samples | strategy {} | {} rounds × {} epochs",
        cfg.benchmark.label(),
        ds.num_clients(),
        ds.total_samples(),
        cfg.run.strategy.label(),
        cfg.run.rounds,
        cfg.run.epochs,
    );
    let engine = Engine::new(&rt, &ds, cfg.run.clone())?;
    eprintln!(
        "fleet: deadline τ = {:.2}s, {:.0}% stragglers observed | exec workers: {} | dispatch: {}",
        engine.fleet.deadline,
        100.0 * engine.fleet.straggler_fraction(),
        engine.executor().workers(),
        engine.executor().dispatch_policy().label(),
    );
    if let (Some(spec), Some(trace)) = (&cfg.run.trace, engine.trace()) {
        eprintln!(
            "scenario: {} availability trace | horizon {:.1} τ | {:.0}% online at t = 0",
            spec.label(),
            trace.horizon() / engine.fleet.deadline,
            100.0 * trace.online_fraction(0.0),
        );
    }
    if let Some(ov) = &cfg.run.overlap {
        eprintln!(
            "async overlap: quorum {:.0}% | max staleness {} rounds | alpha {:.2}{}",
            100.0 * ov.quorum,
            ov.max_staleness,
            ov.alpha,
            if cfg.run.adaptive_quorum { " | adaptive" } else { "" },
        );
    }
    if let Some(spec) = &cfg.run.agg_tree {
        eprintln!(
            "aggregation: {}{}",
            spec.describe(),
            cfg.run
                .clip_norm
                .map(|c| format!(" | clip norm {c} at the edge tier"))
                .unwrap_or_default(),
        );
    } else if cfg.run.aggregator != fedcore::agg::AggPolicy::Mean || cfg.run.clip_norm.is_some() {
        eprintln!(
            "aggregation: {:?}{}",
            cfg.run.aggregator,
            cfg.run
                .clip_norm
                .map(|c| format!(" | clip norm {c}"))
                .unwrap_or_default(),
        );
    }
    match &cfg.run.select {
        fedcore::scenario::SelectPolicy::Baseline => {}
        fedcore::scenario::SelectPolicy::Flanp(fc) => eprintln!(
            "selection: flanp | start prefix {} | widen ×{:.2} below {:.3} improvement",
            fc.start, fc.factor, fc.threshold,
        ),
        fedcore::scenario::SelectPolicy::Forecast { bias } => {
            eprintln!("selection: forecast | uptime bias {bias:.2}")
        }
    }
    if cfg.run.distill_weight > 0.0 {
        eprintln!(
            "distillation: past-staleness updates fold at weight {:.2} × decay",
            cfg.run.distill_weight,
        );
    }
    if let Some(spec) = &cfg.run.corruption {
        eprintln!(
            "corruption: {} | {:.0}% of clients | seed {}",
            spec.label(),
            100.0 * spec.fraction,
            spec.seed,
        );
    }
    let result = if !a.get("load-ckpt").is_empty() {
        let ck = fedcore::fl::Checkpoint::load(a.get("load-ckpt"))?;
        if ck.model != ds.model {
            return Err(anyhow!(
                "checkpoint is for model '{}', benchmark needs '{}'",
                ck.model,
                ds.model
            ));
        }
        eprintln!("resuming from checkpoint (round {})", ck.round);
        engine.run_from(ck.params)?
    } else {
        engine.run()?
    };
    println!(
        "{} on {}: best acc {:.2}% | final loss {:.4} | mean t/τ {:.2}",
        result.strategy,
        cfg.benchmark.label(),
        100.0 * result.best_accuracy(),
        result.final_train_loss(),
        result.mean_normalized_round_time()
    );
    if cfg.run.overlap.is_some() {
        let (folded, discarded) = result.stale_totals();
        println!(
            "overlap: tail t/τ {:.2} (server advances at quorum) | stale folded {folded}, discarded {discarded}",
            result.mean_normalized_tail_time(),
        );
    }
    let (rejected, clipped) = result.agg_totals();
    if rejected + clipped > 0 {
        println!("aggregation: rejected {rejected} contribution-slots, clipped {clipped} updates");
    }
    let (steals, idle) = result.dispatch_totals();
    if steals > 0 {
        println!("dispatch: {steals} stolen jobs | {idle:.2}s simulated worker idle");
    }
    let out = a.get("out");
    if !out.is_empty() {
        result.write_csv(out)?;
        eprintln!("wrote {out}");
    }
    if !a.get("save-ckpt").is_empty() {
        let ck = fedcore::fl::Checkpoint::new(
            ds.model.clone(),
            cfg.run.rounds as u64,
            result.final_params.clone(),
        );
        let t0 = std::time::Instant::now();
        ck.save(a.get("save-ckpt"))?;
        let elapsed_ns = t0.elapsed().as_nanos() as u64;
        // Post-run bookkeeping span: appended outside the engine's trace
        // segment (round index one past the last), exempt from nesting.
        if let Some(path) = cfg.run.obs.path() {
            let sink = fedcore::obs::Jsonl::append(path)?;
            sink.record(&fedcore::obs::Record::span(
                fedcore::obs::Phase::Checkpoint,
                cfg.run.rounds,
                (0, elapsed_ns),
                (0.0, 0.0),
            ));
        }
        eprintln!("saved checkpoint to {}", a.get("save-ckpt"));
    }
    if let Some(path) = cfg.run.obs.path() {
        eprintln!("wrote trace {path} (render: fedcore report --obs-trace {path})");
    }
    Ok(())
}

fn cmd_report(a: &Args) -> Result<()> {
    let path = a.get("obs-trace");
    if path.is_empty() {
        return Err(anyhow!("report needs --obs-trace <trace.jsonl>"));
    }
    let trace = fedcore::obs::report::load(path)?;
    let records = trace.check()?;
    if a.has("check") {
        println!("{path}: OK ({records} records, schema v{})", fedcore::obs::SCHEMA_VERSION);
        if !a.has("health") {
            return Ok(());
        }
    }
    if a.has("health") {
        // Forensics view: leaderboard + critical path + anomaly flags
        // (composable with --check: validate, then render the table).
        print!("{}", trace.health_report());
        return Ok(());
    }
    print!("{}", trace.phase_table());
    println!();
    print!("{}", trace.summary());
    let out = a.get("out");
    if !out.is_empty() {
        std::fs::write(out, trace.timeline_svg(&format!("fedcore timeline — {path}")))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_sweep(a: &Args) -> Result<()> {
    let base = experiment_from_args(a)?;
    let rt = load_runtime(a)?;
    let ds = std::sync::Arc::new(data::generate(
        base.benchmark,
        base.scale,
        &rt.manifest().vocab,
        base.data_seed,
    ));
    // Cross-run pool reuse: one sharded pool (and its compiled per-worker
    // runtimes) serves every engine of the sweep. Results are
    // bit-identical to per-engine pools (exec determinism contract).
    let shared = fedcore::exec::sweep_pool(base.run.workers, rt.factory(), base.run.dispatch);
    if let Some(pool) = &shared {
        eprintln!(
            "sweep: sharing one {}-worker pool across all strategies ({} dispatch)",
            pool.workers(),
            pool.policy().label(),
        );
    }
    let mut results = Vec::new();
    for strategy in all_strategies(base.prox_mu) {
        let cfg = base.clone().with_strategy(strategy);
        eprintln!("--- {} ---", strategy.label());
        let result = match &shared {
            Some(pool) => Engine::with_executor(&rt, &ds, cfg.run.clone(), pool)?.run()?,
            None => Engine::new(&rt, &ds, cfg.run.clone())?.run()?,
        };
        results.push(result);
    }
    println!(
        "\nTable-2 style summary — {} at {}% stragglers:",
        base.benchmark.label(),
        base.run.straggler_pct
    );
    println!("{:<12} {:>10} {:>12}", "strategy", "acc (%)", "mean t/τ");
    for row in table2_rows(&results) {
        let mark = if row.exceeded_deadline { "  (exceeds τ!)" } else { "" };
        println!(
            "{:<12} {:>10.2} {:>12.2}{mark}",
            row.strategy, row.accuracy_pct, row.mean_norm_time
        );
    }
    let out = a.get("out");
    if !out.is_empty() {
        for r in &results {
            let path = format!("{out}/{}_{}.csv", r.benchmark, r.strategy.replace('-', ""));
            r.write_csv(&path)?;
        }
        eprintln!("wrote per-strategy CSVs under {out}/");
    }
    Ok(())
}

fn cmd_data(a: &Args) -> Result<()> {
    let bench = Benchmark::parse(a.get("bench"))
        .ok_or_else(|| anyhow!("unknown benchmark '{}'", a.get("bench")))?;
    let rt = load_runtime(a)?;
    let ds = data::generate(bench, a.get_f64("scale"), &rt.manifest().vocab, a.get_u64("seed"));
    let stats = data::partition::size_stats(&ds.sizes());
    println!("benchmark {}", bench.label());
    println!("  clients          {}", stats.clients);
    println!("  samples          {}", stats.total);
    println!("  samples/client   mean {:.1}  std {:.1}  min {}  max {}",
        stats.mean, stats.std, stats.min, stats.max);
    println!("  test samples     {}", ds.test.len());
    for (edge, count) in data::partition::size_histogram(&ds.sizes(), 12) {
        println!("  [{edge:>6}+) {}", "▇".repeat(1 + count * 40 / stats.clients.max(1)));
    }
    Ok(())
}

fn cmd_info(a: &Args) -> Result<()> {
    let rt = load_runtime(a)?;
    let m = rt.manifest();
    println!("artifacts: train_batch={} feat_batch={} feature_dim={}",
        m.train_batch, m.feat_batch, m.feature_dim);
    println!("pairwise Pallas tile: {}×{} (dim {})", m.pairwise_tile, m.pairwise_tile, m.pairwise_dim);
    println!("vocab: {} chars", m.vocab.len());
    for (name, info) in &m.models {
        println!(
            "model {name:<8} params={:<8} classes={:<3} x{:?} ({:?}) seq={}",
            info.param_size, info.num_classes, info.x_shape, info.x_dtype, info.seq_len
        );
    }
    Ok(())
}

fn main() {
    let args = cli().parse();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("run");
    let result = match cmd {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "data" => cmd_data(&args),
        "info" => cmd_info(&args),
        "report" => cmd_report(&args),
        other => Err(anyhow!("unknown command '{other}' (run|sweep|data|info|report)")),
    };
    if let Err(e) = result {
        eprintln!("fedcore: {e:#}");
        std::process::exit(1);
    }
}
