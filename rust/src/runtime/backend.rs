//! PJRT backend shim: the one seam between this crate and the `xla`
//! bindings crate.
//!
//! * `--features pjrt` re-exports the real `xla` types (requires the `xla`
//!   dependency to be enabled in `Cargo.toml` — it is not on crates.io, so
//!   it is commented out for offline builds).
//! * The default build substitutes an API-compatible stub whose
//!   `PjRtClient::cpu()` fails with a descriptive error. Everything
//!   compiles and the full non-runtime test surface runs; runtime-backed
//!   tests and benches detect the missing artifacts/backend and skip,
//!   exactly as they do when `make artifacts` has not been run.
//!
//! The stub mirrors only the slice of the `xla` API that
//! [`super::Runtime`] actually touches; keep the two in lockstep when the
//! runtime grows a new call.

#[cfg(feature = "pjrt")]
pub use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

#[cfg(not(feature = "pjrt"))]
pub use self::stub::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use anyhow::{anyhow, Result};

    const NO_BACKEND: &str = "fedcore was built without the `pjrt` feature; \
         enable the `xla` dependency in rust/Cargo.toml and rebuild with \
         `--features pjrt` to execute AOT artifacts";

    /// Stub of `xla::PjRtClient` — construction always fails, so no other
    /// stub method is reachable through [`crate::runtime::Runtime`].
    pub struct PjRtClient;

    impl PjRtClient {
        /// Always fails: the stub has no backend to construct.
        pub fn cpu() -> Result<PjRtClient> {
            Err(anyhow!(NO_BACKEND))
        }

        /// Unreachable in practice (no client can exist); errs anyway.
        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
            Err(anyhow!(NO_BACKEND))
        }
    }

    /// Stub of `xla::HloModuleProto`.
    pub struct HloModuleProto;

    impl HloModuleProto {
        /// Always fails: the stub cannot parse HLO text.
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
            Err(anyhow!(NO_BACKEND))
        }
    }

    /// Stub of `xla::XlaComputation`.
    pub struct XlaComputation;

    impl XlaComputation {
        /// Infallible no-op (mirrors the `xla` signature).
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    /// Stub of `xla::PjRtLoadedExecutable`.
    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        /// Always fails: nothing was ever compiled.
        pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
            Err(anyhow!(NO_BACKEND))
        }
    }

    /// Stub of `xla::PjRtBuffer`.
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        /// Always fails: no device memory to fetch.
        pub fn to_literal_sync(&self) -> Result<Literal> {
            Err(anyhow!(NO_BACKEND))
        }
    }

    /// Stub of `xla::Literal`.
    pub struct Literal;

    impl Literal {
        /// Infallible placeholder (mirrors the `xla` signature).
        pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
            Literal
        }

        /// Infallible placeholder (mirrors the `xla` signature).
        pub fn scalar<T: Copy>(_v: T) -> Literal {
            Literal
        }

        /// Always fails on the stub.
        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
            Err(anyhow!(NO_BACKEND))
        }

        /// Always fails on the stub.
        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            Err(anyhow!(NO_BACKEND))
        }

        /// Always fails on the stub.
        pub fn get_first_element<T>(&self) -> Result<T> {
            Err(anyhow!(NO_BACKEND))
        }

        /// Always fails on the stub.
        pub fn to_tuple(self) -> Result<Vec<Literal>> {
            Err(anyhow!(NO_BACKEND))
        }
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_with_guidance() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        let msg = format!("{err}");
        assert!(msg.contains("pjrt"), "unhelpful stub error: {msg}");
    }

    #[test]
    fn stub_literal_paths_error_not_panic() {
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
        assert!(Literal::scalar(0i32).to_vec::<f32>().is_err());
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }
}
