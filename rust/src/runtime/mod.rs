//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! hot path. This is the only module that touches the `xla` crate.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled once on first use and cached for the process
//! lifetime; python is never invoked.
//!
//! Thread model: `PjRtClient` is `Rc`-backed (not `Send`), so a `Runtime`
//! is pinned to the thread that created it. Engines that want parallel
//! client simulation build one `Runtime` per worker thread from the same
//! artifacts directory via [`RuntimeFactory`] (compilation of these small
//! modules is cheap and the CPU PJRT client shares nothing mutable across
//! instances). The [`crate::exec::Sharded`] executor is exactly that: a
//! pool of worker threads, each owning the `Runtime` it built.

pub mod backend;
pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use self::backend::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub use manifest::{Manifest, ModelInfo, XDtype};

/// A recipe for building [`Runtime`]s on other threads. `Runtime` itself is
/// pinned to its creating thread (the PJRT client is `Rc`-backed), but the
/// factory is just the artifacts path — `Send + Sync + Clone` — so worker
/// threads can each materialize their own pinned runtime from shared
/// artifacts.
#[derive(Clone, Debug)]
pub struct RuntimeFactory {
    dir: PathBuf,
}

impl RuntimeFactory {
    /// A factory for the given artifacts directory (no I/O yet).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> RuntimeFactory {
        RuntimeFactory { dir: artifacts_dir.as_ref().to_path_buf() }
    }

    /// The artifacts directory this factory loads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Build a fresh runtime on the calling thread.
    pub fn build(&self) -> Result<Runtime> {
        Runtime::load(&self.dir)
    }
}

/// Input batch for a model call: x is either f32 (dense features / images)
/// or i32 (token ids); y is always i32 (labels / next-token ids).
#[derive(Clone, Debug)]
pub enum XBatch {
    /// Dense f32 features.
    F32(Vec<f32>),
    /// i32 token ids.
    I32(Vec<i32>),
}

impl XBatch {
    /// Total number of stored elements (not samples).
    pub fn len(&self) -> usize {
        match self {
            XBatch::F32(v) => v.len(),
            XBatch::I32(v) => v.len(),
        }
    }

    /// True when the batch holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Result of one training step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// Updated parameter vector.
    pub params: Vec<f32>,
    /// Mean weighted batch loss.
    pub loss: f32,
}

/// Result of a feature-extraction call on one batch.
#[derive(Clone, Debug)]
pub struct FeatOutput {
    /// Row-major `[feat_batch, feature_dim]`.
    pub features: Vec<f32>,
    /// Per-sample loss, `[feat_batch]`.
    pub losses: Vec<f32>,
}

/// Accumulated evaluation numbers for a batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalOutput {
    /// Σ per-sample loss over counted samples.
    pub loss_sum: f64,
    /// Correct predictions (weighted by mask).
    pub correct: f64,
    /// Counted samples (mask sum).
    pub count: f64,
}

impl EvalOutput {
    /// Accumulate another batch's numbers (order-independent totals).
    pub fn merge(&mut self, other: EvalOutput) {
        self.loss_sum += other.loss_sum;
        self.correct += other.correct;
        self.count += other.count;
    }

    /// Mean per-sample loss (0.0 when nothing was counted).
    pub fn mean_loss(&self) -> f64 {
        if self.count > 0.0 {
            self.loss_sum / self.count
        } else {
            0.0
        }
    }

    /// Fraction of counted samples predicted correctly.
    pub fn accuracy(&self) -> f64 {
        if self.count > 0.0 {
            self.correct / self.count
        } else {
            0.0
        }
    }
}

/// Execution statistics (perf instrumentation for EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    /// Total artifact executions.
    pub executions: u64,
    /// Artifacts compiled (≤ distinct artifact files).
    pub compile_count: u64,
    /// Wall nanoseconds spent inside PJRT execution.
    pub exec_nanos: u64,
}

/// Per-artifact execution breakdown: where PJRT time actually goes.
#[derive(Clone, Debug, Default)]
pub struct ArtifactStats {
    /// artifact file → (executions, total nanos).
    pub per_artifact: HashMap<String, (u64, u64)>,
}

impl ArtifactStats {
    /// Render a table sorted by total time, descending.
    pub fn report(&self) -> String {
        let mut rows: Vec<(&String, &(u64, u64))> = self.per_artifact.iter().collect();
        rows.sort_by_key(|(_, (_, ns))| std::cmp::Reverse(*ns));
        let total: u64 = rows.iter().map(|(_, (_, ns))| *ns).sum();
        let mut out = format!(
            "{:<28} {:>8} {:>10} {:>10} {:>6}\n",
            "artifact", "execs", "total ms", "mean µs", "%"
        );
        for (file, (n, ns)) in rows {
            out.push_str(&format!(
                "{:<28} {:>8} {:>10.1} {:>10.1} {:>5.1}%\n",
                file,
                n,
                *ns as f64 / 1e6,
                *ns as f64 / (*n).max(1) as f64 / 1e3,
                100.0 * *ns as f64 / total.max(1) as f64
            ));
        }
        out
    }
}

/// The PJRT-backed runtime. One per thread (see module docs).
pub struct Runtime {
    client: PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    execs: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    stats: RefCell<RuntimeStats>,
    artifact_stats: RefCell<ArtifactStats>,
}

impl Runtime {
    /// Load the manifest and create a CPU PJRT client. Executables are
    /// compiled lazily on first call; use [`Runtime::warmup`] to front-load.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            execs: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
            artifact_stats: RefCell::new(ArtifactStats::default()),
        })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The artifacts directory this runtime was loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// A factory that rebuilds this runtime's configuration on any thread.
    pub fn factory(&self) -> RuntimeFactory {
        RuntimeFactory::new(&self.dir)
    }

    /// Aggregate execution counters so far.
    pub fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    /// Per-artifact time breakdown (the §Perf profiling instrument).
    pub fn artifact_stats(&self) -> ArtifactStats {
        self.artifact_stats.borrow().clone()
    }

    /// Compile every artifact up front (useful before timing runs).
    pub fn warmup(&self) -> Result<()> {
        let files: Vec<String> = self
            .manifest
            .models
            .values()
            .flat_map(|m| {
                [m.train_file.clone(), m.feat_file.clone(), m.eval_file.clone()]
            })
            .chain([self.manifest.pairwise_file.clone()])
            .collect();
        for f in files {
            self.ensure_compiled(&f)?;
        }
        Ok(())
    }

    fn ensure_compiled(&self, file: &str) -> Result<()> {
        if self.execs.borrow().contains_key(file) {
            return Ok(());
        }
        let path = self.dir.join(file);
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", file))?;
        self.execs.borrow_mut().insert(file.to_string(), exe);
        self.stats.borrow_mut().compile_count += 1;
        Ok(())
    }

    /// Execute an artifact; returns the decomposed output tuple.
    fn exec(&self, file: &str, args: &[Literal]) -> Result<Vec<Literal>> {
        self.ensure_compiled(file)?;
        let t0 = std::time::Instant::now();
        let execs = self.execs.borrow();
        let exe = execs.get(file).unwrap();
        let bufs = exe
            .execute::<Literal>(args)
            .with_context(|| format!("executing {}", file))?;
        let out = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", file))?;
        let nanos = t0.elapsed().as_nanos() as u64;
        let mut stats = self.stats.borrow_mut();
        stats.executions += 1;
        stats.exec_nanos += nanos;
        drop(stats);
        let mut astats = self.artifact_stats.borrow_mut();
        let entry = astats.per_artifact.entry(file.to_string()).or_insert((0, 0));
        entry.0 += 1;
        entry.1 += nanos;
        drop(astats);
        // aot.py lowers with return_tuple=True: the single output is a tuple.
        Ok(out.to_tuple()?)
    }

    fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        let expected: i64 = dims.iter().product();
        if expected as usize != data.len() {
            bail!("literal shape {:?} wants {} elems, got {}", dims, expected, data.len());
        }
        Ok(Literal::vec1(data).reshape(dims)?)
    }

    fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        let expected: i64 = dims.iter().product();
        if expected as usize != data.len() {
            bail!("literal shape {:?} wants {} elems, got {}", dims, expected, data.len());
        }
        Ok(Literal::vec1(data).reshape(dims)?)
    }

    fn x_literal(&self, model: &ModelInfo, x: &XBatch, batch: usize) -> Result<Literal> {
        let mut dims: Vec<i64> = vec![batch as i64];
        dims.extend(model.x_shape.iter().map(|&d| d as i64));
        match (model.x_dtype, x) {
            (XDtype::F32, XBatch::F32(v)) => Self::literal_f32(v, &dims),
            (XDtype::I32, XBatch::I32(v)) => Self::literal_i32(v, &dims),
            _ => bail!("model {} x dtype mismatch", model.name),
        }
    }

    fn y_literal(&self, model: &ModelInfo, y: &[i32], batch: usize) -> Result<Literal> {
        let dims: Vec<i64> = if model.seq_len > 0 {
            vec![batch as i64, model.seq_len as i64]
        } else {
            vec![batch as i64]
        };
        Self::literal_i32(y, &dims)
    }

    /// One weighted SGD step (the `{model}_train` artifact).
    ///
    /// `weights` carries coreset δ* weights / padding zeros; `mu > 0`
    /// activates the FedProx proximal term against `gparams`.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        model: &ModelInfo,
        params: &[f32],
        gparams: &[f32],
        x: &XBatch,
        y: &[i32],
        weights: &[f32],
        lr: f32,
        mu: f32,
    ) -> Result<StepOutput> {
        let b = self.manifest.train_batch;
        if weights.len() != b {
            bail!("weights len {} != train batch {}", weights.len(), b);
        }
        let args = [
            Self::literal_f32(params, &[model.param_size as i64])?,
            Self::literal_f32(gparams, &[model.param_size as i64])?,
            self.x_literal(model, x, b)?,
            self.y_literal(model, y, b)?,
            Self::literal_f32(weights, &[b as i64])?,
            Literal::scalar(lr),
            Literal::scalar(mu),
        ];
        let out = self.exec(&model.train_file, &args)?;
        if out.len() != 2 {
            bail!("train artifact returned {} outputs, want 2", out.len());
        }
        Ok(StepOutput {
            params: out[0].to_vec::<f32>()?,
            loss: out[1].get_first_element::<f32>()?,
        })
    }

    /// Per-sample gradient features + losses (the `{model}_feat` artifact).
    pub fn grad_features(
        &self,
        model: &ModelInfo,
        params: &[f32],
        x: &XBatch,
        y: &[i32],
    ) -> Result<FeatOutput> {
        let b = self.manifest.feat_batch;
        let args = [
            Self::literal_f32(params, &[model.param_size as i64])?,
            self.x_literal(model, x, b)?,
            self.y_literal(model, y, b)?,
        ];
        let out = self.exec(&model.feat_file, &args)?;
        if out.len() != 2 {
            bail!("feat artifact returned {} outputs, want 2", out.len());
        }
        Ok(FeatOutput {
            features: out[0].to_vec::<f32>()?,
            losses: out[1].to_vec::<f32>()?,
        })
    }

    /// Masked evaluation (the `{model}_eval` artifact).
    pub fn evaluate(
        &self,
        model: &ModelInfo,
        params: &[f32],
        x: &XBatch,
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOutput> {
        let b = self.manifest.feat_batch;
        let args = [
            Self::literal_f32(params, &[model.param_size as i64])?,
            self.x_literal(model, x, b)?,
            self.y_literal(model, y, b)?,
            Self::literal_f32(mask, &[b as i64])?,
        ];
        let out = self.exec(&model.eval_file, &args)?;
        if out.len() != 3 {
            bail!("eval artifact returned {} outputs, want 3", out.len());
        }
        Ok(EvalOutput {
            loss_sum: out[0].get_first_element::<f32>()? as f64,
            correct: out[1].get_first_element::<f32>()? as f64,
            count: out[2].get_first_element::<f32>()? as f64,
        })
    }

    /// One T×T block of the pairwise gradient-distance matrix (the L1
    /// Pallas artifact). `a` and `b` are row-major [tile, dim].
    pub fn pairwise_tile(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let t = self.manifest.pairwise_tile as i64;
        let c = self.manifest.pairwise_dim as i64;
        let args = [
            Self::literal_f32(a, &[t, c])?,
            Self::literal_f32(b, &[t, c])?,
        ];
        let out = self.exec(&self.manifest.pairwise_file, &args)?;
        if out.len() != 1 {
            bail!("pairwise artifact returned {} outputs, want 1", out.len());
        }
        Ok(out[0].to_vec::<f32>()?)
    }
}
