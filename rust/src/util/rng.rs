//! Deterministic, splittable PRNG for the whole simulation.
//!
//! xoshiro256** (Blackman & Vigna) with a splitmix64 seeder. Every random
//! decision in the system — dataset generation, client speeds, client
//! selection, minibatch shuffles, k-medoids tie-breaking — flows from one
//! of these generators, so entire experiments replay bit-for-bit from a
//! single seed. `split()` derives an independent stream, which is how the
//! coordinator hands per-client / per-round randomness out without any
//! cross-coupling between subsystems.

/// xoshiro256** generator.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 so even seeds 0,1,2,… give well-mixed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream keyed by `salt` without perturbing self.
    pub fn split(&self, salt: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply keeps bias below 2^-64 — negligible for sim use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// statelessness; the sim is not normal-throughput-bound).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// N(mean, sd^2).
    pub fn normal_scaled(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Power-law (Pareto/Lomax-style) sample: returns x >= xmin with density
    /// ∝ x^-(alpha+1). Used for per-client dataset sizes (paper Fig. 2).
    pub fn power_law(&mut self, xmin: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        xmin * u.powf(-1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` indices in [0, n) WITH replacement, weighted by `weights`
    /// (need not be normalized). This is the paper's Assumption A.6 client
    /// sampling: probability ∝ p_i, with replacement.
    pub fn weighted_with_replacement(&mut self, weights: &[f64], k: usize) -> Vec<usize> {
        // Build the cumulative distribution once; binary-search per draw.
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            acc += w.max(0.0);
            cdf.push(acc);
        }
        assert!(acc > 0.0, "all-zero weights");
        (0..k)
            .map(|_| {
                let x = self.f64() * acc;
                match cdf.binary_search_by(|v| v.partial_cmp(&x).unwrap()) {
                    Ok(i) => (i + 1).min(weights.len() - 1),
                    Err(i) => i.min(weights.len() - 1),
                }
            })
            .collect()
    }

    /// Sample `k` distinct indices in [0, n) uniformly (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let root = Rng::new(7);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0);
    }

    #[test]
    fn uniform_mean_quarter_width() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn power_law_min_respected() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.power_law(10.0, 1.5) >= 10.0);
        }
    }

    #[test]
    fn weighted_sampling_matches_weights() {
        let mut r = Rng::new(17);
        let w = vec![1.0, 0.0, 3.0];
        let draws = r.weighted_with_replacement(&w, 40_000);
        let c0 = draws.iter().filter(|&&i| i == 0).count() as f64;
        let c1 = draws.iter().filter(|&&i| i == 1).count();
        let c2 = draws.iter().filter(|&&i| i == 2).count() as f64;
        assert_eq!(c1, 0, "zero-weight index drawn");
        let ratio = c2 / c0;
        assert!((ratio - 3.0).abs() < 0.3, "{ratio}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(19);
        let picks = r.choose_k(100, 30);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut back = xs.clone();
        back.sort_unstable();
        assert_eq!(back, (0..50).collect::<Vec<_>>());
    }
}
