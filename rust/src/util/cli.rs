//! Tiny CLI argument parser (offline build: no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! plus auto-generated usage text. Each binary declares its options up
//! front; unknown flags are hard errors so typos don't silently fall
//! through to defaults.

use std::collections::BTreeMap;

/// One declared option: `--name <v>` (valued) or `--name` (flag).
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Option name without the leading `--`.
    pub name: &'static str,
    /// Help text for the usage listing.
    pub help: &'static str,
    /// Default value (None ⇒ required); unused for flags.
    pub default: Option<&'static str>,
    /// True for boolean `--flag` options.
    pub is_flag: bool,
}

/// Parsed arguments: option values, set flags, and positionals.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Non-option arguments, in order (e.g. the subcommand).
    pub positional: Vec<String>,
}

/// A declared command-line interface (builder-style).
pub struct Cli {
    /// Binary name shown in usage.
    pub program: &'static str,
    /// One-line description shown in usage.
    pub about: &'static str,
    /// Declared options, in declaration order.
    pub opts: Vec<OptSpec>,
}

impl Cli {
    /// Start declaring a CLI.
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli { program, about, opts: Vec::new() }
    }

    /// Declare an optional `--name <v>` with a default.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    /// Declare a required `--name <v>`.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Render the usage/help text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let default = match o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("{head:<28}{}{default}\n", o.help));
        }
        s
    }

    /// Parse the given argv tail (without the program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    args.flags.push(name);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} needs a value"))?,
                    };
                    args.values.insert(name, v);
                }
            } else {
                args.positional.push(a);
            }
        }
        // defaults + required check
        for o in &self.opts {
            if o.is_flag {
                continue;
            }
            if !args.values.contains_key(o.name) {
                match o.default {
                    Some(d) => {
                        args.values.insert(o.name.to_string(), d.to_string());
                    }
                    None => return Err(format!("missing required --{}\n\n{}", o.name, self.usage())),
                }
            }
        }
        Ok(args)
    }

    /// Parse std::env::args(), exiting with usage on error.
    pub fn parse(&self) -> Args {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    /// The value of option `name` (its default when not given). Panics on
    /// undeclared names — that is a programming error, not user input.
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    /// [`Args::get`] parsed as usize (exits via panic on bad input).
    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer, got '{}'", self.get(name)))
    }

    /// [`Args::get`] parsed as u64.
    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer, got '{}'", self.get(name)))
    }

    /// [`Args::get`] parsed as f64.
    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number, got '{}'", self.get(name)))
    }

    /// Was flag `name` passed?
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("rounds", "10", "rounds")
            .req("bench", "benchmark name")
            .flag("verbose", "chatty")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let a = cli()
            .parse_from(argv(&["--bench", "mnist", "--rounds=5", "--verbose", "extra"]))
            .unwrap();
        assert_eq!(a.get("bench"), "mnist");
        assert_eq!(a.get_usize("rounds"), 5);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_applied() {
        let a = cli().parse_from(argv(&["--bench", "x"])).unwrap();
        assert_eq!(a.get_usize("rounds"), 10);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse_from(argv(&[])).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(cli().parse_from(argv(&["--bench", "x", "--nope"])).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(cli().parse_from(argv(&["--bench", "x", "--verbose=1"])).is_err());
    }
}
