//! Micro-benchmark harness (offline build: no criterion).
//!
//! Warmup + timed iterations with mean/p50/p99 reporting and a black-box
//! sink to stop the optimizer from deleting the measured work. The paper-
//! table benches use their own experiment drivers; this harness covers the
//! criterion-style perf benches (k-medoids, runtime exec, distance tiling).

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats;

/// Timing summary of one benched closure.
pub struct BenchResult {
    /// Bench label.
    pub name: String,
    /// Timed iterations actually run.
    pub iters: usize,
    /// Mean nanoseconds per iteration.
    pub mean_ns: f64,
    /// Median nanoseconds.
    pub p50_ns: f64,
    /// 99th-percentile nanoseconds.
    pub p99_ns: f64,
    /// Fastest iteration.
    pub min_ns: f64,
}

impl BenchResult {
    /// One aligned report line (name, iters, mean/p50/p99/min).
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        )
    }
}

/// Human-readable nanoseconds (ns/µs/ms/s with sensible precision).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: a few warmup calls, then timed iterations until
/// either `max_iters` or `budget` wall time is spent, whichever first.
pub fn bench<T>(
    name: &str,
    max_iters: usize,
    budget: Duration,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    for _ in 0..3.min(max_iters) {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(max_iters.min(4096));
    let start = Instant::now();
    for _ in 0..max_iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if start.elapsed() > budget {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: stats::mean(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p99_ns: stats::percentile(&samples, 99.0),
        min_ns: stats::min(&samples),
    }
}

/// Standard entry point used by the perf benches.
pub fn run_group(title: &str, benches: Vec<BenchResult>) {
    println!("\n== {title} ==");
    for b in &benches {
        println!("{}", b.report());
    }
}

/// Provenance stamp for every `BENCH_*.json` output and trace-file
/// header ([`crate::obs::Jsonl`]): `{seed, rounds, scale, git_sha,
/// rustc}` — so bench trajectories and traces stay comparable across
/// PRs (same seed/rounds/scale ⇒ same workload; the sha names the code
/// and the compiler names the codegen that produced the numbers). The
/// sha comes from `GITHUB_SHA` in CI, `git rev-parse HEAD` locally,
/// `"unknown"` when neither is available; the compiler from `rustc -V`
/// with the same fallback.
pub fn provenance(seed: u64, rounds: usize, scale: f64) -> crate::util::json::Json {
    use crate::util::json::Json;
    let sha = std::env::var("GITHUB_SHA")
        .ok()
        .filter(|s| !s.trim().is_empty())
        .or_else(git_head_sha)
        .unwrap_or_else(|| "unknown".into());
    Json::Obj(
        [
            ("seed".to_string(), Json::Num(seed as f64)),
            ("rounds".to_string(), Json::Num(rounds as f64)),
            ("scale".to_string(), Json::Num(scale)),
            ("git_sha".to_string(), Json::Str(sha)),
            ("rustc".to_string(), Json::Str(rustc_version().unwrap_or_else(|| "unknown".into()))),
        ]
        .into_iter()
        .collect(),
    )
}

fn git_head_sha() -> Option<String> {
    let out = std::process::Command::new("git").args(["rev-parse", "HEAD"]).output().ok()?;
    if !out.status.success() {
        return None;
    }
    let sha = String::from_utf8_lossy(&out.stdout).trim().to_string();
    (!sha.is_empty()).then_some(sha)
}

fn rustc_version() -> Option<String> {
    let out = std::process::Command::new("rustc").arg("-V").output().ok()?;
    if !out.status.success() {
        return None;
    }
    let v = String::from_utf8_lossy(&out.stdout).trim().to_string();
    (!v.is_empty()).then_some(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 50, Duration::from_millis(200), || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn provenance_carries_the_workload_identity() {
        let p = provenance(7, 14, 0.25);
        assert_eq!(p.get("seed").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(p.get("rounds").and_then(|v| v.as_f64()), Some(14.0));
        assert_eq!(p.get("scale").and_then(|v| v.as_f64()), Some(0.25));
        let sha = p.get("git_sha").and_then(|v| v.as_str()).expect("sha present");
        assert!(!sha.is_empty());
        let rustc = p.get("rustc").and_then(|v| v.as_str()).expect("rustc present");
        assert!(!rustc.is_empty());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
