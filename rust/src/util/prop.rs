//! Seeded property-testing runner (offline build: no proptest).
//!
//! `check` runs a property over `cases` randomly generated inputs; on
//! failure it reports the seed and case index so the exact input replays
//! deterministically. There is no shrinking — generators are written to
//! produce small cases with reasonable probability instead, which in
//! practice localizes failures well for the invariant suites in
//! rust/tests/proptests.rs.

use super::rng::Rng;

/// Case-count override, proptest-compatible: `PROPTEST_CASES=5000 cargo
/// test proptest_` scales every suite up for hardening runs.
pub fn env_cases(default: usize) -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(default)
}

/// Seed override, proptest-compatible: `PROPTEST_SEED=…` replays a failing
/// run exactly (the failure message reports the seed to use).
pub fn env_seed(default: u64) -> u64 {
    std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(default)
}

/// Run `prop(rng, case_index)` for `cases` cases. The property panics (via
/// assert!) on violation; this wrapper decorates the panic with replay info.
pub fn check(name: &str, seed: u64, cases: usize, mut prop: impl FnMut(&mut Rng, usize)) {
    for case in 0..cases {
        let mut rng = Rng::new(seed).split(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (replay: seed={seed}, case={case}):\n{msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::sync::atomic::AtomicUsize::new(0);
        check("count", 1, 25, |_rng, _case| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 25);
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed at case")]
    fn failing_property_reports_case() {
        check("boom", 2, 10, |rng, _case| {
            // fails eventually: u64 below 4 is frequent
            assert!(rng.below(4) != 0, "hit zero");
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = Vec::new();
        check("collect", 3, 5, |rng, _| {
            first.push(rng.next_u64());
        });
        let mut second = Vec::new();
        check("collect", 3, 5, |rng, _| {
            second.push(rng.next_u64());
        });
        assert_eq!(first, second);
    }
}
