//! Minimal TOML-subset parser for the benchmark config files.
//!
//! Supports exactly what `configs/*.toml` use: `[section]` headers,
//! `key = value` pairs with string / integer / float / bool / flat-array
//! values, `#` comments, and blank lines. Anything outside that subset is a
//! hard error with a line number — configs are hand-written, so strictness
//! beats permissiveness.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value (the subset the configs use).
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float (also produced by exponent notation).
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat array of values.
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// String contents, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer value, if this is an integer.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric value (floats and integers both qualify).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array of numbers, if this is an all-numeric array.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Array(a) => a.iter().map(|v| v.as_f64()).collect(),
            _ => None,
        }
    }
}

/// section name -> key -> value. Keys before any `[section]` land in "".
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    /// Section name → key → value (top-level keys land in `""`).
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

/// Parse error with a 1-based line number.
#[derive(Debug)]
pub struct TomlError {
    /// 1-based line of the error.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    /// Parse a document; anything outside the supported subset errors.
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(err("empty section name"));
                }
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.to_string(), value);
        }
        Ok(doc)
    }

    /// Look up `key` in `[section]` (`""` = before any section header).
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` inside a quoted string must not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        // The config subset needs no escapes beyond \" and \\.
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Array(items));
    }
    let clean = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# benchmark preset
name = "mnist"          # inline comment
[fl]
rounds = 100
clients_per_round = 10
lr = 0.03
deadline_aware = true
straggler_pcts = [10, 30]
[data]
alpha = 0.5
"#;

    #[test]
    fn parses_sample() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("mnist"));
        assert_eq!(doc.get("fl", "rounds").unwrap().as_i64(), Some(100));
        assert_eq!(doc.get("fl", "lr").unwrap().as_f64(), Some(0.03));
        assert_eq!(doc.get("fl", "deadline_aware").unwrap().as_bool(), Some(true));
        assert_eq!(
            doc.get("fl", "straggler_pcts").unwrap().as_f64_vec(),
            Some(vec![10.0, 30.0])
        );
        assert_eq!(doc.get("data", "alpha").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn int_vs_float_distinguished() {
        let doc = TomlDoc::parse("a = 3\nb = 3.0\nc = 1e2").unwrap();
        assert_eq!(doc.get("", "a").unwrap(), &TomlValue::Int(3));
        assert_eq!(doc.get("", "b").unwrap(), &TomlValue::Float(3.0));
        assert_eq!(doc.get("", "c").unwrap(), &TomlValue::Float(100.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = TomlDoc::parse("[unclosed").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn negative_and_underscore_numbers() {
        let doc = TomlDoc::parse("a = -5\nb = 1_000").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_i64(), Some(-5));
        assert_eq!(doc.get("", "b").unwrap().as_i64(), Some(1000));
    }
}
