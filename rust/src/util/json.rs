//! Minimal JSON parser — reads `artifacts/manifest.json`.
//!
//! The offline build has no serde_json, so this module implements the small
//! slice of JSON the manifest needs (objects, arrays, strings with escapes,
//! numbers, bools, null) with precise error positions. It is a strict
//! recursive-descent parser, not a permissive one: malformed manifests fail
//! loudly at startup rather than mis-loading a model.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (JSON has one numeric type).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with a byte position.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the error.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object lookup that errors with the key name — manifest fields are
    /// mandatory, so absence is a configuration bug worth a clear message.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("manifest missing key '{key}'"))
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array contents, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Fast path for the manifest's big float arrays (init_params).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs are not emitted by our writer;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize — used by the metrics writers to emit result JSON.
pub fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        // the manifest vocab embeds NUL as \u0000
        assert_eq!(Json::parse("\"\\u0000\"").unwrap(), Json::Str("\0".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn f32_vec_fast_path() {
        let v = Json::parse("[1.5, -2, 3e-1]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.5, -2.0, 0.3]);
    }

    #[test]
    fn roundtrip_write() {
        let src = r#"{"k":[1,2.5,"s\n"],"z":true}"#;
        let v = Json::parse(src).unwrap();
        let mut out = String::new();
        write_json(&v, &mut out);
        assert_eq!(Json::parse(&out).unwrap(), v);
    }
}
