//! Small statistics helpers shared by metrics, benches, and data generators.

/// Mean of a slice (0.0 for empty — callers treat empty as "no data").
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation on the sorted copy; q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = rank - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

/// Minimum (+∞ for empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum (−∞ for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Simple exponential moving average used to smooth loss curves for display.
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[0.0, 10.0, 10.0], 0.5);
        assert_eq!(out, vec![0.0, 5.0, 7.5]);
    }
}
