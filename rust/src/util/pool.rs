//! Scoped worker pool for parallel client simulation (offline: no tokio /
//! rayon). The FL round loop is compute-bound — each selected client's local
//! training is an independent chunk of PJRT executions — so OS threads with
//! a work queue are the right primitive, not an async runtime.
//!
//! `parallel_map` preserves input order, propagates panics, and falls back
//! to sequential execution for tiny inputs where thread spawn costs exceed
//! the win.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` using up to `threads` OS threads, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 || n == 1 {
        return items.into_iter().map(f).collect();
    }

    // Work-stealing by atomic cursor over a shared Vec<Option<T>>.
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("slot taken twice");
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker died before finishing"))
        .collect()
}

/// Number of worker threads to use by default. A `FEDCORE_THREADS`
/// environment override wins outright and is *not* capped — when the user
/// asks for more threads they get them; otherwise physical parallelism
/// capped at 8 (the sim saturates memory bandwidth well before 8 PJRT
/// streams).
pub fn default_threads() -> usize {
    threads_from(std::env::var("FEDCORE_THREADS").ok().as_deref())
}

/// Pure resolution logic behind [`default_threads`], split out so tests
/// need not mutate process-global environment state.
pub fn threads_from(override_var: Option<&str>) -> usize {
    if let Some(n) = override_var.and_then(|v| v.trim().parse::<usize>().ok()) {
        return n.max(1);
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map((0..100).collect(), 4, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn threads_env_override_uncapped() {
        // No override: bounded by the hard cap.
        assert!((1..=8).contains(&threads_from(None)));
        // Explicit override: honored verbatim, even above the cap.
        assert_eq!(threads_from(Some("24")), 24);
        assert_eq!(threads_from(Some(" 12 ")), 12);
        // Garbage and zero fall back safely.
        assert_eq!(threads_from(Some("0")), 1);
        assert!((1..=8).contains(&threads_from(Some("lots"))));
    }

    #[test]
    fn threads_env_edge_cases() {
        // FEDCORE_THREADS=0 never yields a zero-width pool.
        assert_eq!(threads_from(Some("0")), 1);
        assert_eq!(threads_from(Some(" 0 ")), 1);
        // Non-numeric / empty / fractional / signed values fall back to
        // the auto path (physical parallelism, capped at 8) rather than
        // panicking or producing 0.
        for junk in ["", "   ", "four", "2.5", "-3", "0x8", "8 threads"] {
            let n = threads_from(Some(junk));
            assert!((1..=8).contains(&n), "override '{junk}' resolved to {n}");
        }
        // A request far above any physical core count is honored
        // verbatim — the user asked for it (uncapped by design).
        let physical = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let huge = (physical * 64).to_string();
        assert_eq!(threads_from(Some(&huge)), physical * 64);
    }

    #[test]
    fn heavier_work_all_items_processed() {
        let out = parallel_map((0..1000).collect(), 8, |x: u64| {
            let mut acc = x;
            for _ in 0..100 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            acc
        });
        assert_eq!(out.len(), 1000);
    }
}
