//! Infrastructure substrates built in-tree for the offline environment:
//! RNG, JSON, TOML-subset config, CLI parsing, stats, micro-bench harness,
//! worker pool, and a property-testing runner. See DESIGN.md
//! "Offline-build note".

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod toml;
