//! Experiment metrics: per-round records, curves, histograms, and the
//! CSV/SVG writers the benches use to regenerate every paper table/figure.

pub mod svg;

use std::fmt::Write as _;
use std::path::Path;

use crate::util::stats;

/// One FL round's observable outcomes.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// Round index r (0-based).
    pub round: usize,
    /// Weighted mean of participating clients' final local training loss.
    pub train_loss: f64,
    /// Global test loss / accuracy after aggregation.
    pub test_loss: f64,
    /// Global test accuracy after aggregation (0..1).
    pub test_acc: f64,
    /// Simulated server-advance round length (seconds; the quorum time in
    /// overlapped runs, the straggler tail in synchronous ones —
    /// τ-normalized views live in SimClock).
    pub sim_time: f64,
    /// When this round's slowest participating client finished (seconds
    /// from the round start). Equals `sim_time` in synchronous runs;
    /// `>= sim_time` when the server advanced on a quorum.
    pub tail_time: f64,
    /// Cumulative simulated server time at the end of this round.
    pub sim_elapsed: f64,
    /// Per-participating-client simulated times.
    pub client_times: Vec<f64>,
    /// Clients that contributed nothing this round (strategy drops such as
    /// FedAvg-DS, plus availability churn drops).
    pub dropped: usize,
    /// Selected clients that the availability trace took offline before
    /// their plan completed (a subset of `dropped`; 0 without a trace).
    pub churn_dropped: usize,
    /// Total simulated seconds of partial work discarded by churn drops.
    pub partial_time: f64,
    /// Delayed (stale) updates from earlier rounds folded into this
    /// round's aggregation (0 outside the overlapped pipeline).
    pub stale_folded: usize,
    /// Delayed updates discarded at this round because their staleness
    /// exceeded the cap (accounted like churn drops; 0 outside the
    /// overlapped pipeline).
    pub stale_discarded: usize,
    /// Sum of the staleness weights of the updates in `stale_folded`
    /// (each in (0, 1]; 0.0 when nothing was folded).
    pub stale_weight: f64,
    /// Contribution-slots a robust aggregator excluded from this round's
    /// aggregate per coordinate (2·g for trimmed-mean, n−1/n−2 for the
    /// coordinate median; 0 for the mean/buffered paths — see
    /// [`crate::agg::AggStats`]).
    pub agg_rejected: usize,
    /// Contributions whose update norm was clipped before aggregation
    /// this round (0 without a clip-norm wrapper).
    pub agg_clipped: usize,
    /// Jobs this round's dispatch schedule ran away from their
    /// round-robin home worker (0 under round-robin or sequential
    /// execution). Dispatch *diagnostics*: excluded from
    /// [`RunResult::to_csv`] — which stays bit-identical across dispatch
    /// policies and worker counts — and exported via
    /// [`RunResult::to_dispatch_csv`] instead.
    pub steal_count: usize,
    /// Simulated idle worker-seconds of this round's client dispatch
    /// schedule (workers × makespan − busy). Diagnostics, like
    /// `steal_count`; never feeds `sim_time` or the model.
    pub worker_idle: f64,
    /// Clients that trained on a coreset this round (FedCore).
    pub coreset_clients: usize,
    /// Coreset clients whose k-medoids solve warm-started from cached
    /// medoids this round (non-refresh rounds under
    /// `coreset_refresh > 1`; always 0 at the default refresh of 1).
    /// A diagnostic like `steal_count`: excluded from
    /// [`RunResult::to_csv`], so the model CSV is byte-identical to the
    /// pre-warm-start engine's.
    pub coreset_warm: usize,
    /// Mean coreset compression ratio b/m over coreset clients (1.0 = none).
    pub mean_compression: f64,
    /// Past-staleness delayed updates folded into this round's
    /// straggler-distillation correction instead of being discarded
    /// (`distill_weight > 0`; always 0 on the default drop path). This
    /// feeds the model — it appears in [`RunResult::to_csv`] like
    /// `stale_folded` — and the degenerate config keeps it at 0, which
    /// is what makes the model CSV selection-policy-invariant there.
    pub distilled: usize,
    /// 1 when FLANP widened the active cohort prefix after this round's
    /// loss stalled (`--select flanp`), else 0. A model column like
    /// `distilled`: the degenerate whole-fleet prefix never widens.
    pub cohort_widened: usize,
}

/// A complete run: strategy + benchmark labels, the per-round trace, and
/// the final global model (for checkpointing / downstream evaluation).
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Strategy label (e.g. "FedCore").
    pub strategy: String,
    /// Benchmark label (e.g. "MNIST").
    pub benchmark: String,
    /// s — the straggler percentage the fleet was calibrated for.
    pub straggler_pct: f64,
    /// τ — the round deadline (simulated seconds) used for normalization.
    pub deadline: f64,
    /// Per-round trace, in round order.
    pub rounds: Vec<RoundRecord>,
    /// The final global model wᵣ.
    pub final_params: Vec<f32>,
}

impl RunResult {
    /// Test accuracy after the last round (0.0 for an empty run).
    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.test_acc).unwrap_or(0.0)
    }

    /// Best test accuracy over the run (robust to end-of-run noise; the
    /// paper reports converged accuracy).
    pub fn best_accuracy(&self) -> f64 {
        self.rounds.iter().map(|r| r.test_acc).fold(0.0, f64::max)
    }

    /// Training loss of the last round (NaN for an empty run).
    pub fn final_train_loss(&self) -> f64 {
        self.rounds.last().map(|r| r.train_loss).unwrap_or(f64::NAN)
    }

    /// Mean simulated round time normalized by the deadline (Table 2 rows).
    /// In overlapped runs this is the server-advance (quorum) rate.
    pub fn mean_normalized_round_time(&self) -> f64 {
        let ts: Vec<f64> = self.rounds.iter().map(|r| r.sim_time / self.deadline).collect();
        stats::mean(&ts)
    }

    /// Mean straggler-tail round time normalized by the deadline — how
    /// long rounds' slowest clients ran, regardless of when the server
    /// advanced. Equals [`RunResult::mean_normalized_round_time`] for
    /// synchronous runs.
    pub fn mean_normalized_tail_time(&self) -> f64 {
        let ts: Vec<f64> = self.rounds.iter().map(|r| r.tail_time / self.deadline).collect();
        stats::mean(&ts)
    }

    /// Total simulated server time of the run (the last round's
    /// cumulative clock; 0.0 for an empty run).
    pub fn total_sim_time(&self) -> f64 {
        self.rounds.last().map(|r| r.sim_elapsed).unwrap_or(0.0)
    }

    /// Run-wide delayed-gradient accounting: `(folded, discarded)` totals
    /// over all rounds (both 0 outside the overlapped pipeline).
    pub fn stale_totals(&self) -> (usize, usize) {
        self.rounds
            .iter()
            .fold((0, 0), |(f, d), r| (f + r.stale_folded, d + r.stale_discarded))
    }

    /// Run-wide aggregation-seam accounting: `(rejected, clipped)` totals
    /// over all rounds (both 0 under the plain mean without clipping).
    pub fn agg_totals(&self) -> (usize, usize) {
        self.rounds
            .iter()
            .fold((0, 0), |(rej, cl), r| (rej + r.agg_rejected, cl + r.agg_clipped))
    }

    /// Run-wide dispatch accounting: `(total steals, total simulated
    /// idle worker-seconds)` over all rounds (both 0 for sequential
    /// runs; steals 0 under round-robin).
    pub fn dispatch_totals(&self) -> (usize, f64) {
        self.rounds
            .iter()
            .fold((0, 0.0), |(s, idle), r| (s + r.steal_count, idle + r.worker_idle))
    }

    /// All per-client normalized round times (Fig. 4 / Fig. 7 histograms).
    pub fn client_times_normalized(&self) -> Vec<f64> {
        self.rounds
            .iter()
            .flat_map(|r| r.client_times.iter().map(|t| t / self.deadline))
            .collect()
    }

    /// (cumulative simulated time, train loss) pairs — Fig. 5's axes.
    pub fn loss_vs_time(&self) -> Vec<(f64, f64)> {
        self.rounds.iter().map(|r| (r.sim_elapsed, r.train_loss)).collect()
    }

    /// Serialize the round trace as CSV (one row per round). This is the
    /// run's **model output**: bit-identical across executors, worker
    /// counts, and dispatch policies (determinism rule 6) — the dispatch
    /// diagnostics live in [`RunResult::to_dispatch_csv`].
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,train_loss,test_loss,test_acc,sim_time,tail_time,sim_elapsed,dropped,churn_dropped,partial_time,stale_folded,stale_discarded,stale_weight,agg_rejected,agg_clipped,coreset_clients,mean_compression,distilled,cohort_widened\n",
        );
        for r in &self.rounds {
            let _ = writeln!(
                out,
                "{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{},{},{:.6},{},{},{:.6},{},{},{},{:.4},{},{}",
                r.round,
                r.train_loss,
                r.test_loss,
                r.test_acc,
                r.sim_time,
                r.tail_time,
                r.sim_elapsed,
                r.dropped,
                r.churn_dropped,
                r.partial_time,
                r.stale_folded,
                r.stale_discarded,
                r.stale_weight,
                r.agg_rejected,
                r.agg_clipped,
                r.coreset_clients,
                r.mean_compression,
                r.distilled,
                r.cohort_widened
            );
        }
        out
    }

    /// Serialize the per-round dispatch ledger as CSV (one row per
    /// round): steals and simulated idle worker-seconds of each round's
    /// client dispatch schedule. Deterministic for a fixed config — it
    /// replays bit-for-bit from the seed — but, unlike
    /// [`RunResult::to_csv`], it legitimately varies with the worker
    /// count and dispatch policy (that variation is the thing being
    /// measured).
    pub fn to_dispatch_csv(&self) -> String {
        let mut out = String::from("round,steal_count,worker_idle\n");
        for r in &self.rounds {
            let _ = writeln!(out, "{},{},{:.6}", r.round, r.steal_count, r.worker_idle);
        }
        out
    }

    /// Write [`RunResult::to_csv`] to `path`, creating parent directories.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Log-scale-friendly histogram over normalized round times (Fig. 4/7).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Left edge of each bucket (normalized time units).
    pub edges: Vec<f64>,
    /// Per-bucket counts (aligned with `edges`).
    pub counts: Vec<usize>,
}

impl Histogram {
    /// Fixed-width buckets of `width` from 0 to `max_edge` (last bucket is
    /// open-ended so FedAvg's long tail is never silently dropped).
    pub fn new(values: &[f64], width: f64, max_edge: f64) -> Histogram {
        assert!(width > 0.0 && max_edge > width);
        let n_buckets = (max_edge / width).ceil() as usize + 1;
        let mut counts = vec![0usize; n_buckets];
        for &v in values {
            let b = ((v / width).floor() as usize).min(n_buckets - 1);
            counts[b] += 1;
        }
        let edges = (0..n_buckets).map(|i| i as f64 * width).collect();
        Histogram { edges, counts }
    }

    /// Total count across all buckets.
    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }

    /// Fraction of mass at or beyond normalized time `x`.
    pub fn tail_fraction(&self, x: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let tail: usize = self
            .edges
            .iter()
            .zip(&self.counts)
            .filter(|(&e, _)| e >= x)
            .map(|(_, &c)| c)
            .sum();
        tail as f64 / total as f64
    }

    /// ASCII rendering with log-scaled bars (the paper's Fig. 4 uses log-y).
    pub fn render(&self, label: &str) -> String {
        let mut out = format!("{label}\n");
        let max_count = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let log_max = (max_count as f64).ln_1p();
        for (i, (&e, &c)) in self.edges.iter().zip(&self.counts).enumerate() {
            if c == 0 && e > 2.0 && self.counts[i..].iter().all(|&x| x == 0) {
                break; // truncate empty tail
            }
            let bar_len = if c == 0 {
                0
            } else {
                (40.0 * (c as f64).ln_1p() / log_max).ceil() as usize
            };
            let _ = writeln!(out, "  [{:>5.2}+) {:>6} |{}", e, c, "#".repeat(bar_len));
        }
        out
    }
}

/// Cross-run comparison row for Table 2.
#[derive(Clone, Debug)]
pub struct TableRow {
    /// Strategy label.
    pub strategy: String,
    /// Best test accuracy over the run, in percent.
    pub accuracy_pct: f64,
    /// Mean normalized round time (t/τ).
    pub mean_norm_time: f64,
    /// True when the mean round overshoots τ (the paper's red cells).
    pub exceeded_deadline: bool,
}

/// Summarize runs into Table-2-style rows (one per strategy).
pub fn table2_rows(runs: &[RunResult]) -> Vec<TableRow> {
    runs.iter()
        .map(|r| {
            let t = r.mean_normalized_round_time();
            TableRow {
                strategy: r.strategy.clone(),
                accuracy_pct: 100.0 * r.best_accuracy(),
                mean_norm_time: t,
                // 2% tolerance: the §4.4 minimum-work clamp lets extreme
                // stragglers overshoot τ by a floor's worth of work, which
                // is not the deadline-obliviousness the red cells mark.
                exceeded_deadline: t > 1.02,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize, acc: f64, t: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0 / (round + 1) as f64,
            test_loss: 1.0,
            test_acc: acc,
            sim_time: t,
            tail_time: t,
            sim_elapsed: t * (round + 1) as f64,
            client_times: vec![t, t / 2.0],
            dropped: 0,
            churn_dropped: 0,
            partial_time: 0.0,
            stale_folded: 0,
            stale_discarded: 0,
            stale_weight: 0.0,
            agg_rejected: 0,
            agg_clipped: 0,
            steal_count: 0,
            worker_idle: 0.0,
            coreset_clients: 1,
            coreset_warm: 0,
            mean_compression: 0.5,
            distilled: 0,
            cohort_widened: 0,
        }
    }

    fn run() -> RunResult {
        RunResult {
            strategy: "FedCore".into(),
            benchmark: "MNIST".into(),
            straggler_pct: 30.0,
            deadline: 2.0,
            rounds: vec![record(0, 0.3, 2.0), record(1, 0.7, 1.0), record(2, 0.6, 2.0)],
            final_params: vec![0.0; 4],
        }
    }

    #[test]
    fn accuracy_views() {
        let r = run();
        assert_eq!(r.final_accuracy(), 0.6);
        assert_eq!(r.best_accuracy(), 0.7);
    }

    #[test]
    fn normalized_times() {
        let r = run();
        let want = (1.0 + 0.5 + 1.0) / 3.0;
        assert!((r.mean_normalized_round_time() - want).abs() < 1e-12);
        assert_eq!(r.client_times_normalized().len(), 6);
    }

    #[test]
    fn csv_shape() {
        let csv = run().to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("round,"));
        assert_eq!(lines[1].split(',').count(), 19);
        assert_eq!(lines[0].split(',').count(), 19);
        assert!(lines[0].contains("tail_time"));
        assert!(lines[0].contains("stale_folded"));
        assert!(lines[0].contains("agg_rejected"));
        assert!(lines[0].contains("agg_clipped"));
        // Selection-suite model columns: both stay 0 under degenerate
        // configs, which keeps the model CSV selection-policy-invariant.
        assert!(lines[0].contains("distilled"));
        assert!(lines[0].contains("cohort_widened"));
        // Determinism rule 6: the model CSV carries no dispatch
        // diagnostics — those live in to_dispatch_csv.
        assert!(!lines[0].contains("steal_count"));
        assert!(!lines[0].contains("worker_idle"));
        // ... nor the warm-start diagnostic (same rule: the model CSV is
        // identical across refresh intervals only because the count
        // stays out of it).
        assert!(!lines[0].contains("coreset_warm"));
    }

    #[test]
    fn csv_headers_are_pinned() {
        // Golden headers: column order and count are part of the output
        // contract (downstream notebooks, the differential harnesses'
        // bitwise CSV comparisons, the obs counter registry's mapping
        // onto RoundRecord columns). Appending a column is a deliberate
        // schema change — update these strings in the same commit.
        const GOLDEN: &str = "round,train_loss,test_loss,test_acc,sim_time,tail_time,\
                              sim_elapsed,dropped,churn_dropped,partial_time,stale_folded,\
                              stale_discarded,stale_weight,agg_rejected,agg_clipped,\
                              coreset_clients,mean_compression,distilled,cohort_widened";
        const GOLDEN_DISPATCH: &str = "round,steal_count,worker_idle";
        assert_eq!(run().to_csv().lines().next().unwrap(), GOLDEN);
        assert_eq!(GOLDEN.split(',').count(), 19);
        assert_eq!(run().to_dispatch_csv().lines().next().unwrap(), GOLDEN_DISPATCH);
    }

    #[test]
    fn dispatch_csv_and_totals() {
        let mut r = run();
        r.rounds[0].steal_count = 2;
        r.rounds[0].worker_idle = 1.5;
        r.rounds[2].steal_count = 1;
        r.rounds[2].worker_idle = 0.25;
        assert_eq!(r.dispatch_totals(), (3, 1.75));
        let csv = r.to_dispatch_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines[0], "round,steal_count,worker_idle");
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1], "0,2,1.500000");
        assert_eq!(lines[2], "1,0,0.000000");
        // The model CSV is untouched by dispatch diagnostics: two runs
        // differing only in dispatch columns serialize identically.
        assert_eq!(r.to_csv(), run().to_csv());
    }

    #[test]
    fn agg_totals_view() {
        let mut r = run();
        r.rounds[0].agg_rejected = 2;
        r.rounds[2].agg_rejected = 4;
        r.rounds[1].agg_clipped = 3;
        assert_eq!(r.agg_totals(), (6, 3));
    }

    #[test]
    fn stale_and_tail_views() {
        let mut r = run();
        // Round 1 advanced on a quorum: tail overhangs the server time,
        // and a delayed update was folded while another was discarded.
        r.rounds[1].tail_time = 5.0;
        r.rounds[1].stale_folded = 1;
        r.rounds[1].stale_weight = 0.5;
        r.rounds[2].stale_discarded = 2;
        assert_eq!(r.stale_totals(), (1, 2));
        assert!(r.mean_normalized_tail_time() > r.mean_normalized_round_time());
        assert_eq!(r.total_sim_time(), 6.0);
    }

    #[test]
    fn histogram_counts_and_tail() {
        let h = Histogram::new(&[0.1, 0.5, 0.9, 1.0, 3.0, 11.5], 0.5, 4.0);
        assert_eq!(h.total(), 6);
        // values ≥ 1.0 → 3 of 6
        assert!((h.tail_fraction(1.0) - 0.5).abs() < 1e-12);
        // the 11.5 lands in the open-ended last bucket
        assert_eq!(*h.counts.last().unwrap(), 1);
        let txt = h.render("test");
        assert!(txt.contains('#'));
    }

    #[test]
    fn table2_flags_deadline_violation() {
        let mut fedavg = run();
        fedavg.strategy = "FedAvg".into();
        fedavg.rounds.iter_mut().for_each(|r| r.sim_time = 10.0);
        let rows = table2_rows(&[run(), fedavg]);
        assert!(!rows[0].exceeded_deadline);
        assert!(rows[1].exceeded_deadline);
        assert!((rows[0].accuracy_pct - 70.0).abs() < 1e-9);
    }

    #[test]
    fn loss_vs_time_is_monotone_in_time() {
        let r = run();
        let pts = r.loss_vs_time();
        assert!(pts.windows(2).all(|w| w[0].0 <= w[1].0));
    }
}
