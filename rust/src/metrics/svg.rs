//! SVG figure rendering: regenerate the paper's figures as actual images
//! (`results/figures/*.svg`), not just text tables. No external deps —
//! hand-rolled path/axis emission, enough for line charts (Figs. 3, 5, 6)
//! and log-y bar histograms (Figs. 4, 7; Fig. 2).

use std::fmt::Write as _;
use std::path::Path;

const W: f64 = 640.0;
const H: f64 = 400.0;
const MARGIN: f64 = 54.0;
/// Paper-ish categorical palette.
const COLORS: [&str; 6] = ["#1f77b4", "#d62728", "#2ca02c", "#ff7f0e", "#9467bd", "#8c564b"];

/// One named data series (x, y).
#[derive(Clone, Debug)]
pub struct Series {
    /// Legend label.
    pub label: String,
    /// (x, y) points in data coordinates.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// Bundle a labelled point list.
    pub fn new(label: impl Into<String>, points: Vec<(f64, f64)>) -> Series {
        Series { label: label.into(), points }
    }
}

fn finite(v: f64) -> bool {
    v.is_finite()
}

fn bounds(series: &[Series]) -> (f64, f64, f64, f64) {
    let (mut x0, mut x1, mut y0, mut y1) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for s in series {
        for &(x, y) in &s.points {
            if finite(x) && finite(y) {
                x0 = x0.min(x);
                x1 = x1.max(x);
                y0 = y0.min(y);
                y1 = y1.max(y);
            }
        }
    }
    if x0 > x1 {
        (0.0, 1.0, 0.0, 1.0)
    } else {
        let pad = |a: f64, b: f64| if (b - a).abs() < 1e-12 { (a - 0.5, b + 0.5) } else { (a, b) };
        let (x0, x1) = pad(x0, x1);
        let (y0, y1) = pad(y0, y1);
        (x0, x1, y0, y1)
    }
}

fn header(title: &str) -> String {
    format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{W}\" height=\"{H}\" \
         viewBox=\"0 0 {W} {H}\" font-family=\"sans-serif\" font-size=\"12\">\n\
         <rect width=\"{W}\" height=\"{H}\" fill=\"white\"/>\n\
         <text x=\"{}\" y=\"20\" text-anchor=\"middle\" font-size=\"14\">{}</text>\n",
        W / 2.0,
        xml_escape(title)
    )
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

fn axes(out: &mut String, x0: f64, x1: f64, y0: f64, y1: f64, xlabel: &str, ylabel: &str) {
    let _ = writeln!(
        out,
        "<rect x=\"{MARGIN}\" y=\"{MARGIN}\" width=\"{}\" height=\"{}\" fill=\"none\" stroke=\"#999\"/>",
        W - 2.0 * MARGIN,
        H - 2.0 * MARGIN
    );
    // 5 ticks per axis
    for i in 0..=4 {
        let fx = i as f64 / 4.0;
        let gx = MARGIN + fx * (W - 2.0 * MARGIN);
        let gy = H - MARGIN - fx * (H - 2.0 * MARGIN);
        let xv = x0 + fx * (x1 - x0);
        let yv = y0 + fx * (y1 - y0);
        let _ = writeln!(
            out,
            "<text x=\"{gx:.1}\" y=\"{:.1}\" text-anchor=\"middle\" fill=\"#444\">{}</text>",
            H - MARGIN + 16.0,
            fmt_tick(xv)
        );
        let _ = writeln!(
            out,
            "<text x=\"{:.1}\" y=\"{gy:.1}\" text-anchor=\"end\" fill=\"#444\">{}</text>",
            MARGIN - 6.0,
            fmt_tick(yv)
        );
        let _ = writeln!(
            out,
            "<line x1=\"{MARGIN}\" y1=\"{gy:.1}\" x2=\"{:.1}\" y2=\"{gy:.1}\" stroke=\"#eee\"/>",
            W - MARGIN
        );
    }
    let _ = writeln!(
        out,
        "<text x=\"{}\" y=\"{}\" text-anchor=\"middle\" fill=\"#222\">{}</text>",
        W / 2.0,
        H - 10.0,
        xml_escape(xlabel)
    );
    let _ = writeln!(
        out,
        "<text x=\"14\" y=\"{}\" text-anchor=\"middle\" transform=\"rotate(-90 14 {})\" fill=\"#222\">{}</text>",
        H / 2.0,
        H / 2.0,
        xml_escape(ylabel)
    );
}

fn fmt_tick(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{:.0}", v)
    } else if v.abs() >= 1.0 {
        format!("{:.2}", v)
    } else {
        format!("{:.3}", v)
    }
}

/// Render a multi-series line chart.
pub fn line_chart(title: &str, xlabel: &str, ylabel: &str, series: &[Series]) -> String {
    let (x0, x1, y0, y1) = bounds(series);
    let sx = |x: f64| MARGIN + (x - x0) / (x1 - x0) * (W - 2.0 * MARGIN);
    let sy = |y: f64| H - MARGIN - (y - y0) / (y1 - y0) * (H - 2.0 * MARGIN);
    let mut out = header(title);
    axes(&mut out, x0, x1, y0, y1, xlabel, ylabel);
    for (i, s) in series.iter().enumerate() {
        let color = COLORS[i % COLORS.len()];
        let mut d = String::new();
        let mut first = true;
        for &(x, y) in &s.points {
            if !finite(x) || !finite(y) {
                first = true;
                continue;
            }
            let _ = write!(d, "{}{:.1},{:.1} ", if first { "M" } else { "L" }, sx(x), sy(y));
            first = false;
        }
        let _ = writeln!(
            out,
            "<path d=\"{}\" fill=\"none\" stroke=\"{color}\" stroke-width=\"1.8\"/>",
            d.trim()
        );
        // legend
        let ly = MARGIN + 16.0 * i as f64 + 8.0;
        let _ = writeln!(
            out,
            "<line x1=\"{:.1}\" y1=\"{ly:.1}\" x2=\"{:.1}\" y2=\"{ly:.1}\" stroke=\"{color}\" stroke-width=\"3\"/>\
             <text x=\"{:.1}\" y=\"{:.1}\" fill=\"#222\">{}</text>",
            W - MARGIN - 150.0,
            W - MARGIN - 130.0,
            W - MARGIN - 124.0,
            ly + 4.0,
            xml_escape(&s.label)
        );
    }
    out.push_str("</svg>\n");
    out
}

/// Render a grouped log-y histogram (one group of bars per series).
pub fn log_histogram(title: &str, xlabel: &str, edges: &[f64], series: &[Series]) -> String {
    // Series points are (edge, count); y is log-scaled via ln(1 + c).
    let max_count = series
        .iter()
        .flat_map(|s| s.points.iter().map(|p| p.1))
        .fold(0.0f64, f64::max)
        .max(1.0);
    let ymax = (1.0 + max_count).ln();
    let n_bins = edges.len().max(1);
    let group_w = (W - 2.0 * MARGIN) / n_bins as f64;
    let bar_w = (group_w - 4.0) / series.len().max(1) as f64;

    let mut out = header(title);
    let _ = writeln!(
        out,
        "<rect x=\"{MARGIN}\" y=\"{MARGIN}\" width=\"{}\" height=\"{}\" fill=\"none\" stroke=\"#999\"/>",
        W - 2.0 * MARGIN,
        H - 2.0 * MARGIN
    );
    for (si, s) in series.iter().enumerate() {
        let color = COLORS[si % COLORS.len()];
        for (bi, &(_, c)) in s.points.iter().enumerate() {
            if c <= 0.0 {
                continue;
            }
            let h = (1.0 + c).ln() / ymax * (H - 2.0 * MARGIN);
            let x = MARGIN + bi as f64 * group_w + 2.0 + si as f64 * bar_w;
            let _ = writeln!(
                out,
                "<rect x=\"{x:.1}\" y=\"{:.1}\" width=\"{:.1}\" height=\"{h:.1}\" fill=\"{color}\" fill-opacity=\"0.85\"/>",
                H - MARGIN - h,
                bar_w.max(1.0)
            );
        }
        let ly = MARGIN + 16.0 * si as f64 + 8.0;
        let _ = writeln!(
            out,
            "<rect x=\"{:.1}\" y=\"{:.1}\" width=\"10\" height=\"10\" fill=\"{color}\"/>\
             <text x=\"{:.1}\" y=\"{:.1}\" fill=\"#222\">{}</text>",
            W - MARGIN - 150.0,
            ly - 8.0,
            W - MARGIN - 134.0,
            ly + 2.0,
            xml_escape(&s.label)
        );
    }
    // x tick labels on bin edges (sparse)
    for (bi, e) in edges.iter().enumerate() {
        if bi % 2 == 0 {
            let x = MARGIN + bi as f64 * group_w;
            let _ = writeln!(
                out,
                "<text x=\"{x:.1}\" y=\"{:.1}\" text-anchor=\"middle\" fill=\"#444\">{}</text>",
                H - MARGIN + 16.0,
                fmt_tick(*e)
            );
        }
    }
    let _ = writeln!(
        out,
        "<text x=\"{}\" y=\"{}\" text-anchor=\"middle\" fill=\"#222\">{} (log-scale counts)</text>",
        W / 2.0,
        H - 10.0,
        xml_escape(xlabel)
    );
    out.push_str("</svg>\n");
    out
}

/// Render a Gantt-style timeline: one horizontal lane per row, filled
/// with colored `[x0, x1)` segments; `legend[i]` names color `i`.
/// Backs the `fedcore report` per-round phase timeline
/// ([`crate::obs::report::Trace::timeline_svg`]).
pub fn timeline(
    title: &str,
    xlabel: &str,
    rows: &[(String, Vec<(f64, f64, usize)>)],
    legend: &[&str],
) -> String {
    let (mut x0, mut x1) = (f64::MAX, f64::MIN);
    for (_, segs) in rows {
        for &(a, b, _) in segs {
            if finite(a) && finite(b) {
                x0 = x0.min(a);
                x1 = x1.max(b);
            }
        }
    }
    if x0 > x1 {
        (x0, x1) = (0.0, 1.0);
    } else if (x1 - x0).abs() < 1e-12 {
        (x0, x1) = (x0 - 0.5, x1 + 0.5);
    }
    let sx = |x: f64| MARGIN + (x - x0) / (x1 - x0) * (W - 2.0 * MARGIN);
    let lane_h = (H - 2.0 * MARGIN) / rows.len().max(1) as f64;
    let bar_h = (lane_h * 0.6).min(18.0);

    let mut out = header(title);
    let _ = writeln!(
        out,
        "<rect x=\"{MARGIN}\" y=\"{MARGIN}\" width=\"{}\" height=\"{}\" fill=\"none\" stroke=\"#999\"/>",
        W - 2.0 * MARGIN,
        H - 2.0 * MARGIN
    );
    // x ticks
    for i in 0..=4 {
        let fx = i as f64 / 4.0;
        let gx = MARGIN + fx * (W - 2.0 * MARGIN);
        let _ = writeln!(
            out,
            "<text x=\"{gx:.1}\" y=\"{:.1}\" text-anchor=\"middle\" fill=\"#444\">{}</text>",
            H - MARGIN + 16.0,
            fmt_tick(x0 + fx * (x1 - x0))
        );
    }
    for (ri, (label, segs)) in rows.iter().enumerate() {
        let lane_top = MARGIN + ri as f64 * lane_h;
        let bar_y = lane_top + (lane_h - bar_h) / 2.0;
        let _ = writeln!(
            out,
            "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"end\" fill=\"#222\">{}</text>",
            MARGIN - 6.0,
            bar_y + bar_h / 2.0 + 4.0,
            xml_escape(label)
        );
        for &(a, b, c) in segs {
            if !finite(a) || !finite(b) || b <= a {
                continue;
            }
            let color = COLORS[c % COLORS.len()];
            let _ = writeln!(
                out,
                "<rect x=\"{:.1}\" y=\"{bar_y:.1}\" width=\"{:.1}\" height=\"{bar_h:.1}\" \
                 fill=\"{color}\" fill-opacity=\"0.85\"/>",
                sx(a),
                (sx(b) - sx(a)).max(0.5)
            );
        }
    }
    for (li, name) in legend.iter().enumerate() {
        let color = COLORS[li % COLORS.len()];
        let lx = MARGIN + 90.0 * li as f64;
        let _ = writeln!(
            out,
            "<rect x=\"{lx:.1}\" y=\"{:.1}\" width=\"10\" height=\"10\" fill=\"{color}\"/>\
             <text x=\"{:.1}\" y=\"{:.1}\" fill=\"#222\">{}</text>",
            MARGIN - 24.0,
            lx + 14.0,
            MARGIN - 14.0,
            xml_escape(name)
        );
    }
    let _ = writeln!(
        out,
        "<text x=\"{}\" y=\"{}\" text-anchor=\"middle\" fill=\"#222\">{}</text>",
        W / 2.0,
        H - 10.0,
        xml_escape(xlabel)
    );
    out.push_str("</svg>\n");
    out
}

/// Write an SVG next to the experiment CSVs.
pub fn write_svg(path: impl AsRef<Path>, svg: &str) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, svg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_series() -> Vec<Series> {
        vec![
            Series::new("FedCore", (0..10).map(|i| (i as f64, 1.0 / (i + 1) as f64)).collect()),
            Series::new("FedProx", (0..10).map(|i| (i as f64, 1.3 / (i + 1) as f64)).collect()),
        ]
    }

    #[test]
    fn line_chart_is_valid_svg_with_all_series() {
        let svg = line_chart("Fig 3", "round", "loss", &demo_series());
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert!(svg.contains("FedCore") && svg.contains("FedProx"));
        assert_eq!(svg.matches("<path").count(), 2);
    }

    #[test]
    fn handles_nan_and_constant_series() {
        let s = vec![Series::new("flat", vec![(0.0, 1.0), (1.0, f64::NAN), (2.0, 1.0)])];
        let svg = line_chart("t", "x", "y", &s);
        assert!(svg.contains("<path"));
        assert!(!svg.contains("NaN"));
    }

    #[test]
    fn histogram_renders_bars() {
        let edges = vec![0.0, 0.5, 1.0, 1.5];
        let s = vec![
            Series::new("FedAvg", vec![(0.0, 5.0), (0.5, 10.0), (1.0, 3.0), (1.5, 1.0)]),
            Series::new("FedCore", vec![(0.0, 2.0), (0.5, 30.0), (1.0, 0.0), (1.5, 0.0)]),
        ];
        let svg = log_histogram("Fig 4", "t/τ", &edges, &s);
        assert!(svg.contains("<rect") && svg.contains("FedAvg"));
        // zero-count bars are skipped: FedCore has 2 bars, FedAvg 4
        assert!(svg.matches("fill-opacity").count() == 6);
    }

    #[test]
    fn escapes_xml() {
        let svg = line_chart("a<b&c", "x", "y", &demo_series());
        assert!(svg.contains("a&lt;b&amp;c"));
    }

    #[test]
    fn timeline_renders_lanes_and_legend() {
        let rows = vec![
            ("round 0".to_string(), vec![(0.0, 2.0, 0), (2.0, 5.0, 1), (5.0, 6.0, 2)]),
            ("round 1".to_string(), vec![(6.0, 7.5, 0), (7.5, 9.0, 1)]),
        ];
        let svg = timeline("phases", "wall ms", &rows, &["select", "train", "eval"]);
        assert!(svg.starts_with("<svg") && svg.ends_with("</svg>\n"));
        assert!(svg.contains("round 0") && svg.contains("round 1"));
        assert!(svg.contains("select") && svg.contains("eval"));
        // 5 phase bars, each with fill-opacity.
        assert_eq!(svg.matches("fill-opacity").count(), 5);
    }

    #[test]
    fn timeline_survives_degenerate_input() {
        let svg = timeline("empty", "x", &[], &[]);
        assert!(svg.ends_with("</svg>\n"));
        let rows = vec![("r".to_string(), vec![(1.0, 1.0, 0), (f64::NAN, 2.0, 1)])];
        let svg = timeline("flat", "x", &rows, &["a"]);
        assert!(!svg.contains("NaN"));
    }

    #[test]
    fn write_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("fedcore_svg_{}", std::process::id()));
        let path = dir.join("sub/fig.svg");
        write_svg(&path, "<svg></svg>").unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
