//! FedMNIST benchmark — procedural 28×28 digit images, paper section 6.1
//! dataset 1.
//!
//! **Substitution (see DESIGN.md):** real MNIST is not available offline,
//! so digits are rendered from 7×5 structural glyph templates, upscaled to
//! 20×28 with random sub-glyph shifts, stroke-intensity jitter, and pixel
//! noise. What the experiment needs from MNIST is (a) a learnable 10-class
//! image task for a small CNN and (b) extreme label heterogeneity across
//! 1,000 clients (two digits each, power-law sizes). Both are preserved;
//! coreset behaviour depends on gradient geometry, not pixel provenance.

use super::partition::{label_assignment, power_law_sizes};
use super::types::{FedDataset, Samples, Shard};
use crate::util::rng::Rng;

/// Image side length (28×28 glyph canvas).
pub const IMG: usize = 28;
/// Number of digit classes.
pub const CLASSES: usize = 10;

/// Classic 7-row × 5-col seven-segment-style glyphs.
const GLYPHS: [[&str; 7]; 10] = [
    ["01110", "10001", "10011", "10101", "11001", "10001", "01110"], // 0
    ["00100", "01100", "00100", "00100", "00100", "00100", "01110"], // 1
    ["01110", "10001", "00001", "00110", "01000", "10000", "11111"], // 2
    ["01110", "10001", "00001", "00110", "00001", "10001", "01110"], // 3
    ["00010", "00110", "01010", "10010", "11111", "00010", "00010"], // 4
    ["11111", "10000", "11110", "00001", "00001", "10001", "01110"], // 5
    ["01110", "10000", "10000", "11110", "10001", "10001", "01110"], // 6
    ["11111", "00001", "00010", "00100", "01000", "01000", "01000"], // 7
    ["01110", "10001", "10001", "01110", "10001", "10001", "01110"], // 8
    ["01110", "10001", "10001", "01111", "00001", "00001", "01110"], // 9
];

/// Render one digit: upscale the 7×5 glyph by 3× to 21×15, place it at a
/// jittered offset inside 28×28, apply stroke intensity and noise.
pub fn render_digit(rng: &mut Rng, digit: usize) -> Vec<f32> {
    debug_assert!(digit < 10);
    let mut img = vec![0.0f32; IMG * IMG];
    let glyph = &GLYPHS[digit];
    let scale = 3usize;
    let gh = 7 * scale; // 21
    let gw = 5 * scale; // 15
    // jittered placement, always fully inside the frame
    let max_dy = IMG - gh; // 7
    let max_dx = IMG - gw; // 13
    let dy = rng.below(max_dy + 1);
    let dx = rng.below(max_dx + 1);
    let intensity = 0.75 + 0.25 * rng.f32(); // stroke brightness jitter

    for (r, row) in glyph.iter().enumerate() {
        for (c, ch) in row.bytes().enumerate() {
            if ch == b'1' {
                for sy in 0..scale {
                    for sx in 0..scale {
                        let y = dy + r * scale + sy;
                        let x = dx + c * scale + sx;
                        img[y * IMG + x] = intensity;
                    }
                }
            }
        }
    }
    // additive pixel noise + slight blur-like edge softening via noise
    for px in img.iter_mut() {
        let noise = (rng.f32() - 0.5) * 0.2;
        *px = (*px + noise).clamp(0.0, 1.0);
    }
    img
}

/// Generation parameters. Paper scale: 1,000 clients, mean 69 samples.
#[derive(Clone, Copy, Debug)]
pub struct MnistConfig {
    /// Number of clients.
    pub n_clients: usize,
    /// Target mean samples per client (power-law distributed).
    pub mean_samples: f64,
    /// Distinct digits per client (the paper's label skew: 2).
    pub digits_per_client: usize,
    /// Held-out test-set size.
    pub test_samples: usize,
    /// Generation seed.
    pub seed: u64,
}

impl Default for MnistConfig {
    fn default() -> Self {
        MnistConfig {
            n_clients: 1000,
            mean_samples: 69.0,
            digits_per_client: 2,
            test_samples: 2048,
            seed: 7,
        }
    }
}

/// Generate the label-skewed digit benchmark per `cfg`.
pub fn generate(cfg: &MnistConfig) -> FedDataset {
    let mut rng = Rng::new(cfg.seed).split(0x33);
    let sizes = power_law_sizes(&mut rng, cfg.n_clients, cfg.mean_samples, 1.4, 8);
    let digit_sets = label_assignment(&mut rng, cfg.n_clients, CLASSES, cfg.digits_per_client);

    let mut clients = Vec::with_capacity(cfg.n_clients);
    for i in 0..cfg.n_clients {
        let mut crng = rng.split(i as u64 + 1);
        let n = sizes[i];
        let mut xs = Vec::with_capacity(n * IMG * IMG);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let digit = digit_sets[i][crng.below(cfg.digits_per_client)];
            xs.extend(render_digit(&mut crng, digit));
            ys.push(digit as i32);
        }
        clients.push(Shard {
            samples: Samples::Dense { x: xs, dim: IMG * IMG },
            labels: ys,
        });
    }

    // Balanced global test set over all 10 digits.
    let mut trng = rng.split(0x7E57);
    let mut xs = Vec::with_capacity(cfg.test_samples * IMG * IMG);
    let mut ys = Vec::with_capacity(cfg.test_samples);
    for t in 0..cfg.test_samples {
        let digit = t % CLASSES;
        xs.extend(render_digit(&mut trng, digit));
        ys.push(digit as i32);
    }

    FedDataset {
        model: "mnist".to_string(),
        clients,
        test: Shard {
            samples: Samples::Dense { x: xs, dim: IMG * IMG },
            labels: ys,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> MnistConfig {
        MnistConfig {
            n_clients: 20,
            mean_samples: 12.0,
            test_samples: 40,
            ..Default::default()
        }
    }

    #[test]
    fn render_is_in_unit_range_and_nonempty() {
        let mut rng = Rng::new(3);
        for d in 0..10 {
            let img = render_digit(&mut rng, d);
            assert_eq!(img.len(), IMG * IMG);
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
            let lit = img.iter().filter(|&&p| p > 0.5).count();
            assert!(lit > 30, "digit {d} has only {lit} bright pixels");
        }
    }

    #[test]
    fn digits_are_mutually_distinguishable() {
        // Mean images of different digits must differ far more than two
        // renders of the same digit — the task must be learnable.
        let mut rng = Rng::new(5);
        let mean_img = |d: usize, rng: &mut Rng| -> Vec<f32> {
            let mut acc = vec![0.0f32; IMG * IMG];
            for _ in 0..20 {
                for (a, p) in acc.iter_mut().zip(render_digit(rng, d)) {
                    *a += p / 20.0;
                }
            }
            acc
        };
        let l2 = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
        };
        let m1 = mean_img(1, &mut rng);
        let m1b = mean_img(1, &mut rng);
        let m8 = mean_img(8, &mut rng);
        assert!(l2(&m1, &m8) > 1.5 * l2(&m1, &m1b), "1 vs 8 not separable");
    }

    #[test]
    fn each_client_has_exactly_two_digits() {
        let ds = generate(&small());
        for c in &ds.clients {
            let mut labels: Vec<i32> = c.labels.clone();
            labels.sort_unstable();
            labels.dedup();
            assert!(labels.len() <= 2, "client has {} digits", labels.len());
        }
    }

    #[test]
    fn test_set_covers_all_digits() {
        let ds = generate(&small());
        let mut seen = [false; 10];
        for &y in &ds.test.labels {
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn deterministic() {
        let a = generate(&small());
        let b = generate(&small());
        assert_eq!(a.clients[3].labels, b.clients[3].labels);
    }
}
