//! Shakespeare benchmark — per-role next-character prediction, paper
//! section 6.1 dataset 2.
//!
//! **Substitution (see DESIGN.md):** the Complete Works are not available
//! offline, so we embed a genuine public-domain excerpt (speeches from
//! several plays) and expand it per client with an order-2 character Markov
//! chain seeded differently per role. What the experiment needs from
//! Shakespeare is (a) a learnable next-char task for a small LSTM and
//! (b) 143 clients with per-role distribution shift and heavily skewed
//! sizes (Table 1: mean 3,616, std 6,808 — std ≈ 2× mean). Per-role chains
//! are built from role-specific mixtures of the base speeches, so both the
//! marginal char statistics and the transition structure drift across
//! clients, reproducing the heterogeneity that drives coreset behaviour.

use super::partition::power_law_sizes;
use super::types::{FedDataset, Samples, Shard};
use crate::util::rng::Rng;

/// Matches `python/compile/models/shake_lstm.py::SEQ_LEN`.
pub const SEQ_LEN: usize = 20;

/// Genuine public-domain Shakespeare speeches (spelling lightly normalized
/// to lowercase on ingest). Each entry is one "voice" the per-role Markov
/// chains mix over.
const SPEECHES: [&str; 8] = [
    // Hamlet III.i
    "to be, or not to be, that is the question: whether 'tis nobler in the \
     mind to suffer the slings and arrows of outrageous fortune, or to take \
     arms against a sea of troubles and by opposing end them. to die, to \
     sleep; no more; and by a sleep to say we end the heart-ache and the \
     thousand natural shocks that flesh is heir to.",
    // Macbeth V.v
    "to-morrow, and to-morrow, and to-morrow, creeps in this petty pace from \
     day to day, to the last syllable of recorded time; and all our \
     yesterdays have lighted fools the way to dusty death. out, out, brief \
     candle! life's but a walking shadow, a poor player that struts and \
     frets his hour upon the stage and then is heard no more.",
    // Richard II II.i
    "this royal throne of kings, this sceptred isle, this earth of majesty, \
     this seat of mars, this other eden, demi-paradise, this fortress built \
     by nature for herself against infection and the hand of war, this \
     happy breed of men, this little world, this precious stone set in the \
     silver sea.",
    // As You Like It II.vii
    "all the world's a stage, and all the men and women merely players: they \
     have their exits and their entrances; and one man in his time plays \
     many parts, his acts being seven ages. at first the infant, mewling \
     and puking in the nurse's arms.",
    // Julius Caesar III.ii
    "friends, romans, countrymen, lend me your ears; i come to bury caesar, \
     not to praise him. the evil that men do lives after them; the good is \
     oft interred with their bones; so let it be with caesar. the noble \
     brutus hath told you caesar was ambitious.",
    // Romeo and Juliet II.ii
    "but, soft! what light through yonder window breaks? it is the east, and \
     juliet is the sun. arise, fair sun, and kill the envious moon, who is \
     already sick and pale with grief, that thou her maid art far more fair \
     than she.",
    // Henry V III.i
    "once more unto the breach, dear friends, once more; or close the wall \
     up with our english dead. in peace there's nothing so becomes a man as \
     modest stillness and humility: but when the blast of war blows in our \
     ears, then imitate the action of the tiger.",
    // The Tempest IV.i
    "our revels now are ended. these our actors, as i foretold you, were all \
     spirits and are melted into air, into thin air: and, like the baseless \
     fabric of this vision, the cloud-capp'd towers, the gorgeous palaces, \
     the solemn temples, the great globe itself, shall dissolve.",
];

/// Generation parameters. Paper scale: 143 clients, mean 3,616 samples.
#[derive(Clone, Debug)]
pub struct ShakespeareConfig {
    /// Number of clients (speaking roles).
    pub n_clients: usize,
    /// Target mean samples per client (power-law distributed).
    pub mean_samples: f64,
    /// Held-out test-set size.
    pub test_samples: usize,
    /// Generation seed.
    pub seed: u64,
    /// Char vocabulary from the artifact manifest (index 0 = unknown/pad).
    pub vocab: Vec<char>,
}

impl Default for ShakespeareConfig {
    fn default() -> Self {
        ShakespeareConfig {
            n_clients: 143,
            mean_samples: 3616.0,
            test_samples: 1024,
            seed: 7,
            vocab: (0..64).map(|i| (b'a' + (i % 26) as u8) as char).collect(),
        }
    }
}

/// Order-2 character Markov chain over vocabulary ids.
struct Markov {
    vocab_size: usize,
    /// counts[(a * V + b) * V + c] = #occurrences of c after bigram (a, b).
    counts: Vec<f32>,
}

impl Markov {
    fn new(vocab_size: usize) -> Markov {
        Markov { vocab_size, counts: vec![0.0; vocab_size * vocab_size * vocab_size] }
    }

    /// Accumulate transitions from an id sequence with weight `w`.
    fn train(&mut self, ids: &[usize], w: f32) {
        let v = self.vocab_size;
        for win in ids.windows(3) {
            self.counts[(win[0] * v + win[1]) * v + win[2]] += w;
        }
    }

    /// Sample the next id given the previous two; add-k smoothing keeps the
    /// chain ergodic even where a role's mixture has gaps.
    fn next(&self, rng: &mut Rng, a: usize, b: usize) -> usize {
        let v = self.vocab_size;
        let row = &self.counts[(a * v + b) * v..(a * v + b + 1) * v];
        // Tiny add-k: enough to escape unseen bigrams, small enough that the
        // output keeps English char statistics (space ≈ 1/6 of chars).
        let smooth = 0.001f32;
        let total: f32 = row.iter().sum::<f32>() + smooth * v as f32;
        let mut x = rng.f32() * total;
        for (c, &cnt) in row.iter().enumerate() {
            x -= cnt + smooth;
            if x <= 0.0 {
                return c;
            }
        }
        v - 1
    }
}

/// Map a char to its vocabulary id (uppercase folds to lowercase; unknown → 0).
pub fn char_id(vocab: &[char], ch: char) -> usize {
    let c = ch.to_ascii_lowercase();
    vocab.iter().position(|&vc| vc == c).unwrap_or(0)
}

fn encode(vocab: &[char], text: &str) -> Vec<usize> {
    text.chars().map(|c| char_id(vocab, c)).collect()
}

/// Build one role's corpus: an order-2 chain trained on a role-specific
/// mixture of the base speeches (two dominant voices per role, echoing
/// MNIST's two-digit skew), then sampled to `chars` characters.
fn role_corpus(rng: &mut Rng, vocab: &[char], chars: usize) -> Vec<usize> {
    let v = vocab.len();
    let mut chain = Markov::new(v);
    // Two dominant voices + a faint global mixture for ergodicity.
    let lead = rng.below(SPEECHES.len());
    let second = (lead + 1 + rng.below(SPEECHES.len() - 1)) % SPEECHES.len();
    for (i, speech) in SPEECHES.iter().enumerate() {
        let w = if i == lead {
            1.0
        } else if i == second {
            0.5
        } else {
            0.05
        };
        chain.train(&encode(vocab, speech), w);
    }
    // Roll out from a random position in the lead speech.
    let seed_ids = encode(vocab, SPEECHES[lead]);
    let start = rng.below(seed_ids.len() - 2);
    let (mut a, mut b) = (seed_ids[start], seed_ids[start + 1]);
    let mut out = Vec::with_capacity(chars);
    out.push(a);
    out.push(b);
    while out.len() < chars {
        let c = chain.next(rng, a, b);
        out.push(c);
        a = b;
        b = c;
    }
    out
}

/// Slice a character stream into non-overlapping (x, y) samples:
/// x = ids[t .. t+S], y = ids[t+1 .. t+S+1] (next-char targets).
fn slice_samples(ids: &[usize], n_samples: usize) -> (Vec<i32>, Vec<i32>) {
    let mut xs = Vec::with_capacity(n_samples * SEQ_LEN);
    let mut ys = Vec::with_capacity(n_samples * SEQ_LEN);
    for s in 0..n_samples {
        let t = s * SEQ_LEN;
        for k in 0..SEQ_LEN {
            xs.push(ids[t + k] as i32);
            ys.push(ids[t + k + 1] as i32);
        }
    }
    (xs, ys)
}

/// Generate the full federated Shakespeare benchmark.
pub fn generate(cfg: &ShakespeareConfig) -> FedDataset {
    assert!(cfg.vocab.len() >= 8, "vocab too small");
    let mut rng = Rng::new(cfg.seed).split(0x5A);
    // Table 1: std ≈ 1.9× mean — use a heavier tail than MNIST.
    let sizes = power_law_sizes(&mut rng, cfg.n_clients, cfg.mean_samples, 1.25, 3);

    // Each role's corpus is split into train + held-out samples; the global
    // test set is the union of per-role hold-outs (the LEAF/FedProx
    // convention: test text comes from the same speaking roles).
    let test_per_role = (cfg.test_samples / cfg.n_clients).max(1);
    let mut clients = Vec::with_capacity(cfg.n_clients);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        let mut crng = rng.split(i as u64 + 1);
        let total = n + test_per_role;
        let ids = role_corpus(&mut crng, &cfg.vocab, total * SEQ_LEN + 1);
        let (x_all, y_all) = slice_samples(&ids, total);
        clients.push(Shard {
            samples: Samples::Tokens { x: x_all[..n * SEQ_LEN].to_vec(), seq: SEQ_LEN },
            labels: y_all[..n * SEQ_LEN].to_vec(),
        });
        xs.extend_from_slice(&x_all[n * SEQ_LEN..]);
        ys.extend_from_slice(&y_all[n * SEQ_LEN..]);
    }

    FedDataset {
        model: "shake".to_string(),
        clients,
        test: Shard {
            samples: Samples::Tokens { x: xs, seq: SEQ_LEN },
            labels: ys,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_vocab() -> Vec<char> {
        "\x00 abcdefghijklmnopqrstuvwxyz.,;:!?'-\n\"()[]0123456789&_ABCDEFGHIJ"
            .chars()
            .collect()
    }

    fn small() -> ShakespeareConfig {
        ShakespeareConfig {
            n_clients: 12,
            mean_samples: 30.0,
            test_samples: 32,
            seed: 7,
            vocab: test_vocab(),
        }
    }

    #[test]
    fn shapes_and_shift_invariant() {
        let ds = generate(&small());
        assert_eq!(ds.num_clients(), 12);
        for c in &ds.clients {
            assert!(c.len() >= 3);
            let (x, seq) = match &c.samples {
                Samples::Tokens { x, seq } => (x, *seq),
                _ => panic!("expected tokens"),
            };
            assert_eq!(seq, SEQ_LEN);
            assert_eq!(x.len(), c.len() * SEQ_LEN);
            assert_eq!(c.labels.len(), c.len() * SEQ_LEN);
            // y is x shifted by one within a contiguous stream.
            for s in 0..c.len() {
                for k in 0..SEQ_LEN - 1 {
                    assert_eq!(c.labels[s * SEQ_LEN + k], x[s * SEQ_LEN + k + 1]);
                }
            }
        }
    }

    #[test]
    fn ids_within_vocab() {
        let ds = generate(&small());
        let v = test_vocab().len() as i32;
        for c in ds.clients.iter().chain([&ds.test]) {
            match &c.samples {
                Samples::Tokens { x, .. } => {
                    assert!(x.iter().all(|&id| (0..v).contains(&id)));
                }
                _ => panic!(),
            }
            assert!(c.labels.iter().all(|&id| (0..v).contains(&id)));
        }
    }

    #[test]
    fn text_is_predictable_not_uniform() {
        // An order-2 chain over English text: ' ' and 'e' must dominate.
        let ds = generate(&small());
        let vocab = test_vocab();
        let space = char_id(&vocab, ' ') as i32;
        let mut total = 0usize;
        let mut spaces = 0usize;
        for c in &ds.clients {
            if let Samples::Tokens { x, .. } = &c.samples {
                total += x.len();
                spaces += x.iter().filter(|&&id| id == space).count();
            }
        }
        let frac = spaces as f64 / total as f64;
        assert!((0.05..0.4).contains(&frac), "space frac {frac}");
    }

    #[test]
    fn roles_have_distribution_shift() {
        // Char histograms of different roles should differ more than two
        // halves of the same (large) role.
        let ds = generate(&ShakespeareConfig { mean_samples: 400.0, ..small() });
        let hist = |xs: &[i32]| -> Vec<f64> {
            let mut h = vec![0.0; 64];
            for &id in xs {
                h[id as usize] += 1.0;
            }
            let n: f64 = h.iter().sum();
            h.iter().map(|c| c / n).collect()
        };
        let l1 = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
        };
        let get = |i: usize| match &ds.clients[i].samples {
            Samples::Tokens { x, .. } => x.clone(),
            _ => panic!(),
        };
        // Use the largest role so the within-role baseline is not noise.
        let big = (0..ds.num_clients()).max_by_key(|&i| ds.clients[i].len()).unwrap();
        let a = get(big);
        let (a1, a2) = a.split_at(a.len() / 2);
        let within = l1(&hist(a1), &hist(a2));
        let mut across = 0.0;
        let mut pairs = 0.0;
        for j in 0..6 {
            if j == big {
                continue;
            }
            across += l1(&hist(&a), &hist(&get(j)));
            pairs += 1.0;
        }
        across /= pairs;
        assert!(
            across > within,
            "across-role shift {across} not above within-role {within}"
        );
    }

    #[test]
    fn char_id_folds_case_and_unknowns() {
        let v = test_vocab();
        assert_eq!(char_id(&v, 'A'), char_id(&v, 'a'));
        assert_eq!(char_id(&v, '™'), 0);
    }

    #[test]
    fn deterministic() {
        let a = generate(&small());
        let b = generate(&small());
        assert_eq!(a.clients[5].labels, b.clients[5].labels);
    }

    #[test]
    fn size_skew_matches_table1_shape() {
        let ds = generate(&ShakespeareConfig {
            n_clients: 143,
            mean_samples: 200.0,
            ..small()
        });
        let stats = super::super::partition::size_stats(&ds.sizes());
        assert!(stats.std > stats.mean * 0.8, "std {} mean {}", stats.std, stats.mean);
    }
}
