//! Datasets: the three paper benchmarks (section 6.1) plus the partitioning
//! machinery that creates the statistical heterogeneity of Table 1 / Fig. 2.
//!
//! * [`synthetic`] — FedProx Synthetic(α, β), 30 clients, logistic regression.
//! * [`mnist`] — FedMNIST, 1,000 clients, two digits each, small CNN.
//! * [`shakespeare`] — next-char prediction, 143 speaking-role clients, LSTM.
//!
//! Each generator returns a [`FedDataset`] tying shards to the L2 model that
//! consumes them ("logreg" / "mnist" / "shake" in the artifact manifest).

pub mod mnist;
pub mod partition;
pub mod shakespeare;
pub mod synthetic;
pub mod types;

pub use types::{FedDataset, Samples, Shard};

use crate::util::rng::Rng;

/// Which paper benchmark to generate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Benchmark {
    /// Synthetic(α, β) — FedProx generator, logistic regression.
    Synthetic {
        /// α — inter-client model heterogeneity.
        alpha: f64,
        /// β — inter-client data heterogeneity.
        beta: f64,
    },
    /// FedMNIST — label-skewed digit images, CNN.
    Mnist,
    /// Shakespeare — per-role next-char prediction, LSTM.
    Shakespeare,
}

impl Benchmark {
    /// Parse "synthetic(1,1)" / "synthetic_0.5_0.5" / "mnist" / "shakespeare".
    pub fn parse(s: &str) -> Option<Benchmark> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "mnist" => return Some(Benchmark::Mnist),
            "shakespeare" | "shake" => return Some(Benchmark::Shakespeare),
            _ => {}
        }
        if let Some(rest) = t.strip_prefix("synthetic") {
            let args: Vec<f64> = rest
                .trim_matches(|c: char| "()_ ".contains(c))
                .split(|c: char| ",_".contains(c))
                .filter(|p| !p.is_empty())
                .filter_map(|p| p.trim().parse().ok())
                .collect();
            return match args.as_slice() {
                [] => Some(Benchmark::Synthetic { alpha: 1.0, beta: 1.0 }),
                [a, b] => Some(Benchmark::Synthetic { alpha: *a, beta: *b }),
                _ => None,
            };
        }
        None
    }

    /// Manifest model key consumed by the runtime.
    pub fn model_key(&self) -> &'static str {
        match self {
            Benchmark::Synthetic { .. } => "logreg",
            Benchmark::Mnist => "mnist",
            Benchmark::Shakespeare => "shake",
        }
    }

    /// Canonical display name (paper column headers).
    pub fn label(&self) -> String {
        match self {
            Benchmark::Synthetic { alpha, beta } => format!("Synthetic({alpha},{beta})"),
            Benchmark::Mnist => "MNIST".to_string(),
            Benchmark::Shakespeare => "Shakespeare".to_string(),
        }
    }
}

/// Scale knob for generation: `1.0` reproduces the paper's Table 1 sizes;
/// smaller values shrink client counts and per-client sizes proportionally
/// (used by tests/examples to stay CI-tractable while preserving the
/// power-law shape and label skew).
pub fn generate(bench: Benchmark, scale: f64, vocab: &[char], seed: u64) -> FedDataset {
    assert!(scale > 0.0 && scale <= 1.0, "scale in (0, 1]");
    let sc = |n: usize, min: usize| ((n as f64 * scale).round() as usize).max(min);
    match bench {
        Benchmark::Synthetic { alpha, beta } => synthetic::generate(&synthetic::SyntheticConfig {
            alpha,
            beta,
            n_clients: sc(30, 4),
            mean_samples: (670.0 * scale).max(24.0),
            test_samples: sc(1024, 64),
            seed,
        }),
        Benchmark::Mnist => mnist::generate(&mnist::MnistConfig {
            n_clients: sc(1000, 10),
            mean_samples: 69.0, // per-client sizes stay paper-shaped
            digits_per_client: 2,
            test_samples: sc(2048, 80),
            seed,
        }),
        Benchmark::Shakespeare => shakespeare::generate(&shakespeare::ShakespeareConfig {
            n_clients: sc(143, 6),
            mean_samples: (3616.0 * scale).max(48.0),
            test_samples: sc(1024, 64),
            seed,
            vocab: vocab.to_vec(),
        }),
    }
}

/// All five paper benchmark columns of Table 2, in paper order.
pub fn paper_benchmarks() -> Vec<Benchmark> {
    vec![
        Benchmark::Mnist,
        Benchmark::Shakespeare,
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        Benchmark::Synthetic { alpha: 0.5, beta: 0.5 },
        Benchmark::Synthetic { alpha: 0.0, beta: 0.0 },
    ]
}

/// Deterministic split of a shard index set for local hold-outs.
pub fn holdout_split(rng: &mut Rng, n: usize, frac: f64) -> (Vec<usize>, Vec<usize>) {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let k = ((n as f64) * frac).round() as usize;
    let held = idx.split_off(n - k.min(n));
    (idx, held)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_benchmarks() {
        assert_eq!(Benchmark::parse("mnist"), Some(Benchmark::Mnist));
        assert_eq!(Benchmark::parse("Shakespeare"), Some(Benchmark::Shakespeare));
        assert_eq!(
            Benchmark::parse("synthetic(0.5, 0.5)"),
            Some(Benchmark::Synthetic { alpha: 0.5, beta: 0.5 })
        );
        assert_eq!(
            Benchmark::parse("synthetic_1_1"),
            Some(Benchmark::Synthetic { alpha: 1.0, beta: 1.0 })
        );
        assert_eq!(
            Benchmark::parse("synthetic"),
            Some(Benchmark::Synthetic { alpha: 1.0, beta: 1.0 })
        );
        assert_eq!(Benchmark::parse("cifar"), None);
    }

    #[test]
    fn model_keys_match_manifest_names() {
        for b in paper_benchmarks() {
            assert!(["logreg", "mnist", "shake"].contains(&b.model_key()));
        }
    }

    #[test]
    fn scaled_generation_shrinks() {
        let vocab: Vec<char> = "\x00 abc".chars().collect();
        let small = generate(Benchmark::Synthetic { alpha: 0.0, beta: 0.0 }, 0.2, &vocab, 1);
        assert_eq!(small.num_clients(), 6);
        assert_eq!(small.model, "logreg");
    }

    #[test]
    fn holdout_split_partitions() {
        let mut rng = Rng::new(9);
        let (train, held) = holdout_split(&mut rng, 100, 0.2);
        assert_eq!(train.len(), 80);
        assert_eq!(held.len(), 20);
        let mut all: Vec<usize> = train.iter().chain(&held).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }
}
