//! Partitioning helpers: client dataset sizes and label-skew assignment.
//!
//! The paper's statistical heterogeneity (section 6.1 / Table 1 / Fig. 2):
//! * MNIST — 1,000 clients, two digits each, power-law sizes (mean 69, std 106)
//! * Shakespeare — 143 clients (speaking roles), very skewed sizes
//! * Synthetic — 30 clients, power-law-ish sizes (mean 670, std 1148)

use crate::util::rng::Rng;
use crate::util::stats;

/// Tail cap for client sizes, as a multiple of the mean. The paper's
/// distributions (Fig. 2) top out around 8–12× the mean; an uncapped
/// Pareto tail makes the straggler ratios diverge far beyond the paper's
/// Table 2 regime (FedAvg ≈ 3–8× τ, not 40×).
pub const MAX_MEAN_MULT: f64 = 8.0;

/// Draw per-client sample counts from a truncated power law, then rescale
/// to approximately hit `target_mean`. Matches the long-tailed shape of the
/// paper's Fig. 2 while keeping counts ≥ `min_size` and the tail
/// ≤ [`MAX_MEAN_MULT`]× the mean.
pub fn power_law_sizes(
    rng: &mut Rng,
    n_clients: usize,
    target_mean: f64,
    alpha: f64,
    min_size: usize,
) -> Vec<usize> {
    assert!(n_clients > 0);
    let mut raw: Vec<f64> = (0..n_clients).map(|_| rng.power_law(1.0, alpha)).collect();
    // Two clamp-and-rescale passes settle both the mean and the cap.
    for _ in 0..2 {
        let raw_mean = stats::mean(&raw);
        for r in raw.iter_mut() {
            *r = (*r / raw_mean).min(MAX_MEAN_MULT);
        }
    }
    let raw_mean = stats::mean(&raw);
    raw.into_iter()
        .map(|r| ((r / raw_mean) * target_mean).round().max(min_size as f64) as usize)
        .collect()
}

/// Assign each client a set of `labels_per_client` distinct labels from
/// `num_labels`, round-robin over label pairs so every label is covered.
pub fn label_assignment(
    rng: &mut Rng,
    n_clients: usize,
    num_labels: usize,
    labels_per_client: usize,
) -> Vec<Vec<usize>> {
    assert!(labels_per_client <= num_labels);
    (0..n_clients)
        .map(|i| {
            // deterministic base label walks all labels; partner(s) random
            let mut labels = vec![i % num_labels];
            while labels.len() < labels_per_client {
                let cand = rng.below(num_labels);
                if !labels.contains(&cand) {
                    labels.push(cand);
                }
            }
            labels
        })
        .collect()
}

/// Summary statistics for Table 1.
#[derive(Clone, Copy, Debug)]
pub struct SizeStats {
    /// Number of clients.
    pub clients: usize,
    /// Total samples across clients.
    pub total: usize,
    /// Mean samples per client.
    pub mean: f64,
    /// Standard deviation of samples per client.
    pub std: f64,
    /// Smallest client.
    pub min: usize,
    /// Largest client.
    pub max: usize,
}

/// Compute the Table 1 summary statistics of a size vector.
pub fn size_stats(sizes: &[usize]) -> SizeStats {
    let f: Vec<f64> = sizes.iter().map(|&s| s as f64).collect();
    SizeStats {
        clients: sizes.len(),
        total: sizes.iter().sum(),
        mean: stats::mean(&f),
        std: stats::std_dev(&f),
        min: sizes.iter().copied().min().unwrap_or(0),
        max: sizes.iter().copied().max().unwrap_or(0),
    }
}

/// Histogram of sizes in `buckets` equal-width bins (for Fig. 2 rendering).
pub fn size_histogram(sizes: &[usize], buckets: usize) -> Vec<(usize, usize)> {
    if sizes.is_empty() || buckets == 0 {
        return Vec::new();
    }
    let max = *sizes.iter().max().unwrap();
    let width = (max / buckets).max(1);
    let mut hist = vec![0usize; buckets];
    for &s in sizes {
        let b = (s / width).min(buckets - 1);
        hist[b] += 1;
    }
    hist.into_iter()
        .enumerate()
        .map(|(i, count)| (i * width, count))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_hits_mean_and_min() {
        let mut rng = Rng::new(1);
        let sizes = power_law_sizes(&mut rng, 1000, 69.0, 1.4, 8);
        let s = size_stats(&sizes);
        assert!(s.min >= 8);
        // long tail: std comparable to or larger than mean
        assert!(s.std > 0.5 * s.mean, "std {} mean {}", s.std, s.mean);
        assert!((s.mean - 69.0).abs() < 69.0 * 0.8, "mean {}", s.mean);
    }

    #[test]
    fn label_assignment_covers_all_labels() {
        let mut rng = Rng::new(2);
        let assign = label_assignment(&mut rng, 100, 10, 2);
        for a in &assign {
            assert_eq!(a.len(), 2);
            assert_ne!(a[0], a[1]);
        }
        let mut covered = vec![false; 10];
        for a in &assign {
            for &l in a {
                covered[l] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn histogram_counts_everything() {
        let sizes = vec![1, 5, 10, 10, 50, 100];
        let hist = size_histogram(&sizes, 5);
        let total: usize = hist.iter().map(|(_, c)| c).sum();
        assert_eq!(total, sizes.len());
    }

    #[test]
    fn stats_on_fixed_input() {
        let s = size_stats(&[10, 20, 30]);
        assert_eq!(s.total, 60);
        assert_eq!(s.mean, 20.0);
        assert_eq!(s.min, 10);
        assert_eq!(s.max, 30);
    }
}
