//! Synthetic(α, β) benchmark — the FedProx generator (Li et al., 2020),
//! paper section 6.1 dataset 3.
//!
//! For client i:
//!   u_i ~ N(0, α),  B_i ~ N(0, β)
//!   model:  W_i ~ N(u_i, 1) ∈ R^{60×10},  b_i ~ N(u_i, 1) ∈ R^10
//!   inputs: v_i ~ N(B_i, 1) ∈ R^60,  x ~ N(v_i, Σ),  Σ = diag(j^{-1.2})
//!   labels: y = argmax(W_i^T x + b_i)
//!
//! α controls how much local models differ across clients (cross-client
//! heterogeneity); β controls how much local data distributions differ.
//! (0,0) is the homogeneous end; (1,1) is the most heterogeneous.

use super::partition::power_law_sizes;
use super::types::{FedDataset, Samples, Shard};
use crate::util::rng::Rng;

/// Feature dimension of the synthetic generator.
pub const DIM: usize = 60;
/// Number of classes.
pub const CLASSES: usize = 10;

/// Generation parameters. `n_clients = 30`, `mean_samples = 670` matches
/// the paper's Table 1 scale; tests/examples shrink both.
#[derive(Clone, Copy, Debug)]
pub struct SyntheticConfig {
    /// α — inter-client model heterogeneity.
    pub alpha: f64,
    /// β — inter-client data heterogeneity.
    pub beta: f64,
    /// Number of clients.
    pub n_clients: usize,
    /// Target mean samples per client (power-law distributed).
    pub mean_samples: f64,
    /// Held-out test-set size.
    pub test_samples: usize,
    /// Generation seed.
    pub seed: u64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            alpha: 1.0,
            beta: 1.0,
            n_clients: 30,
            mean_samples: 670.0,
            test_samples: 1024,
            seed: 7,
        }
    }
}

fn gen_client(
    rng: &mut Rng,
    alpha: f64,
    beta: f64,
    n: usize,
    sigma: &[f64],
) -> (Shard, [f64; 2]) {
    let u = rng.normal_scaled(0.0, alpha.sqrt());
    let b_mean = rng.normal_scaled(0.0, beta.sqrt());

    // client-local ground-truth model
    let w: Vec<f64> = (0..DIM * CLASSES).map(|_| rng.normal_scaled(u, 1.0)).collect();
    let bias: Vec<f64> = (0..CLASSES).map(|_| rng.normal_scaled(u, 1.0)).collect();
    // client-local input mean
    let v: Vec<f64> = (0..DIM).map(|_| rng.normal_scaled(b_mean, 1.0)).collect();

    let mut xs = Vec::with_capacity(n * DIM);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let start = xs.len();
        for j in 0..DIM {
            xs.push(rng.normal_scaled(v[j], sigma[j].sqrt()) as f32);
        }
        let x_row = &xs[start..start + DIM];
        // y = argmax(W^T x + b)
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for c in 0..CLASSES {
            let mut acc = bias[c];
            for j in 0..DIM {
                acc += w[j * CLASSES + c] * x_row[j] as f64;
            }
            if acc > best_v {
                best_v = acc;
                best = c;
            }
        }
        ys.push(best as i32);
    }
    (
        Shard {
            samples: Samples::Dense { x: xs, dim: DIM },
            labels: ys,
        },
        [u, b_mean],
    )
}

/// Generate the full federated synthetic benchmark.
pub fn generate(cfg: &SyntheticConfig) -> FedDataset {
    let mut rng = Rng::new(cfg.seed).split(0xD5);
    let sigma: Vec<f64> = (1..=DIM).map(|j| (j as f64).powf(-1.2)).collect();
    let sizes = power_law_sizes(&mut rng, cfg.n_clients, cfg.mean_samples, 1.12, 16);

    // Each client generates train + held-out samples from the SAME local
    // ground-truth model (Wᵢ, bᵢ, vᵢ); the global test set is the union of
    // the per-client hold-outs. This matches the FedProx evaluation: test
    // data is drawn from the federation's own distributions, so a model
    // that fits the population is measurably better than chance.
    let test_per_client = (cfg.test_samples / cfg.n_clients).max(2);
    let mut clients = Vec::with_capacity(cfg.n_clients);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        let mut crng = rng.split(i as u64 + 1);
        let (shard, _) = gen_client(&mut crng, cfg.alpha, cfg.beta, n + test_per_client, &sigma);
        let (train_x, test_x, train_y, test_y) = match shard.samples {
            Samples::Dense { x, .. } => {
                let (tx, hx) = x.split_at(n * DIM);
                let (ty, hy) = shard.labels.split_at(n);
                (tx.to_vec(), hx.to_vec(), ty.to_vec(), hy.to_vec())
            }
            _ => unreachable!(),
        };
        clients.push(Shard {
            samples: Samples::Dense { x: train_x, dim: DIM },
            labels: train_y,
        });
        xs.extend(test_x);
        ys.extend(test_y);
    }

    FedDataset {
        model: "logreg".to_string(),
        clients,
        test: Shard {
            samples: Samples::Dense { x: xs, dim: DIM },
            labels: ys,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SyntheticConfig {
        SyntheticConfig {
            n_clients: 8,
            mean_samples: 40.0,
            test_samples: 64,
            ..Default::default()
        }
    }

    #[test]
    fn shapes_consistent() {
        let ds = generate(&small());
        assert_eq!(ds.num_clients(), 8);
        for c in &ds.clients {
            assert_eq!(c.len() * DIM, match &c.samples {
                Samples::Dense { x, .. } => x.len(),
                _ => panic!(),
            });
            assert_eq!(c.len(), c.labels.len());
            assert!(c.len() >= 16);
        }
        assert!(ds.test.len() > 0);
    }

    #[test]
    fn labels_in_range() {
        let ds = generate(&small());
        for c in ds.clients.iter().chain([&ds.test]) {
            for &y in &c.labels {
                assert!((0..CLASSES as i32).contains(&y));
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(&small());
        let b = generate(&small());
        assert_eq!(a.clients[0].labels, b.clients[0].labels);
        match (&a.clients[0].samples, &b.clients[0].samples) {
            (Samples::Dense { x: xa, .. }, Samples::Dense { x: xb, .. }) => {
                assert_eq!(xa, xb)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn beta_controls_input_distribution_shift() {
        // β scales the spread of the per-client input means vᵢ ~ N(Bᵢ, 1),
        // Bᵢ ~ N(0, β): at β = 1 client feature-means must be measurably
        // farther apart than at β = 0.
        let spread = |beta: f64| -> f64 {
            let ds = generate(&SyntheticConfig {
                alpha: 0.0,
                beta,
                n_clients: 40,
                mean_samples: 120.0,
                test_samples: 16,
                seed: 9,
            });
            // per-client mean feature vector
            let means: Vec<Vec<f64>> = ds
                .clients
                .iter()
                .map(|c| {
                    let (x, n) = match &c.samples {
                        Samples::Dense { x, .. } => (x, c.len()),
                        _ => panic!(),
                    };
                    let mut m = vec![0.0f64; DIM];
                    for i in 0..n {
                        for j in 0..DIM {
                            m[j] += x[i * DIM + j] as f64 / n as f64;
                        }
                    }
                    m
                })
                .collect();
            let mut total = 0.0;
            let mut pairs = 0.0;
            for i in 0..means.len() {
                for j in (i + 1)..means.len() {
                    let d: f64 = means[i]
                        .iter()
                        .zip(&means[j])
                        .map(|(a, b)| (a - b).powi(2))
                        .sum::<f64>()
                        .sqrt();
                    total += d;
                    pairs += 1.0;
                }
            }
            total / pairs
        };
        // vᵢⱼ has variance 1 + β ⇒ expected pairwise-distance ratio √2 ≈ 1.41.
        let hi = spread(1.0);
        let lo = spread(0.0);
        assert!(hi > 1.15 * lo, "β=1 spread {hi} not above β=0 spread {lo}");
    }
}
