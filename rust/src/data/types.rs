//! Core dataset types shared by all three benchmarks.
//!
//! A federated dataset is a set of per-client shards plus a held-out global
//! test set. Samples are stored flat (row-major) to match the fixed-shape
//! HLO batches; `gather_batch` assembles padded training batches directly
//! into the runtime's `XBatch` representation.

use crate::runtime::XBatch;

/// Per-client (or test) sample storage.
#[derive(Clone, Debug)]
pub enum Samples {
    /// Dense f32 features, `dim` values per sample.
    Dense {
        /// Row-major features, `dim` per sample.
        x: Vec<f32>,
        /// Feature dimension.
        dim: usize,
    },
    /// Token sequences, `seq` ids per sample; labels are also per-position.
    Tokens {
        /// Row-major token ids, `seq` per sample.
        x: Vec<i32>,
        /// Sequence length.
        seq: usize,
    },
}

impl Samples {
    /// Number of stored samples.
    pub fn num_samples(&self) -> usize {
        match self {
            Samples::Dense { x, dim } => {
                if *dim == 0 {
                    0
                } else {
                    x.len() / dim
                }
            }
            Samples::Tokens { x, seq } => {
                if *seq == 0 {
                    0
                } else {
                    x.len() / seq
                }
            }
        }
    }

    /// Elements per sample (x side).
    pub fn x_elems(&self) -> usize {
        match self {
            Samples::Dense { dim, .. } => *dim,
            Samples::Tokens { seq, .. } => *seq,
        }
    }
}

/// One client's local shard.
#[derive(Clone, Debug)]
pub struct Shard {
    /// The sample storage (dense features or token sequences).
    pub samples: Samples,
    /// Dense: one label per sample. Tokens: `seq` labels per sample
    /// (next-char targets).
    pub labels: Vec<i32>,
}

impl Shard {
    /// Number of local samples (mᵢ).
    pub fn len(&self) -> usize {
        self.samples.num_samples()
    }

    /// True when the shard holds no samples.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Labels per sample.
    pub fn y_elems(&self) -> usize {
        match &self.samples {
            Samples::Dense { .. } => 1,
            Samples::Tokens { seq, .. } => *seq,
        }
    }

    /// Primary label of a sample (Dense: the label; Tokens: first target) —
    /// used by partition statistics and label-skew checks.
    pub fn primary_label(&self, i: usize) -> i32 {
        self.labels[i * self.y_elems()]
    }

    /// Assemble a padded batch from sample indices. Returns (x, y, weights)
    /// where `weights[i] = δ_i` for real rows and 0.0 for padding. `deltas`
    /// supplies coreset weights (None ⇒ every picked sample weighs 1).
    pub fn gather_batch(
        &self,
        idxs: &[usize],
        deltas: Option<&[f32]>,
        batch: usize,
    ) -> (XBatch, Vec<i32>, Vec<f32>) {
        assert!(idxs.len() <= batch, "{} > batch {}", idxs.len(), batch);
        let ye = self.y_elems();
        let mut y = vec![0i32; batch * ye];
        let mut w = vec![0.0f32; batch];
        for (row, &i) in idxs.iter().enumerate() {
            debug_assert!(i < self.len());
            y[row * ye..(row + 1) * ye].copy_from_slice(&self.labels[i * ye..(i + 1) * ye]);
            w[row] = deltas.map(|d| d[row]).unwrap_or(1.0);
        }
        let x = match &self.samples {
            Samples::Dense { x, dim } => {
                let mut out = vec![0.0f32; batch * dim];
                for (row, &i) in idxs.iter().enumerate() {
                    out[row * dim..(row + 1) * dim].copy_from_slice(&x[i * dim..(i + 1) * dim]);
                }
                XBatch::F32(out)
            }
            Samples::Tokens { x, seq } => {
                let mut out = vec![0i32; batch * seq];
                for (row, &i) in idxs.iter().enumerate() {
                    out[row * seq..(row + 1) * seq].copy_from_slice(&x[i * seq..(i + 1) * seq]);
                }
                XBatch::I32(out)
            }
        };
        (x, y, w)
    }
}

/// A complete federated benchmark: shards + test set + which L2 model runs it.
#[derive(Clone, Debug)]
pub struct FedDataset {
    /// Manifest model key: "logreg" | "mnist" | "shake".
    pub model: String,
    /// Per-client local shards.
    pub clients: Vec<Shard>,
    /// Held-out global test set.
    pub test: Shard,
}

impl FedDataset {
    /// Number of clients (N).
    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    /// Σ mᵢ over all clients.
    pub fn total_samples(&self) -> usize {
        self.clients.iter().map(|c| c.len()).sum()
    }

    /// Per-client sample counts mᵢ.
    pub fn sizes(&self) -> Vec<usize> {
        self.clients.iter().map(|c| c.len()).collect()
    }

    /// Client weights p_i = m_i / Σ m_j (paper Eq. 1).
    pub fn client_weights(&self) -> Vec<f64> {
        let total = self.total_samples() as f64;
        self.clients.iter().map(|c| c.len() as f64 / total).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_shard(n: usize, dim: usize) -> Shard {
        Shard {
            samples: Samples::Dense {
                x: (0..n * dim).map(|i| i as f32).collect(),
                dim,
            },
            labels: (0..n as i32).collect(),
        }
    }

    #[test]
    fn counts() {
        let s = dense_shard(5, 3);
        assert_eq!(s.len(), 5);
        assert_eq!(s.y_elems(), 1);
        assert_eq!(s.primary_label(2), 2);
    }

    #[test]
    fn gather_pads_with_zero_weight() {
        let s = dense_shard(3, 2);
        let (x, y, w) = s.gather_batch(&[2, 0], None, 4);
        match x {
            XBatch::F32(v) => {
                assert_eq!(v.len(), 8);
                assert_eq!(&v[0..2], &[4.0, 5.0]); // sample 2
                assert_eq!(&v[2..4], &[0.0, 1.0]); // sample 0
                assert_eq!(&v[4..], &[0.0; 4]); // padding
            }
            _ => panic!("dtype"),
        }
        assert_eq!(y, vec![2, 0, 0, 0]);
        assert_eq!(w, vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn gather_applies_deltas() {
        let s = dense_shard(3, 2);
        let (_, _, w) = s.gather_batch(&[1, 2], Some(&[3.0, 5.0]), 4);
        assert_eq!(w, vec![3.0, 5.0, 0.0, 0.0]);
    }

    #[test]
    fn token_shard_roundtrip() {
        let s = Shard {
            samples: Samples::Tokens {
                x: vec![1, 2, 3, 4, 5, 6],
                seq: 3,
            },
            labels: vec![2, 3, 4, 5, 6, 7],
        };
        assert_eq!(s.len(), 2);
        assert_eq!(s.y_elems(), 3);
        assert_eq!(s.primary_label(1), 5);
        let (x, y, w) = s.gather_batch(&[1], None, 2);
        match x {
            XBatch::I32(v) => assert_eq!(v, vec![4, 5, 6, 0, 0, 0]),
            _ => panic!("dtype"),
        }
        assert_eq!(y, vec![5, 6, 7, 0, 0, 0]);
        assert_eq!(w, vec![1.0, 0.0]);
    }

    #[test]
    fn dataset_weights_sum_to_one() {
        let ds = FedDataset {
            model: "logreg".into(),
            clients: vec![dense_shard(2, 2), dense_shard(6, 2)],
            test: dense_shard(2, 2),
        };
        let w = ds.client_weights();
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((w[1] - 0.75).abs() < 1e-12);
    }
}
