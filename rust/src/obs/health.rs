//! Per-client straggler-health ledger, bounded to O(cohort + K) memory.
//!
//! At the 10⁵–10⁶-client lazy fleets of `benches/fleet_scale.rs`, dense
//! per-client stats are exactly the O(fleet) state the coordinator must
//! not hold. The [`HealthLedger`] keeps:
//!
//! - a **top-K heavy-hitter table** ([Space-Saving][ss]) over integer
//!   *tail-cost* scores — the virtual microseconds each client made the
//!   server wait (train time for contributors, the full τ deadline for
//!   drops). Eviction picks the (smallest score, **largest id**) entry —
//!   an explicit tie-break, so the table's contents are a pure function
//!   of the observation stream and never of iteration order. The
//!   admitted client inherits the evicted score (`err_us` records the
//!   inherited, possibly-overestimated part — the standard Space-Saving
//!   error bound).
//! - four O(1) [`Sketch`]es (train time, dispatch makespan, staleness,
//!   churn gaps) for cohort-wide quantiles and the MAD anomaly band.
//!
//! Everything the ledger ingests is a deterministic output of the run
//! (virtual times, drop/stale outcomes), and nothing flows back into
//! the engine — determinism rule 7 (write-only observability) holds
//! with health sampling on, enforced by `proptest_obs.rs`.
//!
//! [ss]: https://dl.acm.org/doi/10.1007/978-3-540-30570-5_27 "Metwally, Agrawal, El Abbadi: Efficient computation of frequent and top-k elements in data streams"

use crate::util::json::Json;

use super::sketch::Sketch;
use super::Record;

/// Ledger knobs carried in [`super::ObsConfig::Jsonl`].
#[derive(Clone, Debug, PartialEq)]
pub struct HealthConfig {
    /// Heavy-hitter table capacity (clients tracked exactly; everyone
    /// else is summarized by the sketches). Clamped to ≥ 1.
    pub top_k: usize,
    /// Emit a `snapshot` record every this many rounds (the final round
    /// always snapshots). Clamped to ≥ 1.
    pub snapshot_every: usize,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig { top_k: 64, snapshot_every: 8 }
    }
}

/// Tracked per-client stats (one heavy-hitter table row).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientHealth {
    /// Fleet client id.
    pub id: usize,
    /// Tail-cost score in virtual microseconds (integer, so merges and
    /// comparisons are exact): train time while contributing plus the
    /// τ deadline per drop.
    pub score_us: u64,
    /// Score inherited on admission from the evicted row (Space-Saving
    /// overestimation bound: the true score is `score_us − err_us ..= score_us`).
    pub err_us: u64,
    /// Rounds this client was observed in the cohort (since admission).
    pub seen: u64,
    /// Virtual microseconds spent training while contributing.
    pub train_us: u64,
    /// Rounds where this client bounded the round critical path.
    pub bounded: u64,
    /// Rounds dropped (churn or past-deadline).
    pub drops: u64,
    /// Delayed updates that arrived stale (folded or discarded).
    pub stale: u64,
    /// Coreset builds that warm-started from cached medoids.
    pub warm: u64,
    /// Coreset builds total (warm-hit rate = `warm / builds`).
    pub builds: u64,
}

impl ClientHealth {
    fn fresh(id: usize, score_us: u64, err_us: u64) -> ClientHealth {
        ClientHealth {
            id,
            score_us,
            err_us,
            seen: 0,
            train_us: 0,
            bounded: 0,
            drops: 0,
            stale: 0,
            warm: 0,
            builds: 0,
        }
    }
}

/// Virtual seconds → integer microseconds (the ledger's score unit;
/// integer so accumulation order can never change a comparison).
fn us(secs: f64) -> u64 {
    if secs.is_finite() && secs > 0.0 {
        (secs * 1e6).round() as u64
    } else {
        0
    }
}

/// The streaming straggler-forensics state (see the module docs).
#[derive(Clone, Debug)]
pub struct HealthLedger {
    cfg: HealthConfig,
    /// Heavy-hitter rows, ≤ `cfg.top_k`, admission-ordered (the
    /// snapshot sorts; in-memory order is irrelevant to the output).
    clients: Vec<ClientHealth>,
    /// Contributing clients' virtual train seconds.
    train: Sketch,
    /// Per-round dispatch makespan seconds (rounds with jobs).
    dispatch: Sketch,
    /// Staleness (in rounds) of every delayed update that arrived.
    staleness: Sketch,
    /// Online seconds a churn-dropped client had trained before cutoff.
    churn_gap: Sketch,
    rounds_observed: u64,
}

impl HealthLedger {
    /// Fresh ledger (config clamped to sane minimums).
    pub fn new(cfg: HealthConfig) -> HealthLedger {
        let cfg =
            HealthConfig { top_k: cfg.top_k.max(1), snapshot_every: cfg.snapshot_every.max(1) };
        HealthLedger {
            cfg,
            clients: Vec::new(),
            train: Sketch::new(),
            dispatch: Sketch::new(),
            staleness: Sketch::new(),
            churn_gap: Sketch::new(),
            rounds_observed: 0,
        }
    }

    /// Number of clients currently tracked exactly (≤ `top_k`).
    pub fn tracked(&self) -> usize {
        self.clients.len()
    }

    /// The cohort-wide train-time sketch (for external gates/benches).
    pub fn train_sketch(&self) -> &Sketch {
        &self.train
    }

    /// Space-Saving credit: bump `id`'s score, admitting (and possibly
    /// evicting) as needed. Zero-credit observations go through
    /// [`Self::tracked_mut`] instead — they must not evict.
    fn credit(&mut self, id: usize, credit_us: u64) -> &mut ClientHealth {
        if let Some(pos) = self.clients.iter().position(|c| c.id == id) {
            self.clients[pos].score_us += credit_us;
            return &mut self.clients[pos];
        }
        if self.clients.len() < self.cfg.top_k {
            self.clients.push(ClientHealth::fresh(id, credit_us, 0));
            let last = self.clients.len() - 1;
            return &mut self.clients[last];
        }
        // Evict the (smallest score, largest id) row — deterministic
        // even when scores tie.
        let mut evict = 0usize;
        for i in 1..self.clients.len() {
            let (a, b) = (&self.clients[i], &self.clients[evict]);
            if (a.score_us, std::cmp::Reverse(a.id)) < (b.score_us, std::cmp::Reverse(b.id)) {
                evict = i;
            }
        }
        let inherited = self.clients[evict].score_us;
        self.clients[evict] = ClientHealth::fresh(id, inherited + credit_us, inherited);
        &mut self.clients[evict]
    }

    fn tracked_mut(&mut self, id: usize) -> Option<&mut ClientHealth> {
        self.clients.iter_mut().find(|c| c.id == id)
    }

    /// A selected client contributed an update after `secs` of virtual
    /// training.
    pub fn observe_train(&mut self, client: usize, secs: f64) {
        self.train.insert(secs);
        let credit = us(secs);
        let e = self.credit(client, credit);
        e.seen += 1;
        e.train_us += credit;
    }

    /// A selected client produced nothing this round; the server paid
    /// `cost_secs` (the τ deadline) waiting. `churn_gap` is the online
    /// time a churn-dropped client had banked before its window closed.
    pub fn observe_drop(&mut self, client: usize, cost_secs: f64, churn_gap: Option<f64>) {
        if let Some(g) = churn_gap {
            self.churn_gap.insert(g);
        }
        let e = self.credit(client, us(cost_secs));
        e.seen += 1;
        e.drops += 1;
    }

    /// A delayed update from `client` arrived `staleness` rounds late
    /// (folded or discarded — both count; zero-credit, never evicts).
    pub fn observe_stale(&mut self, client: usize, staleness: usize) {
        self.staleness.insert(staleness as f64);
        if let Some(e) = self.tracked_mut(client) {
            e.stale += 1;
        }
    }

    /// A contributing client trained on a coreset this round
    /// (`warm` = its k-medoids solve warm-started from cached medoids).
    pub fn observe_coreset(&mut self, client: usize, warm: bool) {
        if let Some(e) = self.tracked_mut(client) {
            e.builds += 1;
            e.warm += warm as u64;
        }
    }

    /// Close a round: `bound` is the client whose arrival bounded the
    /// server's advance (the critical path), `makespan` the dispatch
    /// schedule's virtual makespan (rounds with jobs).
    pub fn observe_round_end(&mut self, bound: Option<usize>, makespan: Option<f64>) {
        self.rounds_observed += 1;
        if let Some(m) = makespan {
            self.dispatch.insert(m);
        }
        if let Some(b) = bound {
            if let Some(e) = self.tracked_mut(b) {
                e.bounded += 1;
            }
        }
    }

    /// Should round `r` (of `total_rounds`) emit a snapshot? Every
    /// `snapshot_every` rounds, plus always the final round.
    pub fn snapshot_due(&self, r: usize, total_rounds: usize) -> bool {
        (r + 1) % self.cfg.snapshot_every == 0 || r + 1 == total_rounds
    }

    /// Render the ledger as a schema-v2 `snapshot` record: the client
    /// table sorted by (score desc, id asc) plus the four sketches.
    pub fn snapshot(&self, round: usize) -> Record {
        let mut rows = self.clients.clone();
        rows.sort_by(|a, b| b.score_us.cmp(&a.score_us).then(a.id.cmp(&b.id)));
        let clients: Vec<Json> = rows
            .iter()
            .map(|c| {
                let mut m = std::collections::BTreeMap::new();
                let mut put = |k: &str, v: u64| {
                    m.insert(k.to_string(), Json::Num(v as f64));
                };
                put("id", c.id as u64);
                put("score_us", c.score_us);
                put("err_us", c.err_us);
                put("seen", c.seen);
                put("train_us", c.train_us);
                put("bounded", c.bounded);
                put("drops", c.drops);
                put("stale", c.stale);
                put("warm", c.warm);
                put("builds", c.builds);
                Json::Obj(m)
            })
            .collect();
        let sketches = {
            let mut m = std::collections::BTreeMap::new();
            m.insert("train_s".to_string(), self.train.to_json());
            m.insert("dispatch_s".to_string(), self.dispatch.to_json());
            m.insert("staleness_rounds".to_string(), self.staleness.to_json());
            m.insert("churn_gap_s".to_string(), self.churn_gap.to_json());
            Json::Obj(m)
        };
        Record::Snapshot {
            round,
            fields: vec![
                ("clients", Json::Arr(clients)),
                ("rounds_observed", Json::Num(self.rounds_observed as f64)),
                ("sketches", sketches),
                ("top_k", Json::Num(self.cfg.top_k as f64)),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::write_json;

    fn snapshot_text(l: &HealthLedger, round: usize) -> String {
        let mut t = String::new();
        write_json(&l.snapshot(round).to_json(), &mut t);
        t
    }

    #[test]
    fn table_stays_bounded_and_keeps_the_heavy_hitter() {
        let mut l = HealthLedger::new(HealthConfig { top_k: 8, snapshot_every: 1 });
        for r in 0..50 {
            for c in 0..100usize {
                // Client 13 is pathologically slow; the rest are light.
                let secs = if c == 13 { 40.0 } else { 0.5 + (c % 7) as f64 * 0.1 };
                l.observe_train(c, secs);
            }
            l.observe_round_end(Some(13), Some(40.0));
            assert!(l.tracked() <= 8, "round {r}: table overflowed");
        }
        let snap = l.snapshot(49).to_json();
        let rows = snap.get("clients").unwrap().as_arr().unwrap();
        assert!(!rows.is_empty());
        // Leaderboard is score-descending and the heavy hitter leads.
        assert_eq!(rows[0].get("id").unwrap().as_f64(), Some(13.0));
        let scores: Vec<f64> =
            rows.iter().map(|r| r.get("score_us").unwrap().as_f64().unwrap()).collect();
        assert!(scores.windows(2).all(|w| w[0] >= w[1]), "leaderboard not sorted");
        assert_eq!(rows[0].get("bounded").unwrap().as_f64(), Some(50.0));
    }

    #[test]
    fn eviction_tie_break_is_by_largest_id() {
        let mut l = HealthLedger::new(HealthConfig { top_k: 2, snapshot_every: 1 });
        l.observe_train(5, 1.0);
        l.observe_train(9, 1.0); // same score as 5
        l.observe_train(2, 1.0); // table full: evicts id 9 (largest id at min score)
        let ids: Vec<usize> = l.clients.iter().map(|c| c.id).collect();
        assert!(ids.contains(&5) && ids.contains(&2), "kept {ids:?}");
        // The admitted row inherited the evicted score (Space-Saving).
        let admitted = l.clients.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(admitted.score_us, 2_000_000);
        assert_eq!(admitted.err_us, 1_000_000);
    }

    #[test]
    fn zero_credit_observations_never_evict() {
        let mut l = HealthLedger::new(HealthConfig { top_k: 1, snapshot_every: 1 });
        l.observe_train(3, 2.0);
        l.observe_stale(4, 1); // untracked: sketch only
        l.observe_coreset(4, true);
        l.observe_round_end(Some(4), None);
        assert_eq!(l.tracked(), 1);
        assert_eq!(l.clients[0].id, 3);
        assert_eq!(l.staleness.count(), 1);
    }

    #[test]
    fn drops_and_warm_rates_accumulate() {
        let mut l = HealthLedger::new(HealthConfig::default());
        l.observe_train(1, 3.0);
        l.observe_coreset(1, true);
        l.observe_train(1, 3.0);
        l.observe_coreset(1, false);
        l.observe_drop(1, 30.0, Some(12.5));
        l.observe_stale(1, 2);
        let c = &l.clients[0];
        assert_eq!(c.seen, 3);
        assert_eq!(c.drops, 1);
        assert_eq!(c.builds, 2);
        assert_eq!(c.warm, 1);
        assert_eq!(c.stale, 1);
        assert_eq!(c.score_us, 36_000_000); // 3s + 3s + 30s deadline
        assert_eq!(c.train_us, 6_000_000);
        assert_eq!(l.churn_gap.count(), 1);
    }

    #[test]
    fn identical_feeds_produce_identical_snapshots() {
        let feed = |l: &mut HealthLedger| {
            for r in 0..20 {
                for c in 0..30usize {
                    if (c + r) % 5 == 0 {
                        l.observe_drop(c, 30.0, Some(c as f64));
                    } else {
                        l.observe_train(c, 1.0 + (c as f64) * 0.3);
                    }
                }
                l.observe_stale(r % 30, 1 + r % 3);
                l.observe_round_end(Some(29), Some(9.7));
            }
        };
        let mut a = HealthLedger::new(HealthConfig { top_k: 6, snapshot_every: 4 });
        let mut b = HealthLedger::new(HealthConfig { top_k: 6, snapshot_every: 4 });
        feed(&mut a);
        feed(&mut b);
        assert_eq!(snapshot_text(&a, 19), snapshot_text(&b, 19));
    }

    #[test]
    fn snapshot_cadence_includes_the_final_round() {
        let l = HealthLedger::new(HealthConfig { top_k: 4, snapshot_every: 8 });
        assert!(!l.snapshot_due(0, 10));
        assert!(l.snapshot_due(7, 10)); // every 8th round
        assert!(l.snapshot_due(9, 10)); // final round
        let every = HealthLedger::new(HealthConfig { top_k: 4, snapshot_every: 0 });
        assert!(every.snapshot_due(0, 10)); // clamped to 1
    }
}
