//! Deterministic, mergeable log-histogram quantile sketches.
//!
//! The health ledger ([`super::health`]) needs streaming quantiles over
//! per-round/per-client quantities (train time, dispatch makespan,
//! staleness, churn gaps) at fleet scale — without keeping the samples
//! and without breaking determinism rule 7. A [`Sketch`] is an
//! HdrHistogram-style fixed-bucket log histogram:
//!
//! - **Bucketing is pure integer bit-twiddling** on the IEEE-754
//!   representation (exponent + top mantissa bits), no `log`/`powf`
//!   calls on the insert path — the same value lands in the same bucket
//!   on every platform, so traced runs stay replayable.
//! - **Counts are integers**, and [`Sketch::merge`] is an elementwise
//!   integer add plus `min`/`max` folds. Integer addition and f64
//!   min/max are associative and commutative, so merging per-worker
//!   shards in *any* fold order yields the identical sketch, bitwise —
//!   the sharded ≡ sequential gate `proptest_obs.rs` enforces. (A
//!   floating-point *sum* would not be fold-order invariant, which is
//!   why the sketch deliberately does not keep one.)
//! - **Memory is O(1)**: [`NUM_BUCKETS`] `u64` counts (~8 KiB dense;
//!   serialization is sparse).
//!
//! Resolution: [`SUB`] sub-buckets per octave ⇒ relative quantile error
//! ≤ `1/(2·SUB)` ≈ 3.1 %. Range: `[2⁻²⁰, 2⁴⁴)` seconds (≈ microsecond
//! to ~557 000 years); values at or below zero land in the underflow
//! bucket, values above the range in the overflow bucket, and
//! non-finite values are skipped.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Sub-buckets per power-of-two octave (16 ⇒ ≤ ~3.1 % relative error).
pub const SUB: usize = 16;
/// Number of mantissa bits that index the sub-bucket (`2^SUB_BITS == SUB`).
const SUB_BITS: u32 = 4;
/// Smallest binary exponent with its own octave; values in `(0, 2^E_MIN)`
/// fall into the underflow bucket 0.
pub const E_MIN: i32 = -20;
/// One-past-largest binary exponent; values `≥ 2^E_MAX` fall into the
/// overflow bucket.
pub const E_MAX: i32 = 44;
/// Total bucket count: underflow + (E_MAX − E_MIN)·SUB + overflow.
pub const NUM_BUCKETS: usize = 2 + (E_MAX - E_MIN) as usize * SUB;

/// A fixed-layout streaming log-histogram (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct Sketch {
    /// Per-bucket observation counts (dense; index by [`bucket_index`]).
    counts: Vec<u64>,
    /// Total observations (== sum of `counts`).
    count: u64,
    /// Smallest inserted value (`+inf` when empty — never serialized).
    min: f64,
    /// Largest inserted value (`-inf` when empty — never serialized).
    max: f64,
}

impl Default for Sketch {
    fn default() -> Self {
        Sketch::new()
    }
}

/// Deterministic bucket index for a value (total function: underflow
/// bucket 0 for `v ≤ 0` or tiny values, the last bucket for overflow).
pub fn bucket_index(v: f64) -> usize {
    if !(v > 0.0) || v < f64::from_bits(((E_MIN + 1023) as u64) << 52) {
        return 0;
    }
    let bits = v.to_bits();
    let exp = ((bits >> 52) & 0x7FF) as i32 - 1023;
    if exp >= E_MAX {
        return NUM_BUCKETS - 1;
    }
    let sub = ((bits >> (52 - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    1 + (exp - E_MIN) as usize * SUB + sub
}

/// Representative value for a bucket (its geometric-ish midpoint):
/// `0` for underflow, `2^E_MAX` for overflow.
pub fn bucket_value(idx: usize) -> f64 {
    if idx == 0 {
        return 0.0;
    }
    if idx >= NUM_BUCKETS - 1 {
        return f64::from_bits(((E_MAX + 1023) as u64) << 52);
    }
    let exp = E_MIN + ((idx - 1) / SUB) as i32;
    let sub = (idx - 1) % SUB;
    let base = f64::from_bits(((exp + 1023) as u64) << 52);
    base * (1.0 + (sub as f64 + 0.5) / SUB as f64)
}

impl Sketch {
    /// An empty sketch.
    pub fn new() -> Sketch {
        Sketch { counts: vec![0; NUM_BUCKETS], count: 0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Record one observation (non-finite values are skipped — they
    /// carry no quantile information and would poison min/max).
    pub fn insert(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when nothing has been inserted.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Smallest observation (`None` when empty).
    pub fn min(&self) -> Option<f64> {
        (!self.is_empty()).then_some(self.min)
    }

    /// Largest observation (`None` when empty).
    pub fn max(&self) -> Option<f64> {
        (!self.is_empty()).then_some(self.max)
    }

    /// Fold another sketch in. Elementwise integer adds plus min/max
    /// folds only, so the result is independent of merge order and of
    /// how the observations were sharded across workers.
    pub fn merge(&mut self, other: &Sketch) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Approximate `q`-quantile (`q` clamped to `[0, 1]`): the
    /// representative value of the bucket holding the rank-`⌈q·n⌉`
    /// observation, sharpened to the exact `min`/`max` at the ends.
    /// `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.is_empty() {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        if q <= 0.0 {
            return Some(self.min);
        }
        if q >= 1.0 {
            return Some(self.max);
        }
        // Rank walk over integer counts: deterministic by construction.
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Some(bucket_value(idx).clamp(self.min, self.max));
            }
        }
        Some(self.max)
    }

    /// Weighted median and median-absolute-deviation over bucket
    /// representatives — the robust center/spread pair the anomaly flag
    /// (`train > median + k·MAD`) uses. `None` when empty.
    pub fn median_mad(&self) -> Option<(f64, f64)> {
        if self.is_empty() {
            return None;
        }
        let med = self.quantile(0.5)?;
        // MAD: weighted median of |repr − med| over occupied buckets.
        let mut dev: Vec<(f64, u64)> = self
            .counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(idx, &c)| ((bucket_value(idx) - med).abs(), c))
            .collect();
        dev.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite deviations"));
        let target = (self.count).div_ceil(2);
        let mut seen = 0u64;
        for (d, c) in dev {
            seen += c;
            if seen >= target {
                return Some((med, d));
            }
        }
        Some((med, 0.0))
    }

    /// Serialize to the trace encoding: sparse ascending
    /// `[bucket, count]` pairs plus `count`/`min`/`max` (layout
    /// constants are part of the schema, see `docs/observability.md`).
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(idx, &c)| Json::Arr(vec![Json::Num(idx as f64), Json::Num(c as f64)]))
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("buckets".to_string(), Json::Arr(buckets));
        m.insert("count".to_string(), Json::Num(self.count as f64));
        if !self.is_empty() {
            m.insert("min".to_string(), Json::Num(self.min));
            m.insert("max".to_string(), Json::Num(self.max));
        }
        Json::Obj(m)
    }

    /// Validate a serialized sketch without building it: bucket indices
    /// strictly ascending and in range, counts positive integers, and
    /// the `count` field equal to their sum. The checker
    /// ([`super::report::Trace::check`]) calls this per snapshot.
    pub fn validate_json(j: &Json) -> Result<()> {
        let total = j.get("count").and_then(|v| v.as_f64());
        let Some(total) = total else { bail!("sketch missing numeric 'count'") };
        if total < 0.0 || total.fract() != 0.0 {
            bail!("sketch 'count' {total} is not a non-negative integer");
        }
        let Some(buckets) = j.get("buckets").and_then(|v| v.as_arr()) else {
            bail!("sketch missing 'buckets' array")
        };
        let mut sum = 0.0;
        let mut prev: i64 = -1;
        for b in buckets {
            let pair = b.as_arr().filter(|p| p.len() == 2);
            let Some(pair) = pair else { bail!("sketch bucket is not a [index, count] pair") };
            let (Some(idx), Some(c)) = (pair[0].as_f64(), pair[1].as_f64()) else {
                bail!("sketch bucket pair is not numeric")
            };
            if idx.fract() != 0.0 || idx < 0.0 || idx as usize >= NUM_BUCKETS {
                bail!("sketch bucket index {idx} outside [0, {NUM_BUCKETS})");
            }
            if (idx as i64) <= prev {
                bail!("sketch bucket indices not strictly ascending at {idx}");
            }
            prev = idx as i64;
            if c < 1.0 || c.fract() != 0.0 {
                bail!("sketch bucket count {c} is not a positive integer");
            }
            sum += c;
        }
        if sum != total {
            bail!("sketch bucket counts sum to {sum}, 'count' field says {total}");
        }
        if total > 0.0 {
            for key in ["min", "max"] {
                if j.get(key).and_then(|v| v.as_f64()).is_none() {
                    bail!("non-empty sketch missing numeric '{key}'");
                }
            }
        }
        Ok(())
    }

    /// Rebuild a sketch from its trace encoding (validates first).
    pub fn from_json(j: &Json) -> Result<Sketch> {
        Self::validate_json(j)?;
        let mut s = Sketch::new();
        if let Some(buckets) = j.get("buckets").and_then(|v| v.as_arr()) {
            for b in buckets {
                let pair = b.as_arr().expect("validated bucket pair");
                let idx = pair[0].as_f64().expect("validated index") as usize;
                let c = pair[1].as_f64().expect("validated count") as u64;
                s.counts[idx] = c;
                s.count += c;
            }
        }
        if let Some(v) = j.get("min").and_then(|v| v.as_f64()) {
            s.min = v;
        }
        if let Some(v) = j.get("max").and_then(|v| v.as_f64()) {
            s.max = v;
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::write_json;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_index_is_monotone_and_total() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-3.5), 0);
        assert_eq!(bucket_index(f64::NEG_INFINITY), 0);
        assert_eq!(bucket_index(1e-300), 0);
        assert_eq!(bucket_index(1e300), NUM_BUCKETS - 1);
        let mut prev = 0usize;
        let mut v = 1e-7;
        while v < 1e14 {
            let idx = bucket_index(v);
            assert!(idx >= prev, "bucket index regressed at {v}");
            assert!(idx < NUM_BUCKETS);
            prev = idx;
            v *= 1.07;
        }
    }

    #[test]
    fn bucket_value_lands_in_its_own_bucket() {
        for idx in 1..NUM_BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_value(idx)), idx, "repr of bucket {idx} strayed");
        }
    }

    #[test]
    fn quantiles_bound_relative_error() {
        let mut s = Sketch::new();
        let mut rng = Rng::new(42);
        let mut vals: Vec<f64> = (0..2000).map(|_| 0.01 + 100.0 * rng.f64()).collect();
        for &v in &vals {
            s.insert(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.1, 0.5, 0.9, 0.99] {
            let exact = vals[((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len()) - 1];
            let approx = s.quantile(q).unwrap();
            let rel = (approx - exact).abs() / exact;
            assert!(rel <= 1.0 / SUB as f64, "q{q}: {approx} vs {exact} (rel {rel})");
        }
        assert_eq!(s.quantile(0.0), Some(*vals.first().unwrap()));
        assert_eq!(s.quantile(1.0), Some(*vals.last().unwrap()));
    }

    #[test]
    fn merge_matches_sequential_insert_bitwise() {
        let mut rng = Rng::new(7);
        let vals: Vec<f64> = (0..500).map(|_| 1e-6 + 1e6 * rng.f64() * rng.f64()).collect();
        let mut seq = Sketch::new();
        for &v in &vals {
            seq.insert(v);
        }
        for workers in [2usize, 3, 7] {
            let mut shards = vec![Sketch::new(); workers];
            for (i, &v) in vals.iter().enumerate() {
                shards[i % workers].insert(v);
            }
            // Fold right-to-left — the opposite order from the shard walk.
            let mut merged = Sketch::new();
            for sh in shards.iter().rev() {
                merged.merge(sh);
            }
            assert_eq!(merged, seq, "{workers}-way shard diverged");
            let (mut a, mut b) = (String::new(), String::new());
            write_json(&merged.to_json(), &mut a);
            write_json(&seq.to_json(), &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn median_mad_flags_outliers() {
        let mut s = Sketch::new();
        for _ in 0..100 {
            s.insert(1.0);
        }
        s.insert(50.0);
        let (med, mad) = s.median_mad().unwrap();
        assert!((med - 1.0).abs() / 1.0 < 0.1, "median {med} strayed from 1.0");
        // 100 identical values: MAD is within one bucket of zero.
        assert!(mad < 0.1, "MAD {mad} too wide");
        assert!(50.0 > med + 3.0 * (mad + 1e-9), "outlier not flaggable");
    }

    #[test]
    fn json_round_trip_and_validation() {
        let mut s = Sketch::new();
        for v in [0.5, 0.5, 2.0, 1e-30, -4.0, f64::NAN] {
            s.insert(v);
        }
        assert_eq!(s.count(), 5); // NaN skipped, underflow + negatives kept
        let j = s.to_json();
        Sketch::validate_json(&j).unwrap();
        let back = Sketch::from_json(&j).unwrap();
        assert_eq!(back.count(), s.count());
        assert_eq!(back.quantile(0.5), s.quantile(0.5));

        // Corrupted encodings are rejected.
        let text = {
            let mut t = String::new();
            write_json(&j, &mut t);
            t
        };
        let tampered = Json::parse(&text.replace("\"count\":5", "\"count\":9")).unwrap();
        assert!(Sketch::validate_json(&tampered).is_err());
        let empty = Json::parse("{\"buckets\":[[2,1],[2,1]],\"count\":2}").unwrap();
        assert!(Sketch::validate_json(&empty).is_err(), "non-ascending buckets accepted");
        let huge = Json::parse("{\"buckets\":[[999999,1]],\"count\":1}").unwrap();
        assert!(Sketch::validate_json(&huge).is_err(), "out-of-range bucket accepted");
    }

    #[test]
    fn empty_sketch_is_well_behaved() {
        let s = Sketch::new();
        assert!(s.is_empty());
        assert_eq!(s.quantile(0.5), None);
        assert_eq!(s.median_mad(), None);
        assert_eq!(s.min(), None);
        Sketch::validate_json(&s.to_json()).unwrap();
        assert_eq!(Sketch::from_json(&s.to_json()).unwrap(), s);
    }
}
