//! Trace ingestion, schema validation, and run reports.
//!
//! Backs the `fedcore report` subcommand: load a JSONL trace
//! ([`load`] / [`Trace::from_text`]), validate every line against the
//! schema ([`Trace::check`] — version field, required keys per record
//! type, well-formed span nesting), and render a per-round phase
//! breakdown table, a critical-path/straggler-tail summary, and an SVG
//! timeline via [`crate::metrics::svg`].
//!
//! A trace file may hold several engine runs (the bench sweep traces
//! one per worker configuration): each run opens with a `run_start`
//! event, and the reporting views use the *last* run segment while
//! [`Trace::check`] validates all of them.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::ops::Range;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::sketch::Sketch;
use super::{Counter, Phase, MIN_SCHEMA_VERSION, SCHEMA_VERSION};
use crate::util::json::Json;

/// Anomaly band width for [`Trace::health_report`]: a client is
/// flagged `SLOW` when its mean train time exceeds the cohort sketch's
/// `median + ANOMALY_MAD_K · MAD`.
pub const ANOMALY_MAD_K: f64 = 3.0;

/// A parsed trace: one [`Json`] object per line, in file order.
#[derive(Clone, Debug)]
pub struct Trace {
    /// The records, one per trace line.
    pub records: Vec<Json>,
}

/// Read and parse a trace file.
pub fn load(path: impl AsRef<Path>) -> Result<Trace> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    Trace::from_text(&text).with_context(|| format!("parsing trace {}", path.display()))
}

/// One span, decoded from its record for the nesting/report passes.
struct Sp {
    line: usize,
    name: String,
    round: usize,
    w0: f64,
    w1: f64,
    v0: f64,
    v1: f64,
}

fn get_num(rec: &Json, line: usize, key: &str) -> Result<f64> {
    rec.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("line {line}: missing numeric field '{key}'"))
}

fn get_str<'a>(rec: &'a Json, line: usize, key: &str) -> Result<&'a str> {
    rec.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("line {line}: missing string field '{key}'"))
}

fn kind(rec: &Json) -> Option<&str> {
    rec.get("t").and_then(|v| v.as_str())
}

fn name_of(rec: &Json) -> Option<&str> {
    rec.get("name").and_then(|v| v.as_str())
}

impl Trace {
    /// Parse trace text: one JSON object per non-empty line.
    pub fn from_text(text: &str) -> Result<Trace> {
        let mut records = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let rec = Json::parse(line).map_err(|e| anyhow!("line {}: {e}", i + 1))?;
            records.push(rec);
        }
        Ok(Trace { records })
    }

    /// Validate every record against the schema: version field (the
    /// [`MIN_SCHEMA_VERSION`]`..=`[`SCHEMA_VERSION`] window — v1 traces
    /// still load), required keys per record type (including the v2
    /// `snapshot` body: client-row ordering and sketch encodings),
    /// ordered span bounds, known counter names, header-first, and
    /// well-formed span nesting (every lifecycle span wall-contained in
    /// its round span, phase wall-times summing to within the round's
    /// wall-time). Every rejection names the offending line. Returns
    /// the number of validated records.
    pub fn check(&self) -> Result<usize> {
        if self.records.is_empty() {
            bail!("empty trace: no records");
        }
        if kind(&self.records[0]) != Some("header") {
            bail!("line 1: first record must be the header");
        }
        for (i, rec) in self.records.iter().enumerate() {
            let line = i + 1;
            let v = get_num(rec, line, "v")?;
            // v2 is a pure superset of v1 (it adds `snapshot`), so the
            // reader accepts the whole window — v1 traces still load.
            if v.fract() != 0.0 || v < MIN_SCHEMA_VERSION as f64 || v > SCHEMA_VERSION as f64 {
                bail!(
                    "line {line}: schema version {v}, this reader expects \
                     {MIN_SCHEMA_VERSION}..={SCHEMA_VERSION}"
                );
            }
            match get_str(rec, line, "t")? {
                "header" => {
                    if i != 0 {
                        bail!("line {line}: header record past line 1");
                    }
                    get_str(rec, line, "source")?;
                    let prov = rec
                        .get("provenance")
                        .and_then(|p| p.as_obj())
                        .ok_or_else(|| anyhow!("line {line}: header missing provenance"))?;
                    for key in ["seed", "rounds", "scale", "git_sha", "rustc"] {
                        if !prov.contains_key(key) {
                            bail!("line {line}: provenance missing '{key}'");
                        }
                    }
                }
                "span" => {
                    let name = get_str(rec, line, "name")?;
                    if name.is_empty() {
                        bail!("line {line}: empty span name");
                    }
                    get_num(rec, line, "round")?;
                    let w0 = get_num(rec, line, "wall_start_ns")?;
                    let w1 = get_num(rec, line, "wall_end_ns")?;
                    if w1 < w0 {
                        bail!("line {line}: span '{name}' wall bounds reversed");
                    }
                    let v0 = get_num(rec, line, "virt_start")?;
                    let v1 = get_num(rec, line, "virt_end")?;
                    if !v0.is_finite() || !v1.is_finite() || v1 < v0 {
                        bail!("line {line}: span '{name}' virtual bounds malformed");
                    }
                }
                "event" => {
                    get_str(rec, line, "name")?;
                    get_num(rec, line, "round")?;
                }
                "counter" => {
                    let name = get_str(rec, line, "name")?;
                    if !Counter::ALL.iter().any(|c| c.name() == name) {
                        bail!("line {line}: unknown counter '{name}'");
                    }
                    get_num(rec, line, "round")?;
                    if get_num(rec, line, "value")? < 0.0 {
                        bail!("line {line}: negative counter value");
                    }
                }
                "warn" => {
                    get_str(rec, line, "key")?;
                    get_str(rec, line, "msg")?;
                }
                "mem" => {
                    get_num(rec, line, "round")?;
                    get_num(rec, line, "rss_pages")?;
                    get_num(rec, line, "rss_bytes")?;
                }
                "snapshot" => {
                    get_num(rec, line, "round")?;
                    get_num(rec, line, "rounds_observed")?;
                    let clients = rec.get("clients").and_then(|v| v.as_arr()).ok_or_else(
                        || anyhow!("line {line}: snapshot missing 'clients' array"),
                    )?;
                    // The emitter sorts by (score desc, id asc); hold
                    // readers of partial traces to the same contract.
                    let mut prev: Option<(u64, u64)> = None;
                    for c in clients {
                        for key in [
                            "id", "score_us", "err_us", "seen", "train_us", "bounded", "drops",
                            "stale", "warm", "builds",
                        ] {
                            let v = c.get(key).and_then(|v| v.as_f64()).ok_or_else(|| {
                                anyhow!("line {line}: snapshot client missing numeric '{key}'")
                            })?;
                            if v < 0.0 || v.fract() != 0.0 {
                                bail!(
                                    "line {line}: snapshot client '{key}' is not a \
                                     non-negative integer"
                                );
                            }
                        }
                        let score = get_num(c, line, "score_us")? as u64;
                        let id = get_num(c, line, "id")? as u64;
                        if let Some((ps, pid)) = prev {
                            if (score, std::cmp::Reverse(id)) > (ps, std::cmp::Reverse(pid)) {
                                bail!(
                                    "line {line}: snapshot clients not sorted by \
                                     (score desc, id asc)"
                                );
                            }
                        }
                        prev = Some((score, id));
                    }
                    let sketches = rec
                        .get("sketches")
                        .and_then(|v| v.as_obj())
                        .ok_or_else(|| anyhow!("line {line}: snapshot missing 'sketches'"))?;
                    for (name, j) in sketches {
                        Sketch::validate_json(j)
                            .map_err(|e| anyhow!("line {line}: sketch '{name}': {e}"))?;
                    }
                }
                other => bail!("line {line}: unknown record type '{other}'"),
            }
        }
        for seg in self.segments() {
            self.check_nesting(seg)?;
        }
        Ok(self.records.len())
    }

    /// Run segments: each opens with a `run_start` event. A trace with
    /// no markers is treated as one segment.
    pub fn segments(&self) -> Vec<Range<usize>> {
        let starts: Vec<usize> = self
            .records
            .iter()
            .enumerate()
            .filter(|(_, r)| kind(r) == Some("event") && name_of(r) == Some("run_start"))
            .map(|(i, _)| i)
            .collect();
        if starts.is_empty() {
            return vec![0..self.records.len()];
        }
        starts
            .iter()
            .enumerate()
            .map(|(k, &s)| s..starts.get(k + 1).copied().unwrap_or(self.records.len()))
            .collect()
    }

    fn spans_in(&self, seg: Range<usize>) -> Vec<Sp> {
        let base = seg.start;
        self.records[seg]
            .iter()
            .enumerate()
            .filter(|(_, r)| kind(r) == Some("span"))
            .filter_map(|(i, r)| {
                Some(Sp {
                    line: base + i + 1,
                    name: name_of(r)?.to_string(),
                    round: r.get("round")?.as_f64()? as usize,
                    w0: r.get("wall_start_ns")?.as_f64()?,
                    w1: r.get("wall_end_ns")?.as_f64()?,
                    v0: r.get("virt_start")?.as_f64()?,
                    v1: r.get("virt_end")?.as_f64()?,
                })
            })
            .collect()
    }

    /// Lifecycle spans must be wall-contained in their round span, and
    /// a round's phase wall-times must sum to within the round's own
    /// measured wall-time (they are disjoint nested sub-intervals).
    fn check_nesting(&self, seg: Range<usize>) -> Result<()> {
        let spans = self.spans_in(seg);
        let mut rounds: BTreeMap<usize, (f64, f64, usize)> = BTreeMap::new();
        for sp in spans.iter().filter(|s| s.name == Phase::Round.name()) {
            if rounds.insert(sp.round, (sp.w0, sp.w1, sp.line)).is_some() {
                bail!("line {}: duplicate round span for round {} in one run", sp.line, sp.round);
            }
        }
        let mut phase_sum: BTreeMap<usize, f64> = BTreeMap::new();
        let lifecycle: Vec<&str> = Phase::LIFECYCLE.iter().map(|p| p.name()).collect();
        for sp in spans.iter().filter(|s| lifecycle.contains(&s.name.as_str())) {
            let &(rw0, rw1, _) = rounds.get(&sp.round).ok_or_else(|| {
                anyhow!("line {}: '{}' span has no round {} span", sp.line, sp.name, sp.round)
            })?;
            if sp.w0 < rw0 || sp.w1 > rw1 {
                bail!(
                    "line {}: '{}' span escapes its round {} wall bounds",
                    sp.line,
                    sp.name,
                    sp.round
                );
            }
            *phase_sum.entry(sp.round).or_insert(0.0) += sp.w1 - sp.w0;
        }
        for (r, sum) in phase_sum {
            let (rw0, rw1, rline) = rounds[&r];
            if sum > rw1 - rw0 {
                bail!(
                    "line {rline}: round {r}: phase wall-times sum to {sum} ns > round span {} ns",
                    rw1 - rw0
                );
            }
        }
        Ok(())
    }

    fn last_segment_spans(&self) -> Vec<Sp> {
        let seg = self.segments().pop().unwrap_or(0..self.records.len());
        self.spans_in(seg)
    }

    /// Per-round phase breakdown of the last run segment: wall
    /// milliseconds per lifecycle phase, the phases' sum, the round's
    /// own measured wall-time, and the coverage ratio.
    pub fn phase_table(&self) -> String {
        let spans = self.last_segment_spans();
        let mut rounds: BTreeMap<usize, f64> = BTreeMap::new();
        let mut phases: BTreeMap<usize, [f64; 5]> = BTreeMap::new();
        for sp in &spans {
            if sp.name == Phase::Round.name() {
                rounds.insert(sp.round, (sp.w1 - sp.w0) / 1e6);
            } else if let Some(i) =
                Phase::LIFECYCLE.iter().position(|p| p.name() == sp.name.as_str())
            {
                phases.entry(sp.round).or_insert([0.0; 5])[i] += (sp.w1 - sp.w0) / 1e6;
            }
        }
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:>5} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>7}",
            "round", "select", "dispatch", "train", "aggregate", "eval", "phases", "total", "cover"
        );
        for (r, total) in &rounds {
            let p = phases.get(r).copied().unwrap_or_default();
            let sum: f64 = p.iter().sum();
            let cover = if *total > 0.0 { 100.0 * sum / total } else { 100.0 };
            let _ = writeln!(
                out,
                "{r:>5} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {sum:>10.3} {total:>10.3} \
                 {cover:>6.1}%",
                p[0], p[1], p[2], p[3], p[4]
            );
        }
        if rounds.is_empty() {
            out.push_str("(no round spans in the last run segment)\n");
        }
        out
    }

    /// Critical-path / straggler-tail summary of the last run segment,
    /// plus counter totals and the peak resident-set sample.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        if let Some(head) = self.records.first().filter(|r| kind(r) == Some("header")) {
            let prov = head.get("provenance");
            let field = |k: &str| -> String {
                prov.and_then(|p| p.get(k))
                    .map(|v| match v {
                        Json::Str(s) => s.clone(),
                        other => other.as_f64().map(|n| format!("{n}")).unwrap_or_default(),
                    })
                    .unwrap_or_else(|| "?".into())
            };
            let _ = writeln!(
                out,
                "trace: source={} seed={} git={} rustc={}",
                head.get("source").and_then(|v| v.as_str()).unwrap_or("?"),
                field("seed"),
                field("git_sha"),
                field("rustc"),
            );
        }
        let runs = self.segments().len();
        let spans = self.last_segment_spans();
        let round_wall: f64 = spans
            .iter()
            .filter(|s| s.name == Phase::Round.name())
            .map(|s| s.w1 - s.w0)
            .sum();
        let n_rounds = spans.iter().filter(|s| s.name == Phase::Round.name()).count();
        let _ = writeln!(
            out,
            "records: {}, runs: {runs}, last run: {n_rounds} rounds over {:.3} ms wall",
            self.records.len(),
            round_wall / 1e6
        );
        // Critical path: which lifecycle phase dominates round wall time.
        let mut dominant = ("-", 0.0f64);
        for p in Phase::LIFECYCLE {
            let t: f64 =
                spans.iter().filter(|s| s.name == p.name()).map(|s| s.w1 - s.w0).sum();
            let _ = writeln!(
                out,
                "  {:<10} {:>10.3} ms  ({:>5.1}% of round wall)",
                p.name(),
                t / 1e6,
                if round_wall > 0.0 { 100.0 * t / round_wall } else { 0.0 }
            );
            if t > dominant.1 {
                dominant = (p.name(), t);
            }
        }
        if round_wall > 0.0 {
            let _ = writeln!(
                out,
                "critical path: {} ({:.1}% of round wall time)",
                dominant.0,
                100.0 * dominant.1 / round_wall
            );
        }
        // Straggler tail, from the virtual-time job spans.
        let jobs: Vec<&Sp> = spans.iter().filter(|s| s.name == Phase::Job.name()).collect();
        if !jobs.is_empty() {
            let mut tails: BTreeMap<usize, f64> = BTreeMap::new();
            for j in &jobs {
                let t = tails.entry(j.round).or_insert(0.0);
                *t = t.max(j.v1);
            }
            let mean_tail = tails.values().sum::<f64>() / tails.len() as f64;
            let mean_job =
                jobs.iter().map(|j| j.v1 - j.v0).sum::<f64>() / jobs.len() as f64;
            let _ = writeln!(
                out,
                "straggler tail (virtual): mean batch makespan {:.3} s, mean job {:.3} s, \
                 tail ratio {:.2}",
                mean_tail,
                mean_job,
                if mean_job > 0.0 { mean_tail / mean_job } else { 0.0 }
            );
        }
        // Counter totals over the last run segment.
        let seg = self.segments().pop().unwrap_or(0..self.records.len());
        let mut totals: BTreeMap<&str, f64> = BTreeMap::new();
        for rec in &self.records[seg] {
            if kind(rec) == Some("counter") {
                if let (Some(name), Some(v)) =
                    (name_of(rec), rec.get("value").and_then(|v| v.as_f64()))
                {
                    if let Some(c) = Counter::ALL.iter().find(|c| c.name() == name) {
                        *totals.entry(c.name()).or_insert(0.0) += v;
                    }
                }
            }
        }
        if !totals.is_empty() {
            let parts: Vec<String> =
                totals.iter().map(|(k, v)| format!("{k}={}", *v as u64)).collect();
            let _ = writeln!(out, "counters: {}", parts.join(" "));
        }
        // Peak RSS over the whole trace.
        let peak = self
            .records
            .iter()
            .filter(|r| kind(r) == Some("mem"))
            .filter_map(|r| r.get("rss_bytes").and_then(|v| v.as_f64()))
            .fold(0.0f64, f64::max);
        if peak > 0.0 {
            let _ = writeln!(out, "peak rss: {:.1} MiB", peak / (1024.0 * 1024.0));
        }
        out
    }

    /// Render the last run segment as an SVG Gantt timeline: one lane
    /// per round, one colored bar per lifecycle phase.
    pub fn timeline_svg(&self, title: &str) -> String {
        let spans = self.last_segment_spans();
        let t0 = spans
            .iter()
            .filter(|s| s.name == Phase::Round.name())
            .map(|s| s.w0)
            .fold(f64::MAX, f64::min);
        let t0 = if t0 == f64::MAX { 0.0 } else { t0 };
        let mut rows: BTreeMap<usize, Vec<(f64, f64, usize)>> = BTreeMap::new();
        for sp in &spans {
            if let Some(i) = Phase::LIFECYCLE.iter().position(|p| p.name() == sp.name.as_str())
            {
                rows.entry(sp.round)
                    .or_default()
                    .push(((sp.w0 - t0) / 1e6, (sp.w1 - t0) / 1e6, i));
            }
        }
        let rows: Vec<(String, Vec<(f64, f64, usize)>)> =
            rows.into_iter().map(|(r, segs)| (format!("round {r}"), segs)).collect();
        let legend: Vec<&str> = Phase::LIFECYCLE.iter().map(|p| p.name()).collect();
        crate::metrics::svg::timeline(title, "wall time since run start (ms)", &rows, &legend)
    }

    /// Straggler-forensics report over the last run segment (schema v2
    /// `snapshot` records, `fedcore report --health`): cohort sketch
    /// quantiles, the top-K leaderboard with anomaly flags, and the
    /// per-round critical-path attribution table.
    pub fn health_report(&self) -> String {
        let seg = self.segments().pop().unwrap_or(0..self.records.len());
        let mut out = String::new();
        let Some(snap) =
            self.records[seg].iter().rev().find(|r| kind(r) == Some("snapshot"))
        else {
            out.push_str(
                "(no health snapshots in the last run segment — trace with health \
                 sampling on, e.g. `fedcore run --obs-trace t.jsonl --obs-health`)\n",
            );
            return out;
        };
        let round = snap.get("round").and_then(|v| v.as_f64()).unwrap_or(-1.0);
        let rounds_observed =
            snap.get("rounds_observed").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let _ = writeln!(
            out,
            "health snapshot: round {round:.0}, {rounds_observed:.0} rounds observed"
        );

        // Cohort-wide sketch quantiles; the train sketch also yields the
        // (median, MAD) anomaly band.
        let mut band: Option<(f64, f64)> = None;
        if let Some(sketches) = snap.get("sketches").and_then(|v| v.as_obj()) {
            out.push_str("cohort sketches (approximate quantiles):\n");
            for (name, j) in sketches {
                match Sketch::from_json(j) {
                    Ok(s) if !s.is_empty() => {
                        let q = |x: f64| s.quantile(x).unwrap_or(0.0);
                        let _ = writeln!(
                            out,
                            "  {name:<16} n={:<8} p50={:<9.3} p90={:<9.3} p99={:<9.3} max={:.3}",
                            s.count(),
                            q(0.5),
                            q(0.9),
                            q(0.99),
                            q(1.0)
                        );
                        if name == "train_s" {
                            band = s.median_mad();
                        }
                    }
                    Ok(_) => {
                        let _ = writeln!(out, "  {name:<16} (empty)");
                    }
                    Err(e) => {
                        let _ = writeln!(out, "  {name:<16} (unreadable: {e})");
                    }
                }
            }
        }
        if let Some((med, mad)) = band {
            let _ = writeln!(
                out,
                "anomaly band: train > {:.3} s (median {med:.3} + {ANOMALY_MAD_K}·MAD {mad:.3})",
                med + ANOMALY_MAD_K * mad
            );
        }

        // Leaderboard: the snapshot's client rows are already sorted by
        // (score desc, id asc).
        let clients = snap.get("clients").and_then(|v| v.as_arr()).unwrap_or(&[]);
        let _ = writeln!(out, "straggler leaderboard ({} clients tracked):", clients.len());
        let _ = writeln!(
            out,
            "{:>5} {:>8} {:>10} {:>8} {:>6} {:>8} {:>6} {:>6} {:>6} {:<6}",
            "rank", "client", "score_s", "±err_s", "seen", "bounded", "drops", "stale", "warm%",
            "flags"
        );
        for (rank, c) in clients.iter().enumerate() {
            let f = |k: &str| c.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
            let seen = f("seen");
            let drops = f("drops");
            let builds = f("builds");
            let contribs = (seen - drops).max(0.0);
            let mean_train = if contribs > 0.0 { f("train_us") / 1e6 / contribs } else { 0.0 };
            let mut flags = Vec::new();
            if let Some((med, mad)) = band {
                if contribs > 0.0 && mean_train > med + ANOMALY_MAD_K * mad {
                    flags.push("SLOW");
                }
            }
            if seen > 0.0 && drops * 2.0 > seen {
                flags.push("FLAKY");
            }
            let warm_pct =
                if builds > 0.0 { format!("{:.0}", 100.0 * f("warm") / builds) } else { "-".into() };
            let _ = writeln!(
                out,
                "{:>5} {:>8.0} {:>10.3} {:>8.3} {:>6.0} {:>8.0} {:>6.0} {:>6.0} {:>6} {:<6}",
                rank + 1,
                f("id"),
                f("score_us") / 1e6,
                f("err_us") / 1e6,
                seen,
                f("bounded"),
                drops,
                f("stale"),
                warm_pct,
                flags.join("+")
            );
        }

        out.push_str(&self.critical_path_table());
        out
    }

    /// Per-round critical-path attribution of the last run segment,
    /// from the `round_path` events health sampling emits: which client
    /// bounded the round, the server's quorum wait, the straggler-tail
    /// overhang past it, and the aggregation wall time.
    pub fn critical_path_table(&self) -> String {
        let seg = self.segments().pop().unwrap_or(0..self.records.len());
        let spans = self.spans_in(seg.clone());
        let mut agg_ms: BTreeMap<usize, f64> = BTreeMap::new();
        for sp in spans.iter().filter(|s| s.name == Phase::Aggregate.name()) {
            *agg_ms.entry(sp.round).or_insert(0.0) += (sp.w1 - sp.w0) / 1e6;
        }
        let paths: Vec<&Json> = self.records[seg]
            .iter()
            .filter(|r| kind(r) == Some("event") && name_of(r) == Some("round_path"))
            .collect();
        let mut out = String::new();
        if paths.is_empty() {
            out.push_str("(no round_path events — critical-path attribution unavailable)\n");
            return out;
        }
        out.push_str("critical path per round (virtual seconds; agg is wall ms):\n");
        let _ = writeln!(
            out,
            "{:>5} {:>8} {:>10} {:>10} {:>10} {:>10}",
            "round", "client", "client_s", "quorum_s", "overhang_s", "agg_ms"
        );
        let (mut tot_q, mut tot_o, mut tot_a) = (0.0f64, 0.0f64, 0.0f64);
        for p in &paths {
            let f = |k: &str| p.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
            let round = f("round");
            let quorum = f("quorum_s");
            let overhang = (f("tail_s") - quorum).max(0.0);
            let agg = agg_ms.get(&(round as usize)).copied().unwrap_or(0.0);
            tot_q += quorum;
            tot_o += overhang;
            tot_a += agg;
            let client = p.get("client").and_then(|v| v.as_f64());
            let client = match client {
                Some(c) if c >= 0.0 => format!("{c:.0}"),
                _ => "-".into(),
            };
            let _ = writeln!(
                out,
                "{round:>5.0} {client:>8} {:>10.3} {quorum:>10.3} {overhang:>10.3} {agg:>10.3}",
                f("client_s")
            );
        }
        let _ = writeln!(
            out,
            "decomposition: quorum wait {tot_q:.3} s, straggler overhang {tot_o:.3} s, \
             aggregation {tot_a:.3} ms wall"
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Record;

    fn lifecycle_round(records: &mut Vec<Json>, r: usize, base: u64) {
        let virt = r as f64 * 10.0;
        let spans = [
            (Phase::Round, base, base + 1000, virt, virt + 10.0),
            (Phase::Select, base, base + 100, virt, virt),
            (Phase::Dispatch, base + 100, base + 200, virt, virt),
            (Phase::Train, base + 200, base + 800, virt, virt + 10.0),
            (Phase::Aggregate, base + 800, base + 900, virt + 10.0, virt + 10.0),
            (Phase::Eval, base + 900, base + 1000, virt + 10.0, virt + 10.0),
        ];
        for (p, w0, w1, v0, v1) in spans {
            records.push(Record::span(p, r, (w0, w1), (v0, v1)).to_json());
        }
        records.push(Record::CounterVal { counter: Counter::Steals, round: r, value: 1 }.to_json());
        records.push(Record::Mem { round: r, rss_pages: 100, rss_bytes: 409600 }.to_json());
        records.push(
            Record::Span {
                phase: Phase::Job,
                round: r,
                wall_ns: (0, 0),
                virt_s: (0.0, 3.0),
                extra: vec![("kind", Json::Str("client".into())), ("worker", Json::Num(0.0))],
            }
            .to_json(),
        );
    }

    fn demo_trace() -> Trace {
        let mut records = vec![Record::Header {
            source: "engine",
            provenance: crate::util::bench::provenance(7, 2, 1.0),
        }
        .to_json()];
        records.push(
            Record::Event { name: "run_start", round: 0, fields: vec![] }.to_json(),
        );
        lifecycle_round(&mut records, 0, 0);
        lifecycle_round(&mut records, 1, 2000);
        Trace { records }
    }

    fn render(t: &Trace) -> String {
        let mut text = String::new();
        for r in &t.records {
            crate::util::json::write_json(r, &mut text);
            text.push('\n');
        }
        text
    }

    #[test]
    fn check_accepts_an_engine_shaped_trace() {
        let t = demo_trace();
        assert_eq!(t.check().unwrap(), t.records.len());
        // And survives a serialize → parse round trip.
        let t2 = Trace::from_text(&render(&t)).unwrap();
        assert_eq!(t2.check().unwrap(), t.records.len());
    }

    #[test]
    fn check_rejects_malformed_traces() {
        // Missing header.
        let mut t = demo_trace();
        t.records.remove(0);
        assert!(t.check().unwrap_err().to_string().contains("first record"));
        // Wrong schema version.
        let mut t = demo_trace();
        if let Json::Obj(m) = &mut t.records[2] {
            m.insert("v".into(), Json::Num(99.0));
        }
        assert!(t.check().unwrap_err().to_string().contains("schema version"));
        // Unknown counter name.
        let mut t = demo_trace();
        let bad = Record::CounterVal { counter: Counter::Steals, round: 0, value: 1 }.to_json();
        let Json::Obj(mut m) = bad else { unreachable!() };
        m.insert("name".into(), Json::Str("bogus".into()));
        t.records.push(Json::Obj(m));
        assert!(t.check().unwrap_err().to_string().contains("unknown counter"));
        // A lifecycle span escaping its round's wall bounds.
        let mut t = demo_trace();
        t.records.push(Record::span(Phase::Train, 1, (2000, 99999), (10.0, 20.0)).to_json());
        assert!(t.check().unwrap_err().to_string().contains("escapes"));
        // Reversed wall bounds.
        let mut t = demo_trace();
        t.records.push(Record::span(Phase::Eval, 0, (500, 400), (0.0, 0.0)).to_json());
        assert!(t.check().unwrap_err().to_string().contains("reversed"));
    }

    #[test]
    fn duplicate_rounds_are_fine_across_run_segments_only() {
        // Two runs, same round indexes: valid because run_start splits them.
        let mut t = demo_trace();
        t.records.push(Record::Event { name: "run_start", round: 0, fields: vec![] }.to_json());
        let n = t.records.len();
        lifecycle_round(&mut t.records, 0, 0);
        assert!(t.check().is_ok());
        // The same round span twice within one segment is an error.
        let dup = t.records[n].clone();
        t.records.push(dup);
        assert!(t.check().unwrap_err().to_string().contains("duplicate round span"));
    }

    #[test]
    fn phase_table_covers_the_full_round() {
        let t = demo_trace();
        let table = t.phase_table();
        let lines: Vec<&str> = table.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 rounds:\n{table}");
        assert!(lines[0].contains("aggregate"));
        // The demo rounds are fully covered by their phases.
        assert!(lines[1].contains("100.0%"), "{table}");
        assert!(lines[2].contains("100.0%"), "{table}");
    }

    #[test]
    fn summary_names_the_critical_path() {
        let s = demo_trace().summary();
        // train is 600 of 1000 ns per round in the demo trace.
        assert!(s.contains("critical path: train"), "{s}");
        assert!(s.contains("straggler tail"), "{s}");
        assert!(s.contains("steals=2"), "{s}");
        assert!(s.contains("peak rss"), "{s}");
    }

    #[test]
    fn timeline_svg_is_well_formed() {
        let svg = demo_trace().timeline_svg("demo");
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert!(svg.contains("round 0") && svg.contains("round 1"));
        assert!(svg.contains("select") && svg.contains("eval"));
    }

    #[test]
    fn from_text_rejects_garbage_lines() {
        assert!(Trace::from_text("{\"v\":1}\nnot json\n").is_err());
        assert!(Trace::from_text("").unwrap().records.is_empty());
    }

    #[test]
    fn v1_traces_still_load() {
        // A v1 trace is exactly a v2 trace without snapshots; rewriting
        // the version field must keep the checker green (migration
        // note in docs/observability.md).
        let text = render(&demo_trace()).replace("\"v\":2", "\"v\":1");
        let t = Trace::from_text(&text).unwrap();
        assert_eq!(t.check().unwrap(), t.records.len());
    }

    /// The satellite rejection corpus: every malformed shape is
    /// rejected *and* the error names the offending line.
    #[test]
    fn malformed_trace_corpus_rejects_with_line_numbers() {
        let base = render(&demo_trace());
        let n_lines = base.lines().count();

        // 1. Truncated line: the file was cut mid-record (a crashed
        //    writer without the BufWriter drop-flush).
        let truncated = &base[..base.len() - 25];
        let err = Trace::from_text(truncated).unwrap_err().to_string();
        assert!(err.contains(&format!("line {n_lines}")), "truncation: {err}");

        // 2. Wrong schema version on one record (line 5).
        let lines: Vec<&str> = base.lines().collect();
        let mut doctored: Vec<String> = lines.iter().map(|l| l.to_string()).collect();
        doctored[4] = doctored[4].replace("\"v\":2", "\"v\":7");
        let t = Trace::from_text(&doctored.join("\n")).unwrap();
        let err = t.check().unwrap_err().to_string();
        assert!(err.contains("line 5") && err.contains("schema version"), "{err}");

        // 3. Span end-before-start.
        let mut t = demo_trace();
        t.records.push(Record::span(Phase::Eval, 1, (900, 200), (0.0, 0.0)).to_json());
        let err = t.check().unwrap_err().to_string();
        assert!(
            err.contains(&format!("line {}", n_lines + 1)) && err.contains("reversed"),
            "{err}"
        );

        // 4. Counter with an unknown key.
        let mut t = demo_trace();
        let counter =
            Record::CounterVal { counter: Counter::Dropped, round: 0, value: 2 }.to_json();
        let Json::Obj(mut m) = counter else { unreachable!() };
        m.insert("name".into(), Json::Str("not_a_counter".into()));
        t.records.push(Json::Obj(m));
        let err = t.check().unwrap_err().to_string();
        assert!(
            err.contains(&format!("line {}", n_lines + 1)) && err.contains("unknown counter"),
            "{err}"
        );

        // 5. Interleaved run segments: a lifecycle span after a new
        //    run_start whose round span lives in the *previous*
        //    segment — the segment split makes it an orphan.
        let mut t = demo_trace();
        t.records.push(Record::Event { name: "run_start", round: 0, fields: vec![] }.to_json());
        t.records.push(Record::span(Phase::Train, 0, (10, 20), (0.0, 1.0)).to_json());
        let err = t.check().unwrap_err().to_string();
        assert!(
            err.contains(&format!("line {}", n_lines + 2)) && err.contains("no round 0 span"),
            "{err}"
        );

        // 6. A snapshot with a corrupted sketch encoding.
        let mut t = demo_trace();
        let ledger = crate::obs::health::HealthLedger::new(Default::default());
        let snap = ledger.snapshot(1).to_json();
        let Json::Obj(mut m) = snap else { unreachable!() };
        m.insert(
            "sketches".into(),
            Json::parse("{\"train_s\":{\"buckets\":[[5,2]],\"count\":1}}").unwrap(),
        );
        t.records.push(Json::Obj(m));
        let err = t.check().unwrap_err().to_string();
        assert!(
            err.contains(&format!("line {}", n_lines + 1)) && err.contains("train_s"),
            "{err}"
        );
    }

    #[test]
    fn snapshot_records_validate_and_health_report_renders() {
        use crate::obs::health::{HealthConfig, HealthLedger};
        let mut ledger = HealthLedger::new(HealthConfig { top_k: 8, snapshot_every: 1 });
        for r in 0..4 {
            for c in 0..20usize {
                let secs = if c == 13 { 40.0 } else { 1.0 };
                ledger.observe_train(c, secs);
            }
            ledger.observe_drop(19, 30.0, Some(3.0));
            ledger.observe_stale(2, 1 + r % 2);
            ledger.observe_round_end(Some(13), Some(40.0));
        }
        let mut t = demo_trace();
        t.records.push(
            Record::Event {
                name: "round_path",
                round: 1,
                fields: vec![
                    ("client", Json::Num(13.0)),
                    ("client_s", Json::Num(40.0)),
                    ("quorum_s", Json::Num(40.0)),
                    ("tail_s", Json::Num(46.5)),
                ],
            }
            .to_json(),
        );
        t.records.push(ledger.snapshot(3).to_json());
        assert_eq!(t.check().unwrap(), t.records.len());
        // And the round trip through text survives.
        let t2 = Trace::from_text(&render(&t)).unwrap();
        t2.check().unwrap();

        let report = t.health_report();
        assert!(report.contains("straggler leaderboard"), "{report}");
        // Client 13 leads the leaderboard and is anomaly-flagged: mean
        // train 40 s vs a cohort median of ~1 s.
        let lead = report.lines().find(|l| l.trim_start().starts_with("1 ")).unwrap();
        assert!(lead.contains("13"), "{report}");
        assert!(lead.contains("SLOW"), "{report}");
        // Critical-path attribution found the round_path event.
        assert!(report.contains("critical path per round"), "{report}");
        assert!(report.contains("decomposition: quorum wait"), "{report}");
        // Overhang = tail 46.5 − quorum 40.
        assert!(report.contains("6.500"), "{report}");
    }

    #[test]
    fn health_report_without_snapshots_says_so() {
        let report = demo_trace().health_report();
        assert!(report.contains("no health snapshots"), "{report}");
    }

    /// Satellite: a dropped (never explicitly flushed) buffered sink
    /// must leave a complete, `--check`-clean trace behind.
    #[test]
    fn dropped_sink_leaves_a_check_clean_trace() {
        use crate::obs::health::{HealthConfig, HealthLedger};
        use crate::obs::{Jsonl, Recorder as _};
        let path = std::env::temp_dir()
            .join(format!("fedcore_obs_dropflush_{}.jsonl", std::process::id()));
        let sink =
            Jsonl::create(&path, "engine", crate::util::bench::provenance(3, 60, 1.0)).unwrap();
        sink.record(&Record::Event { name: "run_start", round: 0, fields: vec![] });
        let mut ledger = HealthLedger::new(HealthConfig { top_k: 16, snapshot_every: 8 });
        for r in 0..60usize {
            let base = r as u64 * 1000;
            sink.record(&Record::span(Phase::Round, r, (base, base + 1000), (0.0, 1.0)));
            sink.record(&Record::span(Phase::Train, r, (base, base + 700), (0.0, 1.0)));
            sink.record(&Record::span(Phase::Aggregate, r, (base + 700, base + 900), (1.0, 1.0)));
            for c in 0..10usize {
                ledger.observe_train(c, 1.0 + c as f64);
            }
            ledger.observe_round_end(Some(9), Some(10.0));
            if ledger.snapshot_due(r, 60) {
                sink.record(&ledger.snapshot(r));
            }
        }
        // No explicit flush: drop must push the buffered tail out.
        drop(sink);
        let t = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        t.check().unwrap();
        assert!(t.health_report().contains("straggler leaderboard"));
    }
}
