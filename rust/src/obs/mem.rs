//! Process resident-set sampling for per-round peak-RSS records.
//!
//! Reads `/proc/self/statm` (Linux). On platforms without procfs the
//! read fails and [`sample`] returns `None` — observability degrades
//! gracefully instead of gating the build on an OS probe, and the
//! engine simply emits no `mem` records. Samples feed the trace only
//! (determinism rule 7): RSS never influences the run, and the
//! trace-replay harness scrubs `mem` records before comparing.

/// One resident-set observation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemSample {
    /// Resident pages (`statm` field 2).
    pub pages: u64,
    /// Resident bytes, assuming the conventional 4 KiB page — `statm`
    /// does not report the page size, and a sysconf probe would be the
    /// only libc dependency in the crate.
    pub bytes: u64,
}

/// Assumed page size for the pages→bytes conversion (see
/// [`MemSample::bytes`]).
pub const PAGE_BYTES: u64 = 4096;

/// Sample the process's current resident set. `None` where
/// `/proc/self/statm` is unreadable (non-Linux, restricted procfs) or
/// nonsensical — the engine then *skips* the `mem` record rather than
/// logging a zero that would read as "no memory used". The first
/// failure emits one rate-limited [`super::warn_stderr`]-style notice
/// so a silently mem-less trace is explainable.
pub fn sample() -> Option<MemSample> {
    let s = sample_path("/proc/self/statm");
    if s.is_none() {
        // Once per process: `mem` records will be absent, say why.
        if super::warn_gate("mem_sample_unavailable", 1) == super::WarnGate::Emit {
            eprintln!("[obs] /proc/self/statm unreadable; mem records disabled for this run");
        }
    }
    s
}

/// The testable core of [`sample`]: parse resident pages from a
/// `statm`-format file. `None` on read failure, parse failure, or a
/// zero page count (a live process is never zero-resident; a `0` here
/// means the probe, not the process, is broken).
fn sample_path(path: &str) -> Option<MemSample> {
    let text = std::fs::read_to_string(path).ok()?;
    let pages: u64 = text.split_whitespace().nth(1)?.parse().ok()?;
    if pages == 0 {
        return None;
    }
    Some(MemSample { pages, bytes: pages.saturating_mul(PAGE_BYTES) })
}

/// Fold the current sample into a running per-round peak (keeps the
/// larger resident set; no-op where sampling is unavailable).
pub fn fold_peak(peak: &mut Option<MemSample>) {
    if let Some(s) = sample() {
        if peak.map_or(true, |p| s.bytes > p.bytes) {
            *peak = Some(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_reports_resident_memory_on_linux() {
        match sample() {
            Some(s) => {
                // A live test process is resident: at least one page.
                assert!(s.pages > 0);
                assert_eq!(s.bytes, s.pages * PAGE_BYTES);
            }
            // Graceful no-op path (non-Linux or masked procfs).
            None => {
                let statm = std::path::Path::new("/proc/self/statm");
                assert!(!cfg!(target_os = "linux") || !statm.exists());
            }
        }
    }

    #[test]
    fn bogus_path_yields_none_not_zero() {
        // Unreadable path: no sample (and no zero-page MemSample).
        assert_eq!(sample_path("/definitely/not/a/real/statm"), None);

        // Readable but malformed / zero-resident inputs are rejected too.
        let dir = std::env::temp_dir();
        let write = |tag: &str, body: &str| {
            let p = dir.join(format!("fedcore_statm_{}_{tag}", std::process::id()));
            std::fs::write(&p, body).unwrap();
            p
        };
        let garbage = write("garbage", "not numbers at all\n");
        assert_eq!(sample_path(garbage.to_str().unwrap()), None);
        let short = write("short", "1234\n");
        assert_eq!(sample_path(short.to_str().unwrap()), None);
        let zero = write("zero", "500 0 40 1 0 300 0\n");
        assert_eq!(sample_path(zero.to_str().unwrap()), None, "zero pages must not sample");
        let good = write("good", "500 123 40 1 0 300 0\n");
        assert_eq!(
            sample_path(good.to_str().unwrap()),
            Some(MemSample { pages: 123, bytes: 123 * PAGE_BYTES })
        );
        for p in [garbage, short, zero, good] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn fold_peak_keeps_the_larger_sample() {
        let big = MemSample { pages: u64::MAX / PAGE_BYTES, bytes: u64::MAX };
        let mut peak = Some(big);
        fold_peak(&mut peak);
        // Whatever the sampler said, nothing beats the saturated peak.
        assert_eq!(peak, Some(big));

        let mut fresh = None;
        fold_peak(&mut fresh);
        // On Linux the first fold seeds the peak; elsewhere it stays None.
        assert_eq!(fresh.is_some(), sample().is_some());
    }
}
