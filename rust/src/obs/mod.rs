//! Structured observability: spans, events, counters, and run reports.
//!
//! The engine's virtual-time outcomes ([`crate::metrics::RoundRecord`],
//! `BENCH_*.json`) say nothing about where *real* time and memory go
//! inside a round. This module adds a zero-dependency telemetry spine:
//! a [`Recorder`] sink trait with a [`Null`] implementation (the
//! default — no allocation, no clock reads recorded) and a [`Jsonl`]
//! sink that appends one schema-versioned JSON object per line
//! ([`SCHEMA_VERSION`], see `docs/observability.md` for the schema).
//!
//! Record taxonomy:
//!
//! - **span** — a named [`Phase`] with *both* virtual-time bounds
//!   (simulated seconds, bit-replayable from the seed) and monotonic
//!   wall-time bounds (nanoseconds since the sink's epoch). The engine
//!   emits round-lifecycle spans (`select`/`dispatch`/`train`/
//!   `aggregate`/`eval`, all wall-nested inside the round span) and the
//!   CLI appends a post-run `checkpoint` span; [`emit_schedule`]
//!   translates the executor's [`crate::exec::ScheduleTrace`] into
//!   per-job and per-worker spans (virtual-time only).
//! - **event** — a point occurrence with numeric/string fields:
//!   staleness folds and discards ([`crate::exec::Overlapped`]),
//!   scenario churn dropouts, aggregation rejection/clipping
//!   ([`crate::agg`]), and one `run_start` marker per engine run so a
//!   multi-run trace file stays segmentable.
//! - **counter** — a per-round value from the typed [`Counter`]
//!   registry; the same tallies the [`crate::metrics::RoundRecord`]
//!   columns keep, emitted at their computation sites.
//! - **warn** — a rate-limited diagnostic (see [`warn`]): what used to
//!   be ad-hoc `eprintln!` lines, now structured and capped.
//! - **mem** — per-round peak resident-set sample from
//!   [`mem::sample`] (`/proc/self/statm`; a graceful no-op elsewhere).
//! - **snapshot** (schema v2) — a periodic straggler-forensics dump
//!   from the [`health::HealthLedger`]: the top-K client health table
//!   plus the cohort-wide [`sketch::Sketch`] quantile sketches. Enabled
//!   by [`ObsConfig::Jsonl`]'s `health` knob; rendered by
//!   `fedcore report --health`.
//!
//! **Determinism rule 7 (write-only observability).** Recording must
//! never influence the run: a `Jsonl`-traced run — with or without
//! health sampling — is bit-identical to a `Null`-recorder run in every
//! model output (params, round records, CSV, checkpoint bytes).
//! Wall-clock reads flow *into* the trace and nowhere else. Enforced by
//! `rust/tests/proptest_obs.rs`.

pub mod health;
pub mod mem;
pub mod report;
pub mod sketch;

use std::collections::HashMap;
use std::io::Write as _;
use std::path::Path;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{write_json, Json};

/// Trace schema version stamped into every record's `"v"` field; bump
/// on any breaking change to record shapes or required keys.
///
/// History: **v1** (PR 6) header/span/event/counter/warn/mem; **v2**
/// adds the `snapshot` record (health ledger + sketches). v2 is a pure
/// superset, so the reader ([`report::Trace::check`]) accepts both —
/// v1 traces still load.
pub const SCHEMA_VERSION: u32 = 2;

/// Oldest schema version [`report::Trace::check`] still accepts.
pub const MIN_SCHEMA_VERSION: u32 = 1;

/// Max stderr lines per diagnostic key per process before [`warn`]
/// suppresses further output (structured records keep flowing).
pub const WARN_LIMIT: u64 = 4096;

/// Named phases a span can describe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// One whole engine round (wall-brackets its lifecycle phases).
    Round,
    /// Client selection for the round.
    Select,
    /// Job construction + schedule planning.
    Dispatch,
    /// Client execution (`run_clients`) and outcome stitching.
    Train,
    /// Staleness folding + server aggregation.
    Aggregate,
    /// Test-set evaluation (only on eval rounds).
    Eval,
    /// Post-run checkpoint serialization (appended by the CLI).
    Checkpoint,
    /// Adaptive coreset construction (distance matrix + k-medoids) on
    /// the round's workers. A non-lifecycle overlay of the Train window
    /// — emitted only on rounds with at least one coreset client.
    CoresetBuild,
    /// One dispatched job, from the executor's schedule ledger
    /// (virtual-time bounds only).
    Job,
    /// One worker's busy interval within a dispatch batch
    /// (virtual-time bounds only).
    Worker,
}

impl Phase {
    /// The engine round-lifecycle phases the report tabulates, in
    /// emission order. Each is wall-nested inside its round span.
    pub const LIFECYCLE: [Phase; 5] =
        [Phase::Select, Phase::Dispatch, Phase::Train, Phase::Aggregate, Phase::Eval];

    /// Canonical span name written to the trace.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Round => "round",
            Phase::Select => "select",
            Phase::Dispatch => "dispatch",
            Phase::Train => "train",
            Phase::Aggregate => "aggregate",
            Phase::Eval => "eval",
            Phase::Checkpoint => "checkpoint",
            Phase::CoresetBuild => "coreset_build",
            Phase::Job => "job",
            Phase::Worker => "worker",
        }
    }
}

/// Typed registry of the per-round tallies the engine emits as counter
/// records — the same quantities the [`crate::metrics::RoundRecord`]
/// columns keep (the columns stay; the registry replaces scattered
/// ad-hoc naming at the emission sites).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Clients past the τ deadline this round.
    Dropped,
    /// Clients lost to scenario churn before dispatch.
    ChurnDropped,
    /// Delayed updates folded into this round's aggregate.
    StaleFolded,
    /// Delayed updates discarded past the staleness bound.
    StaleDiscarded,
    /// Client updates rejected by the robust aggregator.
    AggRejected,
    /// Client updates clipped by the norm gate.
    AggClipped,
    /// Updates held in the server buffer after this round.
    AggBuffered,
    /// Jobs that ran away from their round-robin home worker.
    Steals,
    /// Selected clients that trained on a coreset this round.
    CoresetClients,
    /// Coreset clients whose k-medoids solve warm-started from cached
    /// medoids (non-refresh rounds under `coreset_refresh > 1`).
    CoresetWarm,
    /// Rounds whose FLANP active prefix widened after a loss stall
    /// (`--select flanp`; 0 or 1 per round).
    CohortWidened,
    /// Past-staleness updates folded into the distillation correction
    /// instead of being discarded (`--distill-weight > 0`).
    Distilled,
}

impl Counter {
    /// Every counter, in emission order.
    pub const ALL: [Counter; 12] = [
        Counter::Dropped,
        Counter::ChurnDropped,
        Counter::StaleFolded,
        Counter::StaleDiscarded,
        Counter::AggRejected,
        Counter::AggClipped,
        Counter::AggBuffered,
        Counter::Steals,
        Counter::CoresetClients,
        Counter::CoresetWarm,
        Counter::CohortWidened,
        Counter::Distilled,
    ];

    /// Canonical counter name written to the trace.
    pub fn name(self) -> &'static str {
        match self {
            Counter::Dropped => "dropped",
            Counter::ChurnDropped => "churn_dropped",
            Counter::StaleFolded => "stale_folded",
            Counter::StaleDiscarded => "stale_discarded",
            Counter::AggRejected => "agg_rejected",
            Counter::AggClipped => "agg_clipped",
            Counter::AggBuffered => "agg_buffered",
            Counter::Steals => "steals",
            Counter::CoresetClients => "coreset_clients",
            Counter::CoresetWarm => "coreset_warm",
            Counter::CohortWidened => "cohort_widened",
            Counter::Distilled => "distilled",
        }
    }
}

/// One trace record; serialized as a single JSON object per line with a
/// `"t"` discriminant and the [`SCHEMA_VERSION`] in `"v"`.
#[derive(Clone, Debug)]
pub enum Record {
    /// First line of a trace file: schema version, producing source
    /// (`"engine"` / `"bench"`), and the workload provenance stamp
    /// ([`crate::util::bench::provenance`]).
    Header {
        /// Who produced the trace.
        source: &'static str,
        /// `{seed, rounds, scale, git_sha, rustc}` workload identity.
        provenance: Json,
    },
    /// A named phase with wall-time and virtual-time bounds.
    Span {
        /// Which phase this span measures.
        phase: Phase,
        /// Engine round index (the CLI's post-run checkpoint span uses
        /// `rounds`, one past the last round).
        round: usize,
        /// Monotonic (start, end) nanoseconds since the sink's epoch;
        /// `(0, 0)` for virtual-only spans (jobs, workers).
        wall_ns: (u64, u64),
        /// Simulated (start, end) seconds.
        virt_s: (f64, f64),
        /// Extra keys flattened into the record (must not collide with
        /// the reserved span keys).
        extra: Vec<(&'static str, Json)>,
    },
    /// A point occurrence with arbitrary named fields.
    Event {
        /// Event name (e.g. `stale_fold`, `churn_drop`, `run_start`).
        name: &'static str,
        /// Engine round index.
        round: usize,
        /// Extra keys flattened into the record.
        fields: Vec<(&'static str, Json)>,
    },
    /// One per-round value from the [`Counter`] registry.
    CounterVal {
        /// Which counter.
        counter: Counter,
        /// Engine round index.
        round: usize,
        /// The tally.
        value: u64,
    },
    /// A rate-limited diagnostic (structured twin of the stderr line).
    Warn {
        /// Stable diagnostic key (also the rate-limit bucket).
        key: &'static str,
        /// Round the diagnostic refers to, when there is one.
        round: Option<usize>,
        /// The human-readable message.
        msg: String,
    },
    /// Per-round peak resident-set sample (Linux only; never emitted
    /// where [`mem::sample`] returns `None`).
    Mem {
        /// Engine round index.
        round: usize,
        /// Peak resident pages observed during the round.
        rss_pages: u64,
        /// The same, scaled to bytes.
        rss_bytes: u64,
    },
    /// Periodic straggler-forensics dump from the
    /// [`health::HealthLedger`] (schema v2): the sorted top-K client
    /// table under `"clients"` and the quantile sketches under
    /// `"sketches"` (see `docs/observability.md` for the field table).
    Snapshot {
        /// Engine round index the snapshot closes.
        round: usize,
        /// Snapshot body, flattened into the record
        /// (`clients`/`sketches`/`rounds_observed`/`top_k`).
        fields: Vec<(&'static str, Json)>,
    },
}

/// Non-finite values would serialize as invalid JSON; clamp defensively
/// (simulated times are finite by construction).
fn num(v: f64) -> Json {
    Json::Num(if v.is_finite() { v } else { 0.0 })
}

impl Record {
    /// Shorthand for a lifecycle span with no extra fields.
    pub fn span(phase: Phase, round: usize, wall_ns: (u64, u64), virt_s: (f64, f64)) -> Record {
        Record::Span { phase, round, wall_ns, virt_s, extra: Vec::new() }
    }

    /// Serialize to the one-line JSON object form.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("v".to_string(), Json::Num(SCHEMA_VERSION as f64));
        match self {
            Record::Header { source, provenance } => {
                m.insert("t".into(), Json::Str("header".into()));
                m.insert("source".into(), Json::Str(source.to_string()));
                m.insert("provenance".into(), provenance.clone());
            }
            Record::Span { phase, round, wall_ns, virt_s, extra } => {
                m.insert("t".into(), Json::Str("span".into()));
                m.insert("name".into(), Json::Str(phase.name().into()));
                m.insert("round".into(), Json::Num(*round as f64));
                m.insert("wall_start_ns".into(), Json::Num(wall_ns.0 as f64));
                m.insert("wall_end_ns".into(), Json::Num(wall_ns.1 as f64));
                m.insert("virt_start".into(), num(virt_s.0));
                m.insert("virt_end".into(), num(virt_s.1));
                for (k, v) in extra {
                    m.insert(k.to_string(), v.clone());
                }
            }
            Record::Event { name, round, fields } => {
                m.insert("t".into(), Json::Str("event".into()));
                m.insert("name".into(), Json::Str(name.to_string()));
                m.insert("round".into(), Json::Num(*round as f64));
                for (k, v) in fields {
                    m.insert(k.to_string(), v.clone());
                }
            }
            Record::CounterVal { counter, round, value } => {
                m.insert("t".into(), Json::Str("counter".into()));
                m.insert("name".into(), Json::Str(counter.name().into()));
                m.insert("round".into(), Json::Num(*round as f64));
                m.insert("value".into(), Json::Num(*value as f64));
            }
            Record::Warn { key, round, msg } => {
                m.insert("t".into(), Json::Str("warn".into()));
                m.insert("key".into(), Json::Str(key.to_string()));
                if let Some(r) = round {
                    m.insert("round".into(), Json::Num(*r as f64));
                }
                m.insert("msg".into(), Json::Str(msg.clone()));
            }
            Record::Mem { round, rss_pages, rss_bytes } => {
                m.insert("t".into(), Json::Str("mem".into()));
                m.insert("round".into(), Json::Num(*round as f64));
                m.insert("rss_pages".into(), Json::Num(*rss_pages as f64));
                m.insert("rss_bytes".into(), Json::Num(*rss_bytes as f64));
            }
            Record::Snapshot { round, fields } => {
                m.insert("t".into(), Json::Str("snapshot".into()));
                m.insert("round".into(), Json::Num(*round as f64));
                for (k, v) in fields {
                    m.insert(k.to_string(), v.clone());
                }
            }
        }
        Json::Obj(m)
    }
}

/// A write-only trace sink. Implementations must uphold determinism
/// rule 7: recording never feeds back into the run — no retries that
/// block the round, no state the engine can observe. IO failures after
/// sink creation are swallowed, never surfaced to the training loop.
pub trait Recorder: Send + Sync {
    /// Is this sink recording? Hot paths use this to skip record
    /// assembly entirely (`false` for [`Null`]).
    fn enabled(&self) -> bool;

    /// Monotonic nanoseconds since the sink's epoch; `0` for [`Null`]
    /// (the one clock the untraced path never reads).
    fn now_ns(&self) -> u64;

    /// Write one record (no-op for [`Null`]).
    fn record(&self, rec: &Record);

    /// Push buffered records to durable storage (no-op by default).
    /// The engine calls this once at end of run, and the CLI relies on
    /// it before reopening the trace with [`Jsonl::append`] — a
    /// buffered sink that skipped this could interleave its tail with
    /// the appended records. Failures are swallowed (rule 7).
    fn flush(&self) {}
}

/// The default sink: records nothing, reads no clock.
#[derive(Clone, Copy, Debug, Default)]
pub struct Null;

impl Recorder for Null {
    fn enabled(&self) -> bool {
        false
    }

    fn now_ns(&self) -> u64 {
        0
    }

    fn record(&self, _rec: &Record) {}
}

/// JSONL trace sink: one schema-versioned JSON object per line, header
/// first. Interior mutability (`&self` recording) like the executor's
/// `TraceRecorder`. Records go through a [`std::io::BufWriter`] — one
/// tiny syscall per record was measurable drag on job/worker span
/// emission — which flushes on drop, and [`Recorder::flush`] flushes
/// explicitly so the CLI's post-run [`Jsonl::append`] handle (the
/// checkpoint span) never interleaves with a buffered tail.
#[derive(Debug)]
pub struct Jsonl {
    epoch: Instant,
    file: Mutex<std::io::BufWriter<std::fs::File>>,
}

impl Jsonl {
    /// Create (truncate) a trace file and write its header record.
    /// `provenance` is the [`crate::util::bench::provenance`] stamp.
    pub fn create(path: impl AsRef<Path>, source: &'static str, provenance: Json) -> Result<Jsonl> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating trace dir for {}", path.display()))?;
            }
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        let sink = Jsonl { epoch: Instant::now(), file: Mutex::new(std::io::BufWriter::new(file)) };
        sink.record(&Record::Header { source, provenance });
        Ok(sink)
    }

    /// Open an existing trace for appending (no header). The epoch
    /// restarts, so appended wall bounds are relative to this handle's
    /// own start — post-run records only (they are exempt from the
    /// report's round-nesting check).
    pub fn append(path: impl AsRef<Path>) -> Result<Jsonl> {
        let path = path.as_ref();
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("appending to trace file {}", path.display()))?;
        Ok(Jsonl { epoch: Instant::now(), file: Mutex::new(std::io::BufWriter::new(file)) })
    }
}

impl Recorder for Jsonl {
    fn enabled(&self) -> bool {
        true
    }

    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn record(&self, rec: &Record) {
        let mut line = String::new();
        write_json(&rec.to_json(), &mut line);
        line.push('\n');
        let mut file = self.file.lock().expect("trace sink poisoned");
        // Write-only contract: a full disk must not fail the run.
        let _ = file.write_all(line.as_bytes());
    }

    fn flush(&self) {
        let mut file = self.file.lock().expect("trace sink poisoned");
        let _ = file.flush();
    }
}

/// Declarative observability config carried in
/// [`crate::fl::RunConfig`]; [`ObsConfig::build`] turns it into the
/// live sink (the [`crate::agg::AggPolicy`] pattern).
#[derive(Clone, Debug, PartialEq, Default)]
pub enum ObsConfig {
    /// No tracing (the [`Null`] recorder).
    #[default]
    Off,
    /// JSONL trace sink.
    Jsonl {
        /// Trace file path (created/truncated at engine build).
        path: String,
        /// Workload scale stamped into the header provenance (the CLI
        /// passes its resolved scale; engine-only callers use `1.0`).
        scale: f64,
        /// When `Some`, the engine also runs a per-client
        /// [`health::HealthLedger`] and emits periodic `snapshot`
        /// records (schema v2 straggler forensics).
        health: Option<health::HealthConfig>,
    },
}

impl ObsConfig {
    /// Build the recorder. `seed`/`rounds` feed the provenance stamp
    /// in the trace header.
    pub fn build(&self, seed: u64, rounds: usize) -> Result<std::sync::Arc<dyn Recorder>> {
        match self {
            ObsConfig::Off => Ok(std::sync::Arc::new(Null)),
            ObsConfig::Jsonl { path, scale, .. } => {
                let prov = crate::util::bench::provenance(seed, rounds, *scale);
                Ok(std::sync::Arc::new(Jsonl::create(path, "engine", prov)?))
            }
        }
    }

    /// The trace path, when tracing is on.
    pub fn path(&self) -> Option<&str> {
        match self {
            ObsConfig::Off => None,
            ObsConfig::Jsonl { path, .. } => Some(path),
        }
    }

    /// The health-ledger knobs, when health sampling is on.
    pub fn health(&self) -> Option<&health::HealthConfig> {
        match self {
            ObsConfig::Off => None,
            ObsConfig::Jsonl { health, .. } => health.as_ref(),
        }
    }
}

/// How the rate limiter disposed of one diagnostic line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum WarnGate {
    /// Under the cap: print it.
    Emit,
    /// First line over the cap: print the suppression notice instead.
    Notice,
    /// Past the cap: drop silently.
    Suppressed,
}

fn warn_counts() -> &'static Mutex<HashMap<String, u64>> {
    static COUNTS: OnceLock<Mutex<HashMap<String, u64>>> = OnceLock::new();
    COUNTS.get_or_init(|| Mutex::new(HashMap::new()))
}

pub(crate) fn warn_gate(key: &str, limit: u64) -> WarnGate {
    let mut counts = warn_counts().lock().expect("warn limiter poisoned");
    let n = counts.entry(key.to_string()).or_insert(0);
    *n += 1;
    if *n <= limit {
        WarnGate::Emit
    } else if *n == limit + 1 {
        WarnGate::Notice
    } else {
        WarnGate::Suppressed
    }
}

/// The single diagnostic API: print `msg` to stderr, rate-limited to
/// [`WARN_LIMIT`] lines per `key` per process (one suppression notice,
/// then silence), and mirror it as a structured warn record when the
/// sink is recording (records are *not* rate-limited — the trace stays
/// complete). Replaces the ad-hoc `eprintln!` diagnostics in the
/// engine and experiment harness.
pub fn warn(rec: &dyn Recorder, key: &'static str, round: Option<usize>, msg: &str) {
    match warn_gate(key, WARN_LIMIT) {
        WarnGate::Emit => eprintln!("{msg}"),
        WarnGate::Notice => {
            eprintln!("[obs] '{key}' hit its {WARN_LIMIT}-line cap; suppressing further output")
        }
        WarnGate::Suppressed => {}
    }
    if rec.enabled() {
        rec.record(&Record::Warn { key, round, msg: msg.to_string() });
    }
}

/// [`warn`] for call sites without a recorder at hand (the experiment
/// harness): stderr only, same rate limit.
pub fn warn_stderr(key: &'static str, msg: &str) {
    warn(&Null, key, None, msg);
}

/// Translate the executor's schedule ledger into per-job and
/// per-worker spans. Job/worker spans are virtual-time only (wall
/// bounds `(0, 0)`): placement happened in simulated time on the
/// coordinator, and per-job wall timing inside the pool would race.
/// Virtual bounds are seconds within the job's dispatch batch.
pub fn emit_schedule(rec: &dyn Recorder, trace: &crate::exec::ScheduleTrace) {
    if !rec.enabled() {
        return;
    }
    let mut prev_steals = 0usize;
    for e in &trace.entries {
        if e.job_idx == 0 {
            prev_steals = 0;
        }
        let stolen = e.steal_count > prev_steals;
        prev_steals = e.steal_count;
        rec.record(&Record::Span {
            phase: Phase::Job,
            round: e.round,
            wall_ns: (0, 0),
            virt_s: (e.start, e.end),
            extra: vec![
                ("kind", Json::Str(e.kind.label().into())),
                ("job", Json::Num(e.job_idx as f64)),
                ("worker", Json::Num(e.worker as f64)),
                ("stolen", Json::Bool(stolen)),
            ],
        });
    }
    for w in trace.worker_rollup() {
        rec.record(&Record::Span {
            phase: Phase::Worker,
            round: w.round,
            wall_ns: (0, 0),
            virt_s: (w.start, w.end),
            extra: vec![
                ("kind", Json::Str(w.kind.label().into())),
                ("worker", Json::Num(w.worker as f64)),
                ("jobs", Json::Num(w.jobs as f64)),
                ("stolen", Json::Num(w.stolen as f64)),
                ("busy", num(w.busy)),
            ],
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn scratch(tag: &str) -> std::path::PathBuf {
        static NONCE: AtomicUsize = AtomicUsize::new(0);
        let n = NONCE.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("fedcore_obs_{}_{tag}_{n}.jsonl", std::process::id()))
    }

    #[test]
    fn null_recorder_is_inert() {
        let rec = Null;
        assert!(!rec.enabled());
        assert_eq!(rec.now_ns(), 0);
        rec.record(&Record::span(Phase::Round, 0, (0, 1), (0.0, 1.0)));
    }

    #[test]
    fn jsonl_writes_header_then_valid_lines() {
        let path = scratch("header");
        let prov = crate::util::bench::provenance(7, 2, 1.0);
        let sink = Jsonl::create(&path, "engine", prov).unwrap();
        assert!(sink.enabled());
        sink.record(&Record::span(Phase::Round, 0, (5, 9), (0.0, 1.5)));
        sink.record(&Record::CounterVal { counter: Counter::Steals, round: 0, value: 3 });
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let head = Json::parse(lines[0]).unwrap();
        assert_eq!(head.get("t").and_then(|v| v.as_str()), Some("header"));
        assert_eq!(head.get("v").and_then(|v| v.as_f64()), Some(SCHEMA_VERSION as f64));
        assert_eq!(
            head.get("provenance").and_then(|p| p.get("seed")).and_then(|v| v.as_f64()),
            Some(7.0)
        );
        let span = Json::parse(lines[1]).unwrap();
        assert_eq!(span.get("name").and_then(|v| v.as_str()), Some("round"));
        assert_eq!(span.get("wall_end_ns").and_then(|v| v.as_f64()), Some(9.0));
        let counter = Json::parse(lines[2]).unwrap();
        assert_eq!(counter.get("name").and_then(|v| v.as_str()), Some("steals"));
        assert_eq!(counter.get("value").and_then(|v| v.as_f64()), Some(3.0));
    }

    #[test]
    fn monotonic_clock_advances() {
        let path = scratch("clock");
        let sink = Jsonl::create(&path, "engine", Json::Obj(Default::default())).unwrap();
        let a = sink.now_ns();
        let b = sink.now_ns();
        assert!(b >= a);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn warn_gate_caps_per_key() {
        // Unique key: the limiter is process-global.
        assert_eq!(warn_gate("test_gate_alpha", 2), WarnGate::Emit);
        assert_eq!(warn_gate("test_gate_alpha", 2), WarnGate::Emit);
        assert_eq!(warn_gate("test_gate_alpha", 2), WarnGate::Notice);
        assert_eq!(warn_gate("test_gate_alpha", 2), WarnGate::Suppressed);
        assert_eq!(warn_gate("test_gate_alpha", 2), WarnGate::Suppressed);
        // Independent bucket per key.
        assert_eq!(warn_gate("test_gate_beta", 2), WarnGate::Emit);
    }

    #[test]
    fn counter_names_are_unique() {
        let mut names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Counter::ALL.len());
    }

    #[test]
    fn non_finite_virtual_times_are_clamped() {
        let rec = Record::span(Phase::Train, 1, (0, 0), (f64::NAN, f64::INFINITY));
        let mut out = String::new();
        write_json(&rec.to_json(), &mut out);
        assert!(!out.contains("NaN") && !out.contains("inf"));
        Json::parse(&out).unwrap();
    }

    #[test]
    fn obs_config_builds_the_matching_sink() {
        assert!(!ObsConfig::Off.build(1, 1).unwrap().enabled());
        assert_eq!(ObsConfig::Off.path(), None);
        let path = scratch("cfg");
        let cfg = ObsConfig::Jsonl {
            path: path.to_string_lossy().into_owned(),
            scale: 0.5,
            health: None,
        };
        assert_eq!(cfg.path(), Some(path.to_string_lossy().as_ref()));
        assert_eq!(cfg.health(), None);
        let with_health = ObsConfig::Jsonl {
            path: path.to_string_lossy().into_owned(),
            scale: 0.5,
            health: Some(health::HealthConfig::default()),
        };
        assert_eq!(with_health.health(), Some(&health::HealthConfig::default()));
        let rec = cfg.build(11, 4).unwrap();
        assert!(rec.enabled());
        drop(rec);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let head = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(head.get("source").and_then(|v| v.as_str()), Some("engine"));
        let prov = head.get("provenance").unwrap();
        assert_eq!(prov.get("rounds").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(prov.get("scale").and_then(|v| v.as_f64()), Some(0.5));
    }

    #[test]
    fn buffered_sink_flushes_explicitly_and_on_drop() {
        let path = scratch("flush");
        let sink = Jsonl::create(&path, "engine", Json::Obj(Default::default())).unwrap();
        // Many more records than one BufWriter capacity's worth, so a
        // lost buffer would be visible as truncation.
        for r in 0..512 {
            sink.record(&Record::span(Phase::Round, r, (r as u64, r as u64 + 1), (0.0, 1.0)));
        }
        // Explicit flush (the pre-`append` barrier): every line durable
        // while the sink is still alive.
        Recorder::flush(&sink);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 513, "explicit flush left records buffered");
        sink.record(&Record::span(Phase::Checkpoint, 512, (0, 1), (0.0, 0.0)));
        drop(sink);
        // Drop flushed the tail; every line is complete JSON.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(text.lines().count(), 514, "drop lost buffered records");
        assert!(text.ends_with('\n'));
        for line in text.lines() {
            Json::parse(line).unwrap();
        }
    }

    #[test]
    fn snapshot_record_serializes_with_discriminant() {
        let ledger = health::HealthLedger::new(health::HealthConfig::default());
        let rec = ledger.snapshot(3);
        let j = rec.to_json();
        assert_eq!(j.get("t").and_then(|v| v.as_str()), Some("snapshot"));
        assert_eq!(j.get("v").and_then(|v| v.as_f64()), Some(SCHEMA_VERSION as f64));
        assert_eq!(j.get("round").and_then(|v| v.as_f64()), Some(3.0));
        assert!(j.get("clients").and_then(|v| v.as_arr()).is_some());
        assert!(j.get("sketches").and_then(|v| v.as_obj()).is_some());
    }

    #[test]
    fn emit_schedule_translates_jobs_and_workers() {
        use crate::exec::{plan_schedule, DispatchPolicy, JobKind, ScheduleEntry, ScheduleTrace};
        let sched = plan_schedule(DispatchPolicy::WorkStealing, &[5.0, 1.0, 1.0, 1.0], 2);
        let mut entries = Vec::new();
        let mut steals = 0;
        for i in 0..4 {
            steals += sched.stolen[i] as usize;
            entries.push(ScheduleEntry {
                round: 0,
                kind: JobKind::Client,
                job_idx: i,
                worker: sched.assignment[i],
                steal_count: steals,
                start: sched.start[i],
                end: sched.end[i],
            });
        }
        let trace = ScheduleTrace { entries };
        let path = scratch("sched");
        let sink = Jsonl::create(&path, "engine", Json::Obj(Default::default())).unwrap();
        emit_schedule(&sink, &trace);
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let spans: Vec<Json> = text.lines().skip(1).map(|l| Json::parse(l).unwrap()).collect();
        let jobs = spans.iter().filter(|s| s.get("name").unwrap().as_str() == Some("job"));
        assert_eq!(jobs.clone().count(), 4);
        let stolen_jobs = jobs
            .filter(|s| s.get("stolen").map(|v| *v == Json::Bool(true)).unwrap_or(false))
            .count();
        assert_eq!(stolen_jobs, trace.total_steals());
        let workers =
            spans.iter().filter(|s| s.get("name").unwrap().as_str() == Some("worker")).count();
        assert_eq!(workers, 2);
    }
}
