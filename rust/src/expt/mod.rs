//! Experiment harness shared by the paper-reproduction benches
//! (`rust/benches/table*`, `fig*`) and the examples: run strategy ×
//! benchmark × straggler-% grids and render the paper's tables/figures
//! as text.
//!
//! Bench knobs come from the environment so `cargo bench` stays a single
//! command (paper-shape defaults) while full-scale runs remain available:
//!
//! * `FEDCORE_SCALE`   — dataset scale multiplier (default per bench)
//! * `FEDCORE_ROUNDS`  — round-count override
//! * `FEDCORE_FULL=1`  — paper-scale everything (slow)
//! * `FEDCORE_WORKERS` — exec worker threads (0 = auto, default 1)
//! * `FEDCORE_DISPATCH` — job dispatch policy (`round_robin` default,
//!   `work_stealing`)
//! * `FEDCORE_QUORUM` / `FEDCORE_MAX_STALENESS` / `FEDCORE_ALPHA` —
//!   overlap policy for [`bench_overlap`] (defaults 0.7 / 2 / 1.0)
//! * `FEDCORE_CORESET_REFRESH` — adaptive-coreset rebuild interval
//!   (default 1 = rebuild every round; N > 1 warm-starts in between)

use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::data::{self, Benchmark};
use crate::exec::OverlapConfig;
use crate::fl::{all_strategies, Engine, RunConfig, Strategy};
use crate::metrics::RunResult;
use crate::runtime::Runtime;
use crate::scenario::TraceSpec;

/// Read an f64 knob from the environment.
pub fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Read a usize knob from the environment.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// `FEDCORE_FULL=1` — run benches at the paper's full scale (slow).
pub fn full_scale() -> bool {
    std::env::var("FEDCORE_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Per-benchmark default scales for CI-tractable bench runs. Chosen so one
/// strategy-run takes seconds, not minutes, while keeping ≥ 5 clients and
/// the Table 1 heterogeneity shape.
pub fn bench_scale(bench: Benchmark) -> f64 {
    if full_scale() {
        return 1.0;
    }
    let base = match bench {
        Benchmark::Mnist => 0.06,
        Benchmark::Shakespeare => 0.02,
        Benchmark::Synthetic { .. } => 0.2,
    };
    base * env_f64("FEDCORE_SCALE", 1.0)
}

/// Bench-default rounds (papers: 100/30/100 — scaled down ∝ scale).
pub fn bench_rounds(bench: Benchmark) -> usize {
    if full_scale() {
        return ExperimentConfig::paper_preset(bench).run.rounds;
    }
    let r = env_usize("FEDCORE_ROUNDS", 0);
    if r > 0 {
        return r;
    }
    match bench {
        Benchmark::Mnist => 14,
        Benchmark::Shakespeare => 4,
        Benchmark::Synthetic { .. } => 14,
    }
}

/// Bench-default learning rate: the paper's Table 3 rates assume paper
/// round counts; scaled-down runs on synthetic need a proportionally hotter
/// rate to reach the same loss region.
pub fn bench_lr(bench: Benchmark) -> f32 {
    if full_scale() {
        return ExperimentConfig::paper_preset(bench).run.lr;
    }
    match bench {
        Benchmark::Mnist => 0.05,
        Benchmark::Shakespeare => 0.5,
        Benchmark::Synthetic { .. } => 0.01,
    }
}

/// The shared bench-scale configuration (scaled preset + round/lr/eval
/// knobs + the `FEDCORE_WORKERS` override) behind [`run_one`],
/// [`run_cell`] and [`run_scenario`] — one place to add future knobs.
fn bench_cfg(bench: Benchmark, straggler_pct: f64, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::scaled_preset(bench, bench_scale(bench));
    cfg.run.rounds = bench_rounds(bench);
    cfg.run.lr = bench_lr(bench);
    cfg.run.straggler_pct = straggler_pct;
    cfg.run.seed = seed;
    cfg.run.eval_every = 2;
    cfg.run.workers = env_usize("FEDCORE_WORKERS", 1);
    cfg.run.dispatch = crate::exec::DispatchPolicy::from_env();
    cfg.run.coreset_refresh = env_usize("FEDCORE_CORESET_REFRESH", 1).max(1);
    cfg
}

/// One configured run (generating the dataset once per call).
pub fn run_one(
    rt: &Runtime,
    bench: Benchmark,
    strategy: Strategy,
    straggler_pct: f64,
    seed: u64,
) -> Result<RunResult> {
    run_with(rt, bench, strategy, straggler_pct, seed, None, None)
}

/// One configured run under an optional async-overlap policy and/or
/// availability trace (the bench-scale dataset and knobs of [`run_one`]).
/// The runner behind `benches/async_overlap.rs`, and the sweep entry
/// point for overlapped strategy grids.
pub fn run_with(
    rt: &Runtime,
    bench: Benchmark,
    strategy: Strategy,
    straggler_pct: f64,
    seed: u64,
    overlap: Option<OverlapConfig>,
    trace: Option<TraceSpec>,
) -> Result<RunResult> {
    let ds = Arc::new(data::generate(bench, bench_scale(bench), &rt.manifest().vocab, 7));
    let mut cfg = bench_cfg(bench, straggler_pct, seed).with_strategy(strategy);
    cfg.run.overlap = overlap;
    cfg.run.trace = trace;
    Engine::new(rt, &ds, cfg.run.clone())?.run()
}

/// The bench-default overlap policy: [`OverlapConfig`] with the
/// `FEDCORE_QUORUM` / `FEDCORE_MAX_STALENESS` / `FEDCORE_ALPHA` env
/// knobs applied (defaults 0.7 / 2 / 1.0).
pub fn bench_overlap() -> OverlapConfig {
    OverlapConfig {
        quorum: env_f64("FEDCORE_QUORUM", 0.7),
        max_staleness: env_usize("FEDCORE_MAX_STALENESS", 2),
        alpha: env_f64("FEDCORE_ALPHA", 1.0),
    }
}

/// One scenario run's summary: the run itself plus churn aggregates
/// derived from the round records and the materialized trace.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// Short scenario name (the churn model's label, or `"explicit"`).
    pub scenario: String,
    /// The underlying FL run.
    pub result: RunResult,
    /// Rounds in which no selected client did any work (nobody online).
    pub idle_rounds: usize,
    /// Selected clients taken offline mid-round, summed over the run.
    pub churn_dropped: usize,
    /// Simulated seconds of partial work discarded by churn drops.
    pub partial_time: f64,
    /// Mean fraction of the fleet online at round starts.
    pub mean_online_fraction: f64,
}

/// Run `strategy` on `bench` under a client-availability scenario (the
/// bench-scale dataset and knobs of [`run_one`], plus the trace). The
/// scenario runner behind `benches/scenario_churn.rs`.
pub fn run_scenario(
    rt: &Runtime,
    bench: Benchmark,
    strategy: Strategy,
    straggler_pct: f64,
    seed: u64,
    spec: TraceSpec,
) -> Result<ScenarioReport> {
    run_scenario_with(rt, bench, strategy, straggler_pct, seed, spec, |_| {})
}

/// [`run_scenario`] with a configuration hook: `mutate` edits the
/// [`RunConfig`] (selection policy, distillation weight, overlap,
/// aggregator, …) after the trace is attached and before the engine is
/// built, so the churn bench and the selection harness can race cohort
/// policies on one scenario without duplicating the report plumbing.
pub fn run_scenario_with(
    rt: &Runtime,
    bench: Benchmark,
    strategy: Strategy,
    straggler_pct: f64,
    seed: u64,
    spec: TraceSpec,
    mutate: impl Fn(&mut RunConfig),
) -> Result<ScenarioReport> {
    let ds = Arc::new(data::generate(bench, bench_scale(bench), &rt.manifest().vocab, 7));
    let mut cfg = bench_cfg(bench, straggler_pct, seed).with_strategy(strategy);
    let scenario = spec.label().to_string();
    cfg.run.trace = Some(spec);
    mutate(&mut cfg.run);

    let engine = Engine::new(rt, &ds, cfg.run.clone())?;
    let trace = engine.trace().cloned();
    let result = engine.run()?;

    let mut idle_rounds = 0usize;
    let mut churn_dropped = 0usize;
    let mut partial_time = 0.0f64;
    let mut online_acc = 0.0f64;
    for rec in &result.rounds {
        if rec.client_times.is_empty() && rec.dropped == 0 {
            idle_rounds += 1;
        }
        churn_dropped += rec.churn_dropped;
        partial_time += rec.partial_time;
        if let Some(tr) = &trace {
            // The availability the selector actually saw: read the trace at
            // this round's start time.
            online_acc += tr.online_fraction(rec.sim_elapsed - rec.sim_time);
        }
    }
    let n = result.rounds.len().max(1);
    Ok(ScenarioReport {
        scenario,
        result,
        idle_rounds,
        churn_dropped,
        partial_time,
        mean_online_fraction: if trace.is_some() { online_acc / n as f64 } else { 1.0 },
    })
}

/// All four strategies on one (benchmark, straggler%) cell, sharing one
/// generated dataset — the unit of Table 2 / Fig. 3 work. With
/// `FEDCORE_WORKERS > 1` the whole cell also shares **one** sharded pool
/// (and its compiled per-worker runtimes) across all four engines
/// instead of building a pool per engine; results are bit-identical
/// either way (`rust/tests/proptest_exec.rs`).
pub fn run_cell(
    rt: &Runtime,
    bench: Benchmark,
    straggler_pct: f64,
    seed: u64,
) -> Result<Vec<RunResult>> {
    run_cell_with(rt, bench, straggler_pct, seed, |_| {})
}

/// [`run_cell`] with a configuration hook: `mutate` edits the cell's
/// shared [`RunConfig`] (workers, dispatch policy, overlap, aggregator,
/// trace, …) before the engines are built, so tests and drivers can
/// compose cross-subsystem cells — e.g. work-stealing dispatch under an
/// overlap quorum with a robust aggregator on a churn trace — while
/// keeping the sweep's one-pool-per-cell behaviour.
pub fn run_cell_with(
    rt: &Runtime,
    bench: Benchmark,
    straggler_pct: f64,
    seed: u64,
    mutate: impl Fn(&mut RunConfig),
) -> Result<Vec<RunResult>> {
    let ds = Arc::new(data::generate(bench, bench_scale(bench), &rt.manifest().vocab, 7));
    let mut base = bench_cfg(bench, straggler_pct, seed);
    mutate(&mut base.run);
    let shared = crate::exec::sweep_pool(base.run.workers, rt.factory(), base.run.dispatch);
    let mut out = Vec::new();
    for strategy in all_strategies(base.prox_mu) {
        let cfg = base.clone().with_strategy(strategy);
        crate::obs::warn_stderr(
            "expt_cell",
            &format!(
                "  [{} | {}% stragglers] {} ...",
                bench.label(),
                straggler_pct,
                strategy.label()
            ),
        );
        let result = match &shared {
            Some(pool) => Engine::with_executor(rt, &ds, cfg.run.clone(), pool)?.run()?,
            None => Engine::new(rt, &ds, cfg.run.clone())?.run()?,
        };
        out.push(result);
    }
    Ok(out)
}

/// Paper-scale timing projection: Table 2's *time* rows need only the
/// straggler simulation (plans → simulated times), not actual training, so
/// they can be regenerated at the full 1,000-client scale in milliseconds.
/// Returns (strategy label, mean normalized round time) rows.
pub fn timing_projection(
    bench: Benchmark,
    straggler_pct: f64,
    rounds: usize,
    seed: u64,
) -> Vec<(String, f64)> {
    use crate::sim::Fleet;
    use crate::util::rng::Rng;

    // Paper-scale per-client sizes without materializing sample data.
    let preset = ExperimentConfig::paper_preset(bench);
    let mut rng = Rng::new(seed).split(0x71E);
    let sizes: Vec<usize> = match bench {
        Benchmark::Mnist => {
            crate::data::partition::power_law_sizes(&mut rng, 1000, 69.0, 1.4, 8)
        }
        Benchmark::Shakespeare => {
            crate::data::partition::power_law_sizes(&mut rng, 143, 3616.0, 1.25, 3)
        }
        Benchmark::Synthetic { .. } => {
            crate::data::partition::power_law_sizes(&mut rng, 30, 670.0, 1.12, 16)
        }
    };
    let total: usize = sizes.iter().sum();
    let weights: Vec<f64> = sizes.iter().map(|&m| m as f64 / total as f64).collect();
    let mut fleet_rng = Rng::new(seed).split(0xF1EE7);
    let fleet = Fleet::new(&mut fleet_rng, sizes, preset.run.epochs, straggler_pct);
    let k = preset.run.clients_per_round;

    let mut select_rng = Rng::new(seed).split(0x5E1EC7);
    let per_round: Vec<Vec<usize>> = (0..rounds)
        .map(|_| select_rng.weighted_with_replacement(&weights, k))
        .collect();

    all_strategies(preset.prox_mu)
        .into_iter()
        .map(|strategy| {
            let mut mean = 0.0;
            for selected in &per_round {
                let round_time = selected
                    .iter()
                    .map(|&i| {
                        let plan = strategy.plan(&fleet, i);
                        match plan {
                            crate::fl::LocalPlan::Dropped => 0.0,
                            p => p.sim_time(&fleet, i),
                        }
                    })
                    .fold(0.0f64, f64::max);
                mean += round_time / fleet.deadline / rounds as f64;
            }
            (strategy.label().to_string(), mean)
        })
        .collect()
}

/// Load the runtime if this environment can: artifacts present AND a
/// backend able to execute them. Returns `None` (with an explanatory line
/// on stderr) when artifacts are missing or the build uses the stub
/// backend; panics only when a real (`pjrt`) backend fails on existing
/// artifacts. The single skip policy shared by the test suites and the
/// benches.
pub fn try_runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        crate::obs::warn_stderr("runtime_skip", "skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        // The stub-backend build cannot execute artifacts even when they
        // exist; skip like the missing-artifacts case instead of failing.
        Err(e) if !cfg!(feature = "pjrt") => {
            crate::obs::warn_stderr(
                "runtime_skip",
                &format!("skipping: artifacts present but no pjrt backend ({e:#})"),
            );
            None
        }
        Err(e) => panic!("runtime load: {e:#}"),
    }
}

/// Load the runtime or exit 0 with a message (benches must not fail when
/// the environment cannot execute artifacts — same policy as the test
/// suites' skip behaviour, via [`try_runtime`]).
pub fn runtime_or_exit() -> Runtime {
    match try_runtime() {
        Some(rt) => rt,
        None => std::process::exit(0),
    }
}

/// Render a Table-2-style block for one (benchmark, s%) cell.
pub fn print_cell_table(bench: Benchmark, s: f64, runs: &[RunResult]) {
    println!("\n== {} @ {}% stragglers ==", bench.label(), s);
    println!("{:<12} {:>9} {:>10}", "strategy", "acc (%)", "mean t/τ");
    for row in crate::metrics::table2_rows(runs) {
        let mark = if row.exceeded_deadline { "  ← exceeds τ (paper: red)" } else { "" };
        println!(
            "{:<12} {:>9.1} {:>10.2}{mark}",
            row.strategy, row.accuracy_pct, row.mean_norm_time
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_are_sane() {
        for b in data::paper_benchmarks() {
            let s = bench_scale(b);
            assert!(s > 0.0 && s <= 1.0);
            assert!(bench_rounds(b) >= 4);
            assert!(bench_lr(b) > 0.0);
        }
    }

    #[test]
    fn env_parsers_fall_back() {
        assert_eq!(env_f64("FEDCORE_DOES_NOT_EXIST", 2.5), 2.5);
        assert_eq!(env_usize("FEDCORE_DOES_NOT_EXIST", 3), 3);
    }

    #[test]
    fn bench_overlap_policy_is_valid() {
        assert!(bench_overlap().validate().is_ok());
    }
}
