//! Uniform-random subset baseline for the coreset ablation.
//!
//! Picks k distinct samples uniformly; assignment/weights still come from
//! [`super::finalize`], so only the *selection* quality differs from the
//! k-medoids solvers. This is the "coreset = random minibatch" strawman
//! the gradient-matching literature compares against.

use super::DistMatrix;
use crate::util::rng::Rng;

/// Pick `k` distinct samples uniformly at random.
pub fn solve(dist: &DistMatrix, k: usize, rng: &mut Rng) -> Vec<usize> {
    rng.choose_k(dist.n, k.min(dist.n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::objective;
    use crate::coreset::distance::from_features_cpu;

    #[test]
    fn picks_k_distinct() {
        let dist = DistMatrix { n: 30, d: vec![0.0; 900] };
        let mut rng = Rng::new(1);
        let m = solve(&dist, 7, &mut rng);
        let mut s = m.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 7);
    }

    #[test]
    fn usually_worse_than_fasterpam_on_clustered_data() {
        // 4 tight clusters; random often misses one, FasterPAM never does.
        let mut rng = Rng::new(2);
        let mut f = Vec::new();
        for c in 0..4 {
            for _ in 0..12 {
                f.push(10.0 * c as f32 + 0.05 * rng.normal() as f32);
                f.push(10.0 * c as f32 + 0.05 * rng.normal() as f32);
            }
        }
        let dist = from_features_cpu(&f, 48, 2);
        let fp = objective(&dist, &super::super::fasterpam::solve(&dist, 4, &mut rng));
        let mut rnd_mean = 0.0;
        for _ in 0..10 {
            rnd_mean += objective(&dist, &solve(&dist, 4, &mut rng)) / 10.0;
        }
        assert!(fp < rnd_mean, "fp {fp} not below random mean {rnd_mean}");
    }
}
