//! FasterPAM k-medoids (Schubert & Rousseeuw, 2021) — the paper's solver
//! for Eq. (5) (§4.2: "FasterPAM quickly solves the k-medoids problem,
//! generating coresets for large datasets within one second").
//!
//! Structure:
//! * **BUILD** — greedy initialization, identical to classic PAM.
//! * **Eager SWAP** — for each candidate point, the swap gain against *all*
//!   k medoids is computed in one O(n) pass using the nearest/second-
//!   nearest caches, and any improving swap is applied immediately
//!   (first-improvement order) with an **O(n) amortized incremental cache
//!   update** — no O(nk) recompute per swap. Complexity per sweep drops
//!   from PAM's O(n²k) to O(n²), which is what makes the paper's <1 s
//!   claim hold at m in the thousands (see `benches/kmedoids.rs`).
//!
//! Numerical hygiene: swaps are accepted only when they beat a scale-aware
//! threshold (a 1e-6 fraction of the mean nearest-distance), so float noise
//! on near-tied configurations cannot cause unbounded churn.

use super::DistMatrix;
use crate::util::rng::Rng;

/// Nearest/second-nearest cache entry; indices are positions in the medoid
/// array (u32 keeps the struct 16 bytes → cache-friendly scans).
#[derive(Clone, Copy, Debug)]
struct Near {
    n1: u32,
    n2: u32,
    d1: f32,
    d2: f32,
}

/// Greedy BUILD initialization (shared with [`super::pam`]).
pub(crate) fn build_init(dist: &DistMatrix, k: usize) -> Vec<usize> {
    let n = dist.n;
    debug_assert!(k >= 1 && k < n);
    // First medoid: the point minimizing total distance.
    let mut best = 0usize;
    let mut best_td = f64::INFINITY;
    for c in 0..n {
        let td: f64 = (0..n).map(|j| dist.get(j, c) as f64).sum();
        if td < best_td {
            best_td = td;
            best = c;
        }
    }
    let mut medoids = vec![best];
    let mut d1: Vec<f32> = (0..n).map(|j| dist.get(j, best)).collect();
    let mut is_medoid = vec![false; n];
    is_medoid[best] = true;

    while medoids.len() < k {
        let mut best = usize::MAX;
        let mut best_gain = f64::NEG_INFINITY;
        for c in 0..n {
            if is_medoid[c] {
                continue;
            }
            let gain: f64 = (0..n)
                .map(|j| (d1[j] - dist.get(j, c)).max(0.0) as f64)
                .sum();
            if gain > best_gain {
                best_gain = gain;
                best = c;
            }
        }
        medoids.push(best);
        is_medoid[best] = true;
        for j in 0..n {
            d1[j] = d1[j].min(dist.get(j, best));
        }
    }
    medoids
}

/// Full O(nk) cache rebuild (used once after BUILD).
fn rebuild_cache(dist: &DistMatrix, medoids: &[usize], near: &mut [Near]) {
    for j in 0..dist.n {
        near[j] = scan_point(dist, medoids, j);
    }
}

/// O(k) rescan of a single point.
#[inline]
fn scan_point(dist: &DistMatrix, medoids: &[usize], j: usize) -> Near {
    let mut n1 = 0u32;
    let mut n2 = 0u32;
    let mut d1 = f32::INFINITY;
    let mut d2 = f32::INFINITY;
    for (mi, &m) in medoids.iter().enumerate() {
        let d = dist.get(j, m);
        if d < d1 {
            d2 = d1;
            n2 = n1;
            d1 = d;
            n1 = mi as u32;
        } else if d < d2 {
            d2 = d;
            n2 = mi as u32;
        }
    }
    Near { n1, n2, d1, d2 }
}

/// Per-medoid removal loss: Σ_{j: n1 = i} (d2 − d1). O(n).
fn removal_losses(near: &[Near], removal: &mut [f64]) {
    removal.iter_mut().for_each(|r| *r = 0.0);
    for nj in near {
        removal[nj.n1 as usize] += (nj.d2 - nj.d1) as f64;
    }
}

/// Incremental cache update after swapping medoid slot `mi` to point `c`:
/// O(n) plus O(k) for each point whose nearest/second involved the removed
/// medoid (≈ n/k points on average ⇒ O(n) amortized).
fn update_cache_after_swap(
    dist: &DistMatrix,
    medoids: &[usize],
    near: &mut [Near],
    mi: usize,
    c: usize,
) {
    let mi = mi as u32;
    for j in 0..dist.n {
        let dcj = dist.get(j, c);
        let nj = near[j];
        if nj.n1 == mi || nj.n2 == mi {
            // The removed medoid was one of j's two closest: rescan.
            near[j] = scan_point(dist, medoids, j);
        } else if dcj < nj.d1 {
            near[j] = Near { n1: mi, n2: nj.n1, d1: dcj, d2: nj.d1 };
        } else if dcj < nj.d2 {
            near[j] = Near { n2: mi, d2: dcj, ..nj };
        }
    }
}

/// k-medoids++ initialization (D² sampling): O(nk) instead of BUILD's
/// O(n²k). Schubert & Rousseeuw report FasterPAM's eager swap reaches the
/// same local optima from cheap initializations, which is what makes the
/// <1 s target reachable at m ≈ 4096, k ≈ 400.
pub(crate) fn dsq_init(dist: &DistMatrix, k: usize, rng: &mut Rng) -> Vec<usize> {
    let n = dist.n;
    let first = rng.below(n);
    let mut medoids = vec![first];
    let mut is_medoid = vec![false; n];
    is_medoid[first] = true;
    let mut mind: Vec<f64> = (0..n).map(|j| dist.get(j, first) as f64).collect();
    while medoids.len() < k {
        let total: f64 = mind.iter().map(|d| d * d).sum();
        let next = if total <= 0.0 {
            // all remaining points coincide with medoids: pick any free one
            (0..n).find(|&j| !is_medoid[j]).unwrap()
        } else {
            let mut x = rng.f64() * total;
            let mut pick = n - 1;
            for (j, d) in mind.iter().enumerate() {
                x -= d * d;
                if x <= 0.0 && !is_medoid[j] {
                    pick = j;
                    break;
                }
            }
            if is_medoid[pick] {
                (0..n).find(|&j| !is_medoid[j]).unwrap()
            } else {
                pick
            }
        };
        medoids.push(next);
        is_medoid[next] = true;
        for j in 0..n {
            mind[j] = mind[j].min(dist.get(j, next) as f64);
        }
    }
    medoids
}

/// Cost cross-over: below this many BUILD operations (≈ n²·k), BUILD's
/// better starting point is worth it; above, D² sampling + eager swap wins.
/// Measured (examples/perf_profile §3): identical final objective from
/// either init at m ≥ 128, while BUILD costs 7× at m=512 and 120× at
/// m=1024 — so the limit sits just above the tiny-instance regime.
const BUILD_OPS_LIMIT: usize = 1 << 20;

/// Run FasterPAM; returns the medoid indices (unordered).
pub fn solve(dist: &DistMatrix, k: usize, rng: &mut Rng) -> Vec<usize> {
    let n = dist.n;
    let use_build = n.saturating_mul(n).saturating_mul(k) <= BUILD_OPS_LIMIT;
    solve_with_init(dist, k, rng, use_build)
}

/// FasterPAM with an explicit initialization choice (exposed for the perf
/// harness and ablations; [`solve`] picks automatically).
pub fn solve_with_init(dist: &DistMatrix, k: usize, rng: &mut Rng, use_build: bool) -> Vec<usize> {
    let n = dist.n;
    if k >= n {
        return (0..n).collect();
    }
    let mut medoids = if use_build {
        build_init(dist, k)
    } else {
        dsq_init(dist, k, rng)
    };
    if k == n - 1 {
        // Every non-medoid point is the single outsider; BUILD is optimal.
        return medoids;
    }

    let mut near = vec![Near { n1: 0, n2: 0, d1: 0.0, d2: 0.0 }; n];
    rebuild_cache(dist, &medoids, &mut near);
    let mut removal = vec![0.0f64; k];
    removal_losses(&near, &mut removal);
    let mut is_medoid = vec![false; n];
    for &m in &medoids {
        is_medoid[m] = true;
    }

    // Scale-aware acceptance threshold: ignore "improvements" below a 1e-6
    // fraction of the mean nearest distance (pure float noise on ties).
    let mean_d1: f64 =
        near.iter().map(|x| x.d1 as f64).sum::<f64>() / n as f64;
    let eps = -1e-6 * (mean_d1 + 1e-12);

    // Randomized candidate order decorrelates eager-swap scan bias.
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);

    let mut delta = vec![0.0f64; k];
    let mut since_improved = 0usize;
    let mut pos = 0usize;
    // Practical swap budget: eager FasterPAM converges in O(k) swaps; the
    // cap guards degenerate inputs without affecting normal runs.
    let max_swaps = 20 * k + 200;
    let mut swaps = 0usize;

    while since_improved < n && swaps < max_swaps {
        let c = order[pos % n];
        pos += 1;
        if is_medoid[c] {
            since_improved += 1;
            continue;
        }

        delta.copy_from_slice(&removal);
        let mut acc = 0.0f64;
        // One contiguous row of the matrix: d(c, ·).
        let row = &dist.d[c * n..(c + 1) * n];
        for (nj, &dcj) in near.iter().zip(row) {
            if dcj < nj.d1 {
                // j defects to c; removing j's old nearest no longer costs d2.
                acc += (dcj - nj.d1) as f64;
                delta[nj.n1 as usize] += (nj.d1 - nj.d2) as f64;
            } else if dcj < nj.d2 {
                // If j's nearest were removed, j now goes to c, not d2.
                delta[nj.n1 as usize] += (dcj - nj.d2) as f64;
            }
        }

        let (best_i, best_delta) = delta
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, &v)| (i, v))
            .unwrap();

        if best_delta + acc < eps {
            let old = medoids[best_i];
            is_medoid[old] = false;
            is_medoid[c] = true;
            medoids[best_i] = c;
            update_cache_after_swap(dist, &medoids, &mut near, best_i, c);
            removal_losses(&near, &mut removal);
            since_improved = 0;
            swaps += 1;
        } else {
            since_improved += 1;
        }
    }
    medoids
}

/// Total deviation of a medoid set (Σⱼ minₖ d) — exposed for benches.
pub fn total_deviation(dist: &DistMatrix, medoids: &[usize]) -> f64 {
    super::objective(dist, medoids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::{objective, Method};

    fn random_dist(rng: &mut Rng, n: usize, dim: usize) -> DistMatrix {
        let f: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        super::super::distance::from_features_cpu(&f, n, dim)
    }

    /// Exhaustive k-medoids for tiny instances.
    fn brute_force(dist: &DistMatrix, k: usize) -> (Vec<usize>, f64) {
        fn rec(
            dist: &DistMatrix,
            k: usize,
            start: usize,
            cur: &mut Vec<usize>,
            best: &mut (Vec<usize>, f64),
        ) {
            if cur.len() == k {
                let c = objective(dist, cur);
                if c < best.1 {
                    *best = (cur.clone(), c);
                }
                return;
            }
            for i in start..dist.n {
                cur.push(i);
                rec(dist, k, i + 1, cur, best);
                cur.pop();
            }
        }
        let mut best = (vec![], f64::INFINITY);
        rec(dist, k, 0, &mut vec![], &mut best);
        best
    }

    #[test]
    fn matches_brute_force_on_tiny_instances() {
        for seed in 0..8 {
            let mut rng = Rng::new(seed);
            let dist = random_dist(&mut rng, 10, 3);
            for k in [1, 2, 3] {
                let got = solve(&dist, k, &mut rng);
                let got_cost = objective(&dist, &got);
                let (_, want_cost) = brute_force(&dist, k);
                // FasterPAM is a local search; it should usually hit the
                // optimum on these tiny instances and never be far off.
                assert!(
                    got_cost <= want_cost * 1.05 + 1e-9,
                    "seed {seed} k {k}: got {got_cost}, optimum {want_cost}"
                );
            }
        }
    }

    #[test]
    fn swap_never_worse_than_build() {
        for seed in 0..6 {
            let mut rng = Rng::new(100 + seed);
            let dist = random_dist(&mut rng, 60, 4);
            let k = 6;
            let build = build_init(&dist, k);
            let build_cost = objective(&dist, &build);
            let solved = solve(&dist, k, &mut rng);
            let solved_cost = objective(&dist, &solved);
            assert!(
                solved_cost <= build_cost + 1e-9,
                "seed {seed}: {solved_cost} > build {build_cost}"
            );
        }
    }

    #[test]
    fn incremental_cache_matches_full_rebuild() {
        // After a forced swap, the incremental update must agree with a
        // from-scratch rebuild on every point.
        let mut rng = Rng::new(31);
        let dist = random_dist(&mut rng, 40, 3);
        let mut medoids = build_init(&dist, 5);
        let mut near = vec![Near { n1: 0, n2: 0, d1: 0.0, d2: 0.0 }; 40];
        rebuild_cache(&dist, &medoids, &mut near);
        // swap slot 2 for an arbitrary non-medoid
        let c = (0..40).find(|i| !medoids.contains(i)).unwrap();
        medoids[2] = c;
        update_cache_after_swap(&dist, &medoids, &mut near, 2, c);
        let mut fresh = vec![Near { n1: 0, n2: 0, d1: 0.0, d2: 0.0 }; 40];
        rebuild_cache(&dist, &medoids, &mut fresh);
        for j in 0..40 {
            assert_eq!(near[j].d1, fresh[j].d1, "d1 mismatch at {j}");
            assert_eq!(near[j].d2, fresh[j].d2, "d2 mismatch at {j}");
            assert_eq!(near[j].n1, fresh[j].n1, "n1 mismatch at {j}");
        }
    }

    #[test]
    fn beats_random_selection() {
        let mut rng = Rng::new(42);
        let dist = random_dist(&mut rng, 120, 6);
        let k = 10;
        let fp = solve(&dist, k, &mut rng);
        let fp_cost = objective(&dist, &fp);
        let mut worse = 0;
        for _ in 0..20 {
            let rnd = rng.choose_k(dist.n, k);
            if objective(&dist, &rnd) >= fp_cost {
                worse += 1;
            }
        }
        assert!(worse >= 19, "random beat FasterPAM {}/20 times", 20 - worse);
    }

    #[test]
    fn returns_k_distinct_medoids() {
        let mut rng = Rng::new(7);
        let dist = random_dist(&mut rng, 50, 4);
        for k in [1, 5, 17, 49] {
            let m = solve(&dist, k, &mut rng);
            let mut s = m.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "k={k}");
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn duplicate_points_are_harmless() {
        // All points identical: any medoid set has cost 0 and the noise
        // threshold must prevent swap churn.
        let dist = DistMatrix { n: 6, d: vec![0.0; 36] };
        let mut rng = Rng::new(8);
        let m = solve(&dist, 2, &mut rng);
        assert_eq!(m.len(), 2);
        assert_eq!(objective(&dist, &m), 0.0);
    }

    #[test]
    fn clustered_data_with_large_k_terminates_fast() {
        // The regression behind the swap-budget + noise threshold: many
        // near-tied medoid placements inside tight clusters.
        let mut rng = Rng::new(9);
        let n = 400;
        let f: Vec<f32> = (0..n)
            .flat_map(|i| {
                let c = (i % 10) as f32;
                [c * 10.0 + 0.01 * rng.normal() as f32, c * 10.0]
            })
            .collect();
        let dist = super::super::distance::from_features_cpu(&f, n, 2);
        let t0 = std::time::Instant::now();
        let m = solve(&dist, 40, &mut rng);
        assert!(t0.elapsed().as_secs_f64() < 2.0, "took {:?}", t0.elapsed());
        assert_eq!(m.len(), 40);
    }

    #[test]
    fn method_enum_dispatches_here() {
        let mut rng = Rng::new(9);
        let dist = random_dist(&mut rng, 30, 3);
        let cs = crate::coreset::select(&dist, 5, Method::FasterPam, &mut rng);
        assert_eq!(cs.len(), 5);
        assert_eq!(cs.total_weight(), 30.0);
    }
}
