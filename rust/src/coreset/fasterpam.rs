//! FasterPAM k-medoids (Schubert & Rousseeuw, 2021) — the paper's solver
//! for Eq. (5) (§4.2: "FasterPAM quickly solves the k-medoids problem,
//! generating coresets for large datasets within one second").
//!
//! Structure:
//! * **BUILD** — greedy initialization, identical to classic PAM.
//! * **Eager SWAP** — for each candidate point, the swap gain against *all*
//!   k medoids is computed in one O(n) pass using the nearest/second-
//!   nearest caches, and any improving swap is applied immediately
//!   (first-improvement order) with an **O(n) amortized incremental cache
//!   update** — no O(nk) recompute per swap. Complexity per sweep drops
//!   from PAM's O(n²k) to O(n²), which is what makes the paper's <1 s
//!   claim hold at m in the thousands (see `benches/kmedoids.rs`).
//!
//! Numerical hygiene: swaps are accepted only when they beat a scale-aware
//! threshold (a 1e-6 fraction of the mean nearest-distance), so float noise
//! on near-tied configurations cannot cause unbounded churn.
//!
//! **Parallel discipline.** [`solve_par`] shards the BUILD greedy scans and
//! the eager-SWAP candidate evaluation across a scoped worker pool and is
//! **bit-identical** to the sequential [`solve`] at any worker count:
//!
//! * BUILD shards the candidate range into contiguous chunks; each chunk
//!   reports its strict-inequality local best, and chunk results merge in
//!   chunk order with the same strict comparison — so the first index
//!   attaining the optimum wins, exactly as in the sequential scan.
//! * SWAP evaluates a fixed lookahead window of upcoming candidates in
//!   parallel against the *frozen* caches (each evaluation is a pure
//!   function of `(near, removal, row)`), then walks the window in
//!   candidate order replaying the sequential accept/reject decisions;
//!   the first applied swap discards the rest of the window, so the
//!   first-improvement order is preserved verbatim.
//!
//! [`solve_warm`] skips initialization and re-runs only the SWAP sweeps on
//! a cached medoid set — the incremental cross-round path (§4.3).
//! `tests/proptest_coreset.rs` enforces all three equivalences.

use super::DistMatrix;
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;

/// Nearest/second-nearest cache entry; indices are positions in the medoid
/// array (u32 keeps the struct 16 bytes → cache-friendly scans).
#[derive(Clone, Copy, Debug)]
struct Near {
    n1: u32,
    n2: u32,
    d1: f32,
    d2: f32,
}

/// Greedy BUILD initialization (shared with [`super::pam`]).
pub(crate) fn build_init(dist: &DistMatrix, k: usize) -> Vec<usize> {
    build_init_par(dist, k, 1)
}

/// Contiguous candidate ranges for the sharded BUILD scans: `workers`
/// chunks covering `0..n` in index order (first chunks one longer when
/// `n` does not divide evenly).
fn chunk_ranges(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let workers = workers.max(1).min(n.max(1));
    let (base, extra) = (n / workers, n % workers);
    let mut ranges = Vec::with_capacity(workers);
    let mut start = 0usize;
    for w in 0..workers {
        let len = base + usize::from(w < extra);
        ranges.push((start, start + len));
        start += len;
    }
    ranges
}

/// Greedy BUILD with the candidate scans sharded over `workers` threads.
///
/// Each chunk scans its range with the sequential code's strict
/// comparisons; chunk results merge in chunk order with the same strict
/// comparison, so ties resolve to the lowest candidate index — the
/// sequential answer — at every worker count.
pub(crate) fn build_init_par(dist: &DistMatrix, k: usize, workers: usize) -> Vec<usize> {
    let n = dist.n;
    debug_assert!(k >= 1 && k < n);
    // First medoid: the point minimizing total distance.
    let (best, _) = chunk_best(chunk_ranges(n, workers), workers, |lo, hi| {
        let mut best = usize::MAX;
        let mut best_td = f64::INFINITY;
        for c in lo..hi {
            let td: f64 = (0..n).map(|j| dist.get(j, c) as f64).sum();
            if td < best_td {
                best_td = td;
                best = c;
            }
        }
        (best, best_td)
    });
    let mut medoids = vec![best];
    let mut d1: Vec<f32> = (0..n).map(|j| dist.get(j, best)).collect();
    let mut is_medoid = vec![false; n];
    is_medoid[best] = true;

    while medoids.len() < k {
        let (d1_ref, is_medoid_ref) = (&d1, &is_medoid);
        let (best, _) = chunk_best(chunk_ranges(n, workers), workers, |lo, hi| {
            let mut best = usize::MAX;
            let mut best_gain = f64::NEG_INFINITY;
            for c in lo..hi {
                if is_medoid_ref[c] {
                    continue;
                }
                let gain: f64 = (0..n)
                    .map(|j| (d1_ref[j] - dist.get(j, c)).max(0.0) as f64)
                    .sum();
                if gain > best_gain {
                    best_gain = gain;
                    best = c;
                }
            }
            (best, -best_gain)
        });
        medoids.push(best);
        is_medoid[best] = true;
        for j in 0..n {
            d1[j] = d1[j].min(dist.get(j, best));
        }
    }
    medoids
}

/// Run `scan(lo, hi)` over every chunk (in parallel when `workers > 1`) and
/// merge the per-chunk `(index, key)` minima **in chunk order** with a
/// strict `<`, preserving the sequential first-best-wins tie rule. Chunks
/// that found no candidate report `usize::MAX` with an infinite key.
fn chunk_best(
    ranges: Vec<(usize, usize)>,
    workers: usize,
    scan: impl Fn(usize, usize) -> (usize, f64) + Sync,
) -> (usize, f64) {
    let per_chunk = parallel_map(ranges, workers, |(lo, hi)| scan(lo, hi));
    let mut best = (usize::MAX, f64::INFINITY);
    for (c, key) in per_chunk {
        if key < best.1 {
            best = (c, key);
        }
    }
    best
}

/// Full O(nk) cache rebuild (used once after BUILD).
fn rebuild_cache(dist: &DistMatrix, medoids: &[usize], near: &mut [Near]) {
    for j in 0..dist.n {
        near[j] = scan_point(dist, medoids, j);
    }
}

/// O(k) rescan of a single point.
#[inline]
fn scan_point(dist: &DistMatrix, medoids: &[usize], j: usize) -> Near {
    let mut n1 = 0u32;
    let mut n2 = 0u32;
    let mut d1 = f32::INFINITY;
    let mut d2 = f32::INFINITY;
    for (mi, &m) in medoids.iter().enumerate() {
        let d = dist.get(j, m);
        if d < d1 {
            d2 = d1;
            n2 = n1;
            d1 = d;
            n1 = mi as u32;
        } else if d < d2 {
            d2 = d;
            n2 = mi as u32;
        }
    }
    Near { n1, n2, d1, d2 }
}

/// Per-medoid removal loss: Σ_{j: n1 = i} (d2 − d1). O(n).
fn removal_losses(near: &[Near], removal: &mut [f64]) {
    removal.iter_mut().for_each(|r| *r = 0.0);
    for nj in near {
        removal[nj.n1 as usize] += (nj.d2 - nj.d1) as f64;
    }
}

/// Incremental cache update after swapping medoid slot `mi` to point `c`:
/// O(n) plus O(k) for each point whose nearest/second involved the removed
/// medoid (≈ n/k points on average ⇒ O(n) amortized).
fn update_cache_after_swap(
    dist: &DistMatrix,
    medoids: &[usize],
    near: &mut [Near],
    mi: usize,
    c: usize,
) {
    let mi = mi as u32;
    for j in 0..dist.n {
        let dcj = dist.get(j, c);
        let nj = near[j];
        if nj.n1 == mi || nj.n2 == mi {
            // The removed medoid was one of j's two closest: rescan.
            near[j] = scan_point(dist, medoids, j);
        } else if dcj < nj.d1 {
            near[j] = Near { n1: mi, n2: nj.n1, d1: dcj, d2: nj.d1 };
        } else if dcj < nj.d2 {
            near[j] = Near { n2: mi, d2: dcj, ..nj };
        }
    }
}

/// k-medoids++ initialization (D² sampling): O(nk) instead of BUILD's
/// O(n²k). Schubert & Rousseeuw report FasterPAM's eager swap reaches the
/// same local optima from cheap initializations, which is what makes the
/// <1 s target reachable at m ≈ 4096, k ≈ 400.
pub(crate) fn dsq_init(dist: &DistMatrix, k: usize, rng: &mut Rng) -> Vec<usize> {
    let n = dist.n;
    let first = rng.below(n);
    let mut medoids = vec![first];
    let mut is_medoid = vec![false; n];
    is_medoid[first] = true;
    let mut mind: Vec<f64> = (0..n).map(|j| dist.get(j, first) as f64).collect();
    while medoids.len() < k {
        let total: f64 = mind.iter().map(|d| d * d).sum();
        let next = if total <= 0.0 {
            // all remaining points coincide with medoids: pick any free one
            (0..n).find(|&j| !is_medoid[j]).unwrap()
        } else {
            let mut x = rng.f64() * total;
            let mut pick = n - 1;
            for (j, d) in mind.iter().enumerate() {
                x -= d * d;
                if x <= 0.0 && !is_medoid[j] {
                    pick = j;
                    break;
                }
            }
            if is_medoid[pick] {
                (0..n).find(|&j| !is_medoid[j]).unwrap()
            } else {
                pick
            }
        };
        medoids.push(next);
        is_medoid[next] = true;
        for j in 0..n {
            mind[j] = mind[j].min(dist.get(j, next) as f64);
        }
    }
    medoids
}

/// Cost cross-over: below this many BUILD operations (≈ n²·k), BUILD's
/// better starting point is worth it; above, D² sampling + eager swap wins.
/// Measured (examples/perf_profile §3): identical final objective from
/// either init at m ≥ 128, while BUILD costs 7× at m=512 and 120× at
/// m=1024 — so the limit sits just above the tiny-instance regime.
const BUILD_OPS_LIMIT: usize = 1 << 20;

/// Run FasterPAM; returns the medoid indices (unordered).
pub fn solve(dist: &DistMatrix, k: usize, rng: &mut Rng) -> Vec<usize> {
    solve_par(dist, k, rng, 1)
}

/// [`solve`] with the BUILD scans and SWAP candidate evaluation sharded
/// over `workers` threads — bit-identical to the sequential solver at any
/// worker count (see the module docs for the merge discipline).
pub fn solve_par(dist: &DistMatrix, k: usize, rng: &mut Rng, workers: usize) -> Vec<usize> {
    let n = dist.n;
    let use_build = n.saturating_mul(n).saturating_mul(k) <= BUILD_OPS_LIMIT;
    solve_with_init_par(dist, k, rng, use_build, workers)
}

/// FasterPAM with an explicit initialization choice (exposed for the perf
/// harness and ablations; [`solve`] picks automatically).
pub fn solve_with_init(dist: &DistMatrix, k: usize, rng: &mut Rng, use_build: bool) -> Vec<usize> {
    solve_with_init_par(dist, k, rng, use_build, 1)
}

/// [`solve_with_init`] sharded over `workers` threads.
pub fn solve_with_init_par(
    dist: &DistMatrix,
    k: usize,
    rng: &mut Rng,
    use_build: bool,
    workers: usize,
) -> Vec<usize> {
    let n = dist.n;
    if k >= n {
        return (0..n).collect();
    }
    let medoids = if use_build {
        build_init_par(dist, k, workers)
    } else {
        dsq_init(dist, k, rng)
    };
    if k == n - 1 {
        // Every non-medoid point is the single outsider; BUILD is optimal.
        return medoids;
    }
    swap_refine(dist, medoids, rng, workers)
}

/// Warm-start FasterPAM (§4.3 incremental path): skip initialization and
/// re-run only the eager-SWAP sweeps on a previous round's medoid set.
///
/// `cached` must hold `1 ≤ k < n` distinct in-range indices — callers
/// validate and fall back to a cold solve otherwise (see
/// [`super::select_warm`]). Consumes one shuffle from `rng` for the
/// candidate order, exactly like the cold SWAP phase.
pub fn solve_warm(dist: &DistMatrix, cached: &[usize], rng: &mut Rng, workers: usize) -> Vec<usize> {
    let n = dist.n;
    let medoids = cached.to_vec();
    debug_assert!(!medoids.is_empty() && medoids.iter().all(|&m| m < n));
    if medoids.len() >= n {
        return (0..n).collect();
    }
    if medoids.len() == n - 1 {
        return medoids;
    }
    swap_refine(dist, medoids, rng, workers)
}

/// One eager-SWAP candidate evaluation against *frozen* caches: the swap
/// gain of candidate `c` (whose distance row is `row`) against all k
/// medoids. Returns `(best_i, best_delta, acc)` — a pure function of
/// `(near, removal, row)`, so workers may evaluate candidates concurrently
/// and still reproduce the sequential result. Tie-breaks follow
/// `Iterator::min_by` exactly (the **last** minimal slot wins), matching
/// the historical sequential code.
fn eval_candidate(near: &[Near], removal: &[f64], row: &[f32]) -> (usize, f64, f64) {
    let mut delta = removal.to_vec();
    let mut acc = 0.0f64;
    for (nj, &dcj) in near.iter().zip(row) {
        if dcj < nj.d1 {
            // j defects to c; removing j's old nearest no longer costs d2.
            acc += (dcj - nj.d1) as f64;
            delta[nj.n1 as usize] += (nj.d1 - nj.d2) as f64;
        } else if dcj < nj.d2 {
            // If j's nearest were removed, j now goes to c, not d2.
            delta[nj.n1 as usize] += (dcj - nj.d2) as f64;
        }
    }
    let (best_i, best_delta) = delta
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, &v)| (i, v))
        .unwrap();
    (best_i, best_delta, acc)
}

/// The eager-SWAP refinement loop shared by the cold and warm entry
/// points. `workers ≤ 1` is the historical sequential loop verbatim;
/// `workers > 1` evaluates a lookahead window of candidates in parallel
/// and replays the sequential accept/reject walk over it — the first
/// applied swap discards the rest of the window (those evaluations are
/// stale), so the first-improvement order is preserved bit-for-bit.
fn swap_refine(
    dist: &DistMatrix,
    mut medoids: Vec<usize>,
    rng: &mut Rng,
    workers: usize,
) -> Vec<usize> {
    let n = dist.n;
    let k = medoids.len();
    let mut near = vec![Near { n1: 0, n2: 0, d1: 0.0, d2: 0.0 }; n];
    rebuild_cache(dist, &medoids, &mut near);
    let mut removal = vec![0.0f64; k];
    removal_losses(&near, &mut removal);
    let mut is_medoid = vec![false; n];
    for &m in &medoids {
        is_medoid[m] = true;
    }

    // Scale-aware acceptance threshold: ignore "improvements" below a 1e-6
    // fraction of the mean nearest distance (pure float noise on ties).
    let mean_d1: f64 =
        near.iter().map(|x| x.d1 as f64).sum::<f64>() / n as f64;
    let eps = -1e-6 * (mean_d1 + 1e-12);

    // Randomized candidate order decorrelates eager-swap scan bias.
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);

    let mut since_improved = 0usize;
    let mut pos = 0usize;
    // Practical swap budget: eager FasterPAM converges in O(k) swaps; the
    // cap guards degenerate inputs without affecting normal runs.
    let max_swaps = 20 * k + 200;
    let mut swaps = 0usize;

    if workers <= 1 {
        let mut delta = vec![0.0f64; k];
        while since_improved < n && swaps < max_swaps {
            let c = order[pos % n];
            pos += 1;
            if is_medoid[c] {
                since_improved += 1;
                continue;
            }

            delta.copy_from_slice(&removal);
            let mut acc = 0.0f64;
            // One contiguous row of the matrix: d(c, ·).
            let row = &dist.d[c * n..(c + 1) * n];
            for (nj, &dcj) in near.iter().zip(row) {
                if dcj < nj.d1 {
                    acc += (dcj - nj.d1) as f64;
                    delta[nj.n1 as usize] += (nj.d1 - nj.d2) as f64;
                } else if dcj < nj.d2 {
                    delta[nj.n1 as usize] += (dcj - nj.d2) as f64;
                }
            }

            let (best_i, best_delta) = delta
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, &v)| (i, v))
                .unwrap();

            if best_delta + acc < eps {
                let old = medoids[best_i];
                is_medoid[old] = false;
                is_medoid[c] = true;
                medoids[best_i] = c;
                update_cache_after_swap(dist, &medoids, &mut near, best_i, c);
                removal_losses(&near, &mut removal);
                since_improved = 0;
                swaps += 1;
            } else {
                since_improved += 1;
            }
        }
        return medoids;
    }

    // Parallel windowed walk. Window size only trades wasted lookahead
    // against parallelism — the result is window-size-invariant, because
    // candidates before the first accepted swap see exactly the state the
    // sequential loop would, and everything after it is re-evaluated.
    let window = workers * 4;
    while since_improved < n && swaps < max_swaps {
        let win: Vec<usize> = (0..window).map(|w| order[(pos + w) % n]).collect();
        let (near_ref, removal_ref, is_medoid_ref) = (&near, &removal, &is_medoid);
        let evals = parallel_map(win.clone(), workers, |c| {
            if is_medoid_ref[c] {
                None
            } else {
                Some(eval_candidate(near_ref, removal_ref, &dist.d[c * n..(c + 1) * n]))
            }
        });
        for (c, ev) in win.into_iter().zip(evals) {
            pos += 1;
            let improved = match ev {
                None => false,
                Some((best_i, best_delta, acc)) => {
                    if best_delta + acc < eps {
                        let old = medoids[best_i];
                        is_medoid[old] = false;
                        is_medoid[c] = true;
                        medoids[best_i] = c;
                        update_cache_after_swap(dist, &medoids, &mut near, best_i, c);
                        removal_losses(&near, &mut removal);
                        since_improved = 0;
                        swaps += 1;
                        true
                    } else {
                        false
                    }
                }
            };
            if !improved {
                since_improved += 1;
            }
            // A swap invalidates the remaining lookahead evaluations; the
            // termination checks mirror the sequential loop head.
            if improved || since_improved >= n || swaps >= max_swaps {
                break;
            }
        }
    }
    medoids
}

/// Total deviation of a medoid set (Σⱼ minₖ d) — exposed for benches.
pub fn total_deviation(dist: &DistMatrix, medoids: &[usize]) -> f64 {
    super::objective(dist, medoids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::{objective, Method};

    fn random_dist(rng: &mut Rng, n: usize, dim: usize) -> DistMatrix {
        let f: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        super::super::distance::from_features_cpu(&f, n, dim)
    }

    /// Exhaustive k-medoids for tiny instances.
    fn brute_force(dist: &DistMatrix, k: usize) -> (Vec<usize>, f64) {
        fn rec(
            dist: &DistMatrix,
            k: usize,
            start: usize,
            cur: &mut Vec<usize>,
            best: &mut (Vec<usize>, f64),
        ) {
            if cur.len() == k {
                let c = objective(dist, cur);
                if c < best.1 {
                    *best = (cur.clone(), c);
                }
                return;
            }
            for i in start..dist.n {
                cur.push(i);
                rec(dist, k, i + 1, cur, best);
                cur.pop();
            }
        }
        let mut best = (vec![], f64::INFINITY);
        rec(dist, k, 0, &mut vec![], &mut best);
        best
    }

    #[test]
    fn matches_brute_force_on_tiny_instances() {
        for seed in 0..8 {
            let mut rng = Rng::new(seed);
            let dist = random_dist(&mut rng, 10, 3);
            for k in [1, 2, 3] {
                let got = solve(&dist, k, &mut rng);
                let got_cost = objective(&dist, &got);
                let (_, want_cost) = brute_force(&dist, k);
                // FasterPAM is a local search; it should usually hit the
                // optimum on these tiny instances and never be far off.
                assert!(
                    got_cost <= want_cost * 1.05 + 1e-9,
                    "seed {seed} k {k}: got {got_cost}, optimum {want_cost}"
                );
            }
        }
    }

    #[test]
    fn swap_never_worse_than_build() {
        for seed in 0..6 {
            let mut rng = Rng::new(100 + seed);
            let dist = random_dist(&mut rng, 60, 4);
            let k = 6;
            let build = build_init(&dist, k);
            let build_cost = objective(&dist, &build);
            let solved = solve(&dist, k, &mut rng);
            let solved_cost = objective(&dist, &solved);
            assert!(
                solved_cost <= build_cost + 1e-9,
                "seed {seed}: {solved_cost} > build {build_cost}"
            );
        }
    }

    #[test]
    fn incremental_cache_matches_full_rebuild() {
        // After a forced swap, the incremental update must agree with a
        // from-scratch rebuild on every point.
        let mut rng = Rng::new(31);
        let dist = random_dist(&mut rng, 40, 3);
        let mut medoids = build_init(&dist, 5);
        let mut near = vec![Near { n1: 0, n2: 0, d1: 0.0, d2: 0.0 }; 40];
        rebuild_cache(&dist, &medoids, &mut near);
        // swap slot 2 for an arbitrary non-medoid
        let c = (0..40).find(|i| !medoids.contains(i)).unwrap();
        medoids[2] = c;
        update_cache_after_swap(&dist, &medoids, &mut near, 2, c);
        let mut fresh = vec![Near { n1: 0, n2: 0, d1: 0.0, d2: 0.0 }; 40];
        rebuild_cache(&dist, &medoids, &mut fresh);
        for j in 0..40 {
            assert_eq!(near[j].d1, fresh[j].d1, "d1 mismatch at {j}");
            assert_eq!(near[j].d2, fresh[j].d2, "d2 mismatch at {j}");
            assert_eq!(near[j].n1, fresh[j].n1, "n1 mismatch at {j}");
        }
    }

    #[test]
    fn beats_random_selection() {
        let mut rng = Rng::new(42);
        let dist = random_dist(&mut rng, 120, 6);
        let k = 10;
        let fp = solve(&dist, k, &mut rng);
        let fp_cost = objective(&dist, &fp);
        let mut worse = 0;
        for _ in 0..20 {
            let rnd = rng.choose_k(dist.n, k);
            if objective(&dist, &rnd) >= fp_cost {
                worse += 1;
            }
        }
        assert!(worse >= 19, "random beat FasterPAM {}/20 times", 20 - worse);
    }

    #[test]
    fn returns_k_distinct_medoids() {
        let mut rng = Rng::new(7);
        let dist = random_dist(&mut rng, 50, 4);
        for k in [1, 5, 17, 49] {
            let m = solve(&dist, k, &mut rng);
            let mut s = m.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "k={k}");
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn duplicate_points_are_harmless() {
        // All points identical: any medoid set has cost 0 and the noise
        // threshold must prevent swap churn.
        let dist = DistMatrix { n: 6, d: vec![0.0; 36] };
        let mut rng = Rng::new(8);
        let m = solve(&dist, 2, &mut rng);
        assert_eq!(m.len(), 2);
        assert_eq!(objective(&dist, &m), 0.0);
    }

    #[test]
    fn clustered_data_with_large_k_terminates_fast() {
        // The regression behind the swap-budget + noise threshold: many
        // near-tied medoid placements inside tight clusters.
        let mut rng = Rng::new(9);
        let n = 400;
        let f: Vec<f32> = (0..n)
            .flat_map(|i| {
                let c = (i % 10) as f32;
                [c * 10.0 + 0.01 * rng.normal() as f32, c * 10.0]
            })
            .collect();
        let dist = super::super::distance::from_features_cpu(&f, n, 2);
        let t0 = std::time::Instant::now();
        let m = solve(&dist, 40, &mut rng);
        assert!(t0.elapsed().as_secs_f64() < 2.0, "took {:?}", t0.elapsed());
        assert_eq!(m.len(), 40);
    }

    #[test]
    fn method_enum_dispatches_here() {
        let mut rng = Rng::new(9);
        let dist = random_dist(&mut rng, 30, 3);
        let cs = crate::coreset::select(&dist, 5, Method::FasterPam, &mut rng);
        assert_eq!(cs.len(), 5);
        assert_eq!(cs.total_weight(), 30.0);
    }

    #[test]
    fn parallel_solver_is_bitwise_sequential() {
        // The unit-level anchor for tests/proptest_coreset.rs: the same
        // seed must yield identical medoids at every worker count, for
        // both inits (BUILD on small n, D² on the forced path).
        for seed in 0..4 {
            for use_build in [true, false] {
                let mut rng = Rng::new(200 + seed);
                let dist = random_dist(&mut rng, 70, 4);
                let mut seq_rng = Rng::new(300 + seed);
                let seq = solve_with_init(&dist, 7, &mut seq_rng, use_build);
                for workers in [2, 4, 8] {
                    let mut par_rng = Rng::new(300 + seed);
                    let par = solve_with_init_par(&dist, 7, &mut par_rng, use_build, workers);
                    assert_eq!(seq, par, "seed {seed} build {use_build} workers {workers}");
                }
            }
        }
    }

    #[test]
    fn build_chunk_merge_preserves_first_best_ties() {
        // All-zero distances: every candidate ties on total distance and
        // gain, so the sequential scan keeps index 0 then ascending — the
        // chunk-order merge must reproduce exactly that at any width.
        let dist = DistMatrix { n: 9, d: vec![0.0; 81] };
        let seq = build_init(&dist, 4);
        for workers in [2, 3, 4, 8] {
            assert_eq!(build_init_par(&dist, 4, workers), seq, "workers {workers}");
        }
    }

    #[test]
    fn identical_points_do_not_churn_in_parallel() {
        // Pins the 1e-6 scale-aware threshold on the windowed path: with
        // all-zero distances every swap "gain" is float noise, so the
        // parallel walk must terminate without churn like the sequential
        // one (see duplicate_points_are_harmless).
        let dist = DistMatrix { n: 6, d: vec![0.0; 36] };
        let mut seq_rng = Rng::new(8);
        let seq = solve(&dist, 2, &mut seq_rng);
        let mut par_rng = Rng::new(8);
        let par = solve_par(&dist, 2, &mut par_rng, 4);
        assert_eq!(seq, par);
        assert_eq!(objective(&dist, &par), 0.0);
    }

    #[test]
    fn k1_and_single_point_edges() {
        let mut rng = Rng::new(13);
        let dist = random_dist(&mut rng, 20, 3);
        // k = 1: the medoid is the point minimizing total distance, at
        // every worker count.
        let mut a = Rng::new(14);
        let mut b = Rng::new(14);
        assert_eq!(solve(&dist, 1, &mut a), solve_par(&dist, 1, &mut b, 4));
        // Single-point client: k ≥ n short-circuits to the identity.
        let one = DistMatrix { n: 1, d: vec![0.0] };
        let mut rng = Rng::new(15);
        assert_eq!(solve_par(&one, 1, &mut rng, 4), vec![0]);
    }

    #[test]
    fn warm_start_refines_cached_medoids() {
        let mut rng = Rng::new(21);
        let dist = random_dist(&mut rng, 50, 4);
        let cold = solve(&dist, 5, &mut Rng::new(22));
        // Warm from the cold answer: SWAP finds no improvement, so the
        // set is stable (as a set — slots may permute through finalize).
        let warm = solve_warm(&dist, &cold, &mut Rng::new(23), 2);
        let (mut c, mut w) = (cold.clone(), warm.clone());
        c.sort_unstable();
        w.sort_unstable();
        assert!(objective(&dist, &warm) <= objective(&dist, &cold) + 1e-9);
        assert_eq!(c, w, "a converged set must be a SWAP fixed point");
        // Warm from a deliberately bad seed still ends ≤ the seed's cost.
        let bad: Vec<usize> = (0..5).collect();
        let refined = solve_warm(&dist, &bad, &mut Rng::new(24), 4);
        assert!(objective(&dist, &refined) <= objective(&dist, &bad) + 1e-9);
        assert_eq!(refined.len(), 5);
    }
}
