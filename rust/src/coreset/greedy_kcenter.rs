//! Greedy k-center (farthest-point traversal) — geometry-based ablation
//! baseline (the "Geometry Based Clustering" family in the paper's §2).
//!
//! Optimizes the *max* distance objective (2-approximation for k-center),
//! not the k-medoids *sum*; the ablation bench shows it covers outliers
//! well but yields a worse Eq. (5) objective than FasterPAM on typical
//! gradient clouds.

use super::DistMatrix;
use crate::util::rng::Rng;

/// Pick `k` centers by farthest-point traversal from a random start.
pub fn solve(dist: &DistMatrix, k: usize, rng: &mut Rng) -> Vec<usize> {
    let n = dist.n;
    let k = k.min(n);
    if k == 0 {
        return vec![];
    }
    // Deterministic-ish start: a random point (the classic algorithm is
    // robust to the choice; rng keeps ablation runs honest).
    let first = rng.below(n);
    let mut medoids = vec![first];
    let mut mind: Vec<f32> = (0..n).map(|j| dist.get(j, first)).collect();
    let mut selected = vec![false; n];
    selected[first] = true;
    while medoids.len() < k {
        // Farthest not-yet-selected point (ties break low-index; all-zero
        // distance matrices still yield k distinct medoids).
        let far = (0..n)
            .filter(|&j| !selected[j])
            .max_by(|&a, &b| mind[a].partial_cmp(&mind[b]).unwrap())
            .expect("k <= n");
        selected[far] = true;
        medoids.push(far);
        for j in 0..n {
            mind[j] = mind[j].min(dist.get(j, far));
        }
    }
    medoids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::distance::from_features_cpu;

    #[test]
    fn covers_all_clusters() {
        // 5 clusters; k-center must touch each (it is a covering algorithm).
        let mut rng = Rng::new(4);
        let mut f = Vec::new();
        for c in 0..5 {
            for _ in 0..8 {
                f.push(100.0 * c as f32 + rng.normal() as f32);
            }
        }
        let dist = from_features_cpu(&f, 40, 1);
        let m = solve(&dist, 5, &mut rng);
        let mut clusters: Vec<usize> = m.iter().map(|&i| i / 8).collect();
        clusters.sort_unstable();
        clusters.dedup();
        assert_eq!(clusters.len(), 5);
    }

    #[test]
    fn max_radius_is_2_approx_on_line() {
        // Points 0..=9 on a line, k=2: optimal max-radius is 2.25 (centers
        // at 2 and 7). Greedy must stay within 2x.
        let pts: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let dist = from_features_cpu(&pts, 10, 1);
        let mut rng = Rng::new(5);
        let m = solve(&dist, 2, &mut rng);
        let radius = (0..10)
            .map(|j| m.iter().map(|&c| dist.get(j, c)).fold(f32::INFINITY, f32::min))
            .fold(0.0f32, f32::max);
        assert!(radius <= 2.0 * 2.5 + 1e-6, "radius {radius}");
    }

    #[test]
    fn k_clamped_to_n() {
        let dist = DistMatrix { n: 3, d: vec![0.0; 9] };
        let mut rng = Rng::new(6);
        assert_eq!(solve(&dist, 10, &mut rng).len(), 3);
    }
}
