//! Distributed coreset construction — the paper's core contribution
//! (sections 3.2, 4.2, 4.3).
//!
//! Given per-sample gradient features fⱼ (the §4.3 d̂ proxies, produced by
//! the L2 `grad_features` artifact), the coreset problem Eq. (2) is upper-
//! bounded by the k-medoids objective Eq. (5):
//!
//! ```text
//!   min_{S ⊆ V, |S| ≤ b}  Σ_{j ∈ V}  min_{k ∈ S} ‖fⱼ − fₖ‖
//! ```
//!
//! with weights δₖ = |{j : Φ(j) = k}| counting the points assigned to each
//! medoid. [`fasterpam`] is the paper's solver; [`pam`], [`random`] and
//! [`greedy_kcenter`] are ablation baselines (DESIGN.md §3).

pub mod distance;
pub mod fasterpam;
pub mod greedy_kcenter;
pub mod pam;
pub mod random;

pub use distance::DistMatrix;

use crate::util::rng::Rng;

/// A selected coreset: sample indices (into the client's local set), their
/// integer weights δ*, and the k-medoids objective value achieved.
#[derive(Clone, Debug)]
pub struct Coreset {
    /// Medoid sample indices S*, ascending.
    pub indices: Vec<usize>,
    /// δ*ₖ = number of samples assigned to medoid k (aligned with `indices`).
    pub deltas: Vec<f32>,
    /// Σⱼ minₖ d(j, k) — the Eq. (5) objective at the returned S*.
    pub cost: f64,
}

impl Coreset {
    /// Number of selected medoids (b, the coreset size).
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// True when no medoid was selected (empty client).
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Σ δₖ — must equal the client's full-set size m (every point is
    /// assigned to exactly one medoid).
    pub fn total_weight(&self) -> f64 {
        self.deltas.iter().map(|&d| d as f64).sum()
    }

    /// The degenerate "coreset = full set" used when b ≥ m.
    pub fn identity(m: usize) -> Coreset {
        Coreset {
            indices: (0..m).collect(),
            deltas: vec![1.0; m],
            cost: 0.0,
        }
    }
}

/// Which k-medoids solver to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// FasterPAM (Schubert & Rousseeuw 2021) — the paper's choice (§4.2).
    FasterPam,
    /// Classic PAM BUILD + SWAP — ablation baseline.
    Pam,
    /// Uniform random subset — ablation baseline.
    Random,
    /// Greedy k-center (farthest-point) — geometry-based ablation baseline.
    GreedyKCenter,
}

impl Method {
    /// Parse a solver name (`fasterpam` | `pam` | `random` | `kcenter`,
    /// with common aliases; case-insensitive).
    pub fn parse(s: &str) -> Option<Method> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fasterpam" | "faster-pam" => Some(Method::FasterPam),
            "pam" => Some(Method::Pam),
            "random" => Some(Method::Random),
            "kcenter" | "k-center" | "greedy" | "greedykcenter" => Some(Method::GreedyKCenter),
            _ => None,
        }
    }

    /// Display name (parsable back via [`Method::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            Method::FasterPam => "FasterPAM",
            Method::Pam => "PAM",
            Method::Random => "Random",
            Method::GreedyKCenter => "GreedyKCenter",
        }
    }
}

/// Solve Eq. (5): pick ≤ `k` medoids from the `dist.n` points.
///
/// Returns the full-set identity when `k ≥ n` (no compression needed) and
/// clamps `k` to ≥ 1 otherwise.
pub fn select(dist: &DistMatrix, k: usize, method: Method, rng: &mut Rng) -> Coreset {
    select_par(dist, k, method, rng, 1)
}

/// [`select`] with the FasterPAM hot path sharded over `workers` threads —
/// bit-identical to the sequential selection at any worker count (the
/// ablation baselines stay sequential; they are not on the hot path).
pub fn select_par(
    dist: &DistMatrix,
    k: usize,
    method: Method,
    rng: &mut Rng,
    workers: usize,
) -> Coreset {
    let n = dist.n;
    if n == 0 {
        return Coreset { indices: vec![], deltas: vec![], cost: 0.0 };
    }
    if k >= n {
        return Coreset::identity(n);
    }
    let k = k.max(1);
    let medoids = match method {
        Method::FasterPam => fasterpam::solve_par(dist, k, rng, workers),
        Method::Pam => pam::solve(dist, k, rng),
        Method::Random => random::solve(dist, k, rng),
        Method::GreedyKCenter => greedy_kcenter::solve(dist, k, rng),
    };
    finalize(dist, medoids)
}

/// Warm-start selection (§4.3 incremental path): re-run only the FasterPAM
/// SWAP sweeps on a cached medoid set from a previous round.
///
/// Falls back to a cold [`select_par`] whenever the cache is unusable —
/// wrong method, out-of-range or duplicate indices (the client's shard
/// shrank), or a cached size that no longer matches the budget `k`.
pub fn select_warm(
    dist: &DistMatrix,
    k: usize,
    method: Method,
    cached: &[usize],
    rng: &mut Rng,
    workers: usize,
) -> Coreset {
    let n = dist.n;
    if n == 0 {
        return Coreset { indices: vec![], deltas: vec![], cost: 0.0 };
    }
    if k >= n {
        return Coreset::identity(n);
    }
    let k = k.max(1);
    let mut seed: Vec<usize> = cached.iter().copied().filter(|&i| i < n).collect();
    seed.sort_unstable();
    seed.dedup();
    if method != Method::FasterPam || seed.len() != k {
        return select_par(dist, k, method, rng, workers);
    }
    finalize(dist, fasterpam::solve_warm(dist, &seed, rng, workers))
}

/// Assign every point to its nearest medoid and compute (δ*, cost).
pub fn finalize(dist: &DistMatrix, mut medoids: Vec<usize>) -> Coreset {
    medoids.sort_unstable();
    medoids.dedup();
    let n = dist.n;
    let mut deltas = vec![0.0f32; medoids.len()];
    let mut cost = 0.0f64;
    for j in 0..n {
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for (mi, &m) in medoids.iter().enumerate() {
            let d = dist.get(j, m);
            if d < best_d {
                best_d = d;
                best = mi;
            }
        }
        deltas[best] += 1.0;
        cost += best_d as f64;
    }
    Coreset { indices: medoids, deltas, cost }
}

/// Objective value Σⱼ minₖ d(j, k) for an arbitrary medoid set (used by
/// tests and ablations to compare solvers).
pub fn objective(dist: &DistMatrix, medoids: &[usize]) -> f64 {
    let mut cost = 0.0f64;
    for j in 0..dist.n {
        let mut best = f32::INFINITY;
        for &m in medoids {
            best = best.min(dist.get(j, m));
        }
        cost += best as f64;
    }
    cost
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated 1-D clusters; medoids must pick one per cluster.
    pub(crate) fn clustered_dist() -> (DistMatrix, Vec<usize>) {
        let pts: Vec<f32> = vec![0.0, 0.1, 0.2, 10.0, 10.1, 10.2, 20.0, 20.1, 20.2];
        let n = pts.len();
        let mut d = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                d[i * n + j] = (pts[i] - pts[j]).abs();
            }
        }
        (DistMatrix { n, d }, vec![1, 4, 7]) // cluster centers
    }

    #[test]
    fn select_clamps_and_identity() {
        let (dist, _) = clustered_dist();
        let mut rng = Rng::new(1);
        let id = select(&dist, 100, Method::FasterPam, &mut rng);
        assert_eq!(id.len(), 9);
        assert_eq!(id.total_weight(), 9.0);
        assert_eq!(id.cost, 0.0);
    }

    #[test]
    fn every_method_solves_plantable_clusters() {
        let (dist, want) = clustered_dist();
        for method in [Method::FasterPam, Method::Pam, Method::GreedyKCenter] {
            let mut rng = Rng::new(2);
            let cs = select(&dist, 3, method, &mut rng);
            assert_eq!(cs.len(), 3, "{method:?}");
            // One medoid per cluster (any member of the cluster is fine for
            // k-center; PAM/FasterPAM should find the exact centers).
            let clusters: Vec<usize> = cs.indices.iter().map(|&i| i / 3).collect();
            let mut c = clusters.clone();
            c.dedup();
            assert_eq!(c.len(), 3, "{method:?}: {:?}", cs.indices);
            if method != Method::GreedyKCenter {
                assert_eq!(cs.indices, want, "{method:?}");
            }
            assert_eq!(cs.total_weight(), 9.0, "{method:?}");
        }
    }

    #[test]
    fn deltas_count_assignments() {
        let (dist, _) = clustered_dist();
        let cs = finalize(&dist, vec![1, 4, 7]);
        assert_eq!(cs.deltas, vec![3.0, 3.0, 3.0]);
        assert!((cs.cost - 6.0 * 0.1).abs() < 1e-5);
    }

    #[test]
    fn objective_matches_finalize_cost() {
        let (dist, _) = clustered_dist();
        let cs = finalize(&dist, vec![0, 3, 8]);
        assert!((objective(&dist, &cs.indices) - cs.cost).abs() < 1e-9);
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::FasterPam, Method::Pam, Method::Random, Method::GreedyKCenter] {
            assert_eq!(Method::parse(m.label()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn empty_input() {
        let dist = DistMatrix { n: 0, d: vec![] };
        let mut rng = Rng::new(3);
        let cs = select(&dist, 4, Method::FasterPam, &mut rng);
        assert!(cs.is_empty());
    }

    #[test]
    fn select_par_identity_when_budget_covers_set() {
        // b ≥ m short-circuits to the identity on the parallel path too.
        let (dist, _) = clustered_dist();
        let mut rng = Rng::new(4);
        let cs = select_par(&dist, 9, Method::FasterPam, &mut rng, 4);
        assert_eq!(cs.len(), 9);
        assert_eq!(cs.cost, 0.0);
    }

    #[test]
    fn select_warm_reuses_a_valid_cache() {
        let (dist, want) = clustered_dist();
        let warm = select_warm(&dist, 3, Method::FasterPam, &[1, 4, 7], &mut Rng::new(5), 2);
        // The planted centers are optimal: SWAP keeps them.
        assert_eq!(warm.indices, want);
        assert_eq!(warm.total_weight(), 9.0);
    }

    #[test]
    fn select_warm_falls_back_cold_on_bad_cache() {
        let (dist, _) = clustered_dist();
        for cached in [vec![], vec![1, 4], vec![1, 1, 4], vec![1, 4, 99]] {
            // Wrong size / duplicates / out-of-range ⇒ a cold solve, which
            // must match select_par exactly (same RNG consumption).
            let warm =
                select_warm(&dist, 3, Method::FasterPam, &cached, &mut Rng::new(6), 2);
            let cold = select_par(&dist, 3, Method::FasterPam, &mut Rng::new(6), 2);
            assert_eq!(warm.indices, cold.indices, "cache {cached:?}");
            assert_eq!(warm.cost.to_bits(), cold.cost.to_bits(), "cache {cached:?}");
        }
        // Non-FasterPAM methods never warm-start.
        let warm = select_warm(&dist, 3, Method::Pam, &[1, 4, 7], &mut Rng::new(7), 1);
        let cold = select_par(&dist, 3, Method::Pam, &mut Rng::new(7), 1);
        assert_eq!(warm.indices, cold.indices);
    }

    #[test]
    fn select_warm_identity_and_empty_edges() {
        let (dist, _) = clustered_dist();
        let id = select_warm(&dist, 100, Method::FasterPam, &[1, 4, 7], &mut Rng::new(8), 4);
        assert_eq!(id.len(), 9);
        let empty = DistMatrix { n: 0, d: vec![] };
        let cs = select_warm(&empty, 3, Method::FasterPam, &[0], &mut Rng::new(9), 4);
        assert!(cs.is_empty());
    }
}
