//! Pairwise gradient-distance matrix — the k-medoids input (paper §4.3).
//!
//! The m×m matrix d̂ⱼₖ = ‖fⱼ − fₖ‖₂ over per-sample gradient features is
//! produced two ways:
//!
//! * [`from_features_tiled`] — the production path: tiles the matrix with
//!   the L1 **Pallas** artifact (`pairwise_dist.hlo.txt`, one T×T block per
//!   PJRT call), exploiting symmetry by computing only the upper-triangle
//!   blocks and mirroring.
//! * [`from_features_cpu`] — a pure-rust reference used for cross-checking
//!   the kernel and for configurations without artifacts.

use anyhow::Result;

use crate::runtime::Runtime;
use crate::util::pool::parallel_map;

/// CPU tile edge (points per side) for the blocked parallel path — the
/// same 128×128 blocking the Pallas artifact uses (`pairwise_tile` in the
/// manifest), so the CPU and artifact paths share one tiling plan.
pub const CPU_TILE: usize = 128;

/// Dense symmetric distance matrix, row-major `n × n`.
#[derive(Clone, Debug)]
pub struct DistMatrix {
    /// Number of points (rows = columns).
    pub n: usize,
    /// Row-major `n × n` distances.
    pub d: Vec<f32>,
}

impl DistMatrix {
    /// d(i, j), unchecked beyond slice bounds.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.d[i * self.n + j]
    }

    /// Max |d(i,j) − d(j,i)| — sanity metric for the tiled path.
    pub fn asymmetry(&self) -> f32 {
        let mut worst = 0.0f32;
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                worst = worst.max((self.get(i, j) - self.get(j, i)).abs());
            }
        }
        worst
    }
}

/// One pairwise distance ‖fᵢ − fⱼ‖₂ with f64 accumulation. Every matrix
/// entry is an independent pure function of the two feature rows, so the
/// sequential and tiled-parallel paths produce bit-identical values.
#[inline]
fn pair_dist(features: &[f32], dim: usize, i: usize, j: usize) -> f32 {
    let (fi, fj) = (&features[i * dim..(i + 1) * dim], &features[j * dim..(j + 1) * dim]);
    let mut acc = 0.0f64;
    for k in 0..dim {
        let diff = (fi[k] - fj[k]) as f64;
        acc += diff * diff;
    }
    acc.sqrt() as f32
}

/// Exact CPU reference: d(i,j) = ‖fᵢ − fⱼ‖₂ with f64 accumulation.
pub fn from_features_cpu(features: &[f32], n: usize, dim: usize) -> DistMatrix {
    from_features_cpu_par(features, n, dim, 1)
}

/// Blocked-parallel CPU path: the upper triangle is cut into the same
/// [`CPU_TILE`]² blocks the Pallas artifact uses, the tiles are dealt to a
/// scoped worker pool, and results reduce in tile order (each entry is
/// written exactly once, then mirrored). `workers ≤ 1` runs the plain
/// sequential double loop; both paths emit **bit-identical** matrices —
/// see `tests/proptest_coreset.rs`.
pub fn from_features_cpu_par(features: &[f32], n: usize, dim: usize, workers: usize) -> DistMatrix {
    assert_eq!(features.len(), n * dim, "features shape");
    let mut d = vec![0.0f32; n * n];
    if workers <= 1 || n <= 1 {
        for i in 0..n {
            for j in (i + 1)..n {
                let v = pair_dist(features, dim, i, j);
                d[i * n + j] = v;
                d[j * n + i] = v;
            }
        }
        return DistMatrix { n, d };
    }

    let t = CPU_TILE;
    let blocks = n.div_ceil(t);
    // Upper-triangle tiles in (bi, bj) row-major order; `parallel_map`
    // returns them in that same order regardless of which worker ran what.
    let tiles: Vec<(usize, usize)> =
        (0..blocks).flat_map(|bi| (bi..blocks).map(move |bj| (bi, bj))).collect();
    let done = parallel_map(tiles, workers, |(bi, bj)| {
        let rows_i = (n - bi * t).min(t);
        let rows_j = (n - bj * t).min(t);
        let mut block = vec![0.0f32; rows_i * rows_j];
        for r in 0..rows_i {
            let gi = bi * t + r;
            // Diagonal tiles compute the strict upper triangle only
            // (d(i,i) = 0 and the mirror fills the rest).
            let c0 = if bi == bj { r + 1 } else { 0 };
            for c in c0..rows_j {
                block[r * rows_j + c] = pair_dist(features, dim, gi, bj * t + c);
            }
        }
        (bi, bj, rows_i, rows_j, block)
    });
    for (bi, bj, rows_i, rows_j, block) in done {
        for r in 0..rows_i {
            let gi = bi * t + r;
            let c0 = if bi == bj { r + 1 } else { 0 };
            for c in c0..rows_j {
                let gj = bj * t + c;
                let v = block[r * rows_j + c];
                d[gi * n + gj] = v;
                d[gj * n + gi] = v;
            }
        }
    }
    DistMatrix { n, d }
}

/// Production path: tile the n×n matrix with the T×T Pallas artifact.
///
/// Features are padded with zero rows to a multiple of T; padded distances
/// are computed but never copied out. Symmetric blocks (i > j) are mirrored
/// from their transpose instead of re-executed, halving PJRT calls.
pub fn from_features_tiled(rt: &Runtime, features: &[f32], n: usize) -> Result<DistMatrix> {
    let t = rt.manifest().pairwise_tile;
    let dim = rt.manifest().pairwise_dim;
    assert_eq!(features.len(), n * dim, "features must be n × pairwise_dim");
    if n == 0 {
        return Ok(DistMatrix { n: 0, d: vec![] });
    }

    let blocks = n.div_ceil(t);
    // One reusable zero-padded tile buffer per side.
    let mut a_tile = vec![0.0f32; t * dim];
    let mut b_tile = vec![0.0f32; t * dim];
    let mut d = vec![0.0f32; n * n];

    let fill = |buf: &mut [f32], block: usize| {
        buf.fill(0.0);
        let start = block * t;
        let rows = (n - start).min(t);
        buf[..rows * dim].copy_from_slice(&features[start * dim..(start + rows) * dim]);
        rows
    };

    for bi in 0..blocks {
        let rows_i = fill(&mut a_tile, bi);
        for bj in bi..blocks {
            let rows_j = fill(&mut b_tile, bj);
            let tile = rt.pairwise_tile(&a_tile, &b_tile)?;
            // copy the valid region; mirror into the lower triangle
            for r in 0..rows_i {
                let gi = bi * t + r;
                for c in 0..rows_j {
                    let gj = bj * t + c;
                    let v = tile[r * t + c];
                    d[gi * n + gj] = v;
                    d[gj * n + gi] = v;
                }
            }
        }
    }
    Ok(DistMatrix { n, d })
}

/// Convex-model shortcut (§4.3): distances in the *input* space,
/// d̃ⱼₖ = ‖xⱼ − xₖ‖ — computable once, before training starts. Same math
/// as [`from_features_cpu`] but documented as the static-coreset path.
pub fn from_inputs_static(inputs: &[f32], n: usize, dim: usize) -> DistMatrix {
    from_features_cpu(inputs, n, dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn cpu_matrix_is_metriclike() {
        let mut rng = Rng::new(5);
        let n = 17;
        let dim = 8;
        let f: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        let d = from_features_cpu(&f, n, dim);
        assert_eq!(d.asymmetry(), 0.0);
        for i in 0..n {
            assert_eq!(d.get(i, i), 0.0);
            for j in 0..n {
                assert!(d.get(i, j) >= 0.0);
            }
        }
        // spot triangle inequality
        for (i, j, k) in [(0, 5, 11), (2, 9, 16), (1, 3, 4)] {
            assert!(d.get(i, k) <= d.get(i, j) + d.get(j, k) + 1e-5);
        }
    }

    #[test]
    fn known_distances() {
        // unit square in 2-D
        let f = vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let d = from_features_cpu(&f, 4, 2);
        assert!((d.get(0, 1) - 1.0).abs() < 1e-6);
        assert!((d.get(0, 2) - 1.0).abs() < 1e-6);
        assert!((d.get(0, 3) - 2.0f32.sqrt()).abs() < 1e-6);
        assert!((d.get(1, 2) - 2.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn static_input_path_equals_cpu() {
        let mut rng = Rng::new(6);
        let f: Vec<f32> = (0..12 * 4).map(|_| rng.f32()).collect();
        let a = from_features_cpu(&f, 12, 4);
        let b = from_inputs_static(&f, 12, 4);
        assert_eq!(a.d, b.d);
    }

    #[test]
    fn tiled_parallel_path_is_bitwise_sequential() {
        // n = 300 spans 3×3 tile blocks at CPU_TILE = 128, including ragged
        // edge tiles; every worker count must reproduce the sequential
        // matrix bit-for-bit (each entry is an independent pure function).
        let mut rng = Rng::new(7);
        let (n, dim) = (300, 5);
        let f: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        let seq = from_features_cpu(&f, n, dim);
        for workers in [2, 3, 4, 8] {
            let par = from_features_cpu_par(&f, n, dim, workers);
            assert_eq!(par.n, seq.n);
            for (i, (a, b)) in par.d.iter().zip(&seq.d).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers} entry {i}");
            }
        }
    }

    #[test]
    fn tiled_parallel_path_stays_symmetric() {
        // Regression for the mirror step of the blocked path: asymmetry
        // must be exactly zero (the mirror writes the same f32), diagonal
        // exactly zero, at sizes off the tile boundary on both sides.
        let mut rng = Rng::new(8);
        for n in [1usize, 2, 127, 128, 129] {
            let dim = 3;
            let f: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
            let d = from_features_cpu_par(&f, n, dim, 4);
            assert_eq!(d.asymmetry(), 0.0, "n={n}");
            for i in 0..n {
                assert_eq!(d.get(i, i), 0.0, "n={n} diag {i}");
            }
        }
    }

    #[test]
    fn single_point_and_empty_parallel() {
        let d = from_features_cpu_par(&[1.0, 2.0], 1, 2, 4);
        assert_eq!(d.n, 1);
        assert_eq!(d.d, vec![0.0]);
        let e = from_features_cpu_par(&[], 0, 3, 4);
        assert_eq!(e.n, 0);
        assert!(e.d.is_empty());
    }
}
