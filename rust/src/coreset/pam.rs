//! Classic PAM (Kaufman & Rousseeuw) — BUILD + best-improvement SWAP.
//!
//! Ablation baseline against [`super::fasterpam`]: same BUILD, but each
//! SWAP iteration evaluates all (medoid, candidate) pairs and applies only
//! the single best improving swap — O(n²k) per iteration. Kept for the
//! `ablation_coreset` bench (solution quality parity, runtime gap) and as
//! a correctness oracle for FasterPAM on mid-size instances.

use super::fasterpam::build_init;
use super::DistMatrix;
use crate::util::rng::Rng;

/// ΔTD of swapping `medoids[mi]` out for candidate `c`.
fn swap_delta(dist: &DistMatrix, medoids: &[usize], mi: usize, c: usize) -> f64 {
    let n = dist.n;
    let mut delta = 0.0f64;
    for j in 0..n {
        // current nearest distance, and nearest excluding the removed medoid
        let mut d1 = f32::INFINITY;
        let mut d1_wo = f32::INFINITY;
        for (idx, &m) in medoids.iter().enumerate() {
            let d = dist.get(j, m);
            d1 = d1.min(d);
            if idx != mi {
                d1_wo = d1_wo.min(d);
            }
        }
        let new = d1_wo.min(dist.get(j, c));
        delta += (new - d1) as f64;
    }
    delta
}

/// Run PAM; returns medoid indices.
pub fn solve(dist: &DistMatrix, k: usize, _rng: &mut Rng) -> Vec<usize> {
    let n = dist.n;
    if k >= n {
        return (0..n).collect();
    }
    let mut medoids = build_init(dist, k);
    let max_iters = 20 * k + 10;
    for _ in 0..max_iters {
        let mut best = (0usize, 0usize, -1e-9f64);
        for c in 0..n {
            if medoids.contains(&c) {
                continue;
            }
            for mi in 0..k {
                let d = swap_delta(dist, &medoids, mi, c);
                if d < best.2 {
                    best = (mi, c, d);
                }
            }
        }
        if best.2 >= -1e-9 {
            break;
        }
        medoids[best.0] = best.1;
    }
    medoids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::{distance::from_features_cpu, objective};

    fn random_dist(rng: &mut Rng, n: usize, dim: usize) -> DistMatrix {
        let f: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        from_features_cpu(&f, n, dim)
    }

    #[test]
    fn pam_never_worse_than_build() {
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let dist = random_dist(&mut rng, 40, 4);
            let build_cost = objective(&dist, &build_init(&dist, 5));
            let pam_cost = objective(&dist, &solve(&dist, 5, &mut rng));
            assert!(pam_cost <= build_cost + 1e-9, "seed {seed}");
        }
    }

    #[test]
    fn pam_and_fasterpam_reach_similar_quality() {
        for seed in 0..5 {
            let mut rng = Rng::new(50 + seed);
            let dist = random_dist(&mut rng, 60, 5);
            let pam_cost = objective(&dist, &solve(&dist, 6, &mut rng));
            let fp_cost = objective(&dist, &super::super::fasterpam::solve(&dist, 6, &mut rng));
            // Both are local optima of the same neighbourhood structure.
            let ratio = fp_cost / pam_cost;
            assert!((0.9..=1.1).contains(&ratio), "seed {seed}: ratio {ratio}");
        }
    }

    #[test]
    fn swap_delta_matches_objective_difference() {
        let mut rng = Rng::new(3);
        let dist = random_dist(&mut rng, 25, 3);
        let medoids = build_init(&dist, 4);
        let before = objective(&dist, &medoids);
        for c in [0usize, 7, 19] {
            if medoids.contains(&c) {
                continue;
            }
            for mi in 0..4 {
                let mut swapped = medoids.clone();
                swapped[mi] = c;
                let after = objective(&dist, &swapped);
                let delta = swap_delta(&dist, &medoids, mi, c);
                assert!(
                    (delta - (after - before)).abs() < 1e-6,
                    "mi {mi} c {c}: {delta} vs {}",
                    after - before
                );
            }
        }
    }
}
