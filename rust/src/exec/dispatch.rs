//! Deterministic dispatch scheduling: which worker runs which job,
//! decided in *simulated* time on the coordinator.
//!
//! Round-robin dealing (the historical policy) is deterministic but
//! imbalanced: one heavy client plan — the same system-heterogeneity
//! pathology FedCore attacks at the protocol level — idles every other
//! worker for the tail of the round. Classic work stealing fixes the
//! imbalance by letting idle threads race for queued work, but racing
//! real threads would make worker placement (and any schedule ledger)
//! nondeterministic. This module does neither: it **simulates** a
//! work-stealing pool in virtual time from the jobs' deterministic
//! simulated costs ([`crate::fl::LocalPlan::sim_time`]), producing an
//! explicit job → worker [`Schedule`] that the real pool then follows.
//! Placement, steal counts, and the [`ScheduleTrace`] ledger are pure
//! functions of `(policy, costs, workers)` — bit-replayable from the
//! run's seed — while the engine's order-preserving reduce keeps model
//! outputs bit-identical regardless of policy (ARCHITECTURE.md
//! determinism rule 6; enforced by `rust/tests/proptest_dispatch.rs`).
//!
//! The work-stealing simulation: jobs are dealt round-robin into
//! per-worker home deques (so a homogeneous round reproduces round-robin
//! placement exactly, steals = 0). Workers claim in virtual time — the
//! worker with the smallest free-time (ties: smallest id) pops the front
//! of its own deque; a worker whose deque is empty steals the **back**
//! of the richest victim's deque (ties: smallest victim id). Every claim
//! starts a job no later than its round-robin start would have been, so
//! the work-stealing makespan never exceeds the round-robin makespan.

use std::collections::VecDeque;
use std::sync::Mutex;

/// How the sharded executor deals a batch of jobs to its workers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Deal job `i` to worker `i % workers` (the historical default;
    /// deterministic, and balanced only when job costs are similar).
    #[default]
    RoundRobin,
    /// Deterministic work stealing: follow the virtual-time simulation
    /// of a stealing pool over the jobs' simulated costs (module docs).
    WorkStealing,
}

impl DispatchPolicy {
    /// Parse a policy name (`round_robin` | `work_stealing`, with `rr` /
    /// `ws` shorthands; case-insensitive, `-`/`_` ignored).
    pub fn parse(s: &str) -> Option<DispatchPolicy> {
        match s.trim().to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "roundrobin" | "rr" => Some(DispatchPolicy::RoundRobin),
            "workstealing" | "steal" | "ws" => Some(DispatchPolicy::WorkStealing),
            _ => None,
        }
    }

    /// Canonical name (`"round_robin"` / `"work_stealing"`).
    pub fn label(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round_robin",
            DispatchPolicy::WorkStealing => "work_stealing",
        }
    }

    /// The `FEDCORE_DISPATCH` environment override, falling back to the
    /// default ([`DispatchPolicy::RoundRobin`]) when unset or
    /// unparseable. Read by the bench/experiment harness
    /// ([`crate::expt`]) and the CLI's default resolution.
    pub fn from_env() -> DispatchPolicy {
        std::env::var("FEDCORE_DISPATCH")
            .ok()
            .and_then(|v| DispatchPolicy::parse(&v))
            .unwrap_or_default()
    }
}

/// One batch's deterministic dispatch schedule: per-job placement and
/// virtual-time bounds, plus per-worker load accounting. Produced by
/// [`plan_schedule`]; followed verbatim by the sharded pool.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    /// Worker count the schedule was planned for.
    pub workers: usize,
    /// `assignment[i]` = the worker that runs job `i`.
    pub assignment: Vec<usize>,
    /// Virtual start time of each job (seconds of simulated cost).
    pub start: Vec<f64>,
    /// Virtual end time of each job (`start[i] + cost[i]`).
    pub end: Vec<f64>,
    /// `stolen[i]` = job `i` ran on a worker other than its round-robin
    /// home `i % workers` (always `false` under round-robin).
    pub stolen: Vec<bool>,
    /// Simulated busy seconds per worker (sum of its jobs' costs).
    pub worker_busy: Vec<f64>,
    /// Virtual completion time of the batch: `max` over workers of their
    /// last job's end (`0.0` for an empty batch).
    pub makespan: f64,
}

impl Schedule {
    /// Jobs that ran away from their round-robin home worker.
    pub fn steals(&self) -> usize {
        self.stolen.iter().filter(|&&s| s).count()
    }

    /// Total simulated busy seconds across all workers.
    pub fn busy_seconds(&self) -> f64 {
        self.worker_busy.iter().sum()
    }

    /// Total simulated worker-seconds the batch occupied:
    /// `workers × makespan`.
    pub fn capacity_seconds(&self) -> f64 {
        self.stats().capacity_seconds()
    }

    /// Simulated idle worker-seconds: capacity minus busy (clamped at 0
    /// against rounding).
    pub fn idle_seconds(&self) -> f64 {
        self.stats().idle_seconds()
    }

    /// Fraction of the batch's worker-seconds spent busy (`1.0` for an
    /// empty batch).
    pub fn utilization(&self) -> f64 {
        self.stats().utilization()
    }

    /// Condense into the per-batch [`DispatchStats`] the engine records.
    pub fn stats(&self) -> DispatchStats {
        DispatchStats {
            workers: self.workers,
            jobs: self.assignment.len(),
            steals: self.steals(),
            busy_seconds: self.busy_seconds(),
            makespan: self.makespan,
        }
    }
}

/// Condensed accounting of one dispatch batch — what the engine records
/// per round ([`crate::metrics::RoundRecord`]'s `steal_count` /
/// `worker_idle`) and [`crate::sim::SimClock`] accumulates for run-level
/// utilization.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DispatchStats {
    /// Worker count the batch was scheduled over (0 only for the
    /// trait-default stats of an executor without dispatch accounting).
    pub workers: usize,
    /// Jobs in the batch.
    pub jobs: usize,
    /// Jobs that ran away from their round-robin home worker.
    pub steals: usize,
    /// Total simulated busy seconds across workers.
    pub busy_seconds: f64,
    /// Virtual completion time of the batch.
    pub makespan: f64,
}

impl DispatchStats {
    /// Total simulated worker-seconds: `workers × makespan`.
    pub fn capacity_seconds(&self) -> f64 {
        self.workers as f64 * self.makespan
    }

    /// Simulated idle worker-seconds (capacity minus busy, clamped ≥ 0).
    pub fn idle_seconds(&self) -> f64 {
        (self.capacity_seconds() - self.busy_seconds).max(0.0)
    }

    /// Busy fraction of the batch's worker-seconds (`1.0` when empty).
    pub fn utilization(&self) -> f64 {
        let cap = self.capacity_seconds();
        if cap <= 0.0 {
            return 1.0;
        }
        self.busy_seconds / cap
    }
}

/// Plan one batch's dispatch schedule from the jobs' simulated costs.
/// Pure and deterministic: the same `(policy, costs, workers)` always
/// produces the bit-identical [`Schedule`], so schedule traces replay
/// from the run's seed. Costs must be finite and non-negative.
pub fn plan_schedule(policy: DispatchPolicy, costs: &[f64], workers: usize) -> Schedule {
    assert!(workers >= 1, "dispatch needs at least one worker");
    debug_assert!(costs.iter().all(|c| c.is_finite() && *c >= 0.0), "job costs must be finite");
    let n = costs.len();
    let mut assignment = vec![0usize; n];
    let mut start = vec![0.0f64; n];
    let mut end = vec![0.0f64; n];
    let mut stolen = vec![false; n];
    let mut busy = vec![0.0f64; workers];
    let mut free = vec![0.0f64; workers];
    let mut claim = |idx: usize, w: usize, free: &mut [f64], busy: &mut [f64]| {
        assignment[idx] = w;
        stolen[idx] = w != idx % workers;
        start[idx] = free[w];
        end[idx] = free[w] + costs[idx];
        free[w] = end[idx];
        busy[w] += costs[idx];
    };
    match policy {
        DispatchPolicy::RoundRobin => {
            for idx in 0..n {
                claim(idx, idx % workers, &mut free, &mut busy);
            }
        }
        DispatchPolicy::WorkStealing => {
            // Home deques: the round-robin deal, so a balanced batch
            // reproduces round-robin placement exactly (zero steals).
            let mut deques: Vec<VecDeque<usize>> = vec![VecDeque::new(); workers];
            for idx in 0..n {
                deques[idx % workers].push_back(idx);
            }
            let mut active = vec![true; workers];
            let mut remaining = n;
            while remaining > 0 {
                // The next claimant: smallest virtual free-time among
                // workers still in the game, ties broken by worker id.
                let w = (0..workers)
                    .filter(|&w| active[w])
                    .min_by(|&a, &b| {
                        free[a]
                            .partial_cmp(&free[b])
                            .expect("finite virtual times")
                            .then(a.cmp(&b))
                    })
                    .expect("a worker stays active while jobs remain");
                if let Some(idx) = deques[w].pop_front() {
                    claim(idx, w, &mut free, &mut busy);
                    remaining -= 1;
                    continue;
                }
                // Own deque empty: steal the *back* (most recently dealt
                // job) of the richest victim; ties pick the smallest id.
                let victim = (0..workers)
                    .filter(|&v| !deques[v].is_empty())
                    .max_by(|&a, &b| deques[a].len().cmp(&deques[b].len()).then(b.cmp(&a)));
                match victim {
                    Some(v) => {
                        let idx = deques[v].pop_back().expect("victim deque non-empty");
                        claim(idx, w, &mut free, &mut busy);
                        remaining -= 1;
                    }
                    // Nothing left anywhere: this worker idles out.
                    None => active[w] = false,
                }
            }
        }
    }
    let makespan = free.iter().copied().fold(0.0f64, f64::max);
    Schedule { workers, assignment, start, end, stolen, worker_busy: busy, makespan }
}

/// What kind of jobs a dispatch batch carried.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// One selected client's local work ([`crate::exec::ClientJob`]).
    Client,
    /// One test-set evaluation batch ([`crate::exec::EvalJob`]).
    Eval,
}

impl JobKind {
    /// Canonical name (`"client"` / `"eval"`), as written by the CSV
    /// and trace serializers.
    pub fn label(self) -> &'static str {
        match self {
            JobKind::Client => "client",
            JobKind::Eval => "eval",
        }
    }
}

/// One job's entry in the schedule ledger.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleEntry {
    /// Client-dispatch sequence number: with one client batch per engine
    /// round (the synchronous and overlapped loops both dispatch once),
    /// this is the engine's round index. Eval batches carry the round of
    /// the preceding client batch.
    pub round: usize,
    /// Client or eval batch.
    pub kind: JobKind,
    /// The job's index within its batch (= its slot in the
    /// order-preserving reduce).
    pub job_idx: usize,
    /// The worker the schedule placed this job on.
    pub worker: usize,
    /// Cumulative stolen jobs within this batch, up to and including
    /// this job (entries are emitted in job-index order, so the batch's
    /// last entry carries the batch total).
    pub steal_count: usize,
    /// Virtual start time within the batch (simulated seconds).
    pub start: f64,
    /// Virtual end time within the batch.
    pub end: f64,
}

/// The schedule ledger: every dispatched job's placement and virtual
/// timing, recordable from any [`crate::exec::Executor`] via
/// `record_schedule` / `take_schedule`. Entirely virtual-time, so a
/// trace is a pure function of the run's seed and replays bit-for-bit
/// (`rust/tests/proptest_dispatch.rs`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScheduleTrace {
    /// Ledger entries, in dispatch order (batches in dispatch order,
    /// jobs in index order within each batch).
    pub entries: Vec<ScheduleEntry>,
}

impl ScheduleTrace {
    /// Ledger length.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the ledger empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total stolen jobs across all recorded batches.
    pub fn total_steals(&self) -> usize {
        // Within a batch `steal_count` is cumulative; the batch total is
        // its last entry's value. Batch boundaries are where job_idx
        // resets to 0.
        let mut total = 0;
        let mut last_in_batch = 0;
        for e in &self.entries {
            if e.job_idx == 0 {
                total += last_in_batch;
                last_in_batch = 0;
            }
            last_in_batch = e.steal_count;
        }
        total + last_in_batch
    }

    /// Serialize the ledger as CSV (one row per job).
    pub fn to_csv(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("round,kind,job_idx,worker,steal_count,start,end\n");
        for e in &self.entries {
            let _ = writeln!(
                out,
                "{},{},{},{},{},{:.6},{:.6}",
                e.round,
                e.kind.label(),
                e.job_idx,
                e.worker,
                e.steal_count,
                e.start,
                e.end
            );
        }
        out
    }

    /// Roll the per-job ledger up to per-`(round, kind, worker)` busy
    /// intervals — the per-worker spans the observability layer emits
    /// ([`crate::obs::emit_schedule`]). Per-entry steal attribution is
    /// reconstructed from the cumulative `steal_count` (batch
    /// boundaries reset at `job_idx == 0`). Deterministic output
    /// order: sorted by round, then kind (clients first), then worker.
    pub fn worker_rollup(&self) -> Vec<WorkerRollup> {
        let mut map: std::collections::BTreeMap<(usize, u8, usize), WorkerRollup> =
            std::collections::BTreeMap::new();
        let mut prev_steals = 0usize;
        for e in &self.entries {
            if e.job_idx == 0 {
                prev_steals = 0;
            }
            let stolen = usize::from(e.steal_count > prev_steals);
            prev_steals = e.steal_count;
            let kind_ord = match e.kind {
                JobKind::Client => 0u8,
                JobKind::Eval => 1u8,
            };
            let w = map.entry((e.round, kind_ord, e.worker)).or_insert(WorkerRollup {
                round: e.round,
                kind: e.kind,
                worker: e.worker,
                jobs: 0,
                stolen: 0,
                busy: 0.0,
                start: e.start,
                end: e.end,
            });
            w.jobs += 1;
            w.stolen += stolen;
            w.busy += e.end - e.start;
            w.start = w.start.min(e.start);
            w.end = w.end.max(e.end);
        }
        map.into_values().collect()
    }
}

/// One worker's aggregate over one dispatch batch: how many jobs it
/// ran (and how many it stole), and its busy interval in the batch's
/// virtual time. Produced by [`ScheduleTrace::worker_rollup`].
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerRollup {
    /// Client-dispatch sequence number (see [`ScheduleEntry::round`]).
    pub round: usize,
    /// Client or eval batch.
    pub kind: JobKind,
    /// The worker index.
    pub worker: usize,
    /// Jobs this worker ran in the batch.
    pub jobs: usize,
    /// How many of those ran away from their round-robin home.
    pub stolen: usize,
    /// Total simulated busy seconds (sum of its jobs' costs).
    pub busy: f64,
    /// Virtual start of its first job within the batch.
    pub start: f64,
    /// Virtual end of its last job within the batch.
    pub end: f64,
}

/// Shared schedule-instrumentation state for the built-in executors:
/// counts client batches (round numbering), keeps the most recent client
/// batch's [`DispatchStats`] for the engine's per-round accounting, and
/// accumulates [`ScheduleEntry`]s while recording is on. Interior
/// mutability so the `&self` executor methods can write to it.
#[derive(Debug, Default)]
pub(crate) struct TraceRecorder {
    inner: Mutex<RecorderState>,
}

#[derive(Debug, Default)]
struct RecorderState {
    recording: bool,
    rounds: usize,
    entries: Vec<ScheduleEntry>,
    last_client: Option<DispatchStats>,
}

impl TraceRecorder {
    /// Turn ledger recording on (clearing any previous ledger and
    /// resetting round numbering) or off.
    pub(crate) fn set_recording(&self, on: bool) {
        let mut st = self.inner.lock().expect("trace recorder poisoned");
        st.recording = on;
        if on {
            st.entries.clear();
            st.rounds = 0;
        }
    }

    /// Drain the recorded ledger (`None` when recording is off).
    pub(crate) fn take(&self) -> Option<ScheduleTrace> {
        let mut st = self.inner.lock().expect("trace recorder poisoned");
        st.recording.then(|| ScheduleTrace { entries: std::mem::take(&mut st.entries) })
    }

    /// The most recent client batch's stats (regardless of recording).
    pub(crate) fn last_client_dispatch(&self) -> Option<DispatchStats> {
        self.inner.lock().expect("trace recorder poisoned").last_client
    }

    /// Record one dispatched batch's schedule.
    pub(crate) fn observe(&self, kind: JobKind, sched: &Schedule) {
        let mut st = self.inner.lock().expect("trace recorder poisoned");
        let round = match kind {
            JobKind::Client => {
                let r = st.rounds;
                st.rounds += 1;
                st.last_client = Some(sched.stats());
                r
            }
            JobKind::Eval => st.rounds.saturating_sub(1),
        };
        if st.recording {
            let mut steals = 0usize;
            for idx in 0..sched.assignment.len() {
                steals += usize::from(sched.stolen[idx]);
                st.entries.push(ScheduleEntry {
                    round,
                    kind,
                    job_idx: idx,
                    worker: sched.assignment[idx],
                    steal_count: steals,
                    start: sched.start[idx],
                    end: sched.end[idx],
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_labels_roundtrip() {
        for s in ["round_robin", "Round-Robin", "rr", "ROUNDROBIN"] {
            assert_eq!(DispatchPolicy::parse(s), Some(DispatchPolicy::RoundRobin), "{s}");
        }
        for s in ["work_stealing", "work-stealing", "ws", "steal"] {
            assert_eq!(DispatchPolicy::parse(s), Some(DispatchPolicy::WorkStealing), "{s}");
        }
        assert!(DispatchPolicy::parse("lifo").is_none());
        for p in [DispatchPolicy::RoundRobin, DispatchPolicy::WorkStealing] {
            assert_eq!(DispatchPolicy::parse(p.label()), Some(p));
        }
    }

    #[test]
    fn worker_rollup_conserves_jobs_busy_and_steals() {
        // Two batches (rounds 0, 1) over 2 workers, heavy head to force steals.
        let recorder = TraceRecorder::default();
        recorder.set_recording(true);
        let costs = [[6.0, 1.0, 1.0, 1.0], [1.0, 1.0, 1.0, 1.0]];
        for c in &costs {
            recorder.observe(JobKind::Client, &plan_schedule(DispatchPolicy::WorkStealing, c, 2));
        }
        let trace = recorder.take().expect("recording on");
        let rollup = trace.worker_rollup();
        // Deterministic order: (round, kind, worker) ascending.
        let keys: Vec<(usize, usize)> = rollup.iter().map(|w| (w.round, w.worker)).collect();
        assert_eq!(keys, vec![(0, 0), (0, 1), (1, 0), (1, 1)]);
        // Conservation against the raw ledger, per round.
        for r in 0..2 {
            let jobs: usize = rollup.iter().filter(|w| w.round == r).map(|w| w.jobs).sum();
            assert_eq!(jobs, 4);
            let busy: f64 = rollup.iter().filter(|w| w.round == r).map(|w| w.busy).sum();
            let total: f64 = costs[r].iter().sum();
            assert!((busy - total).abs() < 1e-9);
        }
        let stolen: usize = rollup.iter().map(|w| w.stolen).sum();
        assert_eq!(stolen, trace.total_steals());
        assert!(stolen > 0, "the heavy head must force at least one steal");
        // Busy intervals stay within the batch bounds.
        for w in &rollup {
            assert!(w.start >= 0.0 && w.end >= w.start);
            assert!(w.busy <= w.end - w.start + 1e-9);
        }
    }

    #[test]
    fn round_robin_deals_by_index_and_stacks_times() {
        let s = plan_schedule(DispatchPolicy::RoundRobin, &[2.0, 1.0, 3.0, 1.0, 2.0], 2);
        assert_eq!(s.assignment, vec![0, 1, 0, 1, 0]);
        assert_eq!(s.steals(), 0);
        // Worker 0 runs jobs 0, 2, 4 back to back: starts 0, 2, 5.
        assert_eq!(s.start, vec![0.0, 0.0, 2.0, 1.0, 5.0]);
        assert_eq!(s.end, vec![2.0, 1.0, 5.0, 2.0, 7.0]);
        assert_eq!(s.worker_busy, vec![7.0, 2.0]);
        assert_eq!(s.makespan, 7.0);
        assert_eq!(s.idle_seconds(), 7.0 * 2.0 - 9.0);
    }

    #[test]
    fn homogeneous_costs_reduce_work_stealing_to_round_robin() {
        let costs = vec![1.5; 11];
        let rr = plan_schedule(DispatchPolicy::RoundRobin, &costs, 4);
        let ws = plan_schedule(DispatchPolicy::WorkStealing, &costs, 4);
        assert_eq!(ws.assignment, rr.assignment, "balanced batch must not steal");
        assert_eq!(ws.steals(), 0);
        assert_eq!(ws.start, rr.start);
        assert_eq!(ws.end, rr.end);
        assert_eq!(ws.makespan, rr.makespan);
    }

    #[test]
    fn heavy_head_job_is_rebalanced_by_stealing() {
        // Job 0 dominates: round-robin stacks jobs 2 and 4 behind it on
        // worker 0 while worker 1 idles; stealing moves them over.
        let costs = vec![10.0, 1.0, 1.0, 1.0, 1.0];
        let rr = plan_schedule(DispatchPolicy::RoundRobin, &costs, 2);
        let ws = plan_schedule(DispatchPolicy::WorkStealing, &costs, 2);
        assert_eq!(rr.makespan, 12.0);
        assert_eq!(ws.makespan, 10.0, "stealers drain the light jobs under the heavy one");
        assert!(ws.steals() >= 2, "jobs 2 and 4 must migrate, got {}", ws.steals());
        assert!(ws.utilization() > rr.utilization());
        // Work is conserved either way.
        assert!((ws.busy_seconds() - rr.busy_seconds()).abs() < 1e-12);
    }

    #[test]
    fn stealing_never_exceeds_round_robin_makespan() {
        // Deterministic spot-grid (the property version with random costs
        // lives in tests/proptest_dispatch.rs).
        let grids: &[&[f64]] = &[
            &[3.0, 1.0, 1.0, 3.0],
            &[1.0, 4.0, 5.0, 2.0, 0.5],
            &[0.0, 7.0, 0.0, 7.0, 1.0, 1.0, 1.0],
            &[2.0, 2.0, 2.0, 3.0, 3.0],
        ];
        for costs in grids {
            for workers in 1..=4 {
                let rr = plan_schedule(DispatchPolicy::RoundRobin, costs, workers);
                let ws = plan_schedule(DispatchPolicy::WorkStealing, costs, workers);
                assert!(
                    ws.makespan <= rr.makespan + 1e-12,
                    "{costs:?} × {workers}: ws {} > rr {}",
                    ws.makespan,
                    rr.makespan
                );
            }
        }
    }

    #[test]
    fn empty_batch_is_trivial() {
        for policy in [DispatchPolicy::RoundRobin, DispatchPolicy::WorkStealing] {
            let s = plan_schedule(policy, &[], 3);
            assert!(s.assignment.is_empty());
            assert_eq!(s.makespan, 0.0);
            assert_eq!(s.utilization(), 1.0);
            assert_eq!(s.idle_seconds(), 0.0);
            assert_eq!(s.stats(), DispatchStats { workers: 3, ..Default::default() });
        }
    }

    #[test]
    fn single_worker_is_sequential_for_both_policies() {
        let costs = vec![2.0, 5.0, 1.0];
        for policy in [DispatchPolicy::RoundRobin, DispatchPolicy::WorkStealing] {
            let s = plan_schedule(policy, &costs, 1);
            assert_eq!(s.assignment, vec![0, 0, 0]);
            assert_eq!(s.start, vec![0.0, 2.0, 7.0]);
            assert_eq!(s.makespan, 8.0);
            assert_eq!(s.steals(), 0);
            assert_eq!(s.utilization(), 1.0);
        }
    }

    #[test]
    fn recorder_ledger_rounds_and_cumulative_steals() {
        let rec = TraceRecorder::default();
        rec.set_recording(true);
        let batch = plan_schedule(DispatchPolicy::WorkStealing, &[10.0, 1.0, 1.0, 1.0], 2);
        rec.observe(JobKind::Client, &batch);
        rec.observe(JobKind::Eval, &plan_schedule(DispatchPolicy::RoundRobin, &[1.0], 2));
        rec.observe(JobKind::Client, &batch);
        let trace = rec.take().expect("recording was on");
        assert_eq!(trace.len(), 9);
        // Client batch 0, its eval at the same round, client batch 1.
        assert_eq!(trace.entries[0].round, 0);
        assert_eq!(trace.entries[4].kind, JobKind::Eval);
        assert_eq!(trace.entries[4].round, 0);
        assert_eq!(trace.entries[5].round, 1);
        // Cumulative steal counts are monotone within a batch and the
        // ledger total matches the schedules'.
        assert_eq!(trace.total_steals(), 2 * batch.steals());
        let csv = trace.to_csv();
        assert!(csv.starts_with("round,kind,job_idx,worker,steal_count,start,end\n"));
        assert_eq!(csv.trim().lines().count(), 10);
        // Drained: a second take is an empty ledger.
        assert!(rec.take().expect("still recording").is_empty());
        // Stats stay readable with recording off.
        rec.set_recording(false);
        assert!(rec.take().is_none());
        assert_eq!(rec.last_client_dispatch().expect("client batch seen").jobs, 4);
    }

    #[test]
    fn stats_idle_and_utilization_arithmetic() {
        let s = DispatchStats {
            workers: 4,
            jobs: 8,
            steals: 3,
            busy_seconds: 6.0,
            makespan: 2.0,
        };
        assert_eq!(s.capacity_seconds(), 8.0);
        assert_eq!(s.idle_seconds(), 2.0);
        assert_eq!(s.utilization(), 0.75);
        assert_eq!(DispatchStats::default().utilization(), 1.0);
        assert_eq!(DispatchStats::default().idle_seconds(), 0.0);
    }
}
