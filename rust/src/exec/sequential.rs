//! The in-thread executor: runs every job on the engine's own runtime, in
//! job order. This is the reference implementation the sharded executor
//! must match bit-for-bit (and the original engine behaviour, unchanged).

use std::sync::Arc;

use anyhow::Result;

use super::{exec_client, exec_eval, ClientJob, EvalJob, ExecContext, Executor};
use crate::fl::ClientOutcome;
use crate::runtime::{EvalOutput, Runtime};

/// The reference executor: every job runs on the engine's thread, on the
/// engine's runtime, in job order.
pub struct Sequential<'a> {
    rt: &'a Runtime,
}

impl<'a> Sequential<'a> {
    /// Wrap the engine's runtime; no threads, no setup cost.
    pub fn new(rt: &'a Runtime) -> Sequential<'a> {
        Sequential { rt }
    }
}

impl Executor for Sequential<'_> {
    fn workers(&self) -> usize {
        1
    }

    fn run_clients(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<ClientJob>,
    ) -> Result<Vec<ClientOutcome>> {
        jobs.into_iter().map(|job| exec_client(self.rt, ctx, job)).collect()
    }

    fn run_evals(&self, ctx: &Arc<ExecContext>, jobs: Vec<EvalJob>) -> Result<Vec<EvalOutput>> {
        jobs.iter().map(|job| exec_eval(self.rt, ctx, job)).collect()
    }
}
