//! The in-thread executor: runs every job on the engine's own runtime, in
//! job order. This is the reference implementation the sharded executor
//! must match bit-for-bit (and the original engine behaviour, unchanged).
//! It still instruments dispatch — a one-worker round-robin schedule —
//! so the engine's per-round dispatch accounting and the
//! [`ScheduleTrace`] ledger work identically across executors.

use std::sync::Arc;

use anyhow::Result;

use super::dispatch::{plan_schedule, DispatchPolicy, DispatchStats, JobKind, TraceRecorder};
use super::{
    client_job_cost, eval_job_cost, exec_client, exec_eval, ClientJob, EvalJob, ExecContext,
    Executor, ScheduleTrace,
};
use crate::fl::ClientOutcome;
use crate::runtime::{EvalOutput, Runtime};

/// The reference executor: every job runs on the engine's thread, on the
/// engine's runtime, in job order.
pub struct Sequential<'a> {
    rt: &'a Runtime,
    recorder: TraceRecorder,
}

impl<'a> Sequential<'a> {
    /// Wrap the engine's runtime; no threads, no setup cost.
    pub fn new(rt: &'a Runtime) -> Sequential<'a> {
        Sequential { rt, recorder: TraceRecorder::default() }
    }
}

impl Executor for Sequential<'_> {
    fn workers(&self) -> usize {
        1
    }

    fn run_clients(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<ClientJob>,
    ) -> Result<Vec<ClientOutcome>> {
        let costs: Vec<f64> = jobs.iter().map(|j| client_job_cost(ctx, j)).collect();
        self.recorder
            .observe(JobKind::Client, &plan_schedule(DispatchPolicy::RoundRobin, &costs, 1));
        jobs.into_iter().map(|job| exec_client(self.rt, ctx, job)).collect()
    }

    fn run_evals(&self, ctx: &Arc<ExecContext>, jobs: Vec<EvalJob>) -> Result<Vec<EvalOutput>> {
        let costs: Vec<f64> = jobs.iter().map(eval_job_cost).collect();
        self.recorder
            .observe(JobKind::Eval, &plan_schedule(DispatchPolicy::RoundRobin, &costs, 1));
        jobs.iter().map(|job| exec_eval(self.rt, ctx, job)).collect()
    }

    fn record_schedule(&self, on: bool) {
        self.recorder.set_recording(on);
    }

    fn take_schedule(&self) -> Option<ScheduleTrace> {
        self.recorder.take()
    }

    fn last_client_dispatch(&self) -> Option<DispatchStats> {
        self.recorder.last_client_dispatch()
    }
}
