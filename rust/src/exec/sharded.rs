//! The sharded executor: a persistent pool of worker threads, each pinned
//! to its own [`Runtime`] built lazily from a [`RuntimeFactory`] on first
//! job (so constructing the pool is cheap and never touches the
//! filesystem). Jobs are dealt round-robin by job index — deterministic,
//! and balanced because one round's client jobs have similar cost — and
//! results are re-ordered by job index before returning, which is what
//! makes sharded aggregation bit-identical to sequential.
//!
//! Failure model: a worker that cannot build its runtime, or whose job
//! errors, sends the error back and stays alive; a worker that dies
//! entirely closes its channels, which `collect` surfaces as an error
//! instead of deadlocking.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::{exec_client, exec_eval, ClientJob, EvalJob, ExecContext, Executor};
use crate::fl::ClientOutcome;
use crate::runtime::{EvalOutput, Runtime, RuntimeFactory};

enum WorkerMsg {
    Client {
        idx: usize,
        ctx: Arc<ExecContext>,
        job: ClientJob,
        tx: Sender<(usize, Result<ClientOutcome>)>,
    },
    Eval {
        idx: usize,
        ctx: Arc<ExecContext>,
        job: EvalJob,
        tx: Sender<(usize, Result<EvalOutput>)>,
    },
    Shutdown,
}

/// The sharded executor: a persistent pool of worker threads, each pinned
/// to its own lazily-built [`Runtime`], with deterministic round-robin
/// dispatch and an order-restoring collect (see the module docs).
pub struct Sharded {
    senders: Vec<Sender<WorkerMsg>>,
    handles: Vec<JoinHandle<()>>,
}

impl Sharded {
    /// Spawn `workers` threads immediately; each builds its runtime lazily
    /// on its first job.
    pub fn new(workers: usize, factory: RuntimeFactory) -> Sharded {
        assert!(workers >= 1, "sharded executor needs at least one worker");
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = mpsc::channel();
            let f = factory.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fedcore-exec-{w}"))
                .spawn(move || worker_main(rx, f))
                .expect("spawning exec worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        Sharded { senders, handles }
    }

    /// Deal jobs round-robin by job index and collect results in job
    /// order. `wrap` builds the per-kind [`WorkerMsg`]; everything else —
    /// dispatch policy, error surfaces, the order-restoring collect — is
    /// shared by both job kinds.
    fn dispatch<J, T>(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<J>,
        wrap: impl Fn(usize, Arc<ExecContext>, J, Sender<(usize, Result<T>)>) -> WorkerMsg,
    ) -> Result<Vec<T>> {
        let n = jobs.len();
        let (tx, rx) = mpsc::channel();
        for (idx, job) in jobs.into_iter().enumerate() {
            let w = idx % self.senders.len();
            self.senders[w]
                .send(wrap(idx, Arc::clone(ctx), job, tx.clone()))
                .map_err(|_| anyhow!("exec worker {w} is gone"))?;
        }
        drop(tx);
        Self::collect(rx, n)
    }

    /// Receive exactly `n` `(idx, result)` pairs and restore job order.
    fn collect<T>(rx: Receiver<(usize, Result<T>)>, n: usize) -> Result<Vec<T>> {
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        for _ in 0..n {
            let (idx, res) = rx
                .recv()
                .map_err(|_| anyhow!("exec worker died before finishing its jobs"))?;
            out[idx] = Some(res?);
        }
        out.into_iter()
            .map(|o| o.ok_or_else(|| anyhow!("exec worker reported a duplicate job index")))
            .collect()
    }
}

impl Executor for Sharded {
    fn workers(&self) -> usize {
        self.senders.len()
    }

    fn run_clients(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<ClientJob>,
    ) -> Result<Vec<ClientOutcome>> {
        self.dispatch(ctx, jobs, |idx, ctx, job, tx| WorkerMsg::Client { idx, ctx, job, tx })
    }

    fn run_evals(&self, ctx: &Arc<ExecContext>, jobs: Vec<EvalJob>) -> Result<Vec<EvalOutput>> {
        self.dispatch(ctx, jobs, |idx, ctx, job, tx| WorkerMsg::Eval { idx, ctx, job, tx })
    }
}

impl Drop for Sharded {
    fn drop(&mut self) {
        for s in &self.senders {
            let _ = s.send(WorkerMsg::Shutdown);
        }
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(rx: Receiver<WorkerMsg>, factory: RuntimeFactory) {
    // The worker's pinned runtime: built on first use, reused for every
    // subsequent job (executable compilation is cached inside `Runtime`).
    let mut rt: Option<Runtime> = None;
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Client { idx, ctx, job, tx } => {
                let res = caught(|| {
                    pinned_runtime(&mut rt, &factory).and_then(|rt| exec_client(rt, &ctx, job))
                });
                let _ = tx.send((idx, res));
            }
            WorkerMsg::Eval { idx, ctx, job, tx } => {
                let res = caught(|| {
                    pinned_runtime(&mut rt, &factory).and_then(|rt| exec_eval(rt, &ctx, &job))
                });
                let _ = tx.send((idx, res));
            }
            WorkerMsg::Shutdown => break,
        }
    }
}

/// Run one job, converting a panic into the job's `Err` — matching the
/// Sequential executor's failure surface (the panic message reaches the
/// caller) and keeping the worker alive for subsequent rounds.
fn caught<T>(job: impl FnOnce() -> Result<T>) -> Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
        Ok(res) => res,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic>".to_string());
            Err(anyhow!("exec worker job panicked: {msg}"))
        }
    }
}

fn pinned_runtime<'r>(
    slot: &'r mut Option<Runtime>,
    factory: &RuntimeFactory,
) -> Result<&'r Runtime> {
    if slot.is_none() {
        *slot = Some(factory.build()?);
    }
    Ok(slot.as_ref().expect("runtime slot just filled"))
}
