//! The sharded executor: a persistent pool of worker threads, each pinned
//! to its own [`Runtime`] built lazily from a [`RuntimeFactory`] on first
//! job (so constructing the pool is cheap and never touches the
//! filesystem). Job placement follows a deterministic [`DispatchPolicy`]
//! schedule planned on the coordinator ([`super::dispatch`]): round-robin
//! dealing by job index (the default), or a virtual-time work-stealing
//! schedule that rebalances heavy-tailed client plans across workers.
//! Either way results are re-ordered by job index before returning, which
//! is what makes sharded aggregation bit-identical to sequential —
//! regardless of policy.
//!
//! Failure model: a worker that cannot build its runtime, or whose job
//! errors, sends the error back and stays alive; a worker that dies
//! entirely closes its channels, which `collect` surfaces as an error
//! (naming the unreported job and its assigned worker) instead of
//! deadlocking.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::dispatch::{plan_schedule, DispatchPolicy, DispatchStats, JobKind, TraceRecorder};
use super::{
    client_job_cost, eval_job_cost, exec_client, exec_eval, ClientJob, EvalJob, ExecContext,
    Executor, ScheduleTrace,
};
use crate::fl::ClientOutcome;
use crate::runtime::{EvalOutput, Runtime, RuntimeFactory};

enum WorkerMsg {
    Client {
        idx: usize,
        ctx: Arc<ExecContext>,
        job: ClientJob,
        tx: Sender<(usize, usize, Result<ClientOutcome>)>,
    },
    Eval {
        idx: usize,
        ctx: Arc<ExecContext>,
        job: EvalJob,
        tx: Sender<(usize, usize, Result<EvalOutput>)>,
    },
    Shutdown,
}

/// The sharded executor: a persistent pool of worker threads, each pinned
/// to its own lazily-built [`Runtime`], with deterministic policy-planned
/// dispatch and an order-restoring collect (see the module docs).
pub struct Sharded {
    senders: Vec<Sender<WorkerMsg>>,
    handles: Vec<JoinHandle<()>>,
    policy: DispatchPolicy,
    recorder: TraceRecorder,
}

impl Sharded {
    /// Spawn `workers` threads immediately with the default round-robin
    /// dispatch; each builds its runtime lazily on its first job.
    pub fn new(workers: usize, factory: RuntimeFactory) -> Sharded {
        Sharded::with_policy(workers, factory, DispatchPolicy::default())
    }

    /// Spawn `workers` threads with an explicit [`DispatchPolicy`].
    pub fn with_policy(
        workers: usize,
        factory: RuntimeFactory,
        policy: DispatchPolicy,
    ) -> Sharded {
        assert!(workers >= 1, "sharded executor needs at least one worker");
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = mpsc::channel();
            let f = factory.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fedcore-exec-{w}"))
                .spawn(move || worker_main(w, rx, f))
                .expect("spawning exec worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        Sharded { senders, handles, policy, recorder: TraceRecorder::default() }
    }

    /// The dispatch policy this pool places jobs with.
    pub fn policy(&self) -> DispatchPolicy {
        self.policy
    }

    /// Plan the batch's schedule from the per-job costs, send each job to
    /// its scheduled worker, and collect results in job order. `wrap`
    /// builds the per-kind [`WorkerMsg`]; everything else — placement,
    /// trace recording, error surfaces, the order-restoring collect — is
    /// shared by both job kinds.
    fn dispatch<J, T>(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<J>,
        kind: JobKind,
        cost: impl Fn(&J) -> f64,
        wrap: impl Fn(usize, Arc<ExecContext>, J, Sender<(usize, usize, Result<T>)>) -> WorkerMsg,
    ) -> Result<Vec<T>> {
        let n = jobs.len();
        let costs: Vec<f64> = jobs.iter().map(&cost).collect();
        let sched = plan_schedule(self.policy, &costs, self.senders.len());
        self.recorder.observe(kind, &sched);
        let (tx, rx) = mpsc::channel();
        for (idx, job) in jobs.into_iter().enumerate() {
            let w = sched.assignment[idx];
            self.senders[w]
                .send(wrap(idx, Arc::clone(ctx), job, tx.clone()))
                .map_err(|_| anyhow!("exec worker {w} is gone"))?;
        }
        drop(tx);
        Self::collect(rx, n, &sched.assignment)
    }

    /// Receive exactly `n` `(idx, worker, result)` triples and restore
    /// job order. A duplicate, out-of-range, or never-reported job index
    /// is an error naming the offending index and worker, never a silent
    /// overwrite or an anonymous failure.
    fn collect<T>(
        rx: Receiver<(usize, usize, Result<T>)>,
        n: usize,
        assigned: &[usize],
    ) -> Result<Vec<T>> {
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        for _ in 0..n {
            let (idx, worker, res) = match rx.recv() {
                Ok(msg) => msg,
                Err(_) => {
                    // Every sender hung up with results still owed: a
                    // worker died. Name what never arrived.
                    let missing: Vec<usize> = out
                        .iter()
                        .enumerate()
                        .filter(|(_, slot)| slot.is_none())
                        .map(|(i, _)| i)
                        .collect();
                    let more = if missing.len() > 1 {
                        format!(" and {} more", missing.len() - 1)
                    } else {
                        String::new()
                    };
                    let detail = match missing.first() {
                        Some(&i) => format!(
                            "job {i} (assigned to worker {}){more}",
                            assigned.get(i).copied().unwrap_or(0),
                        ),
                        None => "no job".to_string(),
                    };
                    return Err(anyhow!(
                        "exec worker died before finishing its jobs: missing {detail}"
                    ));
                }
            };
            if idx >= n {
                return Err(anyhow!(
                    "exec worker {worker} reported out-of-range job index {idx} (batch of {n})"
                ));
            }
            if out[idx].is_some() {
                return Err(anyhow!("exec worker {worker} reported job {idx} twice"));
            }
            out[idx] = Some(res?);
        }
        // n receives, no duplicates, no out-of-range indices ⇒ by
        // pigeonhole every slot is filled (missing jobs surface in the
        // recv-error arm above, naming their assigned worker).
        Ok(out
            .into_iter()
            .map(|slot| slot.expect("all slots filled by the receive loop"))
            .collect())
    }
}

impl Executor for Sharded {
    fn workers(&self) -> usize {
        self.senders.len()
    }

    fn run_clients(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<ClientJob>,
    ) -> Result<Vec<ClientOutcome>> {
        self.dispatch(
            ctx,
            jobs,
            JobKind::Client,
            |job| client_job_cost(ctx, job),
            |idx, ctx, job, tx| WorkerMsg::Client { idx, ctx, job, tx },
        )
    }

    fn run_evals(&self, ctx: &Arc<ExecContext>, jobs: Vec<EvalJob>) -> Result<Vec<EvalOutput>> {
        self.dispatch(ctx, jobs, JobKind::Eval, eval_job_cost, |idx, ctx, job, tx| {
            WorkerMsg::Eval { idx, ctx, job, tx }
        })
    }

    fn dispatch_policy(&self) -> DispatchPolicy {
        self.policy
    }

    fn record_schedule(&self, on: bool) {
        self.recorder.set_recording(on);
    }

    fn take_schedule(&self) -> Option<ScheduleTrace> {
        self.recorder.take()
    }

    fn last_client_dispatch(&self) -> Option<DispatchStats> {
        self.recorder.last_client_dispatch()
    }
}

impl Drop for Sharded {
    fn drop(&mut self) {
        for s in &self.senders {
            let _ = s.send(WorkerMsg::Shutdown);
        }
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(worker: usize, rx: Receiver<WorkerMsg>, factory: RuntimeFactory) {
    // The worker's pinned runtime: built on first use, reused for every
    // subsequent job (executable compilation is cached inside `Runtime`).
    let mut rt: Option<Runtime> = None;
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Client { idx, ctx, job, tx } => {
                let res = caught(|| {
                    pinned_runtime(&mut rt, &factory).and_then(|rt| exec_client(rt, &ctx, job))
                });
                let _ = tx.send((idx, worker, res));
            }
            WorkerMsg::Eval { idx, ctx, job, tx } => {
                let res = caught(|| {
                    pinned_runtime(&mut rt, &factory).and_then(|rt| exec_eval(rt, &ctx, &job))
                });
                let _ = tx.send((idx, worker, res));
            }
            WorkerMsg::Shutdown => break,
        }
    }
}

/// Run one job, converting a panic into the job's `Err` — matching the
/// Sequential executor's failure surface (the panic message reaches the
/// caller) and keeping the worker alive for subsequent rounds.
fn caught<T>(job: impl FnOnce() -> Result<T>) -> Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
        Ok(res) => res,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic>".to_string());
            Err(anyhow!("exec worker job panicked: {msg}"))
        }
    }
}

fn pinned_runtime<'r>(
    slot: &'r mut Option<Runtime>,
    factory: &RuntimeFactory,
) -> Result<&'r Runtime> {
    if slot.is_none() {
        *slot = Some(factory.build()?);
    }
    Ok(slot.as_ref().expect("runtime slot just filled"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---------- collect error reporting (satellite: no more anonymous
    // duplicate/missing-index failures) ----------

    #[test]
    fn collect_restores_job_order() {
        let (tx, rx) = mpsc::channel();
        tx.send((2usize, 0usize, Ok::<i32, anyhow::Error>(30))).unwrap();
        tx.send((0, 1, Ok(10))).unwrap();
        tx.send((1, 0, Ok(20))).unwrap();
        drop(tx);
        let out = Sharded::collect(rx, 3, &[1, 0, 0]).unwrap();
        assert_eq!(out, vec![10, 20, 30]);
    }

    #[test]
    fn collect_names_the_duplicate_index_and_worker() {
        let (tx, rx) = mpsc::channel();
        tx.send((0usize, 0usize, Ok::<i32, anyhow::Error>(1))).unwrap();
        tx.send((0, 1, Ok(2))).unwrap();
        drop(tx);
        let err = Sharded::collect(rx, 2, &[0, 1]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("job 0"), "duplicate index not named: {msg}");
        assert!(msg.contains("worker 1"), "duplicating worker not named: {msg}");
    }

    #[test]
    fn collect_names_the_out_of_range_index_and_worker() {
        let (tx, rx) = mpsc::channel();
        tx.send((7usize, 2usize, Ok::<i32, anyhow::Error>(1))).unwrap();
        drop(tx);
        let err = Sharded::collect(rx, 1, &[0]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains('7') && msg.contains("worker 2"), "{msg}");
    }

    #[test]
    fn collect_names_the_missing_job_and_its_assigned_worker() {
        let (tx, rx) = mpsc::channel();
        tx.send((0usize, 0usize, Ok::<i32, anyhow::Error>(1))).unwrap();
        drop(tx); // jobs 1 and 2 never report: their worker died
        let err = Sharded::collect(rx, 3, &[0, 1, 1]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("job 1"), "missing index not named: {msg}");
        assert!(msg.contains("worker 1"), "assigned worker not named: {msg}");
        assert!(msg.contains("1 more"), "remaining missing count absent: {msg}");
    }

    #[test]
    fn collect_propagates_job_errors() {
        let (tx, rx) = mpsc::channel();
        tx.send((0usize, 0usize, Err::<i32, _>(anyhow!("job exploded")))).unwrap();
        drop(tx);
        let err = Sharded::collect(rx, 1, &[0]).unwrap_err();
        assert!(format!("{err:#}").contains("job exploded"));
    }
}
