//! Sharded parallel execution for the FL round loop.
//!
//! The engine's hot path — K selected clients each running a [`LocalPlan`]
//! against the PJRT runtime, plus the batched test-set evaluation — is a
//! set of independent jobs. This module abstracts *where* those jobs run:
//!
//! * [`Sequential`] executes them in-thread on the engine's own runtime
//!   (the original behaviour, and the reference semantics).
//! * [`Sharded`] owns a persistent pool of worker threads, each pinned to
//!   its own [`Runtime`] instance built from a
//!   [`crate::runtime::RuntimeFactory`]
//!   (`PjRtClient` is `Rc`-backed and `!Send`, so runtimes cannot migrate
//!   between threads — see `runtime/mod.rs`).
//!
//! Determinism contract: executors return results **in job order**,
//! regardless of completion order, and every job carries its own pre-split
//! [`Rng`] stream. The engine aggregates in that order with the same f64
//! arithmetic as the sequential path, so a run's `RunResult` is
//! bit-identical for any worker count (verified by
//! `rust/tests/proptest_exec.rs`).
//!
//! A third wrapper, [`Overlapped`], parameterizes the engine's *async
//! round overlap* pipeline (quorum-triggered aggregation with
//! staleness-bounded delayed gradients — see [`overlapped`]); it changes
//! when the simulated server aggregates, never what is computed, so it
//! composes with either compute executor.
//!
//! *Where* in the pool a job lands is decided by a [`DispatchPolicy`]
//! ([`dispatch`]): round-robin dealing by job index (the default), or a
//! deterministic work-stealing schedule simulated in virtual time from
//! the jobs' simulated costs — better utilization under heavy-tailed
//! plans, with placement still a pure function of the run's seed.
//! Either way results collect by job index, so the dispatch policy is
//! never observable in model outputs (`rust/tests/proptest_dispatch.rs`).

pub mod dispatch;
pub mod overlapped;
pub mod sequential;
pub mod sharded;

pub use self::dispatch::{
    plan_schedule, DispatchPolicy, DispatchStats, JobKind, Schedule, ScheduleEntry, ScheduleTrace,
    WorkerRollup,
};
pub use self::overlapped::{DelayedUpdate, InFlight, OverlapConfig, Overlapped};
pub use self::sequential::Sequential;
pub use self::sharded::Sharded;

use std::sync::Arc;

use anyhow::Result;

use crate::coreset::{Coreset, Method};
use crate::data::FedDataset;
use crate::fl::client::run_client;
use crate::fl::plan::LocalPlan;
use crate::fl::ClientOutcome;
use crate::runtime::{EvalOutput, ModelInfo, Runtime};
use crate::sim::Fleet;
use crate::util::rng::Rng;

/// Everything shared by all jobs of one engine: the dataset, the model
/// under training, the simulated fleet, and the training hyper-parameters.
/// `Send + Sync`, handed to workers as an `Arc`.
pub struct ExecContext {
    /// The federated dataset (shards + test set).
    pub data: Arc<FedDataset>,
    /// The model under training (manifest entry).
    pub model: ModelInfo,
    /// Shared with the engine (same allocation), so planning and client
    /// simulation can never see diverging fleets.
    pub fleet: Arc<Fleet>,
    /// SGD learning rate.
    pub lr: f32,
    /// FedProx proximal μ (0 for the other strategies).
    pub mu: f32,
    /// k-medoids solver for adaptive coreset construction.
    pub method: Method,
    /// Threads sharding each job's coreset hot path (distance tiles +
    /// FasterPAM scans). Follows the executor's worker count; results are
    /// bit-identical at any value (`tests/proptest_coreset.rs`).
    pub coreset_workers: usize,
}

/// One selected client's work for one round. The RNG stream is split by
/// the engine from `(round, client)` before dispatch, so outcomes do not
/// depend on which worker runs the job or in what order.
pub struct ClientJob {
    /// Index into `ctx.data.clients`.
    pub client: usize,
    /// The client's local work for this round (per-strategy).
    pub plan: LocalPlan,
    /// The round's global model wᵣ (shared, read-only).
    pub global: Arc<Vec<f32>>,
    /// §4.3 static coreset, precomputed by the engine's per-client cache.
    pub static_coreset: Option<Coreset>,
    /// Cached medoids from this client's previous adaptive coreset — the
    /// warm-start seed on non-refresh rounds (`RunConfig::coreset_refresh`).
    pub warm_medoids: Option<Vec<usize>>,
    /// This job's pre-split RNG stream (minibatch shuffles, tie-breaks).
    pub rng: Rng,
}

/// One evaluation batch: test-set rows `start..end` (at most `feat_batch`
/// of them — exactly one PJRT call, so that merging job outputs in order
/// reproduces the sequential merge bit-for-bit).
pub struct EvalJob {
    /// The parameters under evaluation (shared, read-only).
    pub params: Arc<Vec<f32>>,
    /// First test-set row of this batch (inclusive).
    pub start: usize,
    /// One past the last test-set row of this batch.
    pub end: usize,
}

/// Where round jobs execute. Implementations must return results in job
/// order and must not reorder the per-job RNG streams.
///
/// # Example
///
/// ```no_run
/// use std::sync::Arc;
/// use fedcore::data::{self, Benchmark};
/// use fedcore::exec::{Executor, Sharded};
/// use fedcore::fl::{Engine, RunConfig};
/// use fedcore::runtime::Runtime;
///
/// # fn main() -> fedcore::Result<()> {
/// let rt = Runtime::load("artifacts")?;
/// let ds = Arc::new(data::generate(
///     Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
///     0.2,
///     &rt.manifest().vocab,
///     7,
/// ));
/// // Four workers, each pinned to its own runtime. Results reduce in job
/// // order, so this run is bit-identical to a sequential one.
/// let exec = Sharded::new(4, rt.factory());
/// assert_eq!(exec.workers(), 4);
/// let _result = Engine::with_executor(&rt, &ds, RunConfig::default(), exec)?.run()?;
/// # Ok(())
/// # }
/// ```
pub trait Executor {
    /// Worker parallelism (1 for sequential).
    fn workers(&self) -> usize;

    /// Execute all client jobs of one round; `out[i]` corresponds to
    /// `jobs[i]`.
    fn run_clients(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<ClientJob>,
    ) -> Result<Vec<ClientOutcome>>;

    /// Execute evaluation batches; `out[i]` corresponds to `jobs[i]`.
    fn run_evals(&self, ctx: &Arc<ExecContext>, jobs: Vec<EvalJob>) -> Result<Vec<EvalOutput>>;

    /// The dispatch policy placing this executor's jobs (informational;
    /// the default is round-robin, which every single-runtime executor
    /// trivially satisfies).
    fn dispatch_policy(&self) -> DispatchPolicy {
        DispatchPolicy::RoundRobin
    }

    /// Start (or stop) recording a [`ScheduleTrace`] ledger of every
    /// dispatched job's placement and virtual timing. Starting clears
    /// any previous ledger. The default executor records nothing.
    fn record_schedule(&self, _on: bool) {}

    /// Drain the recorded [`ScheduleTrace`] (`None` when recording is
    /// off or the executor does not instrument dispatch).
    fn take_schedule(&self) -> Option<ScheduleTrace> {
        None
    }

    /// Dispatch accounting of the most recent **client** batch (steals,
    /// busy/idle worker-seconds, makespan — all in virtual time), which
    /// the engine records per round. `None` until a client batch ran or
    /// when the executor does not instrument dispatch.
    fn last_client_dispatch(&self) -> Option<DispatchStats> {
        None
    }
}

/// A shared reference to an executor is itself an executor (the trait
/// only ever takes `&self`). This is what lets a whole sweep's engines
/// reuse **one** [`Sharded`] pool — and its compiled per-worker runtimes
/// — instead of building a pool per engine: build the pool once, hand
/// `&pool` to each [`crate::fl::Engine::with_executor`]. Results are
/// bit-identical to per-engine pools (`rust/tests/proptest_exec.rs`).
impl<E: Executor + ?Sized> Executor for &E {
    fn workers(&self) -> usize {
        (**self).workers()
    }

    fn run_clients(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<ClientJob>,
    ) -> Result<Vec<ClientOutcome>> {
        (**self).run_clients(ctx, jobs)
    }

    fn run_evals(&self, ctx: &Arc<ExecContext>, jobs: Vec<EvalJob>) -> Result<Vec<EvalOutput>> {
        (**self).run_evals(ctx, jobs)
    }

    fn dispatch_policy(&self) -> DispatchPolicy {
        (**self).dispatch_policy()
    }

    fn record_schedule(&self, on: bool) {
        (**self).record_schedule(on)
    }

    fn take_schedule(&self) -> Option<ScheduleTrace> {
        (**self).take_schedule()
    }

    fn last_client_dispatch(&self) -> Option<DispatchStats> {
        (**self).last_client_dispatch()
    }
}

/// Resolve a worker-count setting (`0` = auto via
/// [`crate::util::pool::default_threads`]) and build the **shared sweep
/// pool** when it calls for one: `Some(pool)` for `> 1` effective
/// workers, `None` when the sequential path should be used. One rule for
/// every sweep site ([`crate::expt::run_cell`], the CLI `sweep`), so
/// sweeps can never diverge from single runs on worker resolution. The
/// pool deals jobs per `dispatch` (results are bit-identical either
/// way — the policy only moves placement).
pub fn sweep_pool(
    workers: usize,
    factory: crate::runtime::RuntimeFactory,
    dispatch: DispatchPolicy,
) -> Option<Sharded> {
    let n = if workers == 0 { crate::util::pool::default_threads() } else { workers };
    (n > 1).then(|| Sharded::with_policy(n, factory, dispatch))
}

/// Deterministic simulated cost of one client job — the dispatch
/// scheduler's input. Exactly the plan's simulated duration
/// ([`crate::fl::LocalPlan::sim_time`]; 0 for dropped plans), so the
/// schedule is a pure function of the run's seed.
pub(crate) fn client_job_cost(ctx: &ExecContext, job: &ClientJob) -> f64 {
    job.plan.sim_time(&ctx.fleet, job.client)
}

/// Deterministic cost proxy of one evaluation batch: its row count
/// (every row is one forward pass; batches differ only at the tail).
pub(crate) fn eval_job_cost(job: &EvalJob) -> f64 {
    (job.end - job.start) as f64
}

/// Run one client job against `rt` (shared by both executors).
pub(crate) fn exec_client(
    rt: &Runtime,
    ctx: &ExecContext,
    job: ClientJob,
) -> Result<ClientOutcome> {
    let ClientJob { client, plan, global, static_coreset, warm_medoids, mut rng } = job;
    run_client(
        rt,
        &ctx.model,
        &ctx.data.clients[client],
        &ctx.fleet,
        client,
        global.as_slice(),
        &plan,
        ctx.lr,
        ctx.mu,
        ctx.method,
        static_coreset.as_ref(),
        warm_medoids.as_deref(),
        ctx.coreset_workers,
        &mut rng,
    )
}

/// Run one evaluation batch against `rt` (shared by both executors).
pub(crate) fn exec_eval(rt: &Runtime, ctx: &ExecContext, job: &EvalJob) -> Result<EvalOutput> {
    let f = rt.manifest().feat_batch;
    let idxs: Vec<usize> = (job.start..job.end).collect();
    let (x, y, mask) = ctx.data.test.gather_batch(&idxs, None, f);
    rt.evaluate(&ctx.model, job.params.as_slice(), &x, &y, &mask)
}

/// The built-in executors behind one concrete type, so `Engine::new`
/// can pick at run time from `RunConfig::workers` (and
/// `RunConfig::overlap`) without making every caller generic.
pub enum ExecutorImpl<'a> {
    /// In-thread execution on the engine's own runtime.
    Sequential(Sequential<'a>),
    /// Persistent pool of runtime-pinned worker threads.
    Sharded(Sharded),
    /// In-thread execution under the overlapped pipeline.
    OverlappedSequential(Overlapped<Sequential<'a>>),
    /// Sharded pool under the overlapped pipeline.
    OverlappedSharded(Overlapped<Sharded>),
}

impl<'a> ExecutorImpl<'a> {
    /// Resolve a worker-count setting: `0` = auto
    /// ([`crate::util::pool::default_threads`], which honors
    /// `FEDCORE_THREADS`), `1` = in-thread sequential, `N > 1` = sharded
    /// pool of N runtime-pinned workers dealing jobs per `dispatch`.
    /// When `overlap` is set the chosen executor is wrapped in
    /// [`Overlapped`], whose constructor validates the policy (an
    /// invalid quorum/alpha surfaces here as `Err`).
    pub fn from_config(
        rt: &'a Runtime,
        workers: usize,
        overlap: Option<OverlapConfig>,
        dispatch: DispatchPolicy,
    ) -> Result<ExecutorImpl<'a>> {
        let n = if workers == 0 { crate::util::pool::default_threads() } else { workers };
        Ok(match (n <= 1, overlap) {
            (true, None) => ExecutorImpl::Sequential(Sequential::new(rt)),
            (false, None) => {
                ExecutorImpl::Sharded(Sharded::with_policy(n, rt.factory(), dispatch))
            }
            (true, Some(cfg)) => {
                ExecutorImpl::OverlappedSequential(Overlapped::new(Sequential::new(rt), cfg)?)
            }
            (false, Some(cfg)) => ExecutorImpl::OverlappedSharded(Overlapped::new(
                Sharded::with_policy(n, rt.factory(), dispatch),
                cfg,
            )?),
        })
    }
}

impl Executor for ExecutorImpl<'_> {
    fn workers(&self) -> usize {
        match self {
            ExecutorImpl::Sequential(e) => e.workers(),
            ExecutorImpl::Sharded(e) => e.workers(),
            ExecutorImpl::OverlappedSequential(e) => e.workers(),
            ExecutorImpl::OverlappedSharded(e) => e.workers(),
        }
    }

    fn run_clients(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<ClientJob>,
    ) -> Result<Vec<ClientOutcome>> {
        match self {
            ExecutorImpl::Sequential(e) => e.run_clients(ctx, jobs),
            ExecutorImpl::Sharded(e) => e.run_clients(ctx, jobs),
            ExecutorImpl::OverlappedSequential(e) => e.run_clients(ctx, jobs),
            ExecutorImpl::OverlappedSharded(e) => e.run_clients(ctx, jobs),
        }
    }

    fn run_evals(&self, ctx: &Arc<ExecContext>, jobs: Vec<EvalJob>) -> Result<Vec<EvalOutput>> {
        match self {
            ExecutorImpl::Sequential(e) => e.run_evals(ctx, jobs),
            ExecutorImpl::Sharded(e) => e.run_evals(ctx, jobs),
            ExecutorImpl::OverlappedSequential(e) => e.run_evals(ctx, jobs),
            ExecutorImpl::OverlappedSharded(e) => e.run_evals(ctx, jobs),
        }
    }

    fn dispatch_policy(&self) -> DispatchPolicy {
        match self {
            ExecutorImpl::Sequential(e) => e.dispatch_policy(),
            ExecutorImpl::Sharded(e) => e.dispatch_policy(),
            ExecutorImpl::OverlappedSequential(e) => e.dispatch_policy(),
            ExecutorImpl::OverlappedSharded(e) => e.dispatch_policy(),
        }
    }

    fn record_schedule(&self, on: bool) {
        match self {
            ExecutorImpl::Sequential(e) => e.record_schedule(on),
            ExecutorImpl::Sharded(e) => e.record_schedule(on),
            ExecutorImpl::OverlappedSequential(e) => e.record_schedule(on),
            ExecutorImpl::OverlappedSharded(e) => e.record_schedule(on),
        }
    }

    fn take_schedule(&self) -> Option<ScheduleTrace> {
        match self {
            ExecutorImpl::Sequential(e) => e.take_schedule(),
            ExecutorImpl::Sharded(e) => e.take_schedule(),
            ExecutorImpl::OverlappedSequential(e) => e.take_schedule(),
            ExecutorImpl::OverlappedSharded(e) => e.take_schedule(),
        }
    }

    fn last_client_dispatch(&self) -> Option<DispatchStats> {
        match self {
            ExecutorImpl::Sequential(e) => e.last_client_dispatch(),
            ExecutorImpl::Sharded(e) => e.last_client_dispatch(),
            ExecutorImpl::OverlappedSequential(e) => e.last_client_dispatch(),
            ExecutorImpl::OverlappedSharded(e) => e.last_client_dispatch(),
        }
    }
}
