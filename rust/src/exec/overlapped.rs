//! Async round overlap: quorum-triggered aggregation with in-flight
//! bookkeeping for staleness-bounded delayed gradients.
//!
//! Synchronous FL barriers every round on its slowest participant. The
//! overlapped pipeline instead lets the server aggregate — and dispatch
//! the next round — as soon as a **quorum** (a configurable fraction of
//! the round's contributing clients) has reported back. Clients past the
//! quorum keep computing; their updates travel through an [`InFlight`]
//! ledger and are folded into a *later* round's aggregation as delayed
//! gradients, down-weighted by staleness (`1/(1+s)^alpha`, following
//! "Stragglers Are Not Disaster", arXiv:2102.06329) and discarded outright
//! once staleness exceeds a hard cap. (With straggler distillation enabled
//! — `RunConfig::distill_weight > 0` — the engine replaces that discard
//! path: past-cap updates fold into a decayed post-aggregate correction
//! instead, see [`crate::scenario::selection`]; the ledger mechanics here
//! are unchanged.) The fold itself goes through the
//! engine's configured [`crate::agg::Aggregator`] — the weighted mean by
//! default, or FedBuff-style buffering / robust policies — and
//! [`crate::agg::AdaptiveQuorum`] can tighten or relax `quorum` per round
//! from the observed stale-discard rate.
//!
//! Determinism contract: everything here is simulated-time bookkeeping —
//! no wall-clock, no extra RNG draws. Late updates are keyed by
//! `(origin_round, selection slot)` and every drain returns them in that
//! order, so an overlapped run replays bit-for-bit from its seed, and the
//! degenerate configuration (`quorum = 1.0`, `max_staleness = 0`) leaves
//! the ledger empty forever, reproducing the synchronous engine exactly
//! (enforced by `rust/tests/proptest_overlap.rs`).

use anyhow::{anyhow, Result};

use std::sync::Arc;

use super::{ClientJob, EvalJob, ExecContext, Executor};
use crate::fl::ClientOutcome;
use crate::runtime::EvalOutput;

/// Staleness decay weight `1/(1+s)^alpha` for an update that is `s`
/// rounds old at fold time. `s = 0` (an on-time update) always weighs
/// exactly `1.0`; larger `alpha` forgets stale updates faster, and
/// `alpha = 0` treats every non-discarded update equally.
pub fn staleness_weight(staleness: usize, alpha: f64) -> f64 {
    if staleness == 0 {
        return 1.0;
    }
    1.0 / (1.0 + staleness as f64).powf(alpha)
}

/// Parameters of the overlapped (quorum + delayed gradient) pipeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverlapConfig {
    /// Fraction of a round's contributing clients the server waits for
    /// before aggregating and advancing, in `(0, 1]`. `1.0` waits for
    /// everyone — the synchronous barrier.
    pub quorum: f64,
    /// Hard staleness cap, in rounds: a delayed update folded `s` rounds
    /// after its origin is discarded when `s > max_staleness` (and
    /// accounted per-round like churn drops). `0` discards every late
    /// update.
    pub max_staleness: usize,
    /// Staleness decay exponent for [`staleness_weight`].
    pub alpha: f64,
}

impl Default for OverlapConfig {
    fn default() -> Self {
        OverlapConfig { quorum: 0.8, max_staleness: 2, alpha: 1.0 }
    }
}

impl OverlapConfig {
    /// The degenerate configuration that must reproduce the synchronous
    /// engine bit-for-bit: full quorum, no staleness tolerance.
    pub fn degenerate() -> OverlapConfig {
        OverlapConfig { quorum: 1.0, max_staleness: 0, alpha: 1.0 }
    }

    /// Validate the parameters (quorum in `(0, 1]`, finite `alpha >= 0`).
    pub fn validate(&self) -> Result<()> {
        if !(self.quorum > 0.0 && self.quorum <= 1.0) {
            return Err(anyhow!("overlap quorum must be in (0, 1], got {}", self.quorum));
        }
        if !(self.alpha >= 0.0 && self.alpha.is_finite()) {
            return Err(anyhow!("overlap alpha must be finite and >= 0, got {}", self.alpha));
        }
        Ok(())
    }

    /// How many of `n` contributing clients make a quorum:
    /// `ceil(quorum * n)`, clamped to `[1, n]` (`0` only when `n = 0`).
    pub fn quorum_count(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        ((self.quorum * n as f64).ceil() as usize).clamp(1, n)
    }

    /// [`staleness_weight`] at this config's `alpha`.
    pub fn weight(&self, staleness: usize) -> f64 {
        staleness_weight(staleness, self.alpha)
    }
}

/// One late client update in flight between rounds: the round-end local
/// parameters plus everything needed to fold (or discard) them
/// deterministically later.
#[derive(Clone, Debug)]
pub struct DelayedUpdate {
    /// The round the client was selected in.
    pub origin_round: usize,
    /// The client's selection slot within its origin round (the
    /// deterministic tie-break key — slots are unique per round even when
    /// sampling-with-replacement picks one client twice).
    pub slot: usize,
    /// The client's index.
    pub client: usize,
    /// Absolute simulated instant the update reaches the server
    /// (origin round start + the client's simulated local time).
    pub arrival: f64,
    /// The round-end local parameters wᵢ.
    pub params: Vec<f32>,
}

/// The in-flight ledger: every late update between its origin round and
/// the aggregation that folds or discards it.
///
/// All queries are deterministic: arrivals drain ordered by
/// `(origin_round, slot)`, never by insertion or completion order, so the
/// fold order in the engine's weighted aggregation is a pure function of
/// the run's seed.
#[derive(Clone, Debug, Default)]
pub struct InFlight {
    pending: Vec<DelayedUpdate>,
}

impl InFlight {
    /// An empty ledger.
    pub fn new() -> InFlight {
        InFlight::default()
    }

    /// Updates currently in flight.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Is the ledger empty?
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Clients with an update currently in flight (ascending, deduped).
    pub fn busy_clients(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self.pending.iter().map(|u| u.client).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Record a late update.
    pub fn push(&mut self, update: DelayedUpdate) {
        self.pending.push(update);
    }

    /// Remove and return every update that has arrived by `now`
    /// (`arrival <= now`), ordered by `(origin_round, slot)`.
    pub fn take_arrived(&mut self, now: f64) -> Vec<DelayedUpdate> {
        let mut arrived = Vec::new();
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].arrival <= now {
                arrived.push(self.pending.swap_remove(i));
            } else {
                i += 1;
            }
        }
        arrived.sort_by_key(|u| (u.origin_round, u.slot));
        arrived
    }

    /// Drop every still-pending update that can no longer fold: after
    /// round `round`'s aggregation, the earliest possible fold is round
    /// `round + 1`, so anything with `round - origin >= max_staleness` is
    /// already doomed. Returns how many were discarded.
    pub fn discard_doomed(&mut self, round: usize, max_staleness: usize) -> usize {
        let before = self.pending.len();
        self.pending.retain(|u| round - u.origin_round < max_staleness);
        before - self.pending.len()
    }

    /// Drop everything (end of run); returns how many updates were still
    /// in flight.
    pub fn discard_all(&mut self) -> usize {
        let n = self.pending.len();
        self.pending.clear();
        n
    }
}

/// Executor wrapper marking overlapped execution: compute still runs on
/// the wrapped executor — sequential or a sharded pool — and the engine
/// drives the pipeline itself from [`crate::fl::RunConfig`]'s `overlap`
/// policy (this wrapper validates and carries a copy for introspection
/// via [`Overlapped::config`], e.g. by `Engine::executor()` callers).
/// Overlap changes *when the simulated server aggregates*, never *what
/// is computed*, so the executor determinism contract (results in job
/// order) is inherited unchanged from the inner executor.
pub struct Overlapped<E> {
    inner: E,
    cfg: OverlapConfig,
}

impl<E: Executor> Overlapped<E> {
    /// Wrap `inner` with an overlap policy (validated).
    pub fn new(inner: E, cfg: OverlapConfig) -> Result<Overlapped<E>> {
        cfg.validate()?;
        Ok(Overlapped { inner, cfg })
    }

    /// The quorum / staleness policy this executor was built with.
    pub fn config(&self) -> &OverlapConfig {
        &self.cfg
    }

    /// The wrapped executor.
    pub fn inner(&self) -> &E {
        &self.inner
    }
}

impl<E: Executor> Executor for Overlapped<E> {
    fn workers(&self) -> usize {
        self.inner.workers()
    }

    fn run_clients(
        &self,
        ctx: &Arc<ExecContext>,
        jobs: Vec<ClientJob>,
    ) -> Result<Vec<ClientOutcome>> {
        self.inner.run_clients(ctx, jobs)
    }

    fn run_evals(&self, ctx: &Arc<ExecContext>, jobs: Vec<EvalJob>) -> Result<Vec<EvalOutput>> {
        self.inner.run_evals(ctx, jobs)
    }

    // Dispatch instrumentation passes straight through: overlap changes
    // when the server aggregates, never where jobs run.
    fn dispatch_policy(&self) -> super::DispatchPolicy {
        self.inner.dispatch_policy()
    }

    fn record_schedule(&self, on: bool) {
        self.inner.record_schedule(on)
    }

    fn take_schedule(&self) -> Option<super::ScheduleTrace> {
        self.inner.take_schedule()
    }

    fn last_client_dispatch(&self) -> Option<super::DispatchStats> {
        self.inner.last_client_dispatch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn update(origin: usize, slot: usize, arrival: f64) -> DelayedUpdate {
        DelayedUpdate {
            origin_round: origin,
            slot,
            client: 10 * origin + slot,
            arrival,
            params: vec![origin as f32, slot as f32],
        }
    }

    #[test]
    fn weight_is_one_on_time_and_decays() {
        assert_eq!(staleness_weight(0, 2.0), 1.0);
        assert_eq!(staleness_weight(1, 1.0), 0.5);
        assert_eq!(staleness_weight(3, 1.0), 0.25);
        // alpha = 0: every non-discarded update weighs 1.
        assert_eq!(staleness_weight(7, 0.0), 1.0);
    }

    #[test]
    fn quorum_count_bounds() {
        let half = OverlapConfig { quorum: 0.5, ..OverlapConfig::default() };
        assert_eq!(half.quorum_count(0), 0);
        assert_eq!(half.quorum_count(1), 1);
        assert_eq!(half.quorum_count(4), 2);
        assert_eq!(half.quorum_count(5), 3); // ceil
        let full = OverlapConfig::degenerate();
        for n in 0..20 {
            assert_eq!(full.quorum_count(n), n);
        }
        // A tiny quorum still waits for at least one client.
        let tiny = OverlapConfig { quorum: 0.01, ..OverlapConfig::default() };
        assert_eq!(tiny.quorum_count(3), 1);
    }

    #[test]
    fn validate_rejects_bad_params() {
        assert!(OverlapConfig::default().validate().is_ok());
        assert!(OverlapConfig { quorum: 0.0, ..Default::default() }.validate().is_err());
        assert!(OverlapConfig { quorum: 1.5, ..Default::default() }.validate().is_err());
        assert!(OverlapConfig { alpha: -1.0, ..Default::default() }.validate().is_err());
        assert!(OverlapConfig { alpha: f64::NAN, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn in_flight_drains_in_origin_slot_order() {
        let mut fl = InFlight::new();
        // Push out of order; drain must come back (origin, slot)-sorted.
        fl.push(update(2, 1, 5.0));
        fl.push(update(1, 3, 4.0));
        fl.push(update(1, 0, 3.0));
        fl.push(update(2, 0, 9.0));
        assert_eq!(fl.len(), 4);
        assert_eq!(fl.busy_clients(), vec![10, 13, 20, 21]);

        let arrived = fl.take_arrived(5.0);
        let keys: Vec<(usize, usize)> =
            arrived.iter().map(|u| (u.origin_round, u.slot)).collect();
        assert_eq!(keys, vec![(1, 0), (1, 3), (2, 1)]);
        assert_eq!(fl.len(), 1, "the 9.0 arrival stays in flight");
        assert!(fl.take_arrived(5.0).is_empty());
    }

    #[test]
    fn discard_doomed_enforces_the_cap() {
        let mut fl = InFlight::new();
        fl.push(update(0, 0, 100.0));
        fl.push(update(3, 0, 100.0));
        // After round 3 with max_staleness = 2: the round-0 update would
        // fold at staleness >= 4 — doomed; the round-3 one can still make
        // rounds 4 or 5.
        assert_eq!(fl.discard_doomed(3, 2), 1);
        assert_eq!(fl.len(), 1);
        // max_staleness = 0 dooms everything still pending.
        assert_eq!(fl.discard_doomed(3, 0), 1);
        assert!(fl.is_empty());
        assert_eq!(fl.discard_all(), 0);
    }
}
