//! Simulated clock: accumulates per-round simulated time and exposes the
//! paper's normalized-time view (round deadline τ = 1.0).
//!
//! Synchronous FL semantics: a round ends when the *slowest participating
//! client* finishes (or when every deadline-aware client has stopped at τ),
//! so the round length is the max over per-client times. FedAvg ignores τ
//! and its rounds stretch to the straggler tail (paper Fig. 4's 11× tail).
//!
//! Overlapped (async) semantics: the server advances as soon as a quorum
//! of the round's clients has reported, while the straggler tail keeps
//! computing in the background. [`RoundTiming`] therefore carries **two**
//! per-round times — [`RoundTiming::round_time`], the server-advance
//! (quorum) time the clock accumulates, and [`RoundTiming::tail_time`],
//! when the round's slowest client actually finished — so metrics never
//! conflate the pipeline rate with the straggler tail. In synchronous
//! mode the two coincide.

/// Per-round simulated timing record.
#[derive(Clone, Debug, Default)]
pub struct RoundTiming {
    /// Per-participating-client simulated times (seconds).
    pub client_times: Vec<f64>,
    /// Server-advance time: how long the server waits before aggregating
    /// and starting the next round. Synchronous: `max(client_times)`
    /// (plus any deadline wait imposed by the engine). Overlapped: the
    /// quorum completion time.
    pub round_time: f64,
    /// Straggler-tail time: when the round's slowest participating client
    /// finished (`max(client_times)`), regardless of when the server
    /// advanced. `round_time <= tail_time` in overlapped rounds;
    /// `round_time >= tail_time` when the server waits out τ on a
    /// mid-round dropout.
    pub tail_time: f64,
}

impl RoundTiming {
    /// Synchronous round: server advance = straggler tail = max client
    /// time (0.0 for an empty round).
    pub fn from_clients(client_times: Vec<f64>) -> RoundTiming {
        let tail = client_times.iter().copied().fold(0.0f64, f64::max);
        RoundTiming { client_times, round_time: tail, tail_time: tail }
    }

    /// Overlapped round: the server advances at `quorum_time` (the q-th
    /// smallest client time, computed by the engine) while the tail runs
    /// to `max(client_times)`. `quorum_time` must not exceed the tail.
    pub fn with_quorum(client_times: Vec<f64>, quorum_time: f64) -> RoundTiming {
        let tail = client_times.iter().copied().fold(0.0f64, f64::max);
        debug_assert!(
            quorum_time <= tail || client_times.is_empty(),
            "quorum time {quorum_time} past the tail {tail}"
        );
        RoundTiming { client_times, round_time: quorum_time, tail_time: tail }
    }

    /// An idle round (nobody contributed): the server waits out the full
    /// deadline before moving on.
    pub fn idle(deadline: f64) -> RoundTiming {
        RoundTiming { client_times: vec![], round_time: deadline, tail_time: deadline }
    }
}

/// Accumulates rounds; all queries are O(1)/O(n) over stored records.
/// Also keeps the run's **dispatch utilization ledger**: per-round busy
/// and capacity worker-seconds from the executor's virtual-time dispatch
/// schedule ([`crate::exec::DispatchStats`]), so run-level worker
/// utilization is one query away. Dispatch accounting never feeds the
/// simulated round times — it is diagnostics, not simulation state
/// (ARCHITECTURE.md determinism rule 6).
#[derive(Clone, Debug)]
pub struct SimClock {
    /// τ used to normalize (1.0 ⇒ no normalization).
    pub deadline: f64,
    rounds: Vec<RoundTiming>,
    elapsed: f64,
    dispatch_busy: f64,
    dispatch_capacity: f64,
}

impl SimClock {
    /// A fresh clock normalizing by `deadline` (must be positive).
    pub fn new(deadline: f64) -> SimClock {
        assert!(deadline > 0.0);
        SimClock {
            deadline,
            rounds: Vec::new(),
            elapsed: 0.0,
            dispatch_busy: 0.0,
            dispatch_capacity: 0.0,
        }
    }

    /// Record one round's dispatch accounting: `busy` worker-seconds of
    /// simulated work over `capacity` worker-seconds of schedule span
    /// (workers × makespan).
    pub fn record_dispatch(&mut self, busy: f64, capacity: f64) {
        self.dispatch_busy += busy;
        self.dispatch_capacity += capacity;
    }

    /// Run-level worker utilization of the dispatch schedules recorded so
    /// far: total busy over total capacity (`1.0` before any capacity is
    /// recorded — an empty or sequential run wastes nothing).
    pub fn dispatch_utilization(&self) -> f64 {
        if self.dispatch_capacity <= 0.0 {
            return 1.0;
        }
        self.dispatch_busy / self.dispatch_capacity
    }

    /// Total simulated idle worker-seconds across all recorded dispatch
    /// schedules (capacity minus busy, clamped ≥ 0).
    pub fn dispatch_idle_seconds(&self) -> f64 {
        (self.dispatch_capacity - self.dispatch_busy).max(0.0)
    }

    /// Record one round; the clock advances by the **server-advance**
    /// time (`round_time`), never the straggler tail. Returns the
    /// advance.
    pub fn push_round(&mut self, timing: RoundTiming) -> f64 {
        let t = timing.round_time;
        self.elapsed += t;
        self.rounds.push(timing);
        t
    }

    /// Total simulated seconds of server time so far.
    pub fn elapsed(&self) -> f64 {
        self.elapsed
    }

    /// The current simulated instant (alias of [`SimClock::elapsed`]).
    /// This is the time at which a new round begins — availability traces
    /// ([`crate::scenario::AvailabilityTrace`]) are read at this instant
    /// to decide which clients are eligible for selection.
    pub fn now(&self) -> f64 {
        self.elapsed
    }

    /// When the last in-flight client work actually finished: the max
    /// over rounds of (round start + tail time). Equals
    /// [`SimClock::elapsed`] in synchronous runs; in overlapped runs the
    /// final rounds' tails may overhang the server clock.
    pub fn completion_time(&self) -> f64 {
        let mut start = 0.0f64;
        let mut done = 0.0f64;
        for r in &self.rounds {
            done = done.max(start + r.tail_time);
            start += r.round_time;
        }
        done.max(start)
    }

    /// Cumulative simulated server time after each round (Fig. 5's x-axis).
    pub fn cumulative(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.rounds
            .iter()
            .map(|r| {
                acc += r.round_time;
                acc
            })
            .collect()
    }

    /// Rounds recorded so far.
    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Server-advance (quorum) round lengths normalized by τ (paper
    /// Table 2: "normalized time of 1 is round deadline").
    pub fn normalized_round_times(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.round_time / self.deadline).collect()
    }

    /// Straggler-tail round lengths normalized by τ — how long each
    /// round's slowest client ran, even past the server's advance.
    pub fn normalized_tail_times(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.tail_time / self.deadline).collect()
    }

    /// Mean normalized server-advance round length — the Table 2 time
    /// metric.
    pub fn mean_normalized_round(&self) -> f64 {
        let ts = self.normalized_round_times();
        crate::util::stats::mean(&ts)
    }

    /// Every participating client's normalized time across all rounds
    /// (Fig. 4 / Fig. 7 histograms are over *client* round times).
    pub fn all_client_times_normalized(&self) -> Vec<f64> {
        self.rounds
            .iter()
            .flat_map(|r| r.client_times.iter().map(|t| t / self.deadline))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_time_is_max_of_clients() {
        let t = RoundTiming::from_clients(vec![1.0, 3.0, 2.0]);
        assert_eq!(t.round_time, 3.0);
        assert_eq!(t.tail_time, 3.0);
    }

    #[test]
    fn empty_round_is_zero() {
        let t = RoundTiming::from_clients(vec![]);
        assert_eq!(t.round_time, 0.0);
        assert_eq!(t.tail_time, 0.0);
    }

    #[test]
    fn quorum_timing_splits_advance_from_tail() {
        let t = RoundTiming::with_quorum(vec![1.0, 3.0, 2.0], 2.0);
        assert_eq!(t.round_time, 2.0, "server advances at the quorum");
        assert_eq!(t.tail_time, 3.0, "the straggler tail is preserved");
        // Full quorum degenerates to the synchronous record.
        let full = RoundTiming::with_quorum(vec![1.0, 3.0, 2.0], 3.0);
        assert_eq!(full.round_time, full.tail_time);
    }

    #[test]
    fn idle_round_costs_the_deadline() {
        let t = RoundTiming::idle(2.5);
        assert_eq!(t.round_time, 2.5);
        assert_eq!(t.tail_time, 2.5);
        assert!(t.client_times.is_empty());
    }

    #[test]
    fn clock_advances_on_quorum_not_tail() {
        let mut c = SimClock::new(1.0);
        c.push_round(RoundTiming::with_quorum(vec![1.0, 5.0], 1.0));
        c.push_round(RoundTiming::with_quorum(vec![2.0, 3.0], 2.0));
        // Server time: 1 + 2; tails (1+5=6 from round 0) overhang it.
        assert_eq!(c.elapsed(), 3.0);
        assert_eq!(c.completion_time(), 6.0);
        assert_eq!(c.normalized_round_times(), vec![1.0, 2.0]);
        assert_eq!(c.normalized_tail_times(), vec![5.0, 3.0]);
    }

    #[test]
    fn completion_time_equals_elapsed_when_synchronous() {
        let mut c = SimClock::new(1.0);
        c.push_round(RoundTiming::from_clients(vec![2.0, 1.0]));
        c.push_round(RoundTiming::from_clients(vec![4.0]));
        assert_eq!(c.elapsed(), 6.0);
        assert_eq!(c.completion_time(), 6.0);
        // A server-side deadline wait (round_time > tail) is still counted.
        let mut d = SimClock::new(1.0);
        let mut t = RoundTiming::from_clients(vec![0.5]);
        t.round_time = 2.0; // engine maxed with τ after a churn dropout
        d.push_round(t);
        assert_eq!(d.elapsed(), 2.0);
        assert_eq!(d.completion_time(), 2.0);
    }

    #[test]
    fn dispatch_utilization_accumulates_and_defaults_to_full() {
        let mut c = SimClock::new(1.0);
        // Nothing recorded: a sequential run wastes nothing.
        assert_eq!(c.dispatch_utilization(), 1.0);
        assert_eq!(c.dispatch_idle_seconds(), 0.0);
        // Round 1: 6 busy worker-seconds over 8 of capacity; round 2:
        // 2 over 2 (perfectly packed).
        c.record_dispatch(6.0, 8.0);
        c.record_dispatch(2.0, 2.0);
        assert_eq!(c.dispatch_utilization(), 0.8);
        assert_eq!(c.dispatch_idle_seconds(), 2.0);
        // The ledger never touches the simulated clock.
        assert_eq!(c.elapsed(), 0.0);
    }

    #[test]
    fn cumulative_and_elapsed_agree() {
        let mut c = SimClock::new(2.0);
        c.push_round(RoundTiming::from_clients(vec![2.0]));
        c.push_round(RoundTiming::from_clients(vec![4.0, 1.0]));
        assert_eq!(c.elapsed(), 6.0);
        assert_eq!(c.cumulative(), vec![2.0, 6.0]);
        assert_eq!(c.num_rounds(), 2);
    }

    #[test]
    fn normalization_by_deadline() {
        let mut c = SimClock::new(2.0);
        c.push_round(RoundTiming::from_clients(vec![1.0, 2.0]));
        c.push_round(RoundTiming::from_clients(vec![6.0]));
        assert_eq!(c.normalized_round_times(), vec![1.0, 3.0]);
        assert_eq!(c.mean_normalized_round(), 2.0);
        assert_eq!(c.all_client_times_normalized(), vec![0.5, 1.0, 3.0]);
    }
}
