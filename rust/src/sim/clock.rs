//! Simulated clock: accumulates per-round simulated time and exposes the
//! paper's normalized-time view (round deadline τ = 1.0).
//!
//! Synchronous FL semantics: a round ends when the *slowest participating
//! client* finishes (or when every deadline-aware client has stopped at τ),
//! so the round length is the max over per-client times. FedAvg ignores τ
//! and its rounds stretch to the straggler tail (paper Fig. 4's 11× tail).

/// Per-round simulated timing record.
#[derive(Clone, Debug, Default)]
pub struct RoundTiming {
    /// Per-participating-client simulated times (seconds).
    pub client_times: Vec<f64>,
    /// Round length = max(client_times) (0.0 for an empty round).
    pub round_time: f64,
}

impl RoundTiming {
    /// Build a record whose round length is the max client time.
    pub fn from_clients(client_times: Vec<f64>) -> RoundTiming {
        let round_time = client_times.iter().copied().fold(0.0f64, f64::max);
        RoundTiming { client_times, round_time }
    }
}

/// Accumulates rounds; all queries are O(1)/O(n) over stored records.
#[derive(Clone, Debug)]
pub struct SimClock {
    /// τ used to normalize (1.0 ⇒ no normalization).
    pub deadline: f64,
    rounds: Vec<RoundTiming>,
    elapsed: f64,
}

impl SimClock {
    /// A fresh clock normalizing by `deadline` (must be positive).
    pub fn new(deadline: f64) -> SimClock {
        assert!(deadline > 0.0);
        SimClock { deadline, rounds: Vec::new(), elapsed: 0.0 }
    }

    /// Record one round; returns its simulated length.
    pub fn push_round(&mut self, timing: RoundTiming) -> f64 {
        let t = timing.round_time;
        self.elapsed += t;
        self.rounds.push(timing);
        t
    }

    /// Total simulated seconds so far.
    pub fn elapsed(&self) -> f64 {
        self.elapsed
    }

    /// The current simulated instant (alias of [`SimClock::elapsed`]).
    /// This is the time at which a new round begins — availability traces
    /// ([`crate::scenario::AvailabilityTrace`]) are read at this instant
    /// to decide which clients are eligible for selection.
    pub fn now(&self) -> f64 {
        self.elapsed
    }

    /// Cumulative simulated time after each round (for Fig. 5's x-axis).
    pub fn cumulative(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.rounds
            .iter()
            .map(|r| {
                acc += r.round_time;
                acc
            })
            .collect()
    }

    /// Rounds recorded so far.
    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Round lengths normalized by τ (paper Table 2: "normalized time of 1
    /// is round deadline").
    pub fn normalized_round_times(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.round_time / self.deadline).collect()
    }

    /// Mean normalized round length — the Table 2 time metric.
    pub fn mean_normalized_round(&self) -> f64 {
        let ts = self.normalized_round_times();
        crate::util::stats::mean(&ts)
    }

    /// Every participating client's normalized time across all rounds
    /// (Fig. 4 / Fig. 7 histograms are over *client* round times).
    pub fn all_client_times_normalized(&self) -> Vec<f64> {
        self.rounds
            .iter()
            .flat_map(|r| r.client_times.iter().map(|t| t / self.deadline))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_time_is_max_of_clients() {
        let t = RoundTiming::from_clients(vec![1.0, 3.0, 2.0]);
        assert_eq!(t.round_time, 3.0);
    }

    #[test]
    fn empty_round_is_zero() {
        let t = RoundTiming::from_clients(vec![]);
        assert_eq!(t.round_time, 0.0);
    }

    #[test]
    fn cumulative_and_elapsed_agree() {
        let mut c = SimClock::new(2.0);
        c.push_round(RoundTiming::from_clients(vec![2.0]));
        c.push_round(RoundTiming::from_clients(vec![4.0, 1.0]));
        assert_eq!(c.elapsed(), 6.0);
        assert_eq!(c.cumulative(), vec![2.0, 6.0]);
        assert_eq!(c.num_rounds(), 2);
    }

    #[test]
    fn normalization_by_deadline() {
        let mut c = SimClock::new(2.0);
        c.push_round(RoundTiming::from_clients(vec![1.0, 2.0]));
        c.push_round(RoundTiming::from_clients(vec![6.0]));
        assert_eq!(c.normalized_round_times(), vec![1.0, 3.0]);
        assert_eq!(c.mean_normalized_round(), 2.0);
        assert_eq!(c.all_client_times_normalized(), vec![0.5, 1.0, 3.0]);
    }
}
