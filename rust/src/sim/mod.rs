//! Hardware/straggler simulation — paper section 6.1 "Implementations".
//!
//! Each client i draws a computational capability cᵢ ~ N(1, 0.25)
//! (truncated away from zero); training one sample costs 1/cᵢ seconds of
//! *simulated* time, so a full round of E epochs over mᵢ samples costs
//! E·mᵢ/cᵢ. The slowest s% of clients are designated stragglers by choosing
//! the per-round deadline τ as the (100−s)-th percentile of full-round
//! times — exactly the paper's emulation recipe.
//!
//! The simulated clock is what reproduces the paper's *normalized* time
//! metrics (deadline = 1.0); wall-clock perf of our own stack is measured
//! separately in EXPERIMENTS.md §Perf.
//!
//! # Dense vs lazy fleets
//!
//! A [`Fleet`] stores its per-client state in one of two ways:
//!
//! * **Dense** ([`Fleet::new`]) — explicit profile/size vectors, used by
//!   every data-backed run (the dataset already owns O(fleet) memory).
//! * **Lazy** ([`Fleet::lazy`]) — profiles and sizes are *derived on
//!   demand* from a keyed split of the fleet's base RNG, so a
//!   million-client fleet costs O(1) resident memory. The deadline is
//!   calibrated by a streaming order-statistic search that reproduces
//!   [`calibrate_deadline`]'s percentile **bit-for-bit** without ever
//!   materializing the full-round-time vector ([`Fleet::materialize`]
//!   turns a lazy fleet into its dense twin; the sim unit suite gates
//!   the equivalence).
//!
//! Callers go through the accessors ([`Fleet::profile`], [`Fleet::size`],
//! [`Fleet::num_clients`]) and never see which representation backs them.

pub mod clock;

pub use clock::SimClock;

use crate::util::rng::Rng;
use crate::util::stats;

/// Variance of the capability distribution. The paper writes cᵢ ~ N(1, 0.25);
/// reading 0.25 as the *standard deviation* (σ² = 0.0625) reproduces the
/// Table 2 FedAvg ratios (3–8× τ); σ = 0.5 would make 1/cᵢ diverge far
/// beyond anything the paper reports.
pub const CAPABILITY_VAR: f64 = 0.0625;
/// Capabilities are truncated below: a floor of 0.25 means the slowest
/// hardware is 4× slower than the mean, which combined with the 10× size
/// tail yields FedAvg round ratios in the paper's 3–8× τ regime (an
/// untruncated N(1, 0.25) produces near-zero capabilities whose 1/cᵢ
/// blows the ratios far past anything in Table 2).
pub const MIN_CAPABILITY: f64 = 0.25;
/// Cost of a forward+last-layer-gradient pass relative to a full training
/// visit (§4.4: "almost as cheap as calculating the loss"; backward ≈ 2×
/// forward, so forward-only ≈ 1/3 of a training visit).
pub const FEATURE_PASS_COST: f64 = 1.0 / 3.0;

/// Stream salt for lazily derived capabilities (xor'd with the client
/// index; disjoint from every other salt in the crate).
const LAZY_PROFILE_SALT: u64 = 0x0F11E5;
/// Stream salt for lazily derived dataset sizes.
const LAZY_SIZE_SALT: u64 = 0x517E5;

/// Per-client hardware profile.
#[derive(Clone, Copy, Debug)]
pub struct ClientProfile {
    /// Samples processed per simulated second.
    pub capability: f64,
}

impl ClientProfile {
    /// Simulated seconds to process `samples` training samples once.
    pub fn time_for(&self, samples: usize) -> f64 {
        samples as f64 / self.capability
    }

    /// Max samples processable within `budget` simulated seconds.
    ///
    /// The product is saturated explicitly at the `usize` edges: a NaN
    /// budget yields 0 samples and an over-range product yields
    /// `usize::MAX`, each surfaced once through the rate-limited warn
    /// channel rather than relying on the silent `as` cast semantics.
    pub fn samples_within(&self, budget: f64) -> usize {
        let raw = self.capability * budget;
        if raw.is_nan() {
            crate::obs::warn_stderr(
                "sim_budget_nan",
                &format!("samples_within: capability × budget is NaN (budget {budget}); treating as 0 samples"),
            );
            return 0;
        }
        if raw >= usize::MAX as f64 {
            crate::obs::warn_stderr(
                "sim_budget_saturated",
                &format!("samples_within: capability × budget = {raw:e} exceeds usize::MAX; saturating"),
            );
            return usize::MAX;
        }
        raw.floor().max(0.0) as usize
    }
}

/// Dataset-size law for lazily generated fleets: one independent
/// Pareto(1, α) draw per client, mean-normalized analytically, clamped at
/// `max_mult ×` the target mean and floored at `min` — the same shape as
/// [`crate::data::partition::power_law_sizes`], but with **no fleet-wide
/// normalization pass**, so any client's size is a pure function of the
/// fleet seed and its own index (adding clients never perturbs existing
/// sizes, the independence contract churn generation already follows).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SizeLaw {
    /// Target (pre-clamp) mean dataset size.
    pub mean: f64,
    /// Pareto tail index α (> 1 for a finite mean).
    pub alpha: f64,
    /// Per-client floor.
    pub min: usize,
    /// Clamp sizes at `max_mult × mean`.
    pub max_mult: f64,
}

impl Default for SizeLaw {
    /// The synthetic-benchmark regime (mean ≈ 69 samples, α = 1.4,
    /// floor 8, 8× cap — the `power_law_sizes` defaults used across the
    /// benches).
    fn default() -> SizeLaw {
        SizeLaw { mean: 69.0, alpha: 1.4, min: 8, max_mult: crate::data::partition::MAX_MEAN_MULT }
    }
}

impl SizeLaw {
    /// One client's size from its private stream.
    fn sample(&self, r: &mut Rng) -> usize {
        let raw = r.power_law(1.0, self.alpha);
        // E[Pareto(1, α)] = α/(α−1); dividing it out makes `mean` the
        // expected (pre-clamp) size without a fleet-wide pass.
        let norm = if self.alpha > 1.0 { self.alpha / (self.alpha - 1.0) } else { 1.0 };
        ((raw / norm).min(self.max_mult) * self.mean).round().max(self.min as f64) as usize
    }
}

/// Where the per-client state lives (see the module docs).
#[derive(Clone, Debug)]
enum ClientSource {
    /// Explicit vectors (data-backed runs).
    Dense {
        /// Per-client hardware profiles (cᵢ).
        profiles: Vec<ClientProfile>,
        /// mᵢ — per-client training-set sizes.
        sizes: Vec<usize>,
    },
    /// Seed-derived on demand (scale benches, million-client fleets).
    Lazy {
        /// Base stream; client `i` reads `base.split(SALT ^ i)`.
        base: Rng,
        /// Size distribution.
        law: SizeLaw,
        /// Fleet size.
        clients: usize,
    },
}

/// The simulated fleet: capabilities + dataset sizes + the round deadline.
#[derive(Clone, Debug)]
pub struct Fleet {
    /// Per-client profiles and sizes, dense or derived.
    source: ClientSource,
    /// E — local epochs per round.
    pub epochs: usize,
    /// τ — per-round training deadline (simulated seconds).
    pub deadline: f64,
    /// s — straggler percentage used to derive τ.
    pub straggler_pct: f64,
}

impl Fleet {
    /// Sample capabilities for `sizes.len()` clients and calibrate τ so the
    /// slowest `straggler_pct`% cannot finish E full epochs in time.
    pub fn new(rng: &mut Rng, sizes: Vec<usize>, epochs: usize, straggler_pct: f64) -> Fleet {
        assert!(epochs >= 1);
        assert!((0.0..100.0).contains(&straggler_pct));
        let profiles: Vec<ClientProfile> = (0..sizes.len())
            .map(|_| ClientProfile {
                capability: rng
                    .normal_scaled(1.0, CAPABILITY_VAR.sqrt())
                    .max(MIN_CAPABILITY),
            })
            .collect();
        let deadline = calibrate_deadline(&profiles, &sizes, epochs, straggler_pct);
        Fleet { source: ClientSource::Dense { profiles, sizes }, epochs, deadline, straggler_pct }
    }

    /// A fleet whose per-client state is derived from `base` on demand —
    /// O(1) resident memory regardless of `clients`. The deadline is the
    /// same (100−s)-th percentile of full-round times as [`Fleet::new`],
    /// found by a streaming order-statistic bisection instead of a sort
    /// (bit-identical to [`calibrate_deadline`] over the materialized
    /// vectors).
    pub fn lazy(base: Rng, clients: usize, law: SizeLaw, epochs: usize, straggler_pct: f64) -> Fleet {
        assert!(epochs >= 1);
        assert!((0.0..100.0).contains(&straggler_pct));
        assert!(clients > 0, "lazy fleet needs at least one client");
        let deadline = lazy_deadline(&base, law, clients, epochs, straggler_pct);
        Fleet { source: ClientSource::Lazy { base, law, clients }, epochs, deadline, straggler_pct }
    }

    /// Number of clients in the fleet.
    pub fn num_clients(&self) -> usize {
        match &self.source {
            ClientSource::Dense { sizes, .. } => sizes.len(),
            ClientSource::Lazy { clients, .. } => *clients,
        }
    }

    /// Client `i`'s hardware profile.
    pub fn profile(&self, i: usize) -> ClientProfile {
        match &self.source {
            ClientSource::Dense { profiles, .. } => profiles[i],
            ClientSource::Lazy { base, .. } => lazy_profile(base, i),
        }
    }

    /// Client `i`'s training-set size mᵢ.
    pub fn size(&self, i: usize) -> usize {
        match &self.source {
            ClientSource::Dense { sizes, .. } => sizes[i],
            ClientSource::Lazy { base, law, .. } => lazy_size(base, law, i),
        }
    }

    /// The dense twin of this fleet: identical per-client profiles,
    /// sizes, and deadline, backed by explicit vectors. Identity for
    /// dense fleets; for lazy fleets this is the O(fleet) materialization
    /// the unit suite uses to gate the streaming calibration.
    pub fn materialize(&self) -> Fleet {
        match &self.source {
            ClientSource::Dense { .. } => self.clone(),
            ClientSource::Lazy { .. } => {
                let n = self.num_clients();
                let profiles: Vec<ClientProfile> = (0..n).map(|i| self.profile(i)).collect();
                let sizes: Vec<usize> = (0..n).map(|i| self.size(i)).collect();
                Fleet {
                    source: ClientSource::Dense { profiles, sizes },
                    epochs: self.epochs,
                    deadline: self.deadline,
                    straggler_pct: self.straggler_pct,
                }
            }
        }
    }

    /// Full-round (E-epoch, full-set) simulated time of client `i`.
    pub fn full_round_time(&self, i: usize) -> f64 {
        self.profile(i).time_for(self.epochs * self.size(i))
    }

    /// Is client `i` a straggler (cannot finish the full round by τ)?
    pub fn is_straggler(&self, i: usize) -> bool {
        self.full_round_time(i) > self.deadline
    }

    /// Observed straggler fraction (should track `straggler_pct`).
    pub fn straggler_fraction(&self) -> f64 {
        let n = self.num_clients().max(1);
        (0..self.num_clients()).filter(|&i| self.is_straggler(i)).count() as f64 / n as f64
    }

    /// The paper's coreset budget bᵢ = ⌊(cᵢτ − mᵢ)/(E−1)⌋ (section 4.2):
    /// epoch 1 runs the full set, the remaining E−1 epochs run the coreset.
    /// Returns None when even one full epoch does not fit (cᵢτ < mᵢ —
    /// the §4.4 extreme-straggler regime).
    pub fn coreset_budget(&self, i: usize) -> Option<usize> {
        let cap = self.profile(i).capability * self.deadline;
        let m = self.size(i) as f64;
        if cap < m {
            return None;
        }
        if self.epochs == 1 {
            return Some(self.size(i)); // nothing left to shrink
        }
        Some(((cap - m) / (self.epochs - 1) as f64).floor().max(1.0) as usize)
    }

    /// The fleet's clients that `trace` reports online at simulated time
    /// `t`, ascending. Clients beyond the trace's own client count are
    /// treated as always online (see
    /// [`crate::scenario::AvailabilityTrace`]), so a partial trace
    /// composes with any fleet size.
    ///
    /// This materializes an O(fleet) vector, so the engine's selection
    /// path streams `trace.is_online` per candidate instead
    /// ([`crate::fl::select_available_streamed`]); this form remains for
    /// tests and diagnostics.
    pub fn online_clients(
        &self,
        trace: &crate::scenario::AvailabilityTrace,
        t: f64,
    ) -> Vec<usize> {
        (0..self.num_clients()).filter(|&i| trace.is_online(i, t)).collect()
    }

    /// §4.4 fallback budget when even epoch 1 does not fit: d̂ features come
    /// from a cheap forward-only pass over the full set (cost
    /// [`FEATURE_PASS_COST`]·mᵢ visits), then all E epochs run on the
    /// coreset: bᵢ = ⌊(cᵢτ − mᵢ/3)/E⌋, clamped to ≥ 1 so pathologically
    /// slow clients still contribute *something* (like FedProx's minimum
    /// partial work). A client so slow that even the feature pass alone
    /// exceeds τ (cᵢτ < mᵢ/3, i.e. the pre-clamp budget goes negative) is
    /// outside the §4.4 operating regime; the clamp still applies, but the
    /// case is surfaced once through the rate-limited warn channel.
    pub fn fallback_budget(&self, i: usize) -> usize {
        let cap = self.profile(i).capability * self.deadline;
        let feat = FEATURE_PASS_COST * self.size(i) as f64;
        if cap < feat {
            crate::obs::warn_stderr(
                "sim_fallback_floor",
                &format!(
                    "client {i}: feature pass alone exceeds τ (cᵢτ = {cap:.3} < {feat:.3}); clamping §4.4 budget to 1"
                ),
            );
        }
        ((cap - feat) / self.epochs as f64).floor().max(1.0) as usize
    }
}

/// Client `i`'s capability stream, derived from the fleet base.
fn lazy_profile(base: &Rng, i: usize) -> ClientProfile {
    let mut r = base.split(LAZY_PROFILE_SALT ^ i as u64);
    ClientProfile {
        capability: r.normal_scaled(1.0, CAPABILITY_VAR.sqrt()).max(MIN_CAPABILITY),
    }
}

/// Client `i`'s dataset size, derived from the fleet base.
fn lazy_size(base: &Rng, law: &SizeLaw, i: usize) -> usize {
    let mut r = base.split(LAZY_SIZE_SALT ^ i as u64);
    law.sample(&mut r)
}

/// τ = (100−s)-th percentile of full-round times: exactly s% of clients
/// become stragglers.
pub fn calibrate_deadline(
    profiles: &[ClientProfile],
    sizes: &[usize],
    epochs: usize,
    straggler_pct: f64,
) -> f64 {
    let times: Vec<f64> = profiles
        .iter()
        .zip(sizes)
        .map(|(p, &m)| p.time_for(epochs * m))
        .collect();
    stats::percentile(&times, 100.0 - straggler_pct)
}

/// The lazy fleet's τ: [`stats::percentile`]'s linear interpolation
/// reproduced from streamed order statistics — `rank = q/100·(n−1)`,
/// `s[⌊rank⌋]·(1−frac) + s[⌈rank⌉]·frac` — where each order statistic
/// comes from [`kth_smallest`] instead of a sorted O(fleet) vector.
fn lazy_deadline(base: &Rng, law: SizeLaw, n: usize, epochs: usize, straggler_pct: f64) -> f64 {
    let time_of = |i: usize| {
        let p = lazy_profile(base, i);
        p.time_for(epochs * lazy_size(base, &law, i))
    };
    let q = 100.0 - straggler_pct;
    let rank = (q / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let v_lo = kth_smallest(n, lo, &time_of);
    if lo == hi {
        return v_lo;
    }
    let v_hi = next_order_stat(n, lo, v_lo, &time_of);
    let frac = rank - lo as f64;
    v_lo * (1.0 - frac) + v_hi * frac
}

/// Exact `r`-th (0-indexed) smallest of `{time_of(0), …, time_of(n−1)}`
/// in O(1) memory: bisection over the monotone `f64 → u64` bit encoding
/// (valid because full-round times are non-negative), counting values at
/// or below the probe each step. ~64 streaming passes worst case.
fn kth_smallest(n: usize, r: usize, time_of: &impl Fn(usize) -> f64) -> f64 {
    debug_assert!(r < n);
    let mut lo_k = u64::MAX;
    let mut hi_k = 0u64;
    for i in 0..n {
        let t = time_of(i);
        debug_assert!(t >= 0.0, "bit-order bisection needs non-negative times");
        let k = t.to_bits();
        lo_k = lo_k.min(k);
        hi_k = hi_k.max(k);
    }
    while lo_k < hi_k {
        let mid = lo_k + (hi_k - lo_k) / 2;
        let at_or_below = (0..n).filter(|&i| time_of(i).to_bits() <= mid).count();
        if at_or_below >= r + 1 {
            hi_k = mid;
        } else {
            lo_k = mid + 1;
        }
    }
    f64::from_bits(lo_k)
}

/// The `(r+1)`-th order statistic given `v_r` (the `r`-th): `v_r` itself
/// when duplicated past rank `r`, otherwise the smallest value strictly
/// above it. One extra streaming pass.
fn next_order_stat(n: usize, r: usize, v_r: f64, time_of: &impl Fn(usize) -> f64) -> f64 {
    let key = v_r.to_bits();
    let mut at_or_below = 0usize;
    let mut above = f64::INFINITY;
    for i in 0..n {
        let t = time_of(i);
        if t.to_bits() <= key {
            at_or_below += 1;
        } else if t < above {
            above = t;
        }
    }
    if at_or_below >= r + 2 {
        v_r
    } else {
        above
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize, s: f64) -> Fleet {
        let mut rng = Rng::new(11);
        let sizes: Vec<usize> = (0..n).map(|i| 20 + (i * 7) % 200).collect();
        Fleet::new(&mut rng, sizes, 10, s)
    }

    #[test]
    fn capability_moments() {
        let f = fleet(4000, 10.0);
        let caps: Vec<f64> = (0..f.num_clients()).map(|i| f.profile(i).capability).collect();
        let mean = stats::mean(&caps);
        // Truncation at MIN_CAPABILITY pulls the mean slightly above 1.
        assert!((mean - 1.0).abs() < 0.1, "mean {mean}");
        assert!(caps.iter().all(|&c| c >= MIN_CAPABILITY));
    }

    #[test]
    fn straggler_fraction_tracks_setting() {
        for s in [10.0, 30.0] {
            let f = fleet(1000, s);
            let frac = f.straggler_fraction();
            assert!(
                (frac - s / 100.0).abs() < 0.02,
                "s={s}: fraction {frac}"
            );
        }
    }

    #[test]
    fn non_stragglers_fit_full_round() {
        let f = fleet(300, 30.0);
        for i in 0..300 {
            if !f.is_straggler(i) {
                assert!(f.full_round_time(i) <= f.deadline + 1e-9);
            }
        }
    }

    #[test]
    fn coreset_budget_fits_deadline() {
        let f = fleet(300, 30.0);
        for i in 0..300 {
            if let Some(b) = f.coreset_budget(i) {
                // epoch1 full + (E-1) coreset epochs must fit τ (up to the
                // floor's one-sample slack per epoch).
                let work = f.size(i) + (f.epochs - 1) * b;
                let t = f.profile(i).time_for(work);
                assert!(
                    t <= f.deadline + f.profile(i).time_for(1) * (f.epochs - 1) as f64,
                    "client {i}: {t} vs τ {}",
                    f.deadline
                );
                if f.is_straggler(i) {
                    assert!(b < f.size(i), "straggler budget {b} >= m {}", f.size(i));
                }
            }
        }
    }

    #[test]
    fn fallback_budget_fits_deadline() {
        let f = fleet(300, 30.0);
        for i in 0..300 {
            let b = f.fallback_budget(i);
            let t = f.profile(i).time_for(f.epochs * b);
            // ≤ τ up to one sample of flooring slack per epoch.
            assert!(t <= f.deadline + f.profile(i).time_for(f.epochs), "client {i}");
        }
    }

    #[test]
    fn online_clients_respects_trace() {
        use crate::scenario::{AvailabilityTrace, EdgePolicy};
        let f = fleet(4, 10.0);
        let trace = AvailabilityTrace::from_intervals(
            vec![vec![], vec![(0.0, 5.0)]],
            10.0,
            EdgePolicy::Wrap,
        )
        .unwrap();
        // Client 0 is never online, client 1 only in [0, 5); clients 2 and
        // 3 are beyond the trace and therefore always eligible.
        assert_eq!(f.online_clients(&trace, 1.0), vec![1, 2, 3]);
        assert_eq!(f.online_clients(&trace, 6.0), vec![2, 3]);
    }

    #[test]
    fn profile_sample_budget_roundtrip() {
        let p = ClientProfile { capability: 2.0 };
        assert_eq!(p.time_for(10), 5.0);
        assert_eq!(p.samples_within(5.0), 10);
    }

    #[test]
    fn deadline_percentile_semantics() {
        let profiles = vec![ClientProfile { capability: 1.0 }; 10];
        let sizes: Vec<usize> = (1..=10).collect();
        // full-round times = 10, 20, ..., 100 at E = 10
        let tau = calibrate_deadline(&profiles, &sizes, 10, 10.0);
        let over = sizes
            .iter()
            .filter(|&&m| (10 * m) as f64 > tau)
            .count();
        assert_eq!(over, 1, "tau {tau}");
    }

    // ---------- numeric edges (satellite audit) ----------

    #[test]
    fn samples_within_saturates_explicitly() {
        let p = ClientProfile { capability: 2.0 };
        assert_eq!(p.samples_within(f64::NAN), 0, "NaN budget yields no samples");
        assert_eq!(p.samples_within(f64::INFINITY), usize::MAX, "infinite budget saturates");
        assert_eq!(p.samples_within(1e300), usize::MAX, "over-range product saturates");
        assert_eq!(p.samples_within(-5.0), 0, "negative budget clamps to 0");
        // The ordinary path is untouched by the guards.
        assert_eq!(p.samples_within(5.25), 10);
    }

    #[test]
    fn fallback_budget_floor_is_explicit() {
        // A client so slow that cᵢτ < mᵢ/3: the pre-clamp budget is
        // negative and the clamp must hold it at 1 (the §4.4 minimum
        // contribution), not wrap or drop to 0.
        let mut rng = Rng::new(5);
        let mut f = Fleet::new(&mut rng, vec![100_000, 50], 4, 30.0);
        f.deadline = 1.0; // force cᵢτ ≪ mᵢ/3 for client 0
        assert_eq!(f.fallback_budget(0), 1);
        // And a comfortable client keeps its analytic budget.
        let roomy = Fleet::new(&mut Rng::new(5), vec![10, 10], 1, 10.0);
        assert!(roomy.fallback_budget(0) >= 1);
    }

    // ---------- lazy fleets ----------

    #[test]
    fn lazy_fleet_matches_materialized_twin() {
        let base = Rng::new(42).split(0xF1EE7);
        let law = SizeLaw::default();
        let lazy = Fleet::lazy(base.clone(), 600, law, 6, 30.0);
        let dense = lazy.materialize();
        assert_eq!(
            lazy.deadline.to_bits(),
            dense.deadline.to_bits(),
            "materialization must not move τ"
        );
        // The dense twin recalibrated from scratch lands on the same τ:
        // the streaming bisection is bit-identical to sort+percentile.
        let profiles: Vec<ClientProfile> = (0..600).map(|i| lazy.profile(i)).collect();
        let sizes: Vec<usize> = (0..600).map(|i| lazy.size(i)).collect();
        let tau = calibrate_deadline(&profiles, &sizes, 6, 30.0);
        assert_eq!(tau.to_bits(), lazy.deadline.to_bits(), "streamed τ diverged from sorted τ");
        for i in (0..600).step_by(37) {
            assert_eq!(lazy.size(i), dense.size(i));
            assert_eq!(
                lazy.profile(i).capability.to_bits(),
                dense.profile(i).capability.to_bits()
            );
        }
    }

    #[test]
    fn lazy_clients_independent_of_fleet_size() {
        let base = Rng::new(9).split(0xF1EE7);
        let law = SizeLaw::default();
        let small = Fleet::lazy(base.clone(), 50, law, 4, 30.0);
        let big = Fleet::lazy(base, 5_000, law, 4, 30.0);
        for i in 0..50 {
            assert_eq!(small.size(i), big.size(i), "client {i} size moved with fleet growth");
            assert_eq!(
                small.profile(i).capability.to_bits(),
                big.profile(i).capability.to_bits(),
                "client {i} capability moved with fleet growth"
            );
        }
    }

    #[test]
    fn lazy_straggler_fraction_tracks_setting() {
        for s in [10.0, 30.0] {
            let f = Fleet::lazy(Rng::new(3), 2_000, SizeLaw::default(), 6, s);
            let frac = f.straggler_fraction();
            assert!((frac - s / 100.0).abs() < 0.03, "s={s}: fraction {frac}");
        }
    }

    #[test]
    fn size_law_respects_floor_and_cap() {
        let law = SizeLaw { mean: 100.0, alpha: 1.2, min: 10, max_mult: 4.0 };
        let base = Rng::new(77);
        for i in 0..2_000 {
            let s = lazy_size(&base, &law, i);
            assert!(s >= 10, "client {i}: {s} under floor");
            assert!(s as f64 <= 4.0 * 100.0 + 0.5, "client {i}: {s} over cap");
        }
    }

    #[test]
    fn kth_smallest_matches_sort() {
        let mut rng = Rng::new(31);
        for _ in 0..20 {
            let n = 1 + rng.below(40);
            let vals: Vec<f64> = (0..n).map(|_| rng.f64() * 50.0).collect();
            let mut sorted = vals.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let time_of = |i: usize| vals[i];
            for r in 0..n {
                assert_eq!(
                    kth_smallest(n, r, &time_of).to_bits(),
                    sorted[r].to_bits(),
                    "rank {r} of {n}"
                );
            }
            for r in 0..n - 1 {
                let v = kth_smallest(n, r, &time_of);
                assert_eq!(
                    next_order_stat(n, r, v, &time_of).to_bits(),
                    sorted[r + 1].to_bits(),
                    "next after rank {r}"
                );
            }
        }
    }
}
