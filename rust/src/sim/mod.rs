//! Hardware/straggler simulation — paper section 6.1 "Implementations".
//!
//! Each client i draws a computational capability cᵢ ~ N(1, 0.25)
//! (truncated away from zero); training one sample costs 1/cᵢ seconds of
//! *simulated* time, so a full round of E epochs over mᵢ samples costs
//! E·mᵢ/cᵢ. The slowest s% of clients are designated stragglers by choosing
//! the per-round deadline τ as the (100−s)-th percentile of full-round
//! times — exactly the paper's emulation recipe.
//!
//! The simulated clock is what reproduces the paper's *normalized* time
//! metrics (deadline = 1.0); wall-clock perf of our own stack is measured
//! separately in EXPERIMENTS.md §Perf.

pub mod clock;

pub use clock::SimClock;

use crate::util::rng::Rng;
use crate::util::stats;

/// Variance of the capability distribution. The paper writes cᵢ ~ N(1, 0.25);
/// reading 0.25 as the *standard deviation* (σ² = 0.0625) reproduces the
/// Table 2 FedAvg ratios (3–8× τ); σ = 0.5 would make 1/cᵢ diverge far
/// beyond anything the paper reports.
pub const CAPABILITY_VAR: f64 = 0.0625;
/// Capabilities are truncated below: a floor of 0.25 means the slowest
/// hardware is 4× slower than the mean, which combined with the 10× size
/// tail yields FedAvg round ratios in the paper's 3–8× τ regime (an
/// untruncated N(1, 0.25) produces near-zero capabilities whose 1/cᵢ
/// blows the ratios far past anything in Table 2).
pub const MIN_CAPABILITY: f64 = 0.25;
/// Cost of a forward+last-layer-gradient pass relative to a full training
/// visit (§4.4: "almost as cheap as calculating the loss"; backward ≈ 2×
/// forward, so forward-only ≈ 1/3 of a training visit).
pub const FEATURE_PASS_COST: f64 = 1.0 / 3.0;

/// Per-client hardware profile.
#[derive(Clone, Copy, Debug)]
pub struct ClientProfile {
    /// Samples processed per simulated second.
    pub capability: f64,
}

impl ClientProfile {
    /// Simulated seconds to process `samples` training samples once.
    pub fn time_for(&self, samples: usize) -> f64 {
        samples as f64 / self.capability
    }

    /// Max samples processable within `budget` simulated seconds.
    pub fn samples_within(&self, budget: f64) -> usize {
        (self.capability * budget).floor().max(0.0) as usize
    }
}

/// The simulated fleet: capabilities + dataset sizes + the round deadline.
#[derive(Clone, Debug)]
pub struct Fleet {
    /// Per-client hardware profiles (cᵢ).
    pub profiles: Vec<ClientProfile>,
    /// mᵢ — per-client training-set sizes.
    pub sizes: Vec<usize>,
    /// E — local epochs per round.
    pub epochs: usize,
    /// τ — per-round training deadline (simulated seconds).
    pub deadline: f64,
    /// s — straggler percentage used to derive τ.
    pub straggler_pct: f64,
}

impl Fleet {
    /// Sample capabilities for `sizes.len()` clients and calibrate τ so the
    /// slowest `straggler_pct`% cannot finish E full epochs in time.
    pub fn new(rng: &mut Rng, sizes: Vec<usize>, epochs: usize, straggler_pct: f64) -> Fleet {
        assert!(epochs >= 1);
        assert!((0.0..100.0).contains(&straggler_pct));
        let profiles: Vec<ClientProfile> = (0..sizes.len())
            .map(|_| ClientProfile {
                capability: rng
                    .normal_scaled(1.0, CAPABILITY_VAR.sqrt())
                    .max(MIN_CAPABILITY),
            })
            .collect();
        let deadline = calibrate_deadline(&profiles, &sizes, epochs, straggler_pct);
        Fleet { profiles, sizes, epochs, deadline, straggler_pct }
    }

    /// Full-round (E-epoch, full-set) simulated time of client `i`.
    pub fn full_round_time(&self, i: usize) -> f64 {
        self.profiles[i].time_for(self.epochs * self.sizes[i])
    }

    /// Is client `i` a straggler (cannot finish the full round by τ)?
    pub fn is_straggler(&self, i: usize) -> bool {
        self.full_round_time(i) > self.deadline
    }

    /// Observed straggler fraction (should track `straggler_pct`).
    pub fn straggler_fraction(&self) -> f64 {
        let n = self.sizes.len().max(1);
        (0..self.sizes.len()).filter(|&i| self.is_straggler(i)).count() as f64 / n as f64
    }

    /// The paper's coreset budget bᵢ = ⌊(cᵢτ − mᵢ)/(E−1)⌋ (section 4.2):
    /// epoch 1 runs the full set, the remaining E−1 epochs run the coreset.
    /// Returns None when even one full epoch does not fit (cᵢτ < mᵢ —
    /// the §4.4 extreme-straggler regime).
    pub fn coreset_budget(&self, i: usize) -> Option<usize> {
        let cap = self.profiles[i].capability * self.deadline;
        let m = self.sizes[i] as f64;
        if cap < m {
            return None;
        }
        if self.epochs == 1 {
            return Some(self.sizes[i]); // nothing left to shrink
        }
        Some(((cap - m) / (self.epochs - 1) as f64).floor().max(1.0) as usize)
    }

    /// The fleet's clients that `trace` reports online at simulated time
    /// `t`, ascending. Clients beyond the trace's own client count are
    /// treated as always online (see
    /// [`crate::scenario::AvailabilityTrace`]), so a partial trace
    /// composes with any fleet size.
    pub fn online_clients(
        &self,
        trace: &crate::scenario::AvailabilityTrace,
        t: f64,
    ) -> Vec<usize> {
        (0..self.sizes.len()).filter(|&i| trace.is_online(i, t)).collect()
    }

    /// §4.4 fallback budget when even epoch 1 does not fit: d̂ features come
    /// from a cheap forward-only pass over the full set (cost
    /// [`FEATURE_PASS_COST`]·mᵢ visits), then all E epochs run on the
    /// coreset: bᵢ = ⌊(cᵢτ − mᵢ/3)/E⌋, clamped to ≥ 1 so pathologically
    /// slow clients still contribute *something* (like FedProx's minimum
    /// partial work).
    pub fn fallback_budget(&self, i: usize) -> usize {
        let cap = self.profiles[i].capability * self.deadline;
        let feat = FEATURE_PASS_COST * self.sizes[i] as f64;
        ((cap - feat) / self.epochs as f64).floor().max(1.0) as usize
    }
}

/// τ = (100−s)-th percentile of full-round times: exactly s% of clients
/// become stragglers.
pub fn calibrate_deadline(
    profiles: &[ClientProfile],
    sizes: &[usize],
    epochs: usize,
    straggler_pct: f64,
) -> f64 {
    let times: Vec<f64> = profiles
        .iter()
        .zip(sizes)
        .map(|(p, &m)| p.time_for(epochs * m))
        .collect();
    stats::percentile(&times, 100.0 - straggler_pct)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize, s: f64) -> Fleet {
        let mut rng = Rng::new(11);
        let sizes: Vec<usize> = (0..n).map(|i| 20 + (i * 7) % 200).collect();
        Fleet::new(&mut rng, sizes, 10, s)
    }

    #[test]
    fn capability_moments() {
        let f = fleet(4000, 10.0);
        let caps: Vec<f64> = f.profiles.iter().map(|p| p.capability).collect();
        let mean = stats::mean(&caps);
        // Truncation at MIN_CAPABILITY pulls the mean slightly above 1.
        assert!((mean - 1.0).abs() < 0.1, "mean {mean}");
        assert!(caps.iter().all(|&c| c >= MIN_CAPABILITY));
    }

    #[test]
    fn straggler_fraction_tracks_setting() {
        for s in [10.0, 30.0] {
            let f = fleet(1000, s);
            let frac = f.straggler_fraction();
            assert!(
                (frac - s / 100.0).abs() < 0.02,
                "s={s}: fraction {frac}"
            );
        }
    }

    #[test]
    fn non_stragglers_fit_full_round() {
        let f = fleet(300, 30.0);
        for i in 0..300 {
            if !f.is_straggler(i) {
                assert!(f.full_round_time(i) <= f.deadline + 1e-9);
            }
        }
    }

    #[test]
    fn coreset_budget_fits_deadline() {
        let f = fleet(300, 30.0);
        for i in 0..300 {
            if let Some(b) = f.coreset_budget(i) {
                // epoch1 full + (E-1) coreset epochs must fit τ (up to the
                // floor's one-sample slack per epoch).
                let work = f.sizes[i] + (f.epochs - 1) * b;
                let t = f.profiles[i].time_for(work);
                assert!(
                    t <= f.deadline + f.profiles[i].time_for(1) * (f.epochs - 1) as f64,
                    "client {i}: {t} vs τ {}",
                    f.deadline
                );
                if f.is_straggler(i) {
                    assert!(b < f.sizes[i], "straggler budget {b} >= m {}", f.sizes[i]);
                }
            }
        }
    }

    #[test]
    fn fallback_budget_fits_deadline() {
        let f = fleet(300, 30.0);
        for i in 0..300 {
            let b = f.fallback_budget(i);
            let t = f.profiles[i].time_for(f.epochs * b);
            // ≤ τ up to one sample of flooring slack per epoch.
            assert!(t <= f.deadline + f.profiles[i].time_for(f.epochs), "client {i}");
        }
    }

    #[test]
    fn online_clients_respects_trace() {
        use crate::scenario::{AvailabilityTrace, EdgePolicy};
        let f = fleet(4, 10.0);
        let trace = AvailabilityTrace::from_intervals(
            vec![vec![], vec![(0.0, 5.0)]],
            10.0,
            EdgePolicy::Wrap,
        )
        .unwrap();
        // Client 0 is never online, client 1 only in [0, 5); clients 2 and
        // 3 are beyond the trace and therefore always eligible.
        assert_eq!(f.online_clients(&trace, 1.0), vec![1, 2, 3]);
        assert_eq!(f.online_clients(&trace, 6.0), vec![2, 3]);
    }

    #[test]
    fn profile_sample_budget_roundtrip() {
        let p = ClientProfile { capability: 2.0 };
        assert_eq!(p.time_for(10), 5.0);
        assert_eq!(p.samples_within(5.0), 10);
    }

    #[test]
    fn deadline_percentile_semantics() {
        let profiles = vec![ClientProfile { capability: 1.0 }; 10];
        let sizes: Vec<usize> = (1..=10).collect();
        // full-round times = 10, 20, ..., 100 at E = 10
        let tau = calibrate_deadline(&profiles, &sizes, 10, 10.0);
        let over = sizes
            .iter()
            .filter(|&&m| (10 * m) as f64 > tau)
            .count();
        assert_eq!(over, 1, "tau {tau}");
    }
}
