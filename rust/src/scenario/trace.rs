//! Per-client availability traces: who is online at a given simulated time.
//!
//! An [`AvailabilityTrace`] answers, for every client, "is it online at
//! time `t` and for how much longer" over a finite timeline
//! `[0, horizon)`. Time past the horizon is handled by an [`EdgePolicy`]:
//! either the trace repeats cyclically (diurnal patterns) or the state at
//! the end of the trace persists (steady-state tails).
//!
//! Two representations back the same query API:
//!
//! * **Dense** ([`AvailabilityTrace::from_intervals`]) — explicit sorted
//!   interval lists per client, what explicit trace files produce.
//! * **Generated** ([`AvailabilityTrace::generated`]) — a
//!   [`ChurnModel`] plus its seed; a client's schedule is re-derived on
//!   demand from its private RNG split, so a million-client churn trace
//!   costs O(1) resident memory instead of an O(fleet) interval table.
//!   Queries are bit-identical to the dense trace the same model/seed
//!   would generate ([`AvailabilityTrace::densified`] materializes the
//!   dense twin; the unit suite gates the equivalence).
//!
//! Clients beyond the trace's own client count are treated as always
//! online — an explicit trace that lists only the flaky clients composes
//! with any fleet size, and the empty trace degenerates to the classic
//! always-available FL setting.

use anyhow::{anyhow, Result};

use super::churn::ChurnModel;
use crate::util::rng::Rng;

/// What the trace reports for times at or past its horizon.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgePolicy {
    /// The trace repeats cyclically: time `t` is read at `t mod horizon`.
    Wrap,
    /// The state just before the horizon persists forever (a client online
    /// at the end of the trace stays online; one offline stays offline).
    Clamp,
}

impl EdgePolicy {
    /// Parse `"wrap"` / `"clamp"` (case-insensitive).
    pub fn parse(s: &str) -> Option<EdgePolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "wrap" => Some(EdgePolicy::Wrap),
            "clamp" => Some(EdgePolicy::Clamp),
            _ => None,
        }
    }

    /// Canonical lowercase name (`"wrap"` / `"clamp"`).
    pub fn label(&self) -> &'static str {
        match self {
            EdgePolicy::Wrap => "wrap",
            EdgePolicy::Clamp => "clamp",
        }
    }
}

/// Where a trace's per-client schedules live (see the module docs).
#[derive(Clone, Debug, PartialEq)]
enum Schedules {
    /// `0[i]` = sorted, disjoint online intervals of client `i`.
    Dense(Vec<Vec<(f64, f64)>>),
    /// Schedules re-derived per query from the model and its seed.
    Generated {
        /// The churn regime.
        model: ChurnModel,
        /// Root stream; client `i` reads `base.split(i)`.
        base: Rng,
        /// Number of clients the trace describes.
        clients: usize,
        /// Horizon in the model's native unit (pre-scaling).
        unit_horizon: f64,
        /// Accumulated time scale applied to generated intervals.
        scale: f64,
    },
}

/// One client's schedule as a query borrows it: dense traces lend their
/// stored slice, generated traces hand over a freshly derived list, and
/// clients beyond either representation are always online.
enum Sched<'a> {
    Borrowed(&'a [(f64, f64)]),
    Owned(Vec<(f64, f64)>),
    AlwaysOn,
}

impl Sched<'_> {
    /// The interval list, or `None` for the always-online case.
    fn as_slice(&self) -> Option<&[(f64, f64)]> {
        match self {
            Sched::Borrowed(s) => Some(s),
            Sched::Owned(v) => Some(v.as_slice()),
            Sched::AlwaysOn => None,
        }
    }
}

/// Per-client online/offline schedule over simulated time.
///
/// Interval lists are normalized (sorted, merged, clamped to
/// `[0, horizon]`) — at construction for dense traces, per query for
/// generated ones — so every query is a binary search over disjoint
/// intervals.
///
/// ```
/// use fedcore::scenario::{AvailabilityTrace, EdgePolicy};
///
/// // Client 0 is online for the first 6 time-units of every 10; client 1
/// // never appears in the trace, so it counts as always online.
/// let trace = AvailabilityTrace::from_intervals(
///     vec![vec![(0.0, 6.0)]],
///     10.0,
///     EdgePolicy::Wrap,
/// )
/// .unwrap();
/// assert!(trace.is_online(0, 3.0));
/// assert!(!trace.is_online(0, 7.0));
/// assert!(trace.is_online(0, 13.0)); // wraps: 13 ≡ 3 (mod 10)
/// assert!(trace.is_online(1, 7.0)); // beyond the trace ⇒ always on
/// assert_eq!(trace.remaining_online(0, 4.0), 2.0);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct AvailabilityTrace {
    /// Dense interval table or generated-on-demand schedules.
    schedules: Schedules,
    /// Trace length in simulated seconds.
    horizon: f64,
    /// Behaviour for `t >= horizon`.
    policy: EdgePolicy,
}

impl AvailabilityTrace {
    /// Build a dense trace from raw per-client interval lists. Intervals
    /// are clamped to `[0, horizon]`, sorted, and merged; empty (or fully
    /// out-of-range) intervals are dropped. Errors when `horizon <= 0` or
    /// an interval has `start > end`.
    pub fn from_intervals(
        clients: Vec<Vec<(f64, f64)>>,
        horizon: f64,
        policy: EdgePolicy,
    ) -> Result<AvailabilityTrace> {
        if !(horizon > 0.0) {
            return Err(anyhow!("trace horizon must be positive, got {horizon}"));
        }
        let mut normalized = Vec::with_capacity(clients.len());
        for (c, raw) in clients.into_iter().enumerate() {
            for &(s, e) in &raw {
                if !s.is_finite() || !e.is_finite() || s > e {
                    return Err(anyhow!("client {c}: bad interval [{s}, {e})"));
                }
            }
            normalized.push(normalize_intervals(raw, horizon));
        }
        Ok(AvailabilityTrace { schedules: Schedules::Dense(normalized), horizon, policy })
    }

    /// Build a generated trace: per-client schedules are re-derived on
    /// demand from `model` and `base` (client `i` reads `base.split(i)`),
    /// bit-identical to the dense trace [`ChurnModel::generate`] would
    /// produce from the same inputs — without ever holding the O(fleet)
    /// interval table. Errors on invalid model parameters or horizon.
    pub fn generated(
        model: ChurnModel,
        base: Rng,
        clients: usize,
        horizon: f64,
        policy: EdgePolicy,
    ) -> Result<AvailabilityTrace> {
        if !(horizon > 0.0) {
            return Err(anyhow!("trace horizon must be positive, got {horizon}"));
        }
        model.validate()?;
        Ok(AvailabilityTrace {
            schedules: Schedules::Generated {
                model,
                base,
                clients,
                unit_horizon: horizon,
                scale: 1.0,
            },
            horizon,
            policy,
        })
    }

    /// A trace on which all `n` clients are online at every time.
    pub fn always_on(n: usize) -> AvailabilityTrace {
        AvailabilityTrace {
            schedules: Schedules::Dense(vec![vec![(0.0, 1.0)]; n]),
            horizon: 1.0,
            policy: EdgePolicy::Wrap,
        }
    }

    /// Number of clients the trace describes (callers may query beyond
    /// this; such clients count as always online).
    pub fn num_clients(&self) -> usize {
        match &self.schedules {
            Schedules::Dense(clients) => clients.len(),
            Schedules::Generated { clients, .. } => *clients,
        }
    }

    /// Trace length in simulated seconds.
    pub fn horizon(&self) -> f64 {
        self.horizon
    }

    /// Behaviour for times at or past the horizon.
    pub fn policy(&self) -> EdgePolicy {
        self.policy
    }

    /// Client `i`'s normalized online intervals (sorted, disjoint).
    /// Dense traces copy the stored list; generated traces derive it.
    /// Clients beyond the trace yield an empty list (as before — their
    /// always-online treatment lives in the queries, not the listing).
    pub fn intervals(&self, client: usize) -> Vec<(f64, f64)> {
        match self.schedule(client) {
            Sched::Borrowed(s) => s.to_vec(),
            Sched::Owned(v) => v,
            Sched::AlwaysOn => Vec::new(),
        }
    }

    /// The dense twin of this trace: identical query results, explicit
    /// interval table. Identity for dense traces; the unit suite uses it
    /// to gate generated-vs-dense equivalence.
    pub fn densified(&self) -> AvailabilityTrace {
        match &self.schedules {
            Schedules::Dense(_) => self.clone(),
            Schedules::Generated { .. } => {
                let all: Vec<Vec<(f64, f64)>> =
                    (0..self.num_clients()).map(|c| self.intervals(c)).collect();
                AvailabilityTrace {
                    schedules: Schedules::Dense(all),
                    horizon: self.horizon,
                    policy: self.policy,
                }
            }
        }
    }

    /// Rescale every timestamp (and the horizon) by `scale` — used to
    /// convert deadline-unit traces into simulated seconds. Dense traces
    /// rescale their stored intervals; generated traces accumulate the
    /// factor and apply it per query (the identical per-interval multiply,
    /// so the representations stay bit-equal).
    pub fn scaled(mut self, scale: f64) -> Result<AvailabilityTrace> {
        if !scale.is_finite() || scale <= 0.0 {
            return Err(anyhow!("trace time scale must be positive and finite, got {scale}"));
        }
        match &mut self.schedules {
            Schedules::Dense(clients) => {
                for ivs in clients.iter_mut() {
                    for iv in ivs.iter_mut() {
                        iv.0 *= scale;
                        iv.1 *= scale;
                    }
                }
            }
            Schedules::Generated { scale: s, .. } => *s *= scale,
        }
        self.horizon *= scale;
        Ok(self)
    }

    /// Client `i`'s schedule under whichever representation backs it.
    fn schedule(&self, client: usize) -> Sched<'_> {
        match &self.schedules {
            Schedules::Dense(clients) => match clients.get(client) {
                Some(ivs) => Sched::Borrowed(ivs),
                None => Sched::AlwaysOn,
            },
            Schedules::Generated { model, base, clients, unit_horizon, scale } => {
                if client >= *clients {
                    return Sched::AlwaysOn;
                }
                let mut r = base.split(client as u64);
                let raw = model.client_intervals(&mut r, *unit_horizon);
                let mut ivs = normalize_intervals(raw, *unit_horizon);
                if *scale != 1.0 {
                    for iv in ivs.iter_mut() {
                        iv.0 *= scale;
                        iv.1 *= scale;
                    }
                }
                Sched::Owned(ivs)
            }
        }
    }

    /// Is client `i` online at simulated time `t`?
    pub fn is_online(&self, client: usize, t: f64) -> bool {
        self.remaining_online(client, t) > 0.0
    }

    /// How long client `i` remains online starting from time `t`.
    ///
    /// Returns `0.0` when the client is offline at `t`, and
    /// `f64::INFINITY` when it never goes offline again (always-on
    /// clients, wrap traces whose cycle is fully online, clamp traces
    /// whose final state is online).
    pub fn remaining_online(&self, client: usize, t: f64) -> f64 {
        let sched = self.schedule(client);
        let Some(ivs) = sched.as_slice() else {
            return f64::INFINITY; // beyond the trace ⇒ always online
        };
        remaining_in(ivs, self.horizon, self.policy, t)
    }

    /// Client `i`'s uptime fraction over one trace horizon: total online
    /// time divided by the horizon, in `[0, 1]`. Clients beyond the trace
    /// count as always online (1.0). Time-independent, so
    /// availability-aware selection policies (the flaky-client weight
    /// boost in [`crate::fl::boost_flaky_weights`]) can precompute it
    /// once per run.
    pub fn uptime(&self, client: usize) -> f64 {
        let sched = self.schedule(client);
        let Some(ivs) = sched.as_slice() else {
            return 1.0;
        };
        let on: f64 = ivs.iter().map(|&(s, e)| e - s).sum();
        (on / self.horizon).clamp(0.0, 1.0)
    }

    /// Indices of all trace clients online at time `t`, ascending.
    pub fn online_at(&self, t: f64) -> Vec<usize> {
        (0..self.num_clients()).filter(|&c| self.is_online(c, t)).collect()
    }

    /// Fraction of the trace's clients online at time `t` (1.0 for an
    /// empty trace — no client is ever marked offline).
    pub fn online_fraction(&self, t: f64) -> f64 {
        if self.num_clients() == 0 {
            return 1.0;
        }
        self.online_at(t).len() as f64 / self.num_clients() as f64
    }
}

/// Clamp to `[0, horizon]`, drop empties, sort, and merge — the shared
/// normalization both representations run, in the same order, so a
/// generated schedule is bit-identical to its densely stored twin.
/// Assumes interval validity (finite, `start <= end`) was checked by the
/// caller where the input is untrusted.
fn normalize_intervals(raw: Vec<(f64, f64)>, horizon: f64) -> Vec<(f64, f64)> {
    let mut ivs: Vec<(f64, f64)> = Vec::with_capacity(raw.len());
    for (s, e) in raw {
        let (s, e) = (s.max(0.0), e.min(horizon));
        if s < e {
            ivs.push((s, e));
        }
    }
    ivs.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite interval starts"));
    // Merge touching/overlapping intervals so queries see disjoint,
    // maximal online stretches.
    let mut merged: Vec<(f64, f64)> = Vec::with_capacity(ivs.len());
    for (s, e) in ivs {
        match merged.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => merged.push((s, e)),
        }
    }
    merged
}

/// The remaining-online query over one normalized schedule.
fn remaining_in(ivs: &[(f64, f64)], horizon: f64, policy: EdgePolicy, t: f64) -> f64 {
    if ivs.is_empty() {
        return 0.0; // never online
    }
    // Fully-online cycle: no boundary to ever cross.
    if ivs.len() == 1 && ivs[0].0 <= 0.0 && ivs[0].1 >= horizon {
        return f64::INFINITY;
    }
    match policy {
        EdgePolicy::Wrap => {
            let tw = t.rem_euclid(horizon);
            let Some(&(_, end)) = containing(ivs, tw) else { return 0.0 };
            let mut rem = end - tw;
            // The online stretch continues across the cycle boundary
            // when it touches the horizon and the first interval starts
            // at 0 (full coverage was excluded above, so this is finite).
            if end >= horizon && ivs[0].0 <= 0.0 {
                rem += ivs[0].1;
            }
            rem
        }
        EdgePolicy::Clamp => {
            let final_online = ivs.last().map(|&(_, e)| e >= horizon).unwrap_or(false);
            if t >= horizon {
                return if final_online { f64::INFINITY } else { 0.0 };
            }
            let Some(&(_, end)) = containing(ivs, t) else { return 0.0 };
            if end >= horizon {
                f64::INFINITY // clamp: the final online state persists
            } else {
                end - t
            }
        }
    }
}

/// The interval containing `t` (half-open `[start, end)`), if any.
fn containing(ivs: &[(f64, f64)], t: f64) -> Option<&(f64, f64)> {
    // partition_point: first interval with start > t; the candidate is the
    // one before it.
    let idx = ivs.partition_point(|&(s, _)| s <= t);
    if idx == 0 {
        return None;
    }
    let iv = &ivs[idx - 1];
    (t < iv.1).then_some(iv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(ivs: Vec<Vec<(f64, f64)>>, horizon: f64, policy: EdgePolicy) -> AvailabilityTrace {
        AvailabilityTrace::from_intervals(ivs, horizon, policy).unwrap()
    }

    #[test]
    fn normalization_sorts_merges_clamps() {
        let t = trace(
            vec![vec![(8.0, 12.0), (-1.0, 2.0), (1.5, 4.0)]],
            10.0,
            EdgePolicy::Wrap,
        );
        assert_eq!(t.intervals(0), &[(0.0, 4.0), (8.0, 10.0)]);
    }

    #[test]
    fn bad_inputs_are_errors() {
        assert!(AvailabilityTrace::from_intervals(vec![], 0.0, EdgePolicy::Wrap).is_err());
        assert!(AvailabilityTrace::from_intervals(vec![], -1.0, EdgePolicy::Wrap).is_err());
        assert!(
            AvailabilityTrace::from_intervals(vec![vec![(5.0, 1.0)]], 10.0, EdgePolicy::Wrap)
                .is_err()
        );
        assert!(AvailabilityTrace::from_intervals(
            vec![vec![(f64::NAN, 1.0)]],
            10.0,
            EdgePolicy::Wrap
        )
        .is_err());
    }

    #[test]
    fn online_queries_half_open() {
        let t = trace(vec![vec![(2.0, 5.0)]], 10.0, EdgePolicy::Wrap);
        assert!(!t.is_online(0, 1.999));
        assert!(t.is_online(0, 2.0));
        assert!(t.is_online(0, 4.999));
        assert!(!t.is_online(0, 5.0));
    }

    #[test]
    fn wrap_repeats_cycle() {
        let t = trace(vec![vec![(0.0, 6.0)]], 10.0, EdgePolicy::Wrap);
        for k in 0..4 {
            let base = 10.0 * k as f64;
            assert!(t.is_online(0, base + 3.0), "cycle {k}");
            assert!(!t.is_online(0, base + 7.0), "cycle {k}");
        }
    }

    #[test]
    fn clamp_persists_final_state() {
        let on_at_end = trace(vec![vec![(4.0, 10.0)]], 10.0, EdgePolicy::Clamp);
        assert!(on_at_end.is_online(0, 25.0));
        assert_eq!(on_at_end.remaining_online(0, 5.0), f64::INFINITY);

        let off_at_end = trace(vec![vec![(0.0, 6.0)]], 10.0, EdgePolicy::Clamp);
        assert!(!off_at_end.is_online(0, 25.0));
        assert_eq!(off_at_end.remaining_online(0, 2.0), 4.0);
    }

    #[test]
    fn remaining_chains_across_wrap() {
        let t = trace(vec![vec![(0.0, 3.0), (8.0, 10.0)]], 10.0, EdgePolicy::Wrap);
        // At t = 9: 1s to the horizon, then the cycle restarts online for 3.
        assert_eq!(t.remaining_online(0, 9.0), 1.0 + 3.0);
        // At t = 1 (inside the head): just the head's remainder.
        assert_eq!(t.remaining_online(0, 1.0), 2.0);
        assert_eq!(t.remaining_online(0, 5.0), 0.0);
    }

    #[test]
    fn full_cycle_is_infinite() {
        let t = trace(vec![vec![(0.0, 10.0)]], 10.0, EdgePolicy::Wrap);
        assert_eq!(t.remaining_online(0, 3.0), f64::INFINITY);
        let a = AvailabilityTrace::always_on(3);
        for c in 0..3 {
            assert_eq!(a.remaining_online(c, 123.456), f64::INFINITY);
        }
    }

    #[test]
    fn clients_beyond_trace_always_online() {
        let t = trace(vec![vec![]], 10.0, EdgePolicy::Wrap);
        assert!(!t.is_online(0, 1.0)); // listed, never online
        assert!(t.is_online(5, 1.0)); // unlisted ⇒ online
        assert_eq!(t.remaining_online(5, 1.0), f64::INFINITY);
    }

    #[test]
    fn online_at_and_fraction() {
        let t = trace(
            vec![vec![(0.0, 5.0)], vec![(5.0, 10.0)], vec![(0.0, 10.0)]],
            10.0,
            EdgePolicy::Wrap,
        );
        assert_eq!(t.online_at(2.0), vec![0, 2]);
        assert_eq!(t.online_at(6.0), vec![1, 2]);
        assert!((t.online_fraction(2.0) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn scaled_rescales_everything() {
        let t = trace(vec![vec![(1.0, 2.0)]], 4.0, EdgePolicy::Wrap).scaled(10.0).unwrap();
        assert_eq!(t.horizon(), 40.0);
        assert_eq!(t.intervals(0), &[(10.0, 20.0)]);
        assert!(t.is_online(0, 15.0));
        assert!(!t.is_online(0, 25.0));
        assert!(trace(vec![], 1.0, EdgePolicy::Wrap).scaled(0.0).is_err());
    }

    #[test]
    fn edge_policy_parse_roundtrip() {
        for p in [EdgePolicy::Wrap, EdgePolicy::Clamp] {
            assert_eq!(EdgePolicy::parse(p.label()), Some(p));
        }
        assert_eq!(EdgePolicy::parse("nope"), None);
    }

    // ---------- horizon-boundary edge cases (wrap vs clamp) ----------
    // (previously only exercised indirectly via the runtime-gated
    // scenario suites; these pin the exact boundary semantics)

    #[test]
    fn wrap_at_exact_horizon_reads_time_zero() {
        let t = trace(vec![vec![(0.0, 3.0)]], 10.0, EdgePolicy::Wrap);
        // t = horizon wraps to 0 (rem_euclid), which is online.
        assert!(t.is_online(0, 10.0));
        assert_eq!(t.remaining_online(0, 10.0), 3.0);
        assert_eq!(t.remaining_online(0, 30.0), 3.0, "any whole number of cycles");
        // An interval not touching 0: t = horizon is offline.
        let mid = trace(vec![vec![(4.0, 7.0)]], 10.0, EdgePolicy::Wrap);
        assert!(!mid.is_online(0, 10.0));
        assert!(mid.is_online(0, 14.5));
    }

    #[test]
    fn clamp_at_exact_horizon_uses_final_state() {
        let on = trace(vec![vec![(4.0, 10.0)]], 10.0, EdgePolicy::Clamp);
        assert!(on.is_online(0, 10.0), "final-online clamp persists at t = horizon");
        assert_eq!(on.remaining_online(0, 10.0), f64::INFINITY);
        let off = trace(vec![vec![(0.0, 6.0)]], 10.0, EdgePolicy::Clamp);
        assert!(!off.is_online(0, 10.0), "final-offline clamp persists at t = horizon");
        assert_eq!(off.remaining_online(0, 10.0), 0.0);
    }

    #[test]
    fn wrap_tail_without_zero_head_does_not_chain() {
        // The online stretch touches the horizon but the cycle restarts
        // offline, so the remainder must stop at the boundary.
        let t = trace(vec![vec![(8.0, 10.0)]], 10.0, EdgePolicy::Wrap);
        assert_eq!(t.remaining_online(0, 9.0), 1.0);
        // And symmetric: a zero head with no horizon tail never chains.
        let h = trace(vec![vec![(0.0, 3.0), (5.0, 7.0)]], 10.0, EdgePolicy::Wrap);
        assert_eq!(h.remaining_online(0, 6.0), 1.0);
    }

    #[test]
    fn interval_end_is_exclusive_everywhere() {
        for policy in [EdgePolicy::Wrap, EdgePolicy::Clamp] {
            let t = trace(vec![vec![(2.0, 5.0)]], 10.0, policy);
            assert_eq!(t.remaining_online(0, 5.0), 0.0, "{policy:?}: end is exclusive");
            assert!(t.remaining_online(0, 5.0 - 1e-9) > 0.0);
        }
    }

    #[test]
    fn wrap_far_future_matches_first_cycle() {
        let t = trace(vec![vec![(2.0, 6.0)]], 10.0, EdgePolicy::Wrap);
        let far = 1.0e9; // a whole number of cycles
        for probe in [0.0, 2.0, 4.0, 6.0, 9.0] {
            assert_eq!(
                t.is_online(0, probe),
                t.is_online(0, far + probe),
                "cycle state diverged at offset {probe}"
            );
        }
        assert_eq!(t.remaining_online(0, far + 3.0), t.remaining_online(0, 3.0));
    }

    #[test]
    fn clamp_mid_trace_remainder_is_finite() {
        // Inside an interval that does NOT touch the horizon, clamp
        // behaves like a plain finite schedule.
        let t = trace(vec![vec![(1.0, 4.0), (6.0, 8.0)]], 10.0, EdgePolicy::Clamp);
        assert_eq!(t.remaining_online(0, 2.0), 2.0);
        assert_eq!(t.remaining_online(0, 7.5), 0.5);
        assert_eq!(t.remaining_online(0, 9.0), 0.0, "between last interval and horizon");
        assert_eq!(t.remaining_online(0, 12.0), 0.0, "past a final-offline horizon");
    }

    #[test]
    fn uptime_fraction_per_client() {
        let t = trace(
            vec![vec![(0.0, 4.0), (6.0, 8.0)], vec![], vec![(0.0, 10.0)]],
            10.0,
            EdgePolicy::Wrap,
        );
        assert!((t.uptime(0) - 0.6).abs() < 1e-12);
        assert_eq!(t.uptime(1), 0.0, "never-online client");
        assert_eq!(t.uptime(2), 1.0, "fully-online client");
        assert_eq!(t.uptime(99), 1.0, "clients beyond the trace are always on");
    }

    // ---------- generated (lazy) representation ----------

    #[test]
    fn generated_matches_dense_generation_bitwise() {
        for (name, policy) in [
            ("markov", EdgePolicy::Wrap),
            ("heavy_tail", EdgePolicy::Clamp),
            ("periodic", EdgePolicy::Wrap),
            ("always_on", EdgePolicy::Wrap),
        ] {
            let model = ChurnModel::parse(name).unwrap();
            let n = 40;
            let horizon = 60.0;
            let base = Rng::new(17);
            let lazy = AvailabilityTrace::generated(model, base.clone(), n, horizon, policy)
                .unwrap()
                .scaled(33.5)
                .unwrap();
            let dense =
                model.generate(&base, n, horizon, policy).unwrap().scaled(33.5).unwrap();
            assert_eq!(lazy.densified(), dense, "{name}: interval tables diverged");
            for c in (0..n + 3).step_by(3) {
                assert_eq!(lazy.intervals(c), dense.intervals(c), "{name} client {c}");
                assert_eq!(
                    lazy.uptime(c).to_bits(),
                    dense.uptime(c).to_bits(),
                    "{name} client {c} uptime"
                );
                for t in [0.0, 12.3, 59.9, 60.0 * 33.5, 1e4] {
                    assert_eq!(
                        lazy.remaining_online(c, t).to_bits(),
                        dense.remaining_online(c, t).to_bits(),
                        "{name} client {c} at {t}"
                    );
                }
            }
        }
    }

    #[test]
    fn generated_is_deterministic_and_seed_sensitive() {
        let model = ChurnModel::parse("heavy_tail").unwrap();
        let a =
            AvailabilityTrace::generated(model, Rng::new(5), 10, 40.0, EdgePolicy::Wrap).unwrap();
        let b =
            AvailabilityTrace::generated(model, Rng::new(5), 10, 40.0, EdgePolicy::Wrap).unwrap();
        assert_eq!(a, b);
        let c =
            AvailabilityTrace::generated(model, Rng::new(6), 10, 40.0, EdgePolicy::Wrap).unwrap();
        assert_ne!(a.intervals(0), c.intervals(0), "different seeds should differ");
    }

    #[test]
    fn generated_rejects_bad_inputs() {
        let bad_model = ChurnModel::Periodic { period: 0.0, duty: 0.5 };
        assert!(
            AvailabilityTrace::generated(bad_model, Rng::new(1), 4, 10.0, EdgePolicy::Wrap)
                .is_err()
        );
        let ok = ChurnModel::AlwaysOn;
        assert!(
            AvailabilityTrace::generated(ok, Rng::new(1), 4, 0.0, EdgePolicy::Wrap).is_err(),
            "non-positive horizon"
        );
    }

    #[test]
    fn generated_clients_beyond_trace_always_online() {
        let model = ChurnModel::parse("markov").unwrap();
        let t =
            AvailabilityTrace::generated(model, Rng::new(2), 5, 30.0, EdgePolicy::Wrap).unwrap();
        assert_eq!(t.remaining_online(7, 3.0), f64::INFINITY);
        assert_eq!(t.uptime(7), 1.0);
        assert_eq!(t.intervals(7), Vec::<(f64, f64)>::new());
    }
}
