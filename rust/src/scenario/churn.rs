//! Parametric churn models: generate an [`AvailabilityTrace`] from a
//! handful of interpretable parameters instead of hand-written intervals.
//!
//! Four regimes cover the straggler-resilience literature's assumptions:
//! always-on (the classic FL setting), periodic duty cycles (diurnal
//! device availability), two-state Markov on/off churn (exponential
//! session lengths, the standard availability model), and heavy-tailed
//! dropout (Pareto offline gaps — a few clients vanish for a long time,
//! as in FLANP-style straggler traces).
//!
//! Generation is deterministic: the same model, client count, horizon and
//! [`Rng`] stream produce the identical trace, and each client's schedule
//! is drawn from an independent split of the root stream (keyed by client
//! index), so adding clients never perturbs existing schedules.

use anyhow::anyhow;

use super::trace::{AvailabilityTrace, EdgePolicy};
use crate::util::rng::Rng;

/// Guard against zero-length sojourns (u = 0 draws): keeps alternating
/// on/off generation loops strictly advancing.
const MIN_SOJOURN: f64 = 1e-9;

/// A parametric client-availability regime. All durations are in the
/// trace's native time unit (scaled to simulated seconds at
/// materialization — see [`super::TraceSpec`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ChurnModel {
    /// Every client online at every time (the classic FL assumption).
    AlwaysOn,
    /// Deterministic duty cycle: each client is online for `duty × period`
    /// out of every `period`, at a per-client random phase offset (so the
    /// fleet's capacity stays roughly flat while individuals blink). For a
    /// seamless [`EdgePolicy::Wrap`] trace choose a horizon that is a
    /// multiple of `period`; otherwise windows truncate at the boundary.
    Periodic {
        /// Cycle length.
        period: f64,
        /// Online fraction of each cycle, in `(0, 1]`.
        duty: f64,
    },
    /// Two-state Markov process: exponential online sojourns of mean
    /// `mean_on` alternate with exponential offline sojourns of mean
    /// `mean_off`; each client starts online with probability
    /// `p_init_online`.
    Markov {
        /// Mean online sojourn.
        mean_on: f64,
        /// Mean offline sojourn.
        mean_off: f64,
        /// Probability a client is online at t = 0.
        p_init_online: f64,
    },
    /// Heavy-tailed dropout: exponential online sojourns of mean `mean_on`
    /// interrupted by Pareto(`min_off`, `alpha`) offline gaps — small
    /// `alpha` makes a few clients disappear for a very long time.
    HeavyTail {
        /// Mean online sojourn.
        mean_on: f64,
        /// Minimum offline gap (the Pareto scale).
        min_off: f64,
        /// Pareto tail index (smaller ⇒ heavier tail), must be > 0.
        alpha: f64,
    },
}

impl ChurnModel {
    /// Parse a model name: `always_on` | `periodic` | `markov` |
    /// `heavy_tail` (case-insensitive, `-`/`_` interchangeable). Returns
    /// the model with its default parameters; callers override fields
    /// from their config source.
    pub fn parse(s: &str) -> Option<ChurnModel> {
        match s.trim().to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "alwayson" => Some(ChurnModel::AlwaysOn),
            "periodic" => Some(ChurnModel::Periodic { period: 10.0, duty: 0.7 }),
            "markov" => Some(ChurnModel::Markov {
                mean_on: 8.0,
                mean_off: 2.0,
                p_init_online: 0.8,
            }),
            "heavytail" => Some(ChurnModel::HeavyTail {
                mean_on: 8.0,
                min_off: 0.5,
                alpha: 1.1,
            }),
            _ => None,
        }
    }

    /// Canonical snake_case name (inverse of [`ChurnModel::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            ChurnModel::AlwaysOn => "always_on",
            ChurnModel::Periodic { .. } => "periodic",
            ChurnModel::Markov { .. } => "markov",
            ChurnModel::HeavyTail { .. } => "heavy_tail",
        }
    }

    /// The long-run fraction of time a single client is online under this
    /// model (1.0 where the model has no offline state; for heavy-tailed
    /// gaps this uses the mean gap `min_off · α/(α−1)`, or 0 when α ≤ 1 —
    /// an infinite-mean tail eventually swallows everything).
    pub fn expected_online_fraction(&self) -> f64 {
        match *self {
            ChurnModel::AlwaysOn => 1.0,
            ChurnModel::Periodic { duty, .. } => duty.clamp(0.0, 1.0),
            ChurnModel::Markov { mean_on, mean_off, .. } => mean_on / (mean_on + mean_off),
            ChurnModel::HeavyTail { mean_on, min_off, alpha } => {
                if alpha <= 1.0 {
                    0.0
                } else {
                    let mean_off = min_off * alpha / (alpha - 1.0);
                    mean_on / (mean_on + mean_off)
                }
            }
        }
    }

    /// Reject parameter combinations that are meaningless or would make
    /// generation pathological (non-positive sojourn means produce ~1e-9
    /// sojourns and a near-infinite interval list, not an error state a
    /// trace author could want).
    pub fn validate(&self) -> crate::Result<()> {
        let pos = |name: &str, v: f64| {
            if v.is_finite() && v > 0.0 {
                Ok(())
            } else {
                Err(anyhow!(
                    "churn model {}: `{name}` must be positive and finite, got {v}",
                    self.label()
                ))
            }
        };
        let frac = |name: &str, v: f64, lo_open: bool| {
            let ok = v.is_finite() && v <= 1.0 && (v > 0.0 || (!lo_open && v >= 0.0));
            if ok {
                Ok(())
            } else {
                Err(anyhow!(
                    "churn model {}: `{name}` must be in {}0, 1], got {v}",
                    self.label(),
                    if lo_open { "(" } else { "[" }
                ))
            }
        };
        match *self {
            ChurnModel::AlwaysOn => Ok(()),
            ChurnModel::Periodic { period, duty } => {
                pos("period", period)?;
                frac("duty", duty, true)
            }
            ChurnModel::Markov { mean_on, mean_off, p_init_online } => {
                pos("mean_on", mean_on)?;
                pos("mean_off", mean_off)?;
                frac("p_init_online", p_init_online, false)
            }
            ChurnModel::HeavyTail { mean_on, min_off, alpha } => {
                pos("mean_on", mean_on)?;
                pos("min_off", min_off)?;
                pos("alpha", alpha)
            }
        }
    }

    /// Generate the availability schedule of `clients` clients over
    /// `[0, horizon)`. Each client draws from `rng.split(client_index)`,
    /// so the schedule of client `i` is independent of the client count.
    /// Errors on invalid parameters (see [`ChurnModel::validate`]).
    pub fn generate(
        &self,
        rng: &Rng,
        clients: usize,
        horizon: f64,
        policy: EdgePolicy,
    ) -> crate::Result<AvailabilityTrace> {
        self.validate()?;
        let mut all = Vec::with_capacity(clients);
        for c in 0..clients {
            let mut r = rng.split(c as u64);
            all.push(self.client_intervals(&mut r, horizon));
        }
        AvailabilityTrace::from_intervals(all, horizon, policy)
    }

    /// One client's online intervals over `[0, horizon)` (unnormalized —
    /// [`AvailabilityTrace::from_intervals`] sorts/merges/clamps). Exposed
    /// to the trace layer so the generated (lazy) representation can
    /// re-derive a single client's schedule on demand, bit-identically to
    /// [`ChurnModel::generate`].
    pub(crate) fn client_intervals(&self, r: &mut Rng, horizon: f64) -> Vec<(f64, f64)> {
        match *self {
            ChurnModel::AlwaysOn => vec![(0.0, horizon)],
            ChurnModel::Periodic { period, duty } => {
                let duty = duty.clamp(0.0, 1.0);
                if duty >= 1.0 || period <= 0.0 {
                    return vec![(0.0, horizon)];
                }
                let phase = r.f64() * period;
                let window = duty * period;
                let mut ivs = Vec::new();
                // Start one period early so a window straddling t = 0
                // contributes its head — when the horizon is a multiple of
                // the period this is exactly the wrapped continuation of
                // the horizon-crossing window, keeping Wrap traces
                // seamless without any double counting.
                let mut start = phase - period;
                while start < horizon {
                    let end = start + window;
                    if end > 0.0 {
                        ivs.push((start.max(0.0), end.min(horizon)));
                    }
                    start += period;
                }
                ivs
            }
            ChurnModel::Markov { mean_on, mean_off, p_init_online } => {
                let start_online = r.f64() < p_init_online;
                alternate(r, horizon, start_online, |r, online| {
                    let mean = if online { mean_on } else { mean_off };
                    exponential(r, mean)
                })
            }
            ChurnModel::HeavyTail { mean_on, min_off, alpha } => {
                alternate(r, horizon, true, |r, online| {
                    if online {
                        exponential(r, mean_on)
                    } else {
                        r.power_law(min_off.max(MIN_SOJOURN), alpha.max(0.05))
                    }
                })
            }
        }
    }
}

/// Exponential sample of mean `mean` (clamped strictly positive).
fn exponential(r: &mut Rng, mean: f64) -> f64 {
    let u = r.f64(); // [0, 1)
    (-mean.max(MIN_SOJOURN) * (1.0 - u).ln()).max(MIN_SOJOURN)
}

/// Alternate online/offline sojourns from `t = 0` until the horizon,
/// collecting the online stretches. `dur(rng, online)` draws the next
/// sojourn length for the current state.
fn alternate(
    r: &mut Rng,
    horizon: f64,
    start_online: bool,
    mut dur: impl FnMut(&mut Rng, bool) -> f64,
) -> Vec<(f64, f64)> {
    let mut ivs = Vec::new();
    let mut online = start_online;
    let mut t = 0.0;
    while t < horizon {
        let d = dur(r, online).max(MIN_SOJOURN);
        if online {
            ivs.push((t, (t + d).min(horizon)));
        }
        t += d;
        online = !online;
    }
    ivs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(model: ChurnModel, clients: usize, horizon: f64) -> AvailabilityTrace {
        model
            .generate(&Rng::new(99), clients, horizon, EdgePolicy::Wrap)
            .unwrap()
    }

    /// Fraction of client-time online, sampled on a grid.
    fn measured_online_fraction(t: &AvailabilityTrace, horizon: f64) -> f64 {
        let steps = 400;
        let mut acc = 0.0;
        for s in 0..steps {
            let time = horizon * (s as f64 + 0.5) / steps as f64;
            acc += t.online_fraction(time);
        }
        acc / steps as f64
    }

    #[test]
    fn always_on_is_always_on() {
        let t = gen(ChurnModel::AlwaysOn, 5, 50.0);
        for c in 0..5 {
            assert_eq!(t.remaining_online(c, 17.3), f64::INFINITY);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let m = ChurnModel::parse("markov").unwrap();
        let a = m.generate(&Rng::new(7), 20, 100.0, EdgePolicy::Wrap).unwrap();
        let b = m.generate(&Rng::new(7), 20, 100.0, EdgePolicy::Wrap).unwrap();
        assert_eq!(a, b);
        let c = m.generate(&Rng::new(8), 20, 100.0, EdgePolicy::Wrap).unwrap();
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn client_schedules_stable_under_fleet_growth() {
        let m = ChurnModel::parse("heavy_tail").unwrap();
        let small = m.generate(&Rng::new(3), 5, 80.0, EdgePolicy::Wrap).unwrap();
        let big = m.generate(&Rng::new(3), 15, 80.0, EdgePolicy::Wrap).unwrap();
        for c in 0..5 {
            assert_eq!(small.intervals(c), big.intervals(c), "client {c}");
        }
    }

    #[test]
    fn periodic_duty_cycle_tracks_duty() {
        let m = ChurnModel::Periodic { period: 10.0, duty: 0.6 };
        let t = gen(m, 200, 100.0);
        let frac = measured_online_fraction(&t, 100.0);
        assert!((frac - 0.6).abs() < 0.05, "measured {frac}");
    }

    #[test]
    fn periodic_non_divisor_horizon_keeps_duty() {
        // Horizon not a multiple of the period: windows truncate at the
        // boundary but the measured duty must still track `duty` (a
        // regression guard against double-counting a crossing window's
        // wrapped head on top of the period-early start).
        let m = ChurnModel::Periodic { period: 7.0, duty: 0.5 };
        let t = gen(m, 300, 10.0);
        let frac = measured_online_fraction(&t, 10.0);
        assert!((frac - 0.5).abs() < 0.05, "measured {frac}");
    }

    #[test]
    fn markov_online_fraction_tracks_means() {
        let m = ChurnModel::Markov { mean_on: 6.0, mean_off: 2.0, p_init_online: 0.75 };
        let t = gen(m, 300, 400.0);
        let frac = measured_online_fraction(&t, 400.0);
        let want = m.expected_online_fraction();
        assert!((frac - want).abs() < 0.06, "measured {frac}, want {want}");
    }

    #[test]
    fn heavy_tail_produces_long_gaps() {
        let m = ChurnModel::HeavyTail { mean_on: 4.0, min_off: 1.0, alpha: 1.05 };
        let t = gen(m, 200, 200.0);
        // With a near-1 tail index some client must be offline for a long
        // stretch (> 10× the minimum gap).
        let mut longest_gap = 0.0f64;
        for c in 0..200 {
            let ivs = t.intervals(c);
            for w in ivs.windows(2) {
                longest_gap = longest_gap.max(w[1].0 - w[0].1);
            }
        }
        assert!(longest_gap > 10.0, "longest offline gap only {longest_gap}");
    }

    #[test]
    fn invalid_parameters_are_errors_not_hangs() {
        let bad = [
            ChurnModel::Markov { mean_on: 0.0, mean_off: 2.0, p_init_online: 0.5 },
            ChurnModel::Markov { mean_on: 4.0, mean_off: -1.0, p_init_online: 0.5 },
            ChurnModel::Markov { mean_on: 4.0, mean_off: 2.0, p_init_online: 1.5 },
            ChurnModel::HeavyTail { mean_on: 4.0, min_off: 0.0, alpha: 1.1 },
            ChurnModel::HeavyTail { mean_on: 4.0, min_off: 0.5, alpha: f64::NAN },
            ChurnModel::Periodic { period: 0.0, duty: 0.5 },
            ChurnModel::Periodic { period: 8.0, duty: 0.0 },
            ChurnModel::Periodic { period: 8.0, duty: 1.5 },
        ];
        for m in bad {
            assert!(
                m.generate(&Rng::new(1), 3, 24.0, EdgePolicy::Wrap).is_err(),
                "{m:?} should be rejected"
            );
        }
        // Boundary values that are legitimate stay accepted.
        let ok = [
            ChurnModel::Markov { mean_on: 4.0, mean_off: 2.0, p_init_online: 0.0 },
            ChurnModel::Markov { mean_on: 4.0, mean_off: 2.0, p_init_online: 1.0 },
            ChurnModel::Periodic { period: 8.0, duty: 1.0 },
        ];
        for m in ok {
            assert!(m.generate(&Rng::new(1), 3, 24.0, EdgePolicy::Wrap).is_ok(), "{m:?}");
        }
    }

    #[test]
    fn parse_label_roundtrip() {
        for name in ["always_on", "periodic", "markov", "heavy_tail"] {
            let m = ChurnModel::parse(name).unwrap();
            assert_eq!(m.label(), name);
        }
        assert!(ChurnModel::parse("diurnal").is_none());
    }
}
