//! Corrupted-update scenarios: seeded per-client noise / sign-flip
//! attacks on the round-end parameters clients return.
//!
//! The availability trace family models clients *disappearing*; this
//! knob models clients *misbehaving* — the adversarial workload the
//! robust aggregators in [`crate::agg`] exist for. A
//! [`CorruptionSpec`] marks a deterministic fraction of the fleet as
//! corrupted (per-client membership keyed by the spec's own seed, stable
//! under fleet growth like trace generation) and perturbs each corrupted
//! client's returned parameters before aggregation:
//!
//! * [`CorruptionKind::Noise`] — adds i.i.d. Gaussian noise of scale σ
//!   to every coordinate (a faulty sensor / quantization blowup).
//! * [`CorruptionKind::SignFlip`] — replaces the update `wᵢ − w` with
//!   `−scale · (wᵢ − w)` (the classic model-poisoning sign-flip attack).
//!
//! Determinism: membership is a pure function of `(seed, client)`; the
//! noise stream is split from `(seed, round, client)` — independent of
//! the FL seed and of worker scheduling, so corrupted runs replay
//! bit-for-bit and sign flips consume no RNG at all.

use anyhow::{anyhow, Result};

use crate::util::rng::Rng;

/// How a corrupted client's returned parameters are perturbed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CorruptionKind {
    /// Add N(0, σ²) noise to every coordinate.
    Noise {
        /// Noise scale σ (simulated-parameter units).
        sigma: f64,
    },
    /// Replace the update `wᵢ − w` with `−scale · (wᵢ − w)`.
    SignFlip {
        /// Flip magnitude (`1.0` = exact reflection around the global).
        scale: f64,
    },
}

impl CorruptionKind {
    /// Parse a kind name with default parameters:
    /// `noise` (σ = 1) | `sign_flip` (scale = 1).
    pub fn parse(s: &str) -> Option<CorruptionKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "noise" => Some(CorruptionKind::Noise { sigma: 1.0 }),
            "sign_flip" | "signflip" | "flip" => Some(CorruptionKind::SignFlip { scale: 1.0 }),
            _ => None,
        }
    }

    /// Canonical kind name.
    pub fn label(&self) -> &'static str {
        match self {
            CorruptionKind::Noise { .. } => "noise",
            CorruptionKind::SignFlip { .. } => "sign_flip",
        }
    }
}

/// A seeded corruption scenario: which fraction of the fleet misbehaves,
/// and how.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CorruptionSpec {
    /// The perturbation applied to corrupted clients' parameters.
    pub kind: CorruptionKind,
    /// Fraction of the fleet corrupted, in `[0, 1]`. Membership is
    /// per-client Bernoulli(fraction) on the spec's own seed.
    pub fraction: f64,
    /// Root seed of the corruption streams (independent of the FL seed).
    pub seed: u64,
}

impl CorruptionSpec {
    /// A spec with the module defaults (seed 1).
    pub fn new(kind: CorruptionKind, fraction: f64) -> CorruptionSpec {
        CorruptionSpec { kind, fraction, seed: 1 }
    }

    /// Validate the parameters (fraction in `[0, 1]`, finite positive
    /// scales).
    pub fn validate(&self) -> Result<()> {
        if !(self.fraction >= 0.0 && self.fraction <= 1.0) {
            return Err(anyhow!("corruption fraction must be in [0, 1], got {}", self.fraction));
        }
        match self.kind {
            CorruptionKind::Noise { sigma } => {
                if !(sigma >= 0.0 && sigma.is_finite()) {
                    return Err(anyhow!(
                        "corruption noise sigma must be finite and >= 0, got {sigma}"
                    ));
                }
            }
            CorruptionKind::SignFlip { scale } => {
                if !(scale > 0.0 && scale.is_finite()) {
                    return Err(anyhow!("sign-flip scale must be finite and > 0, got {scale}"));
                }
            }
        }
        Ok(())
    }

    /// Which of `n` clients are corrupted. Per-client membership is keyed
    /// by `(seed, client index)`, so adding clients never flips existing
    /// ones — the same stability rule trace generation follows.
    pub fn corrupted_clients(&self, n: usize) -> Vec<bool> {
        let root = Rng::new(self.seed);
        (0..n)
            .map(|i| {
                let mut r = root.split(0xC0_44 ^ i as u64);
                r.f64() < self.fraction
            })
            .collect()
    }

    /// Perturb one corrupted client's round-end parameters in place.
    /// `global` is the round's broadcast model wᵣ (the reflection center
    /// for sign flips). Deterministic per `(seed, round, client)`.
    pub fn apply(&self, params: &mut [f32], global: &[f32], round: usize, client: usize) {
        match self.kind {
            CorruptionKind::Noise { sigma } => {
                let mut rng =
                    Rng::new(self.seed).split(0xBAD ^ ((round as u64) << 24) ^ client as u64);
                for p in params.iter_mut() {
                    *p = (*p as f64 + sigma * rng.normal()) as f32;
                }
            }
            CorruptionKind::SignFlip { scale } => {
                assert_eq!(params.len(), global.len(), "parameter dimension mismatch");
                for (p, &g) in params.iter_mut().zip(global) {
                    *p = (g as f64 - scale * (*p as f64 - g as f64)) as f32;
                }
            }
        }
    }

    /// Canonical kind name (for reports).
    pub fn label(&self) -> &'static str {
        self.kind.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_validate() {
        assert_eq!(CorruptionKind::parse("noise"), Some(CorruptionKind::Noise { sigma: 1.0 }));
        assert_eq!(
            CorruptionKind::parse("SIGN_FLIP"),
            Some(CorruptionKind::SignFlip { scale: 1.0 })
        );
        assert_eq!(CorruptionKind::parse("nope"), None);
        assert!(CorruptionSpec::new(CorruptionKind::Noise { sigma: 0.5 }, 0.2).validate().is_ok());
        assert!(CorruptionSpec::new(CorruptionKind::Noise { sigma: -1.0 }, 0.2)
            .validate()
            .is_err());
        assert!(CorruptionSpec::new(CorruptionKind::SignFlip { scale: 0.0 }, 0.2)
            .validate()
            .is_err());
        assert!(CorruptionSpec::new(CorruptionKind::SignFlip { scale: 1.0 }, 1.5)
            .validate()
            .is_err());
    }

    #[test]
    fn membership_is_deterministic_and_stable_under_growth() {
        let spec = CorruptionSpec::new(CorruptionKind::SignFlip { scale: 1.0 }, 0.3);
        let a = spec.corrupted_clients(20);
        let b = spec.corrupted_clients(20);
        assert_eq!(a, b);
        // Growing the fleet never flips existing clients.
        let bigger = spec.corrupted_clients(40);
        assert_eq!(&bigger[..20], &a[..]);
        // Edge fractions.
        assert!(CorruptionSpec::new(spec.kind, 0.0)
            .corrupted_clients(50)
            .iter()
            .all(|&c| !c));
        assert!(CorruptionSpec::new(spec.kind, 1.0)
            .corrupted_clients(50)
            .iter()
            .all(|&c| c));
    }

    #[test]
    fn sign_flip_reflects_around_the_global() {
        let spec = CorruptionSpec::new(CorruptionKind::SignFlip { scale: 1.0 }, 1.0);
        let global = vec![1.0f32, -2.0, 0.5];
        let mut params = vec![1.5f32, -2.5, 0.5];
        spec.apply(&mut params, &global, 3, 7);
        // w' − g = −(w − g): 1.5 → 0.5, −2.5 → −1.5, 0.5 → 0.5.
        assert!((params[0] - 0.5).abs() < 1e-6);
        assert!((params[1] + 1.5).abs() < 1e-6);
        assert!((params[2] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn noise_replays_per_round_and_client() {
        let spec = CorruptionSpec::new(CorruptionKind::Noise { sigma: 0.5 }, 1.0);
        let global = vec![0.0f32; 8];
        let base = vec![1.0f32; 8];
        let mut a = base.clone();
        let mut b = base.clone();
        spec.apply(&mut a, &global, 2, 5);
        spec.apply(&mut b, &global, 2, 5);
        assert_eq!(a, b, "same (seed, round, client) must replay exactly");
        let mut c = base.clone();
        spec.apply(&mut c, &global, 3, 5);
        assert_ne!(a, c, "different rounds must draw different noise");
        assert!(a.iter().zip(&base).any(|(x, y)| x != y), "sigma > 0 must perturb");
    }
}
