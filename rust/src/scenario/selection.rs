//! Straggler-aware cohort-selection policies (the ROADMAP's selection
//! suite).
//!
//! The engine's cohort-choice step is availability-aware but
//! speed-blind: every online client is sampled with the same data-sized
//! weight no matter how slow its plan is. This module adds the
//! selection-side treatments from the related work behind one seam:
//!
//! * [`SelectPolicy::Flanp`] — FLANP-style adaptive participation
//!   (arXiv:2012.14453): rank clients once per run by their
//!   deterministic simulated plan cost (the same costs dispatch plans
//!   from), start rounds sampling only the fastest prefix, and widen
//!   the prefix geometrically whenever the round-loss improvement
//!   stalls below a threshold. Early rounds are cheap (fast clients
//!   only); statistical accuracy pulls the slow tail in on demand.
//! * [`SelectPolicy::Forecast`] — uptime-forecast selection: bias the
//!   sampling weights toward clients whose availability history
//!   forecasts they will survive the round — the mirror image of
//!   `--flaky-boost`, which oversamples flaky clients for coverage.
//!
//! Straggler distillation (arXiv:2403.09086) is the third treatment in
//! the suite; it lives on the aggregation side
//! ([`crate::fl::RunConfig`]'s `distill_weight` +
//! [`crate::agg::apply_distilled`]) because it changes what happens to
//! past-staleness updates, not who gets selected.
//!
//! Determinism contract (the "degenerate selection knobs are bitwise
//! inert" clause in ARCHITECTURE.md): every knob here has a degenerate
//! setting that reproduces the baseline engine byte-for-byte —
//! `flanp_start ≥ fleet` keeps the active prefix at the whole fleet, so
//! the streamed selector consumes exactly the RNG of the unrestricted
//! sampler; `forecast_bias = 0` returns the input weights unchanged;
//! `distill_weight = 0` is the existing drop path. The selection
//! differential harness (`rust/tests/proptest_select.rs`) pins all
//! three against the baseline engine bit-for-bit.

use anyhow::{anyhow, Result};

/// Knobs for FLANP adaptive participation ([`SelectPolicy::Flanp`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlanpConfig {
    /// Initial active-prefix size, clamped to `[1, fleet]` at run start.
    /// Anything ≥ the fleet size is the degenerate whole-fleet prefix
    /// (bitwise the baseline selector).
    pub start: usize,
    /// Geometric widening factor applied when improvement stalls
    /// (must be > 1 so widening always makes progress).
    pub factor: f64,
    /// Relative round-loss improvement below which the prefix widens:
    /// widen when `(prev - cur) / |prev| < threshold`.
    pub threshold: f64,
}

impl Default for FlanpConfig {
    fn default() -> Self {
        FlanpConfig { start: 8, factor: 2.0, threshold: 0.01 }
    }
}

/// The cohort-selection policy seam over the engine's selection step.
///
/// Baseline is the engine's existing availability-aware weighted
/// sampler; the other policies compose with it (FLANP restricts the
/// candidate set, Forecast transforms the weights) so churn handling,
/// RNG-stream discipline, and the <k deterministic fallback are shared,
/// not re-implemented.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SelectPolicy {
    /// The existing sampler: weight ∝ client data size, online-only.
    Baseline,
    /// FLANP adaptive participation: fastest-prefix sampling with
    /// stall-triggered geometric widening.
    Flanp(FlanpConfig),
    /// Uptime-forecast selection: weights scaled by `1 + bias · uptime`.
    Forecast {
        /// Strength of the uptime bias (0 = degenerate, baseline
        /// weights untouched).
        bias: f64,
    },
}

impl Default for SelectPolicy {
    fn default() -> Self {
        SelectPolicy::Baseline
    }
}

impl SelectPolicy {
    /// Parse a CLI/config/env policy name. Knob-less names get the
    /// default knobs; `--flanp-*` / `--forecast-bias` (or the `[fl]`
    /// keys) overwrite them afterwards.
    pub fn parse(s: &str) -> Option<SelectPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "baseline" => Some(SelectPolicy::Baseline),
            "flanp" => Some(SelectPolicy::Flanp(FlanpConfig::default())),
            "forecast" => Some(SelectPolicy::Forecast { bias: 1.0 }),
            _ => None,
        }
    }

    /// Canonical policy name.
    pub fn label(&self) -> &'static str {
        match self {
            SelectPolicy::Baseline => "baseline",
            SelectPolicy::Flanp(_) => "flanp",
            SelectPolicy::Forecast { .. } => "forecast",
        }
    }

    /// Validate the policy knobs (prefix ≥ 1, factor > 1, finite
    /// threshold/bias, bias ≥ 0).
    pub fn validate(&self) -> Result<()> {
        match self {
            SelectPolicy::Baseline => Ok(()),
            SelectPolicy::Flanp(c) => {
                if c.start == 0 {
                    return Err(anyhow!("flanp start prefix must be >= 1, got 0"));
                }
                if !(c.factor > 1.0 && c.factor.is_finite()) {
                    return Err(anyhow!(
                        "flanp widening factor must be finite and > 1, got {}",
                        c.factor
                    ));
                }
                if !c.threshold.is_finite() {
                    return Err(anyhow!(
                        "flanp improvement threshold must be finite, got {}",
                        c.threshold
                    ));
                }
                Ok(())
            }
            SelectPolicy::Forecast { bias } => {
                if !(*bias >= 0.0 && bias.is_finite()) {
                    return Err(anyhow!(
                        "forecast bias must be finite and >= 0, got {bias}"
                    ));
                }
                Ok(())
            }
        }
    }

    /// The `FEDCORE_SELECT` environment override, falling back to the
    /// default ([`SelectPolicy::Baseline`]) when unset or unparseable.
    /// Like `FEDCORE_DISPATCH`, it only applies to flagless, fileless
    /// runs — an explicit `--select` or `[fl] select` always wins.
    pub fn from_env() -> SelectPolicy {
        std::env::var("FEDCORE_SELECT")
            .ok()
            .and_then(|v| SelectPolicy::parse(&v))
            .unwrap_or_default()
    }
}

/// Per-run FLANP state: the cost ranking (fixed for the run) and the
/// current active-prefix size (monotonically non-decreasing, never
/// above the fleet size).
#[derive(Clone, Debug)]
pub struct FlanpState {
    /// `rank_of[i]` = position of client `i` in the cost-ascending
    /// order (0 = fastest); O(1) prefix-membership tests.
    rank_of: Vec<usize>,
    m: usize,
    factor: f64,
    threshold: f64,
    prev_loss: Option<f64>,
}

impl FlanpState {
    /// Build from per-client simulated plan costs. The ranking is
    /// deterministic and permutation-stable: ties break by client id,
    /// and the costs are the strategy's simulated plan times — already
    /// computed (and pinned by the dispatch harness) for scheduling.
    pub fn new(costs: &[f64], cfg: FlanpConfig) -> FlanpState {
        let n = costs.len();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            costs[a]
                .partial_cmp(&costs[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut rank_of = vec![0usize; n];
        for (rank, &i) in order.iter().enumerate() {
            rank_of[i] = rank;
        }
        FlanpState {
            rank_of,
            m: cfg.start.min(n).max(1),
            factor: cfg.factor,
            threshold: cfg.threshold,
            prev_loss: None,
        }
    }

    /// Current active-prefix size.
    pub fn active(&self) -> usize {
        self.m
    }

    /// Whether client `i` is inside the active (fastest) prefix.
    pub fn admits(&self, i: usize) -> bool {
        self.rank_of[i] < self.m
    }

    /// Observe the round's training loss; widen the prefix
    /// geometrically when the relative improvement over the previous
    /// round stalls below the threshold. Returns `true` only when the
    /// prefix actually grew — the whole-fleet prefix cannot widen, so
    /// the degenerate `start ≥ fleet` config never reports a widen and
    /// the `cohort_widened` column stays zero.
    pub fn observe(&mut self, loss: f64) -> bool {
        let n = self.rank_of.len();
        let mut widened = false;
        if let Some(prev) = self.prev_loss {
            if prev.is_finite() && loss.is_finite() && self.m < n {
                let improvement = (prev - loss) / prev.abs().max(f64::MIN_POSITIVE);
                if improvement < self.threshold {
                    self.m = ((self.m as f64 * self.factor).ceil() as usize)
                        .max(self.m + 1)
                        .min(n);
                    widened = true;
                }
            }
        }
        self.prev_loss = Some(loss);
        widened
    }
}

/// Uptime-forecast weight transform: scale each client's sampling
/// weight by `1 + bias · uptime(i)` and renormalize, favoring clients
/// whose availability history forecasts they will survive the round.
///
/// `bias ≤ 0` returns the input weights **unchanged** (bitwise — the
/// degenerate gate), as does a non-positive scaled sum (all-zero
/// weights stay in the sampler's uniform-fallback regime), mirroring
/// [`crate::fl::boost_flaky_weights`]. `uptime_of` is a closure so the
/// scoring streams one client at a time — O(fleet) time with O(1)
/// resident trace state on `Schedules::Generated`; it never forces
/// `materialize_dense` (the PR-8 discipline, pinned by
/// `tests/proptest_scenario.rs`).
pub fn forecast_weights(
    weights: &[f64],
    uptime_of: impl Fn(usize) -> f64,
    bias: f64,
) -> Vec<f64> {
    if bias <= 0.0 {
        return weights.to_vec();
    }
    let raw: Vec<f64> = weights
        .iter()
        .enumerate()
        .map(|(i, &w)| w.max(0.0) * (1.0 + bias * uptime_of(i).clamp(0.0, 1.0)))
        .collect();
    let sum: f64 = raw.iter().sum();
    if sum <= 0.0 {
        return weights.to_vec();
    }
    raw.into_iter().map(|w| w / sum).collect()
}

/// Clients ordered by forecast score: uptime descending, client id
/// ascending on ties. Deterministic and permutation-stable — the
/// ranking depends only on the (uptime, id) pairs, never on input
/// order; pinned by `tests/proptest_select.rs`.
pub fn forecast_rank(uptimes: &[f64]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..uptimes.len()).collect();
    order.sort_by(|&a, &b| {
        uptimes[b]
            .partial_cmp(&uptimes[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_label_roundtrip() {
        for name in ["baseline", "flanp", "forecast"] {
            let p = SelectPolicy::parse(name).unwrap();
            assert_eq!(p.label(), name);
            assert!(p.validate().is_ok());
        }
        assert_eq!(SelectPolicy::parse(" FLANP "), Some(SelectPolicy::Flanp(FlanpConfig::default())));
        assert!(SelectPolicy::parse("fastest").is_none());
        assert_eq!(SelectPolicy::default(), SelectPolicy::Baseline);
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let bad = [
            SelectPolicy::Flanp(FlanpConfig { start: 0, ..Default::default() }),
            SelectPolicy::Flanp(FlanpConfig { factor: 1.0, ..Default::default() }),
            SelectPolicy::Flanp(FlanpConfig { factor: f64::NAN, ..Default::default() }),
            SelectPolicy::Flanp(FlanpConfig { threshold: f64::INFINITY, ..Default::default() }),
            SelectPolicy::Forecast { bias: -0.5 },
            SelectPolicy::Forecast { bias: f64::NAN },
        ];
        for p in bad {
            assert!(p.validate().is_err(), "{p:?} must be rejected");
        }
        assert!(SelectPolicy::Forecast { bias: 0.0 }.validate().is_ok());
    }

    #[test]
    fn flanp_ranking_is_cost_ascending_with_id_ties() {
        // costs: client 2 fastest, then 0 and 3 tied (id order), then 1.
        let costs = [2.0, 9.0, 1.0, 2.0];
        let st = FlanpState::new(&costs, FlanpConfig { start: 2, ..Default::default() });
        assert_eq!(st.active(), 2);
        assert!(st.admits(2) && st.admits(0), "fastest two: client 2, then id-tie winner 0");
        assert!(!st.admits(3) && !st.admits(1));
    }

    #[test]
    fn flanp_start_clamps_to_fleet() {
        let st = FlanpState::new(&[1.0, 2.0, 3.0], FlanpConfig { start: 99, ..Default::default() });
        assert_eq!(st.active(), 3);
        let st = FlanpState::new(&[1.0, 2.0, 3.0], FlanpConfig { start: 1, ..Default::default() });
        assert_eq!(st.active(), 1);
    }

    #[test]
    fn flanp_widens_only_on_stall_and_is_monotone() {
        let costs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let mut st = FlanpState::new(
            &costs,
            FlanpConfig { start: 4, factor: 2.0, threshold: 0.01 },
        );
        // First observation has no baseline to compare against.
        assert!(!st.observe(10.0));
        assert_eq!(st.active(), 4);
        // 50% improvement: well above threshold, no widen.
        assert!(!st.observe(5.0));
        assert_eq!(st.active(), 4);
        // Stall: widens geometrically, 4 -> 8.
        assert!(st.observe(5.0));
        assert_eq!(st.active(), 8);
        // Keep stalling: the prefix is monotone non-decreasing, capped at n.
        let mut last = st.active();
        for _ in 0..10 {
            st.observe(5.0);
            assert!(st.active() >= last);
            assert!(st.active() <= 100);
            last = st.active();
        }
        assert_eq!(st.active(), 100);
        // At the whole fleet, further stalls report no widen.
        assert!(!st.observe(5.0));
    }

    #[test]
    fn flanp_whole_fleet_prefix_never_widens() {
        let mut st = FlanpState::new(&[3.0, 1.0], FlanpConfig { start: 2, ..Default::default() });
        for _ in 0..5 {
            assert!(!st.observe(1.0), "degenerate prefix must stay silent");
            assert_eq!(st.active(), 2);
        }
        assert!(st.admits(0) && st.admits(1));
    }

    #[test]
    fn flanp_widen_always_progresses() {
        // A factor close to 1 would stall at ceil(m * f) == m without the
        // max(m + 1) guard; validate() rejects f <= 1 but ceil can still
        // round to m for m = 1 edge cases, so the guard is load-bearing.
        let mut st = FlanpState::new(
            &[0.0, 1.0, 2.0],
            FlanpConfig { start: 1, factor: 1.5, threshold: 1.0 },
        );
        st.observe(1.0);
        assert!(st.observe(1.0));
        assert_eq!(st.active(), 2);
    }

    #[test]
    fn forecast_weights_zero_bias_is_bitwise_input() {
        let w = [0.3, 0.0, 0.7, 0.25];
        let out = forecast_weights(&w, |_| panic!("bias 0 must not score"), 0.0);
        for (a, b) in w.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn forecast_weights_favor_high_uptime() {
        let w = [1.0, 1.0];
        let up = [0.9, 0.1];
        let out = forecast_weights(&w, |i| up[i], 2.0);
        assert!(out[0] > out[1], "steady client must outweigh flaky one");
        assert!((out.iter().sum::<f64>() - 1.0).abs() < 1e-12, "renormalized");
    }

    #[test]
    fn forecast_weights_zero_sum_falls_back_to_input() {
        let w = [0.0, -1.0];
        let out = forecast_weights(&w, |_| 1.0, 1.0);
        for (a, b) in w.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn forecast_rank_is_permutation_stable() {
        let up = [0.5, 0.9, 0.5, 0.1];
        assert_eq!(forecast_rank(&up), vec![1, 0, 2, 3], "ties break by id");
    }
}
