//! Trace-driven client availability scenarios — and corrupted-update
//! adversaries ([`corruption`]).
//!
//! FedCore's fleet simulation ([`crate::sim`]) models *how fast* clients
//! are; this module models *whether they are there at all* (availability
//! traces) and *whether their updates can be trusted* (the corruption
//! knob exercising [`crate::agg`]'s robust aggregators). An
//! [`AvailabilityTrace`] maps simulated time to each client's
//! online/offline state, either written out explicitly (interval lists in
//! TOML/JSON — see `examples/traces/`) or generated from a parametric
//! [`ChurnModel`]. The FL engine consults the trace at each round's start
//! time: only online clients are eligible for selection, and a selected
//! client that goes offline before finishing its local plan is dropped
//! mid-round, its partial work discarded and surfaced in the round record
//! — and, on traced runs, as a per-client `churn_drop` event plus the
//! `churn_dropped` counter in the observability trace ([`crate::obs`]).
//!
//! # Time units
//!
//! Fleet deadlines are data-dependent (τ is a percentile of full-round
//! times), so portable trace files express time in **deadline units**
//! (`unit = "deadline"`): one unit is one round deadline τ. A trace is
//! materialized into simulated seconds only once the fleet exists —
//! [`TraceSpec::materialize`] takes the client count and τ. Raw-second
//! traces (`unit = "seconds"`) skip the scaling.
//!
//! # Determinism
//!
//! Loading, generation and every query are deterministic: a
//! [`TraceSpec`] plus a client count and deadline always materializes the
//! bit-identical trace, and churn generation splits one RNG stream per
//! client (keyed by client index), so runs replay exactly and adding
//! clients never perturbs existing schedules.

pub mod churn;
pub mod corruption;
pub mod selection;
pub mod trace;

pub use churn::ChurnModel;
pub use corruption::{CorruptionKind, CorruptionSpec};
pub use selection::{forecast_rank, forecast_weights, FlanpConfig, FlanpState, SelectPolicy};
pub use trace::{AvailabilityTrace, EdgePolicy};

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::toml::TomlDoc;

/// The time unit trace timestamps are written in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceUnit {
    /// Raw simulated seconds.
    Seconds,
    /// Multiples of the fleet's round deadline τ (portable across
    /// benchmarks, whose absolute time scales differ by orders of
    /// magnitude).
    Deadlines,
}

impl TraceUnit {
    /// Parse `"seconds"` / `"deadline"` (or `"deadlines"`).
    pub fn parse(s: &str) -> Option<TraceUnit> {
        match s.trim().to_ascii_lowercase().as_str() {
            "seconds" | "second" | "s" => Some(TraceUnit::Seconds),
            "deadline" | "deadlines" | "tau" => Some(TraceUnit::Deadlines),
            _ => None,
        }
    }

    /// Canonical name (`"seconds"` / `"deadline"`).
    pub fn label(&self) -> &'static str {
        match self {
            TraceUnit::Seconds => "seconds",
            TraceUnit::Deadlines => "deadline",
        }
    }
}

/// Where a trace's schedules come from.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceSource {
    /// Hand-written per-client interval lists; clients not listed are
    /// always online.
    Explicit {
        /// `(client index, flat-ordered online intervals)` pairs.
        clients: Vec<(usize, Vec<(f64, f64)>)>,
    },
    /// Generated from a parametric churn model with its own seed.
    Model {
        /// The churn regime and its parameters.
        model: ChurnModel,
        /// Root seed of the generation RNG (independent of the FL seed).
        seed: u64,
    },
}

/// A declarative, fleet-independent description of an availability trace.
///
/// The spec carries everything a trace file can say; it becomes an
/// [`AvailabilityTrace`] only at [`TraceSpec::materialize`] time, when
/// the fleet size and deadline are known.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpec {
    /// Explicit intervals or a churn model.
    pub source: TraceSource,
    /// Trace length, in `unit`s.
    pub horizon: f64,
    /// Unit of `horizon` and all timestamps.
    pub unit: TraceUnit,
    /// Behaviour for times past the horizon.
    pub policy: EdgePolicy,
}

impl TraceSpec {
    /// The spec of the classic FL setting: everyone online, forever.
    pub fn always_on() -> TraceSpec {
        TraceSpec {
            source: TraceSource::Model { model: ChurnModel::AlwaysOn, seed: 0 },
            horizon: 1.0,
            unit: TraceUnit::Deadlines,
            policy: EdgePolicy::Wrap,
        }
    }

    /// A generated spec with the module defaults (deadline units, wrap).
    pub fn from_model(model: ChurnModel, horizon: f64, seed: u64) -> TraceSpec {
        TraceSpec {
            source: TraceSource::Model { model, seed },
            horizon,
            unit: TraceUnit::Deadlines,
            policy: EdgePolicy::Wrap,
        }
    }

    /// Short name for reports: the churn model's label, or `"explicit"`.
    pub fn label(&self) -> &'static str {
        match &self.source {
            TraceSource::Explicit { .. } => "explicit",
            TraceSource::Model { model, .. } => model.label(),
        }
    }

    /// Load a spec from a trace file, dispatching on the extension
    /// (`.json` ⇒ JSON, anything else ⇒ TOML).
    pub fn from_file(path: impl AsRef<Path>) -> Result<TraceSpec> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        let is_json = path
            .extension()
            .map(|e| e.eq_ignore_ascii_case("json"))
            .unwrap_or(false);
        let spec = if is_json { Self::from_json(&text) } else { Self::from_toml(&text) };
        spec.with_context(|| format!("parsing trace {}", path.display()))
    }

    /// Parse the TOML trace format (see `examples/traces/README.md`):
    /// a `[trace]` section with `kind`/`horizon`/`unit`/`after`/`seed` and
    /// model parameters, plus an optional `[clients]` section of explicit
    /// per-client interval lists for `kind = "explicit"`.
    pub fn from_toml(text: &str) -> Result<TraceSpec> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("trace toml: {e}"))?;
        Self::from_toml_doc(&doc, "trace")
    }

    /// Parse a spec out of `doc`'s `[section]` (the experiment config
    /// loader reuses this for its inline `[scenario]` section, with
    /// explicit intervals coming from the sibling `[clients]` section).
    pub fn from_toml_doc(doc: &TomlDoc, section: &str) -> Result<TraceSpec> {
        let clients = match doc.sections.get("clients") {
            None => None,
            Some(listing) => {
                let mut out = Vec::with_capacity(listing.len());
                for (key, value) in listing {
                    let id: usize = key
                        .parse()
                        .map_err(|_| anyhow!("[clients] key '{key}' is not a client index"))?;
                    let flat = value
                        .as_f64_vec()
                        .ok_or_else(|| anyhow!("client {id}: intervals must be a number array"))?;
                    out.push((id, pair_up(id, &flat)?));
                }
                Some(out)
            }
        };
        assemble_spec(
            &format!("trace [{section}]"),
            |key| doc.get(section, key).and_then(|v| v.as_str()).map(str::to_string),
            |key| doc.get(section, key).and_then(|v| v.as_f64()),
            // Accept `seed = 7` and (tolerantly) `seed = 7.0`.
            doc.get(section, "seed")
                .and_then(|v| v.as_i64().or_else(|| v.as_f64().map(|f| f as i64))),
            clients,
        )
    }

    /// Parse the JSON trace format: a root object with a `"trace"` object
    /// (same keys as the TOML `[trace]` section) and, for explicit traces,
    /// a `"clients"` object mapping client indices to flat interval arrays.
    pub fn from_json(text: &str) -> Result<TraceSpec> {
        let root = Json::parse(text).map_err(|e| anyhow!("trace json: {e}"))?;
        let t = root
            .get("trace")
            .ok_or_else(|| anyhow!("trace json missing \"trace\" object"))?;
        let clients = match root.get("clients").and_then(|v| v.as_obj()) {
            None => None,
            Some(listing) => {
                let mut out = Vec::with_capacity(listing.len());
                for (key, value) in listing {
                    let id: usize = key
                        .parse()
                        .map_err(|_| anyhow!("\"clients\" key '{key}' is not a client index"))?;
                    let arr = value
                        .as_arr()
                        .ok_or_else(|| anyhow!("client {id}: intervals must be a number array"))?;
                    let flat: Option<Vec<f64>> = arr.iter().map(|v| v.as_f64()).collect();
                    let flat =
                        flat.ok_or_else(|| anyhow!("client {id}: intervals must be numbers"))?;
                    out.push((id, pair_up(id, &flat)?));
                }
                Some(out)
            }
        };
        assemble_spec(
            "trace json",
            |key| t.get(key).and_then(|v| v.as_str()).map(str::to_string),
            |key| t.get(key).and_then(|v| v.as_f64()),
            // JSON has one numeric type; route through i64 so negative
            // seeds wrap identically to the TOML path.
            t.get("seed").and_then(|v| v.as_f64()).map(|f| f as i64),
            clients,
        )
    }

    /// Turn the spec into a concrete trace for a fleet of `clients`
    /// clients whose round deadline is `deadline` simulated seconds
    /// (used only when the spec is in deadline units). Deterministic:
    /// identical inputs yield the bit-identical trace.
    ///
    /// Model sources materialize into the *generated* representation
    /// ([`AvailabilityTrace::generated`]): schedules are re-derived per
    /// query instead of stored, so a churn trace over a million-client
    /// fleet costs O(1) resident memory. Queries are bit-identical to the
    /// dense table [`TraceSpec::materialize_dense`] builds.
    pub fn materialize(&self, clients: usize, deadline: f64) -> Result<AvailabilityTrace> {
        let scale = match self.unit {
            TraceUnit::Seconds => 1.0,
            TraceUnit::Deadlines => deadline,
        };
        let unit_trace = match &self.source {
            TraceSource::Model { model, seed } => AvailabilityTrace::generated(
                *model,
                Rng::new(*seed),
                clients,
                self.horizon,
                self.policy,
            )?,
            TraceSource::Explicit { clients: listed } => {
                // Unlisted clients are always online; listed ids past the
                // fleet are ignored.
                let mut all = vec![vec![(0.0, self.horizon)]; clients];
                for (id, ivs) in listed {
                    if *id < clients {
                        all[*id] = ivs.clone();
                    }
                }
                AvailabilityTrace::from_intervals(all, self.horizon, self.policy)?
            }
        };
        unit_trace.scaled(scale)
    }

    /// [`TraceSpec::materialize`], but forcing the dense (explicit
    /// interval table) representation — O(fleet) memory, identical query
    /// results. Builds the table through [`ChurnModel::generate`] (the
    /// pre-lazy pipeline), so it doubles as the independent differential
    /// baseline the generated representation is gated against.
    pub fn materialize_dense(&self, clients: usize, deadline: f64) -> Result<AvailabilityTrace> {
        let scale = match self.unit {
            TraceUnit::Seconds => 1.0,
            TraceUnit::Deadlines => deadline,
        };
        match &self.source {
            TraceSource::Model { model, seed } => model
                .generate(&Rng::new(*seed), clients, self.horizon, self.policy)?
                .scaled(scale),
            TraceSource::Explicit { .. } => self.materialize(clients, deadline),
        }
    }
}

/// Assemble a spec from format-agnostic parts — shared by the TOML and
/// JSON front-ends so the two formats cannot drift. `str_of` / `f64_of`
/// read scalar keys of the trace table, `seed` is the pre-parsed RNG seed
/// (`None` ⇒ default 1, negatives wrap as two's-complement in both
/// formats), and `clients` is the document's explicit per-client interval
/// listing, if it had one.
fn assemble_spec(
    what: &str,
    str_of: impl Fn(&str) -> Option<String>,
    f64_of: impl Fn(&str) -> Option<f64>,
    seed: Option<i64>,
    clients: Option<Vec<(usize, Vec<(f64, f64)>)>>,
) -> Result<TraceSpec> {
    let kind = str_of("kind").ok_or_else(|| anyhow!("{what} missing `kind`"))?;
    let unit = match str_of("unit") {
        Some(u) => TraceUnit::parse(&u).ok_or_else(|| anyhow!("unknown trace unit '{u}'"))?,
        None => TraceUnit::Deadlines,
    };
    let policy = match str_of("after") {
        Some(p) => {
            EdgePolicy::parse(&p).ok_or_else(|| anyhow!("unknown trace edge policy '{p}'"))?
        }
        None => EdgePolicy::Wrap,
    };
    let seed = seed.unwrap_or(1) as u64;

    let source = if kind.eq_ignore_ascii_case("explicit") {
        let mut clients =
            clients.ok_or_else(|| anyhow!("explicit trace needs a clients listing"))?;
        // Source maps iterate keys lexicographically ("10" < "2"); order
        // by numeric client index so the spec is canonical.
        clients.sort_by_key(|&(id, _)| id);
        TraceSource::Explicit { clients }
    } else {
        let mut model =
            ChurnModel::parse(&kind).ok_or_else(|| anyhow!("unknown trace kind '{kind}'"))?;
        override_params(&mut model, &f64_of);
        TraceSource::Model { model, seed }
    };

    let horizon = match f64_of("horizon") {
        Some(h) => h,
        None if matches!(source, TraceSource::Model { model: ChurnModel::AlwaysOn, .. }) => 1.0,
        None => return Err(anyhow!("{what} missing `horizon`")),
    };

    Ok(TraceSpec { source, horizon, unit, policy })
}

/// Interpret a flat `[on, off, on, off, …]` array as interval pairs.
fn pair_up(id: usize, flat: &[f64]) -> Result<Vec<(f64, f64)>> {
    if flat.len() % 2 != 0 {
        return Err(anyhow!(
            "client {id}: interval list has odd length {} (want [on, off, …] pairs)",
            flat.len()
        ));
    }
    Ok(flat.chunks_exact(2).map(|p| (p[0], p[1])).collect())
}

/// Apply per-parameter overrides from a config source onto a model's
/// defaults (missing keys keep the default).
fn override_params(model: &mut ChurnModel, get: impl Fn(&str) -> Option<f64>) {
    match model {
        ChurnModel::AlwaysOn => {}
        ChurnModel::Periodic { period, duty } => {
            if let Some(v) = get("period") {
                *period = v;
            }
            if let Some(v) = get("duty") {
                *duty = v;
            }
        }
        ChurnModel::Markov { mean_on, mean_off, p_init_online } => {
            if let Some(v) = get("mean_on") {
                *mean_on = v;
            }
            if let Some(v) = get("mean_off") {
                *mean_off = v;
            }
            if let Some(v) = get("p_init_online") {
                *p_init_online = v;
            }
        }
        ChurnModel::HeavyTail { mean_on, min_off, alpha } => {
            if let Some(v) = get("mean_on") {
                *mean_on = v;
            }
            if let Some(v) = get("min_off") {
                *min_off = v;
            }
            if let Some(v) = get("alpha") {
                *alpha = v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MARKOV_TOML: &str = r#"
# markov churn in deadline units
[trace]
kind = "markov"
horizon = 24.0
unit = "deadline"
after = "wrap"
seed = 42
mean_on = 6.0
mean_off = 2.0
p_init_online = 0.75
"#;

    const EXPLICIT_TOML: &str = r#"
[trace]
kind = "explicit"
horizon = 10.0
unit = "seconds"
after = "clamp"

[clients]
0 = [0.0, 6.0, 8.0, 10.0]
2 = [5.0, 10.0]
"#;

    #[test]
    fn toml_markov_roundtrip() {
        let spec = TraceSpec::from_toml(MARKOV_TOML).unwrap();
        assert_eq!(spec.horizon, 24.0);
        assert_eq!(spec.unit, TraceUnit::Deadlines);
        assert_eq!(spec.policy, EdgePolicy::Wrap);
        assert_eq!(
            spec.source,
            TraceSource::Model {
                model: ChurnModel::Markov { mean_on: 6.0, mean_off: 2.0, p_init_online: 0.75 },
                seed: 42
            }
        );
        assert_eq!(spec.label(), "markov");
    }

    #[test]
    fn toml_explicit_roundtrip() {
        let spec = TraceSpec::from_toml(EXPLICIT_TOML).unwrap();
        assert_eq!(spec.unit, TraceUnit::Seconds);
        assert_eq!(spec.policy, EdgePolicy::Clamp);
        let TraceSource::Explicit { clients } = &spec.source else {
            panic!("not explicit")
        };
        assert_eq!(
            clients,
            &vec![
                (0, vec![(0.0, 6.0), (8.0, 10.0)]),
                (2, vec![(5.0, 10.0)]),
            ]
        );
    }

    #[test]
    fn json_mirror_of_toml() {
        let json = r#"{
            "trace": {"kind": "markov", "horizon": 24.0, "unit": "deadline",
                      "after": "wrap", "seed": 42,
                      "mean_on": 6.0, "mean_off": 2.0, "p_init_online": 0.75}
        }"#;
        assert_eq!(TraceSpec::from_json(json).unwrap(), TraceSpec::from_toml(MARKOV_TOML).unwrap());

        let json_explicit = r#"{
            "trace": {"kind": "explicit", "horizon": 10.0, "unit": "seconds", "after": "clamp"},
            "clients": {"0": [0.0, 6.0, 8.0, 10.0], "2": [5.0, 10.0]}
        }"#;
        assert_eq!(
            TraceSpec::from_json(json_explicit).unwrap(),
            TraceSpec::from_toml(EXPLICIT_TOML).unwrap()
        );
    }

    #[test]
    fn seed_parses_identically_across_formats() {
        let toml = "[trace]\nkind = \"markov\"\nhorizon = 8.0\nseed = -1\n";
        let json = r#"{"trace": {"kind": "markov", "horizon": 8.0, "seed": -1}}"#;
        assert_eq!(
            TraceSpec::from_toml(toml).unwrap(),
            TraceSpec::from_json(json).unwrap(),
            "negative seeds must wrap identically in both formats"
        );
        // A float-typed seed is tolerated, not silently replaced by the
        // default.
        let spec =
            TraceSpec::from_toml("[trace]\nkind = \"markov\"\nhorizon = 8.0\nseed = 7.0\n")
                .unwrap();
        match spec.source {
            TraceSource::Model { seed, .. } => assert_eq!(seed, 7),
            other => panic!("unexpected source {other:?}"),
        }
    }

    #[test]
    fn bad_specs_are_errors() {
        assert!(TraceSpec::from_toml("[trace]\nhorizon = 5.0\n").is_err(), "missing kind");
        assert!(TraceSpec::from_toml("[trace]\nkind = \"markov\"\n").is_err(), "missing horizon");
        assert!(TraceSpec::from_toml("[trace]\nkind = \"nope\"\nhorizon = 1.0\n").is_err());
        assert!(
            TraceSpec::from_toml("[trace]\nkind = \"explicit\"\nhorizon = 1.0\n").is_err(),
            "explicit without clients"
        );
        let odd = "[trace]\nkind = \"explicit\"\nhorizon = 1.0\n[clients]\n0 = [0.0, 1.0, 2.0]\n";
        assert!(TraceSpec::from_toml(odd).is_err(), "odd interval list");
        assert!(TraceSpec::from_json("{}").is_err());
    }

    #[test]
    fn always_on_defaults_horizon() {
        let spec = TraceSpec::from_toml("[trace]\nkind = \"always_on\"\n").unwrap();
        assert_eq!(spec.horizon, 1.0);
        let t = spec.materialize(4, 100.0).unwrap();
        assert!(t.is_online(3, 1e6));
    }

    #[test]
    fn materialize_scales_deadline_units() {
        let spec = TraceSpec::from_toml(
            "[trace]\nkind = \"explicit\"\nhorizon = 10.0\nunit = \"deadline\"\n\
             [clients]\n0 = [0.0, 4.0]\n",
        )
        .unwrap();
        let t = spec.materialize(2, 50.0).unwrap();
        assert_eq!(t.horizon(), 500.0);
        assert_eq!(t.intervals(0), &[(0.0, 200.0)]);
        // Unlisted client 1 is online over the whole cycle.
        assert_eq!(t.remaining_online(1, 123.0), f64::INFINITY);
    }

    #[test]
    fn materialize_is_deterministic() {
        let spec = TraceSpec::from_model(ChurnModel::parse("heavy_tail").unwrap(), 16.0, 9);
        let a = spec.materialize(12, 33.0).unwrap();
        let b = spec.materialize(12, 33.0).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn seconds_unit_ignores_deadline() {
        let mut spec = TraceSpec::from_model(ChurnModel::AlwaysOn, 5.0, 0);
        spec.unit = TraceUnit::Seconds;
        let a = spec.materialize(3, 10.0).unwrap();
        let b = spec.materialize(3, 9999.0).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn lazy_materialize_matches_dense_baseline() {
        // Model specs now materialize lazily; every query must agree
        // bit-for-bit with the dense table the pre-lazy pipeline builds.
        for kind in ["always_on", "periodic", "markov", "heavy_tail"] {
            let spec = TraceSpec::from_model(ChurnModel::parse(kind).unwrap(), 16.0, 33);
            let n = 24;
            let lazy = spec.materialize(n, 41.5).unwrap();
            let dense = spec.materialize_dense(n, 41.5).unwrap();
            assert_eq!(lazy.densified(), dense, "{kind}: densified lazy != dense baseline");
            assert_eq!(lazy.horizon().to_bits(), dense.horizon().to_bits());
            for c in 0..n + 2 {
                assert_eq!(lazy.intervals(c), dense.intervals(c), "{kind} client {c}");
                assert_eq!(lazy.uptime(c).to_bits(), dense.uptime(c).to_bits());
                for t in [0.0, 7.25, 16.0 * 41.5 - 1.0, 16.0 * 41.5, 1e6] {
                    assert_eq!(
                        lazy.remaining_online(c, t).to_bits(),
                        dense.remaining_online(c, t).to_bits(),
                        "{kind} client {c} at t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn explicit_specs_stay_dense() {
        let spec = TraceSpec::from_toml(EXPLICIT_TOML).unwrap();
        let t = spec.materialize(4, 10.0).unwrap();
        assert_eq!(t, spec.materialize_dense(4, 10.0).unwrap());
        assert_eq!(t.densified(), t, "explicit traces are already dense");
    }
}
