//! Experiment configuration: paper presets (Table 3 hyper-parameters) and
//! a TOML config-file loader for the CLI / examples.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::agg::{AggPolicy, TreeSpec};
use crate::coreset::Method;
use crate::data::Benchmark;
use crate::exec::OverlapConfig;
use crate::fl::{RunConfig, Strategy};
use crate::scenario::{CorruptionKind, CorruptionSpec, FlanpConfig, SelectPolicy, TraceSpec};
use crate::util::toml::TomlDoc;

/// One experiment = benchmark + FL hyper-parameters + generation scale.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Which benchmark to generate.
    pub benchmark: Benchmark,
    /// The FL run parameters.
    pub run: RunConfig,
    /// FedProx μ (paper Table 3, per benchmark).
    pub prox_mu: f32,
    /// Dataset generation scale: 1.0 = paper Table 1 sizes.
    pub scale: f64,
    /// Dataset generation seed (separate from the FL seed).
    pub data_seed: u64,
}

impl ExperimentConfig {
    /// Paper Table 3 hyper-parameters for `bench` at full paper scale.
    pub fn paper_preset(bench: Benchmark) -> ExperimentConfig {
        let (lr, rounds, k, mu) = match bench {
            Benchmark::Mnist => (0.03, 100, 100, 0.1),
            Benchmark::Shakespeare => (0.03, 30, 10, 0.001),
            Benchmark::Synthetic { .. } => (0.001, 100, 10, 0.1),
        };
        ExperimentConfig {
            benchmark: bench,
            run: RunConfig {
                rounds,
                epochs: 10,
                clients_per_round: k,
                lr,
                ..RunConfig::default()
            },
            prox_mu: mu,
            scale: 1.0,
            data_seed: 7,
        }
    }

    /// CI-tractable preset: same hyper-parameters, scaled-down fleet and
    /// round count (selection stays proportional, sizes keep the power law).
    pub fn scaled_preset(bench: Benchmark, scale: f64) -> ExperimentConfig {
        let mut cfg = Self::paper_preset(bench);
        cfg.scale = scale;
        cfg.run.rounds = ((cfg.run.rounds as f64 * scale).round() as usize).clamp(8, 100);
        cfg.run.clients_per_round =
            ((cfg.run.clients_per_round as f64 * scale).round() as usize).max(4);
        // The synthetic benchmark at paper lr=0.001 needs its 100 rounds to
        // move; at reduced round counts we keep the paper lr but callers can
        // override via TOML/CLI.
        cfg
    }

    /// Set the strategy (builder-style, for sweep loops).
    pub fn with_strategy(mut self, s: Strategy) -> Self {
        self.run.strategy = match s {
            Strategy::FedProx { .. } => Strategy::FedProx { mu: self.prox_mu },
            other => other,
        };
        self
    }

    /// Load from a TOML file (see `configs/*.toml`). Missing keys fall back
    /// to the scaled preset for the configured benchmark.
    pub fn from_file(path: impl AsRef<Path>) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    /// Parse a config document (the file-reading half of
    /// [`ExperimentConfig::from_file`]).
    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("config: {e:?}"))?;
        let bench_name = doc
            .get("experiment", "benchmark")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("config missing [experiment] benchmark"))?;
        let bench = Benchmark::parse(bench_name)
            .ok_or_else(|| anyhow!("unknown benchmark '{bench_name}'"))?;
        let scale = doc.get("experiment", "scale").and_then(|v| v.as_f64()).unwrap_or(1.0);

        let mut cfg = Self::scaled_preset(bench, scale);
        if let Some(v) = doc.get("experiment", "seed").and_then(|v| v.as_i64()) {
            cfg.run.seed = v as u64;
        }
        if let Some(v) = doc.get("experiment", "data_seed").and_then(|v| v.as_i64()) {
            cfg.data_seed = v as u64;
        }
        // Observability sink (write-only — a traced run is bit-identical
        // to an untraced one, determinism rule 7). `obs_health` layers
        // per-client health sampling onto the sink and needs one.
        if let Some(v) = doc.get("experiment", "obs_trace").and_then(|v| v.as_str()) {
            cfg.run.obs =
                crate::obs::ObsConfig::Jsonl { path: v.to_string(), scale, health: None };
        }
        if doc.get("experiment", "obs_health").and_then(|v| v.as_bool()).unwrap_or(false) {
            match &mut cfg.run.obs {
                crate::obs::ObsConfig::Jsonl { health, .. } => {
                    *health = Some(crate::obs::health::HealthConfig::default());
                }
                crate::obs::ObsConfig::Off => {
                    return Err(anyhow!(
                        "[experiment] obs_health = true needs obs_trace to name a sink"
                    ));
                }
            }
        }
        let usize_of = |key: &str| doc.get("fl", key).and_then(|v| v.as_i64()).map(|v| v as usize);
        if let Some(v) = usize_of("rounds") {
            cfg.run.rounds = v;
        }
        if let Some(v) = usize_of("epochs") {
            cfg.run.epochs = v;
        }
        if let Some(v) = usize_of("clients_per_round") {
            cfg.run.clients_per_round = v;
        }
        if let Some(v) = usize_of("eval_every") {
            cfg.run.eval_every = v.max(1);
        }
        if let Some(v) = usize_of("eval_cap") {
            cfg.run.eval_cap = v;
        }
        if let Some(v) = usize_of("workers") {
            cfg.run.workers = v;
        }
        if let Some(v) = doc.get("fl", "dispatch").and_then(|v| v.as_str()) {
            cfg.run.dispatch = crate::exec::DispatchPolicy::parse(v)
                .ok_or_else(|| anyhow!("unknown dispatch policy '{v}'"))?;
        }
        if let Some(v) = doc.get("fl", "lr").and_then(|v| v.as_f64()) {
            cfg.run.lr = v as f32;
        }
        if let Some(v) = doc.get("fl", "straggler_pct").and_then(|v| v.as_f64()) {
            cfg.run.straggler_pct = v;
        }
        if let Some(v) = doc.get("fl", "prox_mu").and_then(|v| v.as_f64()) {
            cfg.prox_mu = v as f32;
        }
        if let Some(v) = doc.get("fl", "strategy").and_then(|v| v.as_str()) {
            cfg.run.strategy = Strategy::parse(v)
                .ok_or_else(|| anyhow!("unknown strategy '{v}'"))?;
            if let Strategy::FedProx { .. } = cfg.run.strategy {
                cfg.run.strategy = Strategy::FedProx { mu: cfg.prox_mu };
            }
        }
        if let Some(v) = doc.get("fl", "coreset_method").and_then(|v| v.as_str()) {
            cfg.run.coreset_method =
                Method::parse(v).ok_or_else(|| anyhow!("unknown coreset method '{v}'"))?;
        }
        if let Some(v) = doc.get("fl", "coreset_mode").and_then(|v| v.as_str()) {
            cfg.run.coreset_mode = match v.to_ascii_lowercase().as_str() {
                "adaptive" => crate::fl::CoresetMode::Adaptive,
                "static" => crate::fl::CoresetMode::Static,
                other => return Err(anyhow!("unknown coreset mode '{other}'")),
            };
        }
        if let Some(v) = usize_of("coreset_refresh") {
            if v == 0 {
                return Err(anyhow!(
                    "[fl] coreset_refresh must be >= 1 (1 = rebuild every round), got 0"
                ));
            }
            cfg.run.coreset_refresh = v;
        }
        // Async round overlap: `overlap = true` (or any of the policy
        // keys) enables the quorum + delayed-gradient pipeline; missing
        // keys keep the OverlapConfig defaults, `overlap = false` forces
        // the synchronous barrier regardless of other keys.
        let overlap_flag = doc.get("fl", "overlap").and_then(|v| v.as_bool());
        let quorum = doc.get("fl", "quorum").and_then(|v| v.as_f64());
        let max_staleness = match doc.get("fl", "max_staleness").and_then(|v| v.as_i64()) {
            Some(v) if v < 0 => {
                return Err(anyhow!("[fl] max_staleness must be >= 0, got {v}"))
            }
            other => other.map(|v| v as usize),
        };
        let alpha = doc.get("fl", "alpha").and_then(|v| v.as_f64());
        let any_policy_key = quorum.is_some() || max_staleness.is_some() || alpha.is_some();
        if overlap_flag == Some(true) || (overlap_flag.is_none() && any_policy_key) {
            let mut ov = OverlapConfig::default();
            if let Some(v) = quorum {
                ov.quorum = v;
            }
            if let Some(v) = max_staleness {
                ov.max_staleness = v;
            }
            if let Some(v) = alpha {
                ov.alpha = v;
            }
            ov.validate().map_err(|e| anyhow!("[fl] overlap: {e}"))?;
            cfg.run.overlap = Some(ov);
        }
        // Server aggregation policy: `agg = "..."` selects, the knob keys
        // parameterize; a knob key alone implies its policy (mirroring
        // the overlap section's semantics).
        let agg_name = doc.get("fl", "agg").and_then(|v| v.as_str());
        let momentum = doc.get("fl", "server_momentum").and_then(|v| v.as_f64());
        let buffer_k = usize_of("buffer_k");
        let trim_frac = doc.get("fl", "trim_frac").and_then(|v| v.as_f64());
        let implied = match (agg_name, momentum.or_else(|| buffer_k.map(|k| k as f64)), trim_frac)
        {
            (Some(name), _, _) => Some(
                AggPolicy::parse(name)
                    .ok_or_else(|| anyhow!("unknown aggregation policy '{name}'"))?,
            ),
            (None, Some(_), _) => Some(AggPolicy::Buffered { k: 0, momentum: 0.0 }),
            (None, None, Some(_)) => Some(AggPolicy::TrimmedMean { trim_frac: 0.1 }),
            (None, None, None) => None,
        };
        if let Some(mut pol) = implied {
            match &mut pol {
                AggPolicy::Buffered { k, momentum: m } => {
                    if let Some(v) = buffer_k {
                        *k = v;
                    }
                    if let Some(v) = momentum {
                        *m = v;
                    }
                }
                AggPolicy::TrimmedMean { trim_frac: t } => {
                    if let Some(v) = trim_frac {
                        *t = v;
                    }
                }
                AggPolicy::Mean | AggPolicy::CoordinateMedian => {}
            }
            // A knob aimed at a different policy is a config bug, not a
            // silent no-op (e.g. agg = "mean" with trim_frac set).
            if (momentum.is_some() || buffer_k.is_some())
                && !matches!(pol, AggPolicy::Buffered { .. })
            {
                return Err(anyhow!(
                    "[fl] server_momentum/buffer_k only apply to agg = \"buffered\", got \"{}\"",
                    pol.label()
                ));
            }
            if trim_frac.is_some() && !matches!(pol, AggPolicy::TrimmedMean { .. }) {
                return Err(anyhow!(
                    "[fl] trim_frac only applies to agg = \"trimmed_mean\", got \"{}\"",
                    pol.label()
                ));
            }
            pol.validate().map_err(|e| anyhow!("[fl] aggregation: {e}"))?;
            cfg.run.aggregator = pol;
        }
        if let Some(v) = doc.get("fl", "clip_norm").and_then(|v| v.as_f64()) {
            if !(v > 0.0) {
                return Err(anyhow!("[fl] clip_norm must be positive, got {v}"));
            }
            cfg.run.clip_norm = Some(v);
        }
        // Hierarchical aggregation: `agg_tree = <fanout>` replaces the flat
        // seam with a two-tier tree whose edge tier runs the `agg` policy
        // and whose root runs `agg_root` (default mean). An `agg_root` key
        // without `agg_tree` is a config bug, not a silent no-op.
        let tree_fanout = usize_of("agg_tree");
        let tree_root = doc.get("fl", "agg_root").and_then(|v| v.as_str());
        match (tree_fanout, tree_root) {
            (Some(fanout), root) => {
                let root = match root {
                    Some(name) => AggPolicy::parse(name)
                        .ok_or_else(|| anyhow!("unknown aggregation policy '{name}'"))?,
                    None => AggPolicy::Mean,
                };
                let spec = TreeSpec { fanout, edge: cfg.run.aggregator, root };
                spec.validate().map_err(|e| anyhow!("[fl] aggregation tree: {e}"))?;
                cfg.run.agg_tree = Some(spec);
            }
            (None, Some(_)) => {
                return Err(anyhow!("[fl] agg_root only applies when agg_tree is set"));
            }
            (None, None) => {}
        }
        if let Some(v) = doc.get("fl", "adaptive_quorum").and_then(|v| v.as_bool()) {
            cfg.run.adaptive_quorum = v;
        }
        if let Some(v) = doc.get("fl", "flaky_boost").and_then(|v| v.as_f64()) {
            if !(v >= 0.0 && v.is_finite()) {
                return Err(anyhow!("[fl] flaky_boost must be finite and >= 0, got {v}"));
            }
            cfg.run.flaky_boost = v;
        }
        // Cohort-selection policy: `select = "..."` picks, the knob keys
        // parameterize; a knob key alone implies its policy, and a knob
        // aimed at a different policy is a config bug (mirroring the
        // overlap/agg sections' semantics).
        let select_name = doc.get("fl", "select").and_then(|v| v.as_str());
        let flanp_start = usize_of("flanp_start");
        let flanp_factor = doc.get("fl", "flanp_factor").and_then(|v| v.as_f64());
        let flanp_threshold = doc.get("fl", "flanp_threshold").and_then(|v| v.as_f64());
        let forecast_bias = doc.get("fl", "forecast_bias").and_then(|v| v.as_f64());
        let any_flanp_key =
            flanp_start.is_some() || flanp_factor.is_some() || flanp_threshold.is_some();
        let implied_select = match (select_name, any_flanp_key, forecast_bias) {
            (Some(name), _, _) => Some(
                SelectPolicy::parse(name)
                    .ok_or_else(|| anyhow!("unknown selection policy '{name}'"))?,
            ),
            (None, true, _) => Some(SelectPolicy::Flanp(FlanpConfig::default())),
            (None, false, Some(_)) => Some(SelectPolicy::Forecast { bias: 1.0 }),
            (None, false, None) => None,
        };
        if let Some(mut pol) = implied_select {
            match &mut pol {
                SelectPolicy::Flanp(fc) => {
                    if let Some(v) = flanp_start {
                        fc.start = v;
                    }
                    if let Some(v) = flanp_factor {
                        fc.factor = v;
                    }
                    if let Some(v) = flanp_threshold {
                        fc.threshold = v;
                    }
                }
                SelectPolicy::Forecast { bias } => {
                    if let Some(v) = forecast_bias {
                        *bias = v;
                    }
                }
                SelectPolicy::Baseline => {}
            }
            if any_flanp_key && !matches!(pol, SelectPolicy::Flanp(_)) {
                return Err(anyhow!(
                    "[fl] flanp_start/flanp_factor/flanp_threshold only apply to select = \"flanp\", got \"{}\"",
                    pol.label()
                ));
            }
            if forecast_bias.is_some() && !matches!(pol, SelectPolicy::Forecast { .. }) {
                return Err(anyhow!(
                    "[fl] forecast_bias only applies to select = \"forecast\", got \"{}\"",
                    pol.label()
                ));
            }
            pol.validate().map_err(|e| anyhow!("[fl] selection: {e}"))?;
            cfg.run.select = pol;
        }
        // Straggler distillation composes with any selection policy but
        // needs the overlapped pipeline (the engine enforces that once
        // flags/env have had their say on `overlap`).
        if let Some(v) = doc.get("fl", "distill_weight").and_then(|v| v.as_f64()) {
            if !(v >= 0.0 && v.is_finite()) {
                return Err(anyhow!("[fl] distill_weight must be finite and >= 0, got {v}"));
            }
            cfg.run.distill_weight = v;
        }
        // [scenario]: trace-driven client availability — either a pointer
        // to a trace file (`trace = "examples/traces/markov_churn.toml"`)
        // or an inline spec with the same keys as a trace file's [trace]
        // section (explicit intervals then come from a sibling [clients])
        // — and/or a corrupted-update knob (`corrupt = "noise" |
        // "sign_flip"` with `corrupt_frac` / `corrupt_sigma` /
        // `corrupt_scale` / `corrupt_seed`).
        if doc.sections.contains_key("scenario") {
            let has_trace = doc.get("scenario", "trace").is_some()
                || doc.get("scenario", "kind").is_some();
            if has_trace {
                let spec = match doc.get("scenario", "trace").and_then(|v| v.as_str()) {
                    Some(path) => TraceSpec::from_file(path)?,
                    None => TraceSpec::from_toml_doc(&doc, "scenario")?,
                };
                cfg.run.trace = Some(spec);
            }
            if let Some(kind) = doc.get("scenario", "corrupt").and_then(|v| v.as_str()) {
                let mut kind = CorruptionKind::parse(kind)
                    .ok_or_else(|| anyhow!("unknown corruption kind '{kind}'"))?;
                match &mut kind {
                    CorruptionKind::Noise { sigma } => {
                        if let Some(v) =
                            doc.get("scenario", "corrupt_sigma").and_then(|v| v.as_f64())
                        {
                            *sigma = v;
                        }
                    }
                    CorruptionKind::SignFlip { scale } => {
                        if let Some(v) =
                            doc.get("scenario", "corrupt_scale").and_then(|v| v.as_f64())
                        {
                            *scale = v;
                        }
                    }
                }
                let mut spec = CorruptionSpec::new(kind, 0.1);
                if let Some(v) = doc.get("scenario", "corrupt_frac").and_then(|v| v.as_f64()) {
                    spec.fraction = v;
                }
                if let Some(v) = doc.get("scenario", "corrupt_seed").and_then(|v| v.as_i64()) {
                    spec.seed = v as u64;
                }
                spec.validate().map_err(|e| anyhow!("[scenario] corruption: {e}"))?;
                cfg.run.corruption = Some(spec);
            } else {
                // Corruption knobs without the `corrupt` kind are a
                // config bug, not a silent no-op.
                for key in ["corrupt_frac", "corrupt_sigma", "corrupt_scale", "corrupt_seed"] {
                    if doc.get("scenario", key).is_some() {
                        return Err(anyhow!(
                            "[scenario] {key} set but `corrupt` (noise | sign_flip) is missing"
                        ));
                    }
                }
                if !has_trace {
                    return Err(anyhow!(
                        "[scenario] section needs a trace (`trace`/`kind`) or a `corrupt` knob"
                    ));
                }
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_presets_match_table3() {
        let m = ExperimentConfig::paper_preset(Benchmark::Mnist);
        assert_eq!(m.run.rounds, 100);
        assert_eq!(m.run.clients_per_round, 100);
        assert_eq!(m.run.epochs, 10);
        assert!((m.run.lr - 0.03).abs() < 1e-9);
        assert!((m.prox_mu - 0.1).abs() < 1e-9);

        let s = ExperimentConfig::paper_preset(Benchmark::Shakespeare);
        assert_eq!(s.run.rounds, 30);
        assert_eq!(s.run.clients_per_round, 10);
        assert!((s.prox_mu - 0.001).abs() < 1e-9);

        let y = ExperimentConfig::paper_preset(Benchmark::Synthetic { alpha: 1.0, beta: 1.0 });
        assert_eq!(y.run.rounds, 100);
        assert!((y.run.lr - 0.001).abs() < 1e-9);
    }

    #[test]
    fn scaled_preset_shrinks_but_keeps_lr() {
        let c = ExperimentConfig::scaled_preset(Benchmark::Mnist, 0.2);
        assert_eq!(c.run.rounds, 20);
        assert_eq!(c.run.clients_per_round, 20);
        assert!((c.run.lr - 0.03).abs() < 1e-9);
    }

    #[test]
    fn toml_roundtrip_with_overrides() {
        let text = r#"
[experiment]
benchmark = "synthetic(0.5,0.5)"
scale = 0.3
seed = 42

[fl]
rounds = 12
strategy = "fedprox"
prox_mu = 0.05
lr = 0.01
straggler_pct = 10.0
coreset_method = "pam"
coreset_refresh = 4
workers = 3
dispatch = "work_stealing"
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.benchmark, Benchmark::Synthetic { alpha: 0.5, beta: 0.5 });
        assert_eq!(cfg.run.rounds, 12);
        assert_eq!(cfg.run.seed, 42);
        assert_eq!(cfg.run.strategy, Strategy::FedProx { mu: 0.05 });
        assert!((cfg.run.lr - 0.01).abs() < 1e-9);
        assert_eq!(cfg.run.straggler_pct, 10.0);
        assert_eq!(cfg.run.coreset_method, Method::Pam);
        assert_eq!(cfg.run.coreset_refresh, 4);
        assert_eq!(cfg.run.workers, 3);
        assert_eq!(cfg.run.dispatch, crate::exec::DispatchPolicy::WorkStealing);
    }

    #[test]
    fn coreset_refresh_defaults_and_rejects_zero() {
        let plain = ExperimentConfig::from_toml("[experiment]\nbenchmark = \"mnist\"\n").unwrap();
        assert_eq!(plain.run.coreset_refresh, 1, "default must rebuild every round");
        let zero = "[experiment]\nbenchmark = \"mnist\"\n[fl]\ncoreset_refresh = 0\n";
        assert!(ExperimentConfig::from_toml(zero).is_err());
    }

    #[test]
    fn dispatch_key_defaults_and_rejects_unknowns() {
        use crate::exec::DispatchPolicy;
        let plain = ExperimentConfig::from_toml("[experiment]\nbenchmark = \"mnist\"\n").unwrap();
        assert_eq!(plain.run.dispatch, DispatchPolicy::RoundRobin);
        let rr = "[experiment]\nbenchmark = \"mnist\"\n[fl]\ndispatch = \"rr\"\n";
        assert_eq!(
            ExperimentConfig::from_toml(rr).unwrap().run.dispatch,
            DispatchPolicy::RoundRobin
        );
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\ndispatch = \"lifo\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
    }

    #[test]
    fn obs_trace_key_selects_jsonl_sink() {
        use crate::obs::ObsConfig;
        let plain = ExperimentConfig::from_toml("[experiment]\nbenchmark = \"mnist\"\n").unwrap();
        assert_eq!(plain.run.obs, ObsConfig::Off);
        let text = "[experiment]\nbenchmark = \"mnist\"\nscale = 0.25\n\
                    obs_trace = \"run.jsonl\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.run.obs,
            ObsConfig::Jsonl { path: "run.jsonl".into(), scale: 0.25, health: None }
        );

        // obs_health layers health sampling onto the sink...
        let healthy = format!("{text}obs_health = true\n");
        let cfg = ExperimentConfig::from_toml(&healthy).unwrap();
        assert_eq!(
            cfg.run.obs.health(),
            Some(&crate::obs::health::HealthConfig::default())
        );
        // ...and is rejected without one.
        let orphan = "[experiment]\nbenchmark = \"mnist\"\nobs_health = true\n";
        assert!(ExperimentConfig::from_toml(orphan).is_err());
        // `obs_health = false` with no sink stays Off without erroring.
        let off = "[experiment]\nbenchmark = \"mnist\"\nobs_health = false\n";
        assert_eq!(ExperimentConfig::from_toml(off).unwrap().run.obs, ObsConfig::Off);
    }

    #[test]
    fn scenario_section_inline_model() {
        use crate::scenario::{ChurnModel, TraceSource};
        let text = "[experiment]\nbenchmark = \"mnist\"\n\
                    [scenario]\nkind = \"periodic\"\nhorizon = 12.0\nduty = 0.5\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let spec = cfg.run.trace.expect("scenario parsed");
        assert_eq!(spec.horizon, 12.0);
        match spec.source {
            TraceSource::Model { model: ChurnModel::Periodic { duty, .. }, .. } => {
                assert_eq!(duty, 0.5);
            }
            other => panic!("unexpected source {other:?}"),
        }
    }

    #[test]
    fn scenario_section_from_trace_file() {
        let trace_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../examples/traces/markov_churn.toml");
        let text = format!(
            "[experiment]\nbenchmark = \"mnist\"\n[scenario]\ntrace = \"{}\"\n",
            trace_path.display()
        );
        let cfg = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(cfg.run.trace.expect("trace loaded").label(), "markov");
    }

    #[test]
    fn no_scenario_section_means_no_trace() {
        let cfg = ExperimentConfig::from_toml("[experiment]\nbenchmark = \"mnist\"\n").unwrap();
        assert!(cfg.run.trace.is_none());
    }

    #[test]
    fn overlap_section_roundtrip() {
        let text = "[experiment]\nbenchmark = \"mnist\"\n\
                    [fl]\noverlap = true\nquorum = 0.6\nmax_staleness = 3\nalpha = 2.0\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let ov = cfg.run.overlap.expect("overlap parsed");
        assert_eq!(ov.quorum, 0.6);
        assert_eq!(ov.max_staleness, 3);
        assert_eq!(ov.alpha, 2.0);

        // Policy keys alone enable overlap (no explicit flag needed)…
        let implied = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nquorum = 0.5\n";
        let cfg = ExperimentConfig::from_toml(implied).unwrap();
        let ov = cfg.run.overlap.expect("policy key implies overlap");
        assert_eq!(ov.quorum, 0.5);
        assert_eq!(ov.max_staleness, OverlapConfig::default().max_staleness);

        // …while `overlap = false` forces synchronous regardless.
        let off = "[experiment]\nbenchmark = \"mnist\"\n[fl]\noverlap = false\nquorum = 0.5\n";
        assert!(ExperimentConfig::from_toml(off).unwrap().run.overlap.is_none());

        // No overlap keys ⇒ classic synchronous engine.
        let plain = ExperimentConfig::from_toml("[experiment]\nbenchmark = \"mnist\"\n").unwrap();
        assert!(plain.run.overlap.is_none());

        // Invalid policy values are hard errors, not silent defaults.
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nquorum = 1.5\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        let negative = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nmax_staleness = -3\n";
        assert!(ExperimentConfig::from_toml(negative).is_err());
    }

    #[test]
    fn agg_section_roundtrip() {
        let text = "[experiment]\nbenchmark = \"mnist\"\n\
                    [fl]\nagg = \"buffered\"\nbuffer_k = 5\nserver_momentum = 0.3\n\
                    clip_norm = 2.5\nadaptive_quorum = true\nflaky_boost = 1.5\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.run.aggregator, AggPolicy::Buffered { k: 5, momentum: 0.3 });
        assert_eq!(cfg.run.clip_norm, Some(2.5));
        assert!(cfg.run.adaptive_quorum);
        assert_eq!(cfg.run.flaky_boost, 1.5);

        // Knob keys alone imply their policy (like the overlap keys)…
        let implied = "[experiment]\nbenchmark = \"mnist\"\n[fl]\ntrim_frac = 0.2\n";
        let cfg = ExperimentConfig::from_toml(implied).unwrap();
        assert_eq!(cfg.run.aggregator, AggPolicy::TrimmedMean { trim_frac: 0.2 });
        let implied = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nserver_momentum = 0.5\n";
        let cfg = ExperimentConfig::from_toml(implied).unwrap();
        assert_eq!(cfg.run.aggregator, AggPolicy::Buffered { k: 0, momentum: 0.5 });

        // …no keys ⇒ the classic mean, no clipping.
        let plain = ExperimentConfig::from_toml("[experiment]\nbenchmark = \"mnist\"\n").unwrap();
        assert_eq!(plain.run.aggregator, AggPolicy::Mean);
        assert!(plain.run.clip_norm.is_none());
        assert!(!plain.run.adaptive_quorum);
        assert_eq!(plain.run.flaky_boost, 0.0);

        // Invalid values are hard errors.
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nagg = \"nope\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\ntrim_frac = 0.6\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nclip_norm = -1.0\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        // Knobs aimed at a different policy are hard errors too, not
        // silent no-ops.
        let mismatch =
            "[experiment]\nbenchmark = \"mnist\"\n[fl]\nagg = \"mean\"\ntrim_frac = 0.2\n";
        assert!(ExperimentConfig::from_toml(mismatch).is_err());
        let mismatch = "[experiment]\nbenchmark = \"mnist\"\n\
                        [fl]\nagg = \"trimmed_mean\"\nserver_momentum = 0.5\n";
        assert!(ExperimentConfig::from_toml(mismatch).is_err());
        let ambiguous = "[experiment]\nbenchmark = \"mnist\"\n\
                         [fl]\nserver_momentum = 0.5\ntrim_frac = 0.2\n";
        assert!(ExperimentConfig::from_toml(ambiguous).is_err());
    }

    #[test]
    fn agg_tree_section_roundtrip() {
        // `agg_tree` alone: edge = the (default) mean policy, root = mean.
        let text = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nagg_tree = 8\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.run.agg_tree, Some(TreeSpec::mean(8)));

        // Edge tier follows `agg`, root follows `agg_root`.
        let text = "[experiment]\nbenchmark = \"mnist\"\n\
                    [fl]\nagg = \"median\"\nagg_tree = 4\nagg_root = \"trimmed_mean\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.run.agg_tree,
            Some(TreeSpec {
                fanout: 4,
                edge: AggPolicy::CoordinateMedian,
                root: AggPolicy::TrimmedMean { trim_frac: 0.1 },
            })
        );

        // No tree keys ⇒ the flat seam.
        let plain = ExperimentConfig::from_toml("[experiment]\nbenchmark = \"mnist\"\n").unwrap();
        assert!(plain.run.agg_tree.is_none());

        // Hard errors: zero fanout, buffered edges, orphaned agg_root,
        // unknown root policy.
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nagg_tree = 0\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        let bad = "[experiment]\nbenchmark = \"mnist\"\n\
                   [fl]\nagg = \"buffered\"\nagg_tree = 4\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nagg_root = \"mean\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        let bad = "[experiment]\nbenchmark = \"mnist\"\n\
                   [fl]\nagg_tree = 4\nagg_root = \"nope\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
    }

    #[test]
    fn select_section_roundtrip() {
        use crate::scenario::{FlanpConfig, SelectPolicy};
        let text = "[experiment]\nbenchmark = \"mnist\"\n\
                    [fl]\nselect = \"flanp\"\nflanp_start = 4\nflanp_factor = 3.0\n\
                    flanp_threshold = 0.05\noverlap = true\ndistill_weight = 0.5\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.run.select,
            SelectPolicy::Flanp(FlanpConfig { start: 4, factor: 3.0, threshold: 0.05 })
        );
        assert_eq!(cfg.run.distill_weight, 0.5);

        // Knob keys alone imply their policy (like the overlap/agg keys)…
        let implied = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nflanp_start = 16\n";
        let cfg = ExperimentConfig::from_toml(implied).unwrap();
        assert_eq!(
            cfg.run.select,
            SelectPolicy::Flanp(FlanpConfig { start: 16, ..Default::default() })
        );
        let implied = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nforecast_bias = 0.5\n";
        let cfg = ExperimentConfig::from_toml(implied).unwrap();
        assert_eq!(cfg.run.select, SelectPolicy::Forecast { bias: 0.5 });

        // …no keys ⇒ the baseline sampler, distillation off.
        let plain = ExperimentConfig::from_toml("[experiment]\nbenchmark = \"mnist\"\n").unwrap();
        assert_eq!(plain.run.select, SelectPolicy::Baseline);
        assert_eq!(plain.run.distill_weight, 0.0);

        // Invalid values are hard errors.
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nselect = \"nope\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nflanp_factor = 1.0\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        let bad = "[experiment]\nbenchmark = \"mnist\"\n[fl]\ndistill_weight = -0.5\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        // Knobs aimed at a different policy are hard errors, not silent
        // no-ops.
        let mismatch = "[experiment]\nbenchmark = \"mnist\"\n\
                        [fl]\nselect = \"baseline\"\nflanp_start = 4\n";
        assert!(ExperimentConfig::from_toml(mismatch).is_err());
        let mismatch = "[experiment]\nbenchmark = \"mnist\"\n\
                        [fl]\nselect = \"flanp\"\nforecast_bias = 0.5\n";
        assert!(ExperimentConfig::from_toml(mismatch).is_err());
        let ambiguous = "[experiment]\nbenchmark = \"mnist\"\n\
                         [fl]\nflanp_start = 4\nforecast_bias = 0.5\n";
        assert!(ExperimentConfig::from_toml(ambiguous).is_err());
    }

    #[test]
    fn scenario_corruption_knob() {
        use crate::scenario::CorruptionKind;
        let text = "[experiment]\nbenchmark = \"mnist\"\n\
                    [scenario]\ncorrupt = \"sign_flip\"\ncorrupt_frac = 0.25\ncorrupt_seed = 9\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let spec = cfg.run.corruption.expect("corruption parsed");
        assert_eq!(spec.kind, CorruptionKind::SignFlip { scale: 1.0 });
        assert_eq!(spec.fraction, 0.25);
        assert_eq!(spec.seed, 9);
        assert!(cfg.run.trace.is_none(), "corruption-only section must not imply a trace");

        // Corruption composes with an inline trace in the same section.
        let both = "[experiment]\nbenchmark = \"mnist\"\n\
                    [scenario]\nkind = \"periodic\"\nhorizon = 12.0\n\
                    corrupt = \"noise\"\ncorrupt_sigma = 0.5\n";
        let cfg = ExperimentConfig::from_toml(both).unwrap();
        assert!(cfg.run.trace.is_some());
        assert_eq!(
            cfg.run.corruption.unwrap().kind,
            CorruptionKind::Noise { sigma: 0.5 }
        );

        // An empty scenario section is a configuration bug, not a no-op.
        let empty = "[experiment]\nbenchmark = \"mnist\"\n[scenario]\nx = 1\n";
        assert!(ExperimentConfig::from_toml(empty).is_err());
        // Bad corruption values are hard errors.
        let bad = "[experiment]\nbenchmark = \"mnist\"\n\
                   [scenario]\ncorrupt = \"noise\"\ncorrupt_frac = 1.5\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        // Corruption knobs without the `corrupt` kind are hard errors.
        let orphan = "[experiment]\nbenchmark = \"mnist\"\n\
                      [scenario]\nkind = \"periodic\"\nhorizon = 12.0\ncorrupt_frac = 0.3\n";
        assert!(ExperimentConfig::from_toml(orphan).is_err());
    }

    #[test]
    fn bad_configs_are_errors() {
        assert!(ExperimentConfig::from_toml("[experiment]\nbenchmark = \"nope\"\n").is_err());
        assert!(ExperimentConfig::from_toml("[fl]\nrounds = 3\n").is_err());
        let bad_strategy = "[experiment]\nbenchmark = \"mnist\"\n[fl]\nstrategy = \"sgd\"\n";
        assert!(ExperimentConfig::from_toml(bad_strategy).is_err());
    }

    #[test]
    fn with_strategy_injects_prox_mu() {
        let cfg = ExperimentConfig::paper_preset(Benchmark::Mnist)
            .with_strategy(Strategy::FedProx { mu: 999.0 });
        assert_eq!(cfg.run.strategy, Strategy::FedProx { mu: 0.1 });
    }
}
