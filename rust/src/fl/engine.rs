//! The FL round engine — paper Algorithm 1.
//!
//! Per round: sample K clients with probability ∝ mᵢ (Assumption A.6),
//! broadcast the global model, execute each client's [`LocalPlan`],
//! aggregate the round-end parameters wᵣ₊₁ = (1/K) Σ wᵢ, and record
//! loss/accuracy/timing into a [`RunResult`].

use anyhow::{anyhow, Result};

use super::client::{run_client, ClientOutcome};
use super::plan::Strategy;
use crate::coreset::Method;
use crate::data::FedDataset;
use crate::metrics::{RoundRecord, RunResult};
use crate::runtime::{EvalOutput, ModelInfo, Runtime};
use crate::sim::{clock::RoundTiming, Fleet, SimClock};
use crate::util::rng::Rng;

/// When FedCore (re)builds coresets (paper §4.3/§4.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoresetMode {
    /// The paper's default: new gradient-space coreset every round, from
    /// the round's first-epoch per-sample gradients (answers Q1).
    Adaptive,
    /// The convex-model shortcut: one input-space (d̃) coreset per client,
    /// built once and reused — zero per-round construction cost.
    Static,
}

/// Everything one experiment run needs (strategy × benchmark × straggler%).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub strategy: Strategy,
    /// R — communication rounds.
    pub rounds: usize,
    /// E — local epochs per round (paper Table 3: 10).
    pub epochs: usize,
    /// K — clients sampled per round.
    pub clients_per_round: usize,
    /// SGD learning rate (paper Table 3 per benchmark).
    pub lr: f32,
    /// s — straggler percentage (10 or 30 in the paper).
    pub straggler_pct: f64,
    /// Root seed; every random decision in the run derives from it.
    pub seed: u64,
    /// k-medoids solver for FedCore.
    pub coreset_method: Method,
    /// Adaptive (per-round, gradient-space) vs static (once, input-space).
    pub coreset_mode: CoresetMode,
    /// Evaluate the global model every this many rounds (1 = each round).
    pub eval_every: usize,
    /// Cap on test samples per evaluation (0 = use the full test set).
    pub eval_cap: usize,
    /// Print a progress line per round.
    pub verbose: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            strategy: Strategy::FedCore,
            rounds: 30,
            epochs: 10,
            clients_per_round: 10,
            lr: 0.03,
            straggler_pct: 30.0,
            seed: 7,
            coreset_method: Method::FasterPam,
            coreset_mode: CoresetMode::Adaptive,
            eval_every: 1,
            eval_cap: 512,
            verbose: false,
        }
    }
}

/// FedAvg aggregation (Algorithm 1 line 15): wᵣ₊₁ = (1/K) Σ wᵢ, computed
/// in f64 for order-independence up to f32 rounding. Returns None when no
/// client contributed (all dropped — the server keeps the old model).
pub fn aggregate(locals: &[&[f32]]) -> Option<Vec<f32>> {
    let first = locals.first()?;
    let mut acc = vec![0.0f64; first.len()];
    for l in locals {
        assert_eq!(l.len(), acc.len(), "parameter dimension mismatch");
        for (a, &p) in acc.iter_mut().zip(*l) {
            *a += p as f64;
        }
    }
    let k = locals.len() as f64;
    Some(acc.into_iter().map(|a| (a / k) as f32).collect())
}

/// The engine: owns the fleet simulation, borrows runtime + data.
pub struct Engine<'a> {
    rt: &'a Runtime,
    data: &'a FedDataset,
    model: ModelInfo,
    pub fleet: Fleet,
    cfg: RunConfig,
    /// §4.3 static-coreset cache (client → coreset); budgets are constant
    /// per client, so a static coreset never needs rebuilding.
    static_cache: std::cell::RefCell<std::collections::HashMap<usize, crate::coreset::Coreset>>,
}

impl<'a> Engine<'a> {
    pub fn new(rt: &'a Runtime, data: &'a FedDataset, cfg: RunConfig) -> Result<Engine<'a>> {
        if data.num_clients() == 0 {
            return Err(anyhow!("dataset has no clients"));
        }
        let model = rt.manifest().model(&data.model)?.clone();
        let mut fleet_rng = Rng::new(cfg.seed).split(0xF1EE7);
        let fleet = Fleet::new(&mut fleet_rng, data.sizes(), cfg.epochs, cfg.straggler_pct);
        Ok(Engine {
            rt,
            data,
            model,
            fleet,
            cfg,
            static_cache: std::cell::RefCell::new(std::collections::HashMap::new()),
        })
    }

    /// Fetch-or-build the §4.3 static coreset for client `i` at `budget`.
    fn static_coreset(&self, i: usize, budget: usize) -> crate::coreset::Coreset {
        if let Some(c) = self.static_cache.borrow().get(&i) {
            return c.clone();
        }
        let mut rng = Rng::new(self.cfg.seed).split(0x57A7 ^ i as u64);
        let cs = super::client::build_static_coreset(
            &self.data.clients[i],
            self.rt.manifest().vocab.len(),
            budget,
            self.cfg.coreset_method,
            &mut rng,
        );
        self.static_cache.borrow_mut().insert(i, cs.clone());
        cs
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn model(&self) -> &ModelInfo {
        &self.model
    }

    /// Evaluate `params` on the global test set (masked, batched).
    pub fn evaluate(&self, params: &[f32]) -> Result<EvalOutput> {
        let f = self.rt.manifest().feat_batch;
        let test = &self.data.test;
        let n = if self.cfg.eval_cap > 0 {
            test.len().min(self.cfg.eval_cap)
        } else {
            test.len()
        };
        let mut total = EvalOutput::default();
        let idxs: Vec<usize> = (0..n).collect();
        let mut start = 0usize;
        while start < n {
            let end = (start + f).min(n);
            let chunk = &idxs[start..end];
            let (x, y, mask) = test.gather_batch(chunk, None, f);
            total.merge(self.rt.evaluate(&self.model, params, &x, &y, &mask)?);
            start = end;
        }
        Ok(total)
    }

    /// Run the full experiment from the model's deterministic w₀.
    pub fn run(&self) -> Result<RunResult> {
        self.run_from(self.model.init_params.clone())
    }

    /// Run from an arbitrary starting point (checkpoint resume).
    pub fn run_from(&self, init_params: Vec<f32>) -> Result<RunResult> {
        if init_params.len() != self.model.param_size {
            return Err(anyhow!(
                "initial params have {} values, model '{}' wants {}",
                init_params.len(),
                self.model.name,
                self.model.param_size
            ));
        }
        let cfg = &self.cfg;
        let weights = self.data.client_weights();
        let mut select_rng = Rng::new(cfg.seed).split(0x5E1EC7);
        let client_root = Rng::new(cfg.seed).split(0xC11E47);
        let mut clock = SimClock::new(self.fleet.deadline);

        let mut params = init_params;
        let mut rounds: Vec<RoundRecord> = Vec::with_capacity(cfg.rounds);

        for r in 0..cfg.rounds {
            // --- Algorithm 1 line 3: sample K clients, p ∝ mᵢ ---
            let selected =
                select_rng.weighted_with_replacement(&weights, cfg.clients_per_round);

            // --- lines 5–13: local work ---
            let mut outcomes: Vec<(usize, ClientOutcome)> = Vec::with_capacity(selected.len());
            for &i in &selected {
                let plan = cfg.strategy.plan(&self.fleet, i);
                let mut crng = client_root.split((r as u64) << 20 | i as u64);
                // §4.3 static mode: serve coresets from the per-client cache.
                let static_cs = match (&plan, cfg.coreset_mode) {
                    (super::plan::LocalPlan::Coreset { budget, .. }, CoresetMode::Static) => {
                        Some(self.static_coreset(i, *budget))
                    }
                    _ => None,
                };
                let outcome = run_client(
                    self.rt,
                    &self.model,
                    &self.data.clients[i],
                    &self.fleet,
                    i,
                    &params,
                    &plan,
                    cfg.lr,
                    cfg.strategy.mu(),
                    cfg.coreset_method,
                    static_cs.as_ref(),
                    &mut crng,
                )?;
                outcomes.push((i, outcome));
            }

            // --- line 15: aggregate contributing clients ---
            let contributing: Vec<&ClientOutcome> =
                outcomes.iter().map(|(_, o)| o).filter(|o| o.params.is_some()).collect();
            let dropped = outcomes.len() - contributing.len();
            let locals: Vec<&[f32]> = contributing
                .iter()
                .map(|o| o.params.as_deref().unwrap())
                .collect();
            if let Some(new_params) = aggregate(&locals) {
                params = new_params;
            }

            // --- timing: round ends when the slowest participant finishes;
            //     an all-dropped round still costs the server the full τ ---
            let client_times: Vec<f64> =
                contributing.iter().map(|o| o.sim_time).collect();
            let timing = if client_times.is_empty() {
                RoundTiming { client_times: vec![], round_time: self.fleet.deadline }
            } else {
                RoundTiming::from_clients(client_times)
            };
            let sim_time = timing.round_time;
            clock.push_round(timing.clone());

            // --- metrics ---
            let losses: Vec<f64> = contributing
                .iter()
                .map(|o| o.train_loss)
                .filter(|l| l.is_finite())
                .collect();
            let train_loss = crate::util::stats::mean(&losses);
            let coreset_clients = contributing.iter().filter(|o| o.used_coreset).count();
            let compressions: Vec<f64> = contributing
                .iter()
                .filter(|o| o.used_coreset)
                .map(|o| o.compression)
                .collect();
            let mean_compression = if compressions.is_empty() {
                1.0
            } else {
                crate::util::stats::mean(&compressions)
            };

            let do_eval = r % cfg.eval_every == 0 || r + 1 == cfg.rounds;
            let (test_loss, test_acc) = if do_eval {
                let ev = self.evaluate(&params)?;
                (ev.mean_loss(), ev.accuracy())
            } else {
                rounds
                    .last()
                    .map(|p: &RoundRecord| (p.test_loss, p.test_acc))
                    .unwrap_or((f64::NAN, 0.0))
            };

            if cfg.verbose {
                eprintln!(
                    "[{}] round {r:>3}: loss {train_loss:.4} | test acc {:.2}% | t/τ {:.2} | dropped {dropped} | coreset {coreset_clients}",
                    cfg.strategy.label(),
                    100.0 * test_acc,
                    sim_time / self.fleet.deadline,
                );
            }

            rounds.push(RoundRecord {
                round: r,
                train_loss,
                test_loss,
                test_acc,
                sim_time,
                sim_elapsed: clock.elapsed(),
                client_times: timing.client_times,
                dropped,
                coreset_clients,
                mean_compression,
            });
        }

        Ok(RunResult {
            strategy: cfg.strategy.label().to_string(),
            benchmark: self.data.model.clone(),
            straggler_pct: cfg.straggler_pct,
            deadline: self.fleet.deadline,
            rounds,
            final_params: params,
        })
    }
}
