//! The FL round engine — paper Algorithm 1.
//!
//! Per round: sample K clients with probability ∝ mᵢ (Assumption A.6),
//! broadcast the global model, execute each client's [`LocalPlan`] through
//! the configured [`Executor`] (in-thread or sharded across runtime-pinned
//! workers — see [`crate::exec`]), fold the round-end parameters through
//! the configured [`crate::agg::Aggregator`] in selection order (the
//! default [`AggPolicy::Mean`] is wᵣ₊₁ = (1/K) Σ wᵢ, the classic FedAvg
//! mean), and record loss/accuracy/timing into a [`RunResult`].
//!
//! Determinism: every job's RNG stream is split from `(round, client)`
//! before dispatch and results are aggregated in selection order, so a run
//! is bit-identical for any worker count.
//!
//! With [`RunConfig::overlap`] set, the loop switches to the *async
//! round-overlap* pipeline: the server aggregates — and the clock
//! advances to the next round's dispatch — as soon as a quorum of the
//! round's contributing clients has finished; late finishers travel
//! through an [`InFlight`] ledger and fold into a later round's
//! aggregation as staleness-weighted delayed gradients
//! ([`aggregate_weighted`]), or are discarded past the staleness cap.
//! The degenerate policy (`quorum = 1.0`, `max_staleness = 0`) keeps the
//! ledger empty and reproduces the synchronous loop bit-for-bit
//! (`rust/tests/proptest_overlap.rs`).

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::client::ClientOutcome;
use super::plan::{LocalPlan, Strategy};
use crate::agg::{AdaptiveQuorum, AggPolicy, Aggregator, TreeSpec};
use crate::coreset::Method;
use crate::data::FedDataset;
use crate::exec::{
    ClientJob, DelayedUpdate, DispatchPolicy, EvalJob, ExecContext, Executor, ExecutorImpl,
    InFlight, OverlapConfig,
};
use crate::metrics::{RoundRecord, RunResult};
use crate::obs::{Counter, ObsConfig, Phase, Record, Recorder};
use crate::runtime::{EvalOutput, ModelInfo, Runtime};
use crate::scenario::{
    forecast_weights, AvailabilityTrace, CorruptionSpec, FlanpState, SelectPolicy, TraceSpec,
};
use crate::sim::{clock::RoundTiming, Fleet, SimClock};
use crate::util::json::Json;
use crate::util::rng::Rng;

// The aggregation algebra moved to the agg subsystem; re-exported here
// (and from `fl`) so every historical call site keeps compiling.
pub use crate::agg::{aggregate, aggregate_weighted};

/// When FedCore (re)builds coresets (paper §4.3/§4.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoresetMode {
    /// The paper's default: new gradient-space coreset every round, from
    /// the round's first-epoch per-sample gradients (answers Q1).
    Adaptive,
    /// The convex-model shortcut: one input-space (d̃) coreset per client,
    /// built once and reused — zero per-round construction cost.
    Static,
}

/// Everything one experiment run needs (strategy × benchmark × straggler%).
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Which of the four paper strategies drives local planning.
    pub strategy: Strategy,
    /// R — communication rounds.
    pub rounds: usize,
    /// E — local epochs per round (paper Table 3: 10).
    pub epochs: usize,
    /// K — clients sampled per round.
    pub clients_per_round: usize,
    /// SGD learning rate (paper Table 3 per benchmark).
    pub lr: f32,
    /// s — straggler percentage (10 or 30 in the paper).
    pub straggler_pct: f64,
    /// Root seed; every random decision in the run derives from it.
    pub seed: u64,
    /// k-medoids solver for FedCore.
    pub coreset_method: Method,
    /// Adaptive (per-round, gradient-space) vs static (once, input-space).
    pub coreset_mode: CoresetMode,
    /// Rebuild adaptive coresets from scratch every this many rounds; on
    /// the rounds in between, FasterPAM warm-starts from the client's
    /// previous medoids (SWAP-only refinement — generalizes the §4.3
    /// static cache to the adaptive path). `1` (the default) rebuilds
    /// every round, bit-identical to the pre-warm-start engine
    /// (`rust/tests/proptest_coreset.rs`). Ignored for
    /// [`CoresetMode::Static`] and for non-FasterPAM methods.
    pub coreset_refresh: usize,
    /// Evaluate the global model every this many rounds (1 = each round).
    pub eval_every: usize,
    /// Cap on test samples per evaluation (0 = use the full test set).
    pub eval_cap: usize,
    /// Client-execution worker threads: 1 = sequential (in-thread), N > 1
    /// = sharded pool of N runtime-pinned workers, 0 = auto
    /// (`util::pool::default_threads`, honors `FEDCORE_THREADS`).
    pub workers: usize,
    /// How the sharded pool places jobs on workers (see
    /// [`crate::exec::dispatch`]): deterministic round-robin dealing
    /// (default) or the virtual-time work-stealing schedule. Model
    /// outputs are bit-identical either way — the policy only moves the
    /// dispatch diagnostics (`steal_count` / `worker_idle`).
    pub dispatch: DispatchPolicy,
    /// Optional client-availability scenario: only clients the trace
    /// reports online at a round's start are eligible for selection, and
    /// selected clients that go offline mid-round are dropped with their
    /// partial work discarded. `None` = the classic always-on setting
    /// (byte-identical to pre-scenario behaviour).
    pub trace: Option<TraceSpec>,
    /// Async round overlap: `Some(policy)` aggregates each round at a
    /// quorum of its contributing clients and folds late arrivals into
    /// later rounds as staleness-weighted delayed gradients (see
    /// [`crate::exec::overlapped`]). `None` = the classic synchronous
    /// barrier; the degenerate policy (`quorum = 1.0`,
    /// `max_staleness = 0`) reproduces `None` bit-for-bit.
    pub overlap: Option<OverlapConfig>,
    /// Server aggregation policy (see [`crate::agg`]). The default
    /// [`AggPolicy::Mean`] is the classic weighted FedAvg mean,
    /// bit-identical to the pre-policy engine.
    pub aggregator: AggPolicy,
    /// Hierarchical two-tier aggregation (see [`crate::agg::tree`]):
    /// `Some(spec)` folds each round's contribution sequence through up
    /// to `spec.fanout` edge aggregators over contiguous shards and
    /// composes the edge aggregates at the root. `None` (default) keeps
    /// the flat single-tier fold; a Mean-edge tree with no clipping
    /// relays and reproduces `None` bit-for-bit
    /// (`rust/tests/proptest_tree.rs`). When set, the tree's tier
    /// policies replace `aggregator` at the seam (the CLI builds
    /// `spec.edge` from `--agg`, so the flag keeps meaning "the policy
    /// that sees client updates").
    pub agg_tree: Option<TreeSpec>,
    /// Clip client update L2 norms to this bound before aggregating
    /// (`None` = no clipping; see [`crate::agg::NormClip`]).
    pub clip_norm: Option<f64>,
    /// With `overlap` set: adapt the quorum per round from the observed
    /// stale-discard rate (see [`crate::agg::AdaptiveQuorum`]). Ignored
    /// without overlap.
    pub adaptive_quorum: bool,
    /// Corrupted-update scenario: a seeded fraction of clients returns
    /// noisy / sign-flipped parameters (see
    /// [`crate::scenario::corruption`]). `None` = every update honest.
    pub corruption: Option<CorruptionSpec>,
    /// Availability-aware selection boost: with a trace configured,
    /// multiply each client's selection weight by
    /// `1 + boost · (1 − uptime)` (then renormalize), oversampling flaky
    /// clients so their data is not starved by churn. `0.0` (default)
    /// keeps selection byte-identical to the unboosted path.
    pub flaky_boost: f64,
    /// Cohort-selection policy (see [`crate::scenario::selection`]):
    /// FLANP adaptive participation samples from a cost-ranked fastest
    /// prefix that widens on loss stalls; uptime-forecast selection
    /// biases weights toward clients forecast to survive the round. The
    /// default [`SelectPolicy::Baseline`] — and every policy's
    /// degenerate knob setting (`flanp_start` ≥ fleet,
    /// `forecast_bias = 0`) — is byte-identical to the classic sampler
    /// (`rust/tests/proptest_select.rs`).
    pub select: SelectPolicy,
    /// Straggler distillation (arXiv:2403.09086 shape): with `overlap`
    /// set and this weight > 0, delayed updates past `max_staleness`
    /// stop taking the drop path and instead fold into an auxiliary
    /// correction applied after the main aggregate
    /// ([`crate::agg::apply_distilled`]), at
    /// `distill_weight · staleness-decay` each. `0.0` (default) is the
    /// existing drop path, bit-for-bit.
    pub distill_weight: f64,
    /// Print a progress line per round.
    pub verbose: bool,
    /// Structured observability sink (see [`crate::obs`]). The default
    /// [`ObsConfig::Off`] records nothing; `Jsonl` writes a
    /// schema-versioned span/event/counter trace. Write-only by
    /// contract (determinism rule 7): a traced run is bit-identical to
    /// an untraced one (`rust/tests/proptest_obs.rs`).
    pub obs: ObsConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            strategy: Strategy::FedCore,
            rounds: 30,
            epochs: 10,
            clients_per_round: 10,
            lr: 0.03,
            straggler_pct: 30.0,
            seed: 7,
            coreset_method: Method::FasterPam,
            coreset_mode: CoresetMode::Adaptive,
            coreset_refresh: 1,
            eval_every: 1,
            eval_cap: 512,
            workers: 1,
            dispatch: DispatchPolicy::RoundRobin,
            trace: None,
            overlap: None,
            aggregator: AggPolicy::Mean,
            agg_tree: None,
            clip_norm: None,
            adaptive_quorum: false,
            corruption: None,
            flaky_boost: 0.0,
            select: SelectPolicy::Baseline,
            distill_weight: 0.0,
            verbose: false,
            obs: ObsConfig::Off,
        }
    }
}

/// Availability-aware selection weights: boost flaky clients so churn
/// does not starve their data. Each weight is multiplied by
/// `1 + boost · (1 − uptime)` and the result renormalized to sum 1.
/// `boost <= 0` returns the input weights **unchanged** (bitwise), so
/// the flag-off path is byte-identical to the classic sampler.
pub fn boost_flaky_weights(weights: &[f64], uptimes: &[f64], boost: f64) -> Vec<f64> {
    assert_eq!(weights.len(), uptimes.len(), "one uptime per client");
    if boost <= 0.0 {
        return weights.to_vec();
    }
    let raw: Vec<f64> = weights
        .iter()
        .zip(uptimes)
        .map(|(&w, &u)| w.max(0.0) * (1.0 + boost * (1.0 - u.clamp(0.0, 1.0))))
        .collect();
    let sum: f64 = raw.iter().sum();
    if sum <= 0.0 {
        return weights.to_vec();
    }
    raw.into_iter().map(|w| w / sum).collect()
}

/// Availability-aware client selection (Algorithm 1 line 3 under churn):
/// sample `k` clients with probability ∝ `weights[i]`, with replacement,
/// **among the online clients only**.
///
/// Deterministic fallback when fewer than `k` clients are online: every
/// online client is selected exactly once, in index order, and the RNG is
/// not consumed (so the decision depends only on the trace, never on
/// sampling luck). With every client online and `k ≤ weights.len()` this
/// reduces exactly to the unrestricted sampler — an always-on trace
/// reproduces the traceless run bit-for-bit.
pub fn select_available(
    rng: &mut Rng,
    weights: &[f64],
    online: &[usize],
    k: usize,
) -> Vec<usize> {
    if online.is_empty() {
        return Vec::new();
    }
    if online.len() < k {
        return online.to_vec();
    }
    let mut w: Vec<f64> = online.iter().map(|&i| weights[i]).collect();
    if w.iter().map(|x| x.max(0.0)).sum::<f64>() <= 0.0 {
        // Degenerate weights (all masked out): fall back to uniform so the
        // sampler never panics on an all-zero CDF.
        w = vec![1.0; online.len()];
    }
    rng.weighted_with_replacement(&w, k).into_iter().map(|j| online[j]).collect()
}

/// Streamed availability-aware selection: bit-identical to
/// [`select_available`] over `online = (0..n).filter(is_online)` with
/// `weights[i] = weight_of(i)`, but without ever materializing the
/// fleet-sized online list or its weight/CDF vectors — per-round memory
/// is O(k), not O(fleet).
///
/// How the replication works: the flat sampler builds the online cohort's
/// cumulative weight sums in index order and draws one `f64` threshold
/// per pick against the total. Here the total comes from a first
/// streaming pass, the `k` thresholds are drawn up-front **in the same
/// RNG order**, sorted (carrying their draw positions), and resolved in
/// one second pass that accumulates the identical running sums — each
/// threshold selects the first online client whose cumulative weight
/// exceeds it, which is exactly the flat path's binary-search answer.
/// The `< k` online fallback (everyone once, in index order, RNG
/// untouched) and the all-non-positive-weight uniform fallback carry
/// over unchanged (`select_streamed_matches_flat` in this module's
/// tests is the differential gate).
pub fn select_available_streamed(
    rng: &mut Rng,
    weight_of: impl Fn(usize) -> f64,
    is_online: impl Fn(usize) -> bool,
    n: usize,
    k: usize,
) -> Vec<usize> {
    // Pass 1: cohort size and total (clamped) weight, in index order —
    // the same `acc` the flat path's CDF construction ends on.
    let mut count = 0usize;
    let mut total = 0.0f64;
    for i in 0..n {
        if is_online(i) {
            count += 1;
            total += weight_of(i).max(0.0);
        }
    }
    if count == 0 {
        return Vec::new();
    }
    if count < k {
        // Deterministic fallback: every online client exactly once, in
        // index order, without consuming the RNG.
        return (0..n).filter(|&i| is_online(i)).collect();
    }
    // Degenerate weights: the flat path substitutes uniform 1.0 weights.
    let uniform = total <= 0.0;
    if uniform {
        total = count as f64;
    }
    // Draw the k thresholds in the flat sampler's order (one `f64` per
    // pick), then sort by (threshold, draw position) so one in-order
    // sweep over the clients can resolve them all.
    let mut draws: Vec<(f64, usize)> = (0..k).map(|slot| (rng.f64() * total, slot)).collect();
    draws.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).expect("finite selection thresholds").then(a.1.cmp(&b.1))
    });
    let mut out = vec![0usize; k];
    let mut acc = 0.0f64;
    let mut next = 0usize; // first unresolved draw
    let mut last_online = 0usize;
    for i in 0..n {
        if next >= k {
            break;
        }
        if !is_online(i) {
            continue;
        }
        last_online = i;
        acc += if uniform { 1.0 } else { weight_of(i).max(0.0) };
        while next < k && draws[next].0 < acc {
            out[draws[next].1] = i;
            next += 1;
        }
    }
    // Thresholds at or past the final cumulative sum (f64 rounding can
    // push a draw to exactly `total`): the flat path clamps these to the
    // last online index.
    for d in &draws[next..] {
        out[d.1] = last_online;
    }
    out
}

/// Mean train loss over a round's outcomes that actually contributed
/// parameters (churn-dropped slots carry `params: None` and a NaN
/// placeholder loss; non-finite losses from divergent clients are also
/// excluded). `None` when nobody contributed — an all-dropped round has
/// no training loss, and folding the empty set through `stats::mean`
/// would report a fake perfect `0.0` (the original bug). The engine
/// carries the previous round's value forward instead, mirroring the
/// eval-metric carry-forward on non-eval rounds.
pub(crate) fn round_train_loss(outcomes: &[ClientOutcome]) -> Option<f64> {
    let losses: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.params.is_some())
        .map(|o| o.train_loss)
        .filter(|l| l.is_finite())
        .collect();
    if losses.is_empty() {
        None
    } else {
        Some(crate::util::stats::mean(&losses))
    }
}

/// Interior-mutable cache keyed by `(client, budget)` — the §4.3 static
/// coreset store. The budget is part of the key because the same client
/// is asked at different budgets across strategies/configs sharing an
/// engine; a client-only key (the original bug) silently served the
/// first budget's value at every later budget.
pub(crate) struct BudgetKeyedCache<V> {
    map: std::cell::RefCell<std::collections::HashMap<(usize, usize), V>>,
}

impl<V: Clone> BudgetKeyedCache<V> {
    pub(crate) fn new() -> BudgetKeyedCache<V> {
        BudgetKeyedCache { map: std::cell::RefCell::new(std::collections::HashMap::new()) }
    }

    /// Return the cached value for `(client, budget)`, building and
    /// memoizing it on first use.
    pub(crate) fn fetch(&self, client: usize, budget: usize, build: impl FnOnce() -> V) -> V {
        if let Some(v) = self.map.borrow().get(&(client, budget)) {
            return v.clone();
        }
        let v = build();
        self.map.borrow_mut().insert((client, budget), v.clone());
        v
    }

    /// Number of distinct `(client, budget)` entries held.
    #[cfg(test)]
    pub(crate) fn len(&self) -> usize {
        self.map.borrow().len()
    }
}

/// The engine: owns the fleet simulation and the executor, borrows the
/// runtime, shares the dataset (`Arc`, so sharded workers can hold it).
///
/// # Example
///
/// ```no_run
/// use std::sync::Arc;
/// use fedcore::config::ExperimentConfig;
/// use fedcore::data::{self, Benchmark};
/// use fedcore::fl::Engine;
/// use fedcore::runtime::Runtime;
///
/// # fn main() -> fedcore::Result<()> {
/// let rt = Runtime::load("artifacts")?;
/// let cfg = ExperimentConfig::scaled_preset(
///     Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
///     0.2,
/// );
/// let ds = Arc::new(data::generate(cfg.benchmark, cfg.scale, &rt.manifest().vocab, 7));
/// let result = Engine::new(&rt, &ds, cfg.run.clone())?.run()?;
/// println!("best accuracy {:.2}%", 100.0 * result.best_accuracy());
/// # Ok(())
/// # }
/// ```
pub struct Engine<'a, E: Executor = ExecutorImpl<'a>> {
    rt: &'a Runtime,
    model: ModelInfo,
    /// Shared with `ctx` (same allocation — planning and worker-side
    /// simulation always see the same fleet).
    pub fleet: Arc<Fleet>,
    cfg: RunConfig,
    exec: E,
    /// Shared job context handed to executor workers.
    ctx: Arc<ExecContext>,
    /// Materialized availability trace (None = always-on).
    trace: Option<Arc<AvailabilityTrace>>,
    /// Materialized corruption membership (`corrupted[i]` = client i is
    /// corrupted; None = every update honest).
    corrupted: Option<Vec<bool>>,
    /// §4.3 static-coreset cache, keyed by `(client, budget)`. A static
    /// coreset is a pure function of `(seed, client, budget)` — and the
    /// budget genuinely varies per strategy/config, so keying by client
    /// alone (the original bug) served the first budget's coreset at
    /// every later budget.
    static_cache: BudgetKeyedCache<crate::coreset::Coreset>,
    /// Warm-start medoid cache for the *adaptive* path (client → medoids
    /// of that client's last built coreset). Consulted only on
    /// non-refresh rounds (`cfg.coreset_refresh > 1`); with the default
    /// refresh of 1 it is written but never read, so the engine is
    /// bit-identical to the pre-warm-start one. Cleared at the start of
    /// every run (unlike the static cache, its contents depend on round
    /// history, not just the seed).
    warm_cache: std::cell::RefCell<std::collections::HashMap<usize, Vec<usize>>>,
    /// Observability sink built from `cfg.obs` (the [`crate::obs::Null`]
    /// recorder when tracing is off). Write-only: never read back.
    obs: Arc<dyn Recorder>,
}

impl<'a> Engine<'a> {
    /// Build an engine with the executor implied by `cfg.workers`.
    pub fn new(rt: &'a Runtime, data: &Arc<FedDataset>, cfg: RunConfig) -> Result<Engine<'a>> {
        let exec = ExecutorImpl::from_config(rt, cfg.workers, cfg.overlap, cfg.dispatch)?;
        Engine::with_executor(rt, data, cfg, exec)
    }
}

impl<'a, E: Executor> Engine<'a, E> {
    /// Build an engine around an explicit executor (tests and benches use
    /// this to compare implementations directly).
    pub fn with_executor(
        rt: &'a Runtime,
        data: &Arc<FedDataset>,
        cfg: RunConfig,
        exec: E,
    ) -> Result<Engine<'a, E>> {
        if data.num_clients() == 0 {
            return Err(anyhow!("dataset has no clients"));
        }
        if let Some(ov) = &cfg.overlap {
            ov.validate().context("overlap configuration")?;
        }
        cfg.aggregator.validate().context("aggregation policy")?;
        if let Some(tree) = &cfg.agg_tree {
            tree.validate().context("aggregation tree")?;
        }
        if let Some(c) = cfg.clip_norm {
            if !(c > 0.0) {
                return Err(anyhow!("clip norm must be positive, got {c}"));
            }
        }
        if !(cfg.flaky_boost >= 0.0 && cfg.flaky_boost.is_finite()) {
            return Err(anyhow!("flaky boost must be finite and >= 0, got {}", cfg.flaky_boost));
        }
        cfg.select.validate().context("selection policy")?;
        if !(cfg.distill_weight >= 0.0 && cfg.distill_weight.is_finite()) {
            return Err(anyhow!(
                "distill weight must be finite and >= 0, got {}",
                cfg.distill_weight
            ));
        }
        if cfg.distill_weight > 0.0 && cfg.overlap.is_none() {
            return Err(anyhow!(
                "distill weight only applies to the overlapped pipeline (set overlap)"
            ));
        }
        if cfg.coreset_refresh == 0 {
            return Err(anyhow!("coreset refresh must be >= 1 (1 = rebuild every round)"));
        }
        let corrupted = match &cfg.corruption {
            Some(spec) => {
                spec.validate().context("corruption scenario")?;
                Some(spec.corrupted_clients(data.num_clients()))
            }
            None => None,
        };
        let model = rt.manifest().model(&data.model)?.clone();
        let mut fleet_rng = Rng::new(cfg.seed).split(0xF1EE7);
        let fleet =
            Arc::new(Fleet::new(&mut fleet_rng, data.sizes(), cfg.epochs, cfg.straggler_pct));
        let ctx = Arc::new(ExecContext {
            data: Arc::clone(data),
            model: model.clone(),
            fleet: Arc::clone(&fleet),
            lr: cfg.lr,
            mu: cfg.strategy.mu(),
            method: cfg.coreset_method,
            coreset_workers: exec.workers().max(1),
        });
        // Traces are written fleet-independently (often in deadline units);
        // materialize now that the fleet size and τ are known.
        let trace = match &cfg.trace {
            Some(spec) => Some(Arc::new(
                spec.materialize(data.num_clients(), fleet.deadline)
                    .context("materializing availability trace")?,
            )),
            None => None,
        };
        // The observability sink. Created last so a failing trace path
        // never half-builds an engine; [`ObsConfig::Off`] is free.
        let obs = cfg.obs.build(cfg.seed, cfg.rounds).context("observability sink")?;
        Ok(Engine {
            rt,
            model,
            fleet,
            cfg,
            exec,
            ctx,
            trace,
            corrupted,
            static_cache: BudgetKeyedCache::new(),
            warm_cache: std::cell::RefCell::new(std::collections::HashMap::new()),
            obs,
        })
    }

    /// Fetch-or-build the §4.3 static coreset for client `i` at `budget`.
    /// Static coresets are input-space (no runtime involved), so they are
    /// built on the coordinator thread and shipped to workers inside jobs.
    fn static_coreset(&self, i: usize, budget: usize) -> crate::coreset::Coreset {
        self.static_cache.fetch(i, budget, || {
            let mut rng = Rng::new(self.cfg.seed).split(0x57A7 ^ i as u64);
            super::client::build_static_coreset(
                &self.ctx.data.clients[i],
                self.rt.manifest().vocab.len(),
                budget,
                self.cfg.coreset_method,
                &mut rng,
            )
        })
    }

    /// The run configuration this engine was built with.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// The manifest entry of the model under training.
    pub fn model(&self) -> &ModelInfo {
        &self.model
    }

    /// The executor driving this engine's rounds.
    pub fn executor(&self) -> &E {
        &self.exec
    }

    /// The materialized availability trace driving this engine's rounds
    /// (`None` = the classic always-on setting).
    pub fn trace(&self) -> Option<&Arc<AvailabilityTrace>> {
        self.trace.as_ref()
    }

    /// Evaluate `params` on the global test set (masked, batched). Batches
    /// are sharded across the executor one PJRT call per job and merged in
    /// batch order, reproducing the sequential merge exactly.
    pub fn evaluate(&self, params: &[f32]) -> Result<EvalOutput> {
        let f = self.rt.manifest().feat_batch;
        if f == 0 {
            return Err(anyhow!("manifest feat_batch is 0 — cannot batch evaluation"));
        }
        let test = &self.ctx.data.test;
        let n = if self.cfg.eval_cap > 0 {
            test.len().min(self.cfg.eval_cap)
        } else {
            test.len()
        };
        let shared = Arc::new(params.to_vec());
        let mut jobs = Vec::with_capacity(n.div_ceil(f));
        let mut start = 0usize;
        while start < n {
            let end = (start + f).min(n);
            jobs.push(EvalJob { params: Arc::clone(&shared), start, end });
            start = end;
        }
        let mut total = EvalOutput::default();
        for out in self.exec.run_evals(&self.ctx, jobs)? {
            total.merge(out);
        }
        Ok(total)
    }

    /// Run the full experiment from the model's deterministic w₀.
    pub fn run(&self) -> Result<RunResult> {
        self.run_from(self.model.init_params.clone())
    }

    /// Run from an arbitrary starting point (checkpoint resume).
    pub fn run_from(&self, init_params: Vec<f32>) -> Result<RunResult> {
        if init_params.len() != self.model.param_size {
            return Err(anyhow!(
                "initial params have {} values, model '{}' wants {}",
                init_params.len(),
                self.model.name,
                self.model.param_size
            ));
        }
        let cfg = &self.cfg;
        // A fresh run must not inherit warm medoids from a previous run on
        // the same engine: unlike static coresets (a pure function of seed
        // and client), warm seeds depend on the previous run's history.
        self.warm_cache.borrow_mut().clear();
        let weights = self.ctx.data.client_weights();
        // Availability-aware selection policy: boost flaky clients'
        // weights from the trace's per-client uptime. Off (or traceless)
        // runs keep the exact original weights, bitwise.
        let weights = match &self.trace {
            Some(trace) if cfg.flaky_boost > 0.0 => {
                let uptimes: Vec<f64> =
                    (0..weights.len()).map(|i| trace.uptime(i)).collect();
                boost_flaky_weights(&weights, &uptimes, cfg.flaky_boost)
            }
            _ => weights,
        };
        // Uptime-forecast selection (`--select forecast`): bias the
        // weights toward clients whose availability history forecasts
        // they will survive the round. The scoring streams one client at
        // a time straight off the trace (it never materializes a dense
        // schedule — the PR-8 O(cohort) discipline). Bias 0 — and
        // traceless runs — keep the exact original weights, bitwise.
        let weights = match (&self.trace, &cfg.select) {
            (Some(trace), SelectPolicy::Forecast { bias }) if *bias > 0.0 => {
                forecast_weights(&weights, |i| trace.uptime(i), *bias)
            }
            _ => weights,
        };
        // FLANP adaptive participation (`--select flanp`): rank the
        // fleet once by the strategy's deterministic simulated plan cost
        // — the same numbers dispatch schedules from — and sample each
        // round from the fastest prefix only, widening it on loss
        // stalls. A whole-fleet prefix (the degenerate `start ≥ fleet`)
        // admits every client, and the streamed selector then consumes
        // exactly the baseline sampler's RNG.
        let mut flanp: Option<FlanpState> = match &cfg.select {
            SelectPolicy::Flanp(fc) => {
                let costs: Vec<f64> = (0..self.fleet.num_clients())
                    .map(|i| cfg.strategy.plan(&self.fleet, i).sim_time(&self.fleet, i))
                    .collect();
                Some(FlanpState::new(&costs, *fc))
            }
            _ => None,
        };
        let mut select_rng = Rng::new(cfg.seed).split(0x5E1EC7);
        let client_root = Rng::new(cfg.seed).split(0xC11E47);
        let mut clock = SimClock::new(self.fleet.deadline);

        // Async overlap state: `None` runs the synchronous barrier; the
        // ledger stays empty then, and every quorum degenerates to "all".
        let overlap = cfg.overlap;
        let mut in_flight = InFlight::new();
        let mut adaptive = match (overlap, cfg.adaptive_quorum) {
            (Some(ov), true) => Some(AdaptiveQuorum::new(ov.quorum)),
            _ => None,
        };

        // The aggregation seam: one policy instance per run (buffered
        // policies carry cross-round state). RNG-free by contract. A
        // configured tree replaces the flat fold with the two-tier
        // edge/root composition ([`crate::agg::tree`]).
        let mut agg: Box<dyn Aggregator> = match &cfg.agg_tree {
            Some(tree) => Box::new(tree.build(cfg.clip_norm)),
            None => cfg.aggregator.build(cfg.clip_norm),
        };

        let mut params = init_params;
        let mut rounds: Vec<RoundRecord> = Vec::with_capacity(cfg.rounds);

        // Observability (write-only — determinism rule 7): wall-clock
        // reads flow *into* the trace and nowhere else; the untraced
        // path never reads the clock at all ([`crate::obs::Null`]
        // returns 0). A traced run also takes ownership of the
        // executor's schedule ledger for the per-job/per-worker spans
        // emitted at the end of the run; the `run_start` event keeps a
        // multi-run trace file segmentable.
        let obs = &*self.obs;
        let traced = obs.enabled();
        if traced {
            self.exec.record_schedule(true);
            obs.record(&Record::Event {
                name: "run_start",
                round: 0,
                fields: vec![
                    ("rounds", Json::Num(cfg.rounds as f64)),
                    ("strategy", Json::Str(cfg.strategy.label().into())),
                ],
            });
            if let Some(tree) = &cfg.agg_tree {
                // Topology is config, not per-round state: one event at
                // the head of the trace, not a counter (the registry is
                // pinned to `Counter::ALL`).
                obs.record(&Record::Event {
                    name: "agg_tree",
                    round: 0,
                    fields: vec![
                        ("fanout", Json::Num(tree.fanout as f64)),
                        ("edge", Json::Str(tree.edge.label().into())),
                        ("root", Json::Str(tree.root.label().into())),
                    ],
                });
            }
        }
        // Straggler-forensics ledger (schema v2 `snapshot` records):
        // O(cohort + K) state, fed only from deterministic run outcomes
        // below — health sampling writes to the trace and nowhere else,
        // so rule 7 holds with it on (`proptest_obs.rs` differential).
        let mut health = if traced {
            cfg.obs.health().map(|h| crate::obs::health::HealthLedger::new(h.clone()))
        } else {
            None
        };

        for r in 0..cfg.rounds {
            let round_w0 = obs.now_ns();
            let mut rss_peak: Option<crate::obs::mem::MemSample> = None;
            // --- Algorithm 1 line 3: sample K clients, p ∝ mᵢ, among the
            //     clients the availability trace reports online at the
            //     round's start (everyone, when no trace is configured) ---
            let t_now = clock.now();
            let selected = match (&self.trace, &flanp) {
                (None, None) => {
                    select_rng.weighted_with_replacement(&weights, cfg.clients_per_round)
                }
                // FLANP restricts the candidate set to the active
                // fastest prefix; with no trace the prefix is the only
                // predicate. The whole-fleet prefix makes it all-true,
                // which the streamed selector reduces to the
                // unrestricted sampler bit-for-bit (RNG included).
                (None, Some(st)) => select_available_streamed(
                    &mut select_rng,
                    |i| weights[i],
                    |i| st.admits(i),
                    self.fleet.num_clients(),
                    cfg.clients_per_round,
                ),
                // Streamed over the trace — no fleet-sized online list is
                // ever built; bit-identical to the materialized
                // `online_clients` + `select_available` pipeline.
                (Some(trace), None) => select_available_streamed(
                    &mut select_rng,
                    |i| weights[i],
                    |i| trace.is_online(i, t_now),
                    self.fleet.num_clients(),
                    cfg.clients_per_round,
                ),
                // Both: a client is eligible when it is in the active
                // prefix AND online. The prefix test is checked first
                // (it is a vector lookup); the degenerate prefix leaves
                // the online predicate — and the RNG draw sequence —
                // exactly the baseline's.
                (Some(trace), Some(st)) => select_available_streamed(
                    &mut select_rng,
                    |i| weights[i],
                    |i| st.admits(i) && trace.is_online(i, t_now),
                    self.fleet.num_clients(),
                    cfg.clients_per_round,
                ),
            };
            let select_w1 = obs.now_ns();

            // --- lines 5–13: local work, sharded across the executor.
            //     A selected client whose online window ends before its
            //     plan completes never reaches the executor: its job is
            //     skipped (keeping the order-preserving reduce intact) and
            //     its partial work is discarded but surfaced per-round. ---
            let global = Arc::new(params.clone());
            let mut jobs: Vec<ClientJob> = Vec::with_capacity(selected.len());
            // One entry per selection slot: Some(partial simulated seconds)
            // = churn-dropped before finishing, None = dispatched.
            let mut churn_partial: Vec<Option<f64>> = Vec::with_capacity(selected.len());
            for &i in &selected {
                let plan = cfg.strategy.plan(&self.fleet, i);
                if let Some(trace) = &self.trace {
                    let need = plan.sim_time(&self.fleet, i);
                    let have = trace.remaining_online(i, t_now);
                    if have < need {
                        churn_partial.push(Some(have));
                        if traced {
                            obs.record(&Record::Event {
                                name: "churn_drop",
                                round: r,
                                fields: vec![
                                    ("client", Json::Num(i as f64)),
                                    ("partial_s", Json::Num(have)),
                                ],
                            });
                        }
                        continue;
                    }
                }
                churn_partial.push(None);
                // §4.3 static mode: serve coresets from the per-client cache.
                let static_cs = match (&plan, cfg.coreset_mode) {
                    (LocalPlan::Coreset { budget, .. }, CoresetMode::Static) => {
                        Some(self.static_coreset(i, *budget))
                    }
                    _ => None,
                };
                // Warm start (adaptive mode only): on non-refresh rounds,
                // seed FasterPAM from this client's previous medoids.
                // Refresh rounds — every round at the default refresh of 1
                // — never consult the cache, so the cold path is bitwise
                // untouched.
                let warm = match (&plan, cfg.coreset_mode) {
                    (LocalPlan::Coreset { .. }, CoresetMode::Adaptive)
                        if cfg.coreset_refresh > 1 && r % cfg.coreset_refresh != 0 =>
                    {
                        self.warm_cache.borrow().get(&i).cloned()
                    }
                    _ => None,
                };
                jobs.push(ClientJob {
                    client: i,
                    plan,
                    global: Arc::clone(&global),
                    static_coreset: static_cs,
                    warm_medoids: warm,
                    rng: client_root.split((r as u64) << 20 | i as u64),
                });
            }
            let dispatch_w1 = obs.now_ns();
            let executed = self.exec.run_clients(&self.ctx, jobs)?;
            // Dispatch diagnostics of this round's client batch (virtual
            // time, deterministic): recorded per round and accumulated
            // into the clock's utilization ledger. Never feeds timing or
            // aggregation — determinism rule 6.
            let dispatch = self.exec.last_client_dispatch().unwrap_or_default();
            clock.record_dispatch(dispatch.busy_seconds, dispatch.capacity_seconds());
            // Stitch executor results back into selection order around the
            // skipped slots (dispatched jobs kept their relative order, so
            // a single in-order walk suffices).
            let mut executed = executed.into_iter();
            let mut outcomes: Vec<ClientOutcome> = churn_partial
                .iter()
                .map(|slot| match slot {
                    Some(partial) => ClientOutcome {
                        params: None,
                        train_loss: f64::NAN,
                        sim_time: *partial,
                        used_coreset: false,
                        compression: 1.0,
                        coreset_cost: 0.0,
                        coreset_medoids: None,
                        coreset_warm: false,
                    },
                    None => executed.next().expect("one outcome per dispatched job"),
                })
                .collect();
            // Corrupted-update scenario: perturb marked clients' returned
            // parameters before anything downstream (ledger, aggregation)
            // sees them. Deterministic per (spec seed, round, client) —
            // worker scheduling cannot reach this stream.
            if let (Some(spec), Some(flags)) = (&cfg.corruption, &self.corrupted) {
                for (slot, o) in outcomes.iter_mut().enumerate() {
                    let client = selected[slot];
                    if flags[client] {
                        if let Some(p) = &mut o.params {
                            spec.apply(p, &global, r, client);
                        }
                    }
                }
            }
            // Warm-start bookkeeping: remember each adaptive client's
            // medoids for the next non-refresh round, and count this
            // round's warm-started coresets (a dispatch-style diagnostic —
            // never feeds timing, aggregation, or the model CSV).
            let mut coreset_warm = 0usize;
            for (slot, o) in outcomes.iter().enumerate() {
                if let Some(medoids) = &o.coreset_medoids {
                    self.warm_cache.borrow_mut().insert(selected[slot], medoids.clone());
                }
                if o.coreset_warm {
                    coreset_warm += 1;
                }
            }
            let churn_dropped = churn_partial.iter().filter(|s| s.is_some()).count();
            let partial_time: f64 = churn_partial.iter().flatten().sum();
            let train_w1 = obs.now_ns();
            if traced {
                crate::obs::mem::fold_peak(&mut rss_peak);
            }

            // --- timing: the synchronous server waits for its slowest
            //     participant; the overlapped server advances at the
            //     quorum (q-th smallest contributing time) while the tail
            //     keeps computing. An all-dropped (or fully idle, under
            //     churn) round still costs the server the full τ, and any
            //     mid-round dropout forces the server to wait out τ before
            //     giving up on the vanished client ---
            let contributing: Vec<(usize, &ClientOutcome)> = outcomes
                .iter()
                .enumerate()
                .filter(|(_, o)| o.params.is_some())
                .collect();
            let dropped = outcomes.len() - contributing.len();
            let client_times: Vec<f64> =
                contributing.iter().map(|(_, o)| o.sim_time).collect();
            let mut timing = if client_times.is_empty() {
                RoundTiming::idle(self.fleet.deadline)
            } else {
                // Adaptive quorum: substitute the controller's current
                // quorum for the configured one (same ceil/clamp rule).
                let q = overlap
                    .map(|o| match &adaptive {
                        Some(a) => OverlapConfig { quorum: a.quorum(), ..o }
                            .quorum_count(client_times.len()),
                        None => o.quorum_count(client_times.len()),
                    })
                    .unwrap_or(client_times.len());
                let mut sorted = client_times.clone();
                sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite client times"));
                RoundTiming::with_quorum(client_times, sorted[q - 1])
            };
            if churn_dropped > 0 {
                timing.round_time = timing.round_time.max(self.fleet.deadline);
            }
            let sim_time = timing.round_time;
            // The aggregation instant: when this round's quorum (or
            // barrier) is reached on the absolute simulated clock.
            let agg_instant = t_now + sim_time;

            // --- line 15: aggregate. On-time cohort in selection order at
            //     unit weight; late finishers enter the in-flight ledger;
            //     delayed gradients that have arrived fold after the
            //     cohort, ordered by (origin round, slot) and weighted
            //     1/(1+staleness)^alpha, or are discarded past the cap ---
            let mut locals: Vec<&[f32]> = Vec::with_capacity(contributing.len());
            let mut fold_weights: Vec<f64> = Vec::with_capacity(contributing.len());
            for (slot, o) in &contributing {
                if o.sim_time <= sim_time {
                    locals.push(o.params.as_deref().unwrap());
                    fold_weights.push(1.0);
                } else {
                    in_flight.push(DelayedUpdate {
                        origin_round: r,
                        slot: *slot,
                        client: selected[*slot],
                        arrival: t_now + o.sim_time,
                        params: o.params.clone().expect("contributing outcome has params"),
                    });
                }
            }
            let mut stale_folded = 0usize;
            let mut stale_discarded = 0usize;
            let mut stale_weight = 0.0f64;
            let mut distilled = 0usize;
            // Straggler-distillation collection: past-staleness arrivals'
            // (params, decayed weight) pairs, folded into the model after
            // the main aggregate. Stays empty — zero f32 ops — on the
            // default `distill_weight = 0` drop path.
            let mut distill: Vec<(&[f32], f64)> = Vec::new();
            let arrived = in_flight.take_arrived(agg_instant);
            for u in &arrived {
                let ov = overlap.expect("in-flight updates only exist in overlapped mode");
                let staleness = r - u.origin_round;
                if staleness <= ov.max_staleness {
                    let w = ov.weight(staleness);
                    locals.push(&u.params);
                    fold_weights.push(w);
                    stale_folded += 1;
                    stale_weight += w;
                    if let Some(led) = health.as_mut() {
                        led.observe_stale(u.client, staleness);
                    }
                    if traced {
                        obs.record(&Record::Event {
                            name: "stale_fold",
                            round: r,
                            fields: vec![
                                ("origin_round", Json::Num(u.origin_round as f64)),
                                ("client", Json::Num(u.client as f64)),
                                ("staleness", Json::Num(staleness as f64)),
                                ("weight", Json::Num(w)),
                            ],
                        });
                    }
                } else if cfg.distill_weight > 0.0 {
                    // Straggler distillation: the update is too stale for
                    // the main aggregate but not worthless — continue the
                    // staleness-decay curve past the cap, scale by the
                    // distill weight, and fold it into the post-aggregate
                    // correction instead of dropping it.
                    let w = cfg.distill_weight * ov.weight(staleness);
                    distill.push((u.params.as_slice(), w));
                    distilled += 1;
                    if let Some(led) = health.as_mut() {
                        led.observe_stale(u.client, staleness);
                    }
                    if traced {
                        obs.record(&Record::Event {
                            name: "distill_fold",
                            round: r,
                            fields: vec![
                                ("origin_round", Json::Num(u.origin_round as f64)),
                                ("client", Json::Num(u.client as f64)),
                                ("staleness", Json::Num(staleness as f64)),
                                ("weight", Json::Num(w)),
                            ],
                        });
                    }
                } else {
                    stale_discarded += 1;
                    if let Some(led) = health.as_mut() {
                        led.observe_stale(u.client, staleness);
                    }
                    if traced {
                        obs.record(&Record::Event {
                            name: "stale_discard",
                            round: r,
                            fields: vec![
                                ("origin_round", Json::Num(u.origin_round as f64)),
                                ("client", Json::Num(u.client as f64)),
                                ("staleness", Json::Num(staleness as f64)),
                            ],
                        });
                    }
                }
            }
            if let Some(ov) = overlap {
                // Bound the ledger: anything that can no longer fold
                // within the staleness cap — or is still in flight after
                // the final round — is discarded and accounted now.
                // Distillation changes what "doomed" means: past-staleness
                // arrivals fold into the correction instead of dropping,
                // so nothing is doomed until the run ends.
                let mut doomed = if cfg.distill_weight > 0.0 {
                    0
                } else {
                    in_flight.discard_doomed(r, ov.max_staleness)
                };
                if r + 1 == cfg.rounds {
                    doomed += in_flight.discard_all();
                }
                stale_discarded += doomed;
                if traced && doomed > 0 {
                    obs.record(&Record::Event {
                        name: "stale_discard_doomed",
                        round: r,
                        fields: vec![("count", Json::Num(doomed as f64))],
                    });
                }
            }
            if let Some(a) = &mut adaptive {
                a.observe(stale_folded, stale_discarded);
            }
            // The aggregation seam: fold the deterministic contribution
            // sequence through the configured policy. `Mean` is exactly
            // the historical `aggregate_weighted` call.
            let (new_params, agg_stats) = agg.aggregate_round(&params, &locals, &fold_weights);
            if let Some(p) = new_params {
                params = p;
            }
            if !distill.is_empty() {
                // The straggler-distillation correction: blend the
                // collected past-staleness updates into the freshly
                // aggregated model (before any end-of-run flush). RNG-free
                // and gated on a non-empty collection, so the default drop
                // path never touches the parameters.
                params = crate::agg::apply_distilled(&params, &distill);
            }
            if r + 1 == cfg.rounds {
                // End of run: buffered policies flush whatever they still
                // hold so the final model reflects every folded update
                // (a no-op for stateless policies and drained buffers).
                if let Some(p) = agg.flush(&params) {
                    params = p;
                }
            }
            if traced && !agg_stats.is_quiet() {
                obs.record(&Record::Event {
                    name: "agg",
                    round: r,
                    fields: agg_stats
                        .obs_fields()
                        .iter()
                        .map(|&(k, v)| (k, Json::Num(v)))
                        .collect(),
                });
            }
            clock.push_round(timing.clone());
            let agg_w1 = obs.now_ns();
            if traced {
                crate::obs::mem::fold_peak(&mut rss_peak);
            }

            // --- metrics (over the round's own executed clients — a late
            //     finisher did its local training this round even though
            //     its parameters fold later) ---
            // All-dropped rounds have no loss to report: carry the
            // previous round's value forward (NaN only when round 0
            // itself had no contributor) instead of averaging an empty
            // set into a fake 0.0.
            let train_loss = round_train_loss(&outcomes).unwrap_or_else(|| {
                rounds.last().map(|p: &RoundRecord| p.train_loss).unwrap_or(f64::NAN)
            });
            let coreset_clients =
                contributing.iter().filter(|(_, o)| o.used_coreset).count();
            let compressions: Vec<f64> = contributing
                .iter()
                .filter(|(_, o)| o.used_coreset)
                .map(|(_, o)| o.compression)
                .collect();
            let mean_compression = if compressions.is_empty() {
                1.0
            } else {
                crate::util::stats::mean(&compressions)
            };

            // FLANP: widen the active prefix when this round's loss
            // improvement stalls. Pure arithmetic on the recorded loss —
            // no RNG — so seed replay holds; the whole-fleet prefix never
            // widens, keeping the degenerate run's column at zero.
            let mut cohort_widened = 0usize;
            if let Some(st) = flanp.as_mut() {
                if st.observe(train_loss) {
                    cohort_widened = 1;
                    if traced {
                        obs.record(&Record::Event {
                            name: "flanp_widen",
                            round: r,
                            fields: vec![("active", Json::Num(st.active() as f64))],
                        });
                    }
                }
            }

            let do_eval = r % cfg.eval_every == 0 || r + 1 == cfg.rounds;
            let mut eval_wall: Option<(u64, u64)> = None;
            let (test_loss, test_acc) = if do_eval {
                let w0 = obs.now_ns();
                let ev = self.evaluate(&params)?;
                eval_wall = Some((w0, obs.now_ns()));
                if traced {
                    crate::obs::mem::fold_peak(&mut rss_peak);
                }
                (ev.mean_loss(), ev.accuracy())
            } else {
                rounds
                    .last()
                    .map(|p: &RoundRecord| (p.test_loss, p.test_acc))
                    .unwrap_or((f64::NAN, 0.0))
            };

            if cfg.verbose {
                let churn_note = if self.trace.is_some() {
                    format!(" | offline {churn_dropped} ({} selected)", selected.len())
                } else {
                    String::new()
                };
                let overlap_note = if overlap.is_some() {
                    format!(
                        " | stale +{stale_folded}/-{stale_discarded} | in-flight {}",
                        in_flight.len()
                    )
                } else {
                    String::new()
                };
                let agg_note = if agg_stats.rejected + agg_stats.clipped + agg_stats.buffered > 0
                {
                    format!(
                        " | agg rej {} clip {} buf {}",
                        agg_stats.rejected, agg_stats.clipped, agg_stats.buffered
                    )
                } else {
                    String::new()
                };
                crate::obs::warn(
                    obs,
                    "round_progress",
                    Some(r),
                    &format!(
                        "[{}] round {r:>3}: loss {train_loss:.4} | test acc {:.2}% | t/τ {:.2} | dropped {dropped} | coreset {coreset_clients}{churn_note}{overlap_note}{agg_note}",
                        cfg.strategy.label(),
                        100.0 * test_acc,
                        sim_time / self.fleet.deadline,
                    ),
                );
            }

            if traced {
                // Emission order is part of the trace contract: the round
                // span first, then its lifecycle phases in wall order, the
                // counter registry in `Counter::ALL` order, and the round's
                // peak-RSS sample last. Phase wall windows are captured
                // from sequential monotonic reads, so they are disjoint and
                // contained in the round window by construction — the
                // report's nesting check relies on exactly that.
                let round_w1 = obs.now_ns();
                let span = |phase, wall, virt| Record::span(phase, r, wall, virt);
                obs.record(&span(Phase::Round, (round_w0, round_w1), (t_now, agg_instant)));
                obs.record(&span(Phase::Select, (round_w0, select_w1), (t_now, t_now)));
                obs.record(&span(Phase::Dispatch, (select_w1, dispatch_w1), (t_now, t_now)));
                obs.record(&span(Phase::Train, (dispatch_w1, train_w1), (t_now, agg_instant)));
                if coreset_clients > 0 {
                    // Coreset construction happens on the workers inside
                    // the Train window; this span is a non-lifecycle
                    // overlay (the report's nesting check only constrains
                    // the five lifecycle phases).
                    obs.record(&span(
                        Phase::CoresetBuild,
                        (dispatch_w1, train_w1),
                        (t_now, t_now),
                    ));
                }
                obs.record(&span(
                    Phase::Aggregate,
                    (train_w1, agg_w1),
                    (agg_instant, agg_instant),
                ));
                if let Some(wall) = eval_wall {
                    obs.record(&span(Phase::Eval, wall, (agg_instant, agg_instant)));
                }
                let tallies: [(Counter, usize); 12] = [
                    (Counter::Dropped, dropped),
                    (Counter::ChurnDropped, churn_dropped),
                    (Counter::StaleFolded, stale_folded),
                    (Counter::StaleDiscarded, stale_discarded),
                    (Counter::AggRejected, agg_stats.rejected),
                    (Counter::AggClipped, agg_stats.clipped),
                    (Counter::AggBuffered, agg_stats.buffered),
                    (Counter::Steals, dispatch.steals),
                    (Counter::CoresetClients, coreset_clients),
                    (Counter::CoresetWarm, coreset_warm),
                    (Counter::CohortWidened, cohort_widened),
                    (Counter::Distilled, distilled),
                ];
                for (counter, value) in tallies {
                    obs.record(&Record::CounterVal { counter, round: r, value: value as u64 });
                }
                if let Some(m) = rss_peak {
                    obs.record(&Record::Mem {
                        round: r,
                        rss_pages: m.pages,
                        rss_bytes: m.bytes,
                    });
                }
            }

            // Health sampling (after the counters, before the next round's
            // records — `snapshot` position is part of the trace contract).
            // Everything fed here is a deterministic run outcome; nothing
            // the ledger computes flows back into the run.
            if let Some(led) = health.as_mut() {
                for (slot, o) in outcomes.iter().enumerate() {
                    let c = selected[slot];
                    if o.params.is_some() {
                        led.observe_train(c, o.sim_time);
                        if o.used_coreset {
                            led.observe_coreset(c, o.coreset_warm);
                        }
                    } else {
                        // Both churn and deadline drops cost the server
                        // the full τ wait (the timing rule above).
                        led.observe_drop(c, self.fleet.deadline, churn_partial[slot]);
                    }
                }
                // Critical path: the last arrival the server actually
                // waited for (max on-time sim_time; ties break to the
                // smaller client id). Idle rounds have no bounding client.
                let mut bound: Option<(usize, f64)> = None;
                for (slot, o) in &contributing {
                    if o.sim_time > sim_time {
                        continue;
                    }
                    let c = selected[*slot];
                    let better = match bound {
                        None => true,
                        Some((bc, bt)) => o.sim_time > bt || (o.sim_time == bt && c < bc),
                    };
                    if better {
                        bound = Some((c, o.sim_time));
                    }
                }
                obs.record(&Record::Event {
                    name: "round_path",
                    round: r,
                    fields: vec![
                        ("client", Json::Num(bound.map_or(-1.0, |(c, _)| c as f64))),
                        ("client_s", Json::Num(bound.map_or(0.0, |(_, t)| t))),
                        ("quorum_s", Json::Num(sim_time)),
                        ("tail_s", Json::Num(timing.tail_time)),
                    ],
                });
                led.observe_round_end(
                    bound.map(|(c, _)| c),
                    (dispatch.jobs > 0).then_some(dispatch.makespan),
                );
                if led.snapshot_due(r, cfg.rounds) {
                    obs.record(&led.snapshot(r));
                }
            }

            rounds.push(RoundRecord {
                round: r,
                train_loss,
                test_loss,
                test_acc,
                sim_time,
                tail_time: timing.tail_time,
                sim_elapsed: clock.elapsed(),
                client_times: timing.client_times,
                dropped,
                churn_dropped,
                partial_time,
                stale_folded,
                stale_discarded,
                stale_weight,
                agg_rejected: agg_stats.rejected,
                agg_clipped: agg_stats.clipped,
                steal_count: dispatch.steals,
                worker_idle: dispatch.idle_seconds(),
                coreset_clients,
                coreset_warm,
                mean_compression,
                distilled,
                cohort_widened,
            });
        }

        if traced {
            // Drain the executor's placement ledger into per-job and
            // per-worker spans, then stop recording so an untraced run
            // after this one pays nothing.
            if let Some(sched) = self.exec.take_schedule() {
                crate::obs::emit_schedule(obs, &sched);
            }
            self.exec.record_schedule(false);
            // Push the buffered tail to disk before anyone reopens the
            // trace — the CLI appends its checkpoint span through a
            // second handle while this sink is still alive.
            obs.flush();
        }

        Ok(RunResult {
            strategy: cfg.strategy.label().to_string(),
            benchmark: self.ctx.data.model.clone(),
            straggler_pct: cfg.straggler_pct,
            deadline: self.fleet.deadline,
            rounds,
            final_params: params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---------- select_available: the deterministic <K fallback ----------
    // (previously exercised only indirectly through the runtime-gated
    // scenario suites; these pin the edge semantics without a runtime)

    #[test]
    fn select_fallback_under_k_is_index_ordered_and_rng_free() {
        let weights = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let online = vec![4, 1, 3]; // deliberately unsorted input
        let mut rng = Rng::new(9);
        let before = rng.clone();
        let picked = select_available(&mut rng, &weights, &online, 4);
        // Fewer online than K: every online client exactly once, in the
        // order the caller listed them (the engine passes ascending
        // indices), and the RNG must not have been consumed.
        assert_eq!(picked, online);
        let mut a = rng;
        let mut b = before;
        assert_eq!(a.next_u64(), b.next_u64(), "fallback consumed the RNG");
    }

    #[test]
    fn select_exactly_k_online_still_samples() {
        // online.len() == k is NOT the fallback: sampling (with
        // replacement) runs, so duplicates are possible and the RNG moves.
        let weights = vec![1.0; 6];
        let online = vec![0, 2, 4];
        let mut rng = Rng::new(3);
        let before = rng.clone();
        let picked = select_available(&mut rng, &weights, &online, 3);
        assert_eq!(picked.len(), 3);
        assert!(picked.iter().all(|i| online.contains(i)));
        let mut a = rng;
        let mut b = before;
        assert_ne!(a.next_u64(), b.next_u64(), "sampling must consume the RNG");
    }

    #[test]
    fn select_empty_online_is_empty() {
        let mut rng = Rng::new(1);
        assert!(select_available(&mut rng, &[1.0, 1.0], &[], 2).is_empty());
    }

    #[test]
    fn select_degenerate_weights_fall_back_to_uniform() {
        // All-online clients carry zero/negative weight: the sampler must
        // not panic on an all-zero CDF and must still return k picks.
        let weights = vec![0.0, -1.0, 0.0, 5.0];
        let online = vec![0, 1, 2]; // the positive-weight client is offline
        let mut rng = Rng::new(7);
        let picked = select_available(&mut rng, &weights, &online, 2);
        assert_eq!(picked.len(), 2);
        assert!(picked.iter().all(|i| online.contains(i)));
    }

    #[test]
    fn select_single_online_client_fills_every_slot_or_fallbacks() {
        let weights = vec![1.0, 1.0];
        let mut rng = Rng::new(5);
        // k = 1 == online.len(): sampled, always client 1.
        assert_eq!(select_available(&mut rng, &weights, &[1], 1), vec![1]);
        // k = 3 > online.len(): fallback, client 1 exactly once.
        assert_eq!(select_available(&mut rng, &weights, &[1], 3), vec![1]);
    }

    // ---------- aggregate_weighted re-export ----------
    // (the algebra's own tests live with the code in agg/mean.rs; this
    // pins that the historical `fl` re-export path still resolves)

    #[test]
    fn weighted_aggregate_reexport_unit_weights_bitwise_plain() {
        let a = vec![0.125f32, -3.5, 7.75, 0.1];
        let b = vec![1.0f32, 2.0, -0.25, 0.3];
        let locals: Vec<&[f32]> = vec![&a, &b];
        let plain = aggregate(&locals).unwrap();
        let weighted = aggregate_weighted(&locals, &[1.0, 1.0]).unwrap();
        for (x, y) in plain.iter().zip(&weighted) {
            assert_eq!(x.to_bits(), y.to_bits(), "unit weights must degenerate exactly");
        }
    }

    // ---------- availability-aware selection boost (satellite) ----------

    #[test]
    fn boost_zero_returns_weights_bitwise_unchanged() {
        let weights = vec![0.25, 0.5, 0.125, 0.125];
        let uptimes = vec![0.1, 0.9, 0.5, 1.0];
        let out = boost_flaky_weights(&weights, &uptimes, 0.0);
        for (a, b) in weights.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits(), "boost = 0 must be the identity");
        }
    }

    #[test]
    fn boost_normalizes_and_is_deterministic() {
        let weights = vec![0.4, 0.3, 0.2, 0.1];
        let uptimes = vec![1.0, 0.5, 0.2, 0.0];
        let a = boost_flaky_weights(&weights, &uptimes, 2.0);
        let b = boost_flaky_weights(&weights, &uptimes, 2.0);
        assert_eq!(a, b, "boosting must be deterministic");
        let sum: f64 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "boosted weights must sum to 1, got {sum}");
        assert!(a.iter().all(|&w| w >= 0.0));
    }

    #[test]
    fn boost_favors_low_uptime_clients() {
        // Equal base weights, different uptimes: the flakier client must
        // end up with the strictly larger share.
        let weights = vec![0.5, 0.5];
        let uptimes = vec![0.2, 0.9];
        let out = boost_flaky_weights(&weights, &uptimes, 1.5);
        assert!(
            out[0] > out[1],
            "flaky client not oversampled: {} vs {}",
            out[0],
            out[1]
        );
        // A fully-online fleet is boosted uniformly — shares unchanged.
        let flat = boost_flaky_weights(&[0.3, 0.7], &[1.0, 1.0], 1.5);
        assert!((flat[0] - 0.3).abs() < 1e-12 && (flat[1] - 0.7).abs() < 1e-12);
    }

    #[test]
    fn boost_degenerate_weights_fall_back_to_input() {
        // All-zero weights cannot be normalized: return the input as-is
        // (the selector has its own all-zero fallback).
        let weights = vec![0.0, 0.0];
        let out = boost_flaky_weights(&weights, &[0.5, 0.5], 2.0);
        assert_eq!(out, weights);
    }

    // ---------- streamed selection ≡ materialized selection ----------
    // (the differential gate behind the O(cohort) selection path)

    #[test]
    fn select_streamed_matches_flat() {
        let mut rng = Rng::new(0x57E0);
        for case in 0..300usize {
            let n = 1 + rng.below(60);
            let weights: Vec<f64> = (0..n)
                .map(|_| match rng.below(8) {
                    0 => 0.0,
                    1 => -1.0, // clamped to 0 by both paths
                    _ => rng.range_f64(0.05, 9.0),
                })
                .collect();
            let online_mask: Vec<bool> = (0..n).map(|_| rng.f64() < 0.7).collect();
            let online: Vec<usize> = (0..n).filter(|&i| online_mask[i]).collect();
            let k = 1 + rng.below(16);

            let mut flat_rng = rng.split(case as u64);
            let flat = select_available(&mut flat_rng, &weights, &online, k);
            let mut stream_rng = rng.split(case as u64);
            let streamed = select_available_streamed(
                &mut stream_rng,
                |i| weights[i],
                |i| online_mask[i],
                n,
                k,
            );
            assert_eq!(streamed, flat, "case {case}: selections diverged");
            // And the RNG streams must end in the same state (same number
            // of draws consumed) so everything downstream stays aligned.
            assert_eq!(
                flat_rng.next_u64(),
                stream_rng.next_u64(),
                "case {case}: RNG consumption diverged"
            );
        }
    }

    #[test]
    fn select_streamed_all_online_matches_unrestricted_sampler() {
        let mut rng = Rng::new(0x57E1);
        for case in 0..100usize {
            let n = 2 + rng.below(40);
            let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 4.0)).collect();
            let k = 1 + rng.below(n);
            let mut a = rng.split(case as u64);
            let unrestricted = a.weighted_with_replacement(&weights, k);
            let mut b = rng.split(case as u64);
            let streamed =
                select_available_streamed(&mut b, |i| weights[i], |_| true, n, k);
            assert_eq!(streamed, unrestricted, "case {case}");
        }
    }

    #[test]
    fn select_streamed_fallback_is_rng_free_and_index_ordered() {
        let mut rng = Rng::new(4);
        let before = rng.clone();
        let picked = select_available_streamed(
            &mut rng,
            |_| 1.0,
            |i| i % 2 == 0,
            7, // online: 0, 2, 4, 6
            9,
        );
        assert_eq!(picked, vec![0, 2, 4, 6]);
        let mut a = rng;
        let mut b = before;
        assert_eq!(a.next_u64(), b.next_u64(), "fallback consumed the RNG");
        let mut r = Rng::new(5);
        assert!(select_available_streamed(&mut r, |_| 1.0, |_| false, 10, 3).is_empty());
    }

    // ---------- round_train_loss: the all-dropped NaN/0.0 bug ----------

    fn outcome(train_loss: f64, contributed: bool) -> ClientOutcome {
        ClientOutcome {
            params: contributed.then(|| vec![0.0f32; 3]),
            train_loss,
            sim_time: 1.0,
            used_coreset: false,
            compression: 1.0,
            coreset_cost: 0.0,
            coreset_medoids: None,
            coreset_warm: false,
        }
    }

    #[test]
    fn round_loss_survives_all_but_one_churn_dropped() {
        // Five selection slots, four churn-dropped (NaN placeholder, no
        // params): the round's loss is the lone contributor's, exactly.
        let mut outcomes: Vec<ClientOutcome> =
            (0..4).map(|_| outcome(f64::NAN, false)).collect();
        outcomes.push(outcome(0.625, true));
        assert_eq!(round_train_loss(&outcomes), Some(0.625));
    }

    #[test]
    fn round_loss_is_none_when_nobody_contributes() {
        let outcomes: Vec<ClientOutcome> = (0..3).map(|_| outcome(f64::NAN, false)).collect();
        assert_eq!(round_train_loss(&outcomes), None, "all churn-dropped");
        assert_eq!(round_train_loss(&[]), None, "empty selection");
        // A contributor with a non-finite loss is excluded too — it must
        // not poison the mean, and alone it leaves nothing to average.
        let divergent = vec![outcome(f64::INFINITY, true)];
        assert_eq!(round_train_loss(&divergent), None);
    }

    #[test]
    fn round_loss_filters_non_finite_contributors() {
        let outcomes = vec![
            outcome(2.0, true),
            outcome(f64::NAN, true), // divergent client
            outcome(4.0, true),
            outcome(100.0, false), // dropped: params never arrived
        ];
        assert_eq!(round_train_loss(&outcomes), Some(3.0));
    }

    // ---------- static-coreset cache keying (regression) ----------

    #[test]
    fn budget_cache_keys_by_client_and_budget() {
        let cache: BudgetKeyedCache<usize> = BudgetKeyedCache::new();
        let builds = std::cell::Cell::new(0usize);
        let fetch = |client: usize, budget: usize| {
            cache.fetch(client, budget, || {
                builds.set(builds.get() + 1);
                budget * 1000 + client
            })
        };
        // The regression: same client at two budgets must build twice and
        // return budget-specific values (the old client-only key returned
        // the first budget's coreset for both).
        assert_eq!(fetch(3, 10), 10_003);
        assert_eq!(fetch(3, 25), 25_003);
        assert_eq!(builds.get(), 2, "distinct budgets must not share a cache entry");
        // Hits: same (client, budget) never rebuilds.
        assert_eq!(fetch(3, 10), 10_003);
        assert_eq!(fetch(3, 25), 25_003);
        assert_eq!(builds.get(), 2);
        assert_eq!(cache.len(), 2);
        // Distinct clients stay distinct at the same budget.
        assert_eq!(fetch(4, 10), 10_004);
        assert_eq!(builds.get(), 3);
        assert_eq!(cache.len(), 3);
    }
}
