//! Local-work planning: what a selected client does within one round,
//! per strategy, given its capability and the deadline τ.
//!
//! This is pure logic (no runtime), so every deadline/budget invariant is
//! unit- and property-tested exhaustively; the executor in [`super::client`]
//! just follows the plan.

use crate::sim::Fleet;

/// How a client spends its round.
#[derive(Clone, Debug, PartialEq)]
pub enum LocalPlan {
    /// FedAvg-DS: straggler excluded from the round.
    Dropped,
    /// E epochs over the full set (fits τ, or FedAvg ignoring τ).
    FullSet {
        /// Number of full-set epochs.
        epochs: usize,
    },
    /// FedProx: as many full epochs as fit, plus a partial epoch remainder
    /// of `tail_samples` sample-visits.
    Truncated {
        /// Whole epochs that fit the deadline.
        epochs: usize,
        /// Partial-epoch remainder, in sample-visits.
        tail_samples: usize,
    },
    /// FedCore: coreset of size `budget`. `full_first = true` is the normal
    /// path (epoch 1 full-set, E−1 coreset epochs); `false` is the §4.4
    /// extreme-straggler fallback (features from a cheap forward pass, all
    /// E epochs on the coreset).
    Coreset {
        /// Coreset budget bᵢ (samples).
        budget: usize,
        /// True ⇒ epoch 1 runs the full set (the normal §4.2 path).
        full_first: bool,
    },
}

impl LocalPlan {
    /// Total sample-visits of SGD training this plan performs for client
    /// with full-set size `m` and `epochs` configured epochs.
    pub fn training_samples(&self, m: usize, epochs: usize) -> usize {
        match *self {
            LocalPlan::Dropped => 0,
            LocalPlan::FullSet { epochs: e } => e * m,
            LocalPlan::Truncated { epochs: e, tail_samples } => e * m + tail_samples,
            LocalPlan::Coreset { budget, full_first } => {
                if full_first {
                    m + (epochs - 1) * budget.min(m)
                } else {
                    epochs * budget.min(m)
                }
            }
        }
    }

    /// Simulated seconds this plan takes for client `i` of `fleet`.
    /// The §4.4 fallback's forward-only feature pass costs a fraction
    /// [`FEATURE_PASS_COST`] of a training pass over the full set.
    pub fn sim_time(&self, fleet: &Fleet, i: usize) -> f64 {
        let m = fleet.size(i);
        let visits = self.training_samples(m, fleet.epochs) as f64;
        let feature_pass = match *self {
            LocalPlan::Coreset { full_first: false, .. } => FEATURE_PASS_COST * m as f64,
            _ => 0.0,
        };
        (visits + feature_pass) / fleet.profile(i).capability
    }
}

pub use crate::sim::FEATURE_PASS_COST;

/// The four paper strategies (section 6.1 baselines a–c + FedCore).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    /// FedAvg — deadline-oblivious, always full-set.
    FedAvg,
    /// FedAvg-DS — drops clients that cannot finish by τ.
    FedAvgDS,
    /// FedProx — proximal term μ, stragglers do fewer epochs.
    FedProx {
        /// The proximal coefficient μ (paper Table 3 per benchmark).
        mu: f32,
    },
    /// FedCore — stragglers train on a k-medoids coreset.
    FedCore,
}

impl Strategy {
    /// Parse a strategy name (`fedavg` | `fedavg-ds` | `fedprox` |
    /// `fedcore`; case-insensitive, `-`/`_` ignored). FedProx parses with
    /// its default μ = 0.1; config loaders override it afterwards.
    pub fn parse(s: &str) -> Option<Strategy> {
        match s.trim().to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "fedavg" => Some(Strategy::FedAvg),
            "fedavgds" => Some(Strategy::FedAvgDS),
            "fedprox" => Some(Strategy::FedProx { mu: 0.1 }),
            "fedcore" => Some(Strategy::FedCore),
            _ => None,
        }
    }

    /// Display name (paper table row headers).
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::FedAvg => "FedAvg",
            Strategy::FedAvgDS => "FedAvg-DS",
            Strategy::FedProx { .. } => "FedProx",
            Strategy::FedCore => "FedCore",
        }
    }

    /// FedProx's μ (0 elsewhere — the train artifact takes μ as data).
    pub fn mu(&self) -> f32 {
        match self {
            Strategy::FedProx { mu } => *mu,
            _ => 0.0,
        }
    }

    /// Decide client `i`'s plan for this round.
    pub fn plan(&self, fleet: &Fleet, i: usize) -> LocalPlan {
        let e = fleet.epochs;
        if !fleet.is_straggler(i) {
            return LocalPlan::FullSet { epochs: e };
        }
        match self {
            Strategy::FedAvg => LocalPlan::FullSet { epochs: e },
            Strategy::FedAvgDS => LocalPlan::Dropped,
            Strategy::FedProx { .. } => {
                // FedProx truncates at whole-epoch granularity ("fewer
                // local training epochs", §2/§6) — leaving up to m/cᵢ of
                // budget slack, which is why its Table 2 round times sit
                // below FedCore's. A client too slow for even one epoch
                // contributes the partial work that fits (γ-inexact).
                let cap = fleet.profile(i).capability * fleet.deadline;
                let m = fleet.size(i);
                let full = ((cap / m as f64).floor() as usize).min(e);
                if full >= 1 {
                    LocalPlan::Truncated { epochs: full, tail_samples: 0 }
                } else {
                    LocalPlan::Truncated {
                        epochs: 0,
                        tail_samples: (cap.floor() as usize).clamp(1, m),
                    }
                }
            }
            Strategy::FedCore => match fleet.coreset_budget(i) {
                Some(b) => LocalPlan::Coreset { budget: b, full_first: true },
                None => LocalPlan::Coreset { budget: fleet.fallback_budget(i), full_first: false },
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fleet() -> Fleet {
        let mut rng = Rng::new(21);
        let sizes: Vec<usize> = (0..200).map(|i| 30 + (i * 13) % 400).collect();
        Fleet::new(&mut rng, sizes, 10, 30.0)
    }

    #[test]
    fn non_stragglers_always_full_set() {
        let f = fleet();
        for s in [
            Strategy::FedAvg,
            Strategy::FedAvgDS,
            Strategy::FedProx { mu: 0.1 },
            Strategy::FedCore,
        ] {
            for i in 0..f.num_clients() {
                if !f.is_straggler(i) {
                    assert_eq!(s.plan(&f, i), LocalPlan::FullSet { epochs: 10 });
                }
            }
        }
    }

    #[test]
    fn fedavg_ignores_deadline() {
        let f = fleet();
        let mut exceeded = 0;
        for i in 0..f.num_clients() {
            let p = Strategy::FedAvg.plan(&f, i);
            let t = p.sim_time(&f, i);
            if t > f.deadline {
                exceeded += 1;
            }
        }
        // ~30% of clients run past the deadline under FedAvg.
        assert!(exceeded >= 40, "only {exceeded} clients exceeded");
    }

    #[test]
    fn deadline_aware_plans_fit_tau() {
        let f = fleet();
        for s in [Strategy::FedAvgDS, Strategy::FedProx { mu: 0.1 }, Strategy::FedCore] {
            for i in 0..f.num_clients() {
                let p = s.plan(&f, i);
                let t = p.sim_time(&f, i);
                // flooring slack (one sample per epoch), plus the clamped
                // minimum work of pathologically slow clients: both FedProx
                // (≥1 sample) and FedCore (≥1-sample coreset + feature pass)
                // insist on a floor of useful work, like the paper's §4.4.
                let min_work = match p {
                    LocalPlan::Coreset { full_first: false, .. } => {
                        (f.epochs as f64 + FEATURE_PASS_COST * f.size(i) as f64)
                            / f.profile(i).capability
                    }
                    _ => 0.0,
                };
                let slack = f.epochs as f64 / f.profile(i).capability;
                assert!(
                    t <= (f.deadline + slack).max(min_work + 1e-9),
                    "{}: client {i} time {t} > τ {} (min_work {min_work})",
                    s.label(),
                    f.deadline
                );
            }
        }
    }

    #[test]
    fn fedcore_stragglers_get_compressed_coresets() {
        let f = fleet();
        let mut coreset_count = 0;
        for i in 0..f.num_clients() {
            if let LocalPlan::Coreset { budget, full_first } = Strategy::FedCore.plan(&f, i) {
                coreset_count += 1;
                assert!(budget >= 1);
                if full_first {
                    assert!(budget < f.size(i));
                }
            }
        }
        let frac = coreset_count as f64 / f.num_clients() as f64;
        assert!((frac - 0.3).abs() < 0.05, "coreset fraction {frac}");
    }

    #[test]
    fn fedprox_partial_epochs_monotone_in_capability() {
        let f = fleet();
        // A straggler's planned visits never exceed the full-set visits.
        for i in 0..f.num_clients() {
            let p = Strategy::FedProx { mu: 0.1 }.plan(&f, i);
            let v = p.training_samples(f.size(i), f.epochs);
            assert!(v <= f.epochs * f.size(i));
            if f.is_straggler(i) {
                assert!(v < f.epochs * f.size(i), "straggler {i} not truncated");
            }
        }
    }

    #[test]
    fn training_samples_arithmetic() {
        assert_eq!(LocalPlan::Dropped.training_samples(100, 10), 0);
        assert_eq!(LocalPlan::FullSet { epochs: 10 }.training_samples(100, 10), 1000);
        assert_eq!(
            LocalPlan::Truncated { epochs: 3, tail_samples: 40 }.training_samples(100, 10),
            340
        );
        assert_eq!(
            LocalPlan::Coreset { budget: 20, full_first: true }.training_samples(100, 10),
            100 + 9 * 20
        );
        assert_eq!(
            LocalPlan::Coreset { budget: 20, full_first: false }.training_samples(100, 10),
            200
        );
    }

    #[test]
    fn parse_labels() {
        for s in ["FedAvg", "fedavg-ds", "FEDPROX", "fed_core"] {
            assert!(Strategy::parse(s).is_some(), "{s}");
        }
        assert_eq!(Strategy::parse("FedAvg-DS"), Some(Strategy::FedAvgDS));
        assert!(Strategy::parse("sgd").is_none());
    }
}
