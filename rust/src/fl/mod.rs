//! Federated learning core: the paper's Algorithm 1.
//!
//! * [`plan`] — per-strategy local-work planning (pure logic).
//! * [`client`] — plan execution against the PJRT runtime.
//! * [`engine`] — the round loop: selection (pluggable straggler-aware
//!   cohort policies — [`crate::scenario::selection`]), aggregation,
//!   metrics; dispatches client work through a [`crate::exec::Executor`]
//!   (sequential or sharded across runtime-pinned workers).

pub mod checkpoint;
pub mod client;
pub mod engine;
pub mod plan;

pub use checkpoint::Checkpoint;

pub use client::{run_client, ClientOutcome};
pub use engine::{
    aggregate, aggregate_weighted, boost_flaky_weights, select_available,
    select_available_streamed, CoresetMode, Engine, RunConfig,
};
pub use plan::{LocalPlan, Strategy};

/// All four strategies in paper presentation order.
pub fn all_strategies(prox_mu: f32) -> Vec<Strategy> {
    vec![
        Strategy::FedAvg,
        Strategy::FedAvgDS,
        Strategy::FedProx { mu: prox_mu },
        Strategy::FedCore,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_order_matches_paper_tables() {
        let s = all_strategies(0.1);
        assert_eq!(
            s.iter().map(|x| x.label()).collect::<Vec<_>>(),
            vec!["FedAvg", "FedAvg-DS", "FedProx", "FedCore"]
        );
    }
}
