//! Client-side execution: carry out a [`LocalPlan`] against the PJRT
//! runtime — minibatch SGD epochs, gradient-feature extraction, coreset
//! construction, and coreset-weighted training (paper Algorithm 1 lines
//! 6–13).
//!
//! [`run_client`] is thread-agnostic: it takes the runtime to execute
//! against as an argument and owns no global state, which is what lets
//! [`crate::exec::Sharded`] run many clients concurrently, each on its
//! worker's pinned runtime, with per-job RNG streams.

use anyhow::Result;

use super::plan::LocalPlan;
use crate::coreset::{self, Coreset, DistMatrix, Method};
use crate::data::Shard;
use crate::runtime::{ModelInfo, Runtime};
use crate::sim::Fleet;
use crate::util::rng::Rng;

/// Below this set size the pure-CPU distance path beats tile padding; the
/// Pallas tile is 128×128, so tiny clients would waste >90% of each call.
pub const TILED_DIST_MIN: usize = 96;

/// What a client hands back to the server at the end of a round.
#[derive(Clone, Debug)]
pub struct ClientOutcome {
    /// Round-end local parameters (None ⇒ dropped, nothing to aggregate).
    pub params: Option<Vec<f32>>,
    /// Mean training loss over the final epoch's batches.
    pub train_loss: f64,
    /// Simulated seconds spent (from the plan).
    pub sim_time: f64,
    /// Whether a coreset was built this round.
    pub used_coreset: bool,
    /// Coreset compression b/m (1.0 when training full-set).
    pub compression: f64,
    /// k-medoids objective of the built coreset (0 when unused).
    pub coreset_cost: f64,
    /// Medoid indices of an adaptively built coreset — the engine caches
    /// them per client to warm-start the next round's SWAP sweeps (§4.3
    /// incremental path). `None` when no adaptive coreset was built.
    pub coreset_medoids: Option<Vec<usize>>,
    /// Whether this round's coreset warm-started from cached medoids.
    pub coreset_warm: bool,
}

/// One epoch of minibatch SGD over `idxs` (with optional per-sample δ
/// weights aligned to `idxs`). Returns the mean batch loss.
#[allow(clippy::too_many_arguments)]
fn run_epoch(
    rt: &Runtime,
    model: &ModelInfo,
    shard: &Shard,
    global: &[f32],
    params: &mut Vec<f32>,
    idxs: &[usize],
    deltas: Option<&[f32]>,
    lr: f32,
    mu: f32,
    limit: Option<usize>,
) -> Result<f64> {
    let b = rt.manifest().train_batch;
    let take = limit.unwrap_or(idxs.len()).min(idxs.len());
    let mut loss_sum = 0.0f64;
    let mut batches = 0usize;
    let mut start = 0usize;
    while start < take {
        let end = (start + b).min(take);
        let chunk = &idxs[start..end];
        let chunk_deltas: Option<Vec<f32>> =
            deltas.map(|d| (start..end).map(|i| d[i]).collect());
        let (x, y, w) = shard.gather_batch(chunk, chunk_deltas.as_deref(), b);
        let out = rt.train_step(model, params, global, &x, &y, &w, lr, mu)?;
        *params = out.params;
        loss_sum += out.loss as f64;
        batches += 1;
        start = end;
    }
    Ok(if batches > 0 { loss_sum / batches as f64 } else { f64::NAN })
}

/// Per-sample gradient features for the whole shard (the §4.3 d̂ inputs),
/// batched through the `feat` artifact; returns row-major [m, feature_dim].
pub fn gather_features(
    rt: &Runtime,
    model: &ModelInfo,
    shard: &Shard,
    params: &[f32],
) -> Result<Vec<f32>> {
    let f = rt.manifest().feat_batch;
    let c = rt.manifest().feature_dim;
    let m = shard.len();
    let mut features = vec![0.0f32; m * c];
    let idxs: Vec<usize> = (0..m).collect();
    let mut start = 0usize;
    while start < m {
        let end = (start + f).min(m);
        let chunk = &idxs[start..end];
        let (x, y, _) = shard.gather_batch(chunk, None, f);
        let out = rt.grad_features(model, params, &x, &y)?;
        let rows = end - start;
        features[start * c..end * c].copy_from_slice(&out.features[..rows * c]);
        start = end;
    }
    Ok(features)
}

/// Build the round's coreset: features → pairwise distances (Pallas-tiled
/// when the set is big enough to fill tiles) → k-medoids.
///
/// `warm` re-runs only the SWAP sweeps on a cached medoid set (falling
/// back to a cold solve when the cache is unusable); `workers` shards the
/// CPU distance path and the FasterPAM scans — both bit-identical to the
/// sequential path at any count.
#[allow(clippy::too_many_arguments)]
pub fn build_coreset(
    rt: &Runtime,
    model: &ModelInfo,
    shard: &Shard,
    params: &[f32],
    budget: usize,
    method: Method,
    warm: Option<&[usize]>,
    workers: usize,
    rng: &mut Rng,
) -> Result<Coreset> {
    let m = shard.len();
    let features = gather_features(rt, model, shard, params)?;
    let dist = build_dist_par(rt, &features, m, workers)?;
    Ok(match warm {
        Some(cached) => coreset::select_warm(&dist, budget, method, cached, rng, workers),
        None => coreset::select_par(&dist, budget, method, rng, workers),
    })
}

/// Distance-matrix dispatch: Pallas tile path for large sets, CPU otherwise.
pub fn build_dist(rt: &Runtime, features: &[f32], m: usize) -> Result<DistMatrix> {
    build_dist_par(rt, features, m, 1)
}

/// [`build_dist`] with the CPU fallback path blocked into the same 128²
/// tiles the Pallas artifact uses and sharded over `workers` threads.
pub fn build_dist_par(
    rt: &Runtime,
    features: &[f32],
    m: usize,
    workers: usize,
) -> Result<DistMatrix> {
    let c = rt.manifest().feature_dim;
    if m >= TILED_DIST_MIN {
        coreset::distance::from_features_tiled(rt, features, m)
    } else {
        Ok(coreset::distance::from_features_cpu_par(features, m, c, workers))
    }
}

/// §4.3 static (input-space) features for the convex-model path: dense
/// inputs are used as-is (d̃ⱼₖ = ‖xⱼ − xₖ‖); token sequences are summarized
/// by their character histogram, the natural input-space geometry for a
/// bag-of-chars view. Returns (features, dim).
pub fn static_features(shard: &Shard, vocab_size: usize) -> (Vec<f32>, usize) {
    match &shard.samples {
        crate::data::Samples::Dense { x, dim } => (x.clone(), *dim),
        crate::data::Samples::Tokens { x, seq } => {
            let m = shard.len();
            let mut out = vec![0.0f32; m * vocab_size];
            for s in 0..m {
                for k in 0..*seq {
                    let id = x[s * seq + k] as usize;
                    if id < vocab_size {
                        out[s * vocab_size + id] += 1.0 / *seq as f32;
                    }
                }
            }
            (out, vocab_size)
        }
    }
}

/// Build the §4.3 *static* coreset once per client: input-space distances,
/// no model in the loop, reusable across every round (budgets are fixed
/// because cᵢ, mᵢ, τ are).
pub fn build_static_coreset(
    shard: &Shard,
    vocab_size: usize,
    budget: usize,
    method: Method,
    rng: &mut Rng,
) -> Coreset {
    let m = shard.len();
    let (features, dim) = static_features(shard, vocab_size);
    let dist = coreset::distance::from_inputs_static(&features, m, dim);
    coreset::select(&dist, budget, method, rng)
}

/// Whether a cached medoid set can actually warm-start [`build_coreset`]
/// (mirrors the [`coreset::select_warm`] fallback conditions), so the
/// engine's `coreset_warm` diagnostics count true warm starts only.
pub fn warm_cache_usable(cached: &[usize], budget: usize, m: usize, method: Method) -> bool {
    if method != Method::FasterPam || m == 0 || budget >= m {
        return false;
    }
    let mut seed: Vec<usize> = cached.iter().copied().filter(|&i| i < m).collect();
    seed.sort_unstable();
    seed.dedup();
    seed.len() == budget.max(1)
}

/// Execute `plan` for one client and return its round outcome.
///
/// `precomputed` short-circuits coreset construction with a cached §4.3
/// static coreset (the engine owns the per-client cache); `None` runs the
/// paper's default adaptive path — fresh gradient features every round.
/// `warm_medoids` (adaptive path only) seeds the solver with the client's
/// previous medoids so only SWAP sweeps re-run; `coreset_workers` shards
/// the distance/solver hot path (bit-identical at any count).
#[allow(clippy::too_many_arguments)]
pub fn run_client(
    rt: &Runtime,
    model: &ModelInfo,
    shard: &Shard,
    fleet: &Fleet,
    client: usize,
    global: &[f32],
    plan: &LocalPlan,
    lr: f32,
    mu: f32,
    method: Method,
    precomputed: Option<&Coreset>,
    warm_medoids: Option<&[usize]>,
    coreset_workers: usize,
    rng: &mut Rng,
) -> Result<ClientOutcome> {
    let m = shard.len();
    let sim_time = plan.sim_time(fleet, client);
    let epochs = fleet.epochs;

    let mut shuffled: Vec<usize> = (0..m).collect();
    let mut params = global.to_vec();
    let mut loss = f64::NAN;

    match *plan {
        LocalPlan::Dropped => {
            return Ok(ClientOutcome {
                params: None,
                train_loss: f64::NAN,
                sim_time,
                used_coreset: false,
                compression: 1.0,
                coreset_cost: 0.0,
                coreset_medoids: None,
                coreset_warm: false,
            });
        }
        LocalPlan::FullSet { epochs: e } => {
            for _ in 0..e {
                rng.shuffle(&mut shuffled);
                loss = run_epoch(rt, model, shard, global, &mut params, &shuffled, None, lr, mu, None)?;
            }
        }
        LocalPlan::Truncated { epochs: e, tail_samples } => {
            for _ in 0..e {
                rng.shuffle(&mut shuffled);
                loss = run_epoch(rt, model, shard, global, &mut params, &shuffled, None, lr, mu, None)?;
            }
            if tail_samples > 0 {
                rng.shuffle(&mut shuffled);
                let tail_loss = run_epoch(
                    rt, model, shard, global, &mut params, &shuffled, None, lr, mu,
                    Some(tail_samples),
                )?;
                if loss.is_nan() {
                    loss = tail_loss;
                }
            }
        }
        LocalPlan::Coreset { budget, full_first } => {
            // Epoch 1 (normal path): a comprehensive full-set step — also the
            // pass whose per-sample gradients feed the coreset (§4.1/Fig. 1).
            if full_first {
                rng.shuffle(&mut shuffled);
                loss = run_epoch(rt, model, shard, global, &mut params, &shuffled, None, lr, mu, None)?;
            }
            // Warm seeds only count when they would actually be used (the
            // solver falls back cold otherwise — same RNG, same result).
            let warm = warm_medoids.filter(|w| warm_cache_usable(w, budget, m, method));
            let cs = match precomputed {
                Some(c) => c.clone(),
                None => build_coreset(
                    rt, model, shard, &params, budget, method, warm, coreset_workers, rng,
                )?,
            };
            let adaptive = precomputed.is_none();
            // δ-weighted SGD on the coreset for the remaining epochs.
            let remaining = if full_first { epochs - 1 } else { epochs };
            let mut order: Vec<usize> = (0..cs.indices.len()).collect();
            for _ in 0..remaining {
                rng.shuffle(&mut order);
                let idxs: Vec<usize> = order.iter().map(|&o| cs.indices[o]).collect();
                let deltas: Vec<f32> = order.iter().map(|&o| cs.deltas[o]).collect();
                loss = run_epoch(
                    rt, model, shard, global, &mut params, &idxs, Some(&deltas), lr, mu, None,
                )?;
            }
            return Ok(ClientOutcome {
                params: Some(params),
                train_loss: loss,
                sim_time,
                used_coreset: true,
                compression: (cs.len() as f64 / m.max(1) as f64).min(1.0),
                coreset_cost: cs.cost,
                coreset_medoids: adaptive.then(|| cs.indices.clone()),
                coreset_warm: adaptive && warm.is_some(),
            });
        }
    }

    Ok(ClientOutcome {
        params: Some(params),
        train_loss: loss,
        sim_time,
        used_coreset: false,
        compression: 1.0,
        coreset_cost: 0.0,
        coreset_medoids: None,
        coreset_warm: false,
    })
}
