//! Model checkpointing: persist/restore the global parameter vector so
//! long runs (paper scale: 100 rounds × 1,000 clients) can resume, and so
//! trained models can be handed to the serving/eval paths.
//!
//! Format: little-endian binary, versioned and checksummed —
//! `FEDC | u32 version | u64 model-name-len | name | u64 round |
//!  u64 param-count | f32×N | u64 fnv1a-checksum`.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"FEDC";
const VERSION: u32 = 1;

/// A saved training state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Manifest model key ("logreg" | "mnist" | "shake").
    pub model: String,
    /// Rounds completed when saved.
    pub round: u64,
    /// The global parameter vector wᵣ.
    pub params: Vec<f32>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn param_bytes(params: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(params.len() * 4);
    for p in params {
        out.extend_from_slice(&p.to_le_bytes());
    }
    out
}

impl Checkpoint {
    /// Bundle a training state for saving.
    pub fn new(model: impl Into<String>, round: u64, params: Vec<f32>) -> Checkpoint {
        Checkpoint { model: model.into(), round, params }
    }

    /// Write the checkpoint (creating parent directories as needed).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        let name = self.model.as_bytes();
        f.write_all(&(name.len() as u64).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&self.round.to_le_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        let pb = param_bytes(&self.params);
        f.write_all(&pb)?;
        f.write_all(&fnv1a(&pb).to_le_bytes())?;
        Ok(())
    }

    /// Read and verify (magic, version, checksum) a checkpoint file.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not a FedCore checkpoint", path.display());
        }
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let version = u32::from_le_bytes(u32b);
        if version != VERSION {
            bail!("{}: unsupported checkpoint version {version}", path.display());
        }
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        let name_len = u64::from_le_bytes(u64b) as usize;
        if name_len > 256 {
            bail!("{}: implausible model-name length {name_len}", path.display());
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        f.read_exact(&mut u64b)?;
        let round = u64::from_le_bytes(u64b);
        f.read_exact(&mut u64b)?;
        let count = u64::from_le_bytes(u64b) as usize;
        if count > (1 << 30) {
            bail!("{}: implausible parameter count {count}", path.display());
        }
        let mut pb = vec![0u8; count * 4];
        f.read_exact(&mut pb)?;
        f.read_exact(&mut u64b)?;
        let want = u64::from_le_bytes(u64b);
        let got = fnv1a(&pb);
        if want != got {
            bail!("{}: checksum mismatch (corrupted checkpoint)", path.display());
        }
        let params = pb
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint {
            model: String::from_utf8(name).context("model name not utf-8")?,
            round,
            params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fedcore_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let ck = Checkpoint::new("logreg", 42, vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE]);
        let path = tmp("roundtrip");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn detects_corruption() {
        let ck = Checkpoint::new("mnist", 1, vec![1.0; 64]);
        let path = tmp("corrupt");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_params_ok() {
        let ck = Checkpoint::new("logreg", 0, vec![]);
        let path = tmp("empty");
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().params.len(), 0);
        std::fs::remove_file(&path).ok();
    }
}
