//! Property-based invariant suites (seeded runner in util::prop; offline
//! build, no proptest crate — see DESIGN.md "Offline-build note").
//!
//! Coordinator invariants (DESIGN.md §5): aggregation algebra, client
//! sampling distribution, coreset weight/size/cost invariants, FasterPAM
//! vs BUILD monotonicity, deadline-awareness of every plan, and distance-
//! matrix metric properties.

use fedcore::coreset::{self, distance, fasterpam, Method};
use fedcore::data::{self, Benchmark};
use fedcore::fl::{aggregate, LocalPlan, Strategy};
use fedcore::sim::Fleet;
use fedcore::util::prop::check;
use fedcore::util::rng::Rng;

// ---------- aggregation ----------

#[test]
fn prop_aggregation_preserves_dimension_and_mean() {
    check("agg-dim-mean", 0xA6, 50, |rng, _| {
        let k = 1 + rng.below(8);
        let dim = 1 + rng.below(64);
        let locals: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = locals.iter().map(|v| v.as_slice()).collect();
        let agg = aggregate(&refs).unwrap();
        assert_eq!(agg.len(), dim);
        // mean of column 0 matches
        let want: f64 = locals.iter().map(|l| l[0] as f64).sum::<f64>() / k as f64;
        assert!((agg[0] as f64 - want).abs() < 1e-5);
    });
}

#[test]
fn prop_aggregation_is_permutation_invariant() {
    check("agg-perm", 0xA7, 50, |rng, _| {
        let k = 2 + rng.below(6);
        let dim = 1 + rng.below(32);
        let mut locals: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = locals.iter().map(|v| v.as_slice()).collect();
        let a = aggregate(&refs).unwrap();
        rng.shuffle(&mut locals);
        let refs: Vec<&[f32]> = locals.iter().map(|v| v.as_slice()).collect();
        let b = aggregate(&refs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-6 * (1.0 + x.abs()), "{x} vs {y}");
        }
    });
}

#[test]
fn prop_aggregation_of_identical_params_is_identity() {
    check("agg-ident", 0xA8, 30, |rng, _| {
        let dim = 1 + rng.below(100);
        let p: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let refs: Vec<&[f32]> = (0..5).map(|_| p.as_slice()).collect();
        let agg = aggregate(&refs).unwrap();
        for (a, b) in agg.iter().zip(&p) {
            assert!((a - b).abs() < 1e-6);
        }
    });
}

#[test]
fn aggregate_empty_returns_none() {
    assert!(aggregate(&[]).is_none());
}

// ---------- client sampling ----------

#[test]
fn prop_client_sampling_tracks_weights() {
    check("sampling", 0xB1, 8, |rng, _| {
        let n = 3 + rng.below(20);
        let weights: Vec<f64> = (0..n).map(|_| rng.f64() + 0.01).collect();
        let total: f64 = weights.iter().sum();
        let draws = 30_000;
        let picks = rng.weighted_with_replacement(&weights, draws);
        let mut counts = vec![0usize; n];
        for p in picks {
            counts[p] += 1;
        }
        for i in 0..n {
            let want = weights[i] / total;
            let got = counts[i] as f64 / draws as f64;
            assert!(
                (got - want).abs() < 0.03 + 0.15 * want,
                "client {i}: got {got:.4}, want {want:.4}"
            );
        }
    });
}

// ---------- coresets ----------

fn random_features(rng: &mut Rng, n: usize, dim: usize) -> Vec<f32> {
    (0..n * dim).map(|_| rng.normal() as f32).collect()
}

#[test]
fn prop_coreset_weights_sum_to_m() {
    check("delta-sum", 0xC1, 30, |rng, _| {
        let n = 5 + rng.below(80);
        let dim = 2 + rng.below(16);
        let f = random_features(rng, n, dim);
        let dist = distance::from_features_cpu(&f, n, dim);
        let k = 1 + rng.below(n);
        for method in [Method::FasterPam, Method::Random, Method::GreedyKCenter] {
            let cs = coreset::select(&dist, k, method, rng);
            assert_eq!(
                cs.total_weight() as usize,
                n,
                "{method:?}: Σδ = {} ≠ m = {n}",
                cs.total_weight()
            );
        }
    });
}

#[test]
fn prop_coreset_size_respects_budget() {
    check("size-budget", 0xC2, 30, |rng, _| {
        let n = 5 + rng.below(60);
        let f = random_features(rng, n, 4);
        let dist = distance::from_features_cpu(&f, n, 4);
        let k = 1 + rng.below(2 * n); // may exceed n on purpose
        let cs = coreset::select(&dist, k, Method::FasterPam, rng);
        assert!(cs.len() <= k.min(n) .max(1));
        assert!(cs.indices.iter().all(|&i| i < n));
        // indices strictly ascending (sorted, deduped)
        assert!(cs.indices.windows(2).all(|w| w[0] < w[1]));
    });
}

#[test]
fn prop_fasterpam_cost_never_above_build() {
    check("fp-vs-build", 0xC3, 20, |rng, _| {
        let n = 10 + rng.below(60);
        let f = random_features(rng, n, 4);
        let dist = distance::from_features_cpu(&f, n, 4);
        let k = 1 + rng.below(n / 2);
        let build_cost = coreset::objective(&dist, &{
            // BUILD via one FasterPAM entry with zero swap iterations is not
            // exposed; emulate by comparing to the library result from a
            // different seed — instead use the public invariant:
            fasterpam::solve(&dist, k, rng)
        });
        // Re-running with another RNG stream must land at the same or a
        // comparable local optimum (cost is a deterministic function of the
        // medoid set, and eager swap only ever decreases it).
        let again = coreset::objective(&dist, &fasterpam::solve(&dist, k, rng));
        let lo = build_cost.min(again);
        let hi = build_cost.max(again);
        assert!(hi <= lo * 1.2 + 1e-9, "unstable optima: {lo} vs {hi}");
    });
}

#[test]
fn prop_kmedoids_beats_mean_random_subset() {
    check("fp-vs-random", 0xC4, 15, |rng, _| {
        let n = 20 + rng.below(60);
        let f = random_features(rng, n, 4);
        let dist = distance::from_features_cpu(&f, n, 4);
        let k = 2 + rng.below(n / 4);
        let fp = coreset::select(&dist, k, Method::FasterPam, rng).cost;
        let mut rnd_sum = 0.0;
        const TRIES: usize = 8;
        for _ in 0..TRIES {
            rnd_sum += coreset::select(&dist, k, Method::Random, rng).cost;
        }
        assert!(
            fp <= rnd_sum / TRIES as f64 + 1e-9,
            "FasterPAM {fp} above mean random {}",
            rnd_sum / TRIES as f64
        );
    });
}

#[test]
fn prop_coreset_cost_monotone_in_budget() {
    check("cost-monotone", 0xC5, 15, |rng, _| {
        let n = 20 + rng.below(40);
        let f = random_features(rng, n, 4);
        let dist = distance::from_features_cpu(&f, n, 4);
        let k1 = 1 + rng.below(n / 3);
        let k2 = k1 + 1 + rng.below(n / 3);
        let c1 = coreset::select(&dist, k1, Method::FasterPam, rng).cost;
        let c2 = coreset::select(&dist, k2, Method::FasterPam, rng).cost;
        // More budget ⇒ no worse objective (local search noise tolerance 5%).
        assert!(c2 <= c1 * 1.05 + 1e-9, "k={k1}:{c1} vs k={k2}:{c2}");
    });
}

// ---------- distance matrices ----------

#[test]
fn prop_distance_matrix_is_a_metric() {
    check("metric", 0xD1, 20, |rng, _| {
        let n = 3 + rng.below(30);
        let dim = 1 + rng.below(8);
        let f = random_features(rng, n, dim);
        let d = distance::from_features_cpu(&f, n, dim);
        assert_eq!(d.asymmetry(), 0.0);
        for i in 0..n {
            assert_eq!(d.get(i, i), 0.0);
        }
        // random triangle triples
        for _ in 0..10 {
            let (a, b, c) = (rng.below(n), rng.below(n), rng.below(n));
            assert!(d.get(a, c) <= d.get(a, b) + d.get(b, c) + 1e-4);
        }
    });
}

// ---------- plans / deadlines ----------

fn random_fleet(rng: &mut Rng) -> Fleet {
    let n = 20 + rng.below(150);
    let sizes: Vec<usize> = (0..n).map(|_| 10 + rng.below(300)).collect();
    let epochs = 2 + rng.below(12);
    let s = [10.0, 30.0][rng.below(2)];
    let mut frng = rng.split(99);
    Fleet::new(&mut frng, sizes, epochs, s)
}

#[test]
fn prop_deadline_aware_plans_fit_tau_modulo_floors() {
    check("plans-tau", 0xE1, 25, |rng, _| {
        let fleet = random_fleet(rng);
        for strategy in [Strategy::FedAvgDS, Strategy::FedProx { mu: 0.1 }, Strategy::FedCore] {
            for i in 0..fleet.num_clients() {
                let p = strategy.plan(&fleet, i);
                let t = p.sim_time(&fleet, i);
                let per_sample = 1.0 / fleet.profile(i).capability;
                // floors: one sample per epoch of rounding slack, plus the
                // clamped minimum work of pathological clients.
                let min_work = match p {
                    LocalPlan::Coreset { full_first: false, budget } => {
                        (fleet.epochs * budget) as f64 * per_sample
                            + fedcore::sim::FEATURE_PASS_COST * fleet.size(i) as f64 * per_sample
                    }
                    LocalPlan::Truncated { epochs: 0, tail_samples } => {
                        tail_samples as f64 * per_sample
                    }
                    _ => 0.0,
                };
                let slack = fleet.epochs as f64 * per_sample;
                assert!(
                    t <= (fleet.deadline + slack).max(min_work + 1e-9),
                    "{} client {i}: t {t} τ {} min {min_work}",
                    strategy.label(),
                    fleet.deadline
                );
            }
        }
    });
}

#[test]
fn prop_fedcore_plan_work_never_exceeds_fullset() {
    check("fedcore-work", 0xE2, 25, |rng, _| {
        let fleet = random_fleet(rng);
        for i in 0..fleet.num_clients() {
            let p = Strategy::FedCore.plan(&fleet, i);
            let visits = p.training_samples(fleet.size(i), fleet.epochs);
            assert!(visits <= fleet.epochs * fleet.size(i) + fleet.epochs);
        }
    });
}

#[test]
fn prop_straggler_fraction_matches_setting() {
    check("straggler-frac", 0xE3, 10, |rng, _| {
        let n = 400;
        let sizes: Vec<usize> = (0..n).map(|_| 10 + rng.below(300)).collect();
        let s = [10.0, 30.0][rng.below(2)];
        let mut frng = rng.split(1);
        let fleet = Fleet::new(&mut frng, sizes, 10, s);
        let frac = fleet.straggler_fraction();
        assert!(
            (frac - s / 100.0).abs() < 0.03,
            "s = {s}: observed {frac}"
        );
    });
}

// ---------- checkpoints ----------

#[test]
fn prop_checkpoint_roundtrips_any_params() {
    check("ckpt-roundtrip", 0xCC1, 20, |rng, case| {
        let n = rng.below(512);
        let params: Vec<f32> = (0..n)
            .map(|_| match rng.below(5) {
                0 => 0.0,
                1 => f32::MIN_POSITIVE,
                2 => -1e30,
                _ => rng.normal() as f32,
            })
            .collect();
        let ck = fedcore::fl::Checkpoint::new("logreg", case as u64, params);
        let path = std::env::temp_dir()
            .join(format!("fedcore_prop_ckpt_{}_{case}", std::process::id()));
        ck.save(&path).unwrap();
        let back = fedcore::fl::Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_checkpoint_load_never_panics_on_garbage() {
    check("ckpt-garbage", 0xCC2, 25, |rng, case| {
        let n = rng.below(200);
        let mut bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        // half the cases: corrupt a valid prefix instead of pure noise
        if case % 2 == 0 {
            let mut prefix = b"FEDC".to_vec();
            prefix.extend_from_slice(&1u32.to_le_bytes());
            prefix.extend(bytes.iter());
            bytes = prefix;
        }
        let path = std::env::temp_dir()
            .join(format!("fedcore_prop_garb_{}_{case}", std::process::id()));
        std::fs::write(&path, &bytes).unwrap();
        // the property: loading arbitrary bytes returns Err (or, vanishingly
        // unlikely, a valid parse) — it must never panic or over-allocate.
        let _ = fedcore::fl::Checkpoint::load(&path);
        std::fs::remove_file(&path).ok();
    });
}

// ---------- static (§4.3) features ----------

#[test]
fn prop_static_features_shapes_and_mass() {
    use fedcore::data::{Samples, Shard};
    check("static-feat", 0xDF1, 20, |rng, _| {
        let vocab = 64usize;
        let seq = 1 + rng.below(30);
        let m = 1 + rng.below(40);
        let x: Vec<i32> = (0..m * seq).map(|_| rng.below(vocab) as i32).collect();
        let shard = Shard {
            samples: Samples::Tokens { x, seq },
            labels: vec![0; m * seq],
        };
        let (f, dim) = fedcore::fl::client::static_features(&shard, vocab);
        assert_eq!(dim, vocab);
        assert_eq!(f.len(), m * vocab);
        // each histogram row sums to 1 (seq positions / seq)
        for s in 0..m {
            let sum: f32 = f[s * vocab..(s + 1) * vocab].iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {s} sums to {sum}");
        }
    });
}

// ---------- SVG rendering ----------

#[test]
fn prop_svg_never_emits_nan_and_stays_well_formed() {
    use fedcore::metrics::svg::{line_chart, Series};
    check("svg", 0xE5F, 20, |rng, _| {
        let n_series = 1 + rng.below(4);
        let series: Vec<Series> = (0..n_series)
            .map(|i| {
                let pts: Vec<(f64, f64)> = (0..rng.below(30))
                    .map(|t| {
                        let y = match rng.below(6) {
                            0 => f64::NAN,
                            1 => 0.0,
                            _ => rng.normal() * 100.0,
                        };
                        (t as f64, y)
                    })
                    .collect();
                Series::new(format!("s{i}"), pts)
            })
            .collect();
        let svg = line_chart("t", "x", "y", &series);
        assert!(svg.starts_with("<svg") && svg.trim_end().ends_with("</svg>"));
        assert!(!svg.contains("NaN"), "NaN leaked into SVG");
    });
}

// ---------- checkpoint persistence ----------

/// Save/load round-trip: params bit-identical (via `to_bits`, so −0.0,
/// subnormals and extreme values survive), model name and round
/// preserved, for random sizes including the empty vector.
#[test]
fn proptest_checkpoint_roundtrip_is_bit_identical() {
    use fedcore::fl::Checkpoint;
    use fedcore::util::prop::{env_cases, env_seed};
    check("checkpoint-roundtrip", env_seed(0xC4E5), env_cases(50), |rng, case| {
        let models = ["logreg", "mnist", "shake"];
        let model = models[case % models.len()];
        let round = rng.next_u64();
        let n = rng.below(256);
        let mut params: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        // Salt with the awkward values a plain normal draw never hits.
        for (i, v) in [0.0f32, -0.0, f32::MIN_POSITIVE, f32::MAX, -1.0e-40].iter().enumerate() {
            if n > i {
                params[i] = *v;
            }
        }
        let path = std::env::temp_dir().join(format!(
            "fedcore_prop_ckpt_{}_{case}",
            std::process::id()
        ));
        let ck = Checkpoint::new(model, round, params);
        ck.save(&path).expect("save");
        let back = Checkpoint::load(&path).expect("load");
        std::fs::remove_file(&path).ok();
        assert_eq!(back.model, model, "model name must survive the round trip");
        assert_eq!(back.round, round, "round must survive the round trip");
        assert_eq!(back.params.len(), ck.params.len());
        for (i, (a, b)) in ck.params.iter().zip(&back.params).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "param {i} changed bits: {a} vs {b}");
        }
    });
}

/// Corrupt-file error path: flipping any byte of the parameter payload
/// (or the stored checksum) makes `load` fail loudly; truncation too.
#[test]
fn proptest_checkpoint_corruption_is_detected() {
    use fedcore::fl::Checkpoint;
    use fedcore::util::prop::{env_cases, env_seed};
    check("checkpoint-corruption", env_seed(0xC4E6), env_cases(50), |rng, case| {
        let n = 1 + rng.below(128);
        let params: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let model = "logreg";
        let path = std::env::temp_dir().join(format!(
            "fedcore_prop_ckpt_bad_{}_{case}",
            std::process::id()
        ));
        Checkpoint::new(model, 3, params).save(&path).expect("save");
        let mut bytes = std::fs::read(&path).expect("read");
        // Header layout: magic(4) version(4) name_len(8) name round(8)
        // count(8); everything after is params*4 + checksum(8) — the
        // checksummed region, where any single-byte flip must be caught.
        let payload_start = 4 + 4 + 8 + model.len() + 8 + 8;
        if rng.below(2) == 0 {
            let idx = payload_start + rng.below(bytes.len() - payload_start);
            bytes[idx] ^= 0x40;
            std::fs::write(&path, &bytes).expect("write");
            assert!(
                Checkpoint::load(&path).is_err(),
                "flipped byte {idx} of {} went undetected",
                bytes.len()
            );
        } else {
            // Truncation (always inside the checksummed tail).
            let keep = payload_start + rng.below(bytes.len() - payload_start);
            bytes.truncate(keep);
            std::fs::write(&path, &bytes).expect("write");
            assert!(Checkpoint::load(&path).is_err(), "truncation to {keep} went undetected");
        }
        std::fs::remove_file(&path).ok();
    });
}

// ---------- dataset generators ----------

#[test]
fn prop_generators_produce_consistent_shards() {
    let vocab: Vec<char> =
        "\x00 abcdefghijklmnopqrstuvwxyz.,;:!?'-\n\"()[]0123456789&_ABCDEFGHIJ"
            .chars()
            .collect();
    check("generators", 0xF1, 6, |rng, case| {
        let seed = rng.next_u64();
        let bench = [
            Benchmark::Mnist,
            Benchmark::Shakespeare,
            Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        ][case % 3];
        let ds = data::generate(bench, 0.05, &vocab, seed);
        assert!(ds.num_clients() > 0);
        assert!(ds.test.len() > 0);
        let weights = ds.client_weights();
        assert!((weights.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        for c in &ds.clients {
            assert!(!c.is_empty());
            assert_eq!(c.labels.len(), c.len() * c.y_elems());
        }
    });
}
