//! Differential property suite for the straggler-aware selection policy
//! suite (`scenario/selection.rs`; seeded runner in `util::prop` —
//! offline build, no proptest crate, see docs/testing.md).
//!
//! Invariants:
//! * FLANP's active prefix is monotone non-decreasing, never exceeds the
//!   fleet, and always admits exactly its `active()` fastest clients;
//!   the whole-fleet prefix routed through the streamed selector consumes
//!   exactly the RNG of the unrestricted sampler (output and end state).
//! * `apply_distilled` with no (or only non-positive-weight) updates is a
//!   bitwise identity on f32 parameters — the weight-0 gate has zero
//!   float operations on its inert path.
//! * Forecast scoring is deterministic (bit-for-bit replay) and
//!   permutation-stable with client-id tie-breaks.
//! * With a runtime (`make artifacts`): each policy's **degenerate**
//!   config — `flanp` with a whole-fleet start prefix, `forecast` with
//!   `bias = 0`, distillation under the degenerate overlap — reproduces
//!   the baseline engine **byte-for-byte** (final params, every round
//!   record, the model CSV, the dispatch CSV, checkpoint files) across
//!   Sequential/Sharded executors, aggregation policies, and churn
//!   traces; every *active* policy replays bit-for-bit from its seed;
//!   and FLANP wins (or ties) a time-to-target-loss race against the
//!   baseline on a heavy-tail churn trace — the adaptive-participation
//!   claim (arXiv:2012.14453) at test scale.
//!
//! Knobs: `PROPTEST_CASES` scales case counts, `PROPTEST_SEED` replays.

use std::sync::Arc;

use fedcore::agg::{apply_distilled, AggPolicy};
use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark};
use fedcore::exec::{DispatchPolicy, OverlapConfig, Sharded};
use fedcore::fl::{
    select_available_streamed, Checkpoint, CoresetMode, Engine, RunConfig, Strategy,
};
use fedcore::metrics::RunResult;
use fedcore::scenario::{
    forecast_rank, forecast_weights, ChurnModel, FlanpConfig, FlanpState, SelectPolicy, TraceSpec,
};
use fedcore::util::prop::{check, env_cases, env_seed};
use fedcore::util::rng::Rng;

fn runtime_or_skip() -> Option<fedcore::runtime::Runtime> {
    fedcore::expt::try_runtime()
}

// ---------- pure: FLANP prefix dynamics ----------

#[test]
fn proptest_select_flanp_prefix_monotone_and_bounded() {
    check("select-flanp-monotone", env_seed(0x5E10), env_cases(150), |rng, _| {
        let n = 1 + rng.below(60);
        let costs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 50.0)).collect();
        let cfg = FlanpConfig {
            start: 1 + rng.below(2 * n),
            factor: rng.range_f64(1.1, 3.0),
            threshold: rng.range_f64(0.0, 0.5),
        };
        let mut st = FlanpState::new(&costs, cfg);
        assert_eq!(st.active(), cfg.start.min(n).max(1));
        let mut last = st.active();
        // Feed a random loss walk (plateaus, drops, spikes, non-finites).
        for _ in 0..24 {
            let loss = match rng.below(6) {
                0 => f64::NAN,
                1 => rng.range_f64(-2.0, 0.0),
                _ => rng.range_f64(0.01, 4.0),
            };
            let widened = st.observe(loss);
            assert!(st.active() >= last, "prefix shrank");
            assert!(st.active() <= n, "prefix exceeded the fleet");
            assert_eq!(widened, st.active() > last, "widen report out of sync");
            // The admitted set is exactly the active()-fastest clients.
            let admitted = (0..n).filter(|&i| st.admits(i)).count();
            assert_eq!(admitted, st.active(), "admits() disagrees with active()");
            last = st.active();
        }
    });
}

#[test]
fn proptest_select_flanp_degenerate_prefix_matches_baseline_rng() {
    check("select-flanp-degenerate-rng", env_seed(0x5E11), env_cases(100), |rng, case| {
        let n = 2 + rng.below(40);
        let costs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 20.0)).collect();
        let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 4.0)).collect();
        let k = 1 + rng.below(n);
        // start ≥ fleet: the degenerate whole-fleet prefix. Every client
        // is admitted, so the streamed selector must replicate the
        // unrestricted sampler exactly — output AND RNG consumption —
        // which is what makes the flanp-off engine path byte-identical.
        let st = FlanpState::new(
            &costs,
            FlanpConfig { start: n + rng.below(9), factor: 2.0, threshold: 0.01 },
        );
        assert!((0..n).all(|i| st.admits(i)));

        let mut base_rng = rng.split(case as u64);
        let baseline = base_rng.weighted_with_replacement(&weights, k);
        let mut flanp_rng = rng.split(case as u64);
        let routed =
            select_available_streamed(&mut flanp_rng, |i| weights[i], |i| st.admits(i), n, k);
        assert_eq!(routed, baseline, "case {case}: selections diverged");
        assert_eq!(
            base_rng.next_u64(),
            flanp_rng.next_u64(),
            "case {case}: RNG consumption diverged"
        );
    });
}

// ---------- pure: distillation inertness ----------

#[test]
fn proptest_select_distill_weight_zero_is_bitwise_inert() {
    check("select-distill-inert", env_seed(0x5E12), env_cases(100), |rng, _| {
        let dim = 1 + rng.below(64);
        let current: Vec<f32> = (0..dim).map(|_| rng.range_f64(-3.0, 3.0) as f32).collect();
        // No updates at all: the weight-0 engine path never collects any.
        let out = apply_distilled(&current, &[]);
        for (a, b) in current.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits(), "empty fold must be the identity");
        }
        // Non-positive / non-finite weights are skipped entirely — the
        // fold runs but no f32 changes a bit.
        let junk: Vec<f32> = (0..dim).map(|_| rng.range_f64(-9.0, 9.0) as f32).collect();
        let out = apply_distilled(
            &current,
            &[(junk.as_slice(), 0.0), (junk.as_slice(), -1.5), (junk.as_slice(), f64::NAN)],
        );
        for (a, b) in current.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits(), "zero-weight fold must be the identity");
        }
        // A real weight moves at least one parameter (sanity: the gate is
        // the weight, not a dead code path).
        let shifted: Vec<f32> = current.iter().map(|&p| p + 1.0).collect();
        let out = apply_distilled(&current, &[(shifted.as_slice(), 0.5)]);
        assert!(
            current.iter().zip(&out).any(|(a, b)| a.to_bits() != b.to_bits()),
            "positive-weight fold must not be a no-op"
        );
    });
}

// ---------- pure: forecast determinism ----------

#[test]
fn proptest_select_forecast_scores_deterministic_and_permutation_stable() {
    check("select-forecast-stable", env_seed(0x5E13), env_cases(100), |rng, _| {
        let n = 2 + rng.below(40);
        // Distinct uptimes (id tie-breaks are pinned by the unit tests);
        // permutation stability is about value order, not input order.
        let mut uptimes: Vec<f64> = (0..n).map(|i| rng.f64() + i as f64 * 1e-12).collect();
        let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 5.0)).collect();
        let bias = rng.range_f64(0.1, 3.0);

        // Deterministic: same inputs, bit-identical outputs.
        let a = forecast_weights(&weights, |i| uptimes[i], bias);
        let b = forecast_weights(&weights, |i| uptimes[i], bias);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "forecast weights did not replay");
        }
        assert_eq!(forecast_rank(&uptimes), forecast_rank(&uptimes));

        // Permutation-stable: relabeling clients relabels the ranking,
        // nothing else. perm[j] = original id of new client j.
        let rank = forecast_rank(&uptimes);
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            perm.swap(i, j);
        }
        let mut inv = vec![0usize; n];
        for (j, &orig) in perm.iter().enumerate() {
            inv[orig] = j;
        }
        let permuted: Vec<f64> = perm.iter().map(|&orig| uptimes[orig]).collect();
        let rank_permuted = forecast_rank(&permuted);
        let expect: Vec<usize> = rank.iter().map(|&orig| inv[orig]).collect();
        assert_eq!(rank_permuted, expect, "ranking depends on input order");

        // Zero bias never even reads the uptimes.
        uptimes.clear();
        let inert = forecast_weights(&weights, |_| unreachable!("bias 0 must not score"), 0.0);
        for (x, y) in weights.iter().zip(&inert) {
            assert_eq!(x.to_bits(), y.to_bits(), "bias 0 must be bitwise inert");
        }
    });
}

// ---------- runtime-gated: the selection differential harness ----------

fn agg_for(case: usize) -> (AggPolicy, Option<f64>) {
    let clip = if case % 2 == 0 { None } else { Some(2.5) };
    let policy = match (case / 2) % 4 {
        0 => AggPolicy::Mean,
        1 => AggPolicy::Buffered { k: 3, momentum: 0.2 },
        2 => AggPolicy::TrimmedMean { trim_frac: 0.1 },
        _ => AggPolicy::CoordinateMedian,
    };
    (policy, clip)
}

fn differential_cfg(rng: &mut Rng, case: usize) -> RunConfig {
    let strategies = [
        Strategy::FedCore,
        Strategy::FedAvgDS,
        Strategy::FedProx { mu: 0.1 },
        Strategy::FedAvg,
    ];
    let (aggregator, clip_norm) = agg_for(case);
    let trace = match rng.below(3) {
        0 => None,
        1 => Some(TraceSpec::from_model(
            ChurnModel::Markov {
                mean_on: rng.range_f64(2.0, 8.0),
                mean_off: rng.range_f64(0.5, 3.0),
                p_init_online: 0.8,
            },
            24.0,
            rng.next_u64(),
        )),
        _ => Some(TraceSpec::from_model(
            ChurnModel::HeavyTail {
                mean_on: rng.range_f64(2.0, 6.0),
                min_off: 0.5,
                alpha: rng.range_f64(1.2, 2.5),
            },
            24.0,
            rng.next_u64(),
        )),
    };
    RunConfig {
        strategy: strategies[case % strategies.len()],
        rounds: 1 + rng.below(2),
        epochs: 2 + rng.below(2),
        clients_per_round: 3 + rng.below(4),
        lr: 0.01,
        straggler_pct: [10.0, 30.0][rng.below(2)],
        seed: rng.next_u64(),
        coreset_method: Method::FasterPam,
        coreset_mode: [CoresetMode::Adaptive, CoresetMode::Static][rng.below(2)],
        eval_every: 1,
        eval_cap: 128,
        workers: 1,
        dispatch: DispatchPolicy::RoundRobin,
        trace,
        aggregator,
        clip_norm,
        verbose: false,
        ..RunConfig::default()
    }
}

/// The degenerate setting of each selection knob, labeled. Every one of
/// these must leave a run byte-identical to `SelectPolicy::Baseline`.
fn degenerate_policies() -> Vec<(&'static str, SelectPolicy)> {
    vec![
        // A start prefix at/above the fleet keeps every client admitted
        // forever (the whole-fleet prefix cannot widen).
        (
            "flanp-whole-fleet",
            SelectPolicy::Flanp(FlanpConfig { start: usize::MAX, factor: 2.0, threshold: 0.9 }),
        ),
        // Zero bias returns the sampling weights bitwise-unchanged.
        ("forecast-bias-0", SelectPolicy::Forecast { bias: 0.0 }),
    ]
}

/// Serialized checkpoint bytes of a run's final model (written through
/// the real `Checkpoint` writer, then read back raw).
fn checkpoint_bytes(res: &RunResult, tag: &str) -> Vec<u8> {
    static SCRATCH: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let nonce = SCRATCH.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let path = std::env::temp_dir()
        .join(format!("fedcore-select-{}-{tag}-{nonce}.ckpt", std::process::id()));
    Checkpoint::new(res.benchmark.clone(), res.rounds.len() as u64, res.final_params.clone())
        .save(&path)
        .expect("writing checkpoint");
    let bytes = std::fs::read(&path).expect("reading checkpoint back");
    let _ = std::fs::remove_file(&path);
    bytes
}

/// The selection determinism contract: *everything* is bit-identical —
/// model bytes, every round record (including the new `distilled` /
/// `cohort_widened` columns), both CSV exports, and checkpoint files.
/// Unlike the dispatch harness, the dispatch CSV is included: a
/// degenerate selection knob must not perturb even the diagnostics.
fn assert_everything_bitwise_equal(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.final_params.len(), b.final_params.len(), "{what}: param count");
    for (i, (x, y)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: final param {i}: {x} vs {y}");
    }
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what} round {r} loss");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{what} round {r} test_loss");
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{what} round {r} test_acc");
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{what} round {r} sim_time");
        assert_eq!(x.sim_elapsed.to_bits(), y.sim_elapsed.to_bits(), "{what} round {r} elapsed");
        assert_eq!(x.client_times, y.client_times, "{what} round {r} client_times");
        assert_eq!(x.dropped, y.dropped, "{what} round {r} dropped");
        assert_eq!(x.churn_dropped, y.churn_dropped, "{what} round {r} churn_dropped");
        assert_eq!(x.stale_folded, y.stale_folded, "{what} round {r} stale_folded");
        assert_eq!(x.stale_discarded, y.stale_discarded, "{what} round {r} stale_discarded");
        assert_eq!(x.agg_rejected, y.agg_rejected, "{what} round {r} agg_rejected");
        assert_eq!(x.agg_clipped, y.agg_clipped, "{what} round {r} agg_clipped");
        assert_eq!(x.coreset_clients, y.coreset_clients, "{what} round {r} coreset_clients");
        assert_eq!(x.distilled, y.distilled, "{what} round {r} distilled");
        assert_eq!(x.cohort_widened, y.cohort_widened, "{what} round {r} cohort_widened");
    }
    assert_eq!(a.to_csv(), b.to_csv(), "{what}: model CSV diverged");
    assert_eq!(a.to_dispatch_csv(), b.to_dispatch_csv(), "{what}: dispatch CSV diverged");
    assert_eq!(
        checkpoint_bytes(a, "a"),
        checkpoint_bytes(b, "b"),
        "{what}: checkpoint bytes diverged"
    );
}

/// The centerpiece: every degenerate selection knob ≡ `Baseline`
/// **byte-for-byte** across strategies, aggregation policies, churn
/// traces, and both executors.
#[test]
fn proptest_select_degenerate_policies_bitwise_equal_baseline() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("select-degenerate-equivalence", env_seed(0x5E14), env_cases(4), |rng, case| {
        let mut cfg = differential_cfg(rng, case);
        let baseline = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        for (name, pol) in degenerate_policies() {
            cfg.select = pol;
            let run = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
            assert_everything_bitwise_equal(
                &baseline,
                &run,
                &format!("{} [{name} vs baseline, sequential]", baseline.strategy),
            );
            // No degenerate run may ever report selection activity.
            assert!(
                run.rounds.iter().all(|r| r.cohort_widened == 0 && r.distilled == 0),
                "{name}: degenerate run reported selection activity"
            );
        }
        // Sharded executors must agree too — the policy seam sits above
        // the dispatch seam, so the composition cannot leak either way.
        cfg.workers = 2 + rng.below(3);
        for (name, pol) in degenerate_policies() {
            cfg.select = pol;
            let exec = Sharded::new(cfg.workers, rt.factory());
            let run = Engine::with_executor(&rt, &ds, cfg.clone(), exec).unwrap().run().unwrap();
            assert_everything_bitwise_equal(
                &baseline,
                &run,
                &format!("{} [{name} vs baseline, {} workers]", baseline.strategy, cfg.workers),
            );
        }
    });
}

/// Distillation under the degenerate overlap (`quorum = 1`,
/// `max_staleness = 0`): the in-flight ledger stays empty, nothing ever
/// reaches the distill fold, and a positive `distill_weight` must be
/// byte-for-byte the weight-0 run.
#[test]
fn proptest_select_distill_under_degenerate_overlap_is_inert() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("select-distill-degenerate", env_seed(0x5E15), env_cases(4), |rng, case| {
        let mut cfg = differential_cfg(rng, case);
        cfg.overlap = Some(OverlapConfig { quorum: 1.0, max_staleness: 0, alpha: 1.0 });
        cfg.distill_weight = 0.0;
        let plain = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        cfg.distill_weight = 0.5;
        let distill = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        assert_everything_bitwise_equal(
            &plain,
            &distill,
            &format!("{} [distill degenerate-overlap]", plain.strategy),
        );
        assert!(distill.rounds.iter().all(|r| r.distilled == 0), "nothing could have folded");
    });
}

/// Seeded replay for every *active* policy: flanp with a small prefix,
/// forecast with a real bias, distillation on a real overlap quorum —
/// each run twice from the same seed, byte-identical both times.
#[test]
fn proptest_select_active_policies_replay_bitwise() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("select-active-replay", env_seed(0x5E16), env_cases(3), |rng, case| {
        let mut cfg = differential_cfg(rng, case);
        match case % 3 {
            0 => {
                cfg.select = SelectPolicy::Flanp(FlanpConfig {
                    start: 2,
                    factor: 2.0,
                    threshold: 0.5,
                });
            }
            1 => {
                cfg.select = SelectPolicy::Forecast { bias: rng.range_f64(0.5, 2.0) };
            }
            _ => {
                cfg.overlap = Some(OverlapConfig {
                    quorum: rng.range_f64(0.4, 0.8),
                    max_staleness: rng.below(2),
                    alpha: 1.0,
                });
                cfg.distill_weight = rng.range_f64(0.2, 0.8);
            }
        }
        let a = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        let b = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        assert_everything_bitwise_equal(
            &a,
            &b,
            &format!("{} [{} replay]", a.strategy, cfg.select.label()),
        );
    });
}

/// The FLANP race: on a heavy-tail churn trace, the fastest-prefix start
/// must reach the field's worst final loss in no more simulated time
/// than the baseline sampler. (The bench-scale twin of this race — with
/// forecast in the field and results recorded to `BENCH_scenarios.json`
/// — lives in `benches/scenario_churn.rs`.)
#[test]
fn proptest_select_flanp_wins_time_to_target_race() {
    let Some(rt) = runtime_or_skip() else { return };
    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };
    let spec = || {
        TraceSpec::from_model(
            ChurnModel::HeavyTail { mean_on: 6.0, min_off: 0.5, alpha: 1.1 },
            48.0,
            11,
        )
    };
    let run = |pol: SelectPolicy| {
        fedcore::expt::run_scenario_with(&rt, bench, Strategy::FedCore, 30.0, 7, spec(), |r| {
            r.select = pol;
        })
        .expect("race run")
        .result
    };
    let baseline = run(SelectPolicy::Baseline);
    let flanp =
        run(SelectPolicy::Flanp(FlanpConfig { start: 4, factor: 2.0, threshold: 0.5 }));
    let final_loss =
        |r: &RunResult| r.rounds.last().map(|rec| rec.train_loss).unwrap_or(f64::NAN);
    let target = final_loss(&baseline).max(final_loss(&flanp));
    let time_to = |r: &RunResult| {
        r.rounds
            .iter()
            .find(|rec| rec.train_loss <= target)
            .or(r.rounds.last())
            .map(|rec| rec.sim_elapsed)
            .unwrap_or(0.0)
    };
    assert!(
        time_to(&flanp) <= time_to(&baseline),
        "FLANP lost the race: {} > {} (target loss {target})",
        time_to(&flanp),
        time_to(&baseline)
    );
}
