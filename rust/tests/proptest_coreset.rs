//! Property suites for the parallel coreset hot path (seeded runner in
//! `util::prop`; offline build, no proptest crate — see DESIGN.md
//! "Offline-build note"). Pure CPU: none of these need runtime artifacts.
//!
//! These are the gate for the exec-sharded coreset pipeline: the engine
//! hands every client job `coreset_workers` threads, and the contract is
//! that the sharded construction is **bit-identical** to the sequential
//! one at any worker count (determinism rule: worker count never reaches
//! model outputs).
//!
//! Invariants:
//! * `from_features_cpu_par` equals the sequential distance builder
//!   bitwise at any worker count — each entry is an independent
//!   f64-accumulated function of two feature rows, so the T×T tiling
//!   only reorders writes, never operands.
//! * Parallel FasterPAM (chunk-sharded BUILD + windowed SWAP) returns
//!   bit-identical medoids, deltas, and cost for workers ∈ {1, 2, 4, 8}
//!   and k ∈ {1, m/10, m−1}.
//! * `select_warm` falls back to the cold path bitwise whenever the
//!   cache is unusable (wrong method or stale size), is a fixed point on
//!   an already-converged medoid set, and stays within a small cost
//!   slack of a cold solve under feature drift.
//!
//! Knobs (proptest-compatible, per the testing-strategy doc):
//! `PROPTEST_CASES` scales case counts, `PROPTEST_SEED` replays a run.

use std::sync::Arc;

use fedcore::coreset::{self, distance, fasterpam, DistMatrix, Method};
use fedcore::data::{self, Benchmark};
use fedcore::exec::Sharded;
use fedcore::fl::{Engine, RunConfig, Strategy};
use fedcore::util::prop::{check, env_cases, env_seed};
use fedcore::util::rng::Rng;

/// Clustered feature matrix (n × dim, row-major): well-separated centers
/// plus per-point noise, the shape the gradient-space coresets see.
fn features(rng: &mut Rng, n: usize, dim: usize, clusters: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(n * dim);
    for i in 0..n {
        let c = i % clusters.max(1);
        for d in 0..dim {
            let center = if d % clusters.max(1) == c { 1.5 } else { -0.5 };
            out.push(center + 0.15 * rng.normal() as f32);
        }
    }
    out
}

/// Random symmetric distance matrix with a zero diagonal (exercises the
/// solver on geometry the feature generator can't reach, e.g. ties).
fn random_dist(rng: &mut Rng, n: usize) -> DistMatrix {
    let mut d = vec![0.0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            // Quantized values so exact ties occur regularly — the merge
            // rule's first-best-wins discipline is what's under test.
            let v = (rng.below(32) as f32) * 0.125;
            d[i * n + j] = v;
            d[j * n + i] = v;
        }
    }
    DistMatrix { n, d }
}

/// The k grid the issue pins: degenerate, paper-shaped (b = m/10), and
/// the largest non-trivial budget.
fn k_grid(n: usize) -> [usize; 3] {
    [1, (n / 10).max(1), n.saturating_sub(1).max(1)]
}

// ---------- distance tiling ----------

#[test]
fn proptest_coreset_parallel_distance_is_bitwise_sequential() {
    check("coreset-dist-tiling", env_seed(0xD157), env_cases(24), |rng, _| {
        // Straddle the 128-wide tile boundary often: single tile, exact
        // multiple, and ragged edge all occur across the case budget.
        let n = 1 + rng.below(300);
        let dim = 1 + rng.below(24);
        let feats = features(rng, n, dim, 1 + rng.below(6));
        let seq = distance::from_features_cpu(&feats, n, dim);
        for workers in [2, 3, 4, 8] {
            let par = distance::from_features_cpu_par(&feats, n, dim, workers);
            assert_eq!(seq.n, par.n);
            for (i, (a, b)) in seq.d.iter().zip(&par.d).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "entry {i} diverged at n={n} dim={dim} workers={workers}"
                );
            }
            assert_eq!(par.asymmetry(), 0.0, "tiled mirror broke symmetry");
        }
    });
}

// ---------- FasterPAM: parallel ≡ sequential, bitwise ----------

#[test]
fn proptest_coreset_parallel_solver_is_bitwise_sequential() {
    check("coreset-solver-par", env_seed(0xFA57), env_cases(20), |rng, case| {
        // Alternate clustered geometry and tie-heavy random matrices.
        let n = 12 + rng.below(90);
        let dist = if case % 2 == 0 {
            let dim = 2 + rng.below(12);
            let feats = features(rng, n, dim, 2 + rng.below(5));
            distance::from_features_cpu(&feats, n, dim)
        } else {
            random_dist(rng, n)
        };
        let seed = rng.next_u64();
        for k in k_grid(n) {
            let cold = coreset::select(&dist, k, Method::FasterPam, &mut Rng::new(seed));
            for workers in [1, 2, 4, 8] {
                let par = coreset::select_par(
                    &dist,
                    k,
                    Method::FasterPam,
                    &mut Rng::new(seed),
                    workers,
                );
                assert_eq!(
                    cold.indices, par.indices,
                    "medoids diverged at n={n} k={k} workers={workers}"
                );
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(
                    bits(&cold.deltas),
                    bits(&par.deltas),
                    "deltas diverged at n={n} k={k} workers={workers}"
                );
                assert_eq!(
                    cold.cost.to_bits(),
                    par.cost.to_bits(),
                    "cost diverged at n={n} k={k} workers={workers}"
                );
            }
        }
    });
}

#[test]
fn proptest_coreset_build_init_matches_across_workers() {
    // BUILD in isolation (no SWAP noise): the chunk-merge rule must pick
    // the same greedy medoid sequence as the linear scan, including on
    // exact-tie matrices where first-best-wins is the whole contract.
    check("coreset-build-par", env_seed(0xB11D), env_cases(16), |rng, _| {
        let n = 5 + rng.below(120);
        let dist = random_dist(rng, n);
        let k = 1 + rng.below(n.min(12));
        let seed = rng.next_u64();
        let seq = fasterpam::solve_with_init(&dist, k, &mut Rng::new(seed), true);
        for workers in [2, 3, 4, 8] {
            let par = fasterpam::solve_with_init_par(
                &dist,
                k,
                &mut Rng::new(seed),
                true,
                workers,
            );
            assert_eq!(seq, par, "BUILD+SWAP diverged at n={n} k={k} workers={workers}");
        }
    });
}

// ---------- warm start ----------

#[test]
fn proptest_coreset_warm_unusable_cache_is_bitwise_cold() {
    // The fallback conditions mirror the engine's `warm_cache_usable`
    // gate: wrong method, wrong cache size (shard grew/shrank), or
    // out-of-range indices must reproduce the cold selection *bitwise* —
    // including identical RNG consumption.
    check("coreset-warm-fallback", env_seed(0x3A11), env_cases(16), |rng, _| {
        let n = 10 + rng.below(60);
        let dim = 2 + rng.below(8);
        let feats = features(rng, n, dim, 3);
        let dist = distance::from_features_cpu(&feats, n, dim);
        let k = 2 + rng.below(n / 2);
        let seed = rng.next_u64();
        let workers = 1 + rng.below(4);
        let cold = coreset::select_par(&dist, k, Method::FasterPam, &mut Rng::new(seed), workers);
        // Wrong size (one medoid short) and out-of-range entries.
        let bad_caches: [Vec<usize>; 3] = [
            cold.indices[..k - 1].to_vec(),
            vec![n + 5; k],
            vec![0; k], // duplicates dedup to a single survivor
        ];
        for cache in &bad_caches {
            let warm = coreset::select_warm(
                &dist,
                k,
                Method::FasterPam,
                cache,
                &mut Rng::new(seed),
                workers,
            );
            assert_eq!(cold.indices, warm.indices, "fallback not bitwise cold");
            assert_eq!(cold.cost.to_bits(), warm.cost.to_bits());
        }
        // Non-FasterPAM methods never warm-start.
        let r_cold = coreset::select_par(&dist, k, Method::Random, &mut Rng::new(seed), workers);
        let r_warm = coreset::select_warm(
            &dist,
            k,
            Method::Random,
            &cold.indices,
            &mut Rng::new(seed),
            workers,
        );
        assert_eq!(r_cold.indices, r_warm.indices, "Random method must ignore the cache");
    });
}

#[test]
fn proptest_coreset_warm_is_fixed_point_on_converged_medoids() {
    // Warm-starting from a converged cold solution must return the same
    // medoid set for any worker count: no improving swap exists, so the
    // SWAP-only sweep terminates without churn.
    check("coreset-warm-fixed-point", env_seed(0xF1CE), env_cases(12), |rng, _| {
        let n = 10 + rng.below(80);
        let dim = 2 + rng.below(10);
        let feats = features(rng, n, dim, 4);
        let dist = distance::from_features_cpu(&feats, n, dim);
        let k = 2 + rng.below((n / 3).max(1));
        let cold = coreset::select(&dist, k, Method::FasterPam, &mut Rng::new(rng.next_u64()));
        for workers in [1, 2, 4, 8] {
            let warm = coreset::select_warm(
                &dist,
                k.min(cold.indices.len()),
                Method::FasterPam,
                &cold.indices,
                &mut Rng::new(rng.next_u64()),
                workers,
            );
            assert_eq!(
                cold.indices, warm.indices,
                "converged medoids churned at workers={workers}"
            );
            assert_eq!(cold.cost.to_bits(), warm.cost.to_bits());
        }
    });
}

#[test]
fn proptest_coreset_warm_cost_tracks_cold_under_drift() {
    // The engine's non-refresh rounds warm-start on *drifted* features
    // (the gradient space moves a little each round). Both warm and cold
    // land on local optima of the same landscape, so no strict ordering
    // exists — but under small drift the warm solve must stay within a
    // generous slack of the cold one, in both directions.
    check("coreset-warm-drift", env_seed(0xD81F), env_cases(12), |rng, _| {
        let n = 30 + rng.below(80);
        let dim = 4 + rng.below(8);
        let mut feats = features(rng, n, dim, 4);
        let dist0 = distance::from_features_cpu(&feats, n, dim);
        let k = 3 + rng.below(n / 8);
        let cached = coreset::select(&dist0, k, Method::FasterPam, &mut Rng::new(rng.next_u64()));
        // Drift every feature slightly (≪ cluster separation).
        for f in feats.iter_mut() {
            *f += 0.02 * rng.normal() as f32;
        }
        let dist1 = distance::from_features_cpu(&feats, n, dim);
        let seed = rng.next_u64();
        let workers = 1 + rng.below(4);
        let cold = coreset::select_par(&dist1, k, Method::FasterPam, &mut Rng::new(seed), workers);
        let warm = coreset::select_warm(
            &dist1,
            k.min(cached.indices.len()),
            Method::FasterPam,
            &cached.indices,
            &mut Rng::new(seed),
            workers,
        );
        assert!(warm.cost.is_finite() && cold.cost.is_finite());
        let slack = 1.25 * (cold.cost + 1e-9);
        assert!(
            warm.cost <= slack,
            "warm cost {:.6} blew past cold {:.6} at n={n} k={k}",
            warm.cost,
            cold.cost
        );
        assert!(
            cold.cost <= 1.25 * (warm.cost + 1e-9),
            "cold cost {:.6} blew past warm {:.6} at n={n} k={k}",
            cold.cost,
            warm.cost
        );
        // Weights always repartition the full set.
        assert_eq!(warm.total_weight(), n as f64);
    });
}

// ---------- engine-level gate (runtime-backed; skips without artifacts) ----------

#[test]
fn proptest_coreset_engine_warm_rounds_are_worker_count_invariant() {
    let Some(rt) = fedcore::expt::try_runtime() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        17,
    ));
    check("coreset-engine-warm", env_seed(0xE17A), env_cases(3), |rng, _| {
        let cfg = RunConfig {
            strategy: Strategy::FedCore,
            rounds: 3 + rng.below(2),
            epochs: 2,
            clients_per_round: 3 + rng.below(3),
            lr: 0.01,
            straggler_pct: 30.0,
            seed: rng.next_u64(),
            coreset_method: Method::FasterPam,
            coreset_refresh: 2 + rng.below(2),
            eval_every: 1,
            eval_cap: 128,
            workers: 1,
            verbose: false,
            ..RunConfig::default()
        };
        // Warm-started rounds must not leak the worker count into model
        // outputs: sequential and sharded engines agree byte-for-byte
        // (coreset_workers follows the executor, so this drives the
        // sharded hot path end-to-end).
        let seq = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();
        let workers = 2 + rng.below(3);
        let par = Engine::with_executor(&rt, &ds, cfg.clone(), Sharded::new(workers, rt.factory()))
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(
            seq.final_params, par.final_params,
            "warm rounds diverged at {workers} workers"
        );
        assert_eq!(seq.to_csv(), par.to_csv(), "model CSV diverged at {workers} workers");
        // Refresh rounds rebuild cold by definition.
        for rec in &par.rounds {
            if rec.round % cfg.coreset_refresh == 0 {
                assert_eq!(rec.coreset_warm, 0, "refresh round {} warm-started", rec.round);
            }
        }
        // refresh = 1 must be byte-identical to the untouched default
        // config — the degenerate-warm-start contract the acceptance
        // criterion pins (`--coreset-refresh 1` ≡ today's engine).
        let mut one = cfg.clone();
        one.coreset_refresh = 1;
        let a = Engine::new(&rt, &ds, one).unwrap().run().unwrap();
        let mut untouched = cfg.clone();
        untouched.coreset_refresh = RunConfig::default().coreset_refresh;
        let b = Engine::new(&rt, &ds, untouched).unwrap().run().unwrap();
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.to_csv(), b.to_csv());
        for rec in &a.rounds {
            assert_eq!(rec.coreset_warm, 0, "refresh = 1 must never warm-start");
        }
    });
}
