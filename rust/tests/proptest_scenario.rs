//! Property suites for trace-driven availability scenarios (seeded runner
//! in `util::prop`; offline build, no proptest crate).
//!
//! Invariants:
//! * Availability-aware selection never picks an offline client, is
//!   deterministic under `PROPTEST_SEED`, and reduces exactly to the
//!   unrestricted weighted sampler when every client is online.
//! * The streamed selector agrees with the indexed one — output **and**
//!   RNG consumption — on every edge regime (nobody online, everybody
//!   online, K past the online count, K = fleet).
//! * Generated traces are well-formed (sorted, disjoint, in-range
//!   intervals) and their point queries agree with each other.
//! * Trace generation and materialization replay bit-for-bit from a seed;
//!   uptime read off the lazy `Generated` representation is bit-identical
//!   to the dense interval table's (so forecast scoring never needs to
//!   materialize).
//! * With a runtime (`make artifacts`): an always-on trace reproduces the
//!   traceless run exactly, and sharded equals sequential bit-for-bit
//!   with churn enabled.
//!
//! Knobs: `PROPTEST_CASES` scales case counts, `PROPTEST_SEED` replays.

use std::sync::Arc;

use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark};
use fedcore::exec::Sharded;
use fedcore::fl::{
    select_available, select_available_streamed, CoresetMode, Engine, RunConfig, Strategy,
};
use fedcore::scenario::{AvailabilityTrace, ChurnModel, EdgePolicy, TraceSpec};
use fedcore::sim::Fleet;
use fedcore::util::prop::{check, env_cases, env_seed};
use fedcore::util::rng::Rng;

fn random_model(rng: &mut Rng) -> ChurnModel {
    match rng.below(4) {
        0 => ChurnModel::AlwaysOn,
        1 => ChurnModel::Periodic {
            period: rng.range_f64(2.0, 12.0),
            duty: rng.range_f64(0.2, 1.0),
        },
        2 => ChurnModel::Markov {
            mean_on: rng.range_f64(1.0, 10.0),
            mean_off: rng.range_f64(0.5, 5.0),
            p_init_online: rng.f64(),
        },
        _ => ChurnModel::HeavyTail {
            mean_on: rng.range_f64(1.0, 10.0),
            min_off: rng.range_f64(0.1, 2.0),
            alpha: rng.range_f64(0.8, 2.5),
        },
    }
}

fn random_trace(rng: &mut Rng, clients: usize) -> AvailabilityTrace {
    let model = random_model(rng);
    let horizon = rng.range_f64(5.0, 60.0);
    let policy = [EdgePolicy::Wrap, EdgePolicy::Clamp][rng.below(2)];
    model
        .generate(&rng.split(0x7AACE), clients, horizon, policy)
        .expect("generation")
}

// ---------- selection ----------

#[test]
fn proptest_scenario_selection_never_offline_and_deterministic() {
    check("scenario-select-online", env_seed(0x5CE0), env_cases(200), |rng, _| {
        let n = 2 + rng.below(40);
        let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 5.0)).collect();
        let online: Vec<usize> = (0..n).filter(|_| rng.f64() < 0.6).collect();
        let k = 1 + rng.below(12);

        let mut a_rng = rng.split(1);
        let selected = select_available(&mut a_rng, &weights, &online, k);
        for &i in &selected {
            assert!(online.contains(&i), "selected offline client {i}");
        }
        if online.is_empty() {
            assert!(selected.is_empty());
        } else if online.len() < k {
            // Deterministic fallback: every online client exactly once, in
            // index order, without consuming the RNG.
            assert_eq!(selected, online);
        } else {
            assert_eq!(selected.len(), k);
        }

        // Same RNG stream ⇒ same selection (replayable under PROPTEST_SEED).
        let mut b_rng = rng.split(1);
        let replay = select_available(&mut b_rng, &weights, &online, k);
        assert_eq!(selected, replay);
    });
}

#[test]
fn proptest_scenario_selection_reduces_to_unrestricted_sampler() {
    check("scenario-select-reduction", env_seed(0x5CE1), env_cases(100), |rng, _| {
        let n = 2 + rng.below(30);
        let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 5.0)).collect();
        let k = 1 + rng.below(n); // k ≤ n: the non-fallback regime
        let all: Vec<usize> = (0..n).collect();

        let mut a_rng = rng.split(2);
        let via_available = select_available(&mut a_rng, &weights, &all, k);
        let mut b_rng = rng.split(2);
        let unrestricted = b_rng.weighted_with_replacement(&weights, k);
        assert_eq!(
            via_available, unrestricted,
            "all-online selection must match the baseline sampler"
        );
    });
}

/// The streamed selector's edge regimes — nobody online, everybody
/// online, K past the online count, K = fleet — each checked for output
/// **and** RNG-consumption identity against the indexed selector, so a
/// selection-policy predicate can route through the streamed path without
/// perturbing anything sampled after it.
#[test]
fn proptest_scenario_streamed_selector_edge_cases_match_indexed() {
    check("scenario-select-streamed-edges", env_seed(0x5CE2), env_cases(100), |rng, case| {
        let n = 2 + rng.below(30);
        let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 5.0)).collect();
        // Regime by case: 0 = nobody online, 1 = everybody online,
        // 2 = K exceeds the online count, 3 = K = fleet (everyone online).
        let (mask, k): (Vec<bool>, usize) = match case % 4 {
            0 => (vec![false; n], 1 + rng.below(8)),
            1 => (vec![true; n], 1 + rng.below(n)),
            2 => {
                let mask: Vec<bool> = (0..n).map(|_| rng.f64() < 0.4).collect();
                let online = mask.iter().filter(|&&b| b).count();
                (mask, online + 1 + rng.below(4))
            }
            _ => (vec![true; n], n),
        };
        let online: Vec<usize> = (0..n).filter(|&i| mask[i]).collect();

        let mut flat_rng = rng.split(4);
        let flat = select_available(&mut flat_rng, &weights, &online, k);
        let mut stream_rng = rng.split(4);
        let streamed =
            select_available_streamed(&mut stream_rng, |i| weights[i], |i| mask[i], n, k);
        assert_eq!(streamed, flat, "case {case}: selections diverged");
        assert_eq!(
            flat_rng.next_u64(),
            stream_rng.next_u64(),
            "case {case}: RNG consumption diverged"
        );

        match case % 4 {
            0 => assert!(streamed.is_empty(), "nobody online must select nobody"),
            2 => assert_eq!(streamed, online, "short cohort: everyone once, index order"),
            _ => assert_eq!(streamed.len(), k),
        }
        if case % 4 == 0 || case % 4 == 2 {
            // Both fallbacks are RNG-free: the stream reads like untouched.
            let mut untouched = rng.split(4);
            let mut consumed = rng.split(4);
            let _ = select_available_streamed(&mut consumed, |i| weights[i], |i| mask[i], n, k);
            assert_eq!(untouched.next_u64(), consumed.next_u64(), "fallback consumed RNG");
        }
    });
}

// ---------- trace well-formedness ----------

#[test]
fn proptest_scenario_trace_invariants() {
    check("scenario-trace-invariants", env_seed(0x7ACE), env_cases(60), |rng, _| {
        let clients = 1 + rng.below(30);
        let trace = random_trace(rng, clients);
        let horizon = trace.horizon();

        for c in 0..clients {
            let ivs = trace.intervals(c);
            for iv in &ivs {
                assert!(iv.0 >= 0.0 && iv.1 <= horizon, "client {c}: {iv:?} out of range");
                assert!(iv.0 < iv.1, "client {c}: empty interval {iv:?}");
            }
            for w in ivs.windows(2) {
                assert!(w[0].1 < w[1].0, "client {c}: unmerged/overlapping {w:?}");
            }
        }

        // Point queries agree with each other at random times (including
        // past the horizon, where the edge policy kicks in).
        for _ in 0..32 {
            let t = rng.range_f64(0.0, 3.0 * horizon);
            let online = trace.online_at(t);
            for c in 0..clients {
                let is_on = trace.is_online(c, t);
                assert_eq!(online.contains(&c), is_on, "online_at vs is_online at {t}");
                let rem = trace.remaining_online(c, t);
                assert_eq!(rem > 0.0, is_on, "remaining_online vs is_online at {t}");
                // Just inside a positive remainder the client is still on.
                if rem.is_finite() && rem > 1e-6 {
                    assert!(
                        trace.is_online(c, t + rem * 0.5),
                        "client {c} offline inside its own remainder (t={t}, rem={rem})"
                    );
                }
            }
        }
    });
}

#[test]
fn proptest_scenario_materialize_is_deterministic() {
    check("scenario-materialize-replay", env_seed(0xDE7), env_cases(40), |rng, _| {
        let spec = TraceSpec::from_model(
            random_model(rng),
            rng.range_f64(4.0, 40.0),
            rng.next_u64(),
        );
        let clients = 1 + rng.below(25);
        let deadline = rng.range_f64(0.5, 500.0);
        let a = spec.materialize(clients, deadline).expect("materialize");
        let b = spec.materialize(clients, deadline).expect("materialize");
        assert_eq!(a, b, "materialization must replay bit-for-bit");
    });
}

/// Uptime streamed off the lazy `Generated` representation is
/// bit-identical to the dense interval table's — the guarantee that lets
/// uptime-forecast selection score a fleet without ever forcing
/// `materialize_dense` (O(fleet) interval storage).
#[test]
fn proptest_scenario_streamed_uptime_matches_dense() {
    check("scenario-uptime-streamed", env_seed(0x07A1), env_cases(40), |rng, _| {
        let spec = TraceSpec::from_model(
            random_model(rng),
            rng.range_f64(4.0, 40.0),
            rng.next_u64(),
        );
        let clients = 1 + rng.below(25);
        let deadline = rng.range_f64(0.5, 500.0);
        let lazy = spec.materialize(clients, deadline).expect("materialize");
        let dense = spec.materialize_dense(clients, deadline).expect("materialize dense");
        // +2: clients past the trace count as always online on both paths.
        for c in 0..clients + 2 {
            assert_eq!(
                lazy.uptime(c).to_bits(),
                dense.uptime(c).to_bits(),
                "client {c}: lazy vs dense uptime"
            );
        }
    });
}

#[test]
fn proptest_scenario_fleet_online_clients_matches_trace() {
    check("scenario-fleet-online", env_seed(0xF1EE), env_cases(40), |rng, _| {
        let n = 2 + rng.below(20);
        let sizes: Vec<usize> = (0..n).map(|_| 10 + rng.below(100)).collect();
        let mut frng = rng.split(3);
        let fleet = Fleet::new(&mut frng, sizes, 4, 30.0);
        let trace = random_trace(rng, n);
        let t = rng.range_f64(0.0, 2.0 * trace.horizon());
        let online = fleet.online_clients(&trace, t);
        for i in 0..n {
            assert_eq!(online.contains(&i), trace.is_online(i, t));
        }
    });
}

// ---------- engine equivalences (runtime-backed) ----------

fn runtime_or_skip() -> Option<fedcore::runtime::Runtime> {
    fedcore::expt::try_runtime()
}

fn churn_cfg(rng: &mut Rng, case: usize) -> RunConfig {
    let strategies = [
        Strategy::FedCore,
        Strategy::FedAvgDS,
        Strategy::FedProx { mu: 0.1 },
        Strategy::FedAvg,
    ];
    RunConfig {
        strategy: strategies[case % strategies.len()],
        rounds: 2 + rng.below(2),
        epochs: 2 + rng.below(2),
        clients_per_round: 2 + rng.below(4),
        lr: 0.01,
        straggler_pct: [10.0, 30.0][rng.below(2)],
        seed: rng.next_u64(),
        coreset_method: Method::FasterPam,
        coreset_mode: [CoresetMode::Adaptive, CoresetMode::Static][rng.below(2)],
        eval_every: 1,
        eval_cap: 128,
        workers: 1,
        trace: Some(TraceSpec::from_model(
            ChurnModel::Markov {
                mean_on: rng.range_f64(2.0, 8.0),
                mean_off: rng.range_f64(0.5, 4.0),
                p_init_online: 0.8,
            },
            24.0,
            rng.next_u64(),
        )),
        overlap: None,
        verbose: false,
        ..RunConfig::default()
    }
}

#[test]
fn proptest_scenario_always_on_trace_equals_baseline() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    let mut base = churn_cfg(&mut Rng::new(env_seed(0xA0)), 0);
    base.trace = None;
    let mut with_trace = base.clone();
    with_trace.trace = Some(TraceSpec::always_on());

    let a = Engine::new(&rt, &ds, base).unwrap().run().unwrap();
    let b = Engine::new(&rt, &ds, with_trace).unwrap().run().unwrap();
    assert_eq!(a.final_params, b.final_params, "always-on trace changed the run");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits());
        assert_eq!(x.dropped, y.dropped);
        assert_eq!(y.churn_dropped, 0, "always-on trace cannot churn-drop");
    }
}

#[test]
fn proptest_scenario_sharded_matches_sequential_with_churn() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(
        Benchmark::Synthetic { alpha: 1.0, beta: 1.0 },
        0.15,
        &rt.manifest().vocab,
        11,
    ));
    check("scenario-exec-equivalence", env_seed(0xC4E8), env_cases(4), |rng, case| {
        let cfg = churn_cfg(rng, case);
        let seq = Engine::new(&rt, &ds, cfg.clone()).unwrap().run().unwrap();

        let workers = 2 + rng.below(3);
        let exec = Sharded::new(workers, rt.factory());
        let par = Engine::with_executor(&rt, &ds, cfg, exec).unwrap().run().unwrap();

        assert_eq!(
            seq.final_params, par.final_params,
            "{} × {workers} workers with churn: final params diverged",
            seq.strategy
        );
        assert_eq!(seq.rounds.len(), par.rounds.len());
        for (a, b) in seq.rounds.iter().zip(&par.rounds) {
            let r = a.round;
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {r} train_loss");
            assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits(), "round {r} test_acc");
            assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "round {r} sim_time");
            assert_eq!(a.dropped, b.dropped, "round {r} dropped");
            assert_eq!(a.churn_dropped, b.churn_dropped, "round {r} churn_dropped");
            assert_eq!(
                a.partial_time.to_bits(),
                b.partial_time.to_bits(),
                "round {r} partial_time"
            );
            assert_eq!(a.client_times, b.client_times, "round {r} client_times");
        }
    });
}
