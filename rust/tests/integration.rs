//! End-to-end integration tests: short FL runs per strategy through the
//! real PJRT runtime on tiny-but-heterogeneous federations.
//!
//! Requires `make artifacts` (skips cleanly otherwise, mirroring the
//! python suite's behaviour).

use std::sync::Arc;

use fedcore::config::ExperimentConfig;
use fedcore::coreset::Method;
use fedcore::data::{self, Benchmark};
use fedcore::fl::{all_strategies, Engine, RunConfig, Strategy};
use fedcore::metrics::RunResult;
use fedcore::runtime::Runtime;

fn runtime_or_skip() -> Option<Runtime> {
    fedcore::expt::try_runtime()
}

fn tiny_cfg(strategy: Strategy, rounds: usize) -> RunConfig {
    RunConfig {
        strategy,
        rounds,
        epochs: 6,
        clients_per_round: 5,
        lr: 0.01,
        straggler_pct: 30.0,
        seed: 7,
        coreset_method: Method::FasterPam,
        coreset_mode: fedcore::fl::CoresetMode::Adaptive,
        eval_every: 2,
        eval_cap: 256,
        workers: 1,
        trace: None,
        overlap: None,
        verbose: false,
        ..RunConfig::default()
    }
}

fn synth_ds(rt: &Runtime) -> Arc<data::FedDataset> {
    let bench = Benchmark::Synthetic { alpha: 1.0, beta: 1.0 };
    Arc::new(data::generate(bench, 0.18, &rt.manifest().vocab, 7))
}

fn run_synth(rt: &Runtime, strategy: Strategy, rounds: usize, seed: u64) -> RunResult {
    let ds = synth_ds(rt);
    let mut cfg = tiny_cfg(strategy, rounds);
    cfg.seed = seed;
    let engine = Engine::new(rt, &ds, cfg).expect("engine");
    engine.run().expect("run")
}

#[test]
fn every_strategy_learns_on_synthetic() {
    let Some(rt) = runtime_or_skip() else { return };
    for strategy in all_strategies(0.1) {
        let r = run_synth(&rt, strategy, 10, 7);
        let first = r.rounds.first().unwrap().train_loss;
        let last = r.final_train_loss();
        assert!(
            last < first,
            "{}: loss did not decrease ({first} -> {last})",
            strategy.label()
        );
        assert!(
            r.best_accuracy() > 0.2,
            "{}: accuracy {:.3} not above chance",
            strategy.label(),
            r.best_accuracy()
        );
    }
}

#[test]
fn deadline_aware_strategies_respect_tau_in_sim_time() {
    let Some(rt) = runtime_or_skip() else { return };
    for strategy in [Strategy::FedAvgDS, Strategy::FedProx { mu: 0.1 }, Strategy::FedCore] {
        let r = run_synth(&rt, strategy, 6, 7);
        for round in &r.rounds {
            // tolerance for the one-sample flooring slack per epoch
            assert!(
                round.sim_time <= r.deadline * 1.05,
                "{}: round {} took {:.1} > τ {:.1}",
                strategy.label(),
                round.round,
                round.sim_time,
                r.deadline
            );
        }
    }
}

#[test]
fn fedavg_exceeds_deadline_with_stragglers() {
    let Some(rt) = runtime_or_skip() else { return };
    let r = run_synth(&rt, Strategy::FedAvg, 10, 7);
    // On a tiny fleet the *mean* only mildly exceeds τ (stragglers are not
    // picked every round), but rounds that do pick one blow through it.
    let max_norm = r
        .rounds
        .iter()
        .map(|x| x.sim_time / r.deadline)
        .fold(0.0f64, f64::max);
    assert!(
        r.mean_normalized_round_time() > 1.0 && max_norm > 1.1,
        "FedAvg mean t/τ = {:.2}, max {:.2} — expected deadline violations",
        r.mean_normalized_round_time(),
        max_norm
    );
    // At fleet scale the tail is long (paper Fig. 4 shows >11×): check the
    // simulation layer directly with a paper-sized fleet.
    let mut rng = fedcore::util::rng::Rng::new(7);
    let sizes: Vec<usize> = (0..1000)
        .map(|i| 8 + (i * 37) % 400)
        .collect();
    let fleet = fedcore::sim::Fleet::new(&mut rng, sizes, 10, 30.0);
    let worst = (0..1000)
        .map(|i| fleet.full_round_time(i) / fleet.deadline)
        .fold(0.0f64, f64::max);
    assert!(worst > 2.0, "paper-scale FedAvg tail only {worst:.1}×τ");
}

#[test]
fn fedcore_uses_coresets_and_fedavg_does_not() {
    let Some(rt) = runtime_or_skip() else { return };
    let core = run_synth(&rt, Strategy::FedCore, 6, 7);
    let used: usize = core.rounds.iter().map(|r| r.coreset_clients).sum();
    assert!(used > 0, "FedCore never built a coreset");
    let avg = run_synth(&rt, Strategy::FedAvg, 6, 7);
    let used: usize = avg.rounds.iter().map(|r| r.coreset_clients).sum();
    assert_eq!(used, 0, "FedAvg built coresets");
    // compression only applies to straggler clients and must be < 1
    for r in &core.rounds {
        if r.coreset_clients > 0 {
            assert!(r.mean_compression <= 1.0);
        }
    }
}

#[test]
fn fedavg_ds_drops_clients_fedcore_keeps_them() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds_run = run_synth(&rt, Strategy::FedAvgDS, 8, 7);
    let dropped: usize = ds_run.rounds.iter().map(|r| r.dropped).sum();
    assert!(dropped > 0, "FedAvg-DS never dropped a straggler");
    let core = run_synth(&rt, Strategy::FedCore, 8, 7);
    let dropped: usize = core.rounds.iter().map(|r| r.dropped).sum();
    assert_eq!(dropped, 0, "FedCore dropped clients");
}

#[test]
fn runs_replay_deterministically_from_seed() {
    let Some(rt) = runtime_or_skip() else { return };
    let a = run_synth(&rt, Strategy::FedCore, 5, 13);
    let b = run_synth(&rt, Strategy::FedCore, 5, 13);
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits());
        assert_eq!(ra.test_acc.to_bits(), rb.test_acc.to_bits());
        assert_eq!(ra.sim_time.to_bits(), rb.sim_time.to_bits());
    }
    let c = run_synth(&rt, Strategy::FedCore, 5, 14);
    assert_ne!(
        a.final_train_loss().to_bits(),
        c.final_train_loss().to_bits(),
        "different seeds gave identical runs"
    );
}

#[test]
fn sharded_engine_matches_sequential_bitwise() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = synth_ds(&rt);
    let mut cfg = tiny_cfg(Strategy::FedCore, 4);
    cfg.eval_every = 1;
    let seq = Engine::new(&rt, &ds, cfg.clone()).expect("engine").run().expect("run");
    for workers in [2usize, 4] {
        let mut pcfg = cfg.clone();
        pcfg.workers = workers;
        let par = Engine::new(&rt, &ds, pcfg).expect("engine").run().expect("run");
        assert_eq!(seq.final_params, par.final_params, "{workers} workers: params diverged");
        assert_eq!(seq.rounds.len(), par.rounds.len());
        for (a, b) in seq.rounds.iter().zip(&par.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {}", a.round);
            assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits(), "round {}", a.round);
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "round {}", a.round);
            assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "round {}", a.round);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.coreset_clients, b.coreset_clients);
            assert_eq!(a.client_times, b.client_times);
        }
    }
}

#[test]
fn mnist_cnn_short_run_learns() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(Benchmark::Mnist, 0.03, &rt.manifest().vocab, 7));
    let mut cfg = tiny_cfg(Strategy::FedCore, 6);
    cfg.lr = 0.05;
    let engine = Engine::new(&rt, &ds, cfg).expect("engine");
    let r = engine.run().expect("run");
    assert!(
        r.best_accuracy() > 0.25,
        "MNIST acc {:.3} after 6 rounds (chance = 0.1)",
        r.best_accuracy()
    );
}

#[test]
fn shakespeare_lstm_short_run_descends() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = Arc::new(data::generate(Benchmark::Shakespeare, 0.02, &rt.manifest().vocab, 7));
    let mut cfg = tiny_cfg(Strategy::FedCore, 3);
    cfg.epochs = 4;
    cfg.lr = 0.5; // plain SGD on an LSTM needs a hot rate for 3 rounds
    let engine = Engine::new(&rt, &ds, cfg).expect("engine");
    let r = engine.run().expect("run");
    let ln_v = (64.0f64).ln();
    let last = r.final_train_loss();
    assert!(
        last < 0.97 * ln_v,
        "Shakespeare loss {last:.3} did not descend from ln(64) = {ln_v:.3}"
    );
}

#[test]
fn heterogeneous_synthetic_fedcore_beats_or_matches_fedavg_ds() {
    let Some(rt) = runtime_or_skip() else { return };
    // FedAvg-DS repeatedly drops the slow clients; on (1,1) heterogeneity
    // those clients hold unique distributions, so FedCore must win (or at
    // minimum match within noise).
    let core = run_synth(&rt, Strategy::FedCore, 12, 7);
    let ds_run = run_synth(&rt, Strategy::FedAvgDS, 12, 7);
    assert!(
        core.best_accuracy() >= ds_run.best_accuracy() - 0.03,
        "FedCore {:.3} well below FedAvg-DS {:.3}",
        core.best_accuracy(),
        ds_run.best_accuracy()
    );
}

#[test]
fn table2_paper_preset_hyperparams_flow_through() {
    let Some(rt) = runtime_or_skip() else { return };
    // Scaled preset must produce a runnable engine with the paper's E = 10.
    let cfg = ExperimentConfig::scaled_preset(Benchmark::Synthetic { alpha: 0.0, beta: 0.0 }, 0.15)
        .with_strategy(Strategy::FedProx { mu: 999.0 });
    assert_eq!(cfg.run.epochs, 10);
    assert_eq!(cfg.run.strategy, Strategy::FedProx { mu: 0.1 });
    let ds = Arc::new(data::generate(cfg.benchmark, cfg.scale, &rt.manifest().vocab, cfg.data_seed));
    let mut run_cfg = cfg.run.clone();
    run_cfg.rounds = 2;
    run_cfg.eval_every = 2;
    let engine = Engine::new(&rt, &ds, run_cfg).expect("engine");
    let r = engine.run().expect("run");
    assert_eq!(r.rounds.len(), 2);
}

#[test]
fn static_coreset_mode_runs_and_learns() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = synth_ds(&rt);
    let mut cfg = tiny_cfg(Strategy::FedCore, 8);
    cfg.coreset_mode = fedcore::fl::CoresetMode::Static;
    let engine = Engine::new(&rt, &ds, cfg).expect("engine");
    let r = engine.run().expect("run");
    assert!(r.best_accuracy() > 0.2, "static mode acc {:.3}", r.best_accuracy());
    let used: usize = r.rounds.iter().map(|x| x.coreset_clients).sum();
    assert!(used > 0, "static mode never used a coreset");
}

#[test]
fn checkpoint_resume_matches_model() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = synth_ds(&rt);
    let engine = Engine::new(&rt, &ds, tiny_cfg(Strategy::FedCore, 3)).expect("engine");
    let r = engine.run().expect("run");

    // Save, reload, resume: accuracy should not collapse back to round 0.
    let path = std::env::temp_dir().join(format!("fedcore_it_ckpt_{}", std::process::id()));
    fedcore::fl::Checkpoint::new(ds.model.clone(), 3, r.final_params.clone())
        .save(&path)
        .expect("save");
    let ck = fedcore::fl::Checkpoint::load(&path).expect("load");
    assert_eq!(ck.params, r.final_params);
    assert_eq!(ck.round, 3, "round must survive the round trip");
    assert_eq!(ck.model, ds.model, "model name must survive the round trip");
    let resumed = engine.run_from(ck.params).expect("resume");
    // The resumed run starts from trained params: its first-round accuracy
    // must be in the converged regime, not back at chance (0.1), and within
    // noise of the cold run's first-round (logreg converges in one round on
    // this tiny benchmark, so ≥ is too strict).
    assert!(
        resumed.rounds[0].test_acc >= (r.rounds[0].test_acc - 0.05).max(0.5),
        "resume ({:.3}) fell out of the converged regime (cold round 0: {:.3})",
        resumed.rounds[0].test_acc,
        r.rounds[0].test_acc
    );
    // wrong-size params are rejected
    assert!(engine.run_from(vec![0.0; 3]).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn csv_write_and_shape() {
    let Some(rt) = runtime_or_skip() else { return };
    let r = run_synth(&rt, Strategy::FedCore, 3, 7);
    let dir = std::env::temp_dir().join("fedcore_test_csv");
    let path = dir.join("run.csv");
    r.write_csv(&path).expect("write");
    let text = std::fs::read_to_string(&path).expect("read");
    assert_eq!(text.trim().lines().count(), 4); // header + 3 rounds
    std::fs::remove_dir_all(&dir).ok();
}
